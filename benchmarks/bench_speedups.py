"""Paper Figures 2, 3 & 5: speedups S_T / S_C / S_R vs number of
clusters k, for flat-multilevel (FM) and TopDown (TD) clustering."""

from benchmarks.common import corpus_and_log, row, timed
from repro.core.seclud import SecludPipeline


def run(quick: bool = True, corpus_name: str = "forum"):
    n_docs = 12000 if quick else 48000
    ks = (16, 64, 256) if quick else (16, 64, 256, 1024)
    n_eval = 300 if quick else 1000
    corpus, log = corpus_and_log(corpus_name, n_docs)
    pipe = SecludPipeline(tc=3000 if quick else 10000, doc_grained_below=512)
    rows = []
    for algo in ("topdown", "flat"):
        for k in ks:
            if algo == "flat" and k > 256:
                continue  # paper Fig 6: flat is superlinear in k
            res, t_fit = timed(
                pipe.fit, corpus, k, algo=algo, log=log, repeats=1
            )
            ev = pipe.evaluate(corpus, res, log, max_queries=n_eval)
            rows.append(
                row(
                    f"speedups/{corpus_name}/{algo}/k{k}",
                    t_fit,
                    f"S_T={ev['S_T']:.2f};S_C={ev['S_C']:.2f};"
                    f"S_R={ev['S_R']:.2f};k_actual={res.k}",
                )
            )
    return rows
