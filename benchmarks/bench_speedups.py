"""Paper Figures 2, 3 & 5: speedups S_T / S_C / S_R vs number of
clusters k, for flat-multilevel (FM) and TopDown (TD) clustering —
plus the wall-clock rows of the batched conjunctive-query engine vs the
per-query Python loop (``batched_engine*``, part of the CI smoke set):
the historical 2-term row and arity-3 / arity-5 rows exercising the
cost-ordered k-way chain.

Two further row families (also in the smoke set):

* ``hier_engine/L{1,2,3}`` — the arbitrary-depth hierarchical index
  (``repro.core.hier_index``) at depths 1 (flat Lookup), 2 (the paper's
  cluster index) and 3 (super-clusters): exactness is asserted across
  depths and against ``np.intersect1d``, and the rows report the
  work/wall-clock trade-off as depth grows.
* ``adaptive_vs_lookup`` — the paper's §6 future-work symmetric Lookup
  (``adaptive_intersect``), measured (it was implemented and tested but
  never benchmarked) against ``lookup_intersect`` on the clustered
  (reordered) vs random document orderings.
"""

import sys

import numpy as np

from benchmarks.common import corpus_and_log, row, timed
from repro.core.batched_query import batched_counts, batched_query
from repro.core.seclud import SecludPipeline
from repro.data.query_log import synth_query_log
from repro.index.lookup import adaptive_intersect, lookup_work


def _batched_engine_row(corpus_name, res, queries, suffix=""):
    """Wall-clock: per-query ``ClusterIndex.query`` loop vs the batched
    engine (host exact path + device count path) on the same queries.

    The device path is the fused upload-once engine
    (``repro.core.device_engine``): its wall-clock must not lose to the
    host path (gated via the ``device_s``/``host_s`` fields by
    ``benchmarks.compare``) and its packing waste must stay within the
    pad-to-bin-max budget (asserted here: overhead <= 1.3)."""
    cidx = res.cluster_index

    def loop():
        return [cidx.query(*terms)[0] for terms in queries]

    loop_docs, t_loop = timed(loop, repeats=1)
    (ptr, docs, _work), t_host = timed(batched_query, cidx, queries, repeats=3)
    (counts, info), t_dev = timed(batched_counts, cidx, queries, repeats=3)
    # The engine's exactness guarantee, checked on every benchmark run.
    assert np.array_equal(np.diff(ptr), counts)
    assert np.array_equal(docs, np.concatenate(loop_docs + [np.empty(0, np.int32)]))
    # The tighter packing scheme's contract: materialized cells stay
    # within 1.3x of true cells (the pow2-per-pair scheme ran 1.5-1.9x).
    assert info["padding_overhead"] <= 1.3, info["padding_overhead"]
    return row(
        f"speedups/{corpus_name}/batched_engine{suffix}/n{len(queries)}",
        t_host,
        f"loop_s={t_loop:.4f};host_s={t_host:.4f};device_s={t_dev:.4f};"
        f"host_speedup={t_loop / max(t_host, 1e-9):.1f}x;"
        f"device_speedup={t_loop / max(t_dev, 1e-9):.1f}x;"
        f"pad_overhead={info['padding_overhead']:.2f};"
        f"kernel_calls={info['n_kernel_calls']:.0f}",
    )


def _device_engine_rows(corpus_name, res, query_sets):
    """``device_engine/a{2,3,5}`` rows: the persistent-``DeviceIndex``
    serving path in isolation — plan (work-free mode) + lower + one fused
    fold against the resident index, exactness asserted against the host
    engine, with the per-stage padding/occupancy attribution the fused
    layout reports."""
    from repro.core.device_engine import device_counts, device_index

    cidx = res.cluster_index
    # fit() already uploaded the index; this is the cached resident copy.
    dindex = device_index(cidx)
    rows = []
    for arity, queries in query_sets:
        (ptr, docs_host, _w), _ = timed(batched_query, cidx, queries, repeats=1)
        (counts, docs_dev, info), t_exec = timed(
            device_counts, cidx, queries, dindex=dindex, return_docs=True,
            repeats=3,
        )
        assert np.array_equal(np.diff(ptr), counts), f"device a{arity} counts"
        assert np.array_equal(docs_host, docs_dev), f"device a{arity} docs"
        assert info["padding_overhead"] <= 1.3
        stage_pad = ",".join(
            f"{s['padding_overhead']:.2f}" for s in info["stages"]
        ) or "-"
        rows.append(
            row(
                f"speedups/{corpus_name}/device_engine/a{arity}",
                t_exec,
                f"exec_s={t_exec:.4f};"
                f"resident_mb={dindex.nbytes / 1e6:.1f};"
                f"n_pairs={info['n_pairs']:.0f};"
                f"kernel_calls={info['n_kernel_calls']:.0f};"
                f"pad_overhead={info['padding_overhead']:.2f};"
                f"occupancy={info['occupancy']:.2f};"
                f"stage_pad={stage_pad}",
            )
        )
    return rows


def _sharded_engine_rows(corpus_name, res, queries, shard_counts=(1, 2, 4, 8)):
    """``sharded_engine/s{1,2,4,8}`` rows: the mesh-sharded serving path
    (``repro.core.device_engine.sharded_device_counts``), exactness
    asserted against the host engine at every shard count.

    On the fake CPU device grid every shard shares one physical machine,
    so wall-clock cannot exhibit the scaling — the gated quantities are
    the deterministic load-balance model the partition earns:
    ``agg_throughput`` = total true cells / max per-shard true cells (the
    aggregate-speedup bound of running shards concurrently) and
    ``efficiency`` = agg_throughput / n_shards.  Both are exact functions
    of the (seeded, reproducible) corpus + plan, so ``benchmarks.compare``
    gates them strictly; measured exec_s/qps ride along informationally.
    """
    import jax

    from repro.core.device_engine import (
        shard_mesh,
        sharded_device_counts,
        sharded_device_index,
    )

    cidx = res.cluster_index
    (ptr, docs_host, _w), _ = timed(batched_query, cidx, queries, repeats=1)
    host_counts = np.diff(ptr)
    n_dev = len(jax.devices())
    usable = [s for s in shard_counts if s <= n_dev]
    dropped = [s for s in shard_counts if s > n_dev]
    if dropped:
        print(
            f"# sharded_engine: dropped s={dropped} rows — only {n_dev} "
            "device(s) visible (set XLA_FLAGS="
            "--xla_force_host_platform_device_count=8)",
            file=sys.stderr,
        )
    rows = []
    for s in usable:
        sidx = sharded_device_index(cidx, mesh=shard_mesh(s))
        (counts, docs, info), t_exec = timed(
            sharded_device_counts,
            cidx,
            queries,
            sidx=sidx,
            return_docs=True,
            repeats=3,
        )
        assert np.array_equal(counts, host_counts), f"sharded s{s} counts"
        assert np.array_equal(docs, docs_host), f"sharded s{s} docs"
        qps = len(queries) / max(t_exec, 1e-9)
        rows.append(
            row(
                f"speedups/{corpus_name}/sharded_engine/s{s}",
                t_exec,
                f"exec_s={t_exec:.4f};qps={qps:.0f};"
                f"agg_throughput={info['agg_throughput']:.3f};"
                f"efficiency={info['agg_throughput'] / s:.3f};"
                f"shards_touched={info['shards_touched']:.0f};"
                f"resident_mb={sidx.nbytes / 1e6:.1f}",
            )
        )
    return rows


def _hier_engine_rows(corpus_name, pipe, corpus, log, k, n_queries, index, prefit=None):
    """L ∈ {1, 2, 3} rows through the batched hierarchical engine: every
    depth must return the identical result sets (asserted, plus an
    ``np.intersect1d`` spot oracle); the derived fields record how work
    shifts between the cluster levels and the postings as depth grows.
    ``prefit`` maps a depth to an already-fitted ``(result, fit_seconds)``
    — the sweep's last TopDown fit IS the L = 2 fit, so it is reused
    rather than re-run."""
    cq = log.as_conjunctive()[:n_queries]
    rows = []
    ref = None
    for levels in (1, 2, 3):
        if prefit and levels in prefit:
            res, t_fit = prefit[levels]
        else:
            res, t_fit = timed(
                pipe.fit, corpus, k, algo="topdown", log=log, levels=levels,
                repeats=1,
            )
        hidx = res.hier_index
        assert hidx.depth == levels
        (ptr, docs, work), t_host = timed(batched_query, hidx, cq, repeats=3)
        # Canonicalize in original-id space: exactness across depths.
        inv = np.empty(len(res.perm), np.int64)
        inv[res.perm] = np.arange(len(res.perm))
        counts = np.diff(ptr)
        qid = np.repeat(np.arange(cq.n_queries), counts)
        canon = inv[docs]
        canon = canon[np.lexsort((canon, qid))]
        if ref is None:
            ref = (counts, canon)
            for i in range(0, cq.n_queries, max(cq.n_queries // 5, 1)):
                terms = cq.terms(i)
                want = index.postings(int(terms[0]))
                for t in terms[1:]:
                    want = np.intersect1d(want, index.postings(int(t)))
                got = np.sort(inv[docs[ptr[i] : ptr[i + 1]]])
                assert np.array_equal(got, want), f"hier L=1 oracle, query {i}"
        else:
            assert np.array_equal(ref[0], counts), f"hier L={levels} counts"
            assert np.array_equal(ref[1], canon), f"hier L={levels} results"
        level_ks = "-".join(str(lev.k) for lev in hidx.levels) or "1"
        rows.append(
            row(
                f"speedups/{corpus_name}/hier_engine/L{levels}",
                t_host,
                f"k={level_ks};work={work['total']:.0f};"
                f"cluster_level={work['cluster_level']:.0f};"
                f"probes={work['probes']:.0f};scanned={work['scanned']:.0f};"
                f"host_s={t_host:.4f};fit_s={t_fit:.2f}",
            )
        )
    return rows


def _adaptive_vs_lookup_row(corpus_name, res, queries, n_pairs=200):
    """Work of the §6 ``adaptive_intersect`` vs plain ``lookup_intersect``
    on the same 2-term queries, on the clustered (reordered) and random
    (baseline) orderings — the measurement the implementation never had."""
    pairs = [tuple(int(t) for t in q[:2]) for q in queries[:n_pairs]]
    work = {}
    for tag, idx in (("clus", res.reordered_index), ("rand", res.base_index)):
        for algo, fn in (("lookup", lookup_work), ("adaptive", adaptive_intersect)):
            total = 0
            for t, u in pairs:
                r, w = fn(idx.postings(t), idx.postings(u), idx.n_docs, 16)
                total += w["total"]
            work[f"{algo}_{tag}"] = total

    def _run_clustered():
        for t, u in pairs:
            adaptive_intersect(
                res.reordered_index.postings(t),
                res.reordered_index.postings(u),
                res.reordered_index.n_docs,
                16,
            )

    _, t_adaptive = timed(_run_clustered, repeats=1)
    return row(
        f"speedups/{corpus_name}/adaptive_vs_lookup/n{len(pairs)}",
        t_adaptive,
        f"lookup_clus={work['lookup_clus']};adaptive_clus={work['adaptive_clus']};"
        f"lookup_rand={work['lookup_rand']};adaptive_rand={work['adaptive_rand']};"
        f"ratio_clus={work['adaptive_clus'] / max(work['lookup_clus'], 1):.3f};"
        f"ratio_rand={work['adaptive_rand'] / max(work['lookup_rand'], 1):.3f}",
    )


def run(quick: bool = True, corpus_name: str = "forum"):
    n_docs = 12000 if quick else 48000
    ks = (16, 64, 256) if quick else (16, 64, 256, 1024)
    n_eval = 300 if quick else 1000
    n_bench = 1000 if quick else 2000  # batched-engine wall-clock queries
    corpus, log = corpus_and_log(corpus_name, n_docs)
    pipe = SecludPipeline(tc=3000 if quick else 10000, doc_grained_below=512)
    rows = []
    last_td = None
    last_td_fit_s = 0.0
    for algo in ("topdown", "flat"):
        for k in ks:
            if algo == "flat" and k > 256:
                continue  # paper Fig 6: flat is superlinear in k
            res, t_fit = timed(
                pipe.fit, corpus, k, algo=algo, log=log, repeats=1
            )
            if algo == "topdown":
                last_td, last_td_fit_s = res, t_fit
            ev = pipe.evaluate(corpus, res, log, max_queries=n_eval, batched=True)
            rows.append(
                row(
                    f"speedups/{corpus_name}/{algo}/k{k}",
                    t_fit,
                    f"S_T={ev['S_T']:.2f};S_C={ev['S_C']:.2f};"
                    f"S_R={ev['S_R']:.2f};k_actual={res.k}",
                )
            )
    # Arity-2 (the historical row whose name the CI perf gate tracks),
    # plus arity-3 / arity-5 conjunctions through the same engine.
    query_sets = [(2, log.as_conjunctive()[:n_bench])]
    rows.append(_batched_engine_row(corpus_name, last_td, query_sets[0][1]))
    for arity in (3, 5):
        alog = synth_query_log(
            corpus, n_queries=n_bench, co_topic=0.6, seed=arity, arity=arity
        )
        query_sets.append((arity, alog.as_conjunctive()))
        rows.append(
            _batched_engine_row(
                corpus_name,
                last_td,
                query_sets[-1][1],
                suffix=f"_a{arity}",
            )
        )
    # The persistent-DeviceIndex serving path on the same query sets.
    rows.extend(_device_engine_rows(corpus_name, last_td, query_sets))
    # Mesh-sharded serving at 1/2/4/8 shards (fake CPU devices in CI).
    rows.extend(_sharded_engine_rows(corpus_name, last_td, query_sets[0][1]))
    # Hierarchical engine at depths 1/2/3 (exactness asserted across
    # depths) and the §6 adaptive-vs-lookup work measurement.
    from repro.index.build import build_index

    rows.extend(
        _hier_engine_rows(
            corpus_name, pipe, corpus, log, ks[-1], n_eval, build_index(corpus),
            prefit={2: (last_td, last_td_fit_s)},
        )
    )
    rows.append(_adaptive_vs_lookup_row(corpus_name, last_td, log.queries))
    return rows
