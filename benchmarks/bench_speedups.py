"""Paper Figures 2, 3 & 5: speedups S_T / S_C / S_R vs number of
clusters k, for flat-multilevel (FM) and TopDown (TD) clustering —
plus the wall-clock rows of the batched conjunctive-query engine vs the
per-query Python loop (``batched_engine*``, part of the CI smoke set):
the historical 2-term row and arity-3 / arity-5 rows exercising the
cost-ordered k-way chain."""

import numpy as np

from benchmarks.common import corpus_and_log, row, timed
from repro.core.batched_query import batched_counts, batched_query
from repro.core.seclud import SecludPipeline
from repro.data.query_log import synth_query_log


def _batched_engine_row(corpus_name, res, queries, suffix=""):
    """Wall-clock: per-query ``ClusterIndex.query`` loop vs the batched
    engine (host exact path + device count path) on the same queries."""
    cidx = res.cluster_index

    def loop():
        return [cidx.query(*terms)[0] for terms in queries]

    loop_docs, t_loop = timed(loop, repeats=1)
    (ptr, docs, _work), t_host = timed(batched_query, cidx, queries, repeats=3)
    (counts, info), t_dev = timed(batched_counts, cidx, queries, repeats=3)
    # The engine's exactness guarantee, checked on every benchmark run.
    assert np.array_equal(np.diff(ptr), counts)
    assert np.array_equal(docs, np.concatenate(loop_docs + [np.empty(0, np.int32)]))
    return row(
        f"speedups/{corpus_name}/batched_engine{suffix}/n{len(queries)}",
        t_host,
        f"loop_s={t_loop:.4f};host_s={t_host:.4f};device_s={t_dev:.4f};"
        f"host_speedup={t_loop / max(t_host, 1e-9):.1f}x;"
        f"pad_overhead={info['padding_overhead']:.2f}",
    )


def run(quick: bool = True, corpus_name: str = "forum"):
    n_docs = 12000 if quick else 48000
    ks = (16, 64, 256) if quick else (16, 64, 256, 1024)
    n_eval = 300 if quick else 1000
    n_bench = 1000 if quick else 2000  # batched-engine wall-clock queries
    corpus, log = corpus_and_log(corpus_name, n_docs)
    pipe = SecludPipeline(tc=3000 if quick else 10000, doc_grained_below=512)
    rows = []
    last_td = None
    for algo in ("topdown", "flat"):
        for k in ks:
            if algo == "flat" and k > 256:
                continue  # paper Fig 6: flat is superlinear in k
            res, t_fit = timed(
                pipe.fit, corpus, k, algo=algo, log=log, repeats=1
            )
            if algo == "topdown":
                last_td = res
            ev = pipe.evaluate(corpus, res, log, max_queries=n_eval, batched=True)
            rows.append(
                row(
                    f"speedups/{corpus_name}/{algo}/k{k}",
                    t_fit,
                    f"S_T={ev['S_T']:.2f};S_C={ev['S_C']:.2f};"
                    f"S_R={ev['S_R']:.2f};k_actual={res.k}",
                )
            )
    # Arity-2 (the historical row whose name the CI perf gate tracks),
    # plus arity-3 / arity-5 conjunctions through the same engine.
    rows.append(
        _batched_engine_row(
            corpus_name, last_td, log.as_conjunctive()[:n_bench]
        )
    )
    for arity in (3, 5):
        alog = synth_query_log(
            corpus, n_queries=n_bench, co_topic=0.6, seed=arity, arity=arity
        )
        rows.append(
            _batched_engine_row(
                corpus_name,
                last_td,
                alog.as_conjunctive(),
                suffix=f"_a{arity}",
            )
        )
    return rows
