"""Wall-clock of the production batched-intersection paths (jnp reference
vs Pallas-in-interpret sanity) and the SeCluD search service device path
vs baseline single-index execution. On CPU these numbers are engineering
sanity checks; the TPU numbers come from the roofline analysis."""

import numpy as np

from benchmarks.common import corpus_and_log, row, timed
from repro.core.seclud import SecludPipeline
from repro.index.batched import batch_queries, count_intersections_jnp
from repro.serve.search_service import SearchService


def run(quick: bool = True):
    n_docs = 10000 if quick else 40000
    corpus, log = corpus_and_log("forum", n_docs)
    pipe = SecludPipeline(tc=3000, doc_grained_below=512)
    res = pipe.fit(corpus, 128, algo="topdown", log=log)
    queries = log.queries[:256]

    rows = []
    # Baseline: batched single-index intersection (padded bins).
    batched = batch_queries(res.base_index, queries)
    def run_baseline():
        total = 0
        for b in batched.bins:
            total += int(count_intersections_jnp(b.short, b.long).sum())
        return total
    n_base, t_base = timed(run_baseline, repeats=3)
    rows.append(
        row("device/baseline_batched", t_base,
            f"hits={n_base};pad_overhead={batched.padding_overhead():.2f}")
    )

    # SeCluD: cluster-routed segments (smaller padded problems).
    svc = SearchService(res)
    packed = svc.pack(queries)
    def run_clustered():
        return int(np.asarray(SearchService.device_counts(packed)).sum())
    n_clus, t_clus = timed(run_clustered, repeats=3)
    rows.append(
        row("device/seclud_packed", t_clus,
            f"hits={n_clus};rows={packed.short.shape};speedup={t_base / max(t_clus, 1e-9):.2f}")
    )
    assert n_base == n_clus, "lossless violation in device paths"
    return rows
