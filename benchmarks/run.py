"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # quick sizes
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale-ish
  PYTHONPATH=src python -m benchmarks.run --only speedups
"""

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        bench_cluster_time,
        bench_comparison_cost,
        bench_compression,
        bench_datasets,
        bench_kernels,
        bench_scaling,
        bench_speedups,
        bench_tc,
        roofline_table,
    )

    suites = {
        "datasets": bench_datasets,
        "speedups": bench_speedups,
        "scaling": bench_scaling,
        "cluster_time": bench_cluster_time,
        "tc": bench_tc,
        "compression": bench_compression,
        "comparison_cost": bench_comparison_cost,
        "kernels": bench_kernels,
        "roofline": roofline_table,
    }
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, mod in suites.items():
        if args.only and args.only != name:
            continue
        try:
            for r in mod.run(quick=quick):
                print(r, flush=True)
        except Exception as e:  # pragma: no cover
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
    print(f"# total {time.time() - t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
