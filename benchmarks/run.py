"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # quick sizes
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale-ish
  PYTHONPATH=src python -m benchmarks.run --only speedups
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI: fast subset,
                                                     # writes BENCH_smoke.json

``--smoke`` exists so every CI run appends one comparable data point to the
perf trajectory: quick sizes, a fixed suite subset, and a JSON artifact
(``--out``) the workflow uploads.
"""

import argparse
import json
import sys
import time

# Fast, deterministic-size suites: one clustering row, one index row, one
# kernel row, one serving-replay row set.  The heavy sweeps (scaling,
# datasets, roofline) stay out of the smoke path — CI budgets minutes,
# not hours.
SMOKE_SUITES = ("speedups", "compression", "kernels", "serving", "chaos")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="fast fixed subset; write a JSON artifact for CI")
    ap.add_argument("--out", default="BENCH_smoke.json",
                    help="artifact path for --smoke")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        bench_chaos,
        bench_cluster_time,
        bench_comparison_cost,
        bench_compression,
        bench_datasets,
        bench_kernels,
        bench_scaling,
        bench_serving,
        bench_speedups,
        bench_tc,
        roofline_table,
    )

    suites = {
        "datasets": bench_datasets,
        "speedups": bench_speedups,
        "scaling": bench_scaling,
        "cluster_time": bench_cluster_time,
        "tc": bench_tc,
        "compression": bench_compression,
        "comparison_cost": bench_comparison_cost,
        "kernels": bench_kernels,
        "serving": bench_serving,
        "chaos": bench_chaos,
        "roofline": roofline_table,
    }
    print("name,us_per_call,derived")
    rows = []
    errors = []
    t0 = time.time()
    for name, mod in suites.items():
        if args.only and args.only != name:
            continue
        if args.smoke and name not in SMOKE_SUITES:
            continue
        try:
            for r in mod.run(quick=quick):
                print(r, flush=True)
                rows.append(r)
        except Exception as e:  # pragma: no cover
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            errors.append({"suite": name, "error": f"{type(e).__name__}: {e}"})
    total_s = time.time() - t0
    print(f"# total {total_s:.0f}s", file=sys.stderr)

    if args.smoke:
        parsed = []
        for r in rows:
            parts = str(r).split(",", 2)
            if len(parts) < 2:
                continue
            try:
                us = float(parts[1])
            except ValueError:
                continue
            parsed.append({
                "name": parts[0],
                "us_per_call": us,
                "derived": parts[2] if len(parts) > 2 else "",
            })
        with open(args.out, "w") as f:
            json.dump(
                {
                    "suites": list(SMOKE_SUITES),
                    "quick": quick,
                    "total_seconds": round(total_s, 2),
                    "rows": parsed,
                    "errors": errors,
                },
                f,
                indent=2,
            )
        print(f"# wrote {args.out} ({len(parsed)} rows)", file=sys.stderr)
        if errors:
            # A silent hole in the perf trajectory is worse than a red CI
            # job: fail loudly when a smoke suite breaks.
            sys.exit(1)


if __name__ == "__main__":
    main()
