"""Render the dry-run JSON into the EXPERIMENTS.md roofline table."""

import json
import os


def render(paths=("dryrun_single.json", "dryrun_multi.json")) -> str:
    rows = []
    for p in paths:
        if os.path.exists(p):
            rows += json.load(open(p))
    if not rows:
        return "(no dry-run results found — run repro.launch.dryrun first)\n"
    hdr = (
        "| arch | shape | mesh | status | peak GiB/chip | compute ms | "
        "memory ms | collective ms | dominant | useful | roofline |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    out = [hdr]
    for r in rows:
        if r["status"] != "OK":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} "
                f"| — | — | — | — | — | — | {r.get('reason', r.get('error', ''))[:60]} |\n"
            )
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK "
            f"| {r['peak_memory_per_chip'] / 2**30:.1f} "
            f"| {r['compute_s'] * 1e3:.1f} | {r['memory_s'] * 1e3:.1f} "
            f"| {r['collective_s'] * 1e3:.2f} | {r['dominant']} "
            f"| {r['useful_flop_ratio']:.2f} | {r['roofline_fraction']:.2f} |\n"
        )
    return "".join(out)


def run(quick: bool = True):
    from benchmarks.common import row

    txt = render()
    n = txt.count("| OK")
    return [row("roofline/cells_ok", 0.0, f"ok={n}")]


if __name__ == "__main__":
    print(render())
