"""Paper Figure 4: speedups vs number of documents |D| at fixed k."""

from benchmarks.common import corpus_and_log, row
from repro.core.seclud import SecludPipeline


def run(quick: bool = True):
    sizes = (3000, 8000, 16000) if quick else (8000, 32000, 64000, 128000)
    k = 64 if quick else 256
    rows = []
    pipe = SecludPipeline(tc=3000, doc_grained_below=512)
    for n in sizes:
        corpus, log = corpus_and_log("gov2s", n)
        res = pipe.fit(corpus, k, algo="topdown", log=log)
        ev = pipe.evaluate(corpus, res, log, max_queries=300)
        rows.append(
            row(
                f"scaling/gov2s/n{n}",
                res.cluster_time_s,
                f"S_T={ev['S_T']:.2f};S_C={ev['S_C']:.2f};S_R={ev['S_R']:.2f}",
            )
        )
    return rows
