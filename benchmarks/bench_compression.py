"""Paper Appendix A / Figure 8: posting-list compression. Golomb wins
without clustering; Elias-gamma/delta win WITH clustering (reordered ids)."""

import numpy as np

from benchmarks.common import corpus_and_log, row
from repro.core.seclud import SecludPipeline
from repro.index.build import build_index, permute_docs
from repro.index.compress import index_bits_per_posting


def run(quick: bool = True):
    n_docs = 10000 if quick else 40000
    corpus, log = corpus_and_log("forum", n_docs)
    pipe = SecludPipeline(tc=3000, doc_grained_below=512)
    res = pipe.fit(corpus, 128 if quick else 1280, algo="topdown", log=log)
    idx = build_index(corpus)
    rng = np.random.default_rng(0)
    variants = {
        "random_order": permute_docs(idx, rng.permutation(corpus.n_docs)),
        "original_order": idx,
        "clustered_order": res.reordered_index,
    }
    rows = []
    for vname, vidx in variants.items():
        bits = index_bits_per_posting(vidx, codes=("golomb", "gamma", "delta", "varbyte"))
        rows.append(
            row(
                f"compression/{vname}",
                0.0,
                ";".join(f"{c}={b:.2f}bits" for c, b in bits.items()),
            )
        )
    return rows
