"""Paper Figure 7: effect of the frequent-term cutoff TC on speedup.
The paper finds TC=10k suffices for GOV2; we sweep TC on our corpus."""

from benchmarks.common import corpus_and_log, row
from repro.core.seclud import SecludPipeline


def run(quick: bool = True):
    n_docs = 10000 if quick else 40000
    tcs = (250, 1000, 4000) if quick else (500, 2000, 10000, 50000)
    k = 64 if quick else 256
    corpus, log = corpus_and_log("forum", n_docs)
    rows = []
    for tc in tcs:
        pipe = SecludPipeline(tc=tc, doc_grained_below=512)
        res = pipe.fit(corpus, k, algo="topdown", log=log)
        ev = pipe.evaluate(corpus, res, log, max_queries=300)
        rows.append(
            row(
                f"tc_sweep/tc{tc}",
                res.cluster_time_s,
                f"S_T={ev['S_T']:.2f};S_C={ev['S_C']:.2f};S_R={ev['S_R']:.2f}",
            )
        )
    return rows
