"""Serving-latency benchmark: open-loop Zipf replay through the deadline
batcher.

Rows (gated by ``benchmarks.compare``):

  serving/forum/replay/r{qps} — sealed-mode replay of a mixed-arity
  Zipf query log at target QPS.  ``us_per_call`` is the p50 request
  latency; ``derived`` carries sustained QPS, p50/p99/p999 ms, mean
  batch size / occupancy, the batch-size histogram, steady-state
  compile count (must stay 0 after the shape-grid prewarm — asserted
  here, gated in compare), and the prewarm's key/compile counts.

Standalone (the CI ``serving`` job):

  PYTHONPATH=src python -m benchmarks.bench_serving --smoke

writes a serving-only JSON in the same schema as ``benchmarks.run
--smoke``; the suite is also part of the combined smoke run.
"""

import argparse
import json
import sys
import time

import numpy as np

from benchmarks.common import row


def _service(quick: bool):
    from benchmarks.common import corpus_and_log
    from repro.core.seclud import SecludPipeline
    from repro.serve.search_service import SearchService

    n_docs = 8000 if quick else 24000
    corpus, log = corpus_and_log("forum", n_docs)
    pipe = SecludPipeline(tc=2000 if quick else 6000, doc_grained_below=512)
    res = pipe.fit(corpus, k=64, algo="topdown", log=log)
    return corpus, SearchService(res)


def run(quick: bool = True):
    from repro.core.device_engine import prewarm
    from repro.data.query_log import synth_query_log
    from repro.serve.loop import ServeConfig, plan_batches
    from repro.serve.replay import replay

    corpus, svc = _service(quick)
    cfg = ServeConfig(max_batch=64, deadline_s=0.002)
    n_queries = 600 if quick else 3000
    for qps in (500, 2000) if quick else (500, 2000, 8000):
        log = synth_query_log(
            corpus,
            n_queries=n_queries,
            co_topic=0.6,
            seed=17,
            arity=(1, 2, 3),
            arity_weights=(0.2, 0.6, 0.2),
            arrival_qps=float(qps),
        )
        cq = log.as_conjunctive()
        # Startup: compile the exact shape grid this trace will dispatch.
        batches = plan_batches(log.arrivals, cfg.max_batch, cfg.deadline_s)
        t0 = time.perf_counter()
        pw = prewarm(
            svc.query_index, cq, batches=batches, dindex=svc.device_index
        )
        prewarm_s = time.perf_counter() - t0
        rep = replay(svc, log, config=cfg, mode="sealed")
        # The acceptance bar, enforced where the numbers are made:
        # prewarmed steady-state serving never compiles, and batching
        # never changes results.
        assert rep.jit_compiles == 0, (
            f"steady state compiled {rep.jit_compiles}x after prewarm"
        )
        direct, _ = svc.serve_counts_device(cq)
        assert np.array_equal(rep.counts, direct), "replay counts diverged"
        s = rep.summary()
        hist = "/".join(
            f"{k}:{v}" for k, v in sorted(s["batch_hist"].items())
        )
        yield row(
            f"serving/forum/replay/r{qps}",
            s["p50_ms"] / 1e3,
            f"qps_offered={qps};qps_sustained={s['qps_sustained']:.1f};"
            f"p50_ms={s['p50_ms']:.3f};p99_ms={s['p99_ms']:.3f};"
            f"p999_ms={s['p999_ms']:.3f};mean_batch={s['mean_batch']:.1f};"
            f"occupancy={s['occupancy']:.3f};batches={s['n_batches']};"
            f"compiles_steady={rep.jit_compiles};"
            f"prewarm_keys={pw['n_keys']};prewarm_compiles={pw['n_compiles']};"
            f"prewarm_s={prewarm_s:.2f};n={n_queries};hist={hist}",
        )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="quick sizes; write a serving-only JSON artifact for CI",
    )
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)
    quick = not args.full

    print("name,us_per_call,derived")
    rows = []
    errors = []
    t0 = time.time()
    try:
        for r in run(quick=quick):
            print(r, flush=True)
            rows.append(r)
    except Exception as e:  # pragma: no cover
        print(f"serving/ERROR,0,{type(e).__name__}:{e}", flush=True)
        errors.append({"suite": "serving", "error": f"{type(e).__name__}: {e}"})
    total_s = time.time() - t0
    print(f"# total {total_s:.0f}s", file=sys.stderr)

    if args.smoke:
        parsed = []
        for r in rows:
            parts = str(r).split(",", 2)
            parsed.append(
                {
                    "name": parts[0],
                    "us_per_call": float(parts[1]),
                    "derived": parts[2] if len(parts) > 2 else "",
                }
            )
        with open(args.out, "w") as f:
            json.dump(
                {
                    "suites": ["serving"],
                    "quick": quick,
                    "total_seconds": round(total_s, 2),
                    "rows": parsed,
                    "errors": errors,
                },
                f,
                indent=2,
            )
        print(f"# wrote {args.out} ({len(parsed)} rows)", file=sys.stderr)
        if errors:
            sys.exit(1)


if __name__ == "__main__":
    main()
