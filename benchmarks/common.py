"""Shared corpus/log construction + timing helpers for the benchmarks."""

import functools
import time

import numpy as np

from repro.data.corpus import CorpusSpec, synth_corpus
from repro.data.query_log import synth_query_log


@functools.lru_cache(maxsize=8)
def corpus_and_log(name: str, n_docs: int, n_queries: int = 2000, seed: int = 0):
    spec = {
        "gov2": CorpusSpec.gov2_like,
        "gov2s": CorpusSpec.gov2s_like,
        "wiki": CorpusSpec.wiki_like,
        "forum": CorpusSpec.forum_like,
    }[name](n_docs=n_docs, seed=seed)
    corpus = synth_corpus(spec)
    log = synth_query_log(corpus, n_queries=n_queries, co_topic=0.6, seed=seed + 1)
    return corpus, log


def timed(fn, *args, repeats: int = 3, **kw):
    """(result, seconds) — median of repeats."""
    ts = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return out, float(np.median(ts))


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
