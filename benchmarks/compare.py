"""CI perf-regression gate: diff a fresh BENCH_smoke.json against the
committed BENCH_baseline.json.

Every CI run produces a smoke artifact (``benchmarks.run --smoke``); until
now nothing ever read it, so a PR could silently destroy the batched
engine's 22x win.  This gate fails the benchmark job when

  * a ``batched_engine*`` row's ``host_speedup`` drops more than
    ``--max-regression`` (default 25%) below the baseline — speedups are
    loop-vs-engine ratios measured on the same machine, so they transfer
    across runner generations;
  * a ``batched_engine*`` row's DEVICE path regresses: the gated quantity
    is ``device_s / host_s`` (both measured in the same run, so the ratio
    transfers across machines like ``host_speedup`` does) — it must not
    grow more than ``--max-regression`` over the baseline ratio, and a
    fresh ratio clearly above 1.0 (device losing to host outright; a 2%
    grace band absorbs timer noise at parity) fails whenever the
    baseline had it winning.  Baselines whose rows predate the
    ``device_s``/``host_s`` fields skip this check with a warning;
  * the smoke suite's total wall-clock grows more than
    ``--max-wallclock-regression`` (defaults to ``--max-regression``;
    catches "everything got slower" regressions the ratio hides).
    Absolute seconds do NOT transfer across machine classes — when the
    baseline was recorded on different hardware than the judge, pass a
    loose wall-clock tolerance (CI does) or re-baseline with ``--update``
    on the judging runner class;
  * a ``sharded_engine/s{N}`` row's scaling regresses: aggregate
    throughput (``agg_throughput=``, the deterministic load-balance
    model — total true cells / max per-shard true cells, an exact
    function of the seeded corpus, so it transfers across machines) must
    be monotone non-decreasing in the shard count, the scaling
    efficiency (``efficiency=`` = agg_throughput / shards) at the
    largest shard count must stay above the committed
    ``--min-scaling-efficiency`` floor, and that efficiency must not
    drop more than ``--max-regression`` below the baseline's;
  * a ``serving/*`` row's latency SLO regresses: p99 latency must not
    grow, and sustained QPS must not drop, more than
    ``--max-serving-regression`` (defaults to ``--max-regression``)
    versus the baseline — these are absolute measurements, so like
    wall-clock they need a loose tolerance when the baseline hardware
    differs from the judge — and the steady-state compile count
    (``compiles_steady=``, machine-independent: the shape-grid prewarm
    either covers the replay or it doesn't) must not exceed the
    baseline's (committed baselines carry 0);
  * a ``chaos/*`` row's resilience story breaks: ``exact`` must be 1 on
    every fresh chaos row (bit-identical answers under injected faults
    are machine-independent — there is no tolerance on correctness);
    ``recovery_batches`` (the degraded window after a shard loss) must
    stay within the committed ``MAX_RECOVERY_BATCHES`` bound and must
    not exceed the baseline's (the window is a pure function of the
    schedule and the retry budget, so it transfers across machines);
    ``frac_shed`` must not grow more than ``SHED_SLACK`` over the
    baseline (sheds are composition-deterministic but the committed
    slack absorbs batching drift); ``p99_degraded_ms`` — the p99 over
    *answered* requests while faults are live — is an absolute latency
    and gets the loose ``--max-serving-regression`` tolerance;
  * ANY row present in the baseline disappeared (a benchmark silently
    dropped is a hole in the trajectory, not a pass);
  * the fresh run recorded suite errors.

``--only-prefix serving/`` restricts both documents to rows under a
prefix before gating — how the standalone CI ``serving`` job judges its
serving-only artifact against the combined baseline without tripping
the row-disappearance check for suites it never ran (the wall-clock
check is skipped: a subset's total is not comparable).

Rows present in the fresh run but absent from the baseline are
TOLERATED with a warning (never a failure): a PR adding benchmarks must
not need a same-PR ``--update`` dance to stay green.  When such new rows
exist the wall-clock check is skipped too — the stale baseline total
cannot price work it never ran — and the warning says to re-baseline.

Usage:
    python -m benchmarks.run --smoke --out BENCH_smoke.json
    python -m benchmarks.compare BENCH_smoke.json            # gate
    python -m benchmarks.compare BENCH_smoke.json --update   # re-baseline
"""

from __future__ import annotations

import argparse
import json
import os
import re
import shutil
import sys
from pathlib import Path
from typing import Dict, List, Optional

DEFAULT_BASELINE = Path(__file__).resolve().parent.parent / "BENCH_baseline.json"
_SPEEDUP_RE = re.compile(r"host_speedup=([0-9.]+)x")
_HOST_S_RE = re.compile(r"host_s=([0-9.]+)")
_DEVICE_S_RE = re.compile(r"device_s=([0-9.]+)")
_SHARD_ROW_RE = re.compile(r"/sharded_engine/s(\d+)$")
_AGG_RE = re.compile(r"agg_throughput=([0-9.]+)")
_EFF_RE = re.compile(r"efficiency=([0-9.]+)")
_P50_RE = re.compile(r"p50_ms=([0-9.]+)")
_P99_RE = re.compile(r"p99_ms=([0-9.]+)")
_QPS_RE = re.compile(r"qps_sustained=([0-9.]+)")
_COMPILES_RE = re.compile(r"compiles_steady=(\d+)")
_RECOVERY_RE = re.compile(r"recovery_batches=(\d+)")
_FRAC_SHED_RE = re.compile(r"frac_shed=([0-9.]+)")
_P99_DEG_RE = re.compile(r"p99_degraded_ms=([0-9.]+)")
_EXACT_RE = re.compile(r"exact=(\d+)")
# Committed scaling-efficiency floor at the largest shard count: the
# posting-mass-balanced partition of the smoke corpus must keep at least
# this fraction of perfect linear scaling at s=8 (fake CPU devices; the
# metric is the deterministic load-balance model, so it is reproducible —
# the committed run measures 0.81 at s=8, the floor leaves headroom for
# clustering-side changes without tolerating a broken partitioner).
MIN_SCALING_EFFICIENCY = 0.6
# The device path must keep beating the host path; a hair above parity is
# tolerated so timer noise on a ~0.95 baseline can't flake CI, anything
# clearly above fails even inside the relative tolerance.
_CROSS_GRACE = 1.02
# Chaos gate: after an injected shard loss, the degraded window (batches
# served above the "device" rung) must close within this many batches —
# the committed bound on "failover is automatic and fast".  The default
# retry budget (3) matches strikes_to_evict (3), so the committed run
# recovers in 1 batch; the bound leaves room for policy tuning without
# tolerating a tier that limps for a whole replay.
MAX_RECOVERY_BATCHES = 4
# Allowed absolute growth in the shed fraction over the baseline: sheds
# are a deterministic function of the schedule and the batch plan, but
# batching-policy changes legitimately move a boundary batch or two.
SHED_SLACK = 0.15


def load(path: str | Path) -> dict:
    with open(path) as f:
        return json.load(f)


def engine_speedups(doc: dict) -> Dict[str, float]:
    """``batched_engine*`` row name -> host_speedup (loop / engine)."""
    out: Dict[str, float] = {}
    for r in doc.get("rows", []):
        name = r.get("name", "")
        if "/batched_engine" not in name:
            continue
        m = _SPEEDUP_RE.search(r.get("derived", ""))
        if m:
            out[name] = float(m.group(1))
    return out


def engine_device_ratios(doc: dict) -> Dict[str, float]:
    """``batched_engine*`` row name -> device_s / host_s (same-run ratio;
    < 1.0 means the device path wins).  Rows lacking either field — old
    baselines — are simply absent."""
    out: Dict[str, float] = {}
    for r in doc.get("rows", []):
        name = r.get("name", "")
        if "/batched_engine" not in name:
            continue
        derived = r.get("derived", "")
        mh = _HOST_S_RE.search(derived)
        md = _DEVICE_S_RE.search(derived)
        if mh and md and float(mh.group(1)) > 0:
            out[name] = float(md.group(1)) / float(mh.group(1))
    return out


def sharded_metrics(doc: dict) -> Dict[int, Dict[str, float]]:
    """Shard count -> {"agg": agg_throughput, "eff": efficiency} of the
    ``sharded_engine/s{N}`` rows (absent for pre-sharding baselines)."""
    out: Dict[int, Dict[str, float]] = {}
    for r in doc.get("rows", []):
        m = _SHARD_ROW_RE.search(r.get("name", ""))
        if not m:
            continue
        derived = r.get("derived", "")
        ma = _AGG_RE.search(derived)
        me = _EFF_RE.search(derived)
        if ma and me:
            out[int(m.group(1))] = {
                "agg": float(ma.group(1)),
                "eff": float(me.group(1)),
            }
    return out


def serving_metrics(doc: dict) -> Dict[str, Dict[str, float]]:
    """``serving/*`` row name -> {"p50", "p99", "qps", "compiles"} (rows
    lacking the latency fields — and pre-serving baselines — are
    absent).  ``compiles`` is the steady-state jit-compile count after
    the shape-grid prewarm; committed baselines carry 0."""
    out: Dict[str, Dict[str, float]] = {}
    for r in doc.get("rows", []):
        name = r.get("name", "")
        if not name.startswith("serving/"):
            continue
        derived = r.get("derived", "")
        m99 = _P99_RE.search(derived)
        mq = _QPS_RE.search(derived)
        if not (m99 and mq):
            continue
        m50 = _P50_RE.search(derived)
        mc = _COMPILES_RE.search(derived)
        out[name] = {
            "p50": float(m50.group(1)) if m50 else float("nan"),
            "p99": float(m99.group(1)),
            "qps": float(mq.group(1)),
            "compiles": float(mc.group(1)) if mc else 0.0,
        }
    return out


def chaos_metrics(doc: dict) -> Dict[str, Dict[str, Optional[float]]]:
    """``chaos/*`` row name -> {"recovery", "frac_shed", "p99_deg",
    "exact"} (each None when the row does not carry that field — the
    shard-loss row has no shed fraction, the brownout row no recovery
    window; pre-chaos baselines contribute nothing)."""
    out: Dict[str, Dict[str, Optional[float]]] = {}
    for r in doc.get("rows", []):
        name = r.get("name", "")
        if not name.startswith("chaos/"):
            continue
        derived = r.get("derived", "")
        mr = _RECOVERY_RE.search(derived)
        ms = _FRAC_SHED_RE.search(derived)
        mp = _P99_DEG_RE.search(derived)
        me = _EXACT_RE.search(derived)
        out[name] = {
            "recovery": float(mr.group(1)) if mr else None,
            "frac_shed": float(ms.group(1)) if ms else None,
            "p99_deg": float(mp.group(1)) if mp else None,
            "exact": float(me.group(1)) if me else None,
        }
    return out


def row_names(doc: dict) -> set:
    return {r.get("name", "") for r in doc.get("rows", [])}


def filter_prefix(doc: dict, prefix: str) -> dict:
    """The document restricted to rows whose name starts with ``prefix``
    — scoped gating for partial runs.  ``total_seconds`` is zeroed (a
    subset's wall-clock is not comparable to the full baseline's);
    fresh-run errors are kept (a broken partial run must still fail)."""
    return {
        **doc,
        "rows": [
            r
            for r in doc.get("rows", [])
            if r.get("name", "").startswith(prefix)
        ],
        "total_seconds": 0.0,
    }


def compare(
    baseline: dict,
    fresh: dict,
    max_regression: float = 0.25,
    max_wallclock_regression: float | None = None,
    warnings: List[str] | None = None,
    min_scaling_efficiency: float = MIN_SCALING_EFFICIENCY,
    max_serving_regression: float | None = None,
) -> List[str]:
    """Failure messages (empty = gate passes).

    Pass a list as ``warnings`` to collect non-fatal notes (rows newer
    than the baseline).
    """
    if max_wallclock_regression is None:
        max_wallclock_regression = max_regression
    if max_serving_regression is None:
        max_serving_regression = max_regression
    if warnings is None:
        warnings = []
    fails: List[str] = []
    base_sp = engine_speedups(baseline)
    fresh_sp = engine_speedups(fresh)
    if (
        not base_sp
        and not sharded_metrics(baseline)
        and not serving_metrics(baseline)
        and not chaos_metrics(baseline)
    ):
        fails.append(
            "baseline has no gateable rows (batched_engine / sharded / "
            "serving / chaos) — regenerate it"
        )
    for name, b in sorted(base_sp.items()):
        f = fresh_sp.get(name)
        if f is None:
            fails.append(f"{name}: row disappeared from the fresh run")
        elif f < b * (1.0 - max_regression):
            fails.append(
                f"{name}: host_speedup regressed {b:.1f}x -> {f:.1f}x "
                f"(> {max_regression:.0%} drop)"
            )
    # Device path: gate the same-run device_s/host_s ratio so a slow
    # device engine can't hide behind a healthy host speedup.
    base_dr = engine_device_ratios(baseline)
    fresh_dr = engine_device_ratios(fresh)
    for name, b in sorted(base_dr.items()):
        f = fresh_dr.get(name)
        if f is None:
            if name in fresh_sp:
                warnings.append(
                    f"{name}: fresh row has no device_s/host_s fields — "
                    "device-path gate skipped"
                )
            continue  # missing-row failure already reported above
        if f > b * (1.0 + max_regression):
            fails.append(
                f"{name}: device/host ratio regressed {b:.2f} -> {f:.2f} "
                f"(> {max_regression:.0%} growth)"
            )
        elif f > _CROSS_GRACE and b <= 1.0:
            fails.append(
                f"{name}: device path lost to the host path "
                f"(ratio {b:.2f} -> {f:.2f} crossed 1.0)"
            )
    # Shard-scaling gate: monotone aggregate throughput, efficiency floor
    # at the largest shard count, and no efficiency regression vs the
    # baseline.  The metric is the deterministic load-balance model (not
    # wall-clock), so strict monotonicity is safe to require.
    base_sh = sharded_metrics(baseline)
    fresh_sh = sharded_metrics(fresh)
    if fresh_sh:
        counts = sorted(fresh_sh)
        for lo, hi in zip(counts, counts[1:], strict=False):
            if fresh_sh[hi]["agg"] < fresh_sh[lo]["agg"]:
                fails.append(
                    f"sharded_engine: aggregate throughput not monotone — "
                    f"s{lo}={fresh_sh[lo]['agg']:.2f} > "
                    f"s{hi}={fresh_sh[hi]['agg']:.2f}"
                )
        top = counts[-1]
        eff = fresh_sh[top]["eff"]
        if len(counts) > 1 and eff < min_scaling_efficiency:
            fails.append(
                f"sharded_engine: scaling efficiency at s{top} = {eff:.2f} "
                f"below the committed floor {min_scaling_efficiency:.2f}"
            )
        if base_sh:
            if top in base_sh:
                b = base_sh[top]["eff"]
                if eff < b * (1.0 - max_regression):
                    fails.append(
                        f"sharded_engine: s{top} efficiency regressed "
                        f"{b:.2f} -> {eff:.2f} (> {max_regression:.0%} drop)"
                    )
            btop = max(base_sh)
            if btop not in fresh_sh:
                fails.append(
                    f"sharded_engine: baseline's largest shard count "
                    f"s{btop} disappeared from the fresh run"
                )
    elif base_sh:
        fails.append(
            "sharded_engine: baseline has sharded rows but the fresh run "
            "has none"
        )
    # Serving-SLO gate: p99 latency and sustained QPS are absolute
    # measurements (loose tolerance when hardware differs, like
    # wall-clock); the steady-state compile count is machine-independent
    # and must never grow — a compile appearing after prewarm means the
    # shape grid no longer covers the replay.
    base_srv = serving_metrics(baseline)
    fresh_srv = serving_metrics(fresh)
    for name, b in sorted(base_srv.items()):
        f = fresh_srv.get(name)
        if f is None:
            continue  # the generic row-disappearance check reports it
        if f["p99"] > b["p99"] * (1.0 + max_serving_regression):
            fails.append(
                f"{name}: p99 latency regressed {b['p99']:.2f}ms -> "
                f"{f['p99']:.2f}ms (> {max_serving_regression:.0%} growth)"
            )
        if f["qps"] < b["qps"] * (1.0 - max_serving_regression):
            fails.append(
                f"{name}: sustained QPS regressed {b['qps']:.0f} -> "
                f"{f['qps']:.0f} (> {max_serving_regression:.0%} drop)"
            )
        if f["compiles"] > b["compiles"]:
            fails.append(
                f"{name}: steady-state jit compiles after prewarm "
                f"({b['compiles']:.0f} -> {f['compiles']:.0f}) — the "
                "shape-grid prewarm no longer covers the replay"
            )
    # Chaos-resilience gate.  Exactness is absolute: a chaos row that
    # answered anything wrong fails regardless of what the baseline says
    # — there is no tolerance on correctness.  The recovery window and
    # shed fraction are schedule-deterministic (bounded absolutely and
    # against the baseline); the degraded p99 is wall-clock and gets the
    # loose serving tolerance.
    base_ch = chaos_metrics(baseline)
    fresh_ch = chaos_metrics(fresh)
    for name, f in sorted(fresh_ch.items()):
        if f["exact"] is not None and f["exact"] != 1.0:
            fails.append(
                f"{name}: non-shed responses diverged from the host "
                "engine (exact=0) — resilience must never change answers"
            )
        if f["recovery"] is not None and f["recovery"] > MAX_RECOVERY_BATCHES:
            fails.append(
                f"{name}: recovery took {f['recovery']:.0f} batches "
                f"(> committed bound {MAX_RECOVERY_BATCHES}) — failover "
                "is no longer prompt"
            )
    for name, b in sorted(base_ch.items()):
        f = fresh_ch.get(name)
        if f is None:
            continue  # the generic row-disappearance check reports it
        if (
            b["recovery"] is not None
            and f["recovery"] is not None
            and f["recovery"] > b["recovery"]
        ):
            fails.append(
                f"{name}: recovery window grew {b['recovery']:.0f} -> "
                f"{f['recovery']:.0f} batches over the baseline"
            )
        if (
            b["frac_shed"] is not None
            and f["frac_shed"] is not None
            and f["frac_shed"] > b["frac_shed"] + SHED_SLACK
        ):
            fails.append(
                f"{name}: shed fraction grew {b['frac_shed']:.3f} -> "
                f"{f['frac_shed']:.3f} (> +{SHED_SLACK} over baseline)"
            )
        if (
            b["p99_deg"] is not None
            and f["p99_deg"] is not None
            and f["p99_deg"] > b["p99_deg"] * (1.0 + max_serving_regression)
        ):
            fails.append(
                f"{name}: degraded-path p99 regressed {b['p99_deg']:.2f}ms "
                f"-> {f['p99_deg']:.2f}ms "
                f"(> {max_serving_regression:.0%} growth)"
            )
    # ANY baseline row that vanished fails the gate — a benchmark
    # silently dropped is a hole in the perf trajectory, not a pass.
    # (batched_engine rows already failed above with a richer message.)
    base_only = sorted(row_names(baseline) - row_names(fresh) - set(base_sp))
    for name in base_only:
        fails.append(f"{name}: row disappeared from the fresh run")
    # New rows are progress, not regressions: warn so someone re-baselines,
    # never fail (a PR adding benches must not need a same-PR --update).
    fresh_only = sorted(row_names(fresh) - row_names(baseline))
    if fresh_only:
        warnings.append(
            f"{len(fresh_only)} row(s) not in the baseline (tolerated; "
            "re-baseline with --update to start gating them): "
            + ", ".join(fresh_only[:8])
            + (", ..." if len(fresh_only) > 8 else "")
        )
    bt = float(baseline.get("total_seconds", 0.0))
    ft = float(fresh.get("total_seconds", 0.0))
    if fresh_only:
        if bt > 0:
            warnings.append(
                "wall-clock check skipped: the baseline total does not "
                f"include the new rows (baseline {bt:.1f}s, fresh {ft:.1f}s)"
            )
    elif bt > 0 and ft > bt * (1.0 + max_wallclock_regression):
        fails.append(
            f"smoke wall-clock regressed {bt:.1f}s -> {ft:.1f}s "
            f"(> {max_wallclock_regression:.0%} growth)"
        )
    errs = fresh.get("errors") or []
    for e in errs:
        fails.append(f"suite {e.get('suite')}: {e.get('error')}")
    return fails


def write_step_summary(
    baseline: dict,
    fresh: dict,
    fails: List[str],
    warnings: List[str],
    path: str | None = None,
) -> Optional[str]:
    """Render the gate's verdict as GitHub-flavored markdown and append
    it to ``$GITHUB_STEP_SUMMARY`` (or ``path``) so the per-row speedups,
    device/host ratios and scaling efficiencies are readable on the run
    page without downloading artifacts.  No-op outside CI (returns the
    markdown either way, for tests)."""
    base_sp = engine_speedups(baseline)
    fresh_sp = engine_speedups(fresh)
    base_dr = engine_device_ratios(baseline)
    fresh_dr = engine_device_ratios(fresh)
    base_sh = sharded_metrics(baseline)
    fresh_sh = sharded_metrics(fresh)
    base_srv = serving_metrics(baseline)
    fresh_srv = serving_metrics(fresh)

    def cell(v, fmt="{:.2f}"):
        return "–" if v is None else fmt.format(v)

    lines = [
        "## Perf gate: " + ("❌ FAILED" if fails else "✅ passed"),
        "",
        "| engine row | host_speedup (base → fresh) | device/host (base → fresh) |",
        "|---|---|---|",
    ]
    for name in sorted(set(base_sp) | set(fresh_sp)):
        lines.append(
            f"| `{name}` "
            f"| {cell(base_sp.get(name), '{:.1f}x')} → "
            f"{cell(fresh_sp.get(name), '{:.1f}x')} "
            f"| {cell(base_dr.get(name))} → {cell(fresh_dr.get(name))} |"
        )
    if base_sh or fresh_sh:
        lines += [
            "",
            "| shards | agg throughput (base → fresh) | efficiency (base → fresh) |",
            "|---|---|---|",
        ]
        for s in sorted(set(base_sh) | set(fresh_sh)):
            b, f = base_sh.get(s), fresh_sh.get(s)
            lines.append(
                f"| s{s} "
                f"| {cell(b and b['agg'])} → {cell(f and f['agg'])} "
                f"| {cell(b and b['eff'])} → {cell(f and f['eff'])} |"
            )
    if base_srv or fresh_srv:
        lines += [
            "",
            "| serving row | p50 ms (base → fresh) | p99 ms (base → fresh) "
            "| QPS (base → fresh) | steady compiles (base → fresh) |",
            "|---|---|---|---|---|",
        ]
        for name in sorted(set(base_srv) | set(fresh_srv)):
            b, f = base_srv.get(name), fresh_srv.get(name)
            lines.append(
                f"| `{name}` "
                f"| {cell(b and b['p50'])} → {cell(f and f['p50'])} "
                f"| {cell(b and b['p99'])} → {cell(f and f['p99'])} "
                f"| {cell(b and b['qps'], '{:.0f}')} → "
                f"{cell(f and f['qps'], '{:.0f}')} "
                f"| {cell(b and b['compiles'], '{:.0f}')} → "
                f"{cell(f and f['compiles'], '{:.0f}')} |"
            )
    base_ch = chaos_metrics(baseline)
    fresh_ch = chaos_metrics(fresh)
    if base_ch or fresh_ch:
        lines += [
            "",
            "| chaos row | exact | recovery batches (base → fresh) "
            "| frac shed (base → fresh) | degraded p99 ms (base → fresh) |",
            "|---|---|---|---|---|",
        ]
        for name in sorted(set(base_ch) | set(fresh_ch)):
            b, f = base_ch.get(name), fresh_ch.get(name)

            def opt(d, key, fmt="{:.2f}"):
                v = d.get(key) if d else None
                return "–" if v is None else fmt.format(v)

            lines.append(
                f"| `{name}` "
                f"| {opt(f, 'exact', '{:.0f}')} "
                f"| {opt(b, 'recovery', '{:.0f}')} → "
                f"{opt(f, 'recovery', '{:.0f}')} "
                f"| {opt(b, 'frac_shed', '{:.3f}')} → "
                f"{opt(f, 'frac_shed', '{:.3f}')} "
                f"| {opt(b, 'p99_deg')} → {opt(f, 'p99_deg')} |"
            )
    bt = baseline.get("total_seconds", 0)
    ft = fresh.get("total_seconds", 0)
    lines += ["", f"Smoke wall-clock: {bt}s → {ft}s"]
    if fails:
        lines += ["", "**Failures:**"] + [f"- {m}" for m in fails]
    if warnings:
        lines += ["", "**Warnings:**"] + [f"- {w}" for w in warnings]
    md = "\n".join(lines) + "\n"
    out = path if path is not None else os.environ.get("GITHUB_STEP_SUMMARY")
    if out:
        with open(out, "a") as fh:
            fh.write(md)
    return md


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="fresh BENCH_smoke.json to judge")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.25,
        help="allowed fractional drop in a batched_engine host_speedup",
    )
    ap.add_argument(
        "--max-wallclock-regression",
        type=float,
        default=None,
        help="allowed fractional growth in smoke wall-clock (default: "
        "--max-regression; set loose when baseline hardware differs "
        "from the judging runner)",
    )
    ap.add_argument(
        "--min-scaling-efficiency",
        type=float,
        default=MIN_SCALING_EFFICIENCY,
        help="committed scaling-efficiency floor at the largest "
        "sharded_engine shard count",
    )
    ap.add_argument(
        "--max-serving-regression",
        type=float,
        default=None,
        help="allowed fractional p99-latency growth / sustained-QPS drop "
        "on serving/* rows (default: --max-regression; set loose when "
        "baseline hardware differs from the judging runner — the "
        "steady-state compile gate stays exact regardless)",
    )
    ap.add_argument(
        "--only-prefix",
        default=None,
        help="gate only rows whose name starts with this prefix (e.g. "
        "'serving/' for the standalone serving job's partial artifact); "
        "skips the wall-clock check",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="copy the fresh run over the baseline instead of gating "
        "(run on the CI runner class the gate will judge on)",
    )
    args = ap.parse_args(argv)

    if args.update:
        if args.only_prefix:
            print(
                "--update with --only-prefix would overwrite the full "
                "baseline with a partial run; refusing",
                file=sys.stderr,
            )
            return 1
        shutil.copyfile(args.fresh, args.baseline)
        print(f"baseline updated: {args.baseline}")
        return 0

    baseline = load(args.baseline)
    fresh = load(args.fresh)
    if args.only_prefix:
        baseline = filter_prefix(baseline, args.only_prefix)
        fresh = filter_prefix(fresh, args.only_prefix)
    warnings: List[str] = []
    fails = compare(
        baseline,
        fresh,
        args.max_regression,
        args.max_wallclock_regression,
        warnings=warnings,
        min_scaling_efficiency=args.min_scaling_efficiency,
        max_serving_regression=args.max_serving_regression,
    )
    base_sp = engine_speedups(baseline)
    fresh_sp = engine_speedups(fresh)
    base_dr = engine_device_ratios(baseline)
    fresh_dr = engine_device_ratios(fresh)
    base_sh = sharded_metrics(baseline)
    fresh_sh = sharded_metrics(fresh)
    for name in sorted(set(base_sp) | set(fresh_sp)):
        b = base_sp.get(name)
        f = fresh_sp.get(name)
        bd = base_dr.get(name)
        fd = fresh_dr.get(name)
        print(
            f"{name}: baseline "
            f"{'-' if b is None else f'{b:.1f}x'} -> fresh "
            f"{'-' if f is None else f'{f:.1f}x'}; device/host "
            f"{'-' if bd is None else f'{bd:.2f}'} -> "
            f"{'-' if fd is None else f'{fd:.2f}'}"
        )
    def _fmt(d, key):
        return "-" if d is None else f"{d[key]:.2f}"

    for s in sorted(set(base_sh) | set(fresh_sh)):
        b = base_sh.get(s)
        f = fresh_sh.get(s)
        print(
            f"sharded_engine/s{s}: agg {_fmt(b, 'agg')} -> {_fmt(f, 'agg')}; "
            f"efficiency {_fmt(b, 'eff')} -> {_fmt(f, 'eff')}"
        )
    base_srv = serving_metrics(baseline)
    fresh_srv = serving_metrics(fresh)
    for name in sorted(set(base_srv) | set(fresh_srv)):
        b = base_srv.get(name)
        f = fresh_srv.get(name)
        print(
            f"{name}: p99 {_fmt(b, 'p99')}ms -> {_fmt(f, 'p99')}ms; "
            f"qps {_fmt(b, 'qps')} -> {_fmt(f, 'qps')}; "
            f"steady compiles {_fmt(b, 'compiles')} -> {_fmt(f, 'compiles')}"
        )
    base_ch = chaos_metrics(baseline)
    fresh_ch = chaos_metrics(fresh)

    def _opt(d, key):
        v = d.get(key) if d else None
        return "-" if v is None else f"{v:.2f}"

    for name in sorted(set(base_ch) | set(fresh_ch)):
        b = base_ch.get(name)
        f = fresh_ch.get(name)
        print(
            f"{name}: exact {_opt(f, 'exact')}; recovery "
            f"{_opt(b, 'recovery')} -> {_opt(f, 'recovery')}; frac_shed "
            f"{_opt(b, 'frac_shed')} -> {_opt(f, 'frac_shed')}; "
            f"degraded p99 {_opt(b, 'p99_deg')}ms -> {_opt(f, 'p99_deg')}ms"
        )
    print(
        f"wall-clock: baseline {baseline.get('total_seconds', 0)}s -> "
        f"fresh {fresh.get('total_seconds', 0)}s"
    )
    for w in warnings:
        print(f"WARNING: {w}", file=sys.stderr)
    write_step_summary(baseline, fresh, fails, warnings)
    if fails:
        print("\nPERF GATE FAILED:", file=sys.stderr)
        for m in fails:
            print(f"  - {m}", file=sys.stderr)
        return 1
    print("perf gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
