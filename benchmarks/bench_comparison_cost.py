"""Paper Appendix B / Figure 9: theoretical speedup under the
comparison-based intersection cost model Phi(x,y)=x*log(y/x) vs the
Lookup model Phi=min. The paper finds the comparison-based model predicts
even larger speedups from the same clustering."""

from benchmarks.common import corpus_and_log, row
from repro.core.objective import query_set_cost
from repro.core.seclud import SecludPipeline


def run(quick: bool = True):
    n_docs = 10000 if quick else 40000
    corpus, log = corpus_and_log("forum", n_docs)
    pipe = SecludPipeline(tc=3000, doc_grained_below=512)
    res = pipe.fit(corpus, 128, algo="topdown", log=log)
    q = log.queries[:400]
    rows = []
    for model in ("lookup", "comparison", "binary_search", "merge"):
        base = query_set_cost(corpus, None, 1, q, model=model)
        clus = query_set_cost(corpus, res.assign, res.k, q, model=model)
        rows.append(
            row(
                f"cost_model/{model}",
                0.0,
                f"S_T={base / max(clus, 1e-9):.2f}",
            )
        )
    return rows
