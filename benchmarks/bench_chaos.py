"""Chaos benchmark: serving resilience under injected faults.

Rows (gated by ``benchmarks.compare``):

  chaos/forum/shard_loss — sealed chaos replay with one shard's device
  dying mid-run.  ``recovery_batches`` is the width of the degraded
  window (batches served at any rung above "device"); the gate bounds
  it and requires ``exact=1`` — every response bit-identical to the
  host engine, before, during and after the eviction+re-partition.

  chaos/forum/brownout — a queue-flood window past the brownout
  threshold.  ``frac_shed`` is the refused fraction (gated against
  baseline + slack), ``p99_degraded_ms`` the p99 over *answered*
  requests while shedding is in play, and ``exact=1`` covers every
  non-shed response.

Standalone (the CI ``chaos`` job):

  PYTHONPATH=src python -m benchmarks.bench_chaos --smoke

writes a chaos-only JSON in the same schema as ``benchmarks.run
--smoke``; the suite is also part of the combined smoke run.
"""

import argparse
import json
import sys
import time

import numpy as np

from benchmarks.common import row


def _service(quick: bool, n_shards: int):
    from benchmarks.common import corpus_and_log
    from repro.core.seclud import SecludPipeline
    from repro.serve.search_service import SearchService

    n_docs = 8000 if quick else 24000
    corpus, log = corpus_and_log("forum", n_docs)
    pipe = SecludPipeline(tc=2000 if quick else 6000, doc_grained_below=512)
    res = pipe.fit(corpus, k=64, algo="topdown", log=log)
    svc = SearchService(res)
    svc.enable_sharded(n_shards=n_shards, strikes_to_evict=3)
    return corpus, svc


def _degraded_window(levels):
    """Batches from the first to the last non-"device" rung, inclusive —
    how long the tier took to get back to clean device serving."""
    hit = [i for i, lv in enumerate(levels) if lv != "device"]
    return (hit[-1] - hit[0] + 1) if hit else 0


def run(quick: bool = True):
    import jax

    from repro.serve.faults import SHED, FaultSchedule
    from repro.serve.loop import ServeConfig
    from repro.serve.replay import replay
    from repro.serve.resilience import ResilienceConfig

    n_shards = min(4, jax.device_count())
    cfg = ServeConfig(max_batch=64, deadline_s=0.002)
    n_queries = 400 if quick else 2000
    qps = 2000.0

    def fresh():
        from repro.data.query_log import synth_query_log

        corpus, svc = _service(quick, n_shards)
        log = synth_query_log(
            corpus,
            n_queries=n_queries,
            co_topic=0.6,
            seed=17,
            arity=(1, 2, 3),
            arity_weights=(0.2, 0.6, 0.2),
            arrival_qps=qps,
        )
        return svc, log

    # -- shard loss: die at batch 2, recover via evict + re-partition ----
    svc, log = fresh()
    cq = log.as_conjunctive()
    truth, _ = svc.serve_counts(cq)
    epoch0 = svc._elastic.epoch
    rc = ResilienceConfig(dispatch_timeout_s=1e9)
    rep = replay(
        svc,
        log,
        config=cfg,
        mode="sealed",
        faults=FaultSchedule.shard_loss(0, at=2),
        resilience=rc,
    )
    s = rep.summary()
    exact = int(np.array_equal(rep.counts, truth))
    recovery = _degraded_window(rep.stats.batch_levels)
    yield row(
        "chaos/forum/shard_loss",
        s["p50_ms"] / 1e3,
        f"n_shards={n_shards};shards_after={svc.n_shards};"
        f"evictions={svc._elastic.epoch - epoch0};"
        f"recovery_batches={recovery};"
        f"max_attempts={s['max_attempts']};exact={exact};"
        f"p50_ms={s['p50_ms']:.3f};p99_ms={s['p99_ms']:.3f};"
        f"batches={s['n_batches']};n={n_queries}",
    )

    # -- brownout: flood past the shed threshold, answer the rest -------
    svc, log = fresh()
    cq = log.as_conjunctive()
    truth, _ = svc.serve_counts(cq)
    rc = ResilienceConfig(dispatch_timeout_s=1e9, shed_queue_depth=500)
    rep = replay(
        svc,
        log,
        config=cfg,
        mode="sealed",
        faults=FaultSchedule.flood(at=3, depth=600, n_batches=3),
        resilience=rc,
    )
    s = rep.summary()
    shed = rep.counts == SHED
    exact = int(np.array_equal(rep.counts[~shed], truth[~shed]))
    p99_deg = rep.stats.percentile_ms(99, outcome="ok")
    yield row(
        "chaos/forum/brownout",
        s["p50_ms"] / 1e3,
        f"n_shards={n_shards};frac_shed={s['frac_shed']:.4f};"
        f"n_shed={s['n_shed']};shed_batches={len(rep.stats.shed_batches)};"
        f"p99_degraded_ms={p99_deg:.3f};exact={exact};"
        f"batches={s['n_batches']};n={n_queries}",
    )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="quick sizes; write a chaos-only JSON artifact for CI",
    )
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args(argv)
    quick = not args.full

    print("name,us_per_call,derived")
    rows = []
    errors = []
    t0 = time.time()
    try:
        for r in run(quick=quick):
            print(r, flush=True)
            rows.append(r)
    except Exception as e:  # pragma: no cover
        print(f"chaos/ERROR,0,{type(e).__name__}:{e}", flush=True)
        errors.append({"suite": "chaos", "error": f"{type(e).__name__}: {e}"})
    total_s = time.time() - t0
    print(f"# total {total_s:.0f}s", file=sys.stderr)

    if args.smoke:
        parsed = []
        for r in rows:
            parts = str(r).split(",", 2)
            parsed.append(
                {
                    "name": parts[0],
                    "us_per_call": float(parts[1]),
                    "derived": parts[2] if len(parts) > 2 else "",
                }
            )
        with open(args.out, "w") as f:
            json.dump(
                {
                    "suites": ["chaos"],
                    "quick": quick,
                    "total_seconds": round(total_s, 2),
                    "rows": parsed,
                    "errors": errors,
                },
                f,
                indent=2,
            )
        print(f"# wrote {args.out} ({len(parsed)} rows)", file=sys.stderr)
        if errors:
            sys.exit(1)


if __name__ == "__main__":
    main()
