"""Paper Tables 1-2 + Figure 1: corpus/log statistics and the Zipf shape
of term query-probabilities."""

import numpy as np

from benchmarks.common import corpus_and_log, row
from repro.data.corpus import corpus_stats
from repro.data.query_log import term_probabilities
from repro.index.build import build_index


def run(quick: bool = True):
    rows = []
    sizes = {"gov2": 8000, "gov2s": 30000, "wiki": 10000, "forum": 12000}
    if not quick:
        sizes = {k: v * 4 for k, v in sizes.items()}
    for name, n in sizes.items():
        corpus, log = corpus_and_log(name, n)
        st = corpus_stats(corpus)
        idx = build_index(corpus)
        st["index_MB"] = round(idx.size_bytes() / 2**20, 1)
        st.update(log.stats())
        rows.append(row(f"datasets/{name}", 0.0, str(st).replace(",", ";")))
        # Fig 1: Zipf check — rank/probability log-log slope in [-1.5, -0.4]
        p = term_probabilities(corpus.n_terms, log=log)
        nz = np.sort(p[p > 0])[::-1][:2000]
        ranks = np.arange(1, len(nz) + 1)
        slope = np.polyfit(np.log(ranks), np.log(nz), 1)[0]
        rows.append(row(f"zipf_slope/{name}", 0.0, f"slope={slope:.2f}"))
    return rows
