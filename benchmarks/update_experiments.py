"""Inject the generated roofline table into EXPERIMENTS.md."""

import re

from benchmarks.roofline_table import render


def main() -> None:
    table = render()
    md = open("EXPERIMENTS.md").read()
    md = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\n## |\Z)",
        "<!-- ROOFLINE_TABLE -->\n\n" + table + "\n",
        md,
        flags=re.S,
    ) if "<!-- ROOFLINE_TABLE -->" in md else md
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md roofline table updated")


if __name__ == "__main__":
    main()
