"""Paper Figure 6: clustering wall time, flat (FM) vs TopDown (TD).
Flat grows superlinearly with k; TopDown is orders of magnitude faster."""

from benchmarks.common import corpus_and_log, row, timed
from repro.core.seclud import SecludPipeline


def run(quick: bool = True):
    n_docs = 8000 if quick else 32000
    ks = (16, 64, 128) if quick else (16, 64, 256, 1024)
    corpus, log = corpus_and_log("wiki", n_docs)
    pipe = SecludPipeline(tc=2000, doc_grained_below=512)
    rows = []
    for algo in ("flat", "topdown"):
        for k in ks:
            if algo == "flat" and k > 64 and quick:
                continue
            _, t = timed(pipe.fit, corpus, k, algo=algo, log=log, repeats=1)
            rows.append(row(f"cluster_time/{algo}/k{k}", t, f"n={n_docs}"))
    return rows
