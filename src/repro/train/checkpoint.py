"""Sharded, atomic, resumable checkpoints (fault-tolerance substrate).

Layout:  <dir>/ckpt_<step>/          (atomically renamed from .tmp)
             meta.json               step, keys, dtypes, content hashes
             shard_<h>.npz           arrays for host-shard h

Guarantees:
  * atomicity — a checkpoint directory either has its final name and is
    complete (rename is atomic on POSIX) or is ignored;
  * integrity — per-array CRC recorded in meta.json, verified on load;
  * retention — keep_last newest checkpoints, older ones pruned;
  * resume — ``latest_step`` + ``restore`` rebuild (params, opt_state,
    pipeline_state) exactly; the data pipeline is counter-based so a
    restart replays/skips nothing.

On a real multi-host cluster each host writes its own shard file for its
addressable devices; in this container there is one host shard.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(f"[{p.idx}]")
    return "/".join(parts)


def _flatten(tree) -> dict:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out[_key_str(path)] = np.asarray(leaf)
    return out


def _crc(a: np.ndarray) -> str:
    return hashlib.sha256(a.tobytes()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3, host_id: int = 0):
        self.dir = directory
        self.keep_last = keep_last
        self.host_id = host_id
        os.makedirs(directory, exist_ok=True)

    # -- write ----------------------------------------------------------

    def save(self, step: int, state: dict) -> str:
        """state: arbitrary pytree dict, e.g. {'params': ..., 'opt': ...,
        'pipeline_step': int}. Returns the final checkpoint path."""
        final = os.path.join(self.dir, f"ckpt_{step:08d}")
        tmp = final + f".tmp{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten(state)
        shard_file = os.path.join(tmp, f"shard_{self.host_id}.npz")
        np.savez(shard_file, **{k: v for k, v in flat.items()})
        meta = {
            "step": step,
            "keys": sorted(flat),
            "crc": {k: _crc(v) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "shapes": {k: list(v.shape) for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._prune()
        return final

    def _prune(self) -> None:
        done = sorted(self._complete())
        for step in done[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"ckpt_{step:08d}"))
        # drop stale tmp dirs (crashed saves)
        for name in os.listdir(self.dir):
            if ".tmp" in name:
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)

    # -- read -----------------------------------------------------------

    def _complete(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("ckpt_") and ".tmp" not in name:
                if os.path.exists(os.path.join(self.dir, name, "meta.json")):
                    steps.append(int(name.split("_")[1]))
        return steps

    def latest_step(self) -> Optional[int]:
        done = self._complete()
        return max(done) if done else None

    def restore(self, template: Any, step: Optional[int] = None) -> Tuple[int, Any]:
        """Restore into the structure of ``template`` (a pytree of arrays
        or ShapeDtypeStructs). Returns (step, state)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"ckpt_{step:08d}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        data = {}
        for name in os.listdir(path):
            if name.startswith("shard_") and name.endswith(".npz"):
                with np.load(os.path.join(path, name)) as z:
                    for k in z.files:
                        data[k] = z[k]
        # integrity check
        for k, v in data.items():
            if meta["crc"].get(k) != _crc(v):
                raise IOError(f"checkpoint corruption at key {k}")

        leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
        out_leaves = []
        for p, leaf in leaves_with_path:
            k = _key_str(p)
            if k not in data:
                raise KeyError(f"checkpoint missing key {k}")
            v = data[k]
            want_shape = tuple(leaf.shape)
            if tuple(v.shape) != want_shape:
                raise ValueError(
                    f"shape mismatch for {k}: ckpt {v.shape} vs template {want_shape}"
                )
            out_leaves.append(v)
        return step, jax.tree_util.tree_unflatten(treedef, out_leaves)
