"""AdamW + schedules, hand-rolled (no optax in the image).

Moments can be stored in bf16 (``moment_dtype``) — at 480B params the
optimizer state is the HBM bottleneck and bf16 moments with fp32 update
math is the standard trade (used by the arctic config).  Global-norm
clipping included (production default).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # bf16 for very large models
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def adamw_init(cfg: AdamWConfig, params: Any) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    cfg: AdamWConfig, grads: Any, opt_state: dict, params: Any
) -> Tuple[Any, dict]:
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg, step.astype(jnp.float32))

    # Global-norm clip in fp32.
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m32.astype(mdt),
            v32.astype(mdt),
        )

    out = jax.tree.map(upd, params, grads, opt_state["mu"], opt_state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}
