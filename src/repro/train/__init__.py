"""Training substrate: optimizer, schedules, train-step factory,
checkpointing, fault tolerance."""

from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.train.checkpoint import CheckpointManager

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "CheckpointManager",
]
