"""Training driver: checkpoint/restart, straggler deadline, elastic
re-mesh, deterministic data — the fault-tolerant loop a cluster runs.

Designed so the same code drives (a) the CPU example (smoke config, local
mesh) and (b) a real pod (full config, production mesh): only the mesh
and config differ.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import PipelineState
from repro.dist.fault_tolerance import ElasticMesh, StragglerMonitor
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    straggler_deadline_factor: float = 1.5
    seed: int = 0


class Trainer:
    """Generic loop over (loss_fn, pipeline).

    ``loss_fn(params, batch) -> scalar``; pipeline provides
    ``batch(PipelineState, shard) -> dict of np arrays``.
    """

    def __init__(
        self,
        loss_fn: Callable,
        init_params_fn: Callable[[jax.Array], Any],
        pipeline,
        cfg: TrainerConfig,
        opt_cfg: Optional[AdamWConfig] = None,
        mesh=None,
        in_shardings=None,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg or AdamWConfig(total_steps=cfg.total_steps)
        self.pipeline = pipeline
        self.loss_fn = loss_fn
        self.init_params_fn = init_params_fn
        self.mesh = mesh
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep_last=cfg.keep_last)
        self.monitor = StragglerMonitor(n_hosts=max(jax.process_count(), 1))
        self.history: list = []

        def step_fn(params, opt_state, batch):
            loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
            params, opt_state = adamw_update(self.opt_cfg, grads, opt_state, params)
            return params, opt_state, loss

        self._step = jax.jit(step_fn) if mesh is None else jax.jit(
            step_fn, in_shardings=in_shardings
        )

    # ------------------------------------------------------------------

    def init_or_restore(self):
        params = self.init_params_fn(jax.random.key(self.cfg.seed))
        opt_state = adamw_init(self.opt_cfg, params)
        state = {"params": params, "opt": opt_state, "pipeline_step": np.int64(0)}
        latest = self.ckpt.latest_step()
        if latest is not None:
            _, state = self.ckpt.restore(state, latest)
            state["params"] = jax.tree.map(jnp.asarray, state["params"])
            state["opt"] = jax.tree.map(jnp.asarray, state["opt"])
        return state

    def run(self, on_step: Optional[Callable] = None):
        state = self.init_or_restore()
        params, opt_state = state["params"], state["opt"]
        start = int(state["pipeline_step"])
        pstate = PipelineState(step=start)

        for step in range(start, self.cfg.total_steps):
            t0 = time.perf_counter()
            batch = {
                k: jnp.asarray(v) for k, v in self.pipeline.batch(pstate).items()
            }
            params, opt_state, loss = self._step(params, opt_state, batch)
            loss = float(loss)
            dt = time.perf_counter() - t0
            self.monitor.record([dt])
            self.history.append((step, loss, dt))
            pstate = pstate.advance()

            if (step + 1) % self.cfg.log_every == 0:
                print(f"step {step + 1:6d}  loss {loss:.4f}  {dt * 1e3:.0f} ms")
            if (step + 1) % self.cfg.ckpt_every == 0:
                self.ckpt.save(
                    step + 1,
                    {
                        "params": params,
                        "opt": opt_state,
                        "pipeline_step": np.int64(pstate.step),
                    },
                )
            if on_step is not None:
                on_step(step, loss)
        return params, opt_state
