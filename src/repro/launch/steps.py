"""Step builders: one jit-able step per (architecture × shape) cell.

``build_cell(arch_spec, shape_name, mesh)`` returns a CellPlan with the
step function, ShapeDtypeStruct inputs (no allocation), and in/out
shardings — everything the dry-run needs to ``jit(...).lower().compile()``
and everything the real driver needs to run.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchSpec, Cell
from repro.dist import sharding as sh
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["CellPlan", "build_cell", "round_up"]


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _struct(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


@dataclasses.dataclass
class CellPlan:
    arch: str
    shape_name: str
    kind: str
    step: Callable  # positional args matching in_structs
    in_structs: Tuple[Any, ...]
    in_specs: Tuple[Any, ...]
    out_specs: Any  # pytree of PartitionSpec or None (infer)
    cfg: Any
    note: str = ""
    donate: Tuple[int, ...] = ()  # donated args: train -> (params, opt);
    # decode/prefill -> cache. Aliasing halves their memory footprint.

    def shardings(self, mesh: Mesh):
        ins = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s),
            self.in_specs,
            is_leaf=lambda x: isinstance(x, P),
        )
        outs = (
            jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s),
                self.out_specs,
                is_leaf=lambda x: isinstance(x, P),
            )
            if self.out_specs is not None
            else None
        )
        return ins, outs


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_cell(
    spec: ArchSpec, shape_name: str, cell: Cell, mesh: Mesh,
    extra_overrides: Optional[dict] = None,
) -> CellPlan:
    import dataclasses as dc

    from repro.models import transformer as T

    cfg = dc.replace(spec.cfg, **{**cell.overrides, **(extra_overrides or {})})
    params_struct = jax.eval_shape(lambda: T.init(cfg, jax.random.key(0)))
    pspecs = sh.lm_param_specs(params_struct, mesh, fsdp=spec.fsdp)
    dp = sh.batch_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    if cell.kind == "train":
        b, s = cell.batch, cell.extra["seq_len"]
        micro = int(cell.extra.get("microbatches", 1))
        opt_cfg = AdamWConfig(moment_dtype="bfloat16" if spec.fsdp else "float32")
        opt_struct = jax.eval_shape(functools.partial(adamw_init, opt_cfg), params_struct)
        ospecs = sh.opt_state_specs(pspecs)
        batch_struct = {
            "tokens": _struct((b, s), jnp.int32),
            "targets": _struct((b, s), jnp.int32),
        }
        bspecs = sh.batch_specs({k: v.shape for k, v in batch_struct.items()}, mesh)

        def step(params, opt_state, batch):
            if micro == 1:
                loss, grads = jax.value_and_grad(
                    lambda p: T.loss_fn(p, cfg, batch)
                )(params)
            else:
                # Gradient accumulation over sequential microbatches: the
                # scan (not unrolled) bounds activation memory to one
                # microbatch; the dry-run scales costs by `micro`.
                # The split must INTERLEAVE within each data shard's rows
                # (reshape(micro, b//micro) would give each microbatch to
                # a fraction of the shards and force a reshard), and the
                # constraint pins the layout so every shard keeps
                # b/(micro·n_data) rows per microbatch.
                mspec = jax.sharding.NamedSharding(mesh, P(None, dp, None))
                mb = {
                    k: jax.lax.with_sharding_constraint(
                        v.reshape(b // micro, micro, s).swapaxes(0, 1), mspec
                    )
                    for k, v in batch.items()
                }

                def body(gacc, m):
                    l, g = jax.value_and_grad(
                        lambda p: T.loss_fn(p, cfg, m)
                    )(params)
                    return jax.tree.map(jnp.add, gacc, g), l

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, p.dtype), params
                )
                gacc, losses = jax.lax.scan(body, g0, mb)
                grads = jax.tree.map(lambda g: g / micro, gacc)
                loss = losses.mean()
            params, opt_state = adamw_update(opt_cfg, grads, opt_state, params)
            return params, opt_state, loss

        return CellPlan(
            arch=spec.name, shape_name=shape_name, kind="train", step=step,
            in_structs=(params_struct, opt_struct, batch_struct),
            in_specs=(pspecs, ospecs, bspecs),
            out_specs=(pspecs, ospecs, P()),
            cfg=cfg, note=f"microbatches={micro}" if micro > 1 else "",
            donate=(0, 1),
        )

    if cell.kind == "prefill":
        b, s = cell.batch, cell.extra["seq_len"]
        cache_struct = jax.eval_shape(lambda: T.init_cache(cfg, b, s))
        cspecs = sh.cache_specs(cache_struct, mesh)
        tok = _struct((b, s), jnp.int32)
        tspec = sh.validate_spec(mesh, P(dp, None), tok.shape)

        def step(params, tokens, cache):
            return T.prefill(params, cfg, tokens, cache)

        return CellPlan(
            arch=spec.name, shape_name=shape_name, kind="prefill", step=step,
            in_structs=(params_struct, tok, cache_struct),
            in_specs=(pspecs, tspec, cspecs),
            out_specs=(sh.validate_spec(mesh, P(dp, "model"), (b, cfg.vocab)), cspecs),
            cfg=cfg, donate=(2,),
        )

    if cell.kind == "decode":
        b = cell.batch
        lmax = cell.extra["cache_len"]
        cache_struct = jax.eval_shape(lambda: T.init_cache(cfg, b, lmax))
        cspecs = sh.cache_specs(cache_struct, mesh)
        tok = _struct((b, 1), jnp.int32)
        tspec = sh.validate_spec(mesh, P(dp, None), tok.shape)

        def step(params, tokens, cache):
            return T.decode_step(params, cfg, tokens, cache)

        return CellPlan(
            arch=spec.name, shape_name=shape_name, kind="decode", step=step,
            in_structs=(params_struct, tok, cache_struct),
            in_specs=(pspecs, tspec, cspecs),
            out_specs=(sh.validate_spec(mesh, P(dp, "model"), (b, cfg.vocab)), cspecs),
            cfg=cfg, donate=(2,),
        )

    raise ValueError(cell.kind)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _pna_cell(
    spec: ArchSpec, shape_name: str, cell: Cell, mesh: Mesh,
    extra_overrides: Optional[dict] = None,
) -> CellPlan:
    import dataclasses as dc

    from repro.models import pna as M

    ex = cell.extra
    readout = ex.get("readout", "node")
    cfg = dc.replace(
        spec.cfg,
        d_feat=ex.get("d_feat", spec.cfg.d_feat),
        n_classes=ex.get("n_classes", spec.cfg.n_classes),
        readout=readout,
        **(extra_overrides or {}),
    )
    params_struct = jax.eval_shape(lambda: M.init(cfg, jax.random.key(0)))
    pspecs = sh.pna_param_specs(params_struct, mesh)
    opt_cfg = AdamWConfig()
    opt_struct = jax.eval_shape(functools.partial(adamw_init, opt_cfg), params_struct)
    ospecs = sh.opt_state_specs(pspecs)
    dp = sh.batch_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    if cell.kind == "train_minibatch":
        from repro.data.graphs import NeighborSampler

        class _B:  # budget computation without building the real graph
            fanouts = ex["fanouts"]

        n_pad, e_pad = NeighborSampler.budget(_B, cell.batch)
        n_pad = round_up(n_pad, 512)
        e_pad = round_up(e_pad, 512)
        batch_struct = {
            "feats": _struct((n_pad, ex["d_feat"]), jnp.float32),
            "edges": _struct((e_pad, 2), jnp.int32),
            "edge_mask": _struct((e_pad,), jnp.float32),
            "seed_pos": _struct((cell.batch,), jnp.int32),
            "labels": _struct((cell.batch,), jnp.int32),
        }
        note = f"sampled subgraph: N_pad={n_pad} E_pad={e_pad}"
    elif readout == "graph":
        n = cell.batch * ex["nodes_per_graph"]
        e = cell.batch * ex["edges_per_graph"]
        n_pad, e_pad = round_up(n, 512), round_up(e, 512)
        batch_struct = {
            "feats": _struct((n_pad, ex["d_feat"]), jnp.float32),
            "edges": _struct((e_pad, 2), jnp.int32),
            "edge_mask": _struct((e_pad,), jnp.float32),
            "graph_id": _struct((n_pad,), jnp.int32),
            "labels": _struct((cell.batch,), jnp.int32),
        }
        note = f"batched molecules: N_pad={n_pad} E_pad={e_pad}"
    else:
        n_pad = round_up(ex["n_nodes"], 512)
        e_pad = round_up(ex["n_edges"], 512)
        batch_struct = {
            "feats": _struct((n_pad, ex["d_feat"]), jnp.float32),
            "edges": _struct((e_pad, 2), jnp.int32),
            "edge_mask": _struct((e_pad,), jnp.float32),
            "labels": _struct((n_pad,), jnp.int32),
            "label_mask": _struct((n_pad,), jnp.float32),
        }
        note = f"full graph: N_pad={n_pad} E_pad={e_pad}"

    bspecs = sh.batch_specs(
        {k: v.shape for k, v in batch_struct.items()},
        mesh,
        field_rules={
            # nodes over data-parallel axes, edges over model
            "feats": P(dp, None),
            "labels": P(dp) if readout == "node" and cell.kind == "train" else P(),
            "label_mask": P(dp),
            "graph_id": P(dp),
            "edges": P("model", None),
            "edge_mask": P("model"),
            "seed_pos": P(),
        },
    )

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: M.loss_fn(p, cfg, batch))(params)
        params, opt_state = adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, loss

    return CellPlan(
        arch=spec.name, shape_name=shape_name, kind=cell.kind, step=step,
        in_structs=(params_struct, opt_struct, batch_struct),
        in_specs=(pspecs, ospecs, bspecs),
        out_specs=(pspecs, ospecs, P()),
        cfg=cfg, note=note, donate=(0, 1),
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _recsys_batch_struct(name: str, cfg, batch: int):
    if name == "dien":
        return {
            "hist_ids": _struct((batch, cfg.seq_len), jnp.int32),
            "hist_mask": _struct((batch, cfg.seq_len), jnp.float32),
            "target_id": _struct((batch,), jnp.int32),
            "label": _struct((batch,), jnp.float32),
        }
    if name == "mind":
        return {
            "hist_ids": _struct((batch, cfg.hist_len), jnp.int32),
            "hist_mask": _struct((batch, cfg.hist_len), jnp.float32),
            "target_id": _struct((batch,), jnp.int32),
            "label": _struct((batch,), jnp.float32),
        }
    if name == "dcn-v2":
        return {
            "dense": _struct((batch, cfg.n_dense), jnp.float32),
            "sparse_ids": _struct((batch, cfg.n_sparse), jnp.int32),
            "target_id": _struct((batch,), jnp.int32),
            "label": _struct((batch,), jnp.float32),
        }
    if name == "bert4rec":
        return {
            "hist_ids": _struct((batch, cfg.seq_len), jnp.int32),
            "hist_mask": _struct((batch, cfg.seq_len), jnp.float32),
            "target_id": _struct((batch,), jnp.int32),
            "label": _struct((batch,), jnp.float32),
        }
    raise KeyError(name)


def _recsys_module(name: str):
    from repro.models.recsys import bert4rec, dcnv2, dien, mind

    return {
        "dien": dien,
        "mind": mind,
        "dcn-v2": dcnv2,
        "bert4rec": bert4rec,
    }[name]


def _recsys_cell(
    spec: ArchSpec, shape_name: str, cell: Cell, mesh: Mesh,
    extra_overrides: Optional[dict] = None,
) -> CellPlan:
    import dataclasses as dc

    M = _recsys_module(spec.name)
    cfg = dc.replace(spec.cfg, **(extra_overrides or {}))
    params_struct = jax.eval_shape(lambda: M.init(cfg, jax.random.key(0)))
    pspecs = sh.recsys_param_specs(params_struct, mesh)
    dp = sh.batch_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    if cell.kind == "train":
        opt_cfg = AdamWConfig()
        opt_struct = jax.eval_shape(
            functools.partial(adamw_init, opt_cfg), params_struct
        )
        ospecs = sh.opt_state_specs(pspecs)
        batch_struct = _recsys_batch_struct(spec.name, cfg, cell.batch)
        bspecs = sh.batch_specs({k: v.shape for k, v in batch_struct.items()}, mesh)

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(lambda p: M.loss_fn(p, cfg, batch))(
                params
            )
            params, opt_state = adamw_update(opt_cfg, grads, opt_state, params)
            return params, opt_state, loss

        return CellPlan(
            arch=spec.name, shape_name=shape_name, kind="train", step=step,
            in_structs=(params_struct, opt_struct, batch_struct),
            in_specs=(pspecs, ospecs, bspecs),
            out_specs=(pspecs, ospecs, P()),
            cfg=cfg, donate=(0, 1),
        )

    if cell.kind == "serve":
        batch_struct = _recsys_batch_struct(spec.name, cfg, cell.batch)
        batch_struct.pop("label")
        bspecs = sh.batch_specs({k: v.shape for k, v in batch_struct.items()}, mesh)

        def step(params, batch):
            return M.forward(params, cfg, batch)

        return CellPlan(
            arch=spec.name, shape_name=shape_name, kind="serve", step=step,
            in_structs=(params_struct, batch_struct),
            in_specs=(pspecs, bspecs),
            out_specs=sh.validate_spec(mesh, P(dp), (cell.batch,)),
            cfg=cfg,
        )

    if cell.kind == "retrieval":
        n_cand = cell.extra["n_candidates"]
        batch_struct = _recsys_batch_struct(spec.name, cfg, cell.batch)
        batch_struct.pop("label")
        bspecs = sh.batch_specs({k: v.shape for k, v in batch_struct.items()}, mesh)
        # batch=1: replicate the query, shard the candidates.
        bspecs = jax.tree.map(lambda _: P(), bspecs, is_leaf=lambda x: isinstance(x, P))
        cand = _struct((n_cand,), jnp.int32)
        cand_spec = sh.validate_spec(mesh, P(dp), cand.shape)

        def step(params, batch, cand_ids):
            return M.score_candidates(params, cfg, batch, cand_ids)

        return CellPlan(
            arch=spec.name, shape_name=shape_name, kind="retrieval", step=step,
            in_structs=(params_struct, batch_struct, cand),
            in_specs=(pspecs, bspecs, cand_spec),
            out_specs=sh.validate_spec(mesh, P(None, dp), (cell.batch, n_cand)),
            cfg=cfg,
        )

    raise ValueError(cell.kind)


# ---------------------------------------------------------------------------


def build_cell(
    spec: ArchSpec, shape_name: str, mesh: Mesh,
    extra_overrides: Optional[dict] = None,
) -> CellPlan:
    cell = spec.cells[shape_name]
    if cell.skip:
        raise ValueError(f"cell {spec.name}/{shape_name} is skipped: {cell.skip}")
    if spec.family == "lm":
        return _lm_cell(spec, shape_name, cell, mesh, extra_overrides)
    if spec.family == "gnn":
        return _pna_cell(spec, shape_name, cell, mesh, extra_overrides)
    if spec.family == "recsys":
        return _recsys_cell(spec, shape_name, cell, mesh, extra_overrides)
    raise ValueError(spec.family)


def probe_plan(spec: ArchSpec, shape_name: str, mesh: Mesh):
    """Scan-trip probe spec for cost extrapolation (see dryrun.py):
    returns (param_name, probe_values, full_value) or None.

    cost_analysis counts a while-loop body ONCE, so scanned models report
    per-trip costs. We lower two probe configs and extrapolate linearly.
    For gemma3 the probe stride is one local:global period so both layer
    kinds are represented.
    """
    if spec.family == "lm":
        # One local:global period per probe step so both layer kinds are
        # sampled (gemma3); lo >= 2 because XLA optimizes the single-layer
        # case non-linearly (measured — see EXPERIMENTS.md §Dry-run).
        period = spec.cfg.global_every or 1
        lo = max(2, period)
        return ("n_layers", (lo, 2 * lo), spec.cfg.n_layers)
    if spec.name == "dien":
        # GRU/AUGRU scans over time; everything else is T-independent.
        return ("seq_len", (2, 4), spec.cfg.seq_len)
    return None


def probe_overrides(spec: ArchSpec, param_name: str, value: int) -> dict:
    """Config overrides for one probe compile.  The probed scan must be
    UNROLLED (scan_unroll=value) — otherwise both probe points report the
    same single-body cost and the extrapolation degenerates."""
    return {param_name: value, "scan_unroll": value}


def cost_scale(spec: ArchSpec, shape_name: str) -> int:
    """Known outer-loop trip counts not visible to cost_analysis: the
    gradient-accumulation scan (microbatches) runs its body `micro`
    times."""
    cell = spec.cells[shape_name]
    if cell.kind == "train":
        return int(cell.extra.get("microbatches", 1))
    return 1
