"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init;
tests and benches must keep seeing 1 device).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist, as a 1×N mesh (CPU smoke / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
