import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell:
  ``jit(step, in_shardings, out_shardings).lower(*ShapeDtypeStructs)
  .compile()`` against the 16×16 single-pod mesh and the 2×16×16
  multi-pod mesh, printing ``memory_analysis()`` (fits?) and
  ``cost_analysis()`` (FLOPs/bytes) and recording collective bytes for
  the §Roofline table.

The two XLA_FLAGS lines above MUST stay the first statements: jax locks
the device count at first backend init, and only the dry-run may see 512
host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch pna --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --shape train_4k --out o.json
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs.registry import ARCH_NAMES, get_arch
from repro.dist import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, cost_scale, probe_overrides, probe_plan
from repro.roofline.analysis import (
    RooflineReport,
    V5E,
    analyze_compiled,
    collective_bytes_from_hlo,
    model_flops,
)


def _compile_costs(spec, shape_name, mesh, extra_overrides):
    """Compile one probe config and return (flops, bytes, coll dict)."""
    plan = build_cell(spec, shape_name, mesh, extra_overrides)
    ins, outs = plan.shardings(mesh)
    sh.set_mesh(mesh)  # also sets the ambient mesh (shard_map MoE)
    compiled = (
        jax.jit(plan.step, in_shardings=ins, out_shardings=outs,
                donate_argnums=plan.donate)
        .lower(*plan.in_structs)
        .compile()
    )
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes_from_hlo(compiled.as_text())
    return float(cost.get("flops", 0.0)), float(cost.get("bytes accessed", 0.0)), coll


def run_cell(spec, shape_name: str, mesh, mesh_name: str, verbose: bool = True):
    cell = spec.cells[shape_name]
    if cell.skip:
        return {
            "arch": spec.name,
            "shape": shape_name,
            "mesh": mesh_name,
            "status": "SKIP",
            "reason": cell.skip,
        }
    t0 = time.perf_counter()
    plan = build_cell(spec, shape_name, mesh)
    ins, outs = plan.shardings(mesh)
    sh.set_mesh(mesh)  # also sets the ambient mesh (shard_map MoE)
    jitted = jax.jit(plan.step, in_shardings=ins, out_shardings=outs,
                     donate_argnums=plan.donate)
    lowered = jitted.lower(*plan.in_structs)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    chips = mesh.devices.size
    mf = model_flops(plan, cell)
    report = analyze_compiled(
        compiled, spec.name, shape_name, mesh_name, chips, mf
    )
    mem = compiled.memory_analysis()

    # Scan-trip correction: XLA's cost_analysis counts a while-loop body
    # ONCE; scanned models (LM layer stack, DIEN time recurrence) need a
    # two-point probe to recover true totals (DESIGN.md §7).
    probe = probe_plan(spec, shape_name, mesh)
    probe_info = None
    if probe is not None:
        pname, (lo, hi), full = probe
        t_probe = time.perf_counter()
        f_lo, b_lo, c_lo = _compile_costs(
            spec, shape_name, mesh, probe_overrides(spec, pname, lo)
        )
        f_hi, b_hi, c_hi = _compile_costs(
            spec, shape_name, mesh, probe_overrides(spec, pname, hi)
        )
        scale = (full - lo) / max(hi - lo, 1)
        mscale = cost_scale(spec, shape_name)
        flops = (f_lo + scale * (f_hi - f_lo)) * mscale
        byts = (b_lo + scale * (b_hi - b_lo)) * mscale
        coll = {
            k: int((c_lo[k] + scale * (c_hi[k] - c_lo[k])) * mscale)
            for k in c_lo
        }
        probe_info = {
            "param": pname, "lo": lo, "hi": hi, "full": full,
            "probe_s": round(time.perf_counter() - t_probe, 1),
            "raw_flops_per_chip": report.flops_per_chip,
        }
        report = RooflineReport(
            arch=spec.name, shape=shape_name, mesh=mesh_name, chips=chips,
            flops_per_chip=flops, bytes_per_chip=byts,
            coll_bytes_per_chip=coll,
            compute_s=flops / V5E.peak_flops,
            memory_s=byts / V5E.hbm_bw,
            collective_s=coll["total"] / V5E.link_bw,
            model_flops_total=mf,
            peak_memory_per_chip=report.peak_memory_per_chip,
        )
    out = {
        "status": "OK",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "note": plan.note,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "probe": probe_info,
        **report.to_dict(),
    }
    if verbose:
        gib = lambda b: f"{(b or 0) / 2**30:.2f} GiB"
        fits = (report.peak_memory_per_chip or 0) <= report.hw.hbm_bytes
        print(
            f"  [{mesh_name}] {spec.name}/{shape_name}: "
            f"args={gib(out['memory']['argument_bytes'])} "
            f"temp={gib(out['memory']['temp_bytes'])} "
            f"peak/chip={gib(report.peak_memory_per_chip)} "
            f"({'fits' if fits else 'OVER'} {report.hw.hbm_bytes / 2**30:.0f} GiB) | "
            f"flops/chip={report.flops_per_chip:.3e} "
            f"coll/chip={report.coll_bytes_per_chip['total'] / 2**20:.1f} MiB | "
            f"t(c={report.compute_s * 1e3:.1f} m={report.memory_s * 1e3:.1f} "
            f"x={report.collective_s * 1e3:.1f} ms) -> {report.dominant} | "
            f"useful={report.useful_flop_ratio:.2f} "
            f"roofline={report.roofline_fraction:.2f} | "
            f"compile {t_compile:.0f}s"
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true", help="merge into --out")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else list(ARCH_NAMES)
    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))

    for name in archs:
        spec = get_arch(name)
        shapes = [args.shape] if args.shape else list(spec.cells)
        for shape_name in shapes:
            for mesh_name, mesh in meshes:
                key = (name, shape_name, mesh_name)
                if any(
                    (r.get("arch"), r.get("shape"), r.get("mesh")) == key
                    for r in results
                ):
                    continue
                try:
                    r = run_cell(spec, shape_name, mesh, mesh_name)
                except Exception as e:  # a failure here is a bug in our system
                    traceback.print_exc()
                    r = {
                        "arch": name,
                        "shape": shape_name,
                        "mesh": mesh_name,
                        "status": "FAIL",
                        "error": f"{type(e).__name__}: {e}",
                    }
                    print(f"  [{mesh_name}] {name}/{shape_name}: FAIL {e}")
                r.setdefault("arch", name)
                r.setdefault("shape", shape_name)
                r.setdefault("mesh", mesh_name)
                results.append(r)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    ok = sum(1 for r in results if r["status"] == "OK")
    skip = sum(1 for r in results if r["status"] == "SKIP")
    fail = sum(1 for r in results if r["status"] == "FAIL")
    print(f"\ndry-run: {ok} OK, {skip} SKIP (documented), {fail} FAIL -> {args.out}")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
