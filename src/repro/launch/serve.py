"""Serving launcher:  PYTHONPATH=src python -m repro.launch.serve --arch <id>

Drives the family-appropriate serving path on CPU with the smoke config:
LM → prefill + batched decode loop; recsys → batched scoring + retrieval.
(The production path is exercised shape-for-shape by repro.launch.dryrun.)
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--decode-steps", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_arch

    spec = get_arch(args.arch)
    cfg = spec.smoke_cfg
    rng = np.random.default_rng(0)

    if spec.family == "lm":
        from repro.models import transformer as T

        params = T.init(cfg, jax.random.key(0))
        b = args.requests
        prompts = rng.integers(0, cfg.vocab, (b, 12)).astype(np.int32)
        cache = T.init_cache(cfg, b, 12 + args.decode_steps)
        t0 = time.perf_counter()
        logits, cache = T.prefill(params, cfg, jnp.asarray(prompts), cache)
        toks = []
        step = jax.jit(lambda p, t, c: T.decode_step(p, cfg, t, c))
        for _ in range(args.decode_steps):
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            toks.append(np.asarray(nxt)[:, 0])
            logits, cache = step(params, nxt, cache)
        dt = time.perf_counter() - t0
        out = np.stack(toks, 1)
        print(f"{b} requests x {args.decode_steps} tokens in {dt:.2f}s "
              f"({b * args.decode_steps / dt:.0f} tok/s)")
        print("first request:", out[0].tolist())
    elif spec.family == "recsys":
        from repro.launch.steps import _recsys_module

        M = _recsys_module(spec.name)
        params = M.init(cfg, jax.random.key(0))
        b = max(args.requests, 4)
        if spec.name == "dcn-v2":
            batch = {
                "dense": jnp.asarray(rng.standard_normal((b, cfg.n_dense)), jnp.float32),
                "sparse_ids": jnp.asarray(rng.integers(0, cfg.vocab_per_field, (b, cfg.n_sparse)), jnp.int32),
                "target_id": jnp.asarray(rng.integers(0, cfg.vocab_per_field, (b,)), jnp.int32),
            }
        else:
            seq = getattr(cfg, "seq_len", None) or cfg.hist_len
            batch = {
                "hist_ids": jnp.asarray(rng.integers(0, cfg.vocab, (b, seq)), jnp.int32),
                "hist_mask": jnp.ones((b, seq), jnp.float32),
                "target_id": jnp.asarray(rng.integers(0, cfg.vocab, (b,)), jnp.int32),
            }
        t0 = time.perf_counter()
        scores = M.forward(params, cfg, batch)
        cands = jnp.asarray(rng.integers(0, getattr(cfg, "vocab", 500), 1000), jnp.int32)
        top = M.score_candidates(params, cfg, batch, cands)
        print(f"scored {b} requests ({np.asarray(scores)[:4].round(3)}...) and "
              f"{top.shape[1]} candidates/request in {time.perf_counter() - t0:.2f}s")
    else:
        raise SystemExit("pna serving: use examples/search_service.py patterns")


if __name__ == "__main__":
    main()
