"""Training launcher:  PYTHONPATH=src python -m repro.launch.train --arch <id>

On CPU (this container) runs the SMOKE config end-to-end with the full
fault-tolerant Trainer (checkpoint/restart, deterministic pipeline). On a
real cluster the same entrypoint with --production uses the full config +
production mesh + the CellPlan shardings from launch.steps.
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--production", action="store_true",
                    help="full config on the production mesh (needs TPUs)")
    args = ap.parse_args()

    from repro.configs.registry import get_arch
    from repro.data.pipeline import RecsysPipeline, TokenPipeline
    from repro.train.optimizer import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    spec = get_arch(args.arch)
    if args.production:
        raise SystemExit(
            "production mode requires a TPU pod; use repro.launch.dryrun to "
            "validate the mesh/sharding config from this container"
        )
    cfg = spec.smoke_cfg
    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=max(args.steps // 3, 5),
        log_every=5, ckpt_dir=f"{args.ckpt_dir}_{args.arch}",
    )

    if spec.family == "lm":
        from repro.models import transformer as T

        pipe = TokenPipeline(cfg.vocab, seq_len=32, batch_per_shard=4)
        trainer = Trainer(
            lambda p, b: T.loss_fn(p, cfg, b),
            lambda k: T.init(cfg, k),
            pipe, tcfg, opt_cfg=AdamWConfig(lr=1e-3, total_steps=args.steps),
        )
    elif spec.family == "recsys":
        from repro.launch.steps import _recsys_module

        M = _recsys_module(spec.name)
        if spec.name == "dcn-v2":
            pipe = RecsysPipeline(
                n_dense=cfg.n_dense, n_fields=cfg.n_sparse,
                vocab_size=cfg.vocab_per_field, hist_len=4, batch_per_shard=32,
            )
        else:
            seq = getattr(cfg, "seq_len", None) or cfg.hist_len
            pipe = RecsysPipeline(
                n_dense=4, n_fields=4, vocab_size=cfg.vocab,
                hist_len=seq, batch_per_shard=32,
            )
        trainer = Trainer(
            lambda p, b: M.loss_fn(p, cfg, b),
            lambda k: M.init(cfg, k),
            pipe, tcfg, opt_cfg=AdamWConfig(lr=1e-3, total_steps=args.steps),
        )
    else:  # gnn
        import dataclasses

        import jax
        import numpy as np

        from repro.data.graphs import synth_graph
        from repro.models import pna as M
        from repro.train.optimizer import adamw_init, adamw_update

        cfg = dataclasses.replace(cfg, d_feat=16, n_classes=5)
        g = synth_graph(1000, 8, 16, 5, seed=0)
        src, dst = g.edge_list()
        batch = {
            "feats": g.feats,
            "edges": np.stack([src, dst], 1),
            "edge_mask": np.ones(g.n_edges, np.float32),
            "labels": g.labels,
            "label_mask": np.ones(g.n_nodes, np.float32),
        }
        import jax.numpy as jnp

        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params = M.init(cfg, jax.random.key(0))
        ocfg = AdamWConfig(lr=5e-3, total_steps=args.steps)
        opt = adamw_init(ocfg, params)
        step = jax.jit(
            lambda p, o, b: (lambda l, g_: adamw_update(ocfg, g_, o, p) + (l,))(
                *jax.value_and_grad(lambda p_: M.loss_fn(p_, cfg, b))(p)
            )
        )
        for i in range(args.steps):
            params, opt, loss = step(params, opt, batch)
            if (i + 1) % 5 == 0:
                print(f"step {i + 1:4d}  loss {float(loss):.4f}")
        return

    trainer.run()
    print(f"done; checkpoints in {tcfg.ckpt_dir}")


if __name__ == "__main__":
    main()
