"""SeCluD search-service launcher (the paper's system, end to end):

    PYTHONPATH=src python -m repro.launch.search --docs 8000 --k 128

Builds a corpus + query log, fits the clustering, reports the paper's
three speedups, and serves a query batch through both the host path and
the device (shard_map) path.
"""

from __future__ import annotations

import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=8000)
    ap.add_argument("--k", type=int, default=128)
    ap.add_argument("--corpus", default="forum",
                    choices=["forum", "gov2", "gov2s", "wiki"])
    ap.add_argument("--algo", default="topdown", choices=["topdown", "flat"])
    ap.add_argument("--queries", type=int, default=1000)
    ap.add_argument("--tc", type=int, default=3000)
    args = ap.parse_args()

    from repro.core.seclud import SecludPipeline
    from repro.data.corpus import CorpusSpec, corpus_stats, synth_corpus
    from repro.data.query_log import synth_query_log
    from repro.serve.search_service import SearchService

    spec = getattr(CorpusSpec, f"{args.corpus}_like")(n_docs=args.docs)
    corpus = synth_corpus(spec)
    log = synth_query_log(corpus, n_queries=args.queries, seed=1)
    print("corpus:", corpus_stats(corpus))

    pipe = SecludPipeline(tc=args.tc, doc_grained_below=512)
    res = pipe.fit(corpus, args.k, algo=args.algo, log=log)
    print(f"fit[{args.algo}]: k={res.k} in {res.cluster_time_s:.1f}s "
          f"S_T(objective)={res.s_t:.2f}")

    ev = pipe.evaluate(corpus, res, log, max_queries=min(400, args.queries))
    print(f"speedups: S_T={ev['S_T']:.2f} S_C={ev['S_C']:.2f} "
          f"S_R={ev['S_R']:.2f} over {int(ev['n_queries'])} queries (lossless)")

    svc = SearchService(res)
    queries = log.queries[:64]
    counts, work = svc.serve_counts(queries)
    packed = svc.pack(queries)
    dev = np.asarray(SearchService.device_counts(packed))
    assert np.array_equal(dev, counts)
    print(f"served {len(queries)} queries: host work {work['work']:.0f}, "
          f"device path agrees ({packed.short.shape[0]} segment rows)")


if __name__ == "__main__":
    main()
