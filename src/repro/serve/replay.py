"""Open-loop traffic replay against the serving loop.

Drives the deadline batcher with a Zipf-skewed query stream under
Poisson arrivals (``synth_query_log(..., arrival_qps=...)``) in two
modes:

* ``"sealed"`` (default) — a discrete-event simulation over the *pure*
  batching policy: batch composition comes from
  :func:`repro.serve.loop.plan_batches` (a deterministic function of the
  arrival timestamps), every batch is executed for real on the device
  engine, and latencies unroll on a virtual clock — a batch dispatches
  at ``max(seal_time, device_free)`` and occupies the device for its
  measured service time.  Composition (and therefore result counts and
  jit-shape traffic) is bit-reproducible under a fixed seed, which is
  what makes "prewarm then replay compiles nothing" an assertion rather
  than an observation; latencies are real measurements and carry the
  usual noise.

* ``"async"`` — drives the real :class:`~repro.serve.loop.AsyncServingLoop`
  on wall clock: one asyncio task per request sleeps until its arrival
  offset and submits.  Live-serving realism (actual event-loop timing,
  actual deadline races), at the price of nondeterministic composition.

Both modes return a :class:`ReplayReport` whose per-request counts are
in arrival order and bit-identical to calling the engine directly on
the same queries — batching never changes results, only latency.
"""

from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.data.query_log import QueryLog, poisson_arrivals
from repro.serve.loop import (
    AsyncServingLoop,
    ServeConfig,
    ServeStats,
    plan_batches,
    seal_times,
)

__all__ = ["ReplayReport", "replay"]


@dataclasses.dataclass
class ReplayReport:
    """What a replay produced: exact per-request counts (arrival order),
    the arrival trace, the batch windows actually dispatched, the full
    :class:`ServeStats`, and the jit-cache growth over the whole
    measured pass (0 after a covering prewarm)."""

    counts: np.ndarray
    arrivals: np.ndarray
    batches: List[Tuple[int, int]]
    stats: ServeStats
    jit_compiles: int
    mode: str

    def summary(self) -> dict:
        s = self.stats.summary()
        s["jit_compiles"] = self.jit_compiles
        s["mode"] = self.mode
        if len(self.arrivals) > 1:
            span = max(float(self.arrivals[-1] - self.arrivals[0]), 1e-12)
            s["qps_offered"] = (len(self.arrivals) - 1) / span
        else:
            s["qps_offered"] = 0.0
        return s


def replay(
    service,
    log: QueryLog,
    qps: Optional[float] = None,
    config: Optional[ServeConfig] = None,
    mode: str = "sealed",
    seed: int = 0,
    engine=None,
    cache_probe=None,
    faults=None,
    resilience=None,
) -> ReplayReport:
    """Replay a query log's traffic through the deadline batcher.

    ``log.arrivals`` supplies the open-loop timestamps; without them,
    ``qps`` must be given and a Poisson process is drawn under ``seed``.
    ``engine`` overrides ``service.serve_counts_device`` (tests inject
    counting shims); ``cache_probe`` overrides the fused fold's
    compiled-entry counter.

    ``faults`` (a :class:`repro.serve.faults.FaultSchedule`) turns the
    run into a *chaos replay*: the schedule's failures fire inside the
    real dispatch path and the batches serve through the resilience
    ladder (``resilience`` — a ``ResilienceConfig`` — defaults apply
    when omitted).  Shed requests reply with the ``SHED`` sentinel in
    ``counts`` and outcome ``"shed"`` in the stats; every non-shed count
    stays bit-identical to the host engine.  Batch composition and
    fault firing are both pure functions of the arrivals and the
    schedule, so the same seed + schedule reproduces the same
    ``ServeStats`` outcome/attempt/level records exactly.
    """
    if log.arrivals is not None:
        arrivals = np.asarray(log.arrivals, np.float64)
    elif qps is not None:
        arrivals = poisson_arrivals(log.n_queries, qps, seed=seed)
    else:
        raise ValueError("log has no arrivals and no qps given")
    if len(arrivals) != log.n_queries:
        raise ValueError("one arrival timestamp per query required")
    cfg = config or ServeConfig()
    if engine is None:
        engine = service.serve_counts_device
    if cache_probe is None:
        from repro.core.device_engine import fold_cache_size as cache_probe
    if mode == "sealed":
        return _replay_sealed(
            engine,
            log,
            arrivals,
            cfg,
            cache_probe,
            service=service,
            faults=faults,
            resilience=resilience,
        )
    if mode == "async":
        return asyncio.run(
            _replay_async(
                service,
                engine,
                log,
                arrivals,
                cfg,
                cache_probe,
                faults=faults,
                resilience=resilience,
            )
        )
    raise ValueError(f"unknown replay mode {mode!r} (sealed|async)")


def _replay_sealed(
    engine,
    log,
    arrivals,
    cfg,
    probe,
    service=None,
    faults=None,
    resilience=None,
) -> ReplayReport:
    injector = None
    dispatcher = None
    rcfg = None
    if faults is not None:
        from repro.serve.faults import FaultInjector

        injector = (
            faults if isinstance(faults, FaultInjector) else FaultInjector(faults)
        )
    if resilience is not None or injector is not None:
        from repro.serve.resilience import ResilienceConfig, ResilientDispatcher

        rcfg = resilience or ResilienceConfig()
        dispatcher = ResilientDispatcher(
            service, rcfg, engine=engine, injector=injector
        )
    if injector is not None and service is not None:
        service.install_faults(injector)
    try:
        return _sealed_loop(
            engine, log, arrivals, cfg, probe, injector, dispatcher, rcfg
        )
    finally:
        if injector is not None and service is not None:
            service.install_faults(None)


def _sealed_loop(
    engine, log, arrivals, cfg, probe, injector, dispatcher, rcfg
) -> ReplayReport:
    from repro.serve.faults import SHED

    batches = plan_batches(arrivals, cfg.max_batch, cfg.deadline_s)
    seals = seal_times(arrivals, batches, cfg.max_batch, cfg.deadline_s)
    stats = ServeStats(cfg.max_batch)
    counts_all = np.zeros(log.n_queries, np.int64)
    cache_start = probe()
    device_free = 0.0
    shed_limit = rcfg.shed_queue_depth if rcfg is not None else None
    for (i, j), t_seal in zip(batches, seals, strict=True):
        # Single-server queue on the virtual clock: the batch cannot
        # dispatch before it seals nor before the device frees up.
        dispatch = max(float(t_seal), device_free)
        # Requests arrived but not yet sealed at dispatch time, plus any
        # phantom backlog an active queue-flood fault injects.
        depth = int(
            max(0, np.searchsorted(arrivals, dispatch, side="right") - j)
        )
        if injector is not None:
            injector.begin_batch()
            depth += injector.extra_queue_depth()
        if shed_limit is not None and depth >= shed_limit:
            # Brownout: refuse the whole sealed batch immediately with
            # the SHED sentinel — the device stays free to drain the
            # backlog instead of queueing work it cannot answer in SLO.
            counts_all[i:j] = SHED
            stats.add_shed(arrivals[i:j], dispatch, depth)
            continue
        before = probe()
        t0 = time.perf_counter()
        if dispatcher is not None:
            counts, _info, outcome = dispatcher.dispatch(log.queries[i:j])
            attempts, level = outcome.attempts, outcome.level
            extra_s = outcome.delay_s
        else:
            out = engine(log.queries[i:j])
            counts = np.asarray(out[0] if isinstance(out, tuple) else out)
            attempts, level, extra_s = 1, "device", 0.0
        service_s = time.perf_counter() - t0 + extra_s
        counts_all[i:j] = counts
        reply = dispatch + service_s
        device_free = reply
        stats.add_batch(
            arrivals[i:j],
            dispatch,
            reply,
            device_s=service_s,
            jit_compiles=probe() - before,
            queue_depth=depth,
            attempts=attempts,
            level=level,
        )
    return ReplayReport(
        counts=counts_all,
        arrivals=arrivals,
        batches=batches,
        stats=stats,
        jit_compiles=probe() - cache_start,
        mode="sealed",
    )


async def _replay_async(
    service, engine, log, arrivals, cfg, probe, faults=None, resilience=None
) -> ReplayReport:
    from repro.serve.faults import SHED
    from repro.serve.resilience import ShedError

    loop = AsyncServingLoop(
        service,
        cfg,
        engine=engine,
        cache_probe=probe,
        resilience=resilience,
        faults=faults,
    )
    cache_start = probe()
    await loop.start()
    t0 = arrivals[0] if len(arrivals) else 0.0
    cq = log.as_conjunctive()

    async def one(r: int) -> int:
        await asyncio.sleep(float(arrivals[r] - t0))
        try:
            return await loop.submit(cq.terms(r))
        except ShedError:
            return int(SHED)

    counts = await asyncio.gather(
        *(one(r) for r in range(log.n_queries))
    )
    await loop.stop()
    batches = []
    off = 0
    for size in loop.stats.batch_sizes:
        batches.append((off, off + size))
        off += size
    return ReplayReport(
        counts=np.asarray(counts, np.int64),
        arrivals=arrivals,
        batches=batches,
        stats=loop.stats,
        jit_compiles=probe() - cache_start,
        mode="async",
    )
