"""RecSys retrieval with SeCluD conjunctive pre-filtering.

The ``retrieval_cand`` serving shape scores 1 query against 10⁶
candidates.  In production the dense scoring is preceded by attribute
filters ("in stock AND category=X") — exactly the paper's SAP-HANA
motivation: the full-text/attribute filter must be EXACT because it is
one clause of a larger query.  Pipeline:

  1. candidate items carry sparse attribute sets → an inverted index;
  2. SeCluD clusters the candidates with the ψ objective using the
     serving query-log marginals (items = "documents", attributes =
     "terms");
  3. a conjunctive attribute filter runs through the cluster index
     (lossless, per the paper);
  4. only surviving candidates get dense-scored by the model head.

This is the paper's technique as a first-class feature of the recsys
serving path (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import numpy as np

from repro.core.seclud import SecludPipeline, SecludResult
from repro.data.corpus import Corpus
from repro.data.query_log import QueryLog

__all__ = ["FilteredRetriever", "items_as_corpus"]


def items_as_corpus(item_attrs: list[np.ndarray], n_attrs: int) -> Corpus:
    """Items with sparse attribute sets -> CSR 'corpus'."""
    lengths = np.asarray([len(a) for a in item_attrs], dtype=np.int64)
    ptr = np.zeros(len(item_attrs) + 1, dtype=np.int64)
    np.cumsum(lengths, out=ptr[1:])
    terms = (
        np.concatenate([np.sort(np.unique(a)) for a in item_attrs])
        if len(item_attrs)
        else np.zeros(0, np.int32)
    )
    return Corpus(doc_ptr=ptr, doc_terms=terms.astype(np.int32), n_terms=n_attrs)


@dataclasses.dataclass
class RetrievalReport:
    n_candidates: int
    n_filtered: int
    filter_work: float
    baseline_work: float

    @property
    def speedup(self) -> float:
        return self.baseline_work / max(self.filter_work, 1e-30)


class FilteredRetriever:
    """SeCluD-filtered dense retrieval."""

    def __init__(
        self,
        item_corpus: Corpus,
        k: int = 64,
        attr_log: Optional[QueryLog] = None,
        tc: int = 2_000,
        seed: int = 0,
    ):
        self.corpus = item_corpus
        self.pipe = SecludPipeline(tc=tc, doc_grained_below=512, seed=seed)
        self.res: SecludResult = self.pipe.fit(
            item_corpus, k=k, algo="topdown", log=attr_log
        )
        # old item id for each new (reordered) id
        self.new_to_old = np.empty(item_corpus.n_docs, dtype=np.int64)
        self.new_to_old[self.res.perm] = np.arange(item_corpus.n_docs)

    def filter(self, *attrs: int) -> Tuple[np.ndarray, RetrievalReport]:
        """Exact conjunctive filter: item ids having ALL the attributes
        ("in stock AND category=X AND brand=Y" is ``filter(s, x, y)``)."""
        from repro.core.hier_index import _flatten_terms
        from repro.index.lookup import chain_lookup

        terms = _flatten_terms(attrs)
        docs_new, work = self.res.cluster_index.query(*terms)
        # Baseline work: cost-ordered Lookup chain on the unclustered
        # randomized index (smallest list probes first).
        lists = [self.res.base_index.postings(int(a)) for a in terms]
        _, base_total = chain_lookup(
            lists, self.corpus.n_docs, self.pipe.bucket_size
        )
        if len(terms) == 1:
            # A single-attribute filter intersects nothing in either
            # system — both just emit the posting list.  Price both sides
            # as that read so speedup reports an honest 1.0x instead of
            # baseline_work=0 (which would render as "0.0x speedup").
            base_total = float(len(lists[0]))
            filter_work = float(len(docs_new))
        else:
            filter_work = work["total"]
        report = RetrievalReport(
            n_candidates=self.corpus.n_docs,
            n_filtered=len(docs_new),
            filter_work=filter_work,
            baseline_work=base_total,
        )
        return self.new_to_old[docs_new], report

    def retrieve(
        self,
        score_fn: Callable[[np.ndarray], np.ndarray],
        *attrs: int,
        top_k: int = 10,
    ) -> Tuple[np.ndarray, np.ndarray, RetrievalReport]:
        """Filter on the attribute conjunction, then dense-score only the
        survivors; returns (item_ids, scores, report).
        ``score_fn(cand_ids) -> (B, N)``."""
        cand, report = self.filter(*attrs)
        if len(cand) == 0:
            return cand, np.zeros((0,)), report
        scores = np.asarray(score_fn(cand.astype(np.int32)))[0]
        k = min(top_k, len(cand))
        top = np.argpartition(-scores, k - 1)[:k]
        top = top[np.argsort(-scores[top])]
        return cand[top], scores[top], report
