"""Graceful degradation for the serving tier: the policy that keeps
responses exact while the device path fails underneath it.

The degradation ladder, rung by rung (each one strictly cheaper for the
cluster and strictly worse for the request than the one before):

1. **retry** — a failed dispatch is retried with bounded exponential
   backoff (``max_retries``; never an unbounded loop — seclint SEC006
   forbids those in this tier).
2. **evict / remesh** — a failure blamed on a shard feeds a targeted
   strike into ``SearchService.record_shard_times``; the straggler
   monitor's consecutive-strike rule evicts the device, the
   ``ElasticMesh`` rebuilds one shard smaller, the corpus re-partitions,
   and the retry lands on the surviving world.  Results stay
   bit-identical — the partition changes, the math does not.
3. **host fallback** — retry budget exhausted (or the breaker open):
   the sealed batch re-executes on the exact host engine
   (``SearchService.serve_counts``, the ``batched_query`` path), so even
   total device loss returns bit-identical counts.
4. **shed** — queue depth past the brownout threshold: the request is
   refused *immediately* with a typed :class:`ShedError` instead of
   joining a queue it would time out in.  Shedding is the only rung that
   does not answer; every answered request is exact.

The :class:`CircuitBreaker` keeps rung 3 cheap: after
``breaker_threshold`` consecutive device-path failures it opens and
batches go straight to host (no doomed device attempts), then after
``probe_after`` host-served batches it half-opens and admits exactly one
probe — success closes it, failure re-opens it.

A *timeout* here is detection, not preemption: the engine call is one
fused jit dispatch and cannot be interrupted midway, so a dispatch that
completes past ``dispatch_timeout_s`` keeps its (exact) result but
counts as a breaker failure — persistent slowness routes traffic to the
host path just like persistent raising does.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import numpy as np

from repro.dist.fault_tolerance import NoDevicesError
from repro.serve.faults import FaultInjector

__all__ = [
    "LEVELS",
    "ShedError",
    "ResilienceConfig",
    "CircuitBreaker",
    "DispatchOutcome",
    "ResilientDispatcher",
]

# Degradation levels a batch can be served at, in ladder order.
LEVELS = ("device", "retry", "remesh", "host", "shed")


class ShedError(RuntimeError):
    """Typed SHED reply: the tier refused the request to protect its SLO
    (queue depth past the brownout threshold)."""

    def __init__(self, queue_depth: int, threshold: int):
        super().__init__(
            f"request shed: queue depth {queue_depth} >= brownout "
            f"threshold {threshold}"
        )
        self.queue_depth = int(queue_depth)
        self.threshold = int(threshold)


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Policy knobs for :class:`ResilientDispatcher` and the serving
    loop's load shedding.

    ``dispatch_timeout_s`` — a completed dispatch slower than this is a
    breaker failure (the result is kept; it is exact).  ``max_retries``
    — extra attempts after the first; the bound the backoff loop runs
    to.  ``shed_queue_depth`` — queue depth at which new arrivals are
    refused with :class:`ShedError` (None = never shed).
    ``backoff_sleep`` — really sleep between retries (the live loop);
    sealed replay leaves it off and keeps time virtual.
    """

    dispatch_timeout_s: float = 1.0
    max_retries: int = 3
    backoff_base_s: float = 0.001
    backoff_factor: float = 2.0
    breaker_threshold: int = 2
    probe_after: int = 4
    shed_queue_depth: Optional[int] = None
    backoff_sleep: bool = False

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.breaker_threshold < 1:
            raise ValueError("breaker_threshold must be >= 1")
        if self.probe_after < 1:
            raise ValueError("probe_after must be >= 1")
        if self.shed_queue_depth is not None and self.shed_queue_depth < 0:
            raise ValueError("shed_queue_depth must be >= 0 (or None)")


class CircuitBreaker:
    """closed → open after ``threshold`` consecutive device-path
    failures; open admits nothing for ``probe_after`` host-served
    batches, then half-opens for exactly one probe.  ``trip(permanent=
    True)`` (no devices left at all) opens it for good."""

    def __init__(self, threshold: int = 2, probe_after: int = 4):
        self.threshold = int(threshold)
        self.probe_after = int(probe_after)
        self.state = "closed"
        self.consecutive_failures = 0
        self.host_batches = 0  # host-served batches since the breaker opened
        self.permanent = False

    def allow(self) -> bool:
        """May the next batch try the device path?"""
        if self.permanent:
            return False
        if self.state == "closed":
            return True
        if self.state == "open" and self.host_batches >= self.probe_after:
            self.state = "half_open"
        return self.state == "half_open"

    def record_success(self) -> None:
        self.state = "closed"
        self.consecutive_failures = 0
        self.host_batches = 0

    def record_failure(self) -> None:
        self.consecutive_failures += 1
        if self.state == "half_open" or (
            self.consecutive_failures >= self.threshold
        ):
            self.state = "open"
            self.host_batches = 0

    def note_host(self) -> None:
        """One batch served on the host path while the breaker is open."""
        self.host_batches += 1

    def trip(self, permanent: bool = False) -> None:
        self.state = "open"
        self.host_batches = 0
        self.permanent = self.permanent or permanent


@dataclasses.dataclass
class DispatchOutcome:
    """How one batch was served: the ladder rung (``level``), attempts
    spent, whether a remesh happened underneath it, whether the kept
    result came in past the timeout, and accrued virtual fault delay."""

    level: str = "device"
    attempts: int = 0
    remeshed: bool = False
    timed_out: bool = False
    delay_s: float = 0.0
    error: Optional[str] = None  # last device-path error, if any


class ResilientDispatcher:
    """Wraps one engine callable in the full degradation ladder.

    ``engine`` defaults to ``service.serve_counts_device`` (the routed
    device path), ``host_engine`` to ``service.serve_counts`` (the exact
    ``batched_query`` fallback).  ``injector`` is the shared
    :class:`~repro.serve.faults.FaultInjector` whose virtual delays are
    drained into the outcome (the driver owns ``begin_batch``).
    """

    def __init__(
        self,
        service=None,
        config: Optional[ResilienceConfig] = None,
        engine=None,
        host_engine=None,
        injector: Optional[FaultInjector] = None,
        clock=time.perf_counter,
    ):
        if engine is None:
            if service is None:
                raise ValueError("need a SearchService or an explicit engine")
            engine = service.serve_counts_device
        if host_engine is None:
            if service is None:
                raise ValueError(
                    "need a SearchService or an explicit host_engine for "
                    "the fallback rung"
                )
            host_engine = service.serve_counts
        self.service = service
        self.cfg = config or ResilienceConfig()
        self.breaker = CircuitBreaker(
            self.cfg.breaker_threshold, self.cfg.probe_after
        )
        self._engine = engine
        self._host = host_engine
        self.injector = injector
        self._clock = clock

    # -- the ladder --------------------------------------------------------

    def dispatch(self, queries) -> Tuple[np.ndarray, dict, DispatchOutcome]:
        """Serve one sealed batch at the cheapest rung that answers.

        Returns ``(counts, info, outcome)``; counts are exact at every
        rung (shedding happens upstream, before dispatch)."""
        out = DispatchOutcome()
        if not self.breaker.allow():
            self.breaker.note_host()
            return self._fallback(queries, out, why="circuit open")
        epoch0 = self._epoch()
        backoff = self.cfg.backoff_base_s
        last_err: Optional[BaseException] = None
        for attempt in range(self.cfg.max_retries + 1):
            out.attempts = attempt + 1
            t0 = self._clock()
            try:
                raw = self._engine(queries)
            except NoDevicesError as err:
                # Nothing left to evict to: host forever.
                last_err = err
                self.breaker.trip(permanent=True)
                break
            except Exception as err:  # typed faults + real dispatch errors
                last_err = err
                shard = getattr(err, "shard", None)
                if shard is not None:
                    try:
                        out.remeshed = self._strike(int(shard)) or out.remeshed
                    except NoDevicesError as lost:
                        last_err = lost
                        self.breaker.trip(permanent=True)
                        break
                if attempt >= self.cfg.max_retries:
                    break
                if self.cfg.backoff_sleep and backoff > 0:
                    time.sleep(backoff)
                backoff *= self.cfg.backoff_factor
                continue
            elapsed = self._clock() - t0
            if self.injector is not None:
                d = self.injector.take_delay()
                out.delay_s += d
                elapsed += d
            counts = np.asarray(raw[0] if isinstance(raw, tuple) else raw)
            info = raw[1] if isinstance(raw, tuple) and len(raw) > 1 else {}
            if not isinstance(info, dict):  # (counts, docs, info) form
                info = raw[-1] if isinstance(raw[-1], dict) else {}
            out.remeshed = out.remeshed or self._epoch() > epoch0
            out.timed_out = elapsed > self.cfg.dispatch_timeout_s
            if out.timed_out:
                # Slow-but-exact: keep the result, strike the breaker.
                self.breaker.record_failure()
            else:
                self.breaker.record_success()
            out.level = (
                "remesh"
                if out.remeshed
                else ("retry" if out.attempts > 1 else "device")
            )
            return counts, info, out
        self.breaker.record_failure()
        why = f"{type(last_err).__name__}: {last_err}" if last_err else None
        return self._fallback(queries, out, why=why)

    # -- rungs -------------------------------------------------------------

    def _fallback(self, queries, out: DispatchOutcome, why=None):
        """Rung 3: the exact host engine.  Bit-identical counts, no
        device involved."""
        counts, info = self._host(queries)
        if self.injector is not None:
            out.delay_s += self.injector.take_delay()
        out.level = "host"
        out.error = why
        info = dict(info)
        info["fallback"] = why or "host"
        return np.asarray(counts), info, out

    def _strike(self, shard: int) -> bool:
        """Rung 2: one targeted strike into the eviction chain.  A
        failure blamed on ``shard`` reports it unambiguously past the
        straggler deadline; ``strikes_to_evict`` consecutive failures
        evict it and re-partition.  Returns True when a remesh ran."""
        svc = self.service
        n = getattr(svc, "n_shards", 0) if svc is not None else 0
        if not n or shard >= n:
            return False
        times = np.ones(n, np.float64)
        times[shard] = 1e6  # unambiguously past any deadline_factor
        _verdicts, remeshed = svc.record_shard_times(times)
        return bool(remeshed)

    def _epoch(self) -> int:
        elastic = getattr(self.service, "_elastic", None)
        return int(elastic.epoch) if elastic is not None else 0
