"""Deterministic, seeded fault injection for the serving tier.

A :class:`FaultSchedule` is a pure description of what goes wrong and
when — shard slowdowns, dispatch exceptions, device loss, queue floods —
keyed on *sealed-batch ordinals*, not wall clock, so a chaos replay with
the same seed and schedule reproduces the same failures, retries,
evictions and sheds bit for bit.  A :class:`FaultInjector` interprets
the schedule inside the real dispatch path: ``device_counts`` and
``sharded_device_counts`` accept it as ``fault_hook`` and call
:meth:`FaultInjector.on_dispatch` before the fused fold (where it may
raise or charge virtual latency) and
:meth:`FaultInjector.perturb_shard_times` on the per-shard timing
attribution afterwards — faults fire inside the engine call itself, no
test monkeypatching.

Batch/attempt bookkeeping: the *driver* (sealed replay or the async
loop) calls :meth:`FaultInjector.begin_batch` once per sealed batch;
every engine call inside that batch is one dispatch *attempt*
(``on_dispatch`` counts them), which is how an ``exception`` event with
``n_attempts=1`` fails the first try and lets the retry through.

Persistence: events with ``n_batches=None`` stay active *until the
serving mesh shrinks* — the injector watches the ``n_shards`` each
dispatch reports and consumes such events when a remesh drops it.  That
is the device-loss contract: shard ``k`` keeps failing until failover
evicts it, after which the survivors (a re-partitioned world where
"shard k" no longer names the lost device) serve cleanly.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

__all__ = [
    "SHED",
    "KINDS",
    "InjectedFault",
    "DeviceLostError",
    "FaultEvent",
    "FaultSchedule",
    "FaultInjector",
]

# Count sentinel a shed request replies with (its typed error is
# ShedError in repro.serve.resilience; this is the value that lands in
# ReplayReport.counts so arrival-order arrays stay rectangular).
SHED = -1

KINDS = ("slowdown", "exception", "device_loss", "queue_flood")


class InjectedFault(RuntimeError):
    """A scheduled dispatch failure, raised inside the engine call.

    ``shard`` carries the blamed shard (None = unattributed), which is
    what lets the resilience layer feed a targeted strike into
    ``record_shard_times`` and drive the eviction chain."""

    def __init__(
        self,
        message: str,
        shard: Optional[int] = None,
        batch: Optional[int] = None,
    ):
        super().__init__(message)
        self.shard = shard
        self.batch = batch


class DeviceLostError(InjectedFault):
    """The scheduled loss of a device: every dispatch touching the lost
    shard fails until failover re-partitions the corpus without it."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``at`` is the first sealed-batch ordinal the event is active on;
    ``n_batches`` how many consecutive batches it stays active
    (``None`` = until the mesh shrinks, the device-loss semantics).
    ``n_attempts`` bounds how many dispatch *attempts* per active batch
    an ``exception``/``device_loss`` event fails (``None`` = all — only
    eviction or the host fallback ends it).
    """

    kind: str
    at: int
    n_batches: Optional[int] = 1
    shard: Optional[int] = None
    factor: float = 10.0  # slowdown multiplier on the reported shard time
    delay_s: float = 0.0  # virtual service-time delay per faulted dispatch
    depth: int = 0  # queue_flood: phantom backlog while active
    n_attempts: Optional[int] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} (one of {KINDS})")
        if self.at < 0:
            raise ValueError(f"batch ordinal must be >= 0, got {self.at}")
        if self.n_batches is not None and self.n_batches < 1:
            raise ValueError("n_batches must be >= 1 (or None for until-remesh)")
        if self.factor <= 0:
            raise ValueError("slowdown factor must be > 0")

    def active_at(self, batch: int) -> bool:
        if batch < self.at:
            return False
        if self.n_batches is None:
            return True
        return batch < self.at + self.n_batches


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """An immutable, seed-stamped list of :class:`FaultEvent`.

    The seed is part of the schedule's identity (chaos replays compare
    runs by it); :meth:`chaos` derives a reproducible random mix from it.
    """

    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    # -- canonical scenarios ----------------------------------------------

    @classmethod
    def shard_loss(cls, shard: int, at: int = 0, seed: int = 0) -> "FaultSchedule":
        """Shard ``shard``'s device dies at batch ``at`` and stays dead
        until failover re-partitions the corpus without it."""
        return cls(
            (FaultEvent("device_loss", at=at, n_batches=None, shard=shard),),
            seed=seed,
        )

    @classmethod
    def shard_slowdown(
        cls,
        shard: int,
        at: int = 0,
        factor: float = 10.0,
        n_batches: Optional[int] = None,
        delay_s: float = 0.0,
        seed: int = 0,
    ) -> "FaultSchedule":
        """Shard ``shard`` straggles by ``factor`` from batch ``at`` —
        dispatches still succeed, the reported shard time inflates, and
        the straggler monitor does the rest."""
        return cls(
            (
                FaultEvent(
                    "slowdown",
                    at=at,
                    n_batches=n_batches,
                    shard=shard,
                    factor=factor,
                    delay_s=delay_s,
                ),
            ),
            seed=seed,
        )

    @classmethod
    def flaky(
        cls,
        at: int = 0,
        n_batches: int = 1,
        n_attempts: Optional[int] = 1,
        seed: int = 0,
    ) -> "FaultSchedule":
        """A transient dispatch exception: the first ``n_attempts`` tries
        of each affected batch raise, the retry after them succeeds."""
        return cls(
            (
                FaultEvent(
                    "exception", at=at, n_batches=n_batches, n_attempts=n_attempts
                ),
            ),
            seed=seed,
        )

    @classmethod
    def flood(
        cls, at: int, depth: int, n_batches: int = 1, seed: int = 0
    ) -> "FaultSchedule":
        """``depth`` phantom requests sit in the queue while active —
        the brownout trigger for load-shedding tests."""
        return cls(
            (FaultEvent("queue_flood", at=at, n_batches=n_batches, depth=depth),),
            seed=seed,
        )

    @classmethod
    def chaos(
        cls,
        seed: int,
        n_batches: int,
        n_events: int = 4,
        n_shards: int = 1,
    ) -> "FaultSchedule":
        """A reproducible random mix of transient faults over a replay of
        ``n_batches`` sealed batches.  Deliberately excludes device loss
        (which is one-way); compose :meth:`shard_loss` explicitly."""
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        for _ in range(n_events):
            kind = ("slowdown", "exception", "queue_flood")[int(rng.integers(3))]
            at = int(rng.integers(max(n_batches, 1)))
            span = int(rng.integers(1, 4))
            if kind == "slowdown":
                events.append(
                    FaultEvent(
                        "slowdown",
                        at=at,
                        n_batches=span,
                        shard=int(rng.integers(max(n_shards, 1))),
                        factor=float(2.0 + 8.0 * rng.random()),
                    )
                )
            elif kind == "exception":
                events.append(
                    FaultEvent("exception", at=at, n_batches=span, n_attempts=1)
                )
            else:
                events.append(
                    FaultEvent(
                        "queue_flood",
                        at=at,
                        n_batches=span,
                        depth=int(rng.integers(4, 64)),
                    )
                )
        events.sort(key=lambda e: (e.at, e.kind))
        return cls(tuple(events), seed=seed)


class FaultInjector:
    """Stateful interpreter of a :class:`FaultSchedule` over one run.

    The engine calls :meth:`on_dispatch` / :meth:`perturb_shard_times`
    (threaded through as ``fault_hook``); the driver calls
    :meth:`begin_batch` per sealed batch and :meth:`extra_queue_depth`
    for the flood contribution to its shed decision; the resilience
    layer drains accrued virtual latency with :meth:`take_delay`.
    """

    def __init__(self, schedule: FaultSchedule):
        if not isinstance(schedule, FaultSchedule):
            schedule = FaultSchedule(tuple(schedule))
        self.schedule = schedule
        self.batch_idx = -1  # advanced by begin_batch (drivers own it)
        self.attempt = 0  # dispatch attempts within the current batch
        self._last_n_shards: Optional[int] = None
        self._consumed: set = set()  # event positions ended by a remesh
        self._delay_pending = 0.0
        self.fired: List[Tuple[int, int, str]] = []  # (batch, attempt, kind)

    # -- driver side -------------------------------------------------------

    def begin_batch(self) -> int:
        """Advance to the next sealed batch; resets the attempt counter."""
        self.batch_idx += 1
        self.attempt = 0
        return self.batch_idx

    def extra_queue_depth(self) -> int:
        """Phantom backlog from the queue_flood events active now."""
        return sum(
            ev.depth for _, ev in self._active("queue_flood")
        )

    def take_delay(self) -> float:
        """Drain the virtual service-time delay accrued since last taken."""
        d = self._delay_pending
        self._delay_pending = 0.0
        return d

    # -- engine side (the fault_hook protocol) -----------------------------

    def on_dispatch(self, n_shards: int = 1) -> None:
        """Called inside the engine before the fused fold.  Raises the
        scheduled :class:`InjectedFault`/:class:`DeviceLostError` and
        accrues virtual slowdown latency.  Watches ``n_shards`` to
        consume until-remesh events once failover shrank the mesh."""
        if self.batch_idx < 0:
            self.batch_idx = 0  # direct engine use without a driver
        if self._last_n_shards is not None and n_shards < self._last_n_shards:
            self._note_remesh()
        self._last_n_shards = int(n_shards)
        attempt = self.attempt
        self.attempt += 1
        batch = self.batch_idx
        for _, ev in self._active("slowdown", batch):
            if ev.delay_s:
                self._delay_pending += ev.delay_s
                self.fired.append((batch, attempt, "slowdown"))
        for pos, ev in self._active("exception", batch) + self._active(
            "device_loss", batch
        ):
            if ev.n_attempts is not None and attempt >= ev.n_attempts:
                continue
            self.fired.append((batch, attempt, ev.kind))
            if ev.kind == "device_loss":
                raise DeviceLostError(
                    f"injected device loss (shard {ev.shard}) at batch {batch}",
                    shard=ev.shard,
                    batch=batch,
                )
            raise InjectedFault(
                f"injected dispatch fault at batch {batch} attempt {attempt}",
                shard=ev.shard,
                batch=batch,
            )

    def perturb_shard_times(self, times) -> np.ndarray:
        """Apply active slowdowns to the engine's per-shard timing
        attribution — the signal the straggler monitor acts on."""
        t = np.asarray(times, np.float64).copy()
        for _, ev in self._active("slowdown"):
            if ev.shard is None:
                t *= ev.factor
            elif 0 <= ev.shard < len(t):
                t[ev.shard] *= ev.factor
        return t

    # -- internals ---------------------------------------------------------

    def _active(
        self, kind: str, batch: Optional[int] = None
    ) -> List[Tuple[int, FaultEvent]]:
        b = self.batch_idx if batch is None else batch
        return [
            (pos, ev)
            for pos, ev in enumerate(self.schedule.events)
            if ev.kind == kind
            and pos not in self._consumed
            and ev.active_at(max(b, 0))
        ]

    def _note_remesh(self) -> None:
        """The mesh shrank: until-remesh events have done their damage —
        the shard they named no longer exists in the new partition."""
        for pos, ev in enumerate(self.schedule.events):
            if (
                pos not in self._consumed
                and ev.n_batches is None
                and ev.active_at(max(self.batch_idx, 0))
            ):
                self._consumed.add(pos)
