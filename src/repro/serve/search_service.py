"""Distributed SeCluD search service.

The paper's two-level query algorithm as a serving system:

  * clusters are sharded over the mesh's data axis (the paper §1:
    "the resulting clusters are also useful ... for distributing the work
    over many machines");
  * the cluster index (term → clusters) is replicated — the paper §3.2
    argues this replication is affordable, we adopt it;
  * a query batch is broadcast, every shard intersects the posting
    segments of its local clusters, counts are combined with one psum.

Two execution paths with the same contract, both on the batched
two-level planner (``repro.core.batched_query`` — no per-query loop):
  * ``serve_counts``       — host path (vectorized numpy Lookup, exact
    work metric, bit-identical to looping ``ClusterIndex.query``);
  * ``pack`` + ``device_counts`` — device path: fixed-shape padded segment
    batches + ``shard_map`` over cluster shards, Pallas/jnp intersection
    kernels. Used by the serving dry-run and the wall-clock benchmark.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.batched_query import batched_query, gather_padded, plan_segment_pairs
from repro.core.seclud import SecludResult
from repro.dist import sharding as sh
from repro.kernels.intersect.ref import PAD

__all__ = ["SearchService", "PackedClusters"]


@dataclasses.dataclass
class PackedClusters:
    """Device-resident layout: for each (query, cluster-of-query) pair the
    two posting segments, padded to fixed widths and stacked."""

    short: np.ndarray  # (R, Ls)
    long: np.ndarray  # (R, Ll)
    row_query: np.ndarray  # (R,) query id of each row
    n_queries: int


class SearchService:
    def __init__(self, result: SecludResult):
        self.res = result

    # -- host path -------------------------------------------------------

    def serve_counts(self, queries: np.ndarray) -> Tuple[np.ndarray, dict]:
        """Exact per-query result counts via the two-level cluster index.

        One vectorized engine pass (``repro.core.batched_query``) — counts
        and total work are bit-identical to looping ``cluster_index.query``.
        """
        ptr, _docs, work = batched_query(self.res.cluster_index, np.asarray(queries))
        return np.diff(ptr).astype(np.int64), {"work": work["total"]}

    # -- device path ------------------------------------------------------

    def pack(self, queries: np.ndarray, pad_to: int = 128) -> PackedClusters:
        """Build the fixed-shape per-(query, cluster) segment batch.

        Rows come from the batched planner (one CSR set-intersection for
        the whole batch, no per-query loop).  An empty plan yields an
        honestly-empty ``(0, pad_to)`` pack — never a fabricated PAD row
        attributed to query 0.
        """
        cidx = self.res.cluster_index
        plan = plan_segment_pairs(cidx, np.asarray(queries))
        docs = cidx.index.post_docs
        max_s = max(int(plan.short_len.max()) if plan.n_pairs else 0, pad_to)
        max_l = max(int(plan.long_len.max()) if plan.n_pairs else 0, pad_to)
        max_s = -(-max_s // pad_to) * pad_to
        max_l = -(-max_l // pad_to) * pad_to
        return PackedClusters(
            short=gather_padded(docs, plan.short_start, plan.short_len, max_s),
            long=gather_padded(docs, plan.long_start, plan.long_len, max_l),
            row_query=plan.pair_query.astype(np.int32),
            n_queries=len(queries),
        )

    @staticmethod
    def device_counts(packed: PackedClusters, mesh: Optional[Mesh] = None):
        """Intersect all rows on device; segment-sum counts per query.
        With a mesh, rows are sharded over the data axis and results
        combined with one psum_scatter-equivalent reduction."""
        from repro.kernels.intersect.ops import intersect_count

        nq = packed.n_queries
        if packed.short.shape[0] == 0:
            return jnp.zeros(nq, jnp.int32)
        short = jnp.asarray(packed.short)
        long = jnp.asarray(packed.long)
        rq = jnp.asarray(packed.row_query)

        def local(short, long, rq):
            c = intersect_count(short, long)
            return jax.ops.segment_sum(c, rq, num_segments=nq)

        if mesh is None:
            return local(short, long, rq)
        # Row sharding over ALL data axes (pod included on multi-pod
        # meshes) comes from the distribution substrate, so serving and
        # training agree on what "data-parallel" means.
        dp_axes = sh.batch_axes(mesh)
        dp = sh.data_spec(mesh)
        pad = sh.shard_rows(short.shape[0], mesh)
        if pad:
            short = jnp.pad(short, ((0, pad), (0, 0)), constant_values=PAD)
            long = jnp.pad(long, ((0, pad), (0, 0)), constant_values=PAD)
            # Padding rows carry query id nq (out of range): segment_sum
            # drops them by construction instead of crediting query 0.
            rq = jnp.pad(rq, (0, pad), constant_values=nq)
        from jax.experimental.shard_map import shard_map

        fn = shard_map(
            lambda s, l, r: jax.lax.psum(local(s, l, r), dp_axes),
            mesh=mesh,
            in_specs=(P(dp, None), P(dp, None), P(dp)),
            out_specs=P(),
        )
        return fn(short, long, rq)
