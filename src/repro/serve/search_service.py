"""Distributed SeCluD search service.

The paper's two-level query algorithm as a serving system:

  * clusters are sharded over the mesh's data axis (the paper §1:
    "the resulting clusters are also useful ... for distributing the work
    over many machines");
  * the cluster index (term → clusters) is replicated — the paper §3.2
    argues this replication is affordable, we adopt it;
  * a query batch is broadcast, every shard intersects the posting
    segments of its local clusters, counts are combined with one psum.

Queries are arbitrary-arity conjunctions (``repro.core.queries``): the
historical ``(n, 2)`` term-pair array, the padded ``(n, max_arity)``
form, or a ``ConjunctiveQueries``.  Two execution paths with the same
contract, both on the batched planner (``repro.core.batched_query`` — no
per-query loop):
  * ``serve_counts``       — host path (vectorized numpy Lookup, exact
    work metric, bit-identical to looping ``ClusterIndex.query``);
  * ``pack`` + ``device_counts`` — device path: fixed-shape padded
    rank-r segment blocks + ``shard_map`` over cluster shards.  All-pair
    batches run the single Pallas/jnp ``intersect_count`` reduction (the
    historical layout); mixed/higher arities fold the blocks pairwise
    with a masked membership select before counting survivors.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.batched_query import batched_query, gather_padded, plan_segment_pairs
from repro.core.queries import as_queries
from repro.core.seclud import SecludResult
from repro.dist import sharding as sh
from repro.kernels.intersect.ref import PAD

__all__ = ["SearchService", "PackedClusters"]


@dataclasses.dataclass
class PackedClusters:
    """Device-resident layout: for each (query, cluster-of-query) group
    the cost-ordered posting segments, padded to fixed per-rank widths and
    stacked.  ``segments[r]`` is the (R, L_r) rank-r block; rows whose
    query has fewer than r + 1 terms are all-PAD."""

    segments: Tuple[np.ndarray, ...]
    row_query: np.ndarray  # (R,) query id of each row
    row_arity: np.ndarray  # (R,) int32 — segments actually present per row
    n_queries: int

    @property
    def short(self) -> np.ndarray:
        """Rank-0 block (the probing side of every row's chain)."""
        return self.segments[0]

    @property
    def long(self) -> np.ndarray:
        """Rank-1 block — THE long side for the historical 2-term pack."""
        return self.segments[1]


class SearchService:
    def __init__(self, result: SecludResult):
        self.res = result

    # -- host path -------------------------------------------------------

    def serve_counts(self, queries) -> Tuple[np.ndarray, dict]:
        """Exact per-query result counts via the two-level cluster index.

        One vectorized engine pass (``repro.core.batched_query``) — counts
        and total work are bit-identical to looping ``cluster_index.query``
        over the conjunctions.
        """
        ptr, _docs, work = batched_query(self.res.cluster_index, queries)
        return np.diff(ptr).astype(np.int64), {"work": work["total"]}

    # -- device path ------------------------------------------------------

    def pack(self, queries, pad_to: int = 128) -> PackedClusters:
        """Build the fixed-shape per-(query, cluster) segment batch.

        Rows come from the batched planner (one CSR chain for the whole
        batch, no per-query loop); each query contributes one row per
        common cluster holding its ``arity`` cost-ordered segments.  An
        empty plan yields an honestly-empty ``(0, pad_to)`` pack — never a
        fabricated PAD row attributed to query 0.
        """
        cq = as_queries(queries)
        cidx = self.res.cluster_index
        plan = plan_segment_pairs(cidx, cq)
        docs = cidx.index.post_docs
        n_rows = plan.n_pairs
        max_a = max(plan.max_arity, 2)  # always expose short+long blocks
        segments = []
        for r in range(max_a):
            has = plan.arity > r
            si = np.where(has, plan.seg_ptr[:-1] + r, 0)  # 0 = safe index
            starts = plan.seg_start[si]
            lens = np.where(has, plan.seg_len[si], 0)
            width = max(int(lens.max()) if n_rows else 0, pad_to)
            width = -(-width // pad_to) * pad_to
            segments.append(gather_padded(docs, starts, lens, width))
        return PackedClusters(
            segments=tuple(segments),
            row_query=plan.pair_query.astype(np.int32),
            row_arity=plan.arity.astype(np.int32),
            n_queries=cq.n_queries,
        )

    @staticmethod
    def device_counts(packed: PackedClusters, mesh: Optional[Mesh] = None):
        """Intersect all rows on device; segment-sum counts per query.
        With a mesh, rows are sharded over the data axis and results
        combined with one psum_scatter-equivalent reduction."""
        from repro.kernels.intersect.ops import intersect_count
        from repro.kernels.intersect.ref import intersect_members_ref

        nq = packed.n_queries
        if packed.short.shape[0] == 0:
            return jnp.zeros(nq, jnp.int32)
        segs = tuple(jnp.asarray(b) for b in packed.segments)
        rq = jnp.asarray(packed.row_query)
        ra = jnp.asarray(packed.row_arity)
        pairs_only = bool((packed.row_arity == 2).all()) and len(segs) == 2

        def local(segs, rq, ra):
            if pairs_only:
                # The historical 2-term layout: one kernel reduction.
                c = intersect_count(segs[0], segs[1])
            else:
                # Masked pairwise fold: rows keep their running
                # intersection in the rank-0 block; rank r filters it for
                # rows with arity > r, then survivors are counted.
                cur = segs[0]
                for r in range(1, len(segs)):
                    hit = intersect_members_ref(cur, segs[r])
                    active = (ra > r)[:, None]
                    cur = jnp.where(active & ~hit, PAD, cur)
                c = (cur != PAD).sum(axis=1).astype(jnp.int32)
            return jax.ops.segment_sum(c, rq, num_segments=nq)

        if mesh is None:
            return local(segs, rq, ra)
        # Row sharding over ALL data axes (pod included on multi-pod
        # meshes) comes from the distribution substrate, so serving and
        # training agree on what "data-parallel" means.
        dp_axes = sh.batch_axes(mesh)
        dp = sh.data_spec(mesh)
        pad = sh.shard_rows(segs[0].shape[0], mesh)
        if pad:
            segs = tuple(
                jnp.pad(s, ((0, pad), (0, 0)), constant_values=PAD) for s in segs
            )
            # Padding rows carry query id nq (out of range): segment_sum
            # drops them by construction instead of crediting query 0.
            rq = jnp.pad(rq, (0, pad), constant_values=nq)
            ra = jnp.pad(ra, (0, pad), constant_values=0)
        from jax.experimental.shard_map import shard_map

        fn = shard_map(
            lambda s, r, a: jax.lax.psum(local(s, r, a), dp_axes),
            mesh=mesh,
            in_specs=(tuple(P(dp, None) for _ in segs), P(dp), P(dp)),
            out_specs=P(),
        )
        return fn(segs, rq, ra)
