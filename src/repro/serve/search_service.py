"""Distributed SeCluD search service.

The paper's query algorithm as a serving system, at any hierarchy depth:

  * clusters are sharded over the mesh's data axis (the paper §1:
    "the resulting clusters are also useful ... for distributing the work
    over many machines") — with an L-level ``HierIndex`` the TOP level
    doubles as the machine-level router: ``pack(pin_top=True)`` groups
    rows by their level-0 ancestor so a top-level cluster's work lands on
    one contiguous run of rows, i.e. (modulo the shard boundary cut) one
    mesh shard;
  * the cluster index (term → clusters) is replicated — the paper §3.2
    argues this replication is affordable, we adopt it;
  * a query batch is broadcast, every shard intersects the posting
    segments of its local clusters, counts are combined with one psum.

Queries are arbitrary-arity conjunctions (``repro.core.queries``): the
historical ``(n, 2)`` term-pair array, the padded ``(n, max_arity)``
form, or a ``ConjunctiveQueries``.  Two execution paths with the same
contract, both on the batched planner (``repro.core.batched_query`` — no
per-query loop), both routed through the fitted ``hier_index`` when the
result carries one (the plan already encodes the whole descent; the
two-level ``cluster_index`` is the fallback and the L = 2 case):
  * ``serve_counts``       — host path (vectorized numpy Lookup, exact
    work metric, bit-identical to looping ``HierIndex.query``);
  * ``pack`` + ``device_counts`` — device path: fixed-shape padded
    rank-r segment blocks + ``shard_map`` over cluster shards.  All-pair
    batches run the single Pallas/jnp ``intersect_count`` reduction (the
    historical layout); mixed/higher arities fold the blocks pairwise
    with a masked membership select before counting survivors.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.batched_query import batched_query, gather_padded, plan_segment_pairs
from repro.core.hier_index import as_hier
from repro.core.queries import as_queries
from repro.core.seclud import SecludResult
from repro.dist import sharding as sh
from repro.kernels.intersect.ref import PAD

__all__ = ["SearchService", "PackedClusters"]


@dataclasses.dataclass
class PackedClusters:
    """Device-resident layout: for each (query, leaf-cluster-of-query)
    group the cost-ordered posting segments, padded to fixed per-rank
    widths and stacked.  ``segments[r]`` is the (R, L_r) rank-r block;
    rows whose query has fewer than r + 1 terms are all-PAD.
    ``row_top`` is each row's top-level (level-0) ancestor cluster — the
    shard-routing key (equal to the leaf cluster at L = 2, 0 at L = 1)."""

    segments: Tuple[np.ndarray, ...]
    row_query: np.ndarray  # (R,) query id of each row
    row_arity: np.ndarray  # (R,) int32 — segments actually present per row
    n_queries: int
    row_top: Optional[np.ndarray] = None  # (R,) int32 — level-0 ancestor

    @property
    def short(self) -> np.ndarray:
        """Rank-0 block (the probing side of every row's chain)."""
        return self.segments[0]

    @property
    def long(self) -> np.ndarray:
        """Rank-1 block — THE long side for the historical 2-term pack."""
        return self.segments[1]


class SearchService:
    def __init__(self, result: SecludResult):
        self.res = result
        self._device_index = None
        self._sharded = None  # ShardedDeviceIndex once enable_sharded ran
        self._elastic = None  # ElasticMesh owning the serving device pool
        self._monitor = None  # StragglerMonitor over the shards
        self._faults = None  # FaultInjector threaded into the engines

    @property
    def query_index(self):
        """The index queries route through: the fitted L-level
        ``hier_index`` when the result carries one, else the two-level
        ``cluster_index`` (stub results in tests, old pickles)."""
        hier = getattr(self.res, "hier_index", None)
        return hier if hier is not None else self.res.cluster_index

    @property
    def device_index(self):
        """The upload-once :class:`repro.core.device_engine.DeviceIndex`
        serving this service's device paths.  Built on first access (or
        inherited from ``SecludPipeline.fit``, which caches it on the
        fitted index) and reused by every subsequent batch — the index
        arrays never travel host -> device again."""
        if self._device_index is None:
            from repro.core.device_engine import device_index

            self._device_index = device_index(self.query_index)
        return self._device_index

    # -- host path -------------------------------------------------------

    def serve_counts(self, queries) -> Tuple[np.ndarray, dict]:
        """Exact per-query result counts via the hierarchical descent.

        One vectorized engine pass (``repro.core.batched_query``) — counts
        and total work are bit-identical to looping
        ``query_index.query`` over the conjunctions, at any depth.
        """
        ptr, _docs, work = batched_query(self.query_index, queries)
        return np.diff(ptr).astype(np.int64), {"work": work["total"]}

    # -- device path ------------------------------------------------------

    def serve_counts_device(self, queries, return_docs: bool = False):
        """Exact per-query counts through the device-resident engine.

        The whole cost-ordered k-way chain runs as one fused jit call
        against the persistent :attr:`device_index`; only the counts
        (and, on request, the member doc ids) return to host.  Counts
        are bit-identical to :meth:`serve_counts`; ``info`` carries the
        engine's ``n_kernel_calls`` / ``padding_overhead`` attribution
        instead of the host path's work metric.

        After :meth:`enable_sharded` the same call serves through the
        mesh-sharded engine — one ``shard_map`` dispatch over the
        per-shard corpus partitions, counts psum-combined — with results
        still bit-identical (``info`` gains the sharding attribution).
        """
        from repro.core.device_engine import device_counts, sharded_device_counts

        if self._sharded is not None:
            out = sharded_device_counts(
                self.query_index,
                queries,
                sidx=self._sharded,
                return_docs=return_docs,
                fault_hook=self._faults,
            )
            # Failover is fed from the serving path itself: every sharded
            # dispatch reports its per-shard times to the straggler
            # monitor, so a persistently slow shard is evicted and the
            # corpus re-partitioned with no manual record_shard_times
            # call.  Empty-plan batches (no device work, all-zero times)
            # are skipped — a dead batch says nothing about shard health
            # and must not reset a straggler's consecutive strikes.
            info = out[-1]
            times = info.get("shard_times")
            if (
                self._monitor is not None
                and times is not None
                and info.get("n_kernel_calls", 0.0)
                and len(times) == self._monitor.n_hosts
            ):
                _verdicts, remeshed = self.record_shard_times(times)
                info["remeshed"] = remeshed
            return out
        return device_counts(
            self.query_index,
            queries,
            dindex=self.device_index,
            return_docs=return_docs,
            fault_hook=self._faults,
        )

    # -- async serving loop -----------------------------------------------

    def serve_async(self, config=None, **config_kwargs):
        """An :class:`repro.serve.loop.AsyncServingLoop` over this
        service's device path: arrivals accumulate under a
        deadline/max-batch policy and each sealed batch dispatches as
        one fused engine call (through the mesh-sharded fold after
        :meth:`enable_sharded`).

        Pass a :class:`repro.serve.loop.ServeConfig` or its fields as
        keywords (``max_batch=``, ``deadline_s=``).  ``await start()``
        inside a running event loop; call ``prewarm()`` first so
        steady-state serving never compiles.
        """
        from repro.serve.loop import AsyncServingLoop, ServeConfig

        return AsyncServingLoop(
            self, config or ServeConfig(**config_kwargs)
        )

    # -- fault injection (chaos harness) -----------------------------------

    def install_faults(self, injector):
        """Thread a :class:`repro.serve.faults.FaultInjector` into this
        service's device dispatch paths (``None`` uninstalls).  Scheduled
        faults then fire inside ``device_counts`` /
        ``sharded_device_counts`` — the real dispatch path, not a test
        shim.  Returns the injector for chaining."""
        self._faults = injector
        return injector

    # -- sharded serving + failover ---------------------------------------

    @property
    def sharded_index(self):
        """The active :class:`repro.core.device_engine.ShardedDeviceIndex`
        (None until :meth:`enable_sharded`)."""
        return self._sharded

    @property
    def n_shards(self) -> int:
        return self._sharded.n_shards if self._sharded is not None else 0

    def enable_sharded(
        self,
        n_shards: Optional[int] = None,
        mesh: Optional[Mesh] = None,
        deadline_factor: float = 1.5,
        strikes_to_evict: int = 3,
    ):
        """Partition the corpus over ``n_shards`` devices (or an explicit
        mesh) and route :meth:`serve_counts_device` through the sharded
        engine.

        The device pool is owned by an ``ElasticMesh`` and each shard is
        watched by a ``StragglerMonitor`` (one "host" per shard): feed
        per-step shard times to :meth:`record_shard_times` and an evicted
        shard's device is dropped from the pool, the mesh rebuilt one
        shard smaller, and the corpus re-partitioned — the lost shard's
        top-level clusters are absorbed by the survivors, results stay
        bit-identical.
        """
        from repro.core.device_engine import shard_mesh, sharded_device_index
        from repro.dist.fault_tolerance import ElasticMesh, StragglerMonitor

        if mesh is None:
            mesh = shard_mesh(n_shards)
        self._elastic = ElasticMesh(model_parallel=1)
        self._elastic.remesh(list(np.asarray(mesh.devices).reshape(-1)))
        self._sharded = sharded_device_index(
            self.query_index, mesh=self._elastic.mesh
        )
        self._monitor = StragglerMonitor(
            self._sharded.n_shards,
            deadline_factor=deadline_factor,
            strikes_to_evict=strikes_to_evict,
        )
        return self._sharded

    def record_shard_times(self, step_times):
        """Report one serving step's per-shard wall-clock times.

        Returns ``(verdicts, remeshed)``.  When the monitor's consecutive
        strikes evict a shard, its device is excluded from the elastic
        pool, the mesh rebuilt from the survivors, the corpus
        re-partitioned over the smaller mesh (top clusters of the lost
        shard re-routed to its neighbors) and a fresh monitor started for
        the new shard count.
        """
        if self._monitor is None:
            raise RuntimeError("sharded serving not enabled")
        from repro.core.device_engine import sharded_device_index
        from repro.dist.fault_tolerance import StragglerMonitor

        verdicts = self._monitor.record(step_times)
        evictees = [v.host for v in verdicts if v.evict]
        if not evictees:
            return verdicts, False
        devs = np.asarray(self._sharded.mesh.devices).reshape(
            self._sharded.n_shards, -1
        )
        for h in evictees:
            for d in devs[h]:
                self._elastic.exclude_device(int(d.id))
        mesh = self._elastic.remesh()
        self._sharded = sharded_device_index(self.query_index, mesh=mesh)
        self._monitor = StragglerMonitor(
            self._sharded.n_shards,
            deadline_factor=self._monitor.deadline_factor,
            strikes_to_evict=self._monitor.strikes_to_evict,
        )
        return verdicts, True

    def pack(self, queries, pad_to: int = 128, pin_top: bool = False) -> PackedClusters:
        """Build the fixed-shape per-(query, leaf-cluster) segment batch.

        Rows come from the batched planner (one CSR descent for the whole
        batch, no per-query loop); each query contributes one row per
        common leaf cluster holding its ``arity`` cost-ordered segments.
        An empty plan yields an honestly-empty ``(0, pad_to)`` pack —
        never a fabricated PAD row attributed to query 0.

        ``pin_top=True`` orders rows by their top-level (level-0)
        ancestor, so the contiguous row-sharding of ``device_counts``
        pins each level-0 cluster's work to one mesh shard (up to the
        single row-count cut per shard boundary).  Counts are unaffected
        — the per-query segment-sum is order-invariant.
        """
        cq = as_queries(queries)
        qidx = self.query_index
        hidx = as_hier(qidx)
        plan = plan_segment_pairs(hidx, cq)
        docs = hidx.index.post_docs
        n_rows = plan.n_pairs
        if hidx.levels:
            top_ranges = hidx.levels[0].ranges
            row_top = (
                np.searchsorted(top_ranges, plan.base, side="right") - 1
            ).astype(np.int32)
        else:
            row_top = np.zeros(n_rows, np.int32)
        sel = (
            np.argsort(row_top, kind="stable")
            if pin_top
            else np.arange(n_rows)
        )
        max_a = max(plan.max_arity, 2)  # always expose short+long blocks
        segments = []
        for r in range(max_a):
            has = plan.arity[sel] > r
            si = np.where(has, plan.seg_ptr[:-1][sel] + r, 0)  # 0 = safe index
            starts = plan.seg_start[si]
            lens = np.where(has, plan.seg_len[si], 0)
            width = max(int(lens.max()) if n_rows else 0, pad_to)
            width = -(-width // pad_to) * pad_to
            segments.append(gather_padded(docs, starts, lens, width))
        return PackedClusters(
            segments=tuple(segments),
            row_query=plan.pair_query[sel].astype(np.int32),
            row_arity=plan.arity[sel].astype(np.int32),
            n_queries=cq.n_queries,
            row_top=row_top[sel],
        )

    @staticmethod
    def device_counts(packed: PackedClusters, mesh: Optional[Mesh] = None):
        """Intersect all rows on device; segment-sum counts per query.
        With a mesh, rows are sharded over the data axis and results
        combined with one psum_scatter-equivalent reduction."""
        from repro.kernels.intersect.ops import intersect_count, intersect_members

        nq = packed.n_queries
        if packed.short.shape[0] == 0:
            return jnp.zeros(nq, jnp.int32)
        segs = tuple(jnp.asarray(b) for b in packed.segments)
        rq = jnp.asarray(packed.row_query)
        ra = jnp.asarray(packed.row_arity)
        pairs_only = bool((packed.row_arity == 2).all()) and len(segs) == 2

        def local(segs, rq, ra):
            if pairs_only:
                # The historical 2-term layout: one kernel reduction.
                c = intersect_count(segs[0], segs[1])
            else:
                # Masked pairwise fold: rows keep their running
                # intersection in the rank-0 block; rank r filters it for
                # rows with arity > r, then survivors are counted.  The
                # select runs through the members probe (Pallas kernel on
                # TPU, jnp searchsorted elsewhere).
                cur = segs[0]
                for r in range(1, len(segs)):
                    masked = intersect_members(cur, segs[r], reduce="mask")
                    active = (ra > r)[:, None]
                    cur = jnp.where(active, masked, cur)
                c = (cur != PAD).sum(axis=1).astype(jnp.int32)
            return jax.ops.segment_sum(c, rq, num_segments=nq)

        if mesh is None:
            return local(segs, rq, ra)
        # Row sharding over ALL data axes (pod included on multi-pod
        # meshes) comes from the distribution substrate, so serving and
        # training agree on what "data-parallel" means.
        dp_axes = sh.batch_axes(mesh)
        dp = sh.data_spec(mesh)
        pad = sh.shard_rows(segs[0].shape[0], mesh)
        if pad:
            segs = tuple(
                jnp.pad(s, ((0, pad), (0, 0)), constant_values=PAD) for s in segs
            )
            # Padding rows carry query id nq (out of range): segment_sum
            # drops them by construction instead of crediting query 0.
            rq = jnp.pad(rq, (0, pad), constant_values=nq)
            ra = jnp.pad(ra, (0, pad), constant_values=0)
        from jax.experimental.shard_map import shard_map

        fn = shard_map(
            lambda s, r, a: jax.lax.psum(local(s, r, a), dp_axes),
            mesh=mesh,
            in_specs=(tuple(P(dp, None) for _ in segs), P(dp), P(dp)),
            out_specs=P(),
        )
        return fn(segs, rq, ra)
