"""Distributed SeCluD search service.

The paper's two-level query algorithm as a serving system:

  * clusters are sharded over the mesh's data axis (the paper §1:
    "the resulting clusters are also useful ... for distributing the work
    over many machines");
  * the cluster index (term → clusters) is replicated — the paper §3.2
    argues this replication is affordable, we adopt it;
  * a query batch is broadcast, every shard intersects the posting
    segments of its local clusters, counts are combined with one psum.

Two execution paths with the same contract:
  * ``serve_counts``       — host path (numpy Lookup, exact work metric);
  * ``make_sharded_step``  — device path: fixed-shape padded segment
    batches + ``shard_map`` over cluster shards, Pallas/jnp intersection
    kernels. Used by the serving dry-run and the wall-clock benchmark.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.seclud import SecludResult
from repro.dist import sharding as sh
from repro.kernels.intersect.ref import PAD

__all__ = ["SearchService", "PackedClusters"]


@dataclasses.dataclass
class PackedClusters:
    """Device-resident layout: for each (query, cluster-of-query) pair the
    two posting segments, padded to fixed widths and stacked."""

    short: np.ndarray  # (R, Ls)
    long: np.ndarray  # (R, Ll)
    row_query: np.ndarray  # (R,) query id of each row
    n_queries: int


class SearchService:
    def __init__(self, result: SecludResult):
        self.res = result

    # -- host path -------------------------------------------------------

    def serve_counts(self, queries: np.ndarray) -> Tuple[np.ndarray, dict]:
        """Exact per-query result counts via the two-level cluster index."""
        counts = np.zeros(len(queries), dtype=np.int64)
        total_work = 0.0
        for qi, (t, u) in enumerate(queries):
            docs, work = self.res.cluster_index.query(int(t), int(u))
            counts[qi] = len(docs)
            total_work += work["total"]
        return counts, {"work": total_work}

    # -- device path ------------------------------------------------------

    def pack(self, queries: np.ndarray, pad_to: int = 128) -> PackedClusters:
        """Build the fixed-shape per-(query, cluster) segment batch."""
        cidx = self.res.cluster_index
        docs = cidx.index.post_docs
        rows_s, rows_l, row_q = [], [], []
        max_s = max_l = pad_to
        for qi, (t, u) in enumerate(queries):
            ct, st, et = cidx.term_segments(int(t))
            cu, su, eu = cidx.term_segments(int(u))
            common, it, iu = np.intersect1d(ct, cu, return_indices=True)
            for c, a, b in zip(common, it, iu):
                seg_t = docs[st[a] : et[a]]
                seg_u = docs[su[b] : eu[b]]
                if len(seg_t) > len(seg_u):
                    seg_t, seg_u = seg_u, seg_t
                rows_s.append(seg_t)
                rows_l.append(seg_u)
                row_q.append(qi)
                max_s = max(max_s, len(seg_t))
                max_l = max(max_l, len(seg_u))
        r = len(rows_s)
        max_s = -(-max_s // pad_to) * pad_to
        max_l = -(-max_l // pad_to) * pad_to
        short = np.full((max(r, 1), max_s), PAD, np.int32)
        long = np.full((max(r, 1), max_l), PAD, np.int32)
        for i, (s, l) in enumerate(zip(rows_s, rows_l)):
            short[i, : len(s)] = s
            long[i, : len(l)] = l
        return PackedClusters(
            short=short,
            long=long,
            row_query=np.asarray(row_q, np.int32) if row_q else np.zeros(1, np.int32),
            n_queries=len(queries),
        )

    @staticmethod
    def device_counts(packed: PackedClusters, mesh: Optional[Mesh] = None):
        """Intersect all rows on device; segment-sum counts per query.
        With a mesh, rows are sharded over the data axis and results
        combined with one psum_scatter-equivalent reduction."""
        from repro.kernels.intersect.ops import intersect_count

        short = jnp.asarray(packed.short)
        long = jnp.asarray(packed.long)
        rq = jnp.asarray(packed.row_query)
        nq = packed.n_queries

        def local(short, long, rq):
            c = intersect_count(short, long)
            return jax.ops.segment_sum(c, rq, num_segments=nq)

        if mesh is None:
            return local(short, long, rq)
        # Row sharding over ALL data axes (pod included on multi-pod
        # meshes) comes from the distribution substrate, so serving and
        # training agree on what "data-parallel" means.
        dp_axes = sh.batch_axes(mesh)
        dp = sh.data_spec(mesh)
        pad = sh.shard_rows(short.shape[0], mesh)
        if pad:
            short = jnp.pad(short, ((0, pad), (0, 0)), constant_values=PAD)
            long = jnp.pad(long, ((0, pad), (0, 0)), constant_values=PAD)
            rq = jnp.pad(rq, (0, pad))
        from jax.experimental.shard_map import shard_map

        fn = shard_map(
            lambda s, l, r: jax.lax.psum(local(s, l, r), dp_axes),
            mesh=mesh,
            in_specs=(P(dp, None), P(dp, None), P(dp)),
            out_specs=P(),
        )
        return fn(short, long, rq)
