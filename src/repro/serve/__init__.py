"""Serving layer: the distributed SeCluD search service, the async
deadline-batching request loop with latency SLO accounting
(:mod:`repro.serve.loop` / :mod:`repro.serve.replay`), and the recsys
retrieval pipeline with SeCluD pre-filtering."""
