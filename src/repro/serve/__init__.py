"""Serving layer: the distributed SeCluD search service, batched request
scheduling, and the recsys retrieval pipeline with SeCluD pre-filtering."""
