"""Async serving loop: deadline batching over the device engine.

Wall-clock-per-1000-queries is a benchmarking metric, not a serving one.
A search tier absorbs an *open-loop* arrival process — requests land
when they land — and its contract is a latency SLO (p50/p99/p999), not
batch throughput.  This module turns the fused device engine
(:mod:`repro.core.device_engine`) into that tier:

* :func:`plan_batches` — the batching *policy*, a pure function of the
  arrival timestamps: accumulate requests until the oldest one has
  waited ``deadline_s`` or ``max_batch`` are pending, whichever first.
  Keeping the policy pure is what makes traffic replay deterministic
  (same arrivals -> same batch composition, bit for bit), which in turn
  is what lets the shape-grid prewarm *prove* zero steady-state
  compiles instead of hoping for them.

* :class:`AsyncServingLoop` — the real-time driver: an asyncio task
  applying the same policy to live ``submit()`` calls, dispatching each
  sealed batch as ONE fused engine call (``serve_counts_device`` /
  ``sharded_device_counts``), resolving per-request futures with the
  counts, and accounting every request (enqueue -> dispatch -> reply)
  and every batch (size, queue depth, device time, jit-cache growth via
  ``analysis.sanitize.jit_cache_size``) in :class:`ServeStats`.

* ``AsyncServingLoop.prewarm`` — compile the quantized ``lower_plan``
  shape grid at startup (:func:`repro.core.device_engine.prewarm`), so
  steady-state serving never traces: the ~1/8 shape quantization was
  built exactly so mixed-size batches share jit cache entries, and the
  loop is the component that finally exploits it under load.

The deadline/max-batch accumulation idiom follows the batch schedulers
in serving systems (sglang-style request loops, tensor2tensor-style
bucketed input pipelines), specialized to the fact that our "model" is
an exact set-intersection engine whose cost is shape-quantized.
"""

from __future__ import annotations

import asyncio
import collections
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.queries import ConjunctiveQueries

__all__ = [
    "ServeConfig",
    "ServeStats",
    "AsyncServingLoop",
    "plan_batches",
    "seal_times",
]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """The batching policy knobs.

    ``max_batch`` — dispatch immediately once this many requests are
    pending (the engine's shape quantization makes any size up to this
    share few executables).  ``deadline_s`` — the longest the *oldest*
    pending request may wait before its batch is sealed regardless of
    size: the knob that trades p99 latency against batch occupancy.
    """

    max_batch: int = 32
    deadline_s: float = 0.002

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.deadline_s < 0:
            raise ValueError(f"deadline_s must be >= 0, got {self.deadline_s}")


def plan_batches(
    arrivals: np.ndarray, max_batch: int, deadline_s: float
) -> List[Tuple[int, int]]:
    """The deadline batcher as a pure function of arrival timestamps.

    Returns half-open ``(start, end)`` windows partitioning
    ``range(len(arrivals))`` in order: a batch starting at request ``i``
    absorbs every request arriving within ``arrivals[i] + deadline_s``,
    up to ``max_batch``; the next batch starts at the first request it
    could not take.  ``arrivals`` must be nondecreasing (an arrival
    order).  This is exactly the composition the real-time loop
    converges to, but deterministic — replay and prewarm both build on
    it.
    """
    t = np.asarray(arrivals, np.float64)
    if t.ndim != 1:
        raise ValueError("arrivals must be a 1-d timestamp array")
    if len(t) > 1 and (np.diff(t) < 0).any():
        raise ValueError("arrivals must be nondecreasing")
    batches: List[Tuple[int, int]] = []
    i, n = 0, len(t)
    while i < n:
        seal = t[i] + deadline_s
        j = i + 1
        while j < n and j - i < max_batch and t[j] <= seal:
            j += 1
        batches.append((i, j))
        i = j
    return batches


def seal_times(
    arrivals: np.ndarray,
    batches: Sequence[Tuple[int, int]],
    max_batch: int,
    deadline_s: float,
) -> np.ndarray:
    """When each planned batch seals: at its filling arrival when it hit
    ``max_batch``, else at the first request's deadline.  (A deadline
    batch cannot dispatch earlier even if traffic stops — the loop does
    not know the trace ended.)"""
    t = np.asarray(arrivals, np.float64)
    out = np.empty(len(batches), np.float64)
    for b, (i, j) in enumerate(batches):
        out[b] = t[j - 1] if j - i == max_batch else t[i] + deadline_s
    return out


class ServeStats:
    """Per-request and per-batch serving telemetry.

    Requests carry (enqueue, dispatch, reply) timestamps — latency is
    reply minus enqueue, the number the SLO is written against — plus a
    per-request ``outcome`` ("ok" or "shed").  Batches carry size, queue
    depth at seal, device time, the jit-cache growth their dispatch
    caused (0 on every warm batch), and the resilience accounting:
    dispatch ``attempts`` spent and the degradation ``level`` the batch
    was served at (``repro.serve.resilience.LEVELS`` ladder).
    """

    def __init__(self, max_batch: int):
        self.max_batch = int(max_batch)
        self.t_enqueue: List[float] = []
        self.t_dispatch: List[float] = []
        self.t_reply: List[float] = []
        self.outcomes: List[str] = []  # per request: "ok" | "shed"
        self.batch_sizes: List[int] = []
        self.batch_device_s: List[float] = []
        self.batch_compiles: List[int] = []
        self.queue_depths: List[int] = []
        self.batch_attempts: List[int] = []
        self.batch_levels: List[str] = []
        self.shed_batches: List[int] = []  # sizes of refused seals

    def add_batch(
        self,
        t_enqueue: Sequence[float],
        t_dispatch: float,
        t_reply: float,
        device_s: float,
        jit_compiles: int,
        queue_depth: int,
        attempts: int = 1,
        level: str = "device",
    ) -> None:
        self.t_enqueue.extend(float(t) for t in t_enqueue)
        self.t_dispatch.extend([float(t_dispatch)] * len(t_enqueue))
        self.t_reply.extend([float(t_reply)] * len(t_enqueue))
        self.outcomes.extend(["ok"] * len(t_enqueue))
        self.batch_sizes.append(len(t_enqueue))
        self.batch_device_s.append(float(device_s))
        self.batch_compiles.append(int(jit_compiles))
        self.queue_depths.append(int(queue_depth))
        self.batch_attempts.append(int(attempts))
        self.batch_levels.append(str(level))

    def add_shed(
        self, t_enqueue: Sequence[float], t_reply: float, queue_depth: int
    ) -> None:
        """Record requests refused with the typed SHED error: replied
        immediately (the whole point of shedding), never dispatched.
        Shed requests stay out of the per-batch dispatch accounting —
        those lists describe work the device actually did."""
        self.t_enqueue.extend(float(t) for t in t_enqueue)
        self.t_dispatch.extend([float(t_reply)] * len(t_enqueue))
        self.t_reply.extend([float(t_reply)] * len(t_enqueue))
        self.outcomes.extend(["shed"] * len(t_enqueue))
        self.shed_batches.append(len(t_enqueue))

    @property
    def n_shed(self) -> int:
        return sum(self.shed_batches)

    @property
    def n_requests(self) -> int:
        return len(self.t_enqueue)

    @property
    def n_batches(self) -> int:
        return len(self.batch_sizes)

    def latencies_s(self, outcome: Optional[str] = None) -> np.ndarray:
        lat = np.asarray(self.t_reply, np.float64) - np.asarray(
            self.t_enqueue, np.float64
        )
        if outcome is None:
            return lat
        mask = np.asarray([o == outcome for o in self.outcomes], bool)
        return lat[mask]

    def percentile_ms(self, p: float, outcome: Optional[str] = None) -> float:
        lat = self.latencies_s(outcome)
        if len(lat) == 0:
            return 0.0
        return float(np.percentile(lat, p) * 1e3)

    def batch_hist(self) -> Dict[int, int]:
        sizes, counts = np.unique(
            np.asarray(self.batch_sizes, np.int64), return_counts=True
        )
        return {int(s): int(c) for s, c in zip(sizes, counts, strict=True)}

    def summary(self) -> Dict[str, object]:
        if self.n_requests == 0:
            return {
                "n_requests": 0,
                "n_batches": 0,
                "duration_s": 0.0,
                "qps_sustained": 0.0,
                "p50_ms": 0.0,
                "p99_ms": 0.0,
                "p999_ms": 0.0,
                "mean_batch": 0.0,
                "occupancy": 0.0,
                "max_queue_depth": 0,
                "jit_compiles": 0,
                "batch_hist": {},
                "n_shed": 0,
                "frac_shed": 0.0,
                "levels": {},
                "max_attempts": 0,
            }
        duration = max(max(self.t_reply) - min(self.t_enqueue), 1e-12)
        # Latency percentiles describe answered requests; a shed reply is
        # a refusal, not a fast answer, and must not deflate the p50.
        pct = "ok" if self.n_shed else None
        levels: Dict[str, int] = {}
        for lv in self.batch_levels:
            levels[lv] = levels.get(lv, 0) + 1
        if self.shed_batches:
            levels["shed"] = len(self.shed_batches)
        mean_batch = (
            float(np.mean(self.batch_sizes)) if self.batch_sizes else 0.0
        )
        return {
            "n_requests": self.n_requests,
            "n_batches": self.n_batches,
            "duration_s": duration,
            "qps_sustained": self.n_requests / duration,
            "p50_ms": self.percentile_ms(50, pct),
            "p99_ms": self.percentile_ms(99, pct),
            "p999_ms": self.percentile_ms(99.9, pct),
            "mean_batch": mean_batch,
            "occupancy": mean_batch / self.max_batch,
            "max_queue_depth": (
                int(max(self.queue_depths)) if self.queue_depths else 0
            ),
            "jit_compiles": int(sum(self.batch_compiles)),
            "batch_hist": self.batch_hist(),
            "n_shed": self.n_shed,
            "frac_shed": self.n_shed / self.n_requests,
            "levels": levels,
            "max_attempts": max(self.batch_attempts, default=0),
        }


class AsyncServingLoop:
    """The real-time deadline batcher over a :class:`SearchService`.

    One asyncio task accumulates ``submit()`` arrivals under the
    :class:`ServeConfig` policy and dispatches each sealed batch as one
    fused engine call; every request's future resolves to its exact
    result count.  The engine call runs inline on the event loop — the
    device is the serial resource, and queuing behind it IS the serving
    model (matching the sealed replay's single-server semantics).

    ``engine`` defaults to ``service.serve_counts_device`` — the routed
    entry that serves through the mesh-sharded fold after
    ``enable_sharded``.  ``cache_probe`` defaults to the fused fold's
    compiled-entry count and feeds the per-batch jit accounting.

    ``resilience`` (a :class:`repro.serve.resilience.ResilienceConfig`)
    arms the degradation ladder: each sealed batch dispatches through a
    ``ResilientDispatcher`` (timeout + bounded retry + breaker + exact
    host fallback) and ``submit`` sheds with a typed ``ShedError`` once
    queue depth passes ``shed_queue_depth``.  ``faults`` (a
    :class:`repro.serve.faults.FaultSchedule` or ``FaultInjector``)
    installs the chaos harness into the service's dispatch path.
    """

    def __init__(
        self,
        service=None,
        config: Optional[ServeConfig] = None,
        engine=None,
        cache_probe=None,
        resilience=None,
        faults=None,
    ):
        if engine is None:
            if service is None:
                raise ValueError("need a SearchService or an explicit engine")
            engine = service.serve_counts_device
        if cache_probe is None:
            from repro.core.device_engine import fold_cache_size as cache_probe
        self.service = service
        self.config = config or ServeConfig()
        self.stats = ServeStats(self.config.max_batch)
        self._engine = engine
        self._probe = cache_probe
        self._pending: collections.deque = collections.deque()
        self._wake: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._closing = False
        self.resilience = resilience
        self._injector = None
        self._dispatcher = None
        if faults is not None:
            from repro.serve.faults import FaultInjector

            self._injector = (
                faults
                if isinstance(faults, FaultInjector)
                else FaultInjector(faults)
            )
            if service is not None:
                service.install_faults(self._injector)
        if resilience is not None or self._injector is not None:
            from repro.serve.resilience import (
                ResilienceConfig,
                ResilientDispatcher,
            )

            self.resilience = resilience or ResilienceConfig()
            self._dispatcher = ResilientDispatcher(
                service,
                self.resilience,
                engine=engine,
                injector=self._injector,
            )

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> None:
        if self._task is not None:
            raise RuntimeError("serving loop already running")
        self._closing = False
        self._wake = asyncio.Event()
        self._task = asyncio.create_task(self._run())

    async def stop(self) -> None:
        """Seal and dispatch everything still pending, then stop."""
        if self._task is None:
            return
        self._closing = True
        self._wake.set()
        await self._task
        self._task = None

    # -- request entry -----------------------------------------------------

    async def submit(self, terms: Sequence[int]) -> int:
        """Enqueue one conjunctive query; resolves to its result count.

        With a resilience policy armed, arrivals past the brownout
        queue depth are refused immediately with a typed
        ``ShedError`` — the explicit load-shedding rung."""
        if self._task is None:
            raise RuntimeError("serving loop not started")
        limit = getattr(self.resilience, "shed_queue_depth", None)
        if limit is not None:
            depth = len(self._pending)
            if self._injector is not None:
                depth += self._injector.extra_queue_depth()
            if depth >= limit:
                from repro.serve.resilience import ShedError

                t = time.perf_counter()
                self.stats.add_shed([t], t, depth)
                raise ShedError(depth, limit)
        fut = asyncio.get_running_loop().create_future()
        self._pending.append(
            ([int(t) for t in terms], fut, time.perf_counter())
        )
        self._wake.set()
        return await fut

    @property
    def queue_depth(self) -> int:
        return len(self._pending)

    # -- startup: compile the shape grid before traffic --------------------

    def prewarm(
        self,
        queries,
        batch_sizes: Optional[Sequence[int]] = None,
        batches: Optional[Sequence[Tuple[int, int]]] = None,
    ) -> Dict[str, object]:
        """Compile the engine's quantized shape grid from a sample
        workload so steady-state serving never traces.

        Defaults to warming power-of-two prefix sizes up to
        ``max_batch``; pass ``batches`` (e.g. from :func:`plan_batches`
        over a recorded arrival trace) to warm the exact windows a
        replay will dispatch.  The sharded path has no dead-content
        warmer, so there the sample batches are executed for real —
        same cache effect, slightly costlier startup.
        """
        if self.service is None:
            raise RuntimeError("prewarm needs a SearchService-backed loop")
        from repro.core.queries import as_queries

        if batches is None and batch_sizes is None:
            b = self.config.max_batch
            batch_sizes = sorted(
                {s for s in (1 << i for i in range(b.bit_length())) if s <= b}
                | {b}
            )
        if getattr(self.service, "sharded_index", None) is not None:
            cq = as_queries(queries)
            if batches is None:
                batches = [(0, min(int(s), cq.n_queries)) for s in batch_sizes]
            n = 0
            for i, j in batches:
                if j > i:
                    self._engine(cq[int(i) : int(j)])
                    n += 1
            return {"n_batches": n, "n_keys": n, "n_compiles": 0, "keys": []}
        from repro.core.device_engine import prewarm as engine_prewarm

        return engine_prewarm(
            self.service.query_index,
            queries,
            batch_sizes=batch_sizes,
            batches=batches,
            dindex=self.service.device_index,
        )

    # -- the loop ----------------------------------------------------------

    async def _run(self) -> None:
        cfg = self.config
        while True:
            if not self._pending:
                if self._closing:
                    return
                await self._wake.wait()
                self._wake.clear()
                continue
            first_t = self._pending[0][2]
            while len(self._pending) < cfg.max_batch and not self._closing:
                remaining = first_t + cfg.deadline_s - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    await asyncio.wait_for(self._wake.wait(), timeout=remaining)
                except asyncio.TimeoutError:
                    break
                self._wake.clear()
            batch = [
                self._pending.popleft()
                for _ in range(min(cfg.max_batch, len(self._pending)))
            ]
            self._dispatch(batch)

    def _dispatch(self, batch) -> None:
        terms, futs, t_enq = zip(*batch, strict=True)
        cq = ConjunctiveQueries.from_lists(list(terms))
        depth = len(self._pending)  # what the dispatch leaves queued
        before = self._probe()
        t_d = time.perf_counter()
        if self._dispatcher is not None:
            if self._injector is not None:
                self._injector.begin_batch()
            counts, _info, outcome = self._dispatcher.dispatch(cq)
            attempts, level = outcome.attempts, outcome.level
        else:
            out = self._engine(cq)
            counts = np.asarray(out[0] if isinstance(out, tuple) else out)
            attempts, level = 1, "device"
        t_r = time.perf_counter()
        self.stats.add_batch(
            t_enq,
            t_d,
            t_r,
            device_s=t_r - t_d,
            jit_compiles=self._probe() - before,
            queue_depth=depth,
            attempts=attempts,
            level=level,
        )
        for fut, c in zip(futs, counts, strict=True):
            if not fut.done():
                fut.set_result(int(c))
