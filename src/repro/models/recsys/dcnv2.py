"""DCN-v2 — Deep & Cross Network v2 (Wang et al., arXiv:2008.13535).

Explicit feature crosses  x_{l+1} = x₀ ⊙ (W_l x_l + b_l) + x_l  (full-rank
W, the paper's strongest variant) in parallel with a deep MLP tower,
concatenated into the CTR logit.  Assigned config: 13 dense + 26 sparse
fields, embed_dim=16, 3 cross layers, MLP 1024-1024-512.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.recsys.embedding import embedding_init, lookup, mlp_tower, mlp_tower_init

__all__ = ["DCNv2Config", "init", "forward", "loss_fn", "score_candidates"]


@dataclasses.dataclass(frozen=True)
class DCNv2Config:
    name: str = "dcn-v2"
    n_dense: int = 13
    n_sparse: int = 26
    vocab_per_field: int = 100_000
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp: tuple = (1024, 1024, 512)
    dtype: str = "float32"

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_input(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim

    def n_params(self) -> int:
        d = self.d_input
        emb = self.n_sparse * self.vocab_per_field * self.embed_dim
        cross = self.n_cross_layers * (d * d + d)
        dims = (d,) + self.mlp
        deep = sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
        head = (d + self.mlp[-1]) + 1
        return emb + cross + deep + head


def init(cfg: DCNv2Config, key) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_input
    cross_keys = jax.random.split(ks[1], cfg.n_cross_layers)
    return {
        # one stacked table (F, V, e) — row-shardable over 'model'
        "tables": jax.vmap(
            lambda k: embedding_init(k, cfg.vocab_per_field, cfg.embed_dim)
        )(jax.random.split(ks[0], cfg.n_sparse)),
        "cross": jax.vmap(lambda k: L.dense_init(k, d, d, bias=True))(cross_keys),
        "deep": mlp_tower_init(ks[2], (d,) + cfg.mlp),
        "head": L.dense_init(ks[3], d + cfg.mlp[-1], 1, bias=True),
    }


def _embed_input(params, cfg: DCNv2Config, batch) -> jnp.ndarray:
    ids = batch["sparse_ids"] % cfg.vocab_per_field  # (B, F)
    # Per-field gather from the stacked (F, V, e) table.
    emb = jax.vmap(lambda tbl, i: jnp.take(tbl, i, axis=0), in_axes=(0, 1), out_axes=1)(
        params["tables"], ids
    )  # (B, F, e)
    b = ids.shape[0]
    return jnp.concatenate(
        [batch["dense"].astype(cfg.adtype), emb.reshape(b, -1).astype(cfg.adtype)],
        axis=-1,
    )


def forward(params, cfg: DCNv2Config, batch) -> jnp.ndarray:
    x0 = _embed_input(params, cfg, batch)  # (B, d)

    def cross_body(x, lp):
        return x0 * (x @ lp["kernel"].astype(x.dtype) + lp["bias"].astype(x.dtype)) + x, None

    xc, _ = jax.lax.scan(
        cross_body, x0, params["cross"], unroll=cfg.n_cross_layers
    )
    xd = mlp_tower(params["deep"], x0, final_act=True)
    out = L.dense(params["head"], jnp.concatenate([xc, xd], axis=-1))
    return out[:, 0]


def loss_fn(params, cfg: DCNv2Config, batch) -> jnp.ndarray:
    logit = forward(params, cfg, batch).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )


def score_candidates(params, cfg: DCNv2Config, batch, cand_ids) -> jnp.ndarray:
    """retrieval_cand adaptation (DESIGN.md §5): DCN-v2 is a ranking
    model, not two-tower; for candidate scoring we use the deep-tower
    user representation against candidate embeddings from field 0
    (documented as an adaptation, not the paper's own serving mode)."""
    x0 = _embed_input(params, cfg, batch)
    user = mlp_tower(params["deep"], x0, final_act=True)  # (B, mlp[-1])
    cands = lookup(
        params["tables"][0], cand_ids % cfg.vocab_per_field, cfg.adtype
    )  # (N, e)
    proj = user[:, : cfg.embed_dim]  # (B, e) — shared subspace
    return proj @ cands.T
