"""RecSys architectures: DIEN, MIND, DCN-v2, BERT4Rec.

Shared substrate in ``embedding.py``: JAX has no native EmbeddingBag —
we build it from ``jnp.take`` + segment/one-hot reductions (this IS part
of the system, kernel_taxonomy §B.6), with the Pallas ``cluster_score``
kernel as the TPU hot path.

Every model exposes ``init``, ``forward`` (CTR logit or scores),
``loss_fn``, and ``score_candidates`` (the ``retrieval_cand`` head:
user representation against 10⁶ candidate embeddings as one batched
matmul — never a loop).  The SeCluD integration (conjunctive pre-filter
over candidate attributes before dense scoring) lives in
``repro.serve.retrieval``.
"""
