"""Embedding substrate for recsys: big tables, bags, MLP towers.

Tables are row(vocab)-sharded over the 'model' mesh axis in the
distributed configs (DLRM-style); lookups are plain ``jnp.take`` which
XLA SPMD turns into a sharded gather + reduce.  The multi-hot bag uses
``jnp.take`` + sum (EmbeddingBag(sum) — no native op in JAX).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

__all__ = ["embedding_init", "lookup", "bag_lookup", "mlp_tower_init", "mlp_tower"]


def embedding_init(key, vocab: int, dim: int, dtype=jnp.float32) -> jnp.ndarray:
    return jax.random.normal(key, (vocab, dim), dtype) * 0.05


def lookup(table: jnp.ndarray, ids: jnp.ndarray, dtype=None) -> jnp.ndarray:
    out = jnp.take(table, ids, axis=0)
    return out.astype(dtype) if dtype is not None else out


def bag_lookup(
    table: jnp.ndarray, ids: jnp.ndarray, mask: jnp.ndarray | None = None
) -> jnp.ndarray:
    """EmbeddingBag(sum): ids (..., L) -> (..., dim)."""
    e = jnp.take(table, ids, axis=0)
    if mask is not None:
        e = e * mask[..., None].astype(e.dtype)
    return e.sum(axis=-2)


def mlp_tower_init(key, dims, bias: bool = True, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        L.dense_init(k, dims[i], dims[i + 1], bias=bias, dtype=dtype)
        for i, k in enumerate(ks)
    ]


def mlp_tower(params, x: jnp.ndarray, final_act: bool = False) -> jnp.ndarray:
    for i, p in enumerate(params):
        x = L.dense(p, x)
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x
