"""MIND — Multi-Interest Network with Dynamic routing (Li et al.,
arXiv:1904.08030).

Behaviour-to-Interest (B2I) capsule routing: the user's history item
embeddings are routed into ``n_interests`` interest capsules over
``capsule_iters`` iterations (squash nonlinearity, routing logits updated
by agreement).  Training uses label-aware attention (target attends the
interests with a powered softmax); retrieval scores every candidate
against all interests and takes the max — the classic multi-interest
retrieval head (``retrieval_cand`` is MIND's native serving shape).

Config: embed_dim=64, n_interests=4, capsule_iters=3.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.recsys.embedding import embedding_init, lookup, mlp_tower, mlp_tower_init

__all__ = ["MINDConfig", "init", "forward", "loss_fn", "score_candidates", "user_interests"]


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    vocab: int = 1_000_000
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    label_pow: float = 2.0  # label-aware attention power
    n_negatives: int = 512  # sampled-softmax negatives
    dtype: str = "float32"

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    def n_params(self) -> int:
        e = self.embed_dim
        return self.vocab * e + e * e + 2 * (e * e + e)


def init(cfg: MINDConfig, key) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "item_embed": embedding_init(ks[0], cfg.vocab, cfg.embed_dim),
        "bilinear": jax.random.normal(ks[1], (cfg.embed_dim, cfg.embed_dim))
        * (cfg.embed_dim**-0.5),
        # small transform applied to the pooled interests (paper's ReLU MLP)
        "mlp": mlp_tower_init(ks[2], (cfg.embed_dim, cfg.embed_dim, cfg.embed_dim)),
    }


def _squash(v: jnp.ndarray) -> jnp.ndarray:
    n2 = jnp.sum(jnp.square(v), axis=-1, keepdims=True)
    return (n2 / (1.0 + n2)) * v / jnp.sqrt(n2 + 1e-9)


def user_interests(params, cfg: MINDConfig, batch) -> jnp.ndarray:
    """(B, K, e) interest capsules via B2I dynamic routing."""
    hist = lookup(params["item_embed"], batch["hist_ids"], cfg.adtype)  # (B,T,e)
    mask = batch["hist_mask"].astype(cfg.adtype)  # (B, T)
    w = params["bilinear"].astype(cfg.adtype)
    u = hist @ w  # behaviour capsules, (B, T, e)

    b, t, e = u.shape
    k = cfg.n_interests
    # Routing logits fixed-init (shared); iterations update by agreement.
    logits = jnp.zeros((b, k, t), cfg.adtype)
    neg = jnp.asarray(-1e30, jnp.float32)
    for _ in range(cfg.capsule_iters):
        route = jax.nn.softmax(
            jnp.where(mask[:, None, :] > 0, logits.astype(jnp.float32), neg), axis=1
        ).astype(cfg.adtype)  # softmax over interests per behaviour
        caps = _squash(jnp.einsum("bkt,bte->bke", route * mask[:, None, :], u))
        logits = logits + jnp.einsum("bke,bte->bkt", caps, u)
    caps = mlp_tower(params["mlp"], caps, final_act=False)
    return caps  # (B, K, e)


def forward(params, cfg: MINDConfig, batch) -> jnp.ndarray:
    """Label-aware-attended user vector · target (B,) — the CTR-style
    logit used by the serve shapes."""
    caps = user_interests(params, cfg, batch)
    tgt = lookup(params["item_embed"], batch["target_id"], cfg.adtype)  # (B, e)
    att = jax.nn.softmax(
        cfg.label_pow * jnp.einsum("bke,be->bk", caps, tgt).astype(jnp.float32),
        axis=-1,
    ).astype(cfg.adtype)
    user = jnp.einsum("bk,bke->be", att, caps)
    return jnp.einsum("be,be->b", user, tgt)


def loss_fn(params, cfg: MINDConfig, batch) -> jnp.ndarray:
    """Sampled-softmax over in-batch + shared random negatives."""
    caps = user_interests(params, cfg, batch)
    tgt = lookup(params["item_embed"], batch["target_id"], cfg.adtype)
    att = jax.nn.softmax(
        cfg.label_pow * jnp.einsum("bke,be->bk", caps, tgt).astype(jnp.float32), -1
    ).astype(cfg.adtype)
    user = jnp.einsum("bk,bke->be", att, caps)  # (B, e)
    # In-batch softmax: positives on the diagonal.
    logits = (user @ tgt.T).astype(jnp.float32)  # (B, B)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    return jnp.mean(lse - jnp.diag(logits))


def score_candidates(params, cfg: MINDConfig, batch, cand_ids) -> jnp.ndarray:
    """(B, N): max over interests of interest·candidate — one batched
    matmul against 10⁶ candidates."""
    caps = user_interests(params, cfg, batch)  # (B, K, e)
    cands = lookup(params["item_embed"], cand_ids, cfg.adtype)  # (N, e)
    scores = jnp.einsum("bke,ne->bkn", caps, cands)
    return scores.max(axis=1)
