"""DIEN — Deep Interest Evolution Network (Zhou et al., arXiv:1809.03672).

Two-stage sequential CTR model:
  1. *Interest extraction*: a GRU over the user-behaviour sequence.
  2. *Interest evolution*: an AUGRU (GRU whose update gate is scaled by
     the attention of each hidden state to the target item) — the
     ``interaction=augru`` of the assigned config.

Both recurrences are ``lax.scan``.  Config: embed_dim=18, seq_len=100,
gru_dim=108, mlp=200-80.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.recsys.embedding import (
    embedding_init,
    lookup,
    mlp_tower,
    mlp_tower_init,
)

__all__ = ["DIENConfig", "init", "forward", "loss_fn", "score_candidates"]


@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    vocab: int = 1_000_000
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp: tuple = (200, 80)
    dtype: str = "float32"
    scan_unroll: int = 1  # time-scan unroll (dry-run probes)

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    def n_params(self) -> int:
        e, g = self.embed_dim, self.gru_dim
        gru1 = 3 * (e * g + g * g + g)
        att = g * e
        augru = 3 * (g * g + g * g + g)
        d_in = g + e
        dims = (d_in,) + self.mlp + (1,)
        mlp = sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
        return self.vocab * e + gru1 + att + augru + mlp


def _gru_init(key, d_in, d_h):
    ks = jax.random.split(key, 3)
    mk = lambda k: {
        "wx": jax.random.normal(k, (d_in, d_h)) * (d_in**-0.5),
        "wh": jax.random.normal(jax.random.fold_in(k, 1), (d_h, d_h)) * (d_h**-0.5),
        "b": jnp.zeros((d_h,)),
    }
    return {"z": mk(ks[0]), "r": mk(ks[1]), "h": mk(ks[2])}


def _gru_cell(p, h, x, gate_scale=None):
    z = jax.nn.sigmoid(x @ p["z"]["wx"] + h @ p["z"]["wh"] + p["z"]["b"])
    r = jax.nn.sigmoid(x @ p["r"]["wx"] + h @ p["r"]["wh"] + p["r"]["b"])
    hh = jnp.tanh(x @ p["h"]["wx"] + (r * h) @ p["h"]["wh"] + p["h"]["b"])
    if gate_scale is not None:  # AUGRU: attention-scaled update gate
        z = z * gate_scale[:, None]
    return (1.0 - z) * h + z * hh


def init(cfg: DIENConfig, key) -> dict:
    ks = jax.random.split(key, 5)
    d_in = cfg.gru_dim + cfg.embed_dim
    return {
        "item_embed": embedding_init(ks[0], cfg.vocab, cfg.embed_dim),
        "gru": _gru_init(ks[1], cfg.embed_dim, cfg.gru_dim),
        "att": L.dense_init(ks[2], cfg.gru_dim, cfg.embed_dim),
        "augru": _gru_init(ks[3], cfg.gru_dim, cfg.gru_dim),
        "mlp": mlp_tower_init(ks[4], (d_in,) + cfg.mlp + (1,)),
    }


def user_state(params, cfg: DIENConfig, batch) -> jnp.ndarray:
    """Final AUGRU state (B, gru_dim) — the evolved interest."""
    hist = lookup(params["item_embed"], batch["hist_ids"], cfg.adtype)  # (B,T,e)
    mask = batch["hist_mask"].astype(cfg.adtype)  # (B, T)
    tgt = lookup(params["item_embed"], batch["target_id"], cfg.adtype)  # (B, e)
    b = hist.shape[0]

    # Stage 1: interest extraction GRU over the sequence.
    def step1(h, xs):
        x, m = xs  # (B, e), (B,)
        h_new = _gru_cell(params["gru"], h, x)
        h = jnp.where(m[:, None] > 0, h_new, h)
        return h, h

    h0 = jnp.zeros((b, cfg.gru_dim), cfg.adtype)
    _, states = jax.lax.scan(
        step1, h0, (hist.swapaxes(0, 1), mask.swapaxes(0, 1)),
        unroll=cfg.scan_unroll,
    )  # (T, B, g)

    # Attention of each interest state to the target item.
    scores = jnp.einsum("tbg,ge,be->tb", states, params["att"]["kernel"].astype(cfg.adtype), tgt)
    scores = jnp.where(mask.swapaxes(0, 1) > 0, scores, -1e30)
    att = jax.nn.softmax(scores.astype(jnp.float32), axis=0).astype(cfg.adtype)

    # Stage 2: AUGRU interest evolution.
    def step2(h, xs):
        x, a, m = xs
        h_new = _gru_cell(params["augru"], h, x, gate_scale=a)
        h = jnp.where(m[:, None] > 0, h_new, h)
        return h, None

    h2, _ = jax.lax.scan(
        step2, h0, (states, att, mask.swapaxes(0, 1)), unroll=cfg.scan_unroll
    )
    return h2


def forward(params, cfg: DIENConfig, batch) -> jnp.ndarray:
    """CTR logit (B,)."""
    h2 = user_state(params, cfg, batch)
    tgt = lookup(params["item_embed"], batch["target_id"], cfg.adtype)
    x = jnp.concatenate([h2, tgt], axis=-1)
    return mlp_tower(params["mlp"], x)[:, 0]


def loss_fn(params, cfg: DIENConfig, batch) -> jnp.ndarray:
    logit = forward(params, cfg, batch).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )


def score_candidates(params, cfg: DIENConfig, batch, cand_ids) -> jnp.ndarray:
    """retrieval_cand head: user repr · candidate embeddings (N,) — one
    matmul, not N forwards (the per-candidate AUGRU attention is replaced
    by a target-free user state; DESIGN.md §5 notes the adaptation)."""
    user = user_state(params, cfg, batch)  # (B, g)
    cands = lookup(params["item_embed"], cand_ids, cfg.adtype)  # (N, e)
    w = params["att"]["kernel"].astype(cfg.adtype)  # (g, e)
    return (user @ w) @ cands.T  # (B, N)
