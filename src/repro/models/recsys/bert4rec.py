"""BERT4Rec — bidirectional transformer for sequential recommendation
(Sun et al., arXiv:1904.06690).

Masked-item modelling (Cloze): random history positions are replaced by a
[MASK] token and predicted from both directions.  Encoder-only — there is
no decode step (the assigned recsys shapes are all encode/score).

Config: embed_dim=64, 2 blocks, 2 heads, seq_len=200.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.recsys.embedding import embedding_init, lookup

__all__ = ["BERT4RecConfig", "init", "forward", "loss_fn", "score_candidates"]


@dataclasses.dataclass(frozen=True)
class BERT4RecConfig:
    name: str = "bert4rec"
    vocab: int = 1_000_000  # items; id 0 reserved as [PAD], 1 as [MASK]
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    d_ff: int = 256
    mask_prob: float = 0.2
    dtype: str = "float32"

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def head_dim(self) -> int:
        return self.embed_dim // self.n_heads

    def n_params(self) -> int:
        e = self.embed_dim
        per = 4 * e * e + 2 * e * self.d_ff + 2 * e
        return self.vocab * e + self.seq_len * e + self.n_blocks * per + e


def _block_init(cfg: BERT4RecConfig, key):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": jnp.zeros((cfg.embed_dim,)),
        "attn": L.gqa_attention_init(
            ks[0], cfg.embed_dim, cfg.n_heads, cfg.n_heads, cfg.head_dim
        ),
        "ffn_norm": jnp.zeros((cfg.embed_dim,)),
        "mlp": L.mlp_init(ks[1], cfg.embed_dim, cfg.d_ff, gated=False),
    }


def init(cfg: BERT4RecConfig, key) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "item_embed": embedding_init(ks[0], cfg.vocab, cfg.embed_dim),
        "pos_embed": jax.random.normal(ks[1], (cfg.seq_len, cfg.embed_dim)) * 0.02,
        "blocks": jax.vmap(lambda k: _block_init(cfg, k))(
            jax.random.split(ks[2], cfg.n_blocks)
        ),
        "final_norm": jnp.zeros((cfg.embed_dim,)),
    }


def encode(params, cfg: BERT4RecConfig, ids: jnp.ndarray, mask: jnp.ndarray):
    """ids (B, T) -> hidden (B, T, e); bidirectional attention over valid
    positions (padding masked via large-negative scores through value
    zeroing — adequate for fixed-length padded histories)."""
    b, t = ids.shape
    x = lookup(params["item_embed"], ids, cfg.adtype)
    x = x + params["pos_embed"][:t].astype(cfg.adtype)[None]
    x = x * mask[..., None].astype(cfg.adtype)
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))

    def body(x, bp):
        h, _ = L.gqa_attention_apply(
            bp["attn"],
            L.rms_norm(x, bp["attn_norm"]),
            positions,
            cfg.n_heads,
            cfg.n_heads,
            cfg.head_dim,
            rope_theta=10_000.0,
            causal=False,  # bidirectional
            window=None,
        )
        x = x + h * mask[..., None].astype(x.dtype)
        y = L.mlp_apply(bp["mlp"], L.rms_norm(x, bp["ffn_norm"]), act="gelu")
        return x + y * mask[..., None].astype(x.dtype), None

    x, _ = jax.lax.scan(body, x, params["blocks"], unroll=cfg.n_blocks)
    return L.rms_norm(x, params["final_norm"])


def forward(params, cfg: BERT4RecConfig, batch) -> jnp.ndarray:
    """Serve scoring: encode history, score target at the last position.
    Returns (B,) logits."""
    h = encode(params, cfg, batch["hist_ids"], batch["hist_mask"])
    last = h[:, -1]  # (B, e) — next-item representation
    tgt = lookup(params["item_embed"], batch["target_id"], cfg.adtype)
    return jnp.einsum("be,be->b", last, tgt)


def loss_fn(params, cfg: BERT4RecConfig, batch) -> jnp.ndarray:
    """Cloze training with deterministic in-batch masking derived from the
    step data (mask positions provided by the pipeline or derived here)."""
    ids = batch["hist_ids"]
    mask = batch["hist_mask"]
    b, t = ids.shape
    # Derive mask positions pseudo-randomly from ids (stateless; constants
    # stay within int32).
    h = (ids * 48271 + 97) % 1000
    cloze = (h < int(cfg.mask_prob * 1000)) & (mask > 0)
    masked_ids = jnp.where(cloze, jnp.ones_like(ids), ids)  # [MASK] = 1
    hidden = encode(params, cfg, masked_ids, mask)  # (B, T, e)
    # Sampled softmax with a shared negative set (full 10^6-way softmax is
    # a serving-only shape; (BT)^2 in-batch logits would be astronomical).
    n_neg = 512
    flat_h = hidden.reshape(b * t, -1)
    flat_ids = ids.reshape(b * t)
    flat_cloze = cloze.reshape(b * t)
    neg_ids = (flat_ids[:n_neg] * 40503 + 7) % cfg.vocab  # stateless draws
    neg = lookup(params["item_embed"], neg_ids, cfg.adtype)  # (n_neg, e)
    pos = lookup(params["item_embed"], flat_ids, cfg.adtype)  # (BT, e)
    gold = jnp.einsum("ne,ne->n", flat_h, pos).astype(jnp.float32)  # (BT,)
    neg_logits = (flat_h @ neg.T).astype(jnp.float32)  # (BT, n_neg)
    lse = jax.scipy.special.logsumexp(
        jnp.concatenate([gold[:, None], neg_logits], axis=-1), axis=-1
    )
    per_tok = (lse - gold) * flat_cloze.astype(jnp.float32)
    return per_tok.sum() / jnp.maximum(flat_cloze.sum(), 1.0)


def score_candidates(params, cfg: BERT4RecConfig, batch, cand_ids) -> jnp.ndarray:
    h = encode(params, cfg, batch["hist_ids"], batch["hist_mask"])
    user = h[:, -1]  # (B, e)
    cands = lookup(params["item_embed"], cand_ids, cfg.adtype)  # (N, e)
    return user @ cands.T
