"""The LM family: dense GQA transformers (Qwen1.5), hybrid local:global
(gemma3), and MoE (Arctic dense+MoE residual, Qwen3-MoE), one codebase.

Layers are stacked (leading L axis) and executed with ``lax.scan`` so HLO
and compile time are depth-independent. Heterogeneous layer behaviour
(gemma3's 5 local : 1 global pattern) is data: a per-layer window array
scanned alongside the params.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

__all__ = ["MoESpec", "LMConfig", "init", "forward", "loss_fn", "prefill", "decode_step", "init_cache"]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25
    dense_residual: bool = False  # Arctic: dense FFN in parallel with MoE
    aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: Optional[int] = None  # sliding window for local layers
    global_every: Optional[int] = None  # every Nth layer is global (gemma3: 6)
    moe: Optional[MoESpec] = None
    act: str = "silu"
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "none"  # none | full | dots
    kv_quant: bool = False  # int8 KV cache for long-context serving
    loss_chunk: int = 512  # sequence chunk for the fused CE
    attn_q_chunk: int | None = None  # flash-style query tiling (memory)
    scan_unroll: int = 1  # layer-scan unroll (dry-run probes set = n_layers)

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def layer_windows(self) -> jnp.ndarray:
        """Per-layer attention window; 0 = global (no window)."""
        if self.window is None:
            return jnp.zeros((self.n_layers,), jnp.int32)
        w = jnp.full((self.n_layers,), self.window, jnp.int32)
        if self.global_every:
            idx = jnp.arange(self.n_layers)
            w = jnp.where((idx + 1) % self.global_every == 0, 0, w)
        return w

    def n_params(self) -> int:
        """Total parameter count (for 6·N·D model FLOPs)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
        attn += self.n_heads * self.head_dim * d
        if self.moe is not None:
            ff = self.moe.n_experts * 3 * d * self.moe.d_expert + d * self.moe.n_experts
            if self.moe.dense_residual:
                ff += 3 * d * f
        else:
            ff = 3 * d * f
        per_layer = attn + ff + 2 * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
        attn += self.n_heads * self.head_dim * d
        ff = self.moe.top_k * 3 * d * self.moe.d_expert + d * self.moe.n_experts
        if self.moe.dense_residual:
            ff += 3 * d * f
        per_layer = attn + ff + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _layer_init(cfg: LMConfig, key) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": jnp.zeros((cfg.d_model,), cfg.pdtype),
        "attn": L.gqa_attention_init(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, dtype=cfg.pdtype,
        ),
        "ffn_norm": jnp.zeros((cfg.d_model,), cfg.pdtype),
    }
    if cfg.moe is not None:
        p["moe"] = L.moe_init(
            ks[1], cfg.d_model, cfg.moe.d_expert, cfg.moe.n_experts,
            dtype=cfg.pdtype,
        )
        if cfg.moe.dense_residual:
            p["mlp"] = L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype=cfg.pdtype)
    else:
        p["mlp"] = L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype=cfg.pdtype)
    return p


def init(cfg: LMConfig, key) -> dict:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(cfg, k))(layer_keys)
    params = {
        "embed": jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), cfg.pdtype)
        * (cfg.d_model**-0.5),
        "layers": stacked,
        "final_norm": jnp.zeros((cfg.d_model,), cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab), cfg.pdtype)
            * (cfg.d_model**-0.5)
        )
    return params


# ---------------------------------------------------------------------------
# Forward (scan over layers)
# ---------------------------------------------------------------------------


def _block(cfg: LMConfig, lp, x, positions, window, cache=None):
    """One transformer block. window: int32 scalar, 0 = global."""
    win = jnp.where(window > 0, window, jnp.int32(2**30))
    h, new_cache = L.gqa_attention_apply(
        lp["attn"],
        L.rms_norm(x, lp["attn_norm"]),
        positions,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.head_dim,
        rope_theta=cfg.rope_theta,
        causal=True,
        window=win,
        cache=cache,
        q_chunk=cfg.attn_q_chunk,
    )
    x = x + h
    xin = L.rms_norm(x, lp["ffn_norm"])
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        b, s, d = xin.shape
        y, aux = L.moe_apply(
            lp["moe"], xin.reshape(b * s, d), cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor, act=cfg.act,
        )
        y = y.reshape(b, s, d)
        if cfg.moe.dense_residual:
            y = y + L.mlp_apply(lp["mlp"], xin, cfg.act)
    else:
        y = L.mlp_apply(lp["mlp"], xin, cfg.act)
    return x + y, aux, new_cache


def forward(params, cfg: LMConfig, tokens: jnp.ndarray, positions=None):
    """Returns (hidden (B, S, d), aux_loss)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = params["embed"].astype(cfg.adtype)[tokens] * (cfg.d_model**0.5)
    windows = cfg.layer_windows()

    def body(carry, xs):
        x, aux = carry
        lp, win = xs
        if cfg.remat == "full":
            fn = jax.checkpoint(
                lambda lp_, x_: _block(cfg, lp_, x_, positions, win)[:2]
            )
            x_new, a = fn(lp, x)
        elif cfg.remat == "dots":
            fn = jax.checkpoint(
                lambda lp_, x_: _block(cfg, lp_, x_, positions, win)[:2],
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            )
            x_new, a = fn(lp, x)
        else:
            x_new, a, _ = _block(cfg, lp, x, positions, win)
        return (x_new, aux + a), None

    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["layers"], windows),
        unroll=cfg.scan_unroll,
    )
    x = L.rms_norm(x, params["final_norm"])
    return x, aux


def _head(params, cfg: LMConfig):
    if cfg.tie_embeddings:
        return params["embed"].astype(cfg.adtype).T
    return params["lm_head"].astype(cfg.adtype)


def loss_fn(params, cfg: LMConfig, batch) -> jnp.ndarray:
    """Next-token CE with sequence-chunked logits (never materializes
    (B, S, V)). MoE aux loss folded in."""
    tokens, targets = batch["tokens"], batch["targets"]
    h, aux = forward(params, cfg, tokens)
    head = _head(params, cfg)  # (d, V)
    b, s, d = h.shape
    chunk = min(cfg.loss_chunk, s)
    n_chunks = s // chunk
    h = h[:, : n_chunks * chunk].reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    t = targets[:, : n_chunks * chunk].reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def body(acc, xs):
        hc, tc = xs  # (B, chunk, d), (B, chunk)
        logits = (hc @ head).astype(jnp.float32)  # (B, chunk, V)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return acc + (lse - gold).sum(), None

    total, _ = jax.lax.scan(
        body, jnp.zeros((), jnp.float32), (h, t), unroll=n_chunks
    )
    loss = total / (b * n_chunks * chunk)
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_weight * aux / cfg.n_layers
    return loss


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with stacked KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> L.KVCache:
    """Stacked over layers: fields have leading (n_layers,) axis."""
    tmpl = L.init_kv_cache(
        batch, max_len, cfg.n_kv_heads, cfg.head_dim,
        dtype=cfg.adtype, quantized=cfg.kv_quant,
    )
    return L.KVCache(
        k=jnp.zeros((cfg.n_layers,) + tmpl.k.shape, tmpl.k.dtype),
        v=jnp.zeros((cfg.n_layers,) + tmpl.v.shape, tmpl.v.dtype),
        k_scale=(
            jnp.ones((cfg.n_layers,) + tmpl.k_scale.shape, jnp.float32)
            if tmpl.k_scale is not None
            else None
        ),
        v_scale=(
            jnp.ones((cfg.n_layers,) + tmpl.v_scale.shape, jnp.float32)
            if tmpl.v_scale is not None
            else None
        ),
        length=jnp.zeros((), jnp.int32),
    )


def _scan_layers_cached(params, cfg: LMConfig, x, positions, cache: L.KVCache):
    windows = cfg.layer_windows()
    quantized = cache.k_scale is not None  # static

    def body(carry, xs):
        x, aux = carry
        if quantized:
            lp, win, kc, vc, ks, vs = xs
        else:
            lp, win, kc, vc = xs
            ks = vs = None
        lc = L.KVCache(k=kc, v=vc, k_scale=ks, v_scale=vs, length=cache.length)
        x_new, a, nc = _block(cfg, lp, x, positions, win, cache=lc)
        if quantized:
            out = (nc.k, nc.v, nc.k_scale, nc.v_scale)
        else:
            out = (nc.k, nc.v)
        return (x_new, aux + a), out

    if quantized:
        xs = (params["layers"], windows, cache.k, cache.v, cache.k_scale, cache.v_scale)
    else:
        xs = (params["layers"], windows, cache.k, cache.v)
    (x, aux), outs = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs, unroll=cfg.scan_unroll
    )
    if quantized:
        nk, nv, nks, nvs = outs
    else:
        (nk, nv), nks, nvs = outs, None, None
    new_cache = L.KVCache(
        k=nk,
        v=nv,
        k_scale=nks,
        v_scale=nvs,
        length=cache.length + x.shape[1],
    )
    return x, aux, new_cache


def prefill(params, cfg: LMConfig, tokens: jnp.ndarray, cache: L.KVCache):
    """Run the prompt through the model, filling the cache.
    Returns (last-position logits (B, V), cache)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s)) + cache.length
    x = params["embed"].astype(cfg.adtype)[tokens] * (cfg.d_model**0.5)
    x, _, cache = _scan_layers_cached(params, cfg, x, positions, cache)
    x = L.rms_norm(x, params["final_norm"])
    logits = (x[:, -1] @ _head(params, cfg)).astype(jnp.float32)
    return logits, cache


def decode_step(params, cfg: LMConfig, tokens: jnp.ndarray, cache: L.KVCache):
    """One-token decode: tokens (B, 1) appended at cache.length.
    Returns (logits (B, V), new cache)."""
    b, _ = tokens.shape
    positions = jnp.broadcast_to(cache.length, (b, 1))
    x = params["embed"].astype(cfg.adtype)[tokens] * (cfg.d_model**0.5)
    x, _, cache = _scan_layers_cached(params, cfg, x, positions, cache)
    x = L.rms_norm(x, params["final_norm"])
    logits = (x[:, -1] @ _head(params, cfg)).astype(jnp.float32)
    return logits, cache
