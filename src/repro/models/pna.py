"""PNA — Principal Neighbourhood Aggregation (Corso et al., arXiv:2004.05718).

Multi-aggregator message passing: each layer aggregates messages with
{mean, max, min, std} × degree scalers {identity, amplification,
attenuation} (12 combinations), concatenates and projects.  Message
passing is ``jax.ops.segment_sum``/``segment_max`` over an explicit edge
list — the JAX-native SpMM regime (kernel_taxonomy §B.3); the Pallas
``cluster_score`` kernel covers the same gather-reduce pattern on TPU.

Supports the four assigned shapes:
  * full-batch node classification (full_graph_sm / ogb_products),
  * sampled-subgraph training (minibatch_lg, via data.graphs.NeighborSampler),
  * batched small graphs with graph-level readout (molecule).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L

__all__ = ["PNAConfig", "init", "forward", "loss_fn"]

EPS = 1e-5


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str
    n_layers: int = 4
    d_feat: int = 64
    d_hidden: int = 75
    n_classes: int = 16
    delta: float = 2.5  # mean log-degree of the training graphs
    readout: str = "node"  # node | graph
    dtype: str = "float32"

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    def n_params(self) -> int:
        d = self.d_hidden
        per_layer = 2 * d * d + 12 * d * d + d * d + 2 * d
        return self.d_feat * d + self.n_layers * per_layer + d * self.n_classes


def _layer_init(cfg: PNAConfig, key):
    ks = jax.random.split(key, 4)
    d = cfg.d_hidden
    return {
        "w_src": L.dense_init(ks[0], d, d),
        "w_dst": L.dense_init(ks[1], d, d),
        "w_out": L.dense_init(ks[2], 12 * d, d, bias=True),
        "norm": jnp.zeros((d,)),
    }


def init(cfg: PNAConfig, key) -> dict:
    k_in, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    return {
        "encode": L.dense_init(k_in, cfg.d_feat, cfg.d_hidden, bias=True),
        "layers": jax.vmap(lambda k: _layer_init(cfg, k))(layer_keys),
        "decode": L.dense_init(k_out, cfg.d_hidden, cfg.n_classes, bias=True),
    }


def _aggregate(msg, dst, n_nodes, edge_w):
    """All four PNA aggregators over incoming edges, masked by edge_w."""
    msg = msg * edge_w[:, None]
    deg = jax.ops.segment_sum(edge_w, dst, num_segments=n_nodes)  # (N,)
    denom = jnp.maximum(deg, 1.0)[:, None]
    s = jax.ops.segment_sum(msg, dst, num_segments=n_nodes)
    mean = s / denom
    sq = jax.ops.segment_sum(msg * msg, dst, num_segments=n_nodes)
    var = jnp.maximum(sq / denom - mean * mean, 0.0)
    std = jnp.sqrt(var + EPS)
    big_neg = jnp.float32(-1e30)
    mx = jax.ops.segment_max(
        jnp.where(edge_w[:, None] > 0, msg, big_neg), dst, num_segments=n_nodes
    )
    mx = jnp.where(deg[:, None] > 0, mx, 0.0)
    mn = -jax.ops.segment_max(
        jnp.where(edge_w[:, None] > 0, -msg, big_neg), dst, num_segments=n_nodes
    )
    mn = jnp.where(deg[:, None] > 0, mn, 0.0)
    return mean, mx, mn, std, deg


def _pna_layer(cfg: PNAConfig, lp, h, src, dst, edge_w):
    n = h.shape[0]
    msg = L.dense(lp["w_src"], h)[src] + L.dense(lp["w_dst"], h)[dst]  # (E, d)
    msg = jax.nn.relu(msg)
    mean, mx, mn, std, deg = _aggregate(msg, dst, n, edge_w)
    aggs = jnp.concatenate([mean, mx, mn, std], axis=-1)  # (N, 4d)
    logd = jnp.log(deg + 1.0)[:, None]
    amp = logd / cfg.delta
    att = cfg.delta / jnp.maximum(logd, EPS)
    scaled = jnp.concatenate([aggs, aggs * amp, aggs * att], axis=-1)  # (N, 12d)
    out = L.dense(lp["w_out"], scaled)
    return L.rms_norm(h + out, lp["norm"])


def forward(params, cfg: PNAConfig, batch) -> jnp.ndarray:
    """batch: feats (N, F), edges (E, 2) int32, edge_mask (E,).
    Returns logits — (N, C) for node readout, (G, C) for graph readout
    (requires batch['graph_id'] and batch['n_graphs'] implied by labels)."""
    h = L.dense(params["encode"], batch["feats"].astype(cfg.adtype))
    h = jax.nn.relu(h)
    src = batch["edges"][:, 0]
    dst = batch["edges"][:, 1]
    ew = batch["edge_mask"].astype(cfg.adtype)

    def body(h, lp):
        return _pna_layer(cfg, lp, h, src, dst, ew), None

    h, _ = jax.lax.scan(body, h, params["layers"], unroll=cfg.n_layers)

    if cfg.readout == "graph":
        gid = batch["graph_id"]
        n_graphs = batch["labels"].shape[0]
        pooled = jax.ops.segment_sum(h, gid, num_segments=n_graphs)
        cnt = jax.ops.segment_sum(jnp.ones((h.shape[0],), h.dtype), gid, n_graphs)
        h = pooled / jnp.maximum(cnt, 1.0)[:, None]
    return L.dense(params["decode"], h)


def loss_fn(params, cfg: PNAConfig, batch) -> jnp.ndarray:
    """CE on seeds (minibatch), masked nodes (full graph) or graphs."""
    logits = forward(params, cfg, batch)
    if cfg.readout == "graph":
        labels = batch["labels"]
    else:
        if "seed_pos" in batch:
            logits = logits[batch["seed_pos"]]
        labels = batch["labels"]
        if "label_mask" in batch:
            mask = batch["label_mask"]
            lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), -1)
            gold = jnp.take_along_axis(
                logits.astype(jnp.float32), labels[:, None].astype(jnp.int32), axis=-1
            )[:, 0]
            return ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return (lse - gold).mean()
