"""Architecture zoo.

Pure-pytree models (no flax): every architecture exposes

  * ``init(cfg, key)``                  → params pytree
  * ``forward`` / family-specific steps → pure functions
  * ``loss``-producing train closures consumed by ``repro.train``

Deep stacks are built with ``lax.scan`` over stacked per-layer params so
HLO size and compile time are O(1) in depth (a 64-layer 32B config must
compile on one CPU core for the dry-run).

Submodules are imported lazily (``repro.models.transformer`` etc.) to
keep import order acyclic.
"""
