"""Shared neural-net building blocks (pure pytrees, jax-only).

Conventions:
  * params are nested dicts of jnp arrays;
  * init functions take an explicit PRNG key and return params;
  * dtypes: params in ``param_dtype`` (fp32 default), activations cast to
    ``dtype`` (bf16 for the production configs);
  * attention is GQA-general: n_q heads grouped over n_kv heads, optional
    QKV bias (Qwen), optional sliding window (gemma3 local layers),
    optional per-head QK-norm (Qwen3/gemma3);
  * decode uses an explicit KV cache pytree, optionally int8-quantized
    with per (position, head) scales (the serving memory optimization
    that lets 32k-context decode fit a v5e pod — EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import get_active_mesh

__all__ = [
    "rms_norm",
    "dense_init",
    "dense",
    "rope",
    "attention",
    "gqa_attention_init",
    "gqa_attention_apply",
    "mlp_init",
    "mlp_apply",
    "moe_init",
    "moe_apply",
    "KVCache",
    "init_kv_cache",
]


# ---------------------------------------------------------------------------
# Basics
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def dense_init(key, d_in: int, d_out: int, bias: bool = False, dtype=jnp.float32):
    w = jax.random.normal(key, (d_in, d_out), dtype) * (d_in**-0.5)
    p = {"kernel": w}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["kernel"].astype(x.dtype)
    if "bias" in p:
        y = y + p["bias"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10_000.0) -> jnp.ndarray:
    """x (..., L, H, D) rotated by per-position angle; positions (..., L)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., L, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., L, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, windowed, cached)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def attention(
    q: jnp.ndarray,  # (B, Lq, Hq, D)
    k: jnp.ndarray,  # (B, Lk, Hkv, D)
    v: jnp.ndarray,  # (B, Lk, Hkv, D)
    causal: bool = True,
    window: Optional[int] = None,
    kv_valid_len: Optional[jnp.ndarray] = None,  # (B,) for cached decode
    q_chunk: Optional[int] = None,
) -> jnp.ndarray:
    """GQA attention; q heads grouped over kv heads. Returns (B, Lq, Hq, D).

    ``q_chunk``: process queries in chunks (python-unrolled) so the
    (Lq, Lk) score tensor never materializes — the pure-jnp analogue of
    the Pallas flash kernel's tiling; XLA reuses the chunk buffers, so
    peak memory is (q_chunk, Lk), and straight-line code keeps
    cost_analysis exact (no while-loop undercount).
    """
    b, lq, hq, d = q.shape
    if q_chunk is not None and lq > q_chunk and lq % q_chunk == 0:
        outs = []
        dep = jnp.zeros((), q.dtype)
        # Nested remat: in the backward pass each chunk's score matrix is
        # recomputed on demand instead of every chunk staying live after
        # the layer-level remat replays the forward (measured: dominates
        # train peak memory without it).
        chunk_fn = jax.checkpoint(
            lambda q_, k_, v_, kvl, off: _attention_chunk(
                q_, k_, v_, causal, window, kvl, q_offset=off, full_lq=lq
            ),
            static_argnums=(4,),
        )
        for c0 in range(0, lq, q_chunk):
            # `dep` (always 0) chains a data dependency between chunks so
            # the scheduler runs them sequentially and reuses the score
            # buffers — without it, straight-line chunks can all be
            # scheduled before any is consumed (measured: 4x peak memory).
            o = chunk_fn(
                q[:, c0 : c0 + q_chunk] + dep, k, v, kv_valid_len, c0
            )
            dep = (o[0, 0, 0, 0] * 0).astype(q.dtype)
            outs.append(o)
        return jnp.concatenate(outs, axis=1)
    return _attention_chunk(
        q, k, v, causal, window, kv_valid_len, q_offset=0, full_lq=lq
    )


def _attention_chunk(
    q, k, v, causal, window, kv_valid_len, *, q_offset: int, full_lq: int
) -> jnp.ndarray:
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    groups = hq // hkv
    qg = q.reshape(b, lq, hkv, groups, d)
    scale = d**-0.5
    s = jnp.einsum("blhgd,bmhd->bhglm", qg, k).astype(jnp.float32) * scale
    off = lk - full_lq
    i = q_offset + jnp.arange(lq)[:, None]
    j = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= j <= i + off
    if window is not None:
        mask &= j > i + off - window
    mask = mask[None, None, None]  # (1, 1, 1, lq, lk)
    if kv_valid_len is not None:
        valid = jnp.arange(lk)[None, :] < kv_valid_len[:, None]  # (b, lk)
        mask = mask & valid[:, None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhglm,bmhd->blhgd", p, v)
    return out.reshape(b, lq, hq, d)


def gqa_attention_init(
    key,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    qkv_bias: bool = False,
    qk_norm: bool = False,
    dtype=jnp.float32,
):
    ks = jax.random.split(key, 4)
    p = {
        "q": dense_init(ks[0], d_model, n_heads * head_dim, qkv_bias, dtype),
        "k": dense_init(ks[1], d_model, n_kv_heads * head_dim, qkv_bias, dtype),
        "v": dense_init(ks[2], d_model, n_kv_heads * head_dim, qkv_bias, dtype),
        "o": dense_init(ks[3], n_heads * head_dim, d_model, False, dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.zeros((head_dim,), dtype)
        p["k_norm"] = jnp.zeros((head_dim,), dtype)
    return p


@dataclasses.dataclass
class KVCache:
    """Decode cache. ``k``/``v`` are (B, L_max, Hkv, D) in ``store_dtype``;
    int8 stores keep per-(B, L, Hkv) float scales."""

    k: jnp.ndarray
    v: jnp.ndarray
    k_scale: Optional[jnp.ndarray]  # (B, L_max, Hkv) or None
    v_scale: Optional[jnp.ndarray]
    length: jnp.ndarray  # scalar int32 — valid prefix


jax.tree_util.register_pytree_node(
    KVCache,
    lambda c: ((c.k, c.v, c.k_scale, c.v_scale, c.length), None),
    lambda _, t: KVCache(*t),
)


def init_kv_cache(
    batch: int,
    max_len: int,
    n_kv_heads: int,
    head_dim: int,
    dtype=jnp.bfloat16,
    quantized: bool = False,
) -> KVCache:
    store = jnp.int8 if quantized else dtype
    shape = (batch, max_len, n_kv_heads, head_dim)
    scale = (
        jnp.ones((batch, max_len, n_kv_heads), jnp.float32) if quantized else None
    )
    return KVCache(
        k=jnp.zeros(shape, store),
        v=jnp.zeros(shape, store),
        k_scale=scale,
        v_scale=scale,
        length=jnp.zeros((), jnp.int32),
    )


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(B, L, H) symmetric int8; x (B, L, H, D)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def cache_update(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray) -> KVCache:
    """Append (B, Ln, Hkv, D) at cache.length (decode: Ln == 1)."""
    pos = cache.length
    if cache.k_scale is not None:
        kq, ks = _quantize(k_new)
        vq, vs = _quantize(v_new)
        return KVCache(
            k=jax.lax.dynamic_update_slice(cache.k, kq, (0, pos, 0, 0)),
            v=jax.lax.dynamic_update_slice(cache.v, vq, (0, pos, 0, 0)),
            k_scale=jax.lax.dynamic_update_slice(cache.k_scale, ks, (0, pos, 0)),
            v_scale=jax.lax.dynamic_update_slice(cache.v_scale, vs, (0, pos, 0)),
            length=pos + k_new.shape[1],
        )
    store = cache.k.dtype
    return KVCache(
        k=jax.lax.dynamic_update_slice(cache.k, k_new.astype(store), (0, pos, 0, 0)),
        v=jax.lax.dynamic_update_slice(cache.v, v_new.astype(store), (0, pos, 0, 0)),
        k_scale=None,
        v_scale=None,
        length=pos + k_new.shape[1],
    )


def cache_read(cache: KVCache, dtype) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if cache.k_scale is not None:
        return (
            _dequantize(cache.k, cache.k_scale, dtype),
            _dequantize(cache.v, cache.v_scale, dtype),
        )
    return cache.k.astype(dtype), cache.v.astype(dtype)


def gqa_attention_apply(
    p,
    x: jnp.ndarray,  # (B, L, d_model)
    positions: jnp.ndarray,  # (B, L)
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float = 10_000.0,
    causal: bool = True,
    window: Optional[int] = None,
    cache: Optional[KVCache] = None,
    q_chunk: Optional[int] = None,
) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    b, l, _ = x.shape
    q = dense(p["q"], x).reshape(b, l, n_heads, head_dim)
    k = dense(p["k"], x).reshape(b, l, n_kv_heads, head_dim)
    v = dense(p["v"], x).reshape(b, l, n_kv_heads, head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = rope(q, positions, rope_theta)
    k = rope(k, positions, rope_theta)

    if cache is not None:
        if l == 1 and _flash_decode_applicable(cache, b):
            out, cache = _flash_decode(q, k, v, cache, window)
        else:
            cache = cache_update(cache, k, v)
            k_all, v_all = cache_read(cache, x.dtype)
            out = _cached_attention(q, k_all, v_all, positions, window, q_chunk)
    else:
        out = attention(q, k, v, causal=causal, window=window, q_chunk=q_chunk)
    b_, l_, h_, d_ = out.shape
    y = dense(p["o"], out.reshape(b_, l_, h_ * d_))
    return y, cache


def _flash_decode_applicable(cache: KVCache, batch: int) -> bool:
    """Use the split-K shard_map decode when traced under a mesh whose
    'model' axis divides the cache sequence dim (and 'data' divides the
    batch, or batch == 1 and the data axes join the sequence split)."""
    mesh = get_active_mesh()
    if mesh is None or "model" not in mesh.axis_names or mesh.shape["model"] < 2:
        return False
    s_len = cache.k.shape[1]
    dp = [a for a in mesh.axis_names if a != "model"]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if batch % dp_size == 0:
        return s_len % mesh.shape["model"] == 0
    if batch == 1:
        return s_len % (mesh.shape["model"] * dp_size) == 0
    return False


def _flash_decode(q, k_new, v_new, cache: KVCache, window=None):
    """Split-K (FlashDecoding-style) single-token decode via shard_map.

    The cache's sequence dim is sharded over 'model' (plus the data axes
    when batch == 1).  Every shard: (a) writes the new K/V into its local
    slice iff the write position falls in it, (b) dequantizes and attends
    over its local keys with a local running (m, l, acc), and (c) one
    psum over the sequence-sharding axes combines the partial softmax:

        m = pmax(m_i);  l = Σ l_i e^{m_i − m};  out = Σ acc_i e^{m_i − m} / l

    Per layer this moves O(B·H·D) bytes instead of re-sharding the cache
    (the naive SPMD schedule all-gathered / replicated it — see
    EXPERIMENTS.md §Perf iteration 2).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = get_active_mesh()
    b, _, hq, d = q.shape
    s_len, hkv = cache.k.shape[1], cache.k.shape[2]
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    dp_spec = dp if len(dp) > 1 else dp[0]
    if b % dp_size == 0:
        seq_axes: tuple = ("model",)
        b_spec = dp_spec
    else:  # batch = 1 long-context: sequence over every axis
        seq_axes = tuple(list(dp) + ["model"])
        b_spec = None
    seq_spec = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    quantized = cache.k_scale is not None
    groups = hq // hkv

    def inner(q_, kn, vn, kc, vc, ks, vs, length):
        # Local slice offset along the sequence dim (row-major over the
        # sequence-sharding axes; sizes are static from the mesh).
        idx = jnp.zeros((), jnp.int32)
        mul = 1
        for a in reversed(seq_axes):
            idx = idx + jax.lax.axis_index(a) * mul
            mul *= mesh.shape[a]
        s_loc = kc.shape[1]
        start = idx * s_loc

        # (a) conditional local cache write at position `length`.
        rel = jnp.clip(length - start, 0, s_loc - 1)
        hit = (length >= start) & (length < start + s_loc)

        def write(buf, new, scale_buf):
            if quantized:
                nq, nscale = _quantize(new)
                old = jax.lax.dynamic_slice(buf, (0, rel, 0, 0), nq.shape)
                buf = jax.lax.dynamic_update_slice(
                    buf, jnp.where(hit, nq, old), (0, rel, 0, 0)
                )
                olds = jax.lax.dynamic_slice(
                    scale_buf, (0, rel, 0), nscale.shape
                )
                scale_buf = jax.lax.dynamic_update_slice(
                    scale_buf, jnp.where(hit, nscale, olds), (0, rel, 0)
                )
                return buf, scale_buf
            old = jax.lax.dynamic_slice(buf, (0, rel, 0, 0), new.shape)
            buf = jax.lax.dynamic_update_slice(
                buf, jnp.where(hit, new.astype(buf.dtype), old), (0, rel, 0, 0)
            )
            return buf, scale_buf

        kc, ks = write(kc, kn, ks)
        vc, vs = write(vc, vn, vs)

        # (b) local attention over the shard's keys.
        if quantized:
            k_loc = _dequantize(kc, ks, q_.dtype)
            v_loc = _dequantize(vc, vs, q_.dtype)
        else:
            k_loc, v_loc = kc.astype(q_.dtype), vc.astype(q_.dtype)
        bq = q_.shape[0]
        qg = q_.reshape(bq, 1, hkv, groups, d)
        s = jnp.einsum("blhgd,bmhd->bhglm", qg, k_loc).astype(jnp.float32) * (
            d**-0.5
        )  # (b, hkv, g, 1, s_loc)
        pos_abs = start + jnp.arange(s_loc)
        valid = pos_abs <= length
        if window is not None:
            valid &= pos_abs > length - window
        s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
        m_loc = s.max(axis=-1, keepdims=True)
        # (c) combine across sequence shards.
        m_glob = m_loc
        for a in seq_axes:
            m_glob = jax.lax.pmax(m_glob, a)
        p = jnp.exp(s - m_glob)
        l_loc = p.sum(axis=-1, keepdims=True)
        acc = jnp.einsum("bhglm,bmhd->bhgld", p.astype(q_.dtype), v_loc)
        l_glob = l_loc
        acc_glob = acc.astype(jnp.float32)
        for a in seq_axes:
            l_glob = jax.lax.psum(l_glob, a)
            acc_glob = jax.lax.psum(acc_glob, a)
        out = (acc_glob / jnp.maximum(l_glob[..., 0][..., None], 1e-30)).astype(
            q_.dtype
        )  # (b, hkv, g, 1, d)
        out = out.transpose(0, 3, 1, 2, 4).reshape(bq, 1, hq, d)
        return out, kc, vc, ks, vs

    cache_seq_spec5 = P(b_spec, seq_spec, None, None)
    cache_seq_spec4 = P(b_spec, seq_spec, None)
    dummy = jnp.zeros((), jnp.float32)
    ks_in = cache.k_scale if quantized else dummy
    vs_in = cache.v_scale if quantized else dummy
    scale_spec = cache_seq_spec4 if quantized else P()

    def wrapper(q_, kn, vn, kc, vc, ks, vs, length):
        ks_ = ks if quantized else None
        vs_ = vs if quantized else None
        out, kc2, vc2, ks2, vs2 = inner(q_, kn, vn, kc, vc, ks_, vs_, length)
        if not quantized:
            ks2 = vs2 = jnp.zeros((), jnp.float32)
        return out, kc2, vc2, ks2, vs2

    fn = shard_map(
        wrapper,
        mesh=mesh,
        in_specs=(
            P(b_spec, None, None, None),  # q
            P(b_spec, None, None, None),  # k_new
            P(b_spec, None, None, None),  # v_new
            cache_seq_spec5,  # k cache
            cache_seq_spec5,  # v cache
            scale_spec,
            scale_spec,
            P(),  # length
        ),
        out_specs=(
            P(b_spec, None, None, None),
            cache_seq_spec5,
            cache_seq_spec5,
            scale_spec if quantized else P(),
            scale_spec if quantized else P(),
        ),
    )
    out, kc, vc, ks, vs = fn(
        q, k_new, v_new, cache.k, cache.v, ks_in, vs_in, cache.length
    )
    new_cache = KVCache(
        k=kc,
        v=vc,
        k_scale=ks if quantized else None,
        v_scale=vs if quantized else None,
        length=cache.length + 1,
    )
    return out, new_cache


def _cached_attention(q, k_all, v_all, positions, window=None, q_chunk=None):
    """Attention against a (partially filled) cache buffer.

    Key slot j (absolute position j) is visible to the query at absolute
    position p iff ``j <= p`` (causal; also hides unwritten slots) and,
    with a sliding window, ``j > p - window``.  Works for prefill
    (Lq > 1) and single-token decode alike.  ``q_chunk`` as in
    ``attention`` (python-unrolled flash-style query tiling).
    """
    b, lq, hq, d = q.shape
    if q_chunk is not None and lq > q_chunk and lq % q_chunk == 0:
        outs = []
        dep = jnp.zeros((), q.dtype)
        for c0 in range(0, lq, q_chunk):
            o = _cached_attention(
                q[:, c0 : c0 + q_chunk] + dep, k_all, v_all,
                positions[:, c0 : c0 + q_chunk], window, None,
            )
            dep = (o[0, 0, 0, 0] * 0).astype(q.dtype)  # sequentialize (see attention)
            outs.append(o)
        return jnp.concatenate(outs, axis=1)
    lk, hkv = k_all.shape[1], k_all.shape[2]
    groups = hq // hkv
    qg = q.reshape(b, lq, hkv, groups, d)
    s = jnp.einsum("blhgd,bmhd->bhglm", qg, k_all).astype(jnp.float32) * (d**-0.5)
    j = jnp.arange(lk)[None, None, :]
    pos = positions[:, :, None]  # (B, Lq, 1)
    mask = j <= pos
    if window is not None:
        mask &= j > pos - window
    # (B, Lq, Lk) -> (B, 1, 1, Lq, Lk)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhglm,bmhd->blhgd", p, v_all)
    return out.reshape(b, lq, hq, d)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, gated: bool = True, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "up": dense_init(ks[0], d_model, d_ff, False, dtype),
        "down": dense_init(ks[1], d_ff, d_model, False, dtype),
    }
    if gated:
        p["gate"] = dense_init(ks[2], d_model, d_ff, False, dtype)
    return p


def mlp_apply(p, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    up = dense(p["up"], x)
    if "gate" in p:
        g = dense(p["gate"], x)
        h = jax.nn.silu(g) * up if act == "silu" else jax.nn.gelu(g) * up
    else:
        h = jax.nn.silu(up) if act == "silu" else jax.nn.gelu(up)
    return dense(p["down"], h)


# ---------------------------------------------------------------------------
# MoE (top-k routing, capacity-bounded sort-based dispatch)
# ---------------------------------------------------------------------------


def moe_init(
    key, d_model: int, d_expert: int, n_experts: int, gated: bool = True,
    dtype=jnp.float32,
):
    ks = jax.random.split(key, 4)
    scale_in = d_model**-0.5
    scale_out = d_expert**-0.5
    p = {
        "router": dense_init(ks[0], d_model, n_experts, False, dtype),
        "up": jax.random.normal(ks[1], (n_experts, d_model, d_expert), dtype)
        * scale_in,
        "down": jax.random.normal(ks[2], (n_experts, d_expert, d_model), dtype)
        * scale_out,
    }
    if gated:
        p["gate"] = (
            jax.random.normal(ks[3], (n_experts, d_model, d_expert), dtype)
            * scale_in
        )
    return p


def moe_apply(
    p,
    x: jnp.ndarray,  # (T, d_model) — flattened tokens
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE FFN. Dispatches to the expert-parallel shard_map path when
    traced under a mesh with a >1 'model' axis (experts are sharded over
    'model' by the LM sharding rules); otherwise the single-device dense
    dispatch below.

    The shard_map path exploits that activations are replicated over
    'model' between blocks (Megatron layout): every expert shard already
    holds every token, so dispatch needs NO all-to-all at all — each shard
    gathers the tokens routed to its local experts and one psum over
    'model' combines the outputs.  (This replaced an XLA-chosen schedule
    that all-gathered the full dispatch buffers; see EXPERIMENTS.md §Perf.)
    """
    mesh = get_active_mesh()
    if (
        mesh is not None
        and "model" in mesh.axis_names
        and mesh.shape["model"] > 1
        and p["up"].shape[0] % mesh.shape["model"] == 0
    ):
        dp_axes = tuple(a for a in mesh.axis_names if a != "model")
        dp_size = 1
        for a in dp_axes:
            dp_size *= mesh.shape[a]
        if x.shape[0] % dp_size == 0:
            return _moe_apply_sharded(
                p, x, top_k, capacity_factor, act, mesh, dp_axes
            )
    return _moe_apply_dense(p, x, top_k, capacity_factor, act)


def _moe_apply_sharded(p, x, top_k, capacity_factor, act, mesh, dp_axes):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    e = p["router"]["kernel"].shape[1]
    d = x.shape[1]
    n_model = mesh.shape["model"]
    e_loc = e // n_model
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    t_loc = x.shape[0] // dp_size
    capacity = max(8, -(-int(capacity_factor * t_loc * top_k / e) // 8) * 8)
    has_gate = "gate" in p
    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def inner(router_k, up, gate, down, x_loc):
        m = jax.lax.axis_index("model")
        logits = (x_loc @ router_k.astype(x_loc.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)  # (T_loc, E)
        gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

        # Aux loss (identical on every model shard; averaged over data).
        me = probs.mean(axis=0)
        ce = (
            jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0)
            / (t_loc * top_k)
        )
        aux = e * jnp.sum(me * ce)
        for a in dp_axes:
            aux = jax.lax.pmean(aux, a)

        # Local-expert dispatch: this shard owns experts [m·e_loc, (m+1)·e_loc).
        lo = m * e_loc
        flat_e = gate_idx.reshape(-1)
        flat_g = gate_vals.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t_loc), top_k)
        local = (flat_e >= lo) & (flat_e < lo + e_loc)
        le = jnp.where(local, flat_e - lo, e_loc)  # e_loc = drop group
        order = jnp.argsort(le, stable=True)
        se, st, sg = le[order], flat_t[order], flat_g[order]
        start = jnp.searchsorted(se, jnp.arange(e_loc), side="left")
        rank = jnp.arange(t_loc * top_k) - start[jnp.minimum(se, e_loc - 1)]
        keep = (se < e_loc) & (rank < capacity)
        slot = jnp.where(keep, se * capacity + rank, e_loc * capacity)

        buf = jnp.zeros((e_loc * capacity + 1, d), x_loc.dtype).at[slot].set(
            x_loc[st]
        )
        xe = buf[: e_loc * capacity].reshape(e_loc, capacity, d)
        up_h = jnp.einsum("ecd,edf->ecf", xe, up.astype(x_loc.dtype))
        if has_gate:
            g = jnp.einsum("ecd,edf->ecf", xe, gate.astype(x_loc.dtype))
            h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * up_h
        else:
            h = jax.nn.silu(up_h)
        ye = jnp.einsum("ecf,efd->ecd", h, down.astype(x_loc.dtype))
        ye_flat = ye.reshape(e_loc * capacity, d)
        contrib = jnp.where(
            keep[:, None],
            ye_flat[jnp.minimum(slot, e_loc * capacity - 1)] * sg[:, None],
            0.0,
        )
        out = jnp.zeros((t_loc, d), x_loc.dtype).at[st].add(
            contrib.astype(x_loc.dtype)
        )
        # Combine expert shards: one all-reduce over 'model'.
        return jax.lax.psum(out, "model"), aux

    gate_arr = p["gate"] if has_gate else p["up"]  # placeholder, unused
    fn = shard_map(
        inner,
        mesh=mesh,
        in_specs=(
            P(),  # router replicated
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
            P(dp_spec, None),
        ),
        out_specs=(P(dp_spec, None), P()),
    )
    return fn(p["router"]["kernel"], p["up"], gate_arr, p["down"], x)


def _moe_apply_dense(p, x, top_k, capacity_factor, act):
    """Single-device sort-based capacity-bounded dispatch (GShard
    semantics). Tokens over capacity are dropped — standard."""
    t, d = x.shape
    e = p["router"]["kernel"].shape[1]
    logits = dense(p["router"], x).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # Load-balancing aux loss (Switch): e * Σ_e fraction_tokens * mean_prob.
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0) / (t * top_k)
    aux = e * jnp.sum(me * ce)

    capacity = int(max(1, capacity_factor * t * top_k / e))
    flat_expert = gate_idx.reshape(-1)  # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t), top_k)

    order = jnp.argsort(flat_expert, stable=True)  # group by expert
    se, st_tok, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # rank within expert group
    pos = jnp.arange(t * top_k)
    start = jnp.searchsorted(se, jnp.arange(e), side="left")
    rank = pos - start[se]
    keep = rank < capacity
    slot = jnp.where(keep, se * capacity + rank, e * capacity)  # drop → scratch

    # Gather tokens into (E*C, d) dispatch buffer (+1 scratch row).
    buf = jnp.zeros((e * capacity + 1, d), x.dtype).at[slot].set(x[st_tok])
    xe = buf[: e * capacity].reshape(e, capacity, d)

    up = jnp.einsum("ecd,edf->ecf", xe, p["up"].astype(x.dtype))
    if "gate" in p:
        g = jnp.einsum("ecd,edf->ecf", xe, p["gate"].astype(x.dtype))
        h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * up
    else:
        h = jax.nn.silu(up)
    ye = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(x.dtype))  # (E, C, d)

    # Combine: scatter-add weighted expert outputs back to tokens.
    ye_flat = ye.reshape(e * capacity, d)
    contrib = jnp.where(keep[:, None], ye_flat[jnp.minimum(slot, e * capacity - 1)] * sg[:, None], 0.0)
    out = jnp.zeros((t, d), x.dtype).at[st_tok].add(contrib.astype(x.dtype))
    return out, aux
