"""Sharding rules for every tree the launch/serve layers move onto a mesh.

One place owns the mapping from (param tree | batch dict | KV cache) to
``PartitionSpec``s over the canonical ``("data", "model")`` mesh (with an
optional leading ``"pod"`` axis for multi-pod meshes):

* LM params follow the Megatron layout — attention q/k/v and MLP up/gate are
  column-parallel (output dim over ``model``), attention o and MLP down are
  row-parallel (input dim over ``model``), embeddings shard the vocab dim,
  MoE experts shard the expert dim.  ``fsdp=True`` additionally shards one
  remaining dim over the data axes (ZeRO-3 style).
* Batches shard their leading (batch) dim over the data axes.
* KV caches mirror the split-K flash-decode layout in
  ``repro.models.layers``: sequence over ``model`` (plus the data axes for
  batch-1 long-context), batch over the data axes.

Every emitted spec passes through :func:`validate_spec`, which degrades any
axis that does not evenly divide the corresponding dim to replicated — the
same tree of rules therefore works for the 1×1 CPU smoke mesh, the 16×16
production pod, and the 2×16×16 multi-pod mesh.

This module is also the version-portability seam for the ambient mesh:
``jax.sharding.set_mesh`` / ``get_abstract_mesh`` only exist on newer jax,
so :func:`set_mesh` / :func:`get_active_mesh` back-fill them with a module
global holding the concrete mesh (``shard_map`` accepts either).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

__all__ = [
    "set_mesh",
    "get_active_mesh",
    "batch_axes",
    "data_spec",
    "axes_size",
    "postings_spec",
    "plan_specs",
    "validate_spec",
    "lm_param_specs",
    "pna_param_specs",
    "recsys_param_specs",
    "opt_state_specs",
    "batch_specs",
    "cache_specs",
    "shard_rows",
    "device_count",
]


# ---------------------------------------------------------------------------
# Ambient mesh context (version-portable)
# ---------------------------------------------------------------------------

_ACTIVE_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> Optional[Mesh]:
    """Make ``mesh`` the ambient mesh the shard_map model paths see.

    On jax versions that ship ``jax.sharding.set_mesh`` this delegates to it
    (so ``get_abstract_mesh`` works natively inside traces); on older
    versions the mesh is kept in a module global that
    :func:`get_active_mesh` returns.  Pass ``None`` to clear.
    """
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    native = getattr(jax.sharding, "set_mesh", None)
    if native is not None:
        native(mesh)
    return mesh


def get_active_mesh() -> Optional[Mesh]:
    """The ambient mesh, or None when no mesh has been set.

    Prefers jax's native abstract-mesh context when it exists and is
    non-trivial, falling back to the mesh stored by :func:`set_mesh`.
    """
    native = getattr(jax.sharding, "get_abstract_mesh", None)
    if native is not None:
        mesh = native()
        if mesh is not None and getattr(mesh, "axis_names", ()):
            return mesh
    return _ACTIVE_MESH


# ---------------------------------------------------------------------------
# Axis helpers
# ---------------------------------------------------------------------------


def batch_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes: every mesh axis except ``model``."""
    return tuple(a for a in mesh.axis_names if a != "model")


def data_spec(mesh):
    """The data axes as a single PartitionSpec entry (str or tuple)."""
    dp = batch_axes(mesh)
    if not dp:
        return None
    return dp if len(dp) > 1 else dp[0]


def axes_size(mesh, entry) -> int:
    """Product of mesh-axis sizes named by one PartitionSpec entry."""
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for a in names:
        size *= int(mesh.shape[a])
    return size


def postings_spec(mesh) -> P:
    """Spec of the sharded engine's stacked postings matrix (S, W): the
    shard dim over the data axes, each shard's postings row unsplit."""
    return P(data_spec(mesh), None)


def plan_specs(mesh) -> Tuple[P, P]:
    """Specs of a sharded lowered plan's two stacks — cells (S, 4, C)
    and stage segments (S, 2, n_stages * group_width): shard dim over
    the data axes, per-shard layout unsplit."""
    dp = data_spec(mesh)
    return P(dp, None, None), P(dp, None, None)


def validate_spec(mesh, spec, shape) -> P:
    """Clamp ``spec`` to ``shape``: any entry whose axis-size product does
    not evenly divide the dim (or that names an axis the mesh lacks) is
    replaced by None (replicated).  Raises if the spec is longer than the
    shape — that is a real rank bug, not a divisibility issue."""
    entries = tuple(spec)
    if len(entries) > len(shape):
        raise ValueError(f"spec {spec} has more entries than shape {shape}")
    entries = entries + (None,) * (len(shape) - len(entries))
    out = []
    names = set(mesh.axis_names)
    # A PartitionSpec may legally be shorter than the array rank (the
    # trailing dims are replicated), so this zip must not be strict.
    for dim, entry in zip(shape, entries, strict=False):
        if entry is None:
            out.append(None)
            continue
        req = entry if isinstance(entry, tuple) else (entry,)
        if not set(req) <= names:
            out.append(None)
            continue
        size = axes_size(mesh, entry)
        out.append(entry if size > 1 and dim % size == 0 else None)
    # Drop trailing Nones for a canonical form (P() == fully replicated).
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _path_keys(path) -> Tuple[str, ...]:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "name"):
            keys.append(str(p.name))
    return tuple(keys)


def _with_fsdp(entries: list, shape, mesh, dp) -> list:
    """ZeRO-3 flavor: shard the largest still-replicated dim over data."""
    if dp is None:
        return entries
    size = axes_size(mesh, dp)
    free = [
        i for i, e in enumerate(entries)
        if e is None and shape[i] % size == 0 and shape[i] >= size
    ]
    if free:
        best = max(free, key=lambda i: shape[i])
        entries[best] = dp
    return entries


# ---------------------------------------------------------------------------
# Parameter trees
# ---------------------------------------------------------------------------

_COLUMN_PARALLEL = {"q", "k", "v", "up", "gate", "encode", "router"}
_ROW_PARALLEL = {"o", "down", "decode"}


def _lm_rule(keys: Tuple[str, ...], shape, mesh, fsdp: bool, dp) -> P:
    """Megatron placement for one LM leaf; ``keys`` is the dict-key path."""
    stacked = "layers" in keys  # stacked leaves carry a leading (L,) axis
    lead = 1 if stacked else 0
    name = keys[-1] if keys else ""
    owner = keys[-2] if len(keys) >= 2 else ""
    entries = [None] * len(shape)

    if name == "embed":
        entries[0] = "model"  # vocab-dim sharded
    elif name == "lm_head":
        entries[-1] = "model"
    elif owner == "moe" and len(shape) - lead >= 2:
        entries[lead] = "model"  # experts over model
    elif owner in _COLUMN_PARALLEL or name in _COLUMN_PARALLEL:
        if name == "kernel" or name == "bias" or owner in _COLUMN_PARALLEL:
            entries[-1] = "model"  # output dim
    elif owner in _ROW_PARALLEL or name in _ROW_PARALLEL:
        if len(shape) - lead >= 2:
            entries[-2] = "model"  # input dim; bias stays replicated
    # norms / scalars: replicated.

    if fsdp:
        entries = _with_fsdp(entries, shape, mesh, dp)
    return validate_spec(mesh, P(*entries), shape)


def lm_param_specs(params, mesh, fsdp: bool = False):
    """PartitionSpec tree for an LM parameter tree (Megatron + opt. ZeRO-3)."""
    dp = data_spec(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _lm_rule(_path_keys(path), leaf.shape, mesh, fsdp, dp),
        params,
    )


def _generic_rule(keys: Tuple[str, ...], shape, mesh) -> P:
    """Column-parallel kernels, vocab-sharded embedding tables, replicated
    norms — the rule shared by the GNN and recsys families."""
    name = keys[-1] if keys else ""
    owner = keys[-2] if len(keys) >= 2 else ""
    entries = [None] * len(shape)
    if any("emb" in k for k in (name, owner)) and len(shape) >= 2:
        entries[-2] = "model"  # (vocab, dim) tables: shard the vocab dim
    elif name in _ROW_PARALLEL or owner in _ROW_PARALLEL:
        if len(shape) >= 2:
            entries[-2] = "model"
    elif len(shape) >= 2:
        entries[-1] = "model"
    return validate_spec(mesh, P(*entries), shape)


def pna_param_specs(params, mesh):
    """PartitionSpec tree for the PNA GNN parameter tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _generic_rule(_path_keys(path), leaf.shape, mesh),
        params,
    )


def recsys_param_specs(params, mesh):
    """PartitionSpec tree for a recsys parameter tree (embedding tables
    vocab-sharded over ``model``, towers column-parallel)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _generic_rule(_path_keys(path), leaf.shape, mesh),
        params,
    )


def opt_state_specs(param_specs):
    """AdamW state specs: moments follow the params, step is replicated."""
    return {"mu": param_specs, "nu": param_specs, "step": P()}


# ---------------------------------------------------------------------------
# Batches and caches
# ---------------------------------------------------------------------------


def batch_specs(
    shapes: Mapping[str, Tuple[int, ...]],
    mesh,
    field_rules: Optional[Dict[str, Any]] = None,
) -> Dict[str, P]:
    """Specs for a batch dict: leading dim over the data axes unless a
    field rule says otherwise.  ``shapes`` maps field -> shape tuple."""
    dp = data_spec(mesh)
    out = {}
    for name, shape in shapes.items():
        rule = (field_rules or {}).get(name)
        if rule is None:
            rule = P(dp) if shape else P()
        out[name] = validate_spec(mesh, rule, shape)
    return out


def cache_specs(cache, mesh):
    """Specs for a stacked KV cache (leading ``n_layers`` axis), mirroring
    the split-K flash-decode layout of ``repro.models.layers``:

    * batch divisible by the data axes → batch over data, sequence over
      ``model``;
    * batch == 1 (long context) → sequence over every axis;
    * anything else → replicated (the dense cached-attention path).

    ``None`` leaves (absent int8 scales) map to ``None`` so the result
    tree-maps against the cache itself with ``is_leaf=lambda x: x is None``.
    """
    dp = data_spec(mesh)
    dp_size = axes_size(mesh, dp)
    model = int(mesh.shape["model"]) if "model" in mesh.axis_names else 1
    all_axes = tuple(mesh.axis_names)
    all_spec = all_axes if len(all_axes) > 1 else (all_axes[0] if all_axes else None)

    def one(leaf):
        if leaf is None:
            return None
        shape = leaf.shape
        if len(shape) < 4:  # length scalar and friends
            return P()
        b, s = shape[1], shape[2]  # (L, B, S, H[, D])
        if dp_size > 1 and b % dp_size == 0 and model > 1 and s % model == 0:
            b_spec, s_spec = dp, "model"
        elif b == 1 and s % (model * dp_size) == 0 and model * dp_size > 1:
            b_spec, s_spec = None, all_spec
        else:
            return validate_spec(mesh, P(), shape)
        return validate_spec(
            mesh, P(None, b_spec, s_spec, *([None] * (len(shape) - 3))), shape
        )

    return jax.tree.map(one, cache, is_leaf=lambda x: x is None)


def shard_rows(n_rows: int, mesh) -> int:
    """Rows of padding needed to split ``n_rows`` evenly over the data axes."""
    dp_size = axes_size(mesh, data_spec(mesh))
    return (-n_rows) % max(dp_size, 1)


def device_count(mesh) -> int:
    return int(math.prod(int(mesh.shape[a]) for a in mesh.axis_names))
