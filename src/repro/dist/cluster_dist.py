"""Mesh-sharded SeCluD K-means: the paper's §3.2 parallelization sketch
(documents sharded, counts replicated) as a ``shard_map`` program.

Each device holds a row-shard of the ELL-packed frequent-term view.  One
round is:

  local counts  →  psum over the data axes  →  ψ + δ⁺ tables (computed
  redundantly on every shard — they are (k, TC), tiny next to the docs)
  →  local scores  →  local argmin.

The host drives rounds exactly like ``repro.core.kmeans.kmeans``: accept a
round iff ψ improved, stop below the 1 % relative-improvement threshold
(paper §4), reseed empty clusters from the worst-fitting documents.

``distributed_kmeans_fn`` adapts this to the ``kmeans(view, k, ...)``
signature so ``multilevel_cluster`` / ``topdown_cluster`` can run their
large levels on the mesh and their small recursion leaves on the host
(document-grained mode, which is inherently sequential).
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.jax_ops import (
    counts_from_ell,
    delta_add_tables_jax,
    ell_pack,
    psi_jax,
    scores_from_ell,
)
from repro.core.kmeans import KMeansResult, kmeans
from repro.core.objective import FrequentTermView, cluster_counts, psi_from_counts
from repro.dist.sharding import axes_size, batch_axes, data_spec

__all__ = ["distributed_kmeans", "distributed_kmeans_fn", "make_round_fn"]


def make_round_fn(mesh, k: int, tc: int, block: int = 512) -> Callable:
    """jit(shard_map) computing one round: (ell, assign, p) -> (assign', ψ).

    ``ell`` rows (documents) are sharded over the data axes and replicated
    over ``model``; the returned assignment is sharded the same way and ψ is
    fully replicated (one psum over the data axes makes the counts — and
    everything derived from them — identical on every shard).
    """
    dp_axes = batch_axes(mesh)
    dp = data_spec(mesh)

    def local_round(ell_loc, assign_loc, p):
        counts = counts_from_ell(ell_loc, assign_loc, k, tc)
        counts = jax.lax.psum(counts, dp_axes)
        psi = psi_jax(counts, p)
        tables = delta_add_tables_jax(counts, p)
        scores = scores_from_ell(ell_loc, tables, p, block=block)
        return jnp.argmin(scores, axis=1).astype(assign_loc.dtype), psi

    # check_rep=False: the body nests jit'd ops (counts/psi/tables) whose
    # replication jax 0.4.x's checker cannot track through; the psum over
    # the data axes is what actually establishes the replication of ψ.
    kw = {}
    try:
        import inspect

        if "check_rep" in inspect.signature(shard_map).parameters:
            kw["check_rep"] = False
    except (ValueError, TypeError):  # pragma: no cover
        pass
    fn = shard_map(
        local_round,
        mesh=mesh,
        in_specs=(P(dp, None), P(dp), P()),
        out_specs=(P(dp), P()),
        **kw,
    )
    return jax.jit(fn)


def _reseed_empty_random(
    assign: np.ndarray, k: int, rng: np.random.Generator
) -> np.ndarray:
    """Give each empty cluster one document from the largest cluster."""
    sizes = np.bincount(assign, minlength=k)
    for j in np.flatnonzero(sizes == 0):
        donor = int(np.argmax(sizes))
        cand = np.flatnonzero(assign == donor)
        if len(cand) <= 1:
            break
        d = rng.choice(cand)
        assign[d] = j
        sizes[donor] -= 1
        sizes[j] += 1
    return assign


def distributed_kmeans(
    view: FrequentTermView,
    k: int,
    mesh,
    init_assign: Optional[np.ndarray] = None,
    max_iters: int = 50,
    min_rel_improvement: float = 0.01,
    seed: int = 0,
    block: int = 512,
    l_pad: Optional[int] = None,
) -> Tuple[np.ndarray, float]:
    """Round-based K-means on the ψ objective, documents sharded over the
    mesh's data axes.  Returns ``(assign, psi)`` — ψ as reported by the
    device round *before* the last accepted move (same convention as the
    host driver's history)."""
    assign, psi_dev, _ = _run_rounds(
        view, k, mesh, init_assign, max_iters, min_rel_improvement, seed,
        block, l_pad,
    )
    return assign, psi_dev


def _run_rounds(
    view: FrequentTermView,
    k: int,
    mesh,
    init_assign: Optional[np.ndarray],
    max_iters: int,
    min_rel_improvement: float,
    seed: int,
    block: int,
    l_pad: Optional[int],
) -> Tuple[np.ndarray, float, list]:
    """(assign, device ψ, host ψ history — one entry per accepted round)."""
    n = view.n_docs
    ell, _ = ell_pack(view, l_pad)
    dp_size = axes_size(mesh, data_spec(mesh))
    pad = (-n) % max(dp_size, 1)
    if pad:
        # Padding documents carry only pad slots (rank == tc): they add
        # nothing to any cluster's counts, so their assignment is inert.
        ell = np.concatenate(
            [ell, np.full((pad, ell.shape[1]), view.tc, ell.dtype)]
        )
    p32 = np.asarray(view.p_freq, np.float32)

    rng = np.random.default_rng(seed)
    if init_assign is None:
        assign = (rng.permutation(n + pad) % k).astype(np.int32)
    else:
        assign = np.concatenate(
            [np.asarray(init_assign, np.int32), np.zeros(pad, np.int32)]
        )

    round_fn = make_round_fn(mesh, k, view.tc, block=block)
    psi = psi_from_counts(cluster_counts(view, assign[:n].astype(np.int64), k), view.p_freq)
    psi_dev = float(psi)
    history = [psi]
    # The corpus and P never change across rounds — upload once.
    ell_dev = jnp.asarray(ell)
    p_dev = jnp.asarray(p32)
    for _ in range(max_iters):
        new_assign, psi_round = round_fn(ell_dev, jnp.asarray(assign), p_dev)
        new_assign = np.array(new_assign)  # copy: device arrays are read-only
        new_assign[:n] = _reseed_empty_random(new_assign[:n], k, rng)
        psi_new = psi_from_counts(
            cluster_counts(view, new_assign[:n].astype(np.int64), k), view.p_freq
        )
        if psi_new >= psi * (1.0 - 1e-12):
            break
        improved = (psi - psi_new) / max(psi, 1e-30)
        assign, psi, psi_dev = new_assign, psi_new, float(psi_round)
        history.append(psi)
        if improved < min_rel_improvement:
            break
    return assign[:n].astype(np.int64), psi_dev, history


def distributed_kmeans_fn(
    mesh,
    doc_grained_below: int = 2_048,
    block: int = 512,
) -> Callable[..., KMeansResult]:
    """A drop-in ``kmeans_fn`` for ``multilevel_cluster``/``topdown_cluster``:
    large levels run mesh-sharded, small ones on the host (the
    document-grained mode is sequential by construction)."""

    def fn(
        view: FrequentTermView,
        k: int,
        init_assign: Optional[np.ndarray] = None,
        max_iters: int = 100,
        min_rel_improvement: float = 0.01,
        doc_grained_below: int = doc_grained_below,
        seed: int = 0,
    ) -> KMeansResult:
        if view.n_docs < doc_grained_below:
            return kmeans(
                view, k, init_assign=init_assign, max_iters=max_iters,
                min_rel_improvement=min_rel_improvement,
                doc_grained_below=doc_grained_below, seed=seed,
            )
        assign, _, history = _run_rounds(
            view, k, mesh, init_assign, max_iters, min_rel_improvement,
            seed, block, None,
        )
        return KMeansResult(
            assign=assign, psi=history[-1], n_iters=len(history) - 1,
            psi_history=history,
        )

    return fn
