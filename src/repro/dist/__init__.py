"""Distribution substrate: mesh/sharding specs, distributed clustering,
compressed collectives, and fault tolerance.

This is the layer the paper's closing claim points at — clusters "are also
useful ... for distributing the work over many machines" — realized as four
modules:

* ``sharding``        — PartitionSpec rules for every param/batch/cache tree
                        the launch layer builds, plus a version-portable
                        ambient-mesh context (``set_mesh``/``get_active_mesh``).
* ``cluster_dist``    — mesh-sharded SeCluD K-means (``shard_map`` + ``psum``)
                        and adapters that drop it into ``multilevel_cluster``
                        / ``topdown_cluster``.
* ``compression``     — error-feedback int8 gradient compression and the
                        compressed all-reduce built from it.
* ``fault_tolerance`` — straggler detection, mesh-shape planning under device
                        loss, and elastic re-meshing.

Only ``sharding`` is imported eagerly (it is jax-only and consumed by the
model layer); the other modules are plain submodules — import them directly
(``from repro.dist import compression``) to keep import costs where they are
used.
"""

from repro.dist import sharding

__all__ = ["sharding", "cluster_dist", "compression", "fault_tolerance"]
