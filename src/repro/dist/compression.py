"""Error-feedback int8 gradient compression and the compressed all-reduce.

The paper's compression section is about posting lists; this is the same
bandwidth argument applied to the *training* side of the system: gradients
cross the slowest links (inter-host, inter-pod), so an int8 wire format
with error feedback cuts all-reduce bytes 4× at no asymptotic loss —

    v_t   = g_t + e_{t-1}          (fold in what was previously dropped)
    q_t   = Q(v_t)                 (symmetric int8, per-tensor scale)
    e_t   = v_t − deq(q_t)         (what this step drops)

so the cumulative transmitted signal Σ deq(q_t) equals Σ g_t − e_T: nothing
is ever systematically lost (the invariant ``deq + e_t == v_t`` holds
exactly in fp32, and ``|e_t| ≤ scale/2`` stays bounded).

``compressed_psum_tree`` is the collective built from it: quantize each
leaf, share one scale per leaf via ``pmax``, psum the int8 payload (as
int32 — the wire format is int8, the reduction must not saturate), and
dequantize.  With ``axis_name=None`` it degrades to local
quantize/dequantize, which is what single-host tests exercise.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_decompress", "init_error_state", "compressed_psum_tree"]


def _quantize(v: jnp.ndarray, axis_name: Optional[str] = None):
    """Symmetric per-tensor int8; the scale is pmax-shared when reducing
    over an axis so every participant uses the same grid."""
    amax = jnp.max(jnp.abs(v))
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.where(amax > 0, amax / 127.0, jnp.float32(1.0))
    q = jnp.clip(jnp.round(v / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def compress_decompress(
    x: jnp.ndarray, err: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One error-feedback round trip for a single tensor.

    Returns ``(deq, new_err)`` with ``deq + new_err == x + err`` exactly
    (in fp32): the quantization error is carried, never dropped.
    """
    v = x.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = _quantize(v)
    deq = q.astype(jnp.float32) * scale
    return deq, v - deq


def init_error_state(grads: Any) -> Any:
    """Zero error-feedback state matching a gradient tree."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum_tree(
    grads: Any, err: Any, axis_name: Optional[str] = None
) -> Tuple[Any, Any]:
    """Compressed all-reduce over a gradient tree.

    Inside ``shard_map``/``pmap`` pass the reduction axis name; the result
    is the *sum* over the axis (divide by the axis size for a mean, as the
    caller's optimizer convention dictates).  With ``axis_name=None`` the
    tree is quantized and dequantized locally — same wire format, no
    collective — which keeps a single code path for 1-host smoke runs.

    Returns ``(reduced_tree, new_err_tree)``.
    """

    def one(g, e):
        v = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, scale = _quantize(v, axis_name)
        deq_local = q.astype(jnp.float32) * scale
        new_err = v - deq_local
        if axis_name is None:
            return deq_local, new_err
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return total.astype(jnp.float32) * scale, new_err

    # Flatten/unflatten rather than a tree_map of pairs: a pair-tree can't
    # be picked apart with is_leaf when the gradient tree itself contains
    # tuple nodes.
    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_e = jax.tree.leaves(err)
    pairs = [one(g, e) for g, e in zip(leaves_g, leaves_e, strict=True)]
    out = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    new_err = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return out, new_err
