"""Fault tolerance for multi-host runs: straggler detection, mesh-shape
planning under device loss, and elastic re-meshing.

The serving/training loops this repo grows toward run on hundreds of
chips; at that scale a slow or dead host is the common case, not the
exception.  Three pieces:

* :class:`StragglerMonitor` — per-host step-time tracking against the
  median of the other hosts; ``strikes_to_evict`` *consecutive* misses of
  the ``deadline_factor × median`` deadline flags the host for eviction
  (consecutive, so transient GC/compile hiccups don't evict anyone).
* :func:`plan_mesh_shape` — the largest ``("data", "model")`` (optionally
  ``("pod", "data", "model")``) mesh shape that fits ``n_devices`` while
  keeping the model-parallel degree intact: losing a host shrinks the data
  axis, never the model axis (a model shard is not droppable).
* :class:`ElasticMesh` — applies the plan to the currently-live devices and
  counts re-mesh epochs, so a training loop can rebuild its jit'd step
  when membership changes and checkpoint-restore into the new world size.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Verdict",
    "StragglerMonitor",
    "plan_mesh_shape",
    "ElasticMesh",
    "NoDevicesError",
]


class NoDevicesError(RuntimeError):
    """Eviction left no device to build a mesh from.

    Raised by :meth:`ElasticMesh.remesh` when every pooled device is
    excluded — the typed signal the serving tier's resilience layer
    catches to drop to its host-fallback rung (an opaque numpy reshape
    error here would kill the loop instead of degrading it)."""


@dataclasses.dataclass(frozen=True)
class Verdict:
    host: int
    slow: bool  # missed the deadline on this record
    strikes: int  # consecutive misses so far
    evict: bool  # strikes reached the eviction threshold


class StragglerMonitor:
    """Flags hosts whose step time persistently exceeds the deadline.

    ``record(step_times)`` takes one wall-clock step duration per host and
    returns a verdict per host.  The deadline is
    ``deadline_factor × median(other hosts' times)`` — with a single host
    there is no reference population and nothing is ever flagged.
    """

    def __init__(
        self,
        n_hosts: int,
        deadline_factor: float = 1.5,
        strikes_to_evict: int = 3,
    ):
        if n_hosts < 1:
            raise ValueError("n_hosts must be >= 1")
        self.n_hosts = n_hosts
        self.deadline_factor = float(deadline_factor)
        self.strikes_to_evict = int(strikes_to_evict)
        self._strikes = np.zeros(n_hosts, dtype=np.int64)
        self._evicted: set = set()
        self.n_records = 0

    def record(self, step_times: Sequence[float]) -> List[Verdict]:
        times = np.asarray(step_times, dtype=np.float64)
        if times.shape != (self.n_hosts,):
            raise ValueError(
                f"expected {self.n_hosts} step times, got shape {times.shape}"
            )
        self.n_records += 1
        verdicts = []
        for h in range(self.n_hosts):
            others = [
                times[i]
                for i in range(self.n_hosts)
                if i != h and i not in self._evicted
            ]
            slow = bool(
                others and times[h] > self.deadline_factor * float(np.median(others))
            )
            self._strikes[h] = self._strikes[h] + 1 if slow else 0
            if self._strikes[h] >= self.strikes_to_evict:
                self._evicted.add(h)
            verdicts.append(
                Verdict(
                    host=h,
                    slow=slow,
                    strikes=int(self._strikes[h]),
                    evict=h in self._evicted,
                )
            )
        return verdicts

    def evictees(self) -> List[int]:
        """Hosts flagged for eviction, ascending."""
        return sorted(self._evicted)


def plan_mesh_shape(
    n_devices: int,
    model_parallel: int,
    prefer_pods: Optional[int] = None,
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest mesh shape fitting ``n_devices`` at a fixed model degree.

    The data axis absorbs device loss (``n // model_parallel`` rows); the
    model axis never shrinks — a model shard holds state no other host
    has.  With ``prefer_pods`` the result carries a leading pod axis when
    at least one full data row fits per pod.
    """
    if model_parallel < 1:
        raise ValueError("model_parallel must be >= 1")
    if n_devices < model_parallel:
        raise ValueError(
            f"{n_devices} devices cannot hold one model-parallel group of "
            f"{model_parallel}"
        )
    if prefer_pods and prefer_pods > 1:
        data = n_devices // (prefer_pods * model_parallel)
        if data >= 1:
            return (prefer_pods, data, model_parallel), ("pod", "data", "model")
    return (n_devices // model_parallel, model_parallel), ("data", "model")


class ElasticMesh:
    """Rebuilds the mesh from the currently-live devices.

    Every ``remesh()`` bumps ``epoch`` — the trainer uses the epoch to know
    its jit'd step (whose shardings bake in the old mesh) must be rebuilt
    and the pipeline resumed from the last checkpoint at the new world
    size.
    """

    def __init__(
        self, model_parallel: int = 1, prefer_pods: Optional[int] = None
    ):
        self.model_parallel = int(model_parallel)
        self.prefer_pods = prefer_pods
        self.epoch = 0
        self.mesh = None
        self._excluded_hosts: set = set()
        self._excluded_devices: set = set()
        self._pool: Optional[list] = None

    def exclude_host(self, process_index: int) -> None:
        """Drop a host (e.g. a StragglerMonitor evictee) from future meshes."""
        self._excluded_hosts.add(int(process_index))

    def exclude_device(self, device_id: int) -> None:
        """Drop one device from future meshes.  The device-granular
        analogue of :meth:`exclude_host` — on single-process test rigs
        (fake CPU devices) every device shares ``process_index`` 0, so
        serving-shard failover evicts by ``device.id`` instead."""
        self._excluded_devices.add(int(device_id))

    def remesh(self, devices: Optional[Sequence] = None):
        """Build the largest valid mesh from the live, non-excluded
        devices.  With no explicit ``devices`` the last remesh's pool is
        reused (falling back to ``jax.devices()``), so eviction followed
        by a bare ``remesh()`` shrinks the previous world."""
        import jax
        from jax.sharding import Mesh

        devices = list(
            devices
            if devices is not None
            else (self._pool if self._pool is not None else jax.devices())
        )
        self._pool = list(devices)
        devices = [
            d
            for d in devices
            if d.process_index not in self._excluded_hosts
            and d.id not in self._excluded_devices
        ]
        if not devices:
            raise NoDevicesError(
                f"all {len(self._pool)} pooled devices are excluded — "
                "no mesh can be built; serve on the host path"
            )
        shape, axes = plan_mesh_shape(
            len(devices), self.model_parallel, self.prefer_pods
        )
        n_used = int(np.prod(shape))
        self.mesh = Mesh(np.asarray(devices[:n_used]).reshape(shape), axes)
        self.epoch += 1
        return self.mesh
