"""The bucketed Lookup intersection of Sanders & Transier (ALENEX'07) —
the paper's reference algorithm [14], with exact work accounting.

Representation: the document-id universe [0, n) is divided into buckets of
width ``W = 2^w`` where ``w`` is chosen per posting list so the *average*
bucket occupancy is ``bucket_size`` (the paper uses 16 for the main index,
8 for the cluster index).  A directory array maps bucket id -> start offset
in the (sorted) list.  Intersection walks the shorter list and for each
element x probes the longer list's bucket ``x >> w``, scanning entries
until one >= x is found.

Work accounting (what the benchmarks report):

  * ``probes``  — one directory access per element of the shorter list
  * ``scanned`` — bucket entries examined until the first entry >= x
                  (the CPU algorithm's inner-loop iterations)

``Phi(x, y) = min(x, y)`` — the paper's objective — models exactly the
``probes`` term; ``scanned`` adds the data-dependent part that document
reordering (SeCluD §3.3, speedup S_R) improves.

Hardware adaptation note (DESIGN.md §3): on TPU the per-element scan
becomes a fixed-width vectorized compare against a 16-entry bucket tile;
the Pallas kernel in ``repro.kernels.intersect`` implements that layout.
This module is the exact scalar/numpy oracle for it.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = [
    "BucketedList",
    "bucketize",
    "lookup_intersect",
    "lookup_work",
    "chain_lookup",
    "cost_order",
    "adaptive_intersect",
]


@dataclasses.dataclass
class BucketedList:
    """A sorted posting list with a bucket directory."""

    values: np.ndarray  # (len,) sorted int32
    dir_ptr: np.ndarray  # (n_buckets + 1,) int64: bucket -> offset
    shift: int  # bucket width = 2**shift
    universe: int

    def __len__(self) -> int:
        return len(self.values)

    def bucket(self, b: int) -> np.ndarray:
        return self.values[self.dir_ptr[b] : self.dir_ptr[b + 1]]


def _pick_shift(universe: int, length: int, bucket_size: int) -> int:
    """Largest w with expected occupancy universe/2^w lists -> about
    ``bucket_size`` entries per bucket: 2^w ~ universe * B / len."""
    if length <= 0:
        return max(int(universe).bit_length(), 1)
    target = max(1.0, universe * bucket_size / length)
    return max(0, int(np.floor(np.log2(target))))


def bucketize(values: np.ndarray, universe: int, bucket_size: int = 16) -> BucketedList:
    """Build the bucket directory for a sorted list. O(len + n_buckets)."""
    values = np.asarray(values, dtype=np.int32)
    shift = _pick_shift(universe, len(values), bucket_size)
    n_buckets = (universe + (1 << shift) - 1) >> shift
    n_buckets = max(n_buckets, 1)
    # dir_ptr[b] = first index with value >= b << shift
    boundaries = (np.arange(n_buckets + 1, dtype=np.int64)) << shift
    dir_ptr = np.searchsorted(values, boundaries).astype(np.int64)
    return BucketedList(values=values, dir_ptr=dir_ptr, shift=shift, universe=universe)


def lookup_intersect(
    short: np.ndarray, long_b: BucketedList
) -> Tuple[np.ndarray, dict]:
    """Intersect ``short`` (sorted array) with a bucketized longer list.

    Work accounting models the actual C inner loop of [14]: the sorted
    short list is processed IN ORDER and the bucket scan pointer RESUMES —
    consecutive probes into the same bucket never rescan entries
    (``for x: while (ptr < hi && *ptr < x) ptr++``).  This resumability is
    precisely why cluster-contiguous reordering (S_R) pays off: in regions
    where both lists are dense the algorithm degenerates to a merge, and
    in regions where the long list is absent, probes cost ~nothing.

      * ``probes``  — one directory access + one loop-bound check per
                      element of the short list
      * ``scanned`` — pointer advances (entries examined)

    Fully vectorized and exact. Returns (result, work_dict).
    """
    short = np.asarray(short, dtype=np.int32)
    if len(short) == 0 or len(long_b) == 0:
        return np.empty(0, np.int32), {"probes": 0, "scanned": 0, "total": 0}
    b = short.astype(np.int64) >> long_b.shift
    b = np.clip(b, 0, len(long_b.dir_ptr) - 2)
    lo = long_b.dir_ptr[b]
    hi = long_b.dir_ptr[b + 1]
    pos = np.searchsorted(long_b.values, short)  # first entry >= x (global)
    stop = np.minimum(pos, hi)  # where the scan pointer ends for this probe
    # Resumable scan: within a run of probes sharing a bucket, the pointer
    # starts where the previous probe left it.
    start = lo.copy()
    if len(short) > 1:
        same = b[1:] == b[:-1]
        start[1:] = np.where(same, np.maximum(stop[:-1], lo[1:]), lo[1:])
    scanned = np.maximum(stop - start, 0)
    hit = (pos < hi) & (long_b.values[np.minimum(pos, len(long_b) - 1)] == short)
    work = {
        "probes": int(len(short)),
        "scanned": int(scanned.sum()),
        "total": int(len(short) + scanned.sum()),
    }
    return short[hit], work


def lookup_work(
    a: np.ndarray, b: np.ndarray, universe: int, bucket_size: int = 16
) -> Tuple[np.ndarray, dict]:
    """Convenience: bucketize the longer of (a, b) and intersect."""
    a = np.asarray(a)
    b = np.asarray(b)
    if len(a) > len(b):
        a, b = b, a
    return lookup_intersect(a, bucketize(b, universe, bucket_size))


def cost_order(lengths) -> list:
    """Cost-ordered plan: indices sorted by list length ascending, stable.

    Greedy-optimal under the paper's lookup model Φ(x, y) = min(x, y):
    the running intersection (always the shortest operand) probes each
    remaining list, cheapest first.  Ties keep the caller's order, so the
    2-term plan equals the historical "first term probes when lengths
    tie" behavior.
    """
    return sorted(range(len(lengths)), key=lambda i: lengths[i])


def chain_lookup(
    lists, universe: int, bucket_size: int = 16
) -> Tuple[np.ndarray, float]:
    """Cost-ordered Lookup chain over k >= 1 sorted lists.

    THE single definition of the per-query conjunctive Lookup semantics:
    the running intersection probes each remaining bucketized list,
    smallest-first (k = 2: the shorter list probes the longer — the
    historical loop).  Returns ``(result, total work)``; a single list
    costs nothing (no intersection happens).  ``repro.core.batched_query.
    batched_lookup`` is its vectorized bit-exact mirror.
    """
    order = cost_order([len(x) for x in lists])
    cur = np.asarray(lists[order[0]])
    total = 0.0
    for i in order[1:]:
        cur, w = lookup_intersect(cur, bucketize(lists[i], universe, bucket_size))
        total += w["total"]
    return cur, total


def adaptive_intersect(
    a: np.ndarray, b: np.ndarray, universe: int, bucket_size: int = 16
) -> Tuple[np.ndarray, dict]:
    """The paper's §6 future-work item: a *symmetric* Lookup that probes
    from whichever list is locally sparser ("when a lookup finds an empty
    bucket, we might switch to the other list").

    Realized block-wise: the universe is cut at the bucket boundaries of
    the longer list; within each region the locally SHORTER side probes
    the locally longer side (regions where either side is empty cost
    nothing).  Exact results; work accounted like ``lookup_intersect``.
    Beyond-paper (EXPERIMENTS.md §Perf-SeCluD).
    """
    a = np.asarray(a, dtype=np.int32)
    b = np.asarray(b, dtype=np.int32)
    if len(a) == 0 or len(b) == 0:
        return np.empty(0, np.int32), {"probes": 0, "scanned": 0, "total": 0}
    if len(a) > len(b):
        a, b = b, a
    blong = bucketize(b, universe, bucket_size)
    # Region = run of consecutive probes of `a` into the same bucket.
    bucket_of_a = np.clip(a.astype(np.int64) >> blong.shift, 0, len(blong.dir_ptr) - 2)
    region_start = np.flatnonzero(
        np.concatenate([[True], bucket_of_a[1:] != bucket_of_a[:-1]])
    )
    region_end = np.append(region_start[1:], len(a))
    probes = scanned = 0
    out = []
    for rs, re_ in zip(region_start, region_end, strict=True):
        bu = int(bucket_of_a[rs])
        lo, hi = int(blong.dir_ptr[bu]), int(blong.dir_ptr[bu + 1])
        n_a, n_b = int(re_ - rs), hi - lo
        if n_b == 0:
            probes += 1  # one directory check rules the region out
            continue
        short, long_ = (a[rs:re_], b[lo:hi]) if n_a <= n_b else (b[lo:hi], a[rs:re_])
        pos = np.searchsorted(long_, short)
        stop = np.minimum(pos, len(long_))
        start = np.zeros_like(stop)
        start[1:] = np.maximum(stop[:-1], 0)
        scanned += int(np.maximum(stop - start, 0).sum())
        probes += len(short)
        hit = (pos < len(long_)) & (long_[np.minimum(pos, len(long_) - 1)] == short)
        if hit.any():
            out.append(short[hit])
    res = np.concatenate(out).astype(np.int32) if out else np.empty(0, np.int32)
    return np.sort(res), {
        "probes": probes,
        "scanned": scanned,
        "total": probes + scanned,
    }
