"""Inverted-index construction.

The index is CSR over terms:

  * ``post_ptr``  -- int64 (n_terms + 1,)
  * ``post_docs`` -- int32 (nnz,); ``post_docs[post_ptr[t]:post_ptr[t+1]]``
    is the sorted posting list (document ids) of term t.

Building is a single stable counting sort of the corpus' (term, doc)
pairs — O(nnz), fully vectorized.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.data.corpus import Corpus

__all__ = ["InvertedIndex", "build_index", "permute_docs"]


@dataclasses.dataclass
class InvertedIndex:
    post_ptr: np.ndarray  # (n_terms + 1,) int64
    post_docs: np.ndarray  # (nnz,) int32, sorted within each term
    n_docs: int

    @property
    def n_terms(self) -> int:
        return len(self.post_ptr) - 1

    @property
    def nnz(self) -> int:
        return int(self.post_ptr[-1])

    def postings(self, t: int) -> np.ndarray:
        return self.post_docs[self.post_ptr[t] : self.post_ptr[t + 1]]

    def lengths(self) -> np.ndarray:
        return np.diff(self.post_ptr)

    def size_bytes(self) -> int:
        """Uncompressed int32 posting payload (paper Table 1's 'index size')."""
        return self.nnz * 4


def build_index(corpus: Corpus) -> InvertedIndex:
    """Invert a CSR corpus. O(nnz) via counting sort."""
    n, m = corpus.n_docs, corpus.n_terms
    terms = corpus.doc_terms.astype(np.int64)
    docs = np.repeat(np.arange(n, dtype=np.int64), np.diff(corpus.doc_ptr))
    # Stable sort by term keeps docs sorted within each term (docs are
    # visited in increasing order already).
    order = np.argsort(terms, kind="stable")
    post_docs = docs[order].astype(np.int32)
    counts = np.bincount(terms, minlength=m)
    post_ptr = np.zeros(m + 1, dtype=np.int64)
    np.cumsum(counts, out=post_ptr[1:])
    return InvertedIndex(post_ptr=post_ptr, post_docs=post_docs, n_docs=n)


def permute_docs(index: InvertedIndex, perm: np.ndarray) -> InvertedIndex:
    """Renumber documents: new_id = perm[old_id]; posting lists re-sorted.

    Used both for the randomization required by the Lookup algorithm [14]
    (uniform ids) and for SeCluD's cluster-contiguous reordering (§3.3).
    O(nnz log max_list) via per-list sorts done as one segmented sort.
    """
    new_docs = perm.astype(np.int32)[index.post_docs]
    # Segmented re-sort: sort by (term_segment, new_doc).
    seg = np.repeat(
        np.arange(index.n_terms, dtype=np.int64), np.diff(index.post_ptr)
    )
    order = np.lexsort((new_docs, seg))
    return InvertedIndex(
        post_ptr=index.post_ptr.copy(),
        post_docs=new_docs[order],
        n_docs=index.n_docs,
    )
