"""Posting-list compression (paper Appendix A).

Posting lists are stored as gaps ``g_i = d_i - d_{i-1}`` (g_0 = d_0 + 1,
all gaps >= 1) and the gaps entropy-coded.  The paper compares Golomb
coding (best WITHOUT clustering) against Elias-gamma/delta (best WITH
clustering, because they adapt to the locally varying gap distribution
that cluster-contiguous reordering creates).

We implement bit-exact encoders/decoders (for tests) plus fast
vectorized bit-counting (for the Figure-8 benchmark, which only needs
sizes).

Codes
-----
* unary(q):        q ones then a zero                  -> q + 1 bits
* Elias-gamma(g):  floor(log2 g) zeros, then g         -> 2*floor(log2 g) + 1
* Elias-delta(g):  gamma(floor(log2 g)+1) then g's low -> log g + 2 log log g + O(1)
* Golomb(g; b):    unary((g-1) // b) + truncated-binary remainder
  with the Gallager–van Voorhis optimal b from the list density.
* varbyte:         7 data bits / byte, MSB continuation.
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, Tuple

import numpy as np

__all__ = [
    "gaps_of",
    "posting_bits",
    "index_bits_per_posting",
    "encode_gaps",
    "decode_gaps",
    "golomb_parameter",
]


def gaps_of(postings: np.ndarray) -> np.ndarray:
    """Doc-id list -> gap list (all >= 1)."""
    postings = np.asarray(postings, dtype=np.int64)
    if len(postings) == 0:
        return postings
    g = np.empty_like(postings)
    g[0] = postings[0] + 1
    np.subtract(postings[1:], postings[:-1], out=g[1:])
    if (g <= 0).any():
        raise ValueError("postings must be strictly increasing")
    return g


def golomb_parameter(n_docs: int, list_len: int) -> int:
    """Gallager–van Voorhis optimal Golomb parameter for a Bernoulli gap
    model with density p = list_len / n_docs:  b = ceil(log(2-p)/-log(1-p)),
    commonly approximated b ~ 0.69 * mean_gap."""
    if list_len <= 0:
        return 1
    p = min(list_len / max(n_docs, 1), 1 - 1e-12)
    if p <= 1e-12:
        return max(1, int(0.69 * n_docs))
    return max(1, int(math.ceil(math.log(2.0 - p) / -math.log(1.0 - p))))


# ---------------------------------------------------------------------------
# Bit counting (vectorized; used by benchmarks)
# ---------------------------------------------------------------------------


def _floor_log2(g: np.ndarray) -> np.ndarray:
    return np.frexp(g.astype(np.float64))[1] - 1  # exact for g < 2^52


def _gamma_bits(g: np.ndarray) -> np.ndarray:
    return 2 * _floor_log2(g) + 1


def _delta_bits(g: np.ndarray) -> np.ndarray:
    L = _floor_log2(g)
    return L + _gamma_bits(L + 1)


def _golomb_bits(g: np.ndarray, b: int) -> np.ndarray:
    q = (g - 1) // b
    # truncated binary: ceil(log2 b) bits for small remainders else floor+1
    k = int(math.ceil(math.log2(b))) if b > 1 else 0
    cut = (1 << k) - b  # remainders < cut use k-1 bits
    r = (g - 1) % b
    rbits = np.where(r < cut, max(k - 1, 0), k) if b > 1 else 0
    return q + 1 + rbits


def _varbyte_bits(g: np.ndarray) -> np.ndarray:
    nbytes = np.maximum(1, (_floor_log2(g) + 7) // 7)
    return 8 * nbytes


def posting_bits(postings: np.ndarray, n_docs: int, code: str) -> int:
    """Exact encoded size in bits of one posting list under ``code``."""
    if len(postings) == 0:
        return 0
    g = gaps_of(postings)
    if code == "gamma":
        return int(_gamma_bits(g).sum())
    if code == "delta":
        return int(_delta_bits(g).sum())
    if code == "golomb":
        return int(_golomb_bits(g, golomb_parameter(n_docs, len(postings))).sum())
    if code == "varbyte":
        return int(_varbyte_bits(g).sum())
    if code == "raw":
        return 32 * len(postings)
    raise ValueError(f"unknown code {code!r}")


def index_bits_per_posting(index, codes: Iterable[str] = ("golomb", "gamma", "delta", "varbyte")) -> Dict[str, float]:
    """Average bits per posting over a whole InvertedIndex (Figure 8)."""
    lens = np.diff(index.post_ptr)
    out: Dict[str, float] = {}
    for code in codes:
        total = 0
        for t in np.flatnonzero(lens):
            total += posting_bits(index.postings(int(t)), index.n_docs, code)
        out[code] = total / max(int(lens.sum()), 1)
    return out


# ---------------------------------------------------------------------------
# Bit-exact encode/decode (tests prove losslessness)
# ---------------------------------------------------------------------------


class _BitWriter:
    def __init__(self):
        self.bits: list[int] = []

    def write(self, value: int, nbits: int) -> None:
        for i in range(nbits - 1, -1, -1):
            self.bits.append((value >> i) & 1)

    def write_unary(self, q: int) -> None:
        self.bits.extend([1] * q)
        self.bits.append(0)

    def pack(self) -> np.ndarray:
        return np.packbits(np.asarray(self.bits, dtype=np.uint8))


class _BitReader:
    def __init__(self, packed: np.ndarray, nbits: int):
        self.bits = np.unpackbits(packed)[:nbits]
        self.pos = 0

    def read(self, nbits: int) -> int:
        v = 0
        for _ in range(nbits):
            v = (v << 1) | int(self.bits[self.pos])
            self.pos += 1
        return v

    def read_unary(self) -> int:
        q = 0
        while self.bits[self.pos] == 1:
            q += 1
            self.pos += 1
        self.pos += 1
        return q


def encode_gaps(gaps: np.ndarray, code: str, b: int | None = None) -> Tuple[np.ndarray, int]:
    """Encode gaps; returns (packed uint8 array, total bits)."""
    w = _BitWriter()
    for g in np.asarray(gaps, dtype=np.int64):
        g = int(g)
        if code == "gamma":
            L = g.bit_length() - 1
            w.write_unary(L)
            w.write(g - (1 << L), L)
        elif code == "delta":
            L = g.bit_length() - 1
            LL = (L + 1).bit_length() - 1
            w.write_unary(LL)
            w.write((L + 1) - (1 << LL), LL)
            w.write(g - (1 << L), L)
        elif code == "golomb":
            assert b is not None and b >= 1
            q, r = divmod(g - 1, b)
            w.write_unary(q)
            if b > 1:
                k = int(math.ceil(math.log2(b)))
                cut = (1 << k) - b
                if r < cut:
                    w.write(r, k - 1)
                else:
                    w.write(r + cut, k)
        elif code == "varbyte":
            chunks = []
            v = g
            while True:
                chunks.append(v & 0x7F)
                v >>= 7
                if v == 0:
                    break
            for i, c in enumerate(reversed(chunks)):
                cont = 0x80 if i < len(chunks) - 1 else 0
                w.write(cont | c, 8)
        else:
            raise ValueError(code)
    packed = w.pack()
    return packed, len(w.bits)


def decode_gaps(packed: np.ndarray, nbits: int, n: int, code: str, b: int | None = None) -> np.ndarray:
    """Inverse of encode_gaps (n gaps)."""
    r = _BitReader(packed, nbits)
    out = np.empty(n, dtype=np.int64)
    for i in range(n):
        if code == "gamma":
            L = r.read_unary()
            out[i] = (1 << L) | r.read(L)
        elif code == "delta":
            LL = r.read_unary()
            L = ((1 << LL) | r.read(LL)) - 1
            out[i] = (1 << L) | r.read(L)
        elif code == "golomb":
            assert b is not None and b >= 1
            q = r.read_unary()
            rem = 0
            if b > 1:
                k = int(math.ceil(math.log2(b)))
                cut = (1 << k) - b
                rem = r.read(k - 1)
                if rem >= cut:
                    rem = ((rem << 1) | r.read(1)) - cut
            out[i] = q * b + rem + 1
        elif code == "varbyte":
            v = 0
            while True:
                byte = r.read(8)
                v = (v << 7) | (byte & 0x7F)
                if not byte & 0x80:
                    break
            out[i] = v
        else:
            raise ValueError(code)
    return out
