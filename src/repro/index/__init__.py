"""Inverted-index substrate.

* ``build``      — CSR inverted index over a Corpus, remapping, permutation
* ``intersect``  — intersection algorithms + exact work accounting
* ``lookup``     — the bucketed Lookup algorithm of Sanders & Transier
                   (ALENEX'07), the paper's reference intersector [14]
* ``batched``    — padded, fixed-shape batched query layouts for JAX/Pallas
* ``compress``   — Golomb / Elias-gamma / Elias-delta / varbyte posting-list
                   compression (paper Appendix A)
"""

from repro.index.build import InvertedIndex, build_index, permute_docs
from repro.index.intersect import (
    COST_MODELS,
    intersect_merge,
    intersect_searchsorted,
    intersect_gallop,
    pair_cost,
)
from repro.index.lookup import BucketedList, bucketize, lookup_intersect
from repro.index.batched import BatchedQueries, batch_queries
from repro.index.compress import (
    encode_gaps,
    decode_gaps,
    posting_bits,
    index_bits_per_posting,
)

__all__ = [
    "InvertedIndex",
    "build_index",
    "permute_docs",
    "COST_MODELS",
    "intersect_merge",
    "intersect_searchsorted",
    "intersect_gallop",
    "pair_cost",
    "BucketedList",
    "bucketize",
    "lookup_intersect",
    "BatchedQueries",
    "batch_queries",
    "encode_gaps",
    "decode_gaps",
    "posting_bits",
    "index_bits_per_posting",
]
