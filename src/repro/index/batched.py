"""Fixed-shape batched query layouts for JAX execution.

JAX (and the TPU) want static shapes; posting lists are ragged.  The
standard resolution — used by every production ragged workload on TPU —
is *length-bucketed padding*: queries are binned by the pow2-rounded
lengths of their (shorter, longer) posting lists and each bin is padded
to its bucket maximum.  Padding waste is bounded by 2x per axis and is
measured (reported by benchmarks) rather than assumed.

The per-bin intersection (`count_intersections_jnp`) is the pure-jnp
production path; ``repro.kernels.intersect`` provides the Pallas TPU
kernel with the same contract.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.index.build import InvertedIndex
from repro.kernels.intersect.ref import PAD as _PAD, intersect_count_ref

__all__ = ["BatchedQueries", "batch_queries", "count_intersections_jnp", "pow2_buckets"]

# The intersect oracle lives in ONE place — repro.kernels.intersect.ref —
# so the kernel contract (PAD value, sortedness, int32 counts) can't
# drift between the production jnp path and the Pallas kernel's oracle.
count_intersections_jnp = intersect_count_ref


@dataclasses.dataclass
class QueryBin:
    """One (short_len_bucket, long_len_bucket) bin of padded queries."""

    short: np.ndarray  # (B, Ls) int32, PAD-padded, each row sorted
    long: np.ndarray  # (B, Ll) int32, PAD-padded, each row sorted
    n_short: np.ndarray  # (B,) true lengths
    n_long: np.ndarray  # (B,)
    query_ids: np.ndarray  # (B,) position in the original query array


@dataclasses.dataclass
class BatchedQueries:
    bins: List[QueryBin]
    n_queries: int

    def padding_overhead(self) -> float:
        """Padded cells / true cells — the fixed-shape tax we pay."""
        true = padded = 0
        for b in self.bins:
            true += int(b.n_short.sum() + b.n_long.sum())
            padded += b.short.size + b.long.size
        return padded / max(true, 1)


def pow2_buckets(n: np.ndarray, min_exp: int = 2) -> np.ndarray:
    """Pow2-rounded length buckets ``1 << max(bit_length(n - 1), min_exp)``
    (0 -> ``1 << min_exp``), vectorized.  The single definition of the
    length-bucket contract — ``repro.core.batched_query`` bins with it too."""
    n = np.asarray(n, np.int64)
    m = np.maximum(n - 1, 0)
    e = np.zeros(len(n), np.int64)
    while (m > 0).any():
        e += m > 0
        m >>= 1
    return (np.int64(1) << np.maximum(e, min_exp)).astype(np.int64)


def batch_queries(
    index: InvertedIndex,
    queries: np.ndarray,
    max_list_len: int | None = None,
) -> BatchedQueries:
    """Gather + pad posting lists for an (n_queries, 2) term-pair array.

    Lists longer than ``max_list_len`` are truncated (None = no limit);
    benchmarks keep None so results stay exact.
    """
    lens = index.lengths()
    t, u = queries[:, 0], queries[:, 1]
    lt, lu = lens[t], lens[u]
    short_t = np.where(lt <= lu, t, u)
    long_t = np.where(lt <= lu, u, t)
    ls = np.minimum(lt, lu)
    ll = np.maximum(lt, lu)
    if max_list_len is not None:
        ls = np.minimum(ls, max_list_len)
        ll = np.minimum(ll, max_list_len)

    keys = list(zip(pow2_buckets(ls).tolist(), pow2_buckets(ll).tolist(), strict=True))
    groups: Dict[Tuple[int, int], List[int]] = {}
    for i, k in enumerate(keys):
        groups.setdefault(k, []).append(i)

    bins = []
    for (bs, bl), idxs in sorted(groups.items()):
        idxs = np.asarray(idxs)
        B = len(idxs)
        sh = np.full((B, bs), _PAD, dtype=np.int32)
        lg = np.full((B, bl), _PAD, dtype=np.int32)
        for r, qi in enumerate(idxs):
            ps = index.postings(int(short_t[qi]))[: int(ls[qi])]
            pl = index.postings(int(long_t[qi]))[: int(ll[qi])]
            sh[r, : len(ps)] = ps
            lg[r, : len(pl)] = pl
        bins.append(
            QueryBin(
                short=sh,
                long=lg,
                n_short=ls[idxs].astype(np.int32),
                n_long=ll[idxs].astype(np.int32),
                query_ids=idxs.astype(np.int32),
            )
        )
    return BatchedQueries(bins=bins, n_queries=len(queries))
