"""Sorted-list intersection algorithms with exact work accounting.

The paper's analysis counts algorithm *steps*: ``Phi(x, y) = min(x, y)``
for the Lookup algorithm [14] and ``Phi(x, y) = x log(y/x)`` (x > y
swapped) for an asymptotically optimal comparison-based intersector
(Baeza-Yates [1], paper Appendix B).  This module provides

  * reference intersections (merge / vectorized binary search / galloping),
  * each returning ``(result, work)`` where ``work`` counts the
    comparisons/probes actually performed, and
  * the closed-form cost models used by the clustering objective.

All functions take sorted 1-D int arrays.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np

__all__ = [
    "intersect_merge",
    "intersect_searchsorted",
    "intersect_gallop",
    "pair_cost",
    "COST_MODELS",
]


def _phi_min(x, y):
    """Lookup-algorithm cost model (paper's default objective)."""
    return np.minimum(x, y)


def _phi_sum(x, y):
    """Two-pointer merge cost model."""
    return x + y


def _phi_bs(x, y):
    """Per-element binary search of the shorter into the longer list."""
    lo = np.minimum(x, y).astype(np.float64)
    hi = np.maximum(x, y).astype(np.float64)
    return lo * np.ceil(np.log2(np.maximum(hi, 2.0)))


def _phi_cmp(x, y):
    """Baeza-Yates comparison-based model (paper Appendix B).

    The paper writes Phi(x,y) = x·log(y/x) for x > y; symmetrized here as
    min·log2(max/min + 1), floored at min(x,y) and 0 for empty lists.
    """
    lo = np.minimum(x, y).astype(np.float64)
    hi = np.maximum(x, y).astype(np.float64)
    out = np.zeros_like(lo, dtype=np.float64)
    nz = lo > 0
    out[nz] = np.maximum(lo[nz], lo[nz] * np.log2(hi[nz] / lo[nz] + 1.0))
    return out


COST_MODELS: Dict[str, Callable] = {
    "lookup": _phi_min,  # Phi = min(x, y)            -- paper Eq. objective
    "merge": _phi_sum,  # Phi = x + y
    "binary_search": _phi_bs,  # Phi = min * ceil(log2 max)
    "comparison": _phi_cmp,  # Phi = min * log2(max/min + 1)  -- App. B
}


def pair_cost(x, y, model: str = "lookup"):
    """Vectorized Phi(x, y) under a named cost model."""
    return COST_MODELS[model](np.asarray(x), np.asarray(y))


def intersect_merge(a: np.ndarray, b: np.ndarray) -> Tuple[np.ndarray, int]:
    """Two-pointer merge intersection. work = pointer advances."""
    i = j = 0
    out = []
    work = 0
    na, nb = len(a), len(b)
    while i < na and j < nb:
        work += 1
        if a[i] < b[j]:
            i += 1
        elif a[i] > b[j]:
            j += 1
        else:
            out.append(a[i])
            i += 1
            j += 1
    return np.asarray(out, dtype=a.dtype), work


def intersect_searchsorted(a: np.ndarray, b: np.ndarray) -> Tuple[np.ndarray, float]:
    """Vectorized: binary-search each element of the shorter list into the
    longer. work = min * ceil(log2 max) probe count. This is the pattern
    the Pallas intersect kernel vectorizes on TPU."""
    if len(a) > len(b):
        a, b = b, a
    if len(a) == 0 or len(b) == 0:
        return np.empty(0, dtype=a.dtype), 0.0
    pos = np.searchsorted(b, a)
    hit = (pos < len(b)) & (b[np.minimum(pos, len(b) - 1)] == a)
    work = float(len(a) * max(1, int(np.ceil(np.log2(max(len(b), 2))))))
    return a[hit], work


def intersect_gallop(a: np.ndarray, b: np.ndarray) -> Tuple[np.ndarray, int]:
    """Galloping (exponential) search intersection — adaptive, O(min·log
    gap). work = comparisons performed. Scalar reference implementation."""
    if len(a) > len(b):
        a, b = b, a
    out = []
    work = 0
    j = 0
    nb = len(b)
    for x in a:
        # Gallop from j.
        step = 1
        lo = j
        while j + step < nb and b[j + step] < x:
            work += 1
            lo = j + step
            step <<= 1
        hi = min(j + step, nb - 1)
        work += 1
        # Binary search in (lo, hi].
        left, right = lo, hi
        while left < right:
            work += 1
            mid = (left + right) // 2
            if b[mid] < x:
                left = mid + 1
            else:
                right = mid
        j = left
        if j < nb and b[j] == x:
            out.append(x)
            j += 1
        if j >= nb:
            break
    return np.asarray(out, dtype=a.dtype), work
