"""Batched two-level query engine — the per-query Python loops, vectorized.

The paper's speedups were measured by looping queries one at a time in
interpreted numpy (``SecludPipeline.evaluate``, ``ClusterIndex.query``,
``SearchService.serve_counts``).  This module executes a whole
``(n_queries, 2)`` array at once, in three layers:

* ``_lookup_many`` — one vectorized pass that replicates
  ``lookup_intersect(short, bucketize(long, universe, B))`` *bit-exactly*
  (results, ``probes`` and ``scanned``) for many (short, long) pairs:
  per-pair arrays are keyed as ``pair * BASE + value`` so a single global
  ``searchsorted`` answers every per-pair directory probe at once.

* planning — ``plan_segment_pairs`` intersects the cluster lists of both
  query terms for the whole batch (CSR set-intersection, no Python
  per-query loop), yielding every (query, common-cluster) posting-segment
  pair plus the level-1 work accounting of ``ClusterIndex.query``.

* execution — either the host path ``batched_query`` (exact doc ids +
  the work dict of ``ClusterIndex.query``, summed), or the device path
  ``batched_counts``: segment pairs are length-bucketed and padded like
  ``repro.index.batched``, every bin runs through the batched intersect
  kernel (Pallas on TPU, jnp elsewhere), and a segment-sum maps per-pair
  counts back to per-query counts.

Exactness guarantee: ``batched_query`` returns, for every query, the
identical (sorted) result array and the identical work totals as calling
``ClusterIndex.query`` in a loop; ``batched_counts`` returns the identical
per-query counts.  ``batched_lookup`` does the same for the single-index
Lookup loop (the baseline / S_R paths of ``SecludPipeline.evaluate``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.index.batched import pow2_buckets
from repro.kernels.intersect.ref import PAD

__all__ = [
    "SegmentPlan",
    "plan_segment_pairs",
    "batched_query",
    "batched_counts",
    "batched_lookup",
    "gather_padded",
    "pow2_buckets",
]


# ----------------------------------------------------------------------
# Ragged helpers
# ----------------------------------------------------------------------


def _ragged_indices(lengths: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(row id, offset within row) of every cell of a ragged row layout."""
    rows = np.repeat(np.arange(len(lengths)), lengths)
    within = np.arange(int(lengths.sum())) - (np.cumsum(lengths) - lengths)[rows]
    return rows, within


def _ragged_gather(values: np.ndarray, starts: np.ndarray, lengths: np.ndarray) -> np.ndarray:
    """Concatenate ``values[starts[i] : starts[i] + lengths[i]]`` for all i."""
    if int(lengths.sum()) == 0:
        return np.empty(0, values.dtype)
    rows, within = _ragged_indices(lengths)
    return values[starts[rows] + within]


def gather_padded(
    values: np.ndarray,
    starts: np.ndarray,
    lengths: np.ndarray,
    width: int,
    fill: np.int32 = PAD,
) -> np.ndarray:
    """Gather ragged slices into a PAD-padded ``(len(starts), width)`` int32
    block without a per-row Python loop."""
    out = np.full((len(starts), width), fill, np.int32)
    if int(lengths.sum()):
        rows, within = _ragged_indices(lengths)
        out[rows, within] = values[starts[rows] + within]
    return out


# ----------------------------------------------------------------------
# The vectorized Lookup primitive
# ----------------------------------------------------------------------


def _lookup_many(
    short_vals: np.ndarray,
    short_ptr: np.ndarray,
    long_vals: np.ndarray,
    long_ptr: np.ndarray,
    universes: np.ndarray,
    bucket_size: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized ``lookup_intersect(short_p, bucketize(long_p, U_p, B))``
    over P pairs at once.

    ``short_vals`` / ``long_vals`` are the per-pair sorted arrays
    concatenated in pair order (values in ``[0, U_p)``); ``*_ptr`` are the
    (P + 1,) CSR offsets.  Returns ``(hit, probes, scanned, pos)`` where
    ``hit`` masks ``short_vals`` (matched elements), ``probes`` / ``scanned``
    are per-pair int64 work counts bit-identical to looping
    ``repro.index.lookup.lookup_intersect``, and ``pos`` is the global index
    into ``long_vals`` of each short element's match candidate (valid where
    ``hit``).
    """
    n_pairs = len(universes)
    short_len = np.diff(short_ptr)
    long_len = np.diff(long_ptr)
    n_short = len(short_vals)
    if n_pairs == 0 or n_short == 0:
        return (
            np.zeros(n_short, bool),
            np.zeros(n_pairs, np.int64),
            np.zeros(n_pairs, np.int64),
            np.zeros(n_short, np.int64),
        )
    universes = universes.astype(np.int64)
    # Per-pair bucket shift, exactly `_pick_shift` (only consumed when the
    # long side is non-empty; empty pairs cost nothing below).
    target = np.maximum(
        1.0, universes * float(bucket_size) / np.maximum(long_len, 1)
    )
    shift = np.maximum(np.floor(np.log2(target)).astype(np.int64), 0)
    n_buckets = np.maximum(
        (universes + (np.int64(1) << shift) - 1) >> shift, 1
    )
    # Key space: pair * BASE + value.  BASE exceeds every in-pair key —
    # values (< U) and bucket boundaries (<= n_buckets << shift) — so keyed
    # arrays stay globally sorted and probes never cross pair boundaries.
    base = int((n_buckets << shift).max()) + 1

    pair_s = np.repeat(np.arange(n_pairs, dtype=np.int64), short_len)
    keyed_long = (
        np.repeat(np.arange(n_pairs, dtype=np.int64), long_len) * base
        + long_vals.astype(np.int64)
    )
    x = short_vals.astype(np.int64)
    sh = shift[pair_s]
    b = np.clip(x >> sh, 0, n_buckets[pair_s] - 1)
    key0 = pair_s * base
    lo = np.searchsorted(keyed_long, key0 + (b << sh))
    hi = np.searchsorted(keyed_long, key0 + ((b + 1) << sh))
    pos = np.searchsorted(keyed_long, key0 + x)
    stop = np.minimum(pos, hi)
    # Resumable scan: within a run of probes sharing (pair, bucket) the
    # pointer starts where the previous probe left it.
    start = lo.copy()
    if n_short > 1:
        same = (b[1:] == b[:-1]) & (pair_s[1:] == pair_s[:-1])
        start[1:] = np.where(same, np.maximum(stop[:-1], lo[1:]), lo[1:])
    scanned_el = np.maximum(stop - start, 0)
    if len(keyed_long):
        hit = (pos < hi) & (
            keyed_long[np.minimum(pos, len(keyed_long) - 1)] == key0 + x
        )
    else:
        hit = np.zeros(n_short, bool)
    # lookup_intersect charges zero work when either side is empty.
    probes = np.where(long_len > 0, short_len, 0).astype(np.int64)
    scanned = np.zeros(n_pairs, np.int64)
    np.add.at(scanned, pair_s, scanned_el)
    return hit, probes, scanned, pos


# ----------------------------------------------------------------------
# Planning: all (query, common-cluster) segment pairs in one shot
# ----------------------------------------------------------------------


@dataclasses.dataclass
class SegmentPlan:
    """Every (query, common-cluster) posting-segment pair of a batch,
    ordered by (query, cluster) — the order ``ClusterIndex.query`` emits.

    ``short_*`` / ``long_*`` are absolute slices into
    ``cluster_index.index.post_docs`` with the shorter segment on the
    short side (ties keep the first query term short, like ``query``).
    """

    pair_query: np.ndarray  # (P,) int64 — query id of each segment pair
    cluster: np.ndarray  # (P,) int64 — common cluster id
    short_start: np.ndarray  # (P,) int64
    short_len: np.ndarray  # (P,) int64
    long_start: np.ndarray  # (P,) int64
    long_len: np.ndarray  # (P,) int64
    base: np.ndarray  # (P,) int64 — ranges[cluster]
    width: np.ndarray  # (P,) int64 — cluster width (level-2 universe)
    cluster_work: np.ndarray  # (n_queries,) int64 — level-1 lookup work
    n_queries: int

    @property
    def n_pairs(self) -> int:
        return len(self.pair_query)


def plan_segment_pairs(cidx, queries: np.ndarray) -> SegmentPlan:
    """Vectorized level 1 of the two-level query for a whole batch.

    CSR set-intersection of the two terms' cluster lists via keyed
    ``searchsorted`` — no Python per-query loop — with the same shorter-
    side probing (and work accounting) as ``ClusterIndex.query``.
    """
    q = np.asarray(queries, np.int64).reshape(-1, 2)
    n = len(q)
    t, u = q[:, 0], q[:, 1]
    len_t = cidx.cl_ptr[t + 1] - cidx.cl_ptr[t]
    len_u = cidx.cl_ptr[u + 1] - cidx.cl_ptr[u]
    t_short = len_t <= len_u
    s_off = np.where(t_short, cidx.cl_ptr[t], cidx.cl_ptr[u])
    s_len = np.where(t_short, len_t, len_u)
    l_off = np.where(t_short, cidx.cl_ptr[u], cidx.cl_ptr[t])
    l_len = np.where(t_short, len_u, len_t)
    short_ptr = np.concatenate([[0], np.cumsum(s_len)])
    long_ptr = np.concatenate([[0], np.cumsum(l_len)])
    cl64 = cidx.cl_ids.astype(np.int64)
    short_cl = _ragged_gather(cl64, s_off, s_len)
    long_cl = _ragged_gather(cl64, l_off, l_len)
    hit, probes, scanned, pos = _lookup_many(
        short_cl,
        short_ptr,
        long_cl,
        long_ptr,
        np.full(n, cidx.k, np.int64),
        cidx.bucket_size_clusters,
    )
    pair_s = np.repeat(np.arange(n, dtype=np.int64), s_len)
    within = np.arange(len(short_cl)) - (np.cumsum(s_len) - s_len)[pair_s]
    rows = pair_s[hit]
    i_short = s_off[rows] + within[hit]  # CSR position on the short term
    i_long = l_off[rows] + (pos[hit] - long_ptr[rows])
    it = np.where(t_short[rows], i_short, i_long)
    iu = np.where(t_short[rows], i_long, i_short)
    cluster = cl64[it]
    st, et = cidx.seg_start[it], cidx.seg_end[it]
    su, eu = cidx.seg_start[iu], cidx.seg_end[iu]
    lt2, lu2 = et - st, eu - su
    t_short2 = lt2 <= lu2  # query keeps seg_t short on ties
    return SegmentPlan(
        pair_query=rows,
        cluster=cluster,
        short_start=np.where(t_short2, st, su),
        short_len=np.where(t_short2, lt2, lu2),
        long_start=np.where(t_short2, su, st),
        long_len=np.where(t_short2, lu2, lt2),
        base=cidx.ranges[cluster],
        width=cidx.ranges[cluster + 1] - cidx.ranges[cluster],
        cluster_work=probes + scanned,
        n_queries=n,
    )


# ----------------------------------------------------------------------
# Host execution: exact doc ids + exact work accounting
# ----------------------------------------------------------------------


def batched_query(
    cidx, queries: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, Dict[str, float]]:
    """The whole two-level query batch on the host, exactly.

    Returns ``(ptr, docs, work)``: ``docs[ptr[i] : ptr[i + 1]]`` is
    bit-identical to ``cidx.query(*queries[i])[0]`` and ``work`` holds the
    summed per-query work dict of the loop.
    """
    plan = plan_segment_pairs(cidx, queries)
    docs_arr = cidx.index.post_docs.astype(np.int64)
    pair_s = np.repeat(np.arange(plan.n_pairs, dtype=np.int64), plan.short_len)
    rel_short = _ragged_gather(docs_arr, plan.short_start, plan.short_len) - plan.base[pair_s]
    rel_long = (
        _ragged_gather(docs_arr, plan.long_start, plan.long_len)
        - plan.base[np.repeat(np.arange(plan.n_pairs, dtype=np.int64), plan.long_len)]
    )
    hit, probes, scanned, _ = _lookup_many(
        rel_short,
        np.concatenate([[0], np.cumsum(plan.short_len)]),
        rel_long,
        np.concatenate([[0], np.cumsum(plan.long_len)]),
        np.maximum(plan.width, 1),
        cidx.bucket_size_postings,
    )
    docs = (rel_short[hit] + plan.base[pair_s[hit]]).astype(np.int32)
    counts = np.bincount(
        plan.pair_query[pair_s[hit]], minlength=plan.n_queries
    )
    ptr = np.zeros(plan.n_queries + 1, np.int64)
    np.cumsum(counts, out=ptr[1:])
    cluster_level = int(plan.cluster_work.sum())
    p_tot, s_tot = int(probes.sum()), int(scanned.sum())
    work = {
        "cluster_level": float(cluster_level),
        "probes": float(p_tot),
        "scanned": float(s_tot),
        "total": float(cluster_level + p_tot + s_tot),
    }
    return ptr, docs, work


def batched_lookup(
    index, queries: np.ndarray, bucket_size: int = 16
) -> Tuple[np.ndarray, np.ndarray, Dict[str, float]]:
    """The single-index Lookup loop, vectorized and exact.

    For each (t, u) row: the shorter posting list probes the bucketized
    longer one — bit-identical results and work to the per-query
    ``lookup_intersect(a, bucketize(b, n_docs, bucket_size))`` loop of
    ``SecludPipeline.evaluate``.  Returns ``(ptr, docs, work)`` CSR.
    """
    q = np.asarray(queries, np.int64).reshape(-1, 2)
    n = len(q)
    lens = index.lengths()
    t, u = q[:, 0], q[:, 1]
    lt, lu = lens[t], lens[u]
    t_short = lt <= lu
    s_term = np.where(t_short, t, u)
    l_term = np.where(t_short, u, t)
    s_len, l_len = lens[s_term], lens[l_term]
    short_vals = _ragged_gather(index.post_docs, index.post_ptr[s_term], s_len)
    long_vals = _ragged_gather(index.post_docs, index.post_ptr[l_term], l_len)
    hit, probes, scanned, _ = _lookup_many(
        short_vals.astype(np.int64),
        np.concatenate([[0], np.cumsum(s_len)]),
        long_vals.astype(np.int64),
        np.concatenate([[0], np.cumsum(l_len)]),
        np.full(n, index.n_docs, np.int64),
        bucket_size,
    )
    pair_s = np.repeat(np.arange(n, dtype=np.int64), s_len)
    docs = short_vals[hit].astype(np.int32)
    counts = np.bincount(pair_s[hit], minlength=n)
    ptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=ptr[1:])
    p_tot, s_tot = int(probes.sum()), int(scanned.sum())
    work = {
        "probes": float(p_tot),
        "scanned": float(s_tot),
        "total": float(p_tot + s_tot),
    }
    return ptr, docs, work


# ----------------------------------------------------------------------
# Device execution: length-bucketed bins through the intersect kernels
# ----------------------------------------------------------------------


def batched_counts(
    cidx,
    queries: np.ndarray,
    plan: SegmentPlan | None = None,
) -> Tuple[np.ndarray, Dict[str, float]]:
    """Per-query result counts through the batched intersect kernel.

    Segment pairs from the planner are binned by pow2-rounded (short, long)
    lengths (the ``repro.index.batched`` layout), each bin is PAD-padded
    and intersected on device (``intersect_count`` dispatches: Pallas
    kernel on TPU, jnp reference elsewhere), and a segment-sum maps
    per-pair counts back to per-query counts.  Counts are identical to
    ``ClusterIndex.query``.
    """
    import jax.numpy as jnp

    from repro.kernels.intersect.ops import intersect_count

    if plan is None:
        plan = plan_segment_pairs(cidx, queries)
    docs_arr = cidx.index.post_docs
    pair_counts = np.zeros(plan.n_pairs, np.int64)
    true_cells = padded_cells = 0
    if plan.n_pairs:
        bs = pow2_buckets(plan.short_len)
        bl = pow2_buckets(plan.long_len)
        key = bs * (int(bl.max()) + 1) + bl
        order = np.argsort(key, kind="stable")
        bounds = np.flatnonzero(
            np.concatenate([[True], key[order][1:] != key[order][:-1]])
        )
        for lo, hi in zip(bounds, np.append(bounds[1:], plan.n_pairs)):
            idxs = order[lo:hi]
            short = gather_padded(
                docs_arr, plan.short_start[idxs], plan.short_len[idxs], int(bs[idxs[0]])
            )
            long = gather_padded(
                docs_arr, plan.long_start[idxs], plan.long_len[idxs], int(bl[idxs[0]])
            )
            pair_counts[idxs] = np.asarray(
                intersect_count(jnp.asarray(short), jnp.asarray(long))
            )
            true_cells += int(plan.short_len[idxs].sum() + plan.long_len[idxs].sum())
            padded_cells += short.size + long.size
    counts = np.bincount(
        plan.pair_query, weights=pair_counts, minlength=plan.n_queries
    ).astype(np.int64)
    info = {
        "n_pairs": float(plan.n_pairs),
        "padding_overhead": float(padded_cells / max(true_cells, 1)),
    }
    return counts, info
