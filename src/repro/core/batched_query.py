"""Batched conjunctive-query engine — the per-query Python loops, vectorized.

The paper's speedups were measured by looping queries one at a time in
interpreted numpy (``SecludPipeline.evaluate``, ``ClusterIndex.query``,
``SearchService.serve_counts``).  This module executes a whole batch of
arbitrary-arity conjunctive queries (``repro.core.queries``) at once, in
three layers:

* ``_lookup_many`` — one vectorized pass that replicates
  ``lookup_intersect(short, bucketize(long, universe, B))`` *bit-exactly*
  (results, ``probes`` and ``scanned``) for many (short, long) pairs:
  per-pair arrays are keyed as ``pair * BASE + value`` so a single global
  ``searchsorted`` answers every per-pair directory probe at once.
  ``_chain_stage`` applies it to one stage of a cost-ordered intersection
  chain (the running intersection of every active item probes its next
  list) — the batched mirror of ``ClusterIndex.query``'s smallest-first
  plan.

* planning — ``plan_segment_pairs`` descends an arbitrary-depth
  :class:`repro.core.hier_index.HierIndex` for the whole batch: at every
  cluster level the surviving node lists of all query terms are chained
  smallest-first (CSR set-intersection, no Python per-query loop), the
  common nodes resolve each term's next-level slices, and the leaf level
  yields every (query, common-leaf-cluster) *segment group* — the k
  posting segments of that cluster, cost-ordered — plus the per-level
  work accounting of ``HierIndex.query``.  The historical two-level
  ``ClusterIndex`` is the L = 2 case (``as_hier`` view, no copies); the
  flat L = 1 index plans one whole-universe group per query.

* execution — either the host path ``batched_query`` (exact doc ids +
  the work dict of ``ClusterIndex.query``, summed), or the device path
  ``batched_counts``: segment groups are folded pairwise, stage by stage;
  each stage is length-bucketed and padded like ``repro.index.batched``,
  intermediate stages run a vectorized membership select
  (``intersect_members_ref``) and the final pairwise reduction of each
  group runs through the batched intersect kernel (Pallas on TPU, jnp
  elsewhere); a segment-sum maps per-group counts back to per-query
  counts.

Exactness guarantee: ``batched_query`` returns, for every query, the
identical (sorted) result array and the identical work totals as calling
``ClusterIndex.query`` in a loop; ``batched_counts`` returns the identical
per-query counts.  ``batched_lookup`` does the same for the single-index
Lookup chain (the baseline / S_R paths of ``SecludPipeline.evaluate``).
2-term queries are the degenerate case: one chain stage, one reduction.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.analysis.runtime import maybe_validate
from repro.core.hier_index import _concat_ranges, as_hier
from repro.core.queries import ConjunctiveQueries, as_queries
from repro.index.batched import pow2_buckets
from repro.kernels.intersect.ref import PAD

__all__ = [
    "SegmentPlan",
    "plan_segment_pairs",
    "batched_query",
    "batched_counts",
    "batched_lookup",
    "gather_padded",
    "pow2_buckets",
]


# ----------------------------------------------------------------------
# Ragged helpers
# ----------------------------------------------------------------------


def _ragged_indices(lengths: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(row id, offset within row) of every cell of a ragged row layout."""
    rows = np.repeat(np.arange(len(lengths)), lengths)
    within = np.arange(int(lengths.sum())) - (np.cumsum(lengths) - lengths)[rows]
    return rows, within


def _ragged_gather(values: np.ndarray, starts: np.ndarray, lengths: np.ndarray) -> np.ndarray:
    """Concatenate ``values[starts[i] : starts[i] + lengths[i]]`` for all i."""
    if int(lengths.sum()) == 0:
        return np.empty(0, values.dtype)
    rows, within = _ragged_indices(lengths)
    return values[starts[rows] + within]


def _csr_starts(lengths: np.ndarray) -> np.ndarray:
    out = np.zeros(len(lengths) + 1, dtype=np.int64)
    np.cumsum(lengths, out=out[1:])
    return out


def _ragged_range_idx(starts: np.ndarray, lengths: np.ndarray) -> np.ndarray:
    """Flat gather indices ``concat(arange(starts[i], starts[i] + lengths[i]))``
    — the (starts, lengths) spelling of ``hier_index._concat_ranges``,
    which owns the single implementation."""
    return _concat_ranges(starts, starts + lengths)


def gather_padded(
    values: np.ndarray,
    starts: np.ndarray,
    lengths: np.ndarray,
    width: int,
    fill: np.int32 = PAD,
) -> np.ndarray:
    """Gather ragged slices into a PAD-padded ``(len(starts), width)`` int32
    block without a per-row Python loop."""
    out = np.full((len(starts), width), fill, np.int32)
    if int(lengths.sum()):
        rows, within = _ragged_indices(lengths)
        out[rows, within] = values[starts[rows] + within]
    return out


# ----------------------------------------------------------------------
# The vectorized Lookup primitive
# ----------------------------------------------------------------------


def _lookup_many(
    short_vals: np.ndarray,
    short_ptr: np.ndarray,
    long_vals: np.ndarray,
    long_ptr: np.ndarray,
    universes: np.ndarray,
    bucket_size: int,
    track_work: bool = True,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized ``lookup_intersect(short_p, bucketize(long_p, U_p, B))``
    over P pairs at once.

    ``short_vals`` / ``long_vals`` are the per-pair sorted arrays
    concatenated in pair order (values in ``[0, U_p)``); ``*_ptr`` are the
    (P + 1,) CSR offsets.  Returns ``(hit, probes, scanned, pos)`` where
    ``hit`` masks ``short_vals`` (matched elements), ``probes`` / ``scanned``
    are per-pair int64 work counts bit-identical to looping
    ``repro.index.lookup.lookup_intersect``, and ``pos`` is the global index
    into ``long_vals`` of each short element's match candidate (valid where
    ``hit``).

    ``track_work=False`` skips the directory-probe bookkeeping (bucket
    bounds, resumable-scan pointers) and returns zero ``probes`` /
    ``scanned``: the ``hit`` mask — hence the surviving intersection — is
    identical, at roughly a third of the ``searchsorted`` work.  The
    device engine plans with this (it needs the layout, not the paper's
    work metric); every host path keeps the exact accounting.
    """
    n_pairs = len(universes)
    short_len = np.diff(short_ptr)
    long_len = np.diff(long_ptr)
    n_short = len(short_vals)
    if n_pairs == 0 or n_short == 0:
        return (
            np.zeros(n_short, bool),
            np.zeros(n_pairs, np.int64),
            np.zeros(n_pairs, np.int64),
            np.zeros(n_short, np.int64),
        )
    universes = universes.astype(np.int64)
    pair_s = np.repeat(np.arange(n_pairs, dtype=np.int64), short_len)
    x = short_vals.astype(np.int64)
    if not track_work:
        # Membership only: one keyed searchsorted; keys are unique
        # (pair * base + value), so equality at the insertion point IS the
        # hit test — no bucket directory needed.
        base = int(universes.max()) + 1
        keyed_long = (
            np.repeat(np.arange(n_pairs, dtype=np.int64), long_len) * base
            + long_vals.astype(np.int64)
        )
        key0 = pair_s * base
        pos = np.searchsorted(keyed_long, key0 + x)
        if len(keyed_long):
            hit = keyed_long[np.minimum(pos, len(keyed_long) - 1)] == key0 + x
        else:
            hit = np.zeros(n_short, bool)
        zeros = np.zeros(n_pairs, np.int64)
        return hit, zeros, zeros.copy(), pos
    # Per-pair bucket shift, exactly `_pick_shift` (only consumed when the
    # long side is non-empty; empty pairs cost nothing below).
    target = np.maximum(
        1.0, universes * float(bucket_size) / np.maximum(long_len, 1)
    )
    shift = np.maximum(np.floor(np.log2(target)).astype(np.int64), 0)
    n_buckets = np.maximum(
        (universes + (np.int64(1) << shift) - 1) >> shift, 1
    )
    # Key space: pair * BASE + value.  BASE exceeds every in-pair key —
    # values (< U) and bucket boundaries (<= n_buckets << shift) — so keyed
    # arrays stay globally sorted and probes never cross pair boundaries.
    base = int((n_buckets << shift).max()) + 1

    keyed_long = (
        np.repeat(np.arange(n_pairs, dtype=np.int64), long_len) * base
        + long_vals.astype(np.int64)
    )
    sh = shift[pair_s]
    b = np.clip(x >> sh, 0, n_buckets[pair_s] - 1)
    key0 = pair_s * base
    lo = np.searchsorted(keyed_long, key0 + (b << sh))
    hi = np.searchsorted(keyed_long, key0 + ((b + 1) << sh))
    pos = np.searchsorted(keyed_long, key0 + x)
    stop = np.minimum(pos, hi)
    # Resumable scan: within a run of probes sharing (pair, bucket) the
    # pointer starts where the previous probe left it.
    start = lo.copy()
    if n_short > 1:
        same = (b[1:] == b[:-1]) & (pair_s[1:] == pair_s[:-1])
        start[1:] = np.where(same, np.maximum(stop[:-1], lo[1:]), lo[1:])
    scanned_el = np.maximum(stop - start, 0)
    if len(keyed_long):
        hit = (pos < hi) & (
            keyed_long[np.minimum(pos, len(keyed_long) - 1)] == key0 + x
        )
    else:
        hit = np.zeros(n_short, bool)
    # lookup_intersect charges zero work when either side is empty.
    probes = np.where(long_len > 0, short_len, 0).astype(np.int64)
    scanned = np.zeros(n_pairs, np.int64)
    np.add.at(scanned, pair_s, scanned_el)
    return hit, probes, scanned, pos


def _chain_stage(
    cur_vals: np.ndarray,
    cur_lens: np.ndarray,
    act_idx: np.ndarray,
    long_vals: np.ndarray,
    long_lens: np.ndarray,
    universes: np.ndarray,
    bucket_size: int,
    track_work: bool = True,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """One stage of a batched cost-ordered intersection chain.

    ``(cur_vals, cur_lens)`` is the running intersection of every item as
    a CSR; items listed in ``act_idx`` probe their next list
    (``long_vals``/``long_lens``, CSR over the active items in order) via
    ``_lookup_many`` and are filtered in place; the rest pass through.
    Returns ``(new_vals, new_lens, probes, scanned)`` with per-active-item
    work arrays bit-identical to looping ``lookup_intersect``.
    """
    cur_starts = _csr_starts(cur_lens)[:-1]
    sub_lens = cur_lens[act_idx]
    sub_vals = _ragged_gather(cur_vals, cur_starts[act_idx], sub_lens)
    hit, probes, scanned, _ = _lookup_many(
        sub_vals,
        _csr_starts(sub_lens),
        long_vals,
        _csr_starts(long_lens),
        universes,
        bucket_size,
        track_work=track_work,
    )
    rows, within = _ragged_indices(sub_lens)
    keep = np.ones(len(cur_vals), bool)
    keep[cur_starts[act_idx][rows] + within] = hit
    new_vals = cur_vals[keep]
    new_lens = cur_lens.copy()
    new_lens[act_idx] = np.bincount(rows[hit], minlength=len(act_idx)).astype(np.int64)
    return new_vals, new_lens, probes, scanned


def _cost_ordered_terms(cq: ConjunctiveQueries, slot_lens: np.ndarray) -> np.ndarray:
    """Each query's terms reordered by list length ascending (stable), the
    batched mirror of ``repro.core.cluster_index.cost_order``.  Returns a
    flat array aligned with ``cq.q_ptr``: position ``q_ptr[i] + r`` holds
    query i's rank-r (r-th cheapest) term."""
    slot_q = np.repeat(np.arange(cq.n_queries, dtype=np.int64), cq.arities)
    slot_pos = np.arange(len(cq.q_terms), dtype=np.int64) - cq.q_ptr[:-1][slot_q]
    order = np.lexsort((slot_pos, slot_lens, slot_q))
    return cq.q_terms[order]


# ----------------------------------------------------------------------
# Planning: all (query, common-cluster) segment groups in one shot
# ----------------------------------------------------------------------


@dataclasses.dataclass
class SegmentPlan:
    """Every (query, common-leaf-cluster) segment group of a batch,
    ordered by (query, cluster) — the order ``HierIndex.query`` emits.

    A group holds one posting segment per query term (``arity`` of them),
    stored flat in ``seg_start``/``seg_len`` (absolute slices into
    ``index.post_docs``), *cost-ordered*: within a group, ``seg_ptr[g] +
    r`` is the r-th shortest segment (ties keep original term order) —
    the chain order of the per-cluster intersection.  ``level_work``
    holds one per-query work array per cluster level of the descent
    (empty for the flat L = 1 index); ``cluster_work`` is their
    element-wise sum — at L = 2, exactly the historical level-1 lookup
    work.
    """

    pair_query: np.ndarray  # (G,) int64 — query id of each segment group
    cluster: np.ndarray  # (G,) int64 — common leaf cluster id
    base: np.ndarray  # (G,) int64 — leaf_ranges[cluster]
    width: np.ndarray  # (G,) int64 — cluster width (leaf-level universe)
    arity: np.ndarray  # (G,) int64 — segments per group (= query arity)
    seg_ptr: np.ndarray  # (G + 1,) int64 — group offsets into seg_*
    seg_start: np.ndarray  # (S,) int64 — rank-ordered within each group
    seg_len: np.ndarray  # (S,) int64
    cluster_work: np.ndarray  # (n_queries,) int64 — summed descent work
    n_queries: int
    max_arity: int
    level_work: Tuple[np.ndarray, ...] = ()  # per cluster level, (n_queries,)

    @property
    def n_pairs(self) -> int:
        return len(self.pair_query)

    def validate(self) -> None:
        """Structural invariants of the plan (debug head: ``REPRO_DEBUG``).

        Group arrays are parallel, ``seg_ptr`` is the CSR of ``arity``,
        groups come out in the (query, cluster) emission order, every
        segment is a sane slice, and each group's segments are
        cost-ordered (nondecreasing length) — the chain-order premise of
        both the host chain and the device fold.
        """
        g = self.n_pairs
        for name in ("cluster", "base", "width", "arity"):
            if len(getattr(self, name)) != g:
                raise ValueError(f"SegmentPlan: {name} not parallel to pair_query")
        if len(self.seg_ptr) != g + 1 or self.seg_ptr[0] != 0:
            raise ValueError("SegmentPlan: seg_ptr must be a (G + 1,) CSR from 0")
        if (np.diff(self.seg_ptr) != self.arity).any():
            raise ValueError("SegmentPlan: seg_ptr increments must equal arity")
        n_seg = int(self.seg_ptr[-1])
        if len(self.seg_start) != n_seg or len(self.seg_len) != n_seg:
            raise ValueError("SegmentPlan: segment arrays disagree with seg_ptr")
        if g:
            if int(self.arity.min()) < 1:
                raise ValueError("SegmentPlan: every group needs >= 1 segment")
            if ((self.pair_query < 0) | (self.pair_query >= self.n_queries)).any():
                raise ValueError("SegmentPlan: pair_query outside [0, n_queries)")
            if (np.diff(self.pair_query) < 0).any():
                raise ValueError("SegmentPlan: groups must be query-ordered")
            if (self.width < 0).any() or (self.base < 0).any():
                raise ValueError("SegmentPlan: negative cluster base/width")
            if int(self.arity.max()) > int(self.max_arity):
                raise ValueError("SegmentPlan: max_arity below a group's arity")
        if n_seg and ((self.seg_start < 0) | (self.seg_len < 0)).any():
            raise ValueError("SegmentPlan: negative segment start/length")
        if n_seg > 1:
            starts = np.zeros(n_seg + 1, bool)
            starts[self.seg_ptr] = True
            ok = (np.diff(self.seg_len) >= 0) | starts[1:n_seg]
            if not ok.all():
                raise ValueError(
                    "SegmentPlan: segments within a group must be "
                    "cost-ordered (nondecreasing length)"
                )
        if len(self.cluster_work) != self.n_queries:
            raise ValueError("SegmentPlan: cluster_work not (n_queries,)")

    # Rank-0 / rank-1 views — the historical (short, long) segment pair of
    # a 2-term batch; ``long_len`` is 0 for single-term groups.

    @property
    def short_start(self) -> np.ndarray:
        return self.seg_start[self.seg_ptr[:-1]]

    @property
    def short_len(self) -> np.ndarray:
        return self.seg_len[self.seg_ptr[:-1]]

    @property
    def long_start(self) -> np.ndarray:
        return self.seg_start[self.seg_ptr[:-1] + np.minimum(self.arity - 1, 1)]

    @property
    def long_len(self) -> np.ndarray:
        i = self.seg_ptr[:-1] + np.minimum(self.arity - 1, 1)
        return np.where(self.arity >= 2, self.seg_len[i], 0)


def _empty_plan(n_levels: int) -> SegmentPlan:
    empty = np.zeros(0, np.int64)
    return SegmentPlan(
        pair_query=empty,
        cluster=empty,
        base=empty,
        width=empty,
        arity=empty,
        seg_ptr=np.zeros(1, np.int64),
        seg_start=empty,
        seg_len=empty,
        cluster_work=np.zeros(0, np.int64),
        n_queries=0,
        max_arity=0,
        level_work=tuple(np.zeros(0, np.int64) for _ in range(n_levels)),
    )


def _plan_flat_root(hidx, cq: ConjunctiveQueries) -> SegmentPlan:
    """The L = 1 plan: every query owns one whole-universe group whose
    segments are its full posting lists — the leaf chain then IS the
    cost-ordered single-index Lookup of ``chain_lookup``."""
    n = cq.n_queries
    ar = cq.arities
    max_a = cq.max_arity
    ptr = hidx.index.post_ptr
    parts_g, parts_pos, parts_st, parts_ln = [], [], [], []
    for r in range(max_a):
        qa = np.flatnonzero(ar > r)
        if len(qa) == 0:
            break
        t = cq.q_terms[cq.q_ptr[:-1][qa] + r]
        parts_g.append(qa)
        parts_pos.append(np.full(len(qa), r, np.int64))
        parts_st.append(ptr[t])
        parts_ln.append(ptr[t + 1] - ptr[t])
    if parts_g:
        flat_g = np.concatenate(parts_g)
        flat_pos = np.concatenate(parts_pos)
        flat_st = np.concatenate(parts_st)
        flat_ln = np.concatenate(parts_ln)
    else:
        flat_g = flat_pos = flat_st = flat_ln = np.zeros(0, np.int64)
    order2 = np.lexsort((flat_pos, flat_ln, flat_g))
    g_arity = ar.astype(np.int64)
    return SegmentPlan(
        pair_query=np.arange(n, dtype=np.int64),
        cluster=np.zeros(n, np.int64),
        base=np.zeros(n, np.int64),
        width=np.full(n, hidx.index.n_docs, np.int64),
        arity=g_arity,
        seg_ptr=_csr_starts(g_arity),
        seg_start=flat_st[order2],
        seg_len=flat_ln[order2],
        cluster_work=np.zeros(n, np.int64),
        n_queries=n,
        max_arity=max_a,
        level_work=(),
    )


def plan_segment_pairs(cidx, queries, track_work: bool = True) -> SegmentPlan:
    """Vectorized descent of the hierarchy for a whole batch.

    At every cluster level, each query's surviving node lists are chained
    smallest-first via keyed ``searchsorted`` — no Python per-query loop —
    with the same running-intersection probing (and work accounting) as
    ``HierIndex.query``; the common nodes of a level resolve, per
    original term slot, the contiguous child slice of the next level,
    and the leaf level resolves every common cluster to one posting
    segment per term, cost-ordered for the final per-cluster chain.

    ``cidx`` may be a :class:`repro.core.hier_index.HierIndex` of any
    depth or the two-level ``ClusterIndex`` facade (the L = 2 view).

    ``track_work=False`` plans the identical segment groups without the
    per-level work accounting (``cluster_work`` / ``level_work`` come
    back zero) — the device engine's cheaper planning mode; every path
    that reports the paper's work metric must keep the default.
    """
    hidx = as_hier(cidx)
    cq = as_queries(queries)
    n = cq.n_queries
    ar = cq.arities
    max_a = cq.max_arity
    nlev = len(hidx.levels)
    if n == 0:
        return maybe_validate(_empty_plan(nlev))
    if nlev == 0:
        return maybe_validate(_plan_flat_root(hidx, cq))

    # Per-(slot, query) rows over the current level's CSR arrays.  At the
    # top level every row is a CONTIGUOUS slice of the level arrays, so
    # ``row_start`` holds global starts and no index scratch is needed
    # (`gi is None`); after a descent, rows are unions of child slices,
    # so ``gi`` flattens their global indices and ``row_start`` indexes
    # into it: row (r, q) is gi[row_start[r, q] :][: row_len[r, q]].
    lev = hidx.levels[0]
    row_len = np.zeros((max_a, n), np.int64)
    row_start = np.zeros((max_a, n), np.int64)
    for r in range(max_a):
        qa = np.flatnonzero(ar > r)
        t = cq.q_terms[cq.q_ptr[:-1][qa] + r]
        row_len[r, qa] = (lev.cl_ptr[t + 1] - lev.cl_ptr[t]).astype(np.int64)
        row_start[r, qa] = lev.cl_ptr[t]
    gi = None

    qarange = np.arange(n, dtype=np.int64)
    sentinel = np.iinfo(np.int64).max
    level_work = []
    for li in range(nlev):
        lev = hidx.levels[li]
        # vals_src is addressed by row positions: the level array itself
        # in contiguous mode, the gathered batch otherwise.
        vals_src = (
            lev.cl_ids.astype(np.int64)
            if gi is None
            else lev.cl_ids[gi].astype(np.int64)
        )

        # Cost order of each query's slots by current list length
        # (stable argsort → ties keep slot order, exactly `cost_order`).
        lens_m = np.where(
            np.arange(max_a)[:, None] < ar[None, :], row_len, sentinel
        )
        rank_slot = np.argsort(lens_m, axis=0, kind="stable")

        # Chain: the running intersection of every query probes its next
        # (rank-s) list, bucketized over this level's node universe.
        s0 = rank_slot[0]
        cur_lens = row_len[s0, qarange]
        cur_vals = vals_src[_ragged_range_idx(row_start[s0, qarange], cur_lens)]
        wk = np.zeros(n, np.int64)
        for s in range(1, max_a):
            act = np.flatnonzero(ar > s)
            if len(act) == 0:
                break
            sl = rank_slot[s, act]
            l_lens = row_len[sl, act]
            l_vals = vals_src[_ragged_range_idx(row_start[sl, act], l_lens)]
            cur_vals, cur_lens, probes, scanned = _chain_stage(
                cur_vals,
                cur_lens,
                act,
                l_vals,
                l_lens,
                np.full(len(act), lev.k, np.int64),
                hidx.bucket_size_clusters,
                track_work=track_work,
            )
            wk[act] += probes + scanned
        level_work.append(wk)

        # Groups: one per surviving (query, common node) at this level.
        group_query = np.repeat(qarange, cur_lens)
        g_arity = ar[group_query] if len(group_query) else np.zeros(0, np.int64)

        # Resolve each group to one entry per ORIGINAL term slot: the
        # common node is present in every slot's list, so a keyed
        # searchsorted per slot finds its row position exactly.
        key_base = lev.k + 1
        res_g, res_pos, res_gi = [], [], []
        for r in range(max_a):
            qa = np.flatnonzero(ar > r)
            if len(qa) == 0:
                break
            gm = np.flatnonzero(g_arity > r)
            lens_r = row_len[r, qa]
            l_ptr = _csr_starts(lens_r)
            keyed_long = (
                np.repeat(np.arange(len(qa), dtype=np.int64), lens_r) * key_base
                + vals_src[_ragged_range_idx(row_start[r, qa], lens_r)]
            )
            qrank = np.full(n, -1, np.int64)
            qrank[qa] = np.arange(len(qa))
            gq = qrank[group_query[gm]]
            pos = np.searchsorted(keyed_long, gq * key_base + cur_vals[gm])
            src_pos = row_start[r, qa][gq] + (pos - l_ptr[gq])
            res_g.append(gm)
            res_pos.append(np.full(len(gm), r, np.int64))
            res_gi.append(src_pos if gi is None else gi[src_pos])

        if li == nlev - 1:
            break

        # Descend: slot (r, q)'s next-level row is the concatenation of
        # its child slices over q's common nodes — parents ascend, so the
        # concatenation stays sorted.
        new_row_len = np.zeros((max_a, n), np.int64)
        new_row_start = np.zeros((max_a, n), np.int64)
        gi_parts = []
        off = 0
        for r, (gm, gidx) in enumerate(zip(res_g, res_gi, strict=True)):
            child_s = lev.seg_start[gidx]
            child_ln = lev.seg_end[gidx] - lev.seg_start[gidx]
            qa = np.flatnonzero(ar > r)
            lens_q = np.zeros(n, np.int64)
            np.add.at(lens_q, group_query[gm], child_ln)
            new_row_len[r] = lens_q
            new_row_start[r, qa] = off + _csr_starts(lens_q[qa])[:-1]
            gi_parts.append(_ragged_range_idx(child_s, child_ln))
            off += int(child_ln.sum())
        row_len, row_start = new_row_len, new_row_start
        gi = np.concatenate(gi_parts) if gi_parts else np.empty(0, np.int64)

    # Leaf resolution: flatten per-slot segments, cost-ordered within each
    # group (length ascending, ties by term order — exactly `cost_order`).
    if res_g:
        flat_g = np.concatenate(res_g)
        flat_pos = np.concatenate(res_pos)
        flat_gi = np.concatenate(res_gi)
        flat_st = lev.seg_start[flat_gi]
        flat_ln = lev.seg_end[flat_gi] - lev.seg_start[flat_gi]
    else:
        flat_g = flat_pos = flat_st = flat_ln = np.zeros(0, np.int64)
    order2 = np.lexsort((flat_pos, flat_ln, flat_g))
    cluster = cur_vals.astype(np.int64)
    plan = SegmentPlan(
        pair_query=group_query,
        cluster=cluster,
        base=lev.ranges[cluster],
        width=lev.ranges[cluster + 1] - lev.ranges[cluster],
        arity=g_arity,
        seg_ptr=_csr_starts(g_arity),
        seg_start=flat_st[order2],
        seg_len=flat_ln[order2],
        cluster_work=sum(level_work, np.zeros(n, np.int64)),
        n_queries=n,
        max_arity=max_a,
        level_work=tuple(level_work),
    )
    return maybe_validate(plan)


# ----------------------------------------------------------------------
# Host execution: exact doc ids + exact work accounting
# ----------------------------------------------------------------------


def batched_query(
    cidx, queries
) -> Tuple[np.ndarray, np.ndarray, Dict[str, float]]:
    """The whole hierarchical conjunctive-query batch on the host, exactly.

    ``cidx`` is a ``HierIndex`` of any depth or the two-level
    ``ClusterIndex`` facade.  Returns ``(ptr, docs, work)``:
    ``docs[ptr[i] : ptr[i + 1]]`` is bit-identical to
    ``cidx.query(*terms_i)[0]`` and ``work`` holds the summed per-query
    work dict of the loop (including the per-level ``level_{l}`` keys).
    """
    cq = as_queries(queries)
    plan = plan_segment_pairs(cidx, cq)
    docs64 = cidx.index.post_docs.astype(np.int64)
    n_g = plan.n_pairs
    r0 = plan.seg_ptr[:-1]
    cur_lens = plan.seg_len[r0].astype(np.int64)
    cur_vals = (
        _ragged_gather(docs64, plan.seg_start[r0], cur_lens)
        - plan.base[np.repeat(np.arange(n_g), cur_lens)]
    )
    probes_tot = scanned_tot = 0
    for s in range(1, plan.max_arity):
        act = np.flatnonzero(plan.arity > s)
        if len(act) == 0:
            break
        si = r0[act] + s
        l_lens = plan.seg_len[si].astype(np.int64)
        l_vals = (
            _ragged_gather(docs64, plan.seg_start[si], l_lens)
            - plan.base[act][np.repeat(np.arange(len(act)), l_lens)]
        )
        cur_vals, cur_lens, probes, scanned = _chain_stage(
            cur_vals,
            cur_lens,
            act,
            l_vals,
            l_lens,
            np.maximum(plan.width[act], 1),
            cidx.bucket_size_postings,
        )
        probes_tot += int(probes.sum())
        scanned_tot += int(scanned.sum())
    docs = (cur_vals + plan.base[np.repeat(np.arange(n_g), cur_lens)]).astype(
        np.int32
    )
    counts = np.zeros(plan.n_queries, np.int64)
    np.add.at(counts, plan.pair_query, cur_lens)
    ptr = np.zeros(plan.n_queries + 1, np.int64)
    np.cumsum(counts, out=ptr[1:])
    cluster_level = int(plan.cluster_work.sum())
    work = {f"level_{i}": float(w.sum()) for i, w in enumerate(plan.level_work)}
    work.update(
        {
            "cluster_level": float(cluster_level),
            "probes": float(probes_tot),
            "scanned": float(scanned_tot),
            "total": float(cluster_level + probes_tot + scanned_tot),
        }
    )
    return ptr, docs, work


def batched_lookup(
    index, queries, bucket_size: int = 16
) -> Tuple[np.ndarray, np.ndarray, Dict[str, float]]:
    """The single-index Lookup chain, vectorized and exact.

    For each query: its posting lists, smallest-first, with the running
    intersection probing the next bucketized list — bit-identical results
    and work to the per-query ``lookup_intersect`` chain of
    ``SecludPipeline.evaluate`` (for 2 terms: the shorter list probes the
    longer, the historical loop).  Returns ``(ptr, docs, work)`` CSR.
    """
    cq = as_queries(queries)
    n = cq.n_queries
    docs64 = index.post_docs.astype(np.int64)
    lens_all = index.lengths()
    ord_terms = _cost_ordered_terms(cq, lens_all[cq.q_terms].astype(np.int64))
    t0 = ord_terms[cq.q_ptr[:-1]]
    cur_lens = lens_all[t0].astype(np.int64)
    cur_vals = _ragged_gather(docs64, index.post_ptr[t0], cur_lens)
    probes_tot = scanned_tot = 0
    for s in range(1, cq.max_arity):
        act = np.flatnonzero(cq.arities > s)
        if len(act) == 0:
            break
        ts = ord_terms[cq.q_ptr[:-1][act] + s]
        l_lens = lens_all[ts].astype(np.int64)
        l_vals = _ragged_gather(docs64, index.post_ptr[ts], l_lens)
        cur_vals, cur_lens, probes, scanned = _chain_stage(
            cur_vals,
            cur_lens,
            act,
            l_vals,
            l_lens,
            np.full(len(act), index.n_docs, np.int64),
            bucket_size,
        )
        probes_tot += int(probes.sum())
        scanned_tot += int(scanned.sum())
    docs = cur_vals.astype(np.int32)
    ptr = np.zeros(n + 1, np.int64)
    np.cumsum(cur_lens, out=ptr[1:])
    work = {
        "probes": float(probes_tot),
        "scanned": float(scanned_tot),
        "total": float(probes_tot + scanned_tot),
    }
    return ptr, docs, work


# ----------------------------------------------------------------------
# Device execution: the upload-once fused fold
# ----------------------------------------------------------------------


def batched_counts(
    cidx,
    queries,
    plan: SegmentPlan | None = None,
) -> Tuple[np.ndarray, Dict[str, float]]:
    """Per-query result counts through the device-resident engine.

    Delegates to :func:`repro.core.device_engine.device_counts`: the
    index is uploaded once (cached on ``cidx``), the whole cost-ordered
    k-way chain runs as ONE fused jit call probing the resident posting
    array in place, and only the final counts return to host.  Counts
    are identical to ``HierIndex.query`` (and to the ``ClusterIndex``
    facade at L = 2) at any depth — the plan already encodes the whole
    descent.  ``info`` reports ``n_kernel_calls``, the total
    ``padding_overhead`` and per-stage attribution (see
    ``device_counts``).
    """
    from repro.core.device_engine import device_counts

    return device_counts(cidx, queries, plan=plan)
