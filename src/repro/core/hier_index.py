"""Arbitrary-depth hierarchical cluster index — depth is a parameter,
not an architecture.

The paper's clustering is explicitly *multilevel* (§3.2) and motivates
clusters as a way to "distribute the work over many machines" (§1), yet
the original query side hard-coded exactly two levels.  A
:class:`HierIndex` generalizes the §3.3 cluster index to L levels:

    postings (level L-1)  <-  clusters  <-  super-clusters  <-  ...  <- top

Every *cluster level* l (0 = coarsest .. L-2 = leaf clusters) is one
uniform CSR :class:`HierLevel` ``(cl_ptr, cl_ids, seg_start, seg_end,
ranges)``: for each term, the sorted ids of the level-l nodes containing
it, and for each (term, node) entry the contiguous slice of the *next*
level's ``cl_ids`` holding that node's children for the term — at the
leaf level the slice points into ``index.post_docs`` (the posting
segment).  Nodes own contiguous document-id ranges (``ranges``) and a
parent's children occupy a contiguous id block, which is what makes every
per-(term, node) restriction a single slice — no data duplication at any
depth.

Degeneracies (the compatibility contract, property-tested):

* **L = 1** — zero cluster levels: a query is exactly the single-index
  cost-ordered Lookup chain of Sanders & Transier [14]
  (``repro.index.lookup.chain_lookup`` — bucket size 16, universe
  ``n_docs``), results and work bit-for-bit.
* **L = 2** — one cluster level: exactly the historical
  ``ClusterIndex`` — same arrays, same cost-ordered two-level query,
  same ``cluster_level/probes/scanned/total`` work accounting bit-for-bit
  (``repro.core.cluster_index.ClusterIndex`` is now a thin facade over
  this module).

Querying descends the hierarchy with the existing cost-ordered chain at
every level: at each cluster level the surviving node lists are
intersected smallest-first through the bucketed Lookup (bucket size 8,
universe k_l), the common nodes resolve each term's next-level slices,
and the leaf level runs the per-cluster posting chain (bucket size 16,
local universe = cluster width).  The work dict gains one ``level_{l}``
key per cluster level while preserving the historical totals.

Exactness stays the defining invariant: every depth returns the
identical result set.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.analysis.runtime import maybe_validate
from repro.index.build import InvertedIndex
from repro.index.lookup import bucketize, cost_order, lookup_intersect

__all__ = [
    "HierLevel",
    "HierIndex",
    "build_hier_index",
    "as_hier",
    "shard_tops",
]


def _flatten_terms(terms: Sequence) -> Tuple[int, ...]:
    """query(t, u), query(t, u, v), query([t, u, v]) all mean the same."""
    if len(terms) == 1 and not np.isscalar(terms[0]) and hasattr(terms[0], "__len__"):
        terms = tuple(terms[0])
    out = tuple(int(t) for t in terms)
    if not out:
        raise ValueError("a conjunctive query needs >= 1 term")
    return out


def _concat_ranges(starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
    """Concatenate ``arange(starts[i], ends[i])`` for all i, vectorized."""
    lens = (ends - starts).astype(np.int64)
    tot = int(lens.sum())
    if tot == 0:
        return np.empty(0, np.int64)
    rows = np.repeat(np.arange(len(starts)), lens)
    within = np.arange(tot, dtype=np.int64) - (np.cumsum(lens) - lens)[rows]
    return starts[rows] + within


@dataclasses.dataclass
class HierLevel:
    """One cluster level: CSR of (term -> nodes containing it, with the
    child slice of each (term, node) entry in the next level's array)."""

    cl_ptr: np.ndarray  # (n_terms + 1,) int64
    cl_ids: np.ndarray  # (nnz_l,) int32 — sorted node ids per term
    seg_start: np.ndarray  # (nnz_l,) int64 — child-slice start (absolute)
    seg_end: np.ndarray  # (nnz_l,) int64
    ranges: np.ndarray  # (k_l + 1,) int64 — node doc-id boundaries

    @property
    def k(self) -> int:
        return len(self.ranges) - 1

    def term_entries(
        self, t: int
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        lo, hi = self.cl_ptr[t], self.cl_ptr[t + 1]
        return self.cl_ids[lo:hi], self.seg_start[lo:hi], self.seg_end[lo:hi]


@dataclasses.dataclass
class HierIndex:
    """L-level hierarchical cluster index over a reordered inverted index.

    ``levels`` runs coarse -> fine; ``levels[-1]``'s segments are posting
    slices into ``index.post_docs``.  ``levels == ()`` is the flat L = 1
    single-index Lookup.
    """

    levels: Tuple[HierLevel, ...]
    index: InvertedIndex
    bucket_size_clusters: int = 8
    bucket_size_postings: int = 16

    @property
    def depth(self) -> int:
        """L: number of levels including the posting level."""
        return len(self.levels) + 1

    @property
    def k(self) -> int:
        """Leaf cluster count (1 for the flat L = 1 index)."""
        return self.levels[-1].k if self.levels else 1

    @property
    def leaf_ranges(self) -> np.ndarray:
        if self.levels:
            return self.levels[-1].ranges
        return np.array([0, self.index.n_docs], dtype=np.int64)

    @property
    def top_ranges(self) -> np.ndarray:
        """Level-0 (coarsest) node doc-id boundaries — the machine-level
        partitioning unit (one implicit root for the flat L = 1 index)."""
        if self.levels:
            return self.levels[0].ranges
        return np.array([0, self.index.n_docs], dtype=np.int64)

    def validate(self) -> None:
        """Structural invariants of the hierarchy (debug head:
        ``REPRO_DEBUG`` via :mod:`repro.analysis.runtime`).

        Every level's ranges are a monotone boundary array over [0, n]
        nested in the next finer level; every level CSR is monotone with
        sorted node ids per term and in-bounds child segments; postings
        are strictly increasing within each term segment — the premise
        of both the host chain and the device binary search.
        """
        n = self.index.n_docs
        m = self.index.n_terms
        post_ptr = np.asarray(self.index.post_ptr)
        post_docs = np.asarray(self.index.post_docs)
        n_post = len(post_docs)
        if len(post_ptr) != m + 1 or post_ptr[0] != 0 or post_ptr[-1] != n_post:
            raise ValueError("HierIndex: post_ptr must span [0, n_postings]")
        if (np.diff(post_ptr) < 0).any():
            raise ValueError("HierIndex: post_ptr must be nondecreasing")
        if n_post > 1:
            seg_start = np.zeros(n_post + 1, bool)
            seg_start[post_ptr] = True
            if not ((np.diff(post_docs) > 0) | seg_start[1:n_post]).all():
                raise ValueError(
                    "HierIndex: postings must be strictly increasing "
                    "within each term segment"
                )
        ranges_list = [lev.ranges for lev in self.levels]
        for r in ranges_list:
            if len(r) < 2 or r[0] != 0 or r[-1] != n or (np.diff(r) < 0).any():
                raise ValueError(
                    "HierIndex: level ranges must be a nondecreasing "
                    f"boundary array spanning [0, {n}]"
                )
        for coarse, fine in zip(ranges_list, ranges_list[1:], strict=False):
            _check_nested(coarse, fine)
        for i, lev in enumerate(self.levels):
            nnz = len(lev.cl_ids)
            if (
                len(lev.cl_ptr) != m + 1
                or lev.cl_ptr[0] != 0
                or lev.cl_ptr[-1] != nnz
                or (np.diff(lev.cl_ptr) < 0).any()
            ):
                raise ValueError(f"HierIndex: level {i} cl_ptr not a CSR over terms")
            if len(lev.seg_start) != nnz or len(lev.seg_end) != nnz:
                raise ValueError(f"HierIndex: level {i} segment arrays mismatch")
            if nnz and ((lev.cl_ids < 0) | (lev.cl_ids >= lev.k)).any():
                raise ValueError(f"HierIndex: level {i} node ids outside [0, k)")
            if nnz > 1:
                term_start = np.zeros(nnz + 1, bool)
                term_start[lev.cl_ptr] = True
                if not ((np.diff(lev.cl_ids) > 0) | term_start[1:nnz]).all():
                    raise ValueError(
                        f"HierIndex: level {i} node ids must be strictly "
                        "increasing per term"
                    )
            bound = (
                len(self.levels[i + 1].cl_ids)
                if i + 1 < len(self.levels)
                else n_post
            )
            if nnz and (
                (lev.seg_start < 0)
                | (lev.seg_start > lev.seg_end)
                | (lev.seg_end > bound)
            ).any():
                raise ValueError(
                    f"HierIndex: level {i} child segments out of bounds "
                    "or inverted"
                )

    def slice_top(self, top_lo: int, top_hi: int) -> "HierIndex":
        """The index restricted to top-level nodes ``[top_lo, top_hi)`` —
        the host view of one corpus shard.

        The returned index keeps the ORIGINAL doc-id space, node ids and
        posting array (shared, no copies of ``post_docs``); only the
        per-term CSR entries of nodes outside the top range are dropped,
        so a query returns exactly the global result docs that live in
        the shard's doc range.  Because every leaf cluster lies wholly in
        one shard, summed per-shard counts (and the union of per-shard
        result sets) reproduce the global query bit-for-bit — the oracle
        the sharded device engine is tested against.
        """
        if not self.levels:
            if (top_lo, top_hi) != (0, 1):
                raise ValueError("flat index has exactly one top node")
            return self
        top = self.levels[0]
        if not (0 <= top_lo <= top_hi <= top.k):
            raise ValueError(
                f"top range [{top_lo}, {top_hi}) outside [0, {top.k}]"
            )
        doc_lo = int(top.ranges[top_lo])
        doc_hi = int(top.ranges[top_hi])
        # Per level: the kept node-id range (nested ranges ⇒ doc_lo/doc_hi
        # are boundaries of every level) and the entry keep-mask.
        masks, shifts = [], []
        for lev in self.levels:
            nlo = int(np.searchsorted(lev.ranges, doc_lo))
            nhi = int(np.searchsorted(lev.ranges, doc_hi))
            mask = (lev.cl_ids >= nlo) & (lev.cl_ids < nhi)
            # shift[i] = entries removed before position i (inclusive of
            # nothing at i); one extra slot so seg_end == len remaps too.
            shift = np.zeros(len(mask) + 1, np.int64)
            np.cumsum(~mask, out=shift[1:])
            masks.append(mask)
            shifts.append(shift)
        new_levels = []
        m = self.index.n_terms
        for li, lev in enumerate(self.levels):
            mask = masks[li]
            term_of = np.repeat(np.arange(m, dtype=np.int64), np.diff(lev.cl_ptr))
            cl_ptr = np.zeros(m + 1, np.int64)
            np.add.at(cl_ptr, term_of[mask] + 1, 1)
            np.cumsum(cl_ptr, out=cl_ptr)
            seg_start = lev.seg_start[mask]
            seg_end = lev.seg_end[mask]
            if li < len(self.levels) - 1:
                # Child slices index the next level's (filtered) cl_ids: a
                # kept node's children are all kept, so the whole slice
                # shifts by one constant.
                sh = shifts[li + 1]
                seg_start = seg_start - sh[seg_start]
                seg_end = seg_end - sh[seg_end]
            # Leaf slices stay absolute into the shared post_docs.
            new_levels.append(
                HierLevel(
                    cl_ptr=cl_ptr,
                    cl_ids=lev.cl_ids[mask],
                    seg_start=seg_start,
                    seg_end=seg_end,
                    ranges=lev.ranges,
                )
            )
        return HierIndex(
            levels=tuple(new_levels),
            index=self.index,
            bucket_size_clusters=self.bucket_size_clusters,
            bucket_size_postings=self.bucket_size_postings,
        )

    # ------------------------------------------------------------------
    # Descent
    # ------------------------------------------------------------------

    def _descend(self, terms: Tuple[int, ...], merge: bool):
        """Walk the cluster levels with a cost-ordered chain at each one.

        Returns ``(common, pos, seg_s, seg_e, ranges, work_levels)``:
        the common leaf clusters, each term's entry positions for them,
        the term's leaf segment arrays, the leaf ranges and the per-level
        chain work.  ``merge=True`` replaces the bucketed Lookup with a
        direct merge-join (work = sum of list lengths per chain stage) —
        the 'most direct way' of §3.3, kept as an independent oracle.
        """
        a = len(terms)
        work_levels: List[float] = []
        if not self.levels:
            # L = 1: a single implicit root node covering every document.
            ptr = self.index.post_ptr
            common = np.zeros(1, np.int32)
            pos = [np.zeros(1, np.int64)] * a
            seg_s = [np.array([ptr[t]], np.int64) for t in terms]
            seg_e = [np.array([ptr[t + 1]], np.int64) for t in terms]
            return common, pos, seg_s, seg_e, self.leaf_ranges, work_levels

        lev = self.levels[0]
        entries = [lev.term_entries(t) for t in terms]
        ids = [e[0] for e in entries]
        ss = [e[1] for e in entries]
        se = [e[2] for e in entries]
        for li, lev in enumerate(self.levels):
            order = cost_order([len(x) for x in ids])
            if merge:
                common = ids[order[0]]
                w_lvl = 0.0
                for i in order[1:]:
                    w_lvl += float(len(common) + len(ids[i]))
                    common = np.intersect1d(common, ids[i])
            else:
                common = ids[order[0]].astype(np.int32)
                w_lvl = 0.0
                for i in order[1:]:
                    common, w1 = lookup_intersect(
                        common,
                        bucketize(
                            ids[i].astype(np.int32),
                            lev.k,
                            self.bucket_size_clusters,
                        ),
                    )
                    w_lvl += w1["total"]
            work_levels.append(w_lvl)
            pos = [np.searchsorted(ids[i], common) for i in range(a)]
            if li == len(self.levels) - 1:
                return common, pos, ss, se, lev.ranges, work_levels
            nxt = self.levels[li + 1]
            new_ids, new_ss, new_se = [], [], []
            for i in range(a):
                gi = _concat_ranges(ss[i][pos[i]], se[i][pos[i]])
                new_ids.append(nxt.cl_ids[gi])
                new_ss.append(nxt.seg_start[gi])
                new_se.append(nxt.seg_end[gi])
            ids, ss, se = new_ids, new_ss, new_se
        raise AssertionError("unreachable")

    def _leaf_chain(
        self,
        terms: Tuple[int, ...],
        common: np.ndarray,
        pos: List[np.ndarray],
        seg_s: List[np.ndarray],
        seg_e: List[np.ndarray],
        ranges: np.ndarray,
    ) -> Tuple[np.ndarray, int, int]:
        """Per-cluster posting intersection, cost-ordered chain (bucket
        size 16, local universe = cluster width)."""
        docs = self.index.post_docs
        results = []
        probes = scanned = 0
        for j, ci in enumerate(common):
            base = ranges[ci]
            width = int(ranges[ci + 1] - base)
            slices = [
                docs[seg_s[i][pos[i][j]] : seg_e[i][pos[i][j]]]
                for i in range(len(terms))
            ]
            order = cost_order([len(s) for s in slices])
            cur = (slices[order[0]] - base).astype(np.int32)
            for i in order[1:]:
                blong = bucketize(
                    slices[i] - base, max(width, 1), self.bucket_size_postings
                )
                cur, w2 = lookup_intersect(cur, blong)
                probes += w2["probes"]
                scanned += w2["scanned"]
            if len(cur):
                results.append(cur.astype(np.int64) + base)
        out = (
            np.concatenate(results).astype(np.int32)
            if results
            else np.empty(0, np.int32)
        )
        return out, probes, scanned

    @staticmethod
    def _work_dict(
        work_levels: List[float], probes: int, scanned: int
    ) -> Dict[str, float]:
        cluster_level = float(sum(work_levels))
        work = {f"level_{li}": float(w) for li, w in enumerate(work_levels)}
        work.update(
            {
                "cluster_level": cluster_level,
                "probes": float(probes),
                "scanned": float(scanned),
                "total": cluster_level + probes + scanned,
            }
        )
        return work

    # ------------------------------------------------------------------
    # Query algorithms
    # ------------------------------------------------------------------

    def query(self, *terms) -> Tuple[np.ndarray, Dict[str, float]]:
        """L-level conjunctive query over k >= 1 terms: a cost-ordered
        bucketed-Lookup chain at every cluster level, then the
        cost-ordered per-cluster posting chain.  Returns (result doc ids,
        work dict with per-level ``level_{l}`` keys plus the historical
        ``cluster_level/probes/scanned/total`` totals)."""
        terms = _flatten_terms(terms)
        common, pos, seg_s, seg_e, ranges, work_levels = self._descend(
            terms, merge=False
        )
        out, probes, scanned = self._leaf_chain(
            terms, common, pos, seg_s, seg_e, ranges
        )
        return out, self._work_dict(work_levels, probes, scanned)

    def query_all_clusters(self, *terms) -> Tuple[np.ndarray, Dict[str, float]]:
        """The descent WITHOUT the bucketed Lookup at the cluster levels:
        node lists are merge-joined directly (work = Σ lengths per chain
        stage) and the posting chain runs inside every common leaf
        cluster.  This is the 'most direct way' of §3.3 — competitive when
        k is small, and the oracle the bucketed chain of :meth:`query`
        must match exactly at every depth."""
        terms = _flatten_terms(terms)
        common, pos, seg_s, seg_e, ranges, work_levels = self._descend(
            terms, merge=True
        )
        out, probes, scanned = self._leaf_chain(
            terms, common, pos, seg_s, seg_e, ranges
        )
        return out, self._work_dict(work_levels, probes, scanned)

    def query_batch(self, queries) -> Tuple[np.ndarray, np.ndarray, Dict[str, float]]:
        """Vectorized :meth:`query` over a query batch — see
        ``repro.core.batched_query.batched_query`` (bit-identical results
        and work dicts, no per-query Python loop)."""
        from repro.core.batched_query import batched_query

        return batched_query(self, queries)

    def device(self):
        """The upload-once device mirror of this index
        (:class:`repro.core.device_engine.DeviceIndex`): ``post_docs``
        and every level CSR resident as device arrays, built on first
        call and cached on this object — every device batch afterwards
        reuses the same copy."""
        from repro.core.device_engine import device_index

        return device_index(self)


# ----------------------------------------------------------------------
# Construction
# ----------------------------------------------------------------------


def _rle_term_parent(
    ptr: np.ndarray, parent: np.ndarray, m: int, k: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """O(nnz) run-length encoding of (term, parent) runs over a per-term
    CSR whose items are already grouped by parent within each term.

    Returns ``(cl_ptr, cl_ids, seg_start, seg_end)`` where the segments
    are absolute slices into the item array ``ptr`` indexes.
    """
    term = np.repeat(np.arange(m, dtype=np.int64), np.diff(ptr))
    key = term * k + parent.astype(np.int64)
    change = np.empty(len(key), dtype=bool)
    if len(key):
        change[0] = True
        np.not_equal(key[1:], key[:-1], out=change[1:])
    starts = np.flatnonzero(change)
    ukey = key[starts]
    ends = np.append(starts[1:], len(key))
    cl_ids = (ukey % k).astype(np.int32)
    uterm = ukey // k
    cl_ptr = np.zeros(m + 1, dtype=np.int64)
    np.add.at(cl_ptr, uterm + 1, 1)
    np.cumsum(cl_ptr, out=cl_ptr)
    return cl_ptr, cl_ids, starts.astype(np.int64), ends.astype(np.int64)


def _check_nested(coarse: np.ndarray, fine: np.ndarray) -> None:
    """Every coarse boundary must be a fine boundary (children of a node
    occupy a contiguous block of the finer level)."""
    pos = np.searchsorted(fine, coarse)
    ok = (pos < len(fine)) & (fine[np.minimum(pos, len(fine) - 1)] == coarse)
    if not ok.all():
        bad = coarse[~ok]
        raise ValueError(
            f"level ranges are not nested: boundaries {bad[:5].tolist()} of a "
            "coarser level are not boundaries of the next finer level"
        )


def build_hier_index(
    reordered_index: InvertedIndex,
    level_ranges: Sequence[np.ndarray],
    bucket_size_clusters: int = 8,
    bucket_size_postings: int = 16,
) -> HierIndex:
    """Build an L-level index from nested per-level cluster boundaries.

    ``level_ranges`` runs coarse -> fine (L - 1 arrays; ``[]`` builds the
    flat L = 1 index); each is a ``(k_l + 1,)`` boundary array over the
    *reordered* (cluster-contiguous) document-id space, and every coarser
    boundary must also be a boundary of the next finer level.  O(nnz)
    per level via run-length encoding — the leaf level over the posting
    array, each upper level over the level below's ``cl_ids``.
    """
    level_ranges = [np.asarray(r, dtype=np.int64) for r in level_ranges]
    n = reordered_index.n_docs
    m = reordered_index.n_terms
    for r in level_ranges:
        if len(r) < 2 or r[0] != 0 or r[-1] != n or (np.diff(r) < 0).any():
            raise ValueError(
                "each level's ranges must be a nondecreasing boundary array "
                f"spanning [0, {n}], got {r[:5]}..."
            )
    for coarse, fine in zip(level_ranges, level_ranges[1:], strict=False):
        _check_nested(coarse, fine)

    if not level_ranges:
        return maybe_validate(
            HierIndex(
                levels=(),
                index=reordered_index,
                bucket_size_clusters=bucket_size_clusters,
                bucket_size_postings=bucket_size_postings,
            )
        )

    # Leaf level: RLE over (term, leaf cluster) pairs of the posting array.
    leaf_ranges = level_ranges[-1]
    docs = reordered_index.post_docs.astype(np.int64)
    parent = np.searchsorted(leaf_ranges, docs, side="right") - 1
    cl_ptr, cl_ids, seg_s, seg_e = _rle_term_parent(
        reordered_index.post_ptr, parent, m, len(leaf_ranges) - 1
    )
    levels = [
        HierLevel(
            cl_ptr=cl_ptr,
            cl_ids=cl_ids,
            seg_start=seg_s,
            seg_end=seg_e,
            ranges=leaf_ranges,
        )
    ]
    # Upper levels, fine -> coarse: the level-l entry of a term segments
    # the level-(l+1) cl_ids of that term by parent node.
    child_ranges = leaf_ranges
    for up_ranges in reversed(level_ranges[:-1]):
        child = levels[0]
        # Parent of each child NODE via its doc-range start (empty nodes
        # map somewhere harmlessly — they never appear in cl_ids).
        parent_of_node = (
            np.searchsorted(up_ranges, child_ranges[:-1], side="right") - 1
        ).astype(np.int64)
        parent_items = parent_of_node[child.cl_ids]
        cl_ptr, cl_ids, seg_s, seg_e = _rle_term_parent(
            child.cl_ptr, parent_items, m, len(up_ranges) - 1
        )
        levels.insert(
            0,
            HierLevel(
                cl_ptr=cl_ptr,
                cl_ids=cl_ids,
                seg_start=seg_s,
                seg_end=seg_e,
                ranges=up_ranges,
            ),
        )
        child_ranges = up_ranges
    return maybe_validate(
        HierIndex(
            levels=tuple(levels),
            index=reordered_index,
            bucket_size_clusters=bucket_size_clusters,
            bucket_size_postings=bucket_size_postings,
        )
    )


def shard_tops(hidx: HierIndex, n_shards: int) -> np.ndarray:
    """Contiguous partition of the top-level nodes into ``n_shards``
    shards, balanced by posting mass.

    Returns the ``(n_shards + 1,)`` top-node boundary array: shard s owns
    top nodes ``[bounds[s], bounds[s + 1])`` — and therefore (nested
    contiguous ranges) the contiguous doc-id range
    ``[top_ranges[bounds[s]], top_ranges[bounds[s + 1]])`` and every
    posting of every document in it.  Splits sit at the posting-mass
    quantiles, so shards carry roughly equal intersection work; with more
    shards than top nodes the tail shards come back empty (boundaries
    repeat) rather than splitting a top node — a top cluster is the
    paper's unit of machine-level distribution and never straddles two
    shards.
    """
    hidx = as_hier(hidx)
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    top_ranges = hidx.top_ranges
    k0 = len(top_ranges) - 1
    docs = hidx.index.post_docs.astype(np.int64)
    top_of_post = np.searchsorted(top_ranges, docs, side="right") - 1
    mass = np.bincount(top_of_post, minlength=k0).astype(np.int64)
    cum = np.concatenate([[0], np.cumsum(mass)])
    total = int(cum[-1])
    targets = total * np.arange(1, n_shards, dtype=np.float64) / n_shards
    cuts = np.searchsorted(cum, targets, side="left").astype(np.int64)
    bounds = np.concatenate([[0], np.minimum(cuts, k0), [k0]])
    return np.maximum.accumulate(bounds)


def as_hier(idx) -> HierIndex:
    """Coerce a query index to :class:`HierIndex`.

    Accepts a ``HierIndex`` (returned as-is) or anything exposing the
    two-level ``ClusterIndex`` protocol (``cl_ptr/cl_ids/seg_start/
    seg_end/ranges/index``) — the historical facade, viewed as the L = 2
    case without copying any array.
    """
    if isinstance(idx, HierIndex):
        return idx
    if hasattr(idx, "as_hier"):
        return idx.as_hier()
    return HierIndex(
        levels=(
            HierLevel(
                cl_ptr=idx.cl_ptr,
                cl_ids=idx.cl_ids,
                seg_start=idx.seg_start,
                seg_end=idx.seg_end,
                ranges=idx.ranges,
            ),
        ),
        index=idx.index,
        bucket_size_clusters=idx.bucket_size_clusters,
        bucket_size_postings=idx.bucket_size_postings,
    )
