"""TopDown hierarchical clustering (paper §3.2, "Refinements").

Flat K-means is at least linear in k per iteration, so for large k the
paper recursively *splits*: a subproblem of s documents (out of |D| total,
target k clusters) is split into ``min(χ, s·k/|D|)`` pieces while
``s > |D|/k``; χ = 8 by default (paper §4).  This yields between k and 2k
clusters, is orders of magnitude faster than flat clustering (paper
Fig. 6), and balances cluster sizes as a side effect.

Each split is solved by multilevel K-means at the small piece count, so the
per-level cost is O(χ·N_level) and the total O(χ·N·log_χ k).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.core.multilevel import multilevel_cluster
from repro.core.objective import FrequentTermView

__all__ = ["TopDownResult", "topdown_cluster"]


@dataclasses.dataclass
class TopDownResult:
    assign: np.ndarray  # (n_docs,) int64 in [0, k_actual)
    k_actual: int
    n_splits: int


def topdown_cluster(
    view: FrequentTermView,
    k: int,
    chi: int = 8,
    eps: float = 0.1,
    max_iters: int = 100,
    min_rel_improvement: float = 0.01,
    doc_grained_below: int = 2_048,
    seed: int = 0,
    kmeans_fn: Optional[Callable] = None,
) -> TopDownResult:
    """``kmeans_fn`` is forwarded to every ``multilevel_cluster`` split —
    pass ``repro.dist.cluster_dist.distributed_kmeans_fn(mesh)`` to solve
    the big top-level splits on the mesh while small recursion leaves stay
    on the host."""
    n_total = view.n_docs
    leaf_size = n_total / max(k, 1)
    next_cluster = 0
    n_splits = 0
    assign = np.zeros(n_total, dtype=np.int64)

    # Explicit stack; each entry is a doc-id array.
    stack: List[np.ndarray] = [np.arange(n_total, dtype=np.int64)]
    rng = np.random.default_rng(seed)
    while stack:
        ids = stack.pop()
        s = len(ids)
        if s <= leaf_size or s <= 1:
            assign[ids] = next_cluster
            next_cluster += 1
            continue
        q = int(min(chi, max(2, round(s * k / n_total))))
        q = min(q, s)  # never more pieces than documents
        sub = view.subset(ids)
        res = multilevel_cluster(
            sub,
            q,
            eps=eps,
            max_iters=max_iters,
            min_rel_improvement=min_rel_improvement,
            doc_grained_below=doc_grained_below,
            seed=int(rng.integers(0, 2**31)),
            kmeans_fn=kmeans_fn,
        )
        n_splits += 1
        pieces = 0
        for j in range(q):
            piece = ids[res.assign == j]
            if len(piece):
                stack.append(piece)
                pieces += 1
        if pieces <= 1:
            # Degenerate split (all docs identical): make it a leaf.
            stack.pop()
            assign[ids] = next_cluster
            next_cluster += 1
    return TopDownResult(assign=assign, k_actual=next_cluster, n_splits=n_splits)
