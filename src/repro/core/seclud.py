"""SecludPipeline — the end-to-end public API of the paper's system.

fit():   estimate P → frequent-term view → cluster (flat-multilevel "FM"
         or TopDown "TD") → recursively cluster the clusters for
         ``levels`` > 2 → nested reorder → build the cluster index and
         the L-level :class:`repro.core.hier_index.HierIndex`.
evaluate(): the paper's three speedups against the unclustered baseline
         (which, per [14], uses a *random* document permutation):

  * S_T — theoretical, from the ψ cost model (Eq. 2) evaluated on the
          actual query set;
  * S_C — measured work of the two-level cluster-index query;
  * S_R — measured work of the single-index Lookup query on the
          cluster-contiguously *reordered* index;
  * S_H — measured work of the L-level hierarchical descent (reported
          when ``fit(levels=L)`` built a depth other than 2, where it
          would equal S_C).

Every query algorithm returns the exact same result set (losslessness is
asserted, modulo the id permutation) — the paper's defining property, at
every depth.
"""

from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster_index import ClusterIndex, build_cluster_index
from repro.core.hier_index import HierIndex, build_hier_index
from repro.core.multilevel import multilevel_cluster
from repro.core.objective import (
    FrequentTermView,
    cluster_counts,
    frequent_term_view,
    hier_query_set_cost,
    psi_from_counts,
    query_set_cost,
)
from repro.core.queries import as_queries
from repro.core.reorder import cluster_ranges, reorder_permutation
from repro.core.topdown import topdown_cluster
from repro.data.corpus import Corpus
from repro.index.build import InvertedIndex, build_index, permute_docs
from repro.index.lookup import chain_lookup

if TYPE_CHECKING:  # deferred: repro.data.query_log itself imports
    from repro.data.query_log import QueryLog  # repro.core.queries

__all__ = ["SecludPipeline", "SecludResult"]


@dataclasses.dataclass
class SecludResult:
    assign: np.ndarray
    k: int
    perm: np.ndarray  # old doc id -> new doc id (cluster-contiguous)
    ranges: np.ndarray  # (k+1,) cluster boundaries in new id space
    psi: float
    psi_single: float
    cluster_time_s: float
    view: FrequentTermView
    base_index: InvertedIndex  # randomized ids (the [14] baseline)
    base_perm: np.ndarray
    reordered_index: InvertedIndex
    cluster_index: ClusterIndex
    # -- hierarchy (levels = 2 unless fit(levels=L) said otherwise) ------
    levels: int = 2
    level_ranges: Tuple[np.ndarray, ...] = ()  # coarse -> fine, L-1 arrays
    level_assigns: Tuple[np.ndarray, ...] = ()  # doc -> node id per level
    psi_levels: Tuple[float, ...] = ()  # ψ priced at each cluster level
    hier_index: Optional[HierIndex] = None
    # Upload-once device mirror of hier_index (repro.core.device_engine),
    # built by fit() so serving never pays the upload per batch.
    device_index: Optional[object] = None

    @property
    def s_t(self) -> float:
        """Theoretical speedup from ψ itself (frequent terms, Eq. 2)."""
        return self.psi_single / max(self.psi, 1e-30)

    def shard_slices(self, n_shards: int):
        """Host views of the fitted index, one per corpus shard — the
        partitioning a multi-machine deployment hands each machine.

        Shards are contiguous groups of top-level clusters balanced by
        posting mass (``repro.core.hier_index.shard_tops``); each view is
        the fitted :class:`HierIndex` restricted to its group
        (``slice_top``), sharing the underlying postings.  Returns
        ``(bounds, views)`` with ``bounds`` the ``(n_shards + 1,)``
        top-node boundaries and ``views`` the per-shard indexes.
        """
        from repro.core.hier_index import as_hier, shard_tops

        hidx = as_hier(
            self.hier_index
            if self.hier_index is not None
            else self.cluster_index
        )
        bounds = shard_tops(hidx, n_shards)
        views = [
            hidx.slice_top(int(lo), int(hi))
            for lo, hi in zip(bounds[:-1], bounds[1:], strict=True)
        ]
        return bounds, views


def _corpus_of_clusters(corpus: Corpus, assign: np.ndarray, k: int) -> Corpus:
    """The corpus whose "documents" are clusters: cluster j's term set is
    the union of its members' terms — presence, not counts, because the
    upper-level node lists the descent intersects are presence lists."""
    e_doc = np.repeat(
        np.arange(corpus.n_docs, dtype=np.int64), np.diff(corpus.doc_ptr)
    )
    key = assign[e_doc].astype(np.int64) * corpus.n_terms + corpus.doc_terms
    u = np.unique(key)
    cl = u // corpus.n_terms
    terms = (u % corpus.n_terms).astype(np.int32)
    ptr = np.zeros(k + 1, dtype=np.int64)
    np.add.at(ptr, cl + 1, 1)
    np.cumsum(ptr, out=ptr)
    return Corpus(doc_ptr=ptr, doc_terms=terms, n_terms=corpus.n_terms)


def _nest_level_assigns(raw_assigns):
    """Renumber raw per-level doc assignments (coarse -> fine) so node
    ids are nested and contiguous: sort documents by the level tuple, cut
    each level where its (coarser..self) prefix changes.  Empty nodes
    vanish; the finest renumbered assignment alone sorts documents into
    the hierarchy order (ties keep original doc order, so
    ``reorder_permutation`` of it IS the nested permutation)."""
    n = len(raw_assigns[-1])
    order = np.lexsort(tuple(reversed(raw_assigns)))
    level_assigns, level_ranges = [], []
    change = np.zeros(n, dtype=bool)
    if n:
        change[0] = True
    for raw in raw_assigns:
        col = raw[order]
        change = change.copy()
        if n > 1:
            change[1:] |= col[1:] != col[:-1]
        ids_sorted = np.cumsum(change) - 1
        new_a = np.empty(n, dtype=np.int64)
        new_a[order] = ids_sorted
        level_assigns.append(new_a)
        level_ranges.append(
            np.append(np.flatnonzero(change), n).astype(np.int64)
        )
    return level_assigns, level_ranges


class SecludPipeline:
    def __init__(
        self,
        tc: int = 10_000,
        bucket_size: int = 16,
        bucket_size_clusters: int = 8,
        eps: float = 0.1,
        chi: int = 8,
        doc_grained_below: int = 2_048,
        min_rel_improvement: float = 0.01,
        seed: int = 0,
    ):
        self.tc = tc
        self.bucket_size = bucket_size
        self.bucket_size_clusters = bucket_size_clusters
        self.eps = eps
        self.chi = chi
        self.doc_grained_below = doc_grained_below
        self.min_rel_improvement = min_rel_improvement
        self.seed = seed

    # ------------------------------------------------------------------

    def fit(
        self,
        corpus: Corpus,
        k: int,
        algo: str = "topdown",
        log: Optional[QueryLog] = None,
        p: Optional[np.ndarray] = None,
        levels: int = 2,
        level_ks: Optional[Sequence[int]] = None,
    ) -> SecludResult:
        """Cluster, reorder and index the corpus at depth ``levels``.

        ``levels = 2`` (default) is the paper's pipeline, bit-for-bit.
        ``levels = 1`` skips clustering entirely — the flat single-index
        Lookup baseline as a degenerate hierarchy.  ``levels >= 3``
        recursively clusters the clusters: the leaf clustering runs as
        usual, then each upper level clusters a corpus whose "documents"
        are the level below's clusters (term-presence sets), targeting
        ``level_ks`` (coarse -> fine, ``levels - 2`` values; default: the
        geometric ladder round(k^((i+1)/(L-1)))).  Document ids are
        renumbered so every level's nodes own nested contiguous ranges,
        and the result carries the L-level ``hier_index`` next to the
        historical two-level ``cluster_index`` (both exact).
        """
        if levels < 1:
            raise ValueError(f"levels must be >= 1, got {levels}")
        if p is None:
            from repro.data.query_log import term_probabilities

            p = term_probabilities(corpus.n_terms, log=log, corpus=corpus)
        view = frequent_term_view(corpus, p, tc=self.tc)

        t0 = time.perf_counter()
        if levels == 1:
            assign, k_actual = np.zeros(corpus.n_docs, dtype=np.int64), 1
        elif algo in ("flat", "fm"):
            res = multilevel_cluster(
                view,
                k,
                eps=self.eps,
                doc_grained_below=self.doc_grained_below,
                min_rel_improvement=self.min_rel_improvement,
                seed=self.seed,
            )
            assign, k_actual = res.assign, k
        elif algo in ("topdown", "td"):
            res = topdown_cluster(
                view,
                k,
                chi=self.chi,
                eps=self.eps,
                doc_grained_below=self.doc_grained_below,
                min_rel_improvement=self.min_rel_improvement,
                seed=self.seed,
            )
            assign, k_actual = res.assign, res.k_actual
        else:
            raise ValueError(f"unknown algo {algo!r}")

        if levels <= 2:
            level_assigns = [assign] if levels == 2 else []
        else:
            level_assigns = self._cluster_the_clusters(
                corpus, p, assign, k_actual, levels, level_ks
            )
        cluster_time = time.perf_counter() - t0

        if levels >= 3:
            # Renumber node ids per level so children of every node are
            # contiguous (nested ranges); the leaf renumbering replaces
            # `assign` and sorting by it alone reorders the documents.
            level_assigns, level_ranges = _nest_level_assigns(level_assigns)
            assign = level_assigns[-1]
            k_actual = len(level_ranges[-1]) - 1

        counts = cluster_counts(view, assign, k_actual)
        psi = psi_from_counts(counts, view.p_freq)
        psi_single = psi_from_counts(
            counts.sum(axis=0, keepdims=True), view.p_freq
        )

        index = build_index(corpus)
        rng = np.random.default_rng(self.seed + 7)
        base_perm = rng.permutation(corpus.n_docs)
        base_index = permute_docs(index, base_perm)

        perm = reorder_permutation(assign, k_actual)
        ranges = cluster_ranges(assign, k_actual)
        if levels <= 2:
            level_ranges = [ranges] if levels == 2 else []
        reordered = permute_docs(index, perm)
        cidx = build_cluster_index(
            reordered,
            ranges,
            bucket_size_clusters=self.bucket_size_clusters,
            bucket_size_postings=self.bucket_size,
        )
        hier = build_hier_index(
            reordered,
            level_ranges,
            bucket_size_clusters=self.bucket_size_clusters,
            bucket_size_postings=self.bucket_size,
        )
        if levels == 2:
            # The two-level facade's cached hier view IS this index:
            # share one object so the device upload is shared too.
            cidx.__dict__["_hier"] = hier
        psi_levels = tuple(
            psi_from_counts(
                cluster_counts(view, a, len(r) - 1), view.p_freq
            )
            for a, r in zip(level_assigns, level_ranges, strict=True)
        )
        # Upload the index once, now: every device batch (benchmarks,
        # SearchService, batched_counts) reuses this resident copy.
        from repro.core.device_engine import device_index as _build_device_index

        dev = _build_device_index(hier)
        return SecludResult(
            assign=assign,
            k=k_actual,
            perm=perm,
            ranges=ranges,
            psi=psi,
            psi_single=psi_single,
            cluster_time_s=cluster_time,
            view=view,
            base_index=base_index,
            base_perm=base_perm,
            reordered_index=reordered,
            cluster_index=cidx,
            levels=levels,
            level_ranges=tuple(level_ranges),
            level_assigns=tuple(level_assigns),
            psi_levels=psi_levels,
            hier_index=hier,
            device_index=dev,
        )

    def _cluster_the_clusters(
        self,
        corpus: Corpus,
        p: np.ndarray,
        assign: np.ndarray,
        k_actual: int,
        levels: int,
        level_ks: Optional[Sequence[int]],
    ):
        """Raw (un-renumbered) doc-level assignments for every cluster
        level, coarse -> fine, by recursively clustering the clusters."""
        if level_ks is not None:
            upper_ks = [int(x) for x in level_ks]
            if len(upper_ks) != levels - 2:
                raise ValueError(
                    f"level_ks needs {levels - 2} entries (coarse -> fine "
                    f"above the leaf), got {len(upper_ks)}"
                )
        else:
            upper_ks = [
                max(2, int(round(k_actual ** ((i + 1) / (levels - 1)))))
                for i in range(levels - 2)
            ]
        level_assigns = [assign]
        cur, k_cur = assign, k_actual
        for depth_up, k_up in enumerate(reversed(upper_ks)):
            k_up = min(k_up, k_cur)
            cl_corpus = _corpus_of_clusters(corpus, cur, k_cur)
            view_up = frequent_term_view(cl_corpus, p, tc=self.tc)
            up = multilevel_cluster(
                view_up,
                k_up,
                eps=self.eps,
                doc_grained_below=self.doc_grained_below,
                min_rel_improvement=self.min_rel_improvement,
                seed=self.seed + 101 * (depth_up + 1),
            ).assign
            cur = up[cur]
            k_cur = k_up
            level_assigns.insert(0, cur)
        return level_assigns

    # ------------------------------------------------------------------

    def evaluate(
        self,
        corpus: Corpus,
        result: SecludResult,
        log: QueryLog,
        check_lossless: bool = True,
        max_queries: Optional[int] = None,
        cost_model: str = "lookup",
        batched: bool = False,
    ) -> Dict[str, float]:
        """Work-metric speedups S_T / S_C / S_R over the query log.

        Queries may be any arity >= 1 (``log.queries`` is the padded
        rectangular form; ragged rows carry ``QUERY_PAD``).  The baseline
        and S_R paths chain the single-index Lookup smallest-list-first;
        S_C runs the cost-ordered two-level query.

        ``batched=True`` runs the vectorized engine
        (``repro.core.batched_query``) instead of the per-query Python
        loop: identical work dict (the engine is bit-exact), plus
        wall-clock timings ``t_baseline_s`` / ``t_cluster_index_s`` /
        ``t_reordered_s``.

        When the result was fit at a depth other than 2 the report adds
        the hierarchical descent: ``S_H`` / ``work_hier`` (measured, also
        lossless-checked), ``depth``, and the theoretical ``S_T_hier``
        from :func:`repro.core.objective.hier_query_set_cost`.
        """
        # `max_queries=0` must mean "no queries", not "the full log".
        queries = log.queries[:max_queries] if max_queries is not None else log.queries
        if batched:
            return self._evaluate_batched(
                corpus, result, queries, check_lossless, cost_model
            )
        cq = as_queries(np.asarray(queries))
        n_docs = corpus.n_docs
        hier = self._hier_of(result)

        def chain(index, terms):
            """Cost-ordered single-index Lookup chain (k=2: the shorter
            list probes the longer — the historical loop)."""
            lists = [index.postings(int(t)) for t in terms]
            return chain_lookup(lists, n_docs, self.bucket_size)

        base_total = 0.0
        sc_total = 0.0
        sr_total = 0.0
        sh_total = 0.0
        inv_base = np.empty(n_docs, dtype=np.int64)
        inv_base[result.base_perm] = np.arange(n_docs)
        inv_perm = np.empty(n_docs, dtype=np.int64)
        inv_perm[result.perm] = np.arange(n_docs)

        for terms in cq:
            # Baseline: Lookup on the randomized single index.
            r0, w0 = chain(result.base_index, terms)
            base_total += w0
            # S_C: two-level cluster-index query.
            r1, w1 = result.cluster_index.query(*terms)
            sc_total += w1["total"]
            # S_R: single-index Lookup on the reordered index.
            r2, w2 = chain(result.reordered_index, terms)
            sr_total += w2
            # S_H: the L-level descent (only when depth differs from 2).
            r3 = None
            if hier is not None:
                r3, w3 = hier.query(*terms)
                sh_total += w3["total"]
            if check_lossless:
                s0 = np.sort(inv_base[r0])
                s1 = np.sort(inv_perm[r1])
                s2 = np.sort(inv_perm[r2])
                assert np.array_equal(s0, s1) and np.array_equal(s0, s2), (
                    f"lossless violation on query {tuple(terms)}"
                )
                if r3 is not None:
                    assert np.array_equal(s0, np.sort(inv_perm[r3])), (
                        f"lossless violation (hier) on query {tuple(terms)}"
                    )

        extra = self._hier_report(corpus, result, cq, cost_model, base_total, sh_total)
        return self._speedup_report(
            corpus, result, queries, cost_model, base_total, sc_total, sr_total,
            **extra,
        )

    @staticmethod
    def _hier_of(result: SecludResult) -> Optional[HierIndex]:
        """The hierarchical index to measure separately, or None when it
        coincides with the two-level cluster index (S_H ≡ S_C)."""
        hier = getattr(result, "hier_index", None)
        if hier is None or hier.depth == 2:
            return None
        return hier

    def _hier_report(
        self,
        corpus: Corpus,
        result: SecludResult,
        queries,
        cost_model: str,
        base_total: float,
        sh_total: float,
    ) -> Dict[str, float]:
        hier = self._hier_of(result)
        if hier is None:
            return {}
        hc = hier_query_set_cost(
            corpus,
            result.level_assigns,
            [len(r) - 1 for r in result.level_ranges],
            queries,
            model=cost_model,
        )
        flat = query_set_cost(corpus, None, 1, queries, model=cost_model)
        return {
            "S_H": base_total / max(sh_total, 1e-30),
            "work_hier": sh_total,
            "depth": float(hier.depth),
            "S_T_hier": flat / max(hc["total"], 1e-30),
        }

    def _speedup_report(
        self,
        corpus: Corpus,
        result: SecludResult,
        queries: np.ndarray,
        cost_model: str,
        base_total: float,
        sc_total: float,
        sr_total: float,
        **extra: float,
    ) -> Dict[str, float]:
        s_t = (
            query_set_cost(corpus, None, 1, queries, model=cost_model)
            / max(
                query_set_cost(
                    corpus, result.assign, result.k, queries, model=cost_model
                ),
                1e-30,
            )
        )
        return {
            "S_T": float(s_t),
            "S_C": base_total / max(sc_total, 1e-30),
            "S_R": base_total / max(sr_total, 1e-30),
            "work_baseline": base_total,
            "work_cluster_index": sc_total,
            "work_reordered": sr_total,
            "n_queries": float(len(queries)),
            "psi": result.psi,
            "psi_single": result.psi_single,
            "S_T_objective": result.s_t,
            **extra,
        }

    def _evaluate_batched(
        self,
        corpus: Corpus,
        result: SecludResult,
        queries: np.ndarray,
        check_lossless: bool,
        cost_model: str,
    ) -> Dict[str, float]:
        """The batched fast path: one engine call per algorithm, no
        per-query Python loop.  Work numbers are bit-identical to the
        looped path (the engine replicates Lookup's accounting exactly)."""
        from repro.core.batched_query import batched_lookup, batched_query

        cq = as_queries(np.asarray(queries))
        n_docs = corpus.n_docs
        hier = self._hier_of(result)

        t0 = time.perf_counter()
        ptr0, docs0, w0 = batched_lookup(
            result.base_index, cq, bucket_size=self.bucket_size
        )
        t_base = time.perf_counter() - t0
        t0 = time.perf_counter()
        ptr1, docs1, w1 = batched_query(result.cluster_index, cq)
        t_cluster = time.perf_counter() - t0
        t0 = time.perf_counter()
        ptr2, docs2, w2 = batched_lookup(
            result.reordered_index, cq, bucket_size=self.bucket_size
        )
        t_reordered = time.perf_counter() - t0
        ptr3 = docs3 = None
        extra: Dict[str, float] = {}
        if hier is not None:
            t0 = time.perf_counter()
            ptr3, docs3, w3 = batched_query(hier, cq)
            extra = self._hier_report(
                corpus, result, cq, cost_model, w0["total"], w3["total"]
            )
            extra["t_hier_s"] = time.perf_counter() - t0

        if check_lossless:
            inv_base = np.empty(n_docs, dtype=np.int64)
            inv_base[result.base_perm] = np.arange(n_docs)
            inv_perm = np.empty(n_docs, dtype=np.int64)
            inv_perm[result.perm] = np.arange(n_docs)
            assert np.array_equal(ptr0, ptr1) and np.array_equal(ptr0, ptr2), (
                "lossless violation: per-query result counts differ"
            )
            # Sort each per-query segment in original-id space and compare.
            qid = np.repeat(np.arange(cq.n_queries), np.diff(ptr0))

            def canon(docs, inv):
                mapped = inv[docs]
                return mapped[np.lexsort((mapped, qid))]

            s0 = canon(docs0, inv_base)
            assert np.array_equal(s0, canon(docs1, inv_perm)) and np.array_equal(
                s0, canon(docs2, inv_perm)
            ), "lossless violation: result sets differ"
            if ptr3 is not None:
                assert np.array_equal(ptr0, ptr3) and np.array_equal(
                    s0, canon(docs3, inv_perm)
                ), "lossless violation: hierarchical result sets differ"

        return self._speedup_report(
            corpus,
            result,
            cq,
            cost_model,
            w0["total"],
            w1["total"],
            w2["total"],
            t_baseline_s=t_base,
            t_cluster_index_s=t_cluster,
            t_reordered_s=t_reordered,
            **extra,
        )
