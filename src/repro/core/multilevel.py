"""Multilevel initialization for K-means (paper §3.2, "Refinements").

For a scaling factor ε < 1: take a sample of size max(k, ε·|D|), cluster it
*recursively* into k clusters (trivial base case |D| = k: one document per
cluster), then initialize the full problem from the sample's clustering and
run K-means.  The paper notes this initialization "may be of independent
interest" — it converges far faster than random init because each level
starts from a high-quality coarse solution.

The base case and small levels use the document-grained update mode
(oscillation fix, paper §3.2 last paragraph).
"""

from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core.kmeans import KMeansResult, kmeans
from repro.core.objective import (
    FrequentTermView,
    assignment_scores,
    cluster_counts,
    delta_add_tables,
)

__all__ = ["multilevel_cluster"]


def multilevel_cluster(
    view: FrequentTermView,
    k: int,
    eps: float = 0.1,
    max_iters: int = 100,
    min_rel_improvement: float = 0.01,
    doc_grained_below: int = 2_048,
    seed: int = 0,
    kmeans_fn: Optional[Callable[..., KMeansResult]] = None,
    _depth: int = 0,
) -> KMeansResult:
    """Recursive ε-sampling initialization + K-means at every level.

    ``kmeans_fn`` replaces the host K-means at every level — pass
    ``repro.dist.cluster_dist.distributed_kmeans_fn(mesh)`` to run the
    large levels mesh-sharded.  It must accept the keyword signature of
    :func:`repro.core.kmeans.kmeans`.
    """
    solve = kmeans_fn or kmeans
    n = view.n_docs
    rng = np.random.default_rng(seed + 1_000_003 * _depth)
    base = max(k, doc_grained_below // 2)

    sample_size = max(k, int(np.ceil(eps * n)))
    if n <= base or sample_size >= n or eps >= 1.0:
        # Base level: trivial init (round-robin over a random permutation —
        # for |D| == k this is exactly "one document per cluster").
        init = np.empty(n, dtype=np.int64)
        init[rng.permutation(n)] = np.arange(n) % k
        return solve(
            view,
            k,
            init_assign=init,
            max_iters=max_iters,
            min_rel_improvement=min_rel_improvement,
            doc_grained_below=doc_grained_below,
            seed=seed,
        )

    sample_ids = rng.choice(n, size=sample_size, replace=False)
    sub = view.subset(sample_ids)
    sub_res = multilevel_cluster(
        sub,
        k,
        eps=eps,
        max_iters=max_iters,
        min_rel_improvement=min_rel_improvement,
        doc_grained_below=doc_grained_below,
        seed=seed,
        kmeans_fn=kmeans_fn,
        _depth=_depth + 1,
    )

    # Project the sample clustering to all documents: score every document
    # against the sample clusters' δ⁺ tables, take the argmin.
    counts = cluster_counts(sub, sub_res.assign, k)
    tables = delta_add_tables(counts, view.p_freq)
    init = np.argmin(assignment_scores(view, tables), axis=1)
    # Keep the sample's assignments (they were optimized at this k).
    init[sample_ids] = sub_res.assign

    return solve(
        view,
        k,
        init_assign=init,
        max_iters=max_iters,
        min_rel_improvement=min_rel_improvement,
        doc_grained_below=doc_grained_below,
        seed=seed,
    )
