"""The SeCluD objective ψ and its incremental δ lookup tables (paper §3.1–3.2).

For a clustering C with per-cluster term counts ``n_i(t)`` and independent
query-term marginals P[t], the expected conjunctive-query cost is

    ψ(C) = Σ_{t<u} P[t]·P[u] · Σ_i min(n_i(t), n_i(u))          (Eq. 2)

The marginal cost of ADDING a document containing term t to cluster j is

    δ_j⁺(t) = P[t] · Σ_{u≠t, n_j(t) < n_j(u)} P[u]

(only pairs where t is the *strictly smaller* list get more expensive), and
of REMOVING it

    δ_j⁻(t) = −P[t] · Σ_{u≠t, n_j(t) ≤ n_j(u)} P[u]

(the min shrinks whenever t's list is the smaller-or-equal one).  Both are
O(1) per (cluster, term) after building a lookup table: sort the cluster's
counts, suffix-sum the P's in sorted order, and map each term through a
``searchsorted`` on its own count (this also handles ties *exactly* — the
paper's "n_j(t) < n_j(u)" is strict).

Everything here is restricted to the TC most frequent terms (paper §3.2
"Ignoring Infrequent Terms"): rare terms contribute negligibly to query
cost but dominate the vocabulary.

Implementation notes: numpy + scipy.sparse on the host (the clustering
driver is recursion-heavy and runs on CPU; zero-compile vectorized numpy is
the right tool), with jit'd JAX equivalents in ``repro.core.jax_ops`` used
by the distributed/TPU path and cross-validated in tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import scipy.sparse as sp

from repro.data.corpus import Corpus

__all__ = [
    "FrequentTermView",
    "frequent_term_view",
    "cluster_counts",
    "psi_from_counts",
    "delta_add_tables",
    "delta_remove_tables",
    "assignment_scores",
    "query_set_cost",
]


@dataclasses.dataclass
class FrequentTermView:
    """A corpus restricted to its TC most frequent terms.

    * ``edge_doc`` / ``edge_rank`` — COO edges (document, frequent-term
      rank); rank ∈ [0, TC).
    * ``p_freq``  — P[t] for the frequent terms, in rank order.
    * ``rank_of_term`` — n_terms array, −1 for infrequent terms.
    * ``term_of_rank`` — TC array of original term ids.
    * ``mat`` — CSR (n_docs × TC) with values P[rank] (the SpMM operand:
      scores = mat @ tablesᵀ).
    """

    edge_doc: np.ndarray
    edge_rank: np.ndarray
    p_freq: np.ndarray
    rank_of_term: np.ndarray
    term_of_rank: np.ndarray
    mat: sp.csr_matrix
    n_docs: int

    @property
    def tc(self) -> int:
        return len(self.term_of_rank)

    def subset(self, doc_ids: np.ndarray) -> "FrequentTermView":
        """Row-subset view (multilevel sampling / TopDown recursion).

        Keeps the global rank space and P so tables remain comparable.
        """
        doc_ids = np.asarray(doc_ids)
        sub = self.mat[doc_ids]
        coo = sub.tocoo()
        return FrequentTermView(
            edge_doc=coo.row.astype(np.int64),
            edge_rank=coo.col.astype(np.int32),
            p_freq=self.p_freq,
            rank_of_term=self.rank_of_term,
            term_of_rank=self.term_of_rank,
            mat=sub.tocsr(),
            n_docs=len(doc_ids),
        )


def frequent_term_view(
    corpus: Corpus, p: np.ndarray, tc: int = 10_000
) -> FrequentTermView:
    """Restrict a corpus to its ``tc`` highest-P terms (§3.2).

    The paper selects by frequency; selecting by P[t] is equivalent when P
    is estimated from frequencies and strictly better when P comes from a
    query log (we care about *query* cost). Ties broken by term id.
    """
    m = corpus.n_terms
    tc = min(tc, m)
    top = np.argpartition(-p, tc - 1)[:tc] if tc < m else np.arange(m)
    top = top[np.argsort(-p[top], kind="stable")]
    rank_of_term = np.full(m, -1, dtype=np.int32)
    rank_of_term[top] = np.arange(tc, dtype=np.int32)

    ranks_all = rank_of_term[corpus.doc_terms]
    keep = ranks_all >= 0
    edge_rank = ranks_all[keep].astype(np.int32)
    edge_doc = np.repeat(
        np.arange(corpus.n_docs, dtype=np.int64), np.diff(corpus.doc_ptr)
    )[keep]
    p_freq = p[top].astype(np.float64)

    mat = sp.csr_matrix(
        (p_freq[edge_rank], (edge_doc, edge_rank)),
        shape=(corpus.n_docs, tc),
        dtype=np.float64,
    )
    return FrequentTermView(
        edge_doc=edge_doc,
        edge_rank=edge_rank,
        p_freq=p_freq,
        rank_of_term=rank_of_term,
        term_of_rank=top.astype(np.int32),
        mat=mat,
        n_docs=corpus.n_docs,
    )


def cluster_counts(view: FrequentTermView, assign: np.ndarray, k: int) -> np.ndarray:
    """n_j(t): (k, TC) int64 — documents of cluster j containing rank-t term."""
    key = assign[view.edge_doc].astype(np.int64) * view.tc + view.edge_rank
    return np.bincount(key, minlength=k * view.tc).reshape(k, view.tc)


def psi_from_counts(counts: np.ndarray, p_freq: np.ndarray) -> float:
    """ψ = Σ_i Σ_{t<u} P_t P_u min(n_i(t), n_i(u)), exactly, in O(k·TC·log TC).

    Per cluster: sort terms by count ascending; then min(n_t, n_u) for any
    pair is the count of the earlier-sorted one (ties give the same value
    either way), so ψ_i = Σ_j P_(j) · n_(j) · (Σ_{l>j} P_(l)).
    """
    counts = np.asarray(counts)
    order = np.argsort(counts, axis=1, kind="stable")
    n_sorted = np.take_along_axis(counts, order, axis=1).astype(np.float64)
    p_sorted = p_freq[order]
    # suffix[l] = sum of p_sorted[l+1:]
    suffix = np.cumsum(p_sorted[:, ::-1], axis=1)[:, ::-1]
    suffix = np.concatenate([suffix[:, 1:], np.zeros((len(counts), 1))], axis=1)
    return float((p_sorted * n_sorted * suffix).sum())


def _sorted_tables(
    counts: np.ndarray, p_freq: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-cluster (sorted counts, suffix-P) — shared by δ⁺ and δ⁻."""
    order = np.argsort(counts, axis=1, kind="stable")
    n_sorted = np.take_along_axis(counts, order, axis=1)
    p_sorted = p_freq[order]
    # suffix_incl[l] = sum of p_sorted[l:]
    suffix_incl = np.cumsum(p_sorted[:, ::-1], axis=1)[:, ::-1]
    return n_sorted, suffix_incl


def delta_add_tables(counts: np.ndarray, p_freq: np.ndarray) -> np.ndarray:
    """S⁺[j, t] = Σ_{u: n_j(u) > n_j(t)} P_u  (strict; excludes u = t).

    δ_j⁺(t) = P[t]·S⁺[j, t]; δ_j⁺(d) = Σ_{t∈d} δ_j⁺(t) = (view.mat @ S⁺ᵀ)[d, j].
    """
    counts = np.asarray(counts)
    k, tc = counts.shape
    n_sorted, suffix_incl = _sorted_tables(counts, p_freq)
    out = np.empty((k, tc), dtype=np.float64)
    pad = np.zeros(1)
    for j in range(k):  # k rows; each row one vectorized searchsorted
        idx = np.searchsorted(n_sorted[j], counts[j], side="right")
        suf = np.concatenate([suffix_incl[j], pad])
        out[j] = suf[idx]
    return out


def delta_remove_tables(counts: np.ndarray, p_freq: np.ndarray) -> np.ndarray:
    """S⁻[j, t] = Σ_{u≠t: n_j(u) ≥ n_j(t)} P_u  (paper §6: removal matters
    for small clusters; used by the document-grained update mode)."""
    counts = np.asarray(counts)
    k, tc = counts.shape
    n_sorted, suffix_incl = _sorted_tables(counts, p_freq)
    out = np.empty((k, tc), dtype=np.float64)
    pad = np.zeros(1)
    for j in range(k):
        idx = np.searchsorted(n_sorted[j], counts[j], side="left")
        suf = np.concatenate([suffix_incl[j], pad])
        out[j] = suf[idx] - p_freq  # drop u = t (its count ≥ itself)
    return out


def assignment_scores(view: FrequentTermView, tables: np.ndarray) -> np.ndarray:
    """(n_docs, k) δ⁺ scores: one sparse-dense matmul (the SpMM hot loop;
    the Pallas kernel `repro.kernels.cluster_score` is the TPU version)."""
    return np.asarray(view.mat @ tables.T)


def query_set_cost(
    corpus: Corpus,
    assign: Optional[np.ndarray],
    k: int,
    queries,
    model: str = "lookup",
) -> float:
    """Theoretical per-cluster cost of an explicit conjunctive query set.

    For a query with per-cluster term counts (c_1, ..., c_a) the chain
    cost in cluster i is modeled as Σ_{s ≠ argmin} Φ(min_j c_j, c_s): the
    smallest list is the running probe side of the cost-ordered plan and
    Φ prices each of the a−1 pairwise reductions.  For 2-term queries
    this is exactly the paper's Σ_q Σ_i Φ(n_i(t_q), n_i(u_q)); single-term
    queries cost 0 (no intersection happens).

    ``assign=None`` means the unclustered baseline (k = 1).  Used for the
    theoretical speedup S_T on held-out query logs — note this uses FULL
    term counts, not the TC-restricted view (queries hit rare terms too).
    ``queries`` is any form ``repro.core.queries.as_queries`` accepts.
    """
    from repro.core.queries import as_queries
    from repro.index.intersect import pair_cost

    cq = as_queries(queries)
    terms = np.unique(cq.q_terms)
    rows = np.searchsorted(terms, cq.q_terms)  # (nnz,) rank of each slot

    if assign is None:
        assign = np.zeros(corpus.n_docs, dtype=np.int64)
        k = 1
    # counts over only the queried terms: (len(terms), k)
    sel = np.isin(corpus.doc_terms, terms)
    e_term = corpus.doc_terms[sel]
    e_doc = np.repeat(
        np.arange(corpus.n_docs, dtype=np.int64), np.diff(corpus.doc_ptr)
    )[sel]
    e_rank = np.searchsorted(terms, e_term)
    cnt = np.bincount(
        e_rank.astype(np.int64) * k + assign[e_doc], minlength=len(terms) * k
    ).reshape(len(terms), k)

    if cq.n_queries == 0:
        return 0.0
    c = cnt[rows]  # (nnz, k) per-slot per-cluster counts
    # x: per-query per-cluster minimum — the probing side of the chain.
    x = np.minimum.reduceat(c, cq.q_ptr[:-1], axis=0)  # (nq, k)
    qid = np.repeat(np.arange(cq.n_queries), cq.arities)
    # Σ_slots Φ(x, c_s) − Φ(x, x): the min slot contributes Φ(x, x) which
    # cancels, leaving one Φ per actual chain stage.
    return float(pair_cost(x[qid], c, model).sum() - pair_cost(x, x, model).sum())
