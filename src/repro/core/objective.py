"""The SeCluD objective ψ and its incremental δ lookup tables (paper §3.1–3.2).

For a clustering C with per-cluster term counts ``n_i(t)`` and independent
query-term marginals P[t], the expected conjunctive-query cost is

    ψ(C) = Σ_{t<u} P[t]·P[u] · Σ_i min(n_i(t), n_i(u))          (Eq. 2)

The marginal cost of ADDING a document containing term t to cluster j is

    δ_j⁺(t) = P[t] · Σ_{u≠t, n_j(t) < n_j(u)} P[u]

(only pairs where t is the *strictly smaller* list get more expensive), and
of REMOVING it

    δ_j⁻(t) = −P[t] · Σ_{u≠t, n_j(t) ≤ n_j(u)} P[u]

(the min shrinks whenever t's list is the smaller-or-equal one).  Both are
O(1) per (cluster, term) after building a lookup table: sort the cluster's
counts, suffix-sum the P's in sorted order, and map each term through a
``searchsorted`` on its own count (this also handles ties *exactly* — the
paper's "n_j(t) < n_j(u)" is strict).

Everything here is restricted to the TC most frequent terms (paper §3.2
"Ignoring Infrequent Terms"): rare terms contribute negligibly to query
cost but dominate the vocabulary.

Implementation notes: numpy + scipy.sparse on the host (the clustering
driver is recursion-heavy and runs on CPU; zero-compile vectorized numpy is
the right tool), with jit'd JAX equivalents in ``repro.core.jax_ops`` used
by the distributed/TPU path and cross-validated in tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import scipy.sparse as sp

from repro.data.corpus import Corpus

__all__ = [
    "FrequentTermView",
    "frequent_term_view",
    "cluster_counts",
    "psi_from_counts",
    "delta_add_tables",
    "delta_remove_tables",
    "assignment_scores",
    "query_set_cost",
    "hier_query_set_cost",
]


@dataclasses.dataclass
class FrequentTermView:
    """A corpus restricted to its TC most frequent terms.

    * ``edge_doc`` / ``edge_rank`` — COO edges (document, frequent-term
      rank); rank ∈ [0, TC).
    * ``p_freq``  — P[t] for the frequent terms, in rank order.
    * ``rank_of_term`` — n_terms array, −1 for infrequent terms.
    * ``term_of_rank`` — TC array of original term ids.
    * ``mat`` — CSR (n_docs × TC) with values P[rank] (the SpMM operand:
      scores = mat @ tablesᵀ).
    """

    edge_doc: np.ndarray
    edge_rank: np.ndarray
    p_freq: np.ndarray
    rank_of_term: np.ndarray
    term_of_rank: np.ndarray
    mat: sp.csr_matrix
    n_docs: int

    @property
    def tc(self) -> int:
        return len(self.term_of_rank)

    def subset(self, doc_ids: np.ndarray) -> "FrequentTermView":
        """Row-subset view (multilevel sampling / TopDown recursion).

        Keeps the global rank space and P so tables remain comparable.
        """
        doc_ids = np.asarray(doc_ids)
        sub = self.mat[doc_ids]
        coo = sub.tocoo()
        return FrequentTermView(
            edge_doc=coo.row.astype(np.int64),
            edge_rank=coo.col.astype(np.int32),
            p_freq=self.p_freq,
            rank_of_term=self.rank_of_term,
            term_of_rank=self.term_of_rank,
            mat=sub.tocsr(),
            n_docs=len(doc_ids),
        )


def frequent_term_view(
    corpus: Corpus, p: np.ndarray, tc: int = 10_000
) -> FrequentTermView:
    """Restrict a corpus to its ``tc`` highest-P terms (§3.2).

    The paper selects by frequency; selecting by P[t] is equivalent when P
    is estimated from frequencies and strictly better when P comes from a
    query log (we care about *query* cost). Ties broken by term id.
    """
    m = corpus.n_terms
    tc = min(tc, m)
    top = np.argpartition(-p, tc - 1)[:tc] if tc < m else np.arange(m)
    top = top[np.argsort(-p[top], kind="stable")]
    rank_of_term = np.full(m, -1, dtype=np.int32)
    rank_of_term[top] = np.arange(tc, dtype=np.int32)

    ranks_all = rank_of_term[corpus.doc_terms]
    keep = ranks_all >= 0
    edge_rank = ranks_all[keep].astype(np.int32)
    edge_doc = np.repeat(
        np.arange(corpus.n_docs, dtype=np.int64), np.diff(corpus.doc_ptr)
    )[keep]
    p_freq = p[top].astype(np.float64)

    mat = sp.csr_matrix(
        (p_freq[edge_rank], (edge_doc, edge_rank)),
        shape=(corpus.n_docs, tc),
        dtype=np.float64,
    )
    return FrequentTermView(
        edge_doc=edge_doc,
        edge_rank=edge_rank,
        p_freq=p_freq,
        rank_of_term=rank_of_term,
        term_of_rank=top.astype(np.int32),
        mat=mat,
        n_docs=corpus.n_docs,
    )


def cluster_counts(view: FrequentTermView, assign: np.ndarray, k: int) -> np.ndarray:
    """n_j(t): (k, TC) int64 — documents of cluster j containing rank-t term."""
    key = assign[view.edge_doc].astype(np.int64) * view.tc + view.edge_rank
    return np.bincount(key, minlength=k * view.tc).reshape(k, view.tc)


def psi_from_counts(counts: np.ndarray, p_freq: np.ndarray) -> float:
    """ψ = Σ_i Σ_{t<u} P_t P_u min(n_i(t), n_i(u)), exactly, in O(k·TC·log TC).

    Per cluster: sort terms by count ascending; then min(n_t, n_u) for any
    pair is the count of the earlier-sorted one (ties give the same value
    either way), so ψ_i = Σ_j P_(j) · n_(j) · (Σ_{l>j} P_(l)).
    """
    counts = np.asarray(counts)
    order = np.argsort(counts, axis=1, kind="stable")
    n_sorted = np.take_along_axis(counts, order, axis=1).astype(np.float64)
    p_sorted = p_freq[order]
    # suffix[l] = sum of p_sorted[l+1:]
    suffix = np.cumsum(p_sorted[:, ::-1], axis=1)[:, ::-1]
    suffix = np.concatenate([suffix[:, 1:], np.zeros((len(counts), 1))], axis=1)
    return float((p_sorted * n_sorted * suffix).sum())


def _sorted_tables(
    counts: np.ndarray, p_freq: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-cluster (sorted counts, suffix-P) — shared by δ⁺ and δ⁻."""
    order = np.argsort(counts, axis=1, kind="stable")
    n_sorted = np.take_along_axis(counts, order, axis=1)
    p_sorted = p_freq[order]
    # suffix_incl[l] = sum of p_sorted[l:]
    suffix_incl = np.cumsum(p_sorted[:, ::-1], axis=1)[:, ::-1]
    return n_sorted, suffix_incl


def delta_add_tables(counts: np.ndarray, p_freq: np.ndarray) -> np.ndarray:
    """S⁺[j, t] = Σ_{u: n_j(u) > n_j(t)} P_u  (strict; excludes u = t).

    δ_j⁺(t) = P[t]·S⁺[j, t]; δ_j⁺(d) = Σ_{t∈d} δ_j⁺(t) = (view.mat @ S⁺ᵀ)[d, j].
    """
    counts = np.asarray(counts)
    k, tc = counts.shape
    n_sorted, suffix_incl = _sorted_tables(counts, p_freq)
    out = np.empty((k, tc), dtype=np.float64)
    pad = np.zeros(1)
    for j in range(k):  # k rows; each row one vectorized searchsorted
        idx = np.searchsorted(n_sorted[j], counts[j], side="right")
        suf = np.concatenate([suffix_incl[j], pad])
        out[j] = suf[idx]
    return out


def delta_remove_tables(counts: np.ndarray, p_freq: np.ndarray) -> np.ndarray:
    """S⁻[j, t] = Σ_{u≠t: n_j(u) ≥ n_j(t)} P_u  (paper §6: removal matters
    for small clusters; used by the document-grained update mode)."""
    counts = np.asarray(counts)
    k, tc = counts.shape
    n_sorted, suffix_incl = _sorted_tables(counts, p_freq)
    out = np.empty((k, tc), dtype=np.float64)
    pad = np.zeros(1)
    for j in range(k):
        idx = np.searchsorted(n_sorted[j], counts[j], side="left")
        suf = np.concatenate([suffix_incl[j], pad])
        out[j] = suf[idx] - p_freq  # drop u = t (its count ≥ itself)
    return out


def assignment_scores(view: FrequentTermView, tables: np.ndarray) -> np.ndarray:
    """(n_docs, k) δ⁺ scores: one sparse-dense matmul (the SpMM hot loop;
    the Pallas kernel `repro.kernels.cluster_score` is the TPU version)."""
    return np.asarray(view.mat @ tables.T)


def _queried_term_edges(
    corpus: Corpus, terms: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """(term rank, doc id) of every corpus edge touching a queried term —
    the O(nnz) selection scan, hoisted so multi-level pricing pays it
    once (FULL term counts, not the TC-restricted view — queries hit
    rare terms too)."""
    sel = np.isin(corpus.doc_terms, terms)
    e_rank = np.searchsorted(terms, corpus.doc_terms[sel]).astype(np.int64)
    e_doc = np.repeat(
        np.arange(corpus.n_docs, dtype=np.int64), np.diff(corpus.doc_ptr)
    )[sel]
    return e_rank, e_doc


def _counts_from_edges(
    e_rank: np.ndarray,
    e_doc: np.ndarray,
    assign: np.ndarray,
    k: int,
    n_sel_terms: int,
) -> np.ndarray:
    """(n_sel_terms, k) per-cluster counts from pre-selected edges."""
    return np.bincount(
        e_rank * k + assign[e_doc], minlength=n_sel_terms * k
    ).reshape(n_sel_terms, k)


def _chain_cost(c: np.ndarray, q_ptr: np.ndarray, arities: np.ndarray, model: str) -> float:
    """Σ_q Σ_i Σ_{s ≠ argmin} Φ(min_j c[j, i], c[s, i]) for per-slot cost
    rows ``c`` ((nnz, k); pass (nnz, 1) for a scalar-per-term model).

    The smallest list is the running probe side of the cost-ordered plan
    and Φ prices each of the a−1 pairwise reductions: the min slot's
    Φ(x, x) cancels, leaving one Φ per actual chain stage.  Single-term
    queries cost 0 (no intersection happens).
    """
    from repro.index.intersect import pair_cost

    n_q = len(q_ptr) - 1
    if n_q == 0:
        return 0.0
    x = np.minimum.reduceat(c, q_ptr[:-1], axis=0)  # (nq, k)
    qid = np.repeat(np.arange(n_q), arities)
    return float(pair_cost(x[qid], c, model).sum() - pair_cost(x, x, model).sum())


def query_set_cost(
    corpus: Corpus,
    assign: Optional[np.ndarray],
    k: int,
    queries,
    model: str = "lookup",
) -> float:
    """Theoretical per-cluster cost of an explicit conjunctive query set.

    For a query with per-cluster term counts (c_1, ..., c_a) the chain
    cost in cluster i is modeled as Σ_{s ≠ argmin} Φ(min_j c_j, c_s): the
    smallest list is the running probe side of the cost-ordered plan and
    Φ prices each of the a−1 pairwise reductions.  For 2-term queries
    this is exactly the paper's Σ_q Σ_i Φ(n_i(t_q), n_i(u_q)) (Eq. 2 on
    the query set); single-term queries cost 0 (no intersection happens).

    ``assign=None`` means the unclustered baseline (k = 1).  Used for the
    theoretical speedup S_T on held-out query logs.  ``queries`` is any
    form ``repro.core.queries.as_queries`` accepts.  This prices the
    *posting* level only — :func:`hier_query_set_cost` prices the full
    descent of a multi-level index.
    """
    from repro.core.queries import as_queries

    cq = as_queries(queries)
    terms = np.unique(cq.q_terms)
    rows = np.searchsorted(terms, cq.q_terms)  # (nnz,) rank of each slot

    if assign is None:
        assign = np.zeros(corpus.n_docs, dtype=np.int64)
        k = 1
    e_rank, e_doc = _queried_term_edges(corpus, terms)
    cnt = _counts_from_edges(e_rank, e_doc, assign, k, len(terms))
    if cq.n_queries == 0:
        return 0.0
    return _chain_cost(cnt[rows], cq.q_ptr, cq.arities, model)


def hier_query_set_cost(
    corpus: Corpus,
    level_assigns,
    level_ks,
    queries,
    model: str = "lookup",
) -> dict:
    """Theoretical cost of the FULL L-level descent for a query set.

    ``level_assigns``/``level_ks`` run coarse -> fine over the cluster
    levels (empty for the flat L = 1 index): each level-l chain over the
    terms' node lists is priced with the per-term node-presence counts
    c_l(t) = #{level-l nodes containing t} — the lists the descent
    actually intersects — and the leaf posting chain is priced per
    cluster exactly as :func:`query_set_cost`.

    Returns ``{"level_0": ..., ..., "postings": ..., "total": ...}``.
    Eq. 2 is recovered at L = 2: the ``postings`` component equals
    ``query_set_cost(corpus, leaf_assign, leaf_k, queries)`` exactly (and
    at L = 1 the whole dict degenerates to the unclustered baseline).
    """
    from repro.core.queries import as_queries

    cq = as_queries(queries)
    level_assigns = list(level_assigns)
    level_ks = [int(x) for x in level_ks]
    if len(level_assigns) != len(level_ks):
        raise ValueError("level_assigns and level_ks must align")
    out = {f"level_{li}": 0.0 for li in range(len(level_assigns))}
    if cq.n_queries == 0:
        out["postings"] = 0.0
        out["total"] = 0.0
        return out
    terms = np.unique(cq.q_terms)
    rows = np.searchsorted(terms, cq.q_terms)
    # One O(nnz) corpus scan for the whole descent: only the assignment
    # (a bincount) changes between levels.
    e_rank, e_doc = _queried_term_edges(corpus, terms)
    leaf_assign = (
        level_assigns[-1]
        if level_assigns
        else np.zeros(corpus.n_docs, dtype=np.int64)
    )
    leaf_k = level_ks[-1] if level_ks else 1
    cnt_leaf = _counts_from_edges(e_rank, e_doc, leaf_assign, leaf_k, len(terms))
    leaf = _chain_cost(cnt_leaf[rows], cq.q_ptr, cq.arities, model)
    out["postings"] = leaf
    total = leaf
    for li, (assign, kl) in enumerate(zip(level_assigns, level_ks, strict=True)):
        if li == len(level_assigns) - 1:
            cnt = cnt_leaf  # the leaf counts were just computed
        else:
            cnt = _counts_from_edges(e_rank, e_doc, assign, kl, len(terms))
        presence = (cnt > 0).sum(axis=1).astype(np.float64)  # node-list lengths
        cost_l = _chain_cost(presence[rows][:, None], cq.q_ptr, cq.arities, model)
        out[f"level_{li}"] = cost_l
        total += cost_l
    out["total"] = total
    return out
