"""Device (JAX) versions of the clustering hot ops.

The host driver (kmeans.py) uses numpy/scipy — right for a recursion-heavy
CPU workload.  These jit'd equivalents are the TPU path: they are used by
the distributed clustering implementation (``repro.dist.cluster_dist``,
documents sharded over the mesh, counts replicated — exactly the paper's
§3.2 parallelization sketch) and are cross-validated against the numpy
implementations in tests.

Layouts are fixed-shape: documents are ELL-padded to ``L_pad`` frequent
terms (rank = TC means "empty slot"), which is what both shard_map and the
Pallas ``cluster_score`` kernel consume.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import FrequentTermView

__all__ = [
    "ell_pack",
    "counts_from_ell",
    "psi_jax",
    "delta_add_tables_jax",
    "scores_from_ell",
    "kmeans_round_jax",
]


def ell_pack(view: FrequentTermView, l_pad: int | None = None) -> Tuple[np.ndarray, int]:
    """Pack a FrequentTermView into an ELL (n_docs, L_pad) rank matrix.

    Pad slots hold ``tc`` (one-past-last rank). Documents with more than
    L_pad frequent terms keep their L_pad highest-P ones (ranks are sorted
    by P, so the smallest ranks win; truncation is logged by the caller).
    """
    lens = np.diff(view.mat.indptr)
    if l_pad is None:
        l_pad = int(lens.max()) if len(lens) else 1
    n = view.n_docs
    out = np.full((n, l_pad), view.tc, dtype=np.int32)
    indptr, indices = view.mat.indptr, view.mat.indices
    for d in range(n):
        lo, hi = indptr[d], indptr[d + 1]
        ranks = np.sort(indices[lo:hi])[:l_pad]  # keep highest-P (lowest rank)
        out[d, : len(ranks)] = ranks
    return out, l_pad


@functools.partial(jax.jit, static_argnames=("k", "tc"))
def counts_from_ell(ell: jnp.ndarray, assign: jnp.ndarray, k: int, tc: int) -> jnp.ndarray:
    """(k, tc) n_j(t) from ELL doc-rank matrix + assignment."""
    valid = ell < tc
    key = assign[:, None] * (tc + 1) + jnp.where(valid, ell, tc)
    flat = jnp.zeros(k * (tc + 1), dtype=jnp.int32).at[key.reshape(-1)].add(1)
    return flat.reshape(k, tc + 1)[:, :tc]


@jax.jit
def psi_jax(counts: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """Device ψ — same O(k·TC log TC) sort + suffix-sum as the host version."""
    order = jnp.argsort(counts, axis=1, stable=True)
    n_sorted = jnp.take_along_axis(counts, order, axis=1).astype(jnp.float32)
    p_sorted = p[order]
    suffix_excl = jnp.flip(jnp.cumsum(jnp.flip(p_sorted, 1), 1), 1) - p_sorted
    return (p_sorted * n_sorted * suffix_excl).sum()


@jax.jit
def delta_add_tables_jax(counts: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """S⁺[j, t] = Σ_{u: n_j(u) > n_j(t)} P_u, batched over clusters."""
    order = jnp.argsort(counts, axis=1, stable=True)
    n_sorted = jnp.take_along_axis(counts, order, axis=1)
    p_sorted = p[order]
    suffix_incl = jnp.flip(jnp.cumsum(jnp.flip(p_sorted, 1), 1), 1)
    suffix_pad = jnp.concatenate(
        [suffix_incl, jnp.zeros((counts.shape[0], 1), suffix_incl.dtype)], axis=1
    )
    idx = jax.vmap(lambda ns, c: jnp.searchsorted(ns, c, side="right"))(
        n_sorted, counts
    )
    return jnp.take_along_axis(suffix_pad, idx, axis=1)


@functools.partial(jax.jit, static_argnames=("block",))
def scores_from_ell(
    ell: jnp.ndarray, tables: jnp.ndarray, p: jnp.ndarray, block: int = 4096
) -> jnp.ndarray:
    """(n_docs, k) δ⁺ scores from the ELL layout.

    scan over document blocks; per block gather tables[:, ranks] and
    reduce over the L_pad axis.  This is the op the Pallas
    ``cluster_score`` kernel implements with explicit VMEM tiling.
    """
    n, l_pad = ell.shape
    k, tc = tables.shape
    pad_docs = (-n) % block
    ell_p = jnp.pad(ell, ((0, pad_docs), (0, 0)), constant_values=tc)
    t_pad = jnp.concatenate([tables, jnp.zeros((k, 1), tables.dtype)], axis=1)
    p_pad = jnp.concatenate([p.astype(tables.dtype), jnp.zeros((1,), tables.dtype)])

    def body(_, blk):  # blk: (block, L_pad)
        w = p_pad[blk]  # (block, L)
        g = t_pad[:, blk]  # (k, block, L)
        return None, jnp.einsum("bl,kbl->bk", w, g)

    _, out = jax.lax.scan(
        body, None, ell_p.reshape(-1, block, l_pad)
    )
    return out.reshape(-1, k)[:n]


@functools.partial(jax.jit, static_argnames=("k", "tc", "block"))
def kmeans_round_jax(
    ell: jnp.ndarray,
    assign: jnp.ndarray,
    p: jnp.ndarray,
    k: int,
    tc: int,
    block: int = 4096,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One full round-based K-means iteration on device.

    Returns (new_assign, psi_before). Composes: counts → ψ → δ⁺ tables →
    scores → argmin.
    """
    counts = counts_from_ell(ell, assign, k, tc)
    psi = psi_jax(counts, p.astype(jnp.float32))
    tables = delta_add_tables_jax(counts, p.astype(jnp.float32))
    scores = scores_from_ell(ell, tables, p.astype(jnp.float32), block=block)
    return jnp.argmin(scores, axis=1), psi
