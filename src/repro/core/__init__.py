"""SeCluD core — the paper's primary contribution.

Search with Clustered Documents (Dimond & Sanders): cluster documents so
conjunctive posting-list intersections get cheaper, losslessly.

* ``objective``     — the query-cost objective ψ (Eq. 2), δ⁺/δ⁻ lookup
                      tables, frequent-term restriction (TC cutoff)
* ``kmeans``        — flat K-means on ψ with round-based and
                      document-grained update modes
* ``multilevel``    — ε-sampling multilevel initialization
* ``topdown``       — hierarchical TopDown splitting (χ splitting factor)
* ``queries``       — arbitrary-arity conjunctive query batches (ragged
                      CSR + padded forms)
* ``hier_index``    — arbitrary-depth hierarchical cluster index: the
                      flat Lookup index is L = 1, the paper's cluster
                      index is L = 2, super-clusters/shard routers above
* ``cluster_index`` — the historical two-level cluster index (query
                      speedup S_C) as a thin L = 2 facade over
                      ``hier_index``; cost-ordered plans for k >= 1 terms
* ``batched_query`` — batched hierarchical engine: vectorized per-level
                      descent planning + length-bucketed kernel execution
                      for whole query batches (bit-exact vs the
                      per-query loop at every depth)
* ``reorder``       — cluster-contiguous renumbering (query speedup S_R)
* ``seclud``        — SecludPipeline: fit + query + speedup report
* ``jax_ops``       — jit'd device versions of the hot ops (tables,
                      scores) used by the distributed implementation
"""

from repro.core.objective import (
    FrequentTermView,
    frequent_term_view,
    cluster_counts,
    psi_from_counts,
    delta_add_tables,
    delta_remove_tables,
    assignment_scores,
    query_set_cost,
    hier_query_set_cost,
)
from repro.core.kmeans import kmeans, KMeansResult
from repro.core.multilevel import multilevel_cluster
from repro.core.topdown import topdown_cluster
from repro.core.batched_query import (
    SegmentPlan,
    batched_counts,
    batched_lookup,
    batched_query,
    plan_segment_pairs,
)
from repro.core.cluster_index import ClusterIndex, build_cluster_index, cost_order
from repro.core.hier_index import HierIndex, HierLevel, as_hier, build_hier_index
from repro.core.queries import QUERY_PAD, ConjunctiveQueries, as_queries
from repro.core.reorder import reorder_permutation
from repro.core.seclud import SecludPipeline, SecludResult

__all__ = [
    "FrequentTermView",
    "frequent_term_view",
    "cluster_counts",
    "psi_from_counts",
    "delta_add_tables",
    "delta_remove_tables",
    "assignment_scores",
    "query_set_cost",
    "hier_query_set_cost",
    "kmeans",
    "KMeansResult",
    "multilevel_cluster",
    "topdown_cluster",
    "ClusterIndex",
    "build_cluster_index",
    "cost_order",
    "HierIndex",
    "HierLevel",
    "as_hier",
    "build_hier_index",
    "QUERY_PAD",
    "ConjunctiveQueries",
    "as_queries",
    "SegmentPlan",
    "plan_segment_pairs",
    "batched_query",
    "batched_counts",
    "batched_lookup",
    "reorder_permutation",
    "SecludPipeline",
    "SecludResult",
]
