"""Device-resident batched query engine — upload the index once, run the
whole cost-ordered k-way chain on device, return only final counts/docs.

The previous device path (``batched_counts`` before this module) gave the
paper's work savings back as execution overhead: every chain stage
re-gathered its posting segments on the host, re-padded them into
pow2-length buckets, dispatched one kernel per bucket, pulled the hit
masks back and re-compacted the survivors in numpy — a host⇄device
ping-pong per (stage, bucket) whose wall-clock lost to the plain host
engine at arity >= 3.  This module replaces all of it with three pieces:

* :class:`DeviceIndex` — ``post_docs`` plus every :class:`HierLevel` CSR
  of a :class:`repro.core.hier_index.HierIndex`, ``jax.device_put`` once
  and cached on the host index object (so ``SecludPipeline.fit`` /
  ``SearchService`` construct it a single time and every batch reuses the
  resident arrays).

* ``lower_plan`` — lowers a host :class:`SegmentPlan` to the device *cell
  layout*: every group's rank-0 (cheapest) segment becomes a run of cells
  in one flat vector, groups ordered by arity (descending, stable).  The
  long sides are never materialized at all — each stage probes its
  posting segments *in place* inside the resident ``post_docs`` — so the
  only padding anywhere is the flat vector's tail quantization
  (``pad-to-bin-max`` degenerates to pad-to-tail here; the pow2-per-pair
  scheme and its 1.5–1.9x overhead are gone).  Every shape entering the
  jit — cell count, per-stage group width, query count — is rounded up
  at ~1/8 granularity and the per-stage binary-search depths to even
  values, so batches of similar size share one compiled executable
  instead of retracing per batch.

* ``_fused_fold`` — ONE ``jax.jit`` call executes every chain stage:
  stage s binary-searches the surviving cells of the still-active groups
  (``arity > s``, a per-cell mask) into their group's rank-s segment
  (``lo/hi`` bounds per cell, ``lax.fori_loop`` over the static bit
  length of the stage's longest segment); misses are masked to PAD in
  place — intermediate survivor lists never leave device memory.  A
  final ``segment_sum`` maps cells to per-query counts.  Only the counts
  (and, on request, the member doc ids) return to host.

Exactness: counts (and docs) are bit-identical to looping
``HierIndex.query`` / ``ClusterIndex.query`` at every depth and arity —
the plan already encodes the descent, and masked binary-search
intersection is exact set intersection.  On CPU the same fused fold runs
through XLA (the jnp path IS the fallback); no TPU is required.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batched_query import _ragged_gather, _ragged_indices
from repro.core.hier_index import HierIndex, as_hier
from repro.core.queries import as_queries
from repro.kernels.intersect.ref import PAD

__all__ = [
    "DeviceIndex",
    "DeviceLevel",
    "device_index",
    "lower_plan",
    "device_fold",
    "device_counts",
]

_CELL_ALIGN = 8  # flat cell vector tail alignment (the only padding left)


def _quantize(n: int) -> int:
    """Round ``n`` up at ~1/8 granularity (min 8).  Shapes entering the
    fused fold are quantized with this so nearby batch sizes map to the
    SAME jit cache entry — the waste is bounded by 12.5% and counted in
    ``padding_overhead``; without it every batch would retrace."""
    g = max(_CELL_ALIGN, 1 << max(int(max(n, 1) - 1).bit_length() - 3, 0))
    return -(-max(n, 1) // g) * g


# ----------------------------------------------------------------------
# The upload-once index
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceLevel:
    """One :class:`repro.core.hier_index.HierLevel` CSR, device-resident."""

    cl_ptr: object  # jax.Array (n_terms + 1,) int64
    cl_ids: object  # jax.Array (nnz_l,) int32
    seg_start: object  # jax.Array (nnz_l,) int64
    seg_end: object  # jax.Array (nnz_l,) int64
    ranges: object  # jax.Array (k_l + 1,) int64


@dataclasses.dataclass(frozen=True)
class DeviceIndex:
    """The whole hierarchical index resident on device, uploaded once.

    ``post_docs`` is the array every fold probes; the level CSRs ride
    along so any future device-side descent finds them already resident.
    ``host`` is the host-side :class:`HierIndex` the planner runs on —
    the two views share nothing at execution time (the fold touches only
    device arrays) but stay paired so callers can't mix indexes.
    """

    post_docs: object  # jax.Array (n_postings,) int32
    post_ptr: object  # jax.Array (n_terms + 1,) int64
    levels: Tuple[DeviceLevel, ...]
    n_docs: int
    n_postings: int
    search_iters: int  # static: bit length of the longest posting list
    host: HierIndex

    @property
    def nbytes(self) -> int:
        """Resident bytes (post_docs + ptr + level CSRs) — what upload
        amortizes over every subsequent batch."""
        total = int(self.post_docs.nbytes) + int(self.post_ptr.nbytes)
        for lev in self.levels:
            total += sum(
                int(getattr(lev, f).nbytes)
                for f in ("cl_ptr", "cl_ids", "seg_start", "seg_end", "ranges")
            )
        return total


def device_index(cidx) -> DeviceIndex:
    """The cached :class:`DeviceIndex` of ``cidx`` (a ``HierIndex`` of any
    depth or the two-level ``ClusterIndex`` facade), uploading on first
    use only.  The cache lives on the host ``HierIndex`` object, so every
    caller sharing an index — pipeline, service, benchmarks — shares one
    device copy."""
    hidx = as_hier(cidx)
    cached = getattr(hidx, "_device_index", None)
    if cached is not None:
        return cached
    index = hidx.index
    lens = np.diff(index.post_ptr)
    max_len = int(lens.max()) if len(lens) else 0
    di = DeviceIndex(
        post_docs=jax.device_put(np.asarray(index.post_docs, np.int32)),
        post_ptr=jax.device_put(np.asarray(index.post_ptr, np.int64)),
        levels=tuple(
            DeviceLevel(
                cl_ptr=jax.device_put(lev.cl_ptr),
                cl_ids=jax.device_put(lev.cl_ids),
                seg_start=jax.device_put(lev.seg_start),
                seg_end=jax.device_put(lev.seg_end),
                ranges=jax.device_put(lev.ranges),
            )
            for lev in hidx.levels
        ),
        n_docs=index.n_docs,
        n_postings=len(index.post_docs),
        search_iters=max(max_len.bit_length(), 1),
        host=hidx,
    )
    hidx._device_index = di  # plain attribute: HierIndex is a mutable dataclass
    return di


# ----------------------------------------------------------------------
# Plan lowering: SegmentPlan -> flat device cell layout
# ----------------------------------------------------------------------


@dataclasses.dataclass
class LoweredPlan:
    """A :class:`SegmentPlan` in the device cell layout.

    Groups are permuted arity-descending (stable), each contributing one
    cell per element of its rank-0 segment; chain stage s (1-based)
    filters the cells whose ``cell_arity > s`` (the first
    ``group_prefix[s - 1]`` groups / ``cell_prefix[s - 1]`` cells — kept
    for attribution; the fold itself masks on the arity row so every
    array shape can be quantized for jit-cache reuse).  ``stage_seg``
    holds, per stage, each group's rank-s posting segment ``(start,
    len)`` (absolute into ``post_docs``; zeros for groups without one).
    Tail cells (quantization) carry ``cell_post = -1``, ``arity = 0``
    and ``cell_query >= n_queries`` so the fold masks them and
    ``segment_sum`` drops them.
    """

    cells: np.ndarray  # (4, N) int32 rows: post index (-1 = pad), group
    #                    id, query id (>= n_queries = pad), arity (0 =
    #                    pad) — one upload for the whole batch
    stage_seg: np.ndarray  # (2, n_stages * group_width) int32 — per
    #                        stage, every group's (start, len), zeros
    #                        where the group has no rank-s segment
    group_width: int  # quantized per-stage width of stage_seg
    cell_prefix: Tuple[int, ...]  # true active cells per stage (host info)
    group_prefix: Tuple[int, ...]  # true active groups per stage
    stage_iters: Tuple[int, ...]  # static per-stage binary-search depth
    order: np.ndarray  # (G,) the arity-descending group permutation
    cell_counts: np.ndarray  # (G,) cells per permuted group (= rank-0 len)
    n_queries: int
    n_queries_pad: int  # quantized segment_sum width
    n_cells_true: int

    @property
    def n_cells(self) -> int:
        return self.cells.shape[1]

    @property
    def n_stages(self) -> int:
        return len(self.stage_iters)

    def stage_len_sum(self, s: int) -> int:
        w = self.group_width
        return int(self.stage_seg[1, s * w : (s + 1) * w].sum())


def lower_plan(plan) -> LoweredPlan:
    """Lower a host :class:`repro.core.batched_query.SegmentPlan` to the
    flat cell layout (pure numpy; the small per-batch arrays this builds
    are the only per-batch upload)."""
    n_queries = plan.n_queries
    g_arity = plan.arity.astype(np.int64)
    order = np.argsort(-g_arity, kind="stable")
    r0 = plan.seg_ptr[:-1][order]
    cell_counts = plan.seg_len[r0].astype(np.int64)
    starts0 = plan.seg_start[r0]
    n_true = int(cell_counts.sum())
    n_cells = _quantize(n_true)

    cells = np.empty((4, n_cells), np.int32)
    cells[0] = -1
    cells[1] = len(order)
    cells[2] = n_queries
    cells[3] = 0
    if n_true:
        rows, within = _ragged_indices(cell_counts)
        cells[0, :n_true] = starts0[rows] + within
        cells[1, :n_true] = rows
        cells[2, :n_true] = plan.pair_query[order][rows]
        cells[3, :n_true] = g_arity[order][rows]

    cell_cum = np.concatenate([[0], np.cumsum(cell_counts)])
    sorted_arity = g_arity[order]
    group_width = _quantize(len(order))
    cell_prefix: List[int] = []
    group_prefix: List[int] = []
    stage_iters: List[int] = []
    seg_parts: List[np.ndarray] = []
    for s in range(1, int(plan.max_arity)):
        # Groups still active at stage s are those with arity > s — a
        # prefix of the arity-descending order; the rest keep (0, 0)
        # segments and are mask-protected by the arity row.
        n_g = int(np.searchsorted(-sorted_arity, -s, side="left"))
        if n_g == 0:
            break
        si = r0[:n_g] + s
        lens = plan.seg_len[si]
        seg = np.zeros((2, group_width), np.int32)
        seg[0, :n_g] = plan.seg_start[si]
        seg[1, :n_g] = lens
        seg_parts.append(seg)
        group_prefix.append(n_g)
        cell_prefix.append(int(cell_cum[n_g]))
        # The probed segments are cluster-local slices, usually far
        # shorter than the longest posting list: size the binary search
        # to THIS stage's longest segment (rounded up to even depth so
        # close batches share a compiled executable).
        it = max(int(lens.max()).bit_length(), 1)
        stage_iters.append(it + (it & 1))
    stage_seg = (
        np.concatenate(seg_parts, axis=1)
        if seg_parts
        else np.zeros((2, 0), np.int32)
    )
    return LoweredPlan(
        cells=cells,
        stage_seg=stage_seg,
        group_width=group_width,
        cell_prefix=tuple(cell_prefix),
        group_prefix=tuple(group_prefix),
        stage_iters=tuple(stage_iters),
        order=order,
        cell_counts=cell_counts,
        n_queries=n_queries,
        n_queries_pad=_quantize(n_queries),
        n_cells_true=n_true,
    )


# ----------------------------------------------------------------------
# The fused fold: every chain stage in one jit
# ----------------------------------------------------------------------


def _search_segments(post_docs, cur, lo, hi, iters: int):
    """Leftmost position of each ``cur`` element inside its own posting
    segment ``post_docs[lo : hi]`` — a vectorized binary search with
    per-element bounds, probing the resident array in place (no gather of
    the long side, no padding)."""
    n = post_docs.shape[0]
    end = hi

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) >> 1
        v = post_docs[jnp.minimum(mid, n - 1)]
        below = v < cur
        return jnp.where(below, mid + 1, lo), jnp.where(below, hi, mid)

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    found = (lo < end) & (post_docs[jnp.minimum(lo, n - 1)] == cur)
    return found


@functools.partial(
    jax.jit,
    static_argnames=(
        "group_width",
        "stage_iters",
        "n_queries_pad",
        "return_members",
    ),
)
def _fused_fold(
    post_docs,
    cells,
    stage_seg,
    group_width: int,
    stage_iters: Tuple[int, ...],
    n_queries_pad: int,
    return_members: bool,
):
    """The whole multi-stage fold on device.  Returns per-query counts
    (quantized width — the caller slices), per-stage survivor totals
    (live active cells entering each stage), and — when
    ``return_members`` — the final cell vector (PAD holes in place).

    Stage s filters only the cells whose group is still active
    (``arity > s``); finished groups and quantization-pad cells pass
    through untouched, so every shape here is a quantized static — the
    jit cache key is (shapes, group_width, stage_iters, n_queries_pad),
    shared by all batches of similar size.
    """
    n = post_docs.shape[0]
    cell_post, cell_group, cell_query, cell_arity = (
        cells[0], cells[1], cells[2], cells[3],
    )
    cur = post_docs[jnp.clip(cell_post, 0, n - 1)]
    cur = jnp.where(cell_post >= 0, cur, PAD)
    entering = []
    for s, iters in enumerate(stage_iters, start=1):
        seg = stage_seg[:, (s - 1) * group_width : s * group_width]
        lo = seg[0][cell_group]
        hi = lo + seg[1][cell_group]
        act = cell_arity > s
        entering.append(((cur != PAD) & act).sum())
        found = _search_segments(post_docs, cur, lo, hi, iters)
        cur = jnp.where(act & ~found, PAD, cur)
    counts = jax.ops.segment_sum(
        (cur != PAD).astype(jnp.int32), cell_query, num_segments=n_queries_pad
    )
    entering_arr = (
        jnp.stack(entering) if entering else jnp.zeros(0, jnp.int32)
    )
    return counts, entering_arr, (cur if return_members else None)


def device_fold(
    dindex: DeviceIndex,
    lowered: LoweredPlan,
    return_members: bool = False,
):
    """Run the fused fold of a lowered plan against a resident index.
    Returns ``(counts, entering, members)`` — device arrays; ``counts``
    has the quantized ``n_queries_pad`` width and ``members`` is None
    unless requested."""
    return _fused_fold(
        dindex.post_docs,
        jnp.asarray(lowered.cells),
        jnp.asarray(lowered.stage_seg),
        group_width=lowered.group_width,
        stage_iters=lowered.stage_iters,
        n_queries_pad=lowered.n_queries_pad,
        return_members=return_members,
    )


# ----------------------------------------------------------------------
# Public entry: counts (and docs) for a whole batch
# ----------------------------------------------------------------------


def _stage_info(lowered: LoweredPlan, entering: np.ndarray) -> List[Dict[str, float]]:
    """Per-stage attribution: how many cells the stage carried (padded),
    how many were live survivors (true), how many posting cells it probed
    in place, and the resulting padding overhead."""
    stages = []
    for s in range(len(lowered.cell_prefix)):
        carried = float(lowered.cell_prefix[s])
        live = float(entering[s]) if s < len(entering) else carried
        long_cells = float(lowered.stage_len_sum(s))
        stages.append(
            {
                "stage": float(s + 1),
                "cur_cells": carried,
                "cur_live": live,
                "long_cells": long_cells,
                "padding_overhead": (carried + long_cells)
                / max(live + long_cells, 1.0),
                "kernel_calls": 0.0,  # fused: no per-stage dispatch at all
            }
        )
    return stages


def device_counts(
    cidx,
    queries,
    plan=None,
    dindex: Optional[DeviceIndex] = None,
    return_docs: bool = False,
):
    """Per-query result counts of a conjunctive batch, fully on device.

    ``cidx`` is a ``HierIndex`` of any depth or the ``ClusterIndex``
    facade; the resident :class:`DeviceIndex` is looked up (or built on
    first use) unless passed explicitly.  Returns ``(counts, info)`` —
    or ``(counts, docs, info)`` with ``return_docs=True``, where ``docs``
    is the CSR value array bit-identical to ``batched_query``'s.

    ``info`` keys: ``n_pairs``, ``n_kernel_calls`` (fused dispatches for
    the whole batch — 1), ``padding_overhead`` (cells materialized /
    true cells; the long sides are probed in place and contribute zero
    padding), ``occupancy`` (live survivor cells / cells carried across
    all stages — the masked-execution analogue of pad waste), and
    ``stages`` (per-stage attribution dicts).
    """
    from repro.core.batched_query import plan_segment_pairs

    cq = as_queries(queries)
    if dindex is None:
        dindex = device_index(cidx)
    if plan is None:
        # The device path needs the segment layout, not the paper's work
        # metric — plan without the probe/scan accounting.
        plan = plan_segment_pairs(dindex.host, cq, track_work=False)
    if plan.n_pairs == 0:
        counts = np.zeros(plan.n_queries, np.int64)
        info = {
            "n_pairs": 0.0,
            "n_kernel_calls": 0.0,
            "padding_overhead": 1.0,
            "occupancy": 1.0,
            "stages": [],
        }
        if return_docs:
            return counts, np.empty(0, np.int32), info
        return counts, info

    lowered = lower_plan(plan)
    counts_d, entering_d, members_d = device_fold(
        dindex, lowered, return_members=return_docs
    )
    counts = np.asarray(counts_d)[: lowered.n_queries].astype(np.int64)
    entering = np.asarray(entering_d)

    stages = _stage_info(lowered, entering)
    true_cells = float(lowered.n_cells_true)
    long_cells = float(sum(s["long_cells"] for s in stages))
    carried = float(lowered.n_cells) + sum(s["cur_cells"] for s in stages)
    live = true_cells + sum(s["cur_live"] for s in stages)
    info = {
        "n_pairs": float(plan.n_pairs),
        "n_kernel_calls": 1.0,
        "padding_overhead": (float(lowered.n_cells) + long_cells)
        / max(true_cells + long_cells, 1.0),
        "occupancy": live / max(carried, 1.0),
        "stages": stages,
    }
    if not return_docs:
        return counts, info

    # Un-permute the final cells to plan (query, cluster) order; dropping
    # PAD holes leaves exactly batched_query's doc array.
    members = np.asarray(members_d)
    perm_start = np.concatenate([[0], np.cumsum(lowered.cell_counts)])[:-1]
    inv_order = np.empty(len(lowered.order), np.int64)
    inv_order[lowered.order] = np.arange(len(lowered.order))
    orig_cells = _ragged_gather(
        members, perm_start[inv_order], lowered.cell_counts[inv_order]
    )
    docs = orig_cells[orig_cells != PAD].astype(np.int32)
    return counts, docs, info
