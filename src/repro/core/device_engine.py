"""Device-resident batched query engine — upload the index once, run the
whole cost-ordered k-way chain on device, return only final counts/docs.

The previous device path (``batched_counts`` before this module) gave the
paper's work savings back as execution overhead: every chain stage
re-gathered its posting segments on the host, re-padded them into
pow2-length buckets, dispatched one kernel per bucket, pulled the hit
masks back and re-compacted the survivors in numpy — a host⇄device
ping-pong per (stage, bucket) whose wall-clock lost to the plain host
engine at arity >= 3.  This module replaces all of it with three pieces:

* :class:`DeviceIndex` — ``post_docs`` plus every :class:`HierLevel` CSR
  of a :class:`repro.core.hier_index.HierIndex`, ``jax.device_put`` once
  and cached on the host index object (so ``SecludPipeline.fit`` /
  ``SearchService`` construct it a single time and every batch reuses the
  resident arrays).

* ``lower_plan`` — lowers a host :class:`SegmentPlan` to the device *cell
  layout*: every group's rank-0 (cheapest) segment becomes a run of cells
  in one flat vector, groups ordered by arity (descending, stable).  The
  long sides are never materialized at all — each stage probes its
  posting segments *in place* inside the resident ``post_docs`` — so the
  only padding anywhere is the flat vector's tail quantization
  (``pad-to-bin-max`` degenerates to pad-to-tail here; the pow2-per-pair
  scheme and its 1.5–1.9x overhead are gone).  Every shape entering the
  jit — cell count, per-stage group width, query count — is rounded up
  at ~1/8 granularity and the per-stage binary-search depths to even
  values, so batches of similar size share one compiled executable
  instead of retracing per batch.

* ``_fused_fold`` — ONE ``jax.jit`` call executes every chain stage:
  stage s binary-searches the surviving cells of the still-active groups
  (``arity > s``, a per-cell mask) into their group's rank-s segment
  (``lo/hi`` bounds per cell, ``lax.fori_loop`` over the static bit
  length of the stage's longest segment); misses are masked to PAD in
  place — intermediate survivor lists never leave device memory.  A
  final ``segment_sum`` maps cells to per-query counts.  Only the counts
  (and, on request, the member doc ids) return to host.

Exactness: counts (and docs) are bit-identical to looping
``HierIndex.query`` / ``ClusterIndex.query`` at every depth and arity —
the plan already encodes the descent, and masked binary-search
intersection is exact set intersection.  On CPU the same fused fold runs
through XLA (the jnp path IS the fallback); no TPU is required.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.runtime import maybe_validate
from repro.core.batched_query import _ragged_gather, _ragged_indices
from repro.core.hier_index import HierIndex, as_hier, shard_tops
from repro.core.queries import as_queries
from repro.kernels.intersect.ref import PAD

__all__ = [
    "DeviceIndex",
    "DeviceLevel",
    "device_index",
    "lower_plan",
    "device_fold",
    "device_counts",
    "fold_cache_size",
    "plan_shape_key",
    "warm_fold",
    "prewarm",
    "ShardedDeviceIndex",
    "ShardedLoweredPlan",
    "sharded_device_index",
    "lower_plan_sharded",
    "sharded_device_counts",
    "shard_mesh",
]

_CELL_ALIGN = 8  # flat cell vector tail alignment (the only padding left)


def _quantize(n: int) -> int:
    """Round ``n`` up at ~1/8 granularity (min 8).  Shapes entering the
    fused fold are quantized with this so nearby batch sizes map to the
    SAME jit cache entry — the waste is bounded by 12.5% and counted in
    ``padding_overhead``; without it every batch would retrace."""
    g = max(_CELL_ALIGN, 1 << max(int(max(n, 1) - 1).bit_length() - 3, 0))
    return -(-max(n, 1) // g) * g


# ----------------------------------------------------------------------
# The upload-once index
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceLevel:
    """One :class:`repro.core.hier_index.HierLevel` CSR, device-resident."""

    cl_ptr: object  # jax.Array (n_terms + 1,) int64
    cl_ids: object  # jax.Array (nnz_l,) int32
    seg_start: object  # jax.Array (nnz_l,) int64
    seg_end: object  # jax.Array (nnz_l,) int64
    ranges: object  # jax.Array (k_l + 1,) int64


@dataclasses.dataclass(frozen=True)
class DeviceIndex:
    """The whole hierarchical index resident on device, uploaded once.

    ``post_docs`` is the array every fold probes; the level CSRs ride
    along so any future device-side descent finds them already resident.
    ``host`` is the host-side :class:`HierIndex` the planner runs on —
    the two views share nothing at execution time (the fold touches only
    device arrays) but stay paired so callers can't mix indexes.
    """

    post_docs: object  # jax.Array (n_postings,) int32
    post_ptr: object  # jax.Array (n_terms + 1,) int64
    levels: Tuple[DeviceLevel, ...]
    n_docs: int
    n_postings: int
    search_iters: int  # static: bit length of the longest posting list
    host: HierIndex

    @property
    def nbytes(self) -> int:
        """Resident bytes (post_docs + ptr + level CSRs) — what upload
        amortizes over every subsequent batch."""
        total = int(self.post_docs.nbytes) + int(self.post_ptr.nbytes)
        for lev in self.levels:
            total += sum(
                int(getattr(lev, f).nbytes)
                for f in ("cl_ptr", "cl_ids", "seg_start", "seg_end", "ranges")
            )
        return total

    def validate(self) -> None:
        """Structural invariants the fused fold's exactness rests on
        (debug head: ``REPRO_DEBUG`` via :mod:`repro.analysis.runtime`).

        * ``post_ptr`` is a monotone CSR spanning the posting array;
        * postings are strictly increasing inside every term segment —
          the binary search (:func:`_search_segments`) is only exact on
          sorted, duplicate-free segments;
        * every level CSR is monotone with in-bounds nested segments;
        * ``search_iters`` covers the longest posting list.
        """
        post_ptr = jax.device_get(self.post_ptr)
        post_docs = jax.device_get(self.post_docs)
        n_post = self.n_postings
        if len(post_docs) != n_post:
            raise ValueError("DeviceIndex: post_docs length != n_postings")
        if post_ptr[0] != 0 or post_ptr[-1] != n_post:
            raise ValueError("DeviceIndex: post_ptr must span [0, n_postings]")
        if (np.diff(post_ptr) < 0).any():
            raise ValueError("DeviceIndex: post_ptr must be nondecreasing")
        if n_post and (
            (post_docs < 0) | (post_docs >= self.n_docs)
        ).any():
            raise ValueError("DeviceIndex: posting doc ids outside [0, n_docs)")
        if n_post > 1:
            seg_start = np.zeros(n_post + 1, bool)
            seg_start[post_ptr] = True
            ok = (np.diff(post_docs) > 0) | seg_start[1:n_post]
            if not ok.all():
                raise ValueError(
                    "DeviceIndex: postings must be strictly increasing "
                    "within each term segment (binary-search invariant)"
                )
        lens = np.diff(post_ptr)
        max_len = int(lens.max()) if len(lens) else 0
        if self.search_iters < max(max_len.bit_length(), 1):
            raise ValueError(
                "DeviceIndex: search_iters below the longest posting "
                "list's bit length — the fold would miss matches"
            )
        for i, lev in enumerate(self.levels):
            cl_ptr = jax.device_get(lev.cl_ptr)
            cl_ids = jax.device_get(lev.cl_ids)
            seg_s = jax.device_get(lev.seg_start)
            seg_e = jax.device_get(lev.seg_end)
            ranges = jax.device_get(lev.ranges)
            nnz = len(cl_ids)
            if cl_ptr[0] != 0 or cl_ptr[-1] != nnz or (np.diff(cl_ptr) < 0).any():
                raise ValueError(f"DeviceIndex: level {i} cl_ptr not a CSR")
            if len(seg_s) != nnz or len(seg_e) != nnz:
                raise ValueError(f"DeviceIndex: level {i} segment arity mismatch")
            bound = (
                len(jax.device_get(self.levels[i + 1].cl_ids))
                if i + 1 < len(self.levels)
                else n_post
            )
            if nnz and (
                (seg_s > seg_e) | (seg_s < 0) | (seg_e > bound)
            ).any():
                raise ValueError(
                    f"DeviceIndex: level {i} segments not nested in bounds"
                )
            if (np.diff(ranges) < 0).any():
                raise ValueError(f"DeviceIndex: level {i} ranges not monotone")
            k = len(ranges) - 1
            if nnz and ((cl_ids < 0) | (cl_ids >= k)).any():
                raise ValueError(f"DeviceIndex: level {i} node ids outside [0, k)")


def device_index(cidx) -> DeviceIndex:
    """The cached :class:`DeviceIndex` of ``cidx`` (a ``HierIndex`` of any
    depth or the two-level ``ClusterIndex`` facade), uploading on first
    use only.  The cache lives on the host ``HierIndex`` object, so every
    caller sharing an index — pipeline, service, benchmarks — shares one
    device copy."""
    hidx = as_hier(cidx)
    cached = getattr(hidx, "_device_index", None)
    if cached is not None:
        return cached
    index = hidx.index
    lens = np.diff(index.post_ptr)
    max_len = int(lens.max()) if len(lens) else 0
    di = DeviceIndex(
        post_docs=jax.device_put(np.asarray(index.post_docs, np.int32)),
        post_ptr=jax.device_put(np.asarray(index.post_ptr, np.int64)),
        levels=tuple(
            DeviceLevel(
                cl_ptr=jax.device_put(lev.cl_ptr),
                cl_ids=jax.device_put(lev.cl_ids),
                seg_start=jax.device_put(lev.seg_start),
                seg_end=jax.device_put(lev.seg_end),
                ranges=jax.device_put(lev.ranges),
            )
            for lev in hidx.levels
        ),
        n_docs=index.n_docs,
        n_postings=len(index.post_docs),
        search_iters=max(max_len.bit_length(), 1),
        host=hidx,
    )
    maybe_validate(di)  # REPRO_DEBUG: structural check before caching
    hidx._device_index = di  # plain attribute: HierIndex is a mutable dataclass
    return di


# ----------------------------------------------------------------------
# Plan lowering: SegmentPlan -> flat device cell layout
# ----------------------------------------------------------------------


@dataclasses.dataclass
class LoweredPlan:
    """A :class:`SegmentPlan` in the device cell layout.

    Groups are permuted arity-descending (stable), each contributing one
    cell per element of its rank-0 segment; chain stage s (1-based)
    filters the cells whose ``cell_arity > s`` (the first
    ``group_prefix[s - 1]`` groups / ``cell_prefix[s - 1]`` cells — kept
    for attribution; the fold itself masks on the arity row so every
    array shape can be quantized for jit-cache reuse).  ``stage_seg``
    holds, per stage, each group's rank-s posting segment ``(start,
    len)`` (absolute into ``post_docs``; zeros for groups without one).
    Tail cells (quantization) carry ``cell_post = PAD``, ``arity = 0``
    and ``cell_query >= n_queries`` so the fold masks them and
    ``segment_sum`` drops them.
    """

    cells: np.ndarray  # (4, N) int32 rows: post index (PAD = pad), group
    #                    id, query id (>= n_queries = pad), arity (0 =
    #                    pad) — one upload for the whole batch
    stage_seg: np.ndarray  # (2, n_stages * group_width) int32 — per
    #                        stage, every group's (start, len), zeros
    #                        where the group has no rank-s segment
    group_width: int  # quantized per-stage width of stage_seg
    cell_prefix: Tuple[int, ...]  # true active cells per stage (host info)
    group_prefix: Tuple[int, ...]  # true active groups per stage
    stage_iters: Tuple[int, ...]  # static per-stage binary-search depth
    order: np.ndarray  # (G,) the arity-descending group permutation
    cell_counts: np.ndarray  # (G,) cells per permuted group (= rank-0 len)
    n_queries: int
    n_queries_pad: int  # quantized segment_sum width
    n_cells_true: int

    @property
    def n_cells(self) -> int:
        return self.cells.shape[1]

    @property
    def n_stages(self) -> int:
        return len(self.stage_iters)

    def stage_len_sum(self, s: int) -> int:
        w = self.group_width
        return int(self.stage_seg[1, s * w : (s + 1) * w].sum())


def lower_plan(plan) -> LoweredPlan:
    """Lower a host :class:`repro.core.batched_query.SegmentPlan` to the
    flat cell layout (pure numpy; the small per-batch arrays this builds
    are the only per-batch upload)."""
    n_queries = plan.n_queries
    g_arity = plan.arity.astype(np.int64)
    order = np.argsort(-g_arity, kind="stable")
    r0 = plan.seg_ptr[:-1][order]
    cell_counts = plan.seg_len[r0].astype(np.int64)
    starts0 = plan.seg_start[r0]
    n_true = int(cell_counts.sum())
    n_cells = _quantize(n_true)

    cells = np.empty((4, n_cells), np.int32)
    cells[0] = PAD
    cells[1] = len(order)
    cells[2] = n_queries
    cells[3] = 0
    if n_true:
        rows, within = _ragged_indices(cell_counts)
        cells[0, :n_true] = starts0[rows] + within
        cells[1, :n_true] = rows
        cells[2, :n_true] = plan.pair_query[order][rows]
        cells[3, :n_true] = g_arity[order][rows]

    cell_cum = np.concatenate([[0], np.cumsum(cell_counts)])
    sorted_arity = g_arity[order]
    group_width = _quantize(len(order))
    cell_prefix: List[int] = []
    group_prefix: List[int] = []
    stage_iters: List[int] = []
    seg_parts: List[np.ndarray] = []
    for s in range(1, int(plan.max_arity)):
        # Groups still active at stage s are those with arity > s — a
        # prefix of the arity-descending order; the rest keep (0, 0)
        # segments and are mask-protected by the arity row.
        n_g = int(np.searchsorted(-sorted_arity, -s, side="left"))
        if n_g == 0:
            break
        si = r0[:n_g] + s
        lens = plan.seg_len[si]
        seg = np.zeros((2, group_width), np.int32)
        seg[0, :n_g] = plan.seg_start[si]
        seg[1, :n_g] = lens
        seg_parts.append(seg)
        group_prefix.append(n_g)
        cell_prefix.append(int(cell_cum[n_g]))
        # The probed segments are cluster-local slices, usually far
        # shorter than the longest posting list: size the binary search
        # to THIS stage's longest segment (rounded up to even depth so
        # close batches share a compiled executable).
        it = max(int(lens.max()).bit_length(), 1)
        stage_iters.append(it + (it & 1))
    stage_seg = (
        np.concatenate(seg_parts, axis=1)
        if seg_parts
        else np.zeros((2, 0), np.int32)
    )
    return LoweredPlan(
        cells=cells,
        stage_seg=stage_seg,
        group_width=group_width,
        cell_prefix=tuple(cell_prefix),
        group_prefix=tuple(group_prefix),
        stage_iters=tuple(stage_iters),
        order=order,
        cell_counts=cell_counts,
        n_queries=n_queries,
        n_queries_pad=_quantize(n_queries),
        n_cells_true=n_true,
    )


# ----------------------------------------------------------------------
# The fused fold: every chain stage in one jit
# ----------------------------------------------------------------------


def _search_segments(post_docs, cur, lo, hi, iters: int):
    """Leftmost position of each ``cur`` element inside its own posting
    segment ``post_docs[lo : hi]`` — a vectorized binary search with
    per-element bounds, probing the resident array in place (no gather of
    the long side, no padding)."""
    n = post_docs.shape[0]
    end = hi

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) >> 1
        v = post_docs[jnp.minimum(mid, n - 1)]
        below = v < cur
        return jnp.where(below, mid + 1, lo), jnp.where(below, hi, mid)

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    found = (lo < end) & (post_docs[jnp.minimum(lo, n - 1)] == cur)
    return found


def _fold_core(
    post_docs,
    cells,
    stage_seg,
    group_width: int,
    stage_iters: Tuple[int, ...],
    n_queries_pad: int,
    return_members: bool,
):
    """The whole multi-stage fold — the traced body shared by the
    single-device jit (:func:`_fused_fold`) and the per-shard program the
    sharded path runs under ``shard_map``.  Returns per-query counts
    (quantized width — the caller slices), per-stage survivor totals
    (live active cells entering each stage), and — when
    ``return_members`` — the final cell vector (PAD holes in place).

    Stage s filters only the cells whose group is still active
    (``arity > s``); finished groups and quantization-pad cells pass
    through untouched, so every shape here is a quantized static — the
    jit cache key is (shapes, group_width, stage_iters, n_queries_pad),
    shared by all batches of similar size.
    """
    n = post_docs.shape[0]
    cell_post, cell_group, cell_query, cell_arity = (
        cells[0], cells[1], cells[2], cells[3],
    )
    cur = post_docs[jnp.clip(cell_post, 0, n - 1)]
    cur = jnp.where(cell_post != PAD, cur, PAD)
    entering = []
    for s, iters in enumerate(stage_iters, start=1):
        seg = stage_seg[:, (s - 1) * group_width : s * group_width]
        lo = seg[0][cell_group]
        hi = lo + seg[1][cell_group]
        act = cell_arity > s
        entering.append(((cur != PAD) & act).sum())
        found = _search_segments(post_docs, cur, lo, hi, iters)
        cur = jnp.where(act & ~found, PAD, cur)
    counts = jax.ops.segment_sum(
        (cur != PAD).astype(jnp.int32), cell_query, num_segments=n_queries_pad
    )
    entering_arr = (
        jnp.stack(entering) if entering else jnp.zeros(0, jnp.int32)
    )
    return counts, entering_arr, (cur if return_members else None)


_fused_fold = functools.partial(
    jax.jit,
    static_argnames=(
        "group_width",
        "stage_iters",
        "n_queries_pad",
        "return_members",
    ),
)(_fold_core)


def device_fold(
    dindex: DeviceIndex,
    lowered: LoweredPlan,
    return_members: bool = False,
):
    """Run the fused fold of a lowered plan against a resident index.
    Returns ``(counts, entering, members)`` — device arrays; ``counts``
    has the quantized ``n_queries_pad`` width and ``members`` is None
    unless requested."""
    return _fused_fold(
        dindex.post_docs,
        jax.device_put(lowered.cells),
        jax.device_put(lowered.stage_seg),
        group_width=lowered.group_width,
        stage_iters=lowered.stage_iters,
        n_queries_pad=lowered.n_queries_pad,
        return_members=return_members,
    )


# ----------------------------------------------------------------------
# Shape-grid prewarm: compile the fold's cache entries at startup
# ----------------------------------------------------------------------
#
# The fused fold's jit-cache key is the quantized shape tuple
# (n_cells, group_width, stage_iters, n_queries_pad) — everything else
# is traced data.  A serving loop can therefore enumerate the keys its
# batch plan will produce, compile each once on *dead* cell content
# (all-PAD cells, zero segments — the fold is mask-safe by design), and
# then serve indefinitely without a single steady-state compile.


def fold_cache_size() -> int:
    """Compiled-entry count of the fused fold — the serving loop's
    compile counter.  0 when this jax version exposes no cache probe."""
    from repro.analysis.sanitize import jit_cache_size

    try:
        return jit_cache_size(_fused_fold)
    except AttributeError:  # pragma: no cover - other jax versions
        return 0


def plan_shape_key(lowered: LoweredPlan) -> Tuple[int, int, Tuple[int, ...], int]:
    """The jit-cache key of a lowered plan: the quantized shape tuple
    ``(n_cells, group_width, stage_iters, n_queries_pad)``.  Two plans
    with equal keys share one compiled executable."""
    return (
        lowered.n_cells,
        lowered.group_width,
        lowered.stage_iters,
        lowered.n_queries_pad,
    )


def warm_fold(
    dindex: DeviceIndex,
    key: Tuple[int, int, Tuple[int, ...], int],
    return_members: bool = False,
) -> None:
    """Compile the fused fold for one shape key without a real plan.

    Builds dead content of exactly the key's shapes — all-PAD cells with
    arity 0 and out-of-range query ids, zero-length segments — so the
    executable lands in the jit cache at startup cost but near-zero
    execution cost.  The fold masks dead cells everywhere, so warming
    content never touches real postings.
    """
    n_cells, group_width, stage_iters, n_queries_pad = key
    cells = np.empty((4, n_cells), np.int32)
    cells[0] = PAD
    cells[1] = 0
    cells[2] = n_queries_pad
    cells[3] = 0
    stage_seg = np.zeros((2, len(stage_iters) * group_width), np.int32)
    out = _fused_fold(
        dindex.post_docs,
        jax.device_put(cells),
        jax.device_put(stage_seg),
        group_width=group_width,
        stage_iters=tuple(stage_iters),
        n_queries_pad=n_queries_pad,
        return_members=return_members,
    )
    jax.device_get(out[0])  # block: the compile is done when we return


def prewarm(
    cidx,
    queries,
    batch_sizes: Optional[Sequence[int]] = None,
    batches: Optional[Sequence[Tuple[int, int]]] = None,
    dindex: Optional[DeviceIndex] = None,
    return_members: bool = False,
) -> Dict[str, object]:
    """Pre-compile the fused fold's quantized shape grid for a workload.

    ``queries`` is a representative sample (e.g. yesterday's log);
    either ``batches`` gives explicit ``(start, end)`` windows into it —
    e.g. the exact windows :func:`repro.serve.loop.plan_batches` will
    dispatch — or ``batch_sizes`` names prefix sizes to warm.  Each
    window is planned and lowered on host only (cheap) to find its shape
    key; each distinct key compiles once via :func:`warm_fold`.

    Returns ``{"n_batches", "n_keys", "n_compiles", "keys"}`` —
    ``n_compiles <= n_keys`` since some keys may already be cached.
    """
    from repro.core.batched_query import plan_segment_pairs

    cq = as_queries(queries)
    if dindex is None:
        dindex = device_index(cidx)
    if batches is None:
        if batch_sizes is None:
            raise ValueError("prewarm needs batch_sizes or explicit batches")
        batches = [(0, min(int(b), cq.n_queries)) for b in batch_sizes]
    before = fold_cache_size()
    keys: List[Tuple[int, int, Tuple[int, ...], int]] = []
    seen = set()
    n_batches = 0
    for i, j in batches:
        if j <= i:
            continue
        n_batches += 1
        plan = plan_segment_pairs(dindex.host, cq[int(i) : int(j)], track_work=False)
        if plan.n_pairs == 0:
            continue  # empty plans never reach the fold
        key = plan_shape_key(lower_plan(plan))
        if key in seen:
            continue
        seen.add(key)
        keys.append(key)
        warm_fold(dindex, key, return_members=return_members)
    return {
        "n_batches": n_batches,
        "n_keys": len(keys),
        "n_compiles": fold_cache_size() - before,
        "keys": keys,
    }


# ----------------------------------------------------------------------
# Public entry: counts (and docs) for a whole batch
# ----------------------------------------------------------------------


def _stage_info(lowered: LoweredPlan, entering: np.ndarray) -> List[Dict[str, float]]:
    """Per-stage attribution: how many cells the stage carried (padded),
    how many were live survivors (true), how many posting cells it probed
    in place, and the resulting padding overhead."""
    stages = []
    for s in range(len(lowered.cell_prefix)):
        carried = float(lowered.cell_prefix[s])
        live = float(entering[s]) if s < len(entering) else carried
        long_cells = float(lowered.stage_len_sum(s))
        stages.append(
            {
                "stage": float(s + 1),
                "cur_cells": carried,
                "cur_live": live,
                "long_cells": long_cells,
                "padding_overhead": (carried + long_cells)
                / max(live + long_cells, 1.0),
                "kernel_calls": 0.0,  # fused: no per-stage dispatch at all
            }
        )
    return stages


def device_counts(
    cidx,
    queries,
    plan=None,
    dindex: Optional[DeviceIndex] = None,
    return_docs: bool = False,
    fault_hook=None,
):
    """Per-query result counts of a conjunctive batch, fully on device.

    ``cidx`` is a ``HierIndex`` of any depth or the ``ClusterIndex``
    facade; the resident :class:`DeviceIndex` is looked up (or built on
    first use) unless passed explicitly.  Returns ``(counts, info)`` —
    or ``(counts, docs, info)`` with ``return_docs=True``, where ``docs``
    is the CSR value array bit-identical to ``batched_query``'s.

    ``info`` keys: ``n_pairs``, ``n_kernel_calls`` (fused dispatches for
    the whole batch — 1), ``padding_overhead`` (cells materialized /
    true cells; the long sides are probed in place and contribute zero
    padding), ``occupancy`` (live survivor cells / cells carried across
    all stages — the masked-execution analogue of pad waste), and
    ``stages`` (per-stage attribution dicts).  Per-call timing hooks for
    the serving loop ride along: ``t_plan_s`` / ``t_lower_s`` /
    ``t_fold_s`` split the call into host planning, lowering, and the
    fused dispatch (incl. the device round-trip); ``jit_compiles`` is
    the fold-cache growth this call caused (0 on every warm path).
    """
    from repro.core.batched_query import plan_segment_pairs

    t0 = time.perf_counter()
    cq = as_queries(queries)
    if dindex is None:
        dindex = device_index(cidx)
    if plan is None:
        # The device path needs the segment layout, not the paper's work
        # metric — plan without the probe/scan accounting.
        plan = plan_segment_pairs(dindex.host, cq, track_work=False)
    t_plan = time.perf_counter() - t0
    if fault_hook is not None:
        # Injection point of the chaos harness (repro.serve.faults): a
        # scheduled fault raises here, inside the real dispatch path —
        # exactly where a device error would surface — so the resilience
        # ladder is exercised without patching the engine in tests.
        fault_hook.on_dispatch(n_shards=1)
    if plan.n_pairs == 0:
        counts = np.zeros(plan.n_queries, np.int64)
        info = {
            "n_pairs": 0.0,
            "n_kernel_calls": 0.0,
            "padding_overhead": 1.0,
            "occupancy": 1.0,
            "stages": [],
            "t_plan_s": t_plan,
            "t_lower_s": 0.0,
            "t_fold_s": 0.0,
            "jit_compiles": 0.0,
        }
        if return_docs:
            return counts, np.empty(0, np.int32), info
        return counts, info

    t1 = time.perf_counter()
    lowered = lower_plan(plan)
    t_lower = time.perf_counter() - t1
    cache_before = fold_cache_size()
    t2 = time.perf_counter()
    counts_d, entering_d, members_d = device_fold(
        dindex, lowered, return_members=return_docs
    )
    counts = jax.device_get(counts_d)[: lowered.n_queries].astype(np.int64)
    entering = jax.device_get(entering_d)
    t_fold = time.perf_counter() - t2

    stages = _stage_info(lowered, entering)
    true_cells = float(lowered.n_cells_true)
    long_cells = float(sum(s["long_cells"] for s in stages))
    carried = float(lowered.n_cells) + sum(s["cur_cells"] for s in stages)
    live = true_cells + sum(s["cur_live"] for s in stages)
    info = {
        "n_pairs": float(plan.n_pairs),
        "n_kernel_calls": 1.0,
        "padding_overhead": (float(lowered.n_cells) + long_cells)
        / max(true_cells + long_cells, 1.0),
        "occupancy": live / max(carried, 1.0),
        "stages": stages,
        "t_plan_s": t_plan,
        "t_lower_s": t_lower,
        "t_fold_s": t_fold,
        "jit_compiles": float(fold_cache_size() - cache_before),
    }
    if not return_docs:
        return counts, info

    # Un-permute the final cells to plan (query, cluster) order; dropping
    # PAD holes leaves exactly batched_query's doc array.
    members = jax.device_get(members_d)
    perm_start = np.concatenate([[0], np.cumsum(lowered.cell_counts)])[:-1]
    inv_order = np.empty(len(lowered.order), np.int64)
    inv_order[lowered.order] = np.arange(len(lowered.order))
    orig_cells = _ragged_gather(
        members, perm_start[inv_order], lowered.cell_counts[inv_order]
    )
    docs = orig_cells[orig_cells != PAD].astype(np.int32)
    return counts, docs, info


# ----------------------------------------------------------------------
# Mesh-sharded serving: per-shard postings, fused fold under shard_map
# ----------------------------------------------------------------------
#
# The corpus is partitioned by level-0 ancestor into S contiguous
# doc-id ranges (``shard_tops`` balances posting mass), each shard
# holding the postings of its own docs as one row of a stacked (S, W)
# matrix laid over the mesh's data axis.  Because every segment group of
# a plan lives inside ONE leaf cluster — hence one top cluster, hence
# one shard — the global plan routes exactly: each group's cells land on
# the shard owning its docs, untouched shards receive only dead
# (masked) cells.  One ``shard_map`` call then runs :func:`_fold_core`
# per shard and a single ``psum`` over the data axes produces the final
# counts; member docs come back per-shard and are re-concatenated on
# host in original plan-group order, bit-identical to the single-device
# path.


def shard_mesh(n_shards: Optional[int] = None):
    """A ``(n_shards, 1)`` mesh over the first ``n_shards`` local devices
    with the canonical ``("data", "model")`` axes — the serving mesh the
    sharded engine partitions the corpus over (defaults to every
    device)."""
    from jax.sharding import Mesh

    devs = jax.devices()
    if n_shards is None:
        n_shards = len(devs)
    if not 1 <= n_shards <= len(devs):
        raise ValueError(
            f"n_shards={n_shards} outside [1, {len(devs)}] available devices"
        )
    return Mesh(np.asarray(devs[:n_shards]).reshape(n_shards, 1), ("data", "model"))


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedDeviceIndex:
    """The corpus partitioned by level-0 ancestor over a mesh's data axis.

    ``post_docs`` is a (S, W) matrix — row s holds shard s's postings
    (the global postings whose doc id falls in ``[doc_bounds[s],
    doc_bounds[s + 1])``, order preserved, PAD beyond ``shard_counts[s]``)
    — laid out with ``NamedSharding`` so each mesh shard holds exactly
    its own row.  ``local_pos`` maps a global posting position to its
    position within its shard's row: a plan segment (contiguous globally,
    wholly inside one leaf cluster and therefore one shard) stays
    contiguous locally, so lowering only remaps segment starts.
    """

    mesh: object  # jax.sharding.Mesh
    n_shards: int
    top_bounds: np.ndarray  # (S + 1,) level-0 node boundaries per shard
    doc_bounds: np.ndarray  # (S + 1,) doc-id boundaries per shard
    post_docs: object  # jax.Array (S, W) int32, sharded P(data, None)
    post_width: int  # W — quantized max shard posting count
    local_pos: np.ndarray  # (n_postings,) int64 — global -> within-shard
    shard_counts: np.ndarray  # (S,) int64 — true postings per shard
    search_iters: int
    host: HierIndex

    @property
    def nbytes(self) -> int:
        """Total resident bytes across the mesh (PAD tail included)."""
        return int(self.post_docs.nbytes)

    def validate(self) -> None:
        """Shard partition exactness (debug head: ``REPRO_DEBUG``).

        The sharded fold is bit-identical to the single-device path only
        if the (S, W) stacked postings are an exact partition: every
        global posting sits at ``(shard_of(doc), local_pos)`` in its
        owner's row, rows carry nothing else but PAD tail, and the
        doc-range routing that ``lower_plan_sharded`` uses reproduces
        the row assignment.
        """
        S = self.n_shards
        if len(self.top_bounds) != S + 1 or len(self.doc_bounds) != S + 1:
            raise ValueError("ShardedDeviceIndex: bounds must have S + 1 entries")
        if (np.diff(self.top_bounds) < 0).any() or (
            np.diff(self.doc_bounds) < 0
        ).any():
            raise ValueError("ShardedDeviceIndex: shard bounds not monotone")
        docs = np.asarray(self.host.index.post_docs, np.int64)
        n_post = len(docs)
        if len(self.local_pos) != n_post:
            raise ValueError("ShardedDeviceIndex: local_pos length mismatch")
        if int(self.shard_counts.sum()) != n_post:
            raise ValueError(
                "ShardedDeviceIndex: shard_counts do not partition the postings"
            )
        stacked = jax.device_get(self.post_docs)
        if stacked.shape != (S, self.post_width):
            raise ValueError("ShardedDeviceIndex: stacked postings shape mismatch")
        shard_of = np.clip(
            np.searchsorted(self.doc_bounds, docs, side="right") - 1, 0, S - 1
        )
        if not np.array_equal(
            np.bincount(shard_of, minlength=S).astype(np.int64),
            self.shard_counts,
        ):
            raise ValueError(
                "ShardedDeviceIndex: shard_counts disagree with doc-range routing"
            )
        live = np.zeros((S, self.post_width), bool)
        if n_post:
            if ((self.local_pos < 0) | (self.local_pos >= self.post_width)).any():
                raise ValueError("ShardedDeviceIndex: local_pos outside its row")
            if not (stacked[shard_of, self.local_pos] == docs).all():
                raise ValueError(
                    "ShardedDeviceIndex: a posting is not at its routed "
                    "(shard, local) slot — partition is not exact"
                )
            live[shard_of, self.local_pos] = True
            if int(live.sum()) != n_post:
                raise ValueError(
                    "ShardedDeviceIndex: local_pos collides within a shard"
                )
        if (stacked[~live] != PAD).any():
            raise ValueError(
                "ShardedDeviceIndex: non-PAD value outside the live partition"
            )


def sharded_device_index(
    cidx, mesh=None, n_shards: Optional[int] = None
) -> ShardedDeviceIndex:
    """The cached :class:`ShardedDeviceIndex` of ``cidx`` over ``mesh``
    (built from ``n_shards`` local devices when omitted).  Cached per
    mesh on the host ``HierIndex``, so re-serving after a remesh (shard
    failover) rebuilds once and every later batch reuses the upload."""
    from repro.dist import sharding as sh
    from jax.sharding import NamedSharding

    hidx = as_hier(cidx)
    if mesh is None:
        mesh = shard_mesh(n_shards)
    cache = getattr(hidx, "_sharded_indexes", None)
    if cache is None:
        cache = {}
        hidx._sharded_indexes = cache
    cached = cache.get(mesh)
    if cached is not None:
        return cached

    S = sh.axes_size(mesh, sh.data_spec(mesh))
    top_bounds = shard_tops(hidx, S)
    doc_bounds = hidx.top_ranges[top_bounds].astype(np.int64)
    docs = np.asarray(hidx.index.post_docs, np.int64)
    n_post = len(docs)
    shard_of = np.clip(
        np.searchsorted(doc_bounds, docs, side="right") - 1, 0, S - 1
    )
    shard_counts = np.bincount(shard_of, minlength=S).astype(np.int64)
    shard_off = np.concatenate([[0], np.cumsum(shard_counts)])
    order = np.argsort(shard_of, kind="stable")
    local = np.arange(n_post, dtype=np.int64) - np.repeat(
        shard_off[:-1], shard_counts
    )
    local_pos = np.empty(n_post, np.int64)
    local_pos[order] = local
    width = _quantize(int(shard_counts.max()) if n_post else 1)
    stacked = np.full((S, width), PAD, np.int32)
    stacked[shard_of, local_pos] = docs.astype(np.int32)
    max_len = int(shard_counts.max()) if n_post else 0
    sidx = ShardedDeviceIndex(
        mesh=mesh,
        n_shards=S,
        top_bounds=top_bounds,
        doc_bounds=doc_bounds,
        post_docs=jax.device_put(
            stacked, NamedSharding(mesh, sh.postings_spec(mesh))
        ),
        post_width=width,
        local_pos=local_pos,
        shard_counts=shard_counts,
        search_iters=max(max_len.bit_length(), 1),
        host=hidx,
    )
    maybe_validate(sidx)  # REPRO_DEBUG: partition exactness before caching
    cache[mesh] = sidx
    return sidx


def _take_groups(plan, g_idx: np.ndarray, sidx: ShardedDeviceIndex):
    """The sub-:class:`SegmentPlan` of groups ``g_idx``, segment starts
    remapped into the owning shard's local postings row.  Query ids stay
    global — per-shard counts segment-sum into the full query range and
    the cross-shard psum adds disjoint contributions."""
    from repro.core.batched_query import SegmentPlan

    arity = plan.arity[g_idx].astype(np.int64)
    rows, within = _ragged_indices(arity)
    si = plan.seg_ptr[:-1][g_idx][rows] + within
    seg_len = plan.seg_len[si]
    gstart = plan.seg_start[si]
    n_post = len(sidx.local_pos)
    # Empty segments may sit at the postings tail (start == n_postings):
    # clamp the lookup, their remapped start is never probed.
    seg_start = np.where(
        seg_len > 0,
        sidx.local_pos[np.minimum(gstart, max(n_post - 1, 0))],
        0,
    )
    return SegmentPlan(
        pair_query=plan.pair_query[g_idx],
        cluster=plan.cluster[g_idx],
        base=plan.base[g_idx],
        width=plan.width[g_idx],
        arity=arity,
        seg_ptr=np.concatenate([[0], np.cumsum(arity)]).astype(np.int64),
        seg_start=seg_start.astype(np.int64),
        seg_len=seg_len.astype(np.int64),
        cluster_work=np.zeros(plan.n_queries, np.int64),
        n_queries=plan.n_queries,
        max_arity=int(plan.max_arity),
    )


@dataclasses.dataclass
class ShardedLoweredPlan:
    """A :class:`SegmentPlan` lowered per shard and stacked for one
    ``shard_map`` dispatch: shard s's cells/segments sit in row s (dead
    cells where another shard owns the group), shapes unified across
    shards so a single compiled program serves the whole mesh.
    ``grp_shard`` / ``grp_off`` / ``grp_cnt`` locate every original plan
    group inside the stacked member matrix — the host-side gather that
    restores single-device doc order exactly."""

    cells: np.ndarray  # (S, 4, C) int32 — per-shard cell layout
    stage_seg: np.ndarray  # (S, 2, n_stages * group_width) int32
    group_width: int  # unified quantized per-stage width
    stage_iters: Tuple[int, ...]  # per-stage max binary-search depth
    n_queries: int
    n_queries_pad: int
    n_cells_true: np.ndarray  # (S,) true cells per shard (load balance)
    grp_shard: np.ndarray  # (G,) owning shard of each original group
    grp_off: np.ndarray  # (G,) cell offset inside the shard's row
    grp_cnt: np.ndarray  # (G,) cells of the group (= rank-0 len)
    shards_touched: int
    n_shards: int

    @property
    def n_cells(self) -> int:
        return self.cells.shape[2]

    @property
    def n_stages(self) -> int:
        return len(self.stage_iters)


def lower_plan_sharded(plan, sidx: ShardedDeviceIndex) -> ShardedLoweredPlan:
    """Route a global plan's groups to their owning shards and lower each
    shard's slice (pure numpy).  A group's top-level ancestor decides its
    shard — the level-0 descent IS the router; shards outside the batch's
    descent receive only dead cells and contribute nothing but a masked
    no-op to the fused fold."""
    S = sidx.n_shards
    top = np.searchsorted(sidx.host.top_ranges, plan.base, side="right") - 1
    gshard = np.clip(
        np.searchsorted(sidx.top_bounds, top, side="right") - 1, 0, S - 1
    ).astype(np.int64)

    lowereds = {}
    for s in np.unique(gshard):
        g_idx = np.flatnonzero(gshard == s)
        lowereds[int(s)] = (g_idx, lower_plan(_take_groups(plan, g_idx, sidx)))

    # Unify shapes across shards: one compiled executable for the mesh.
    width = max(low.group_width for _, low in lowereds.values())
    n_cells = max(low.n_cells for _, low in lowereds.values())
    n_stages = max(low.n_stages for _, low in lowereds.values())
    iters = [0] * n_stages
    for _, low in lowereds.values():
        for t, it in enumerate(low.stage_iters):
            iters[t] = max(iters[t], it)
    n_queries = plan.n_queries

    cells = np.empty((S, 4, n_cells), np.int32)
    cells[:, 0] = PAD
    cells[:, 1] = width
    cells[:, 2] = n_queries
    cells[:, 3] = 0
    stage_seg = np.zeros((S, 2, n_stages * width), np.int32)
    n_true = np.zeros(S, np.int64)
    n_groups = plan.n_pairs
    grp_off = np.zeros(n_groups, np.int64)
    grp_cnt = np.zeros(n_groups, np.int64)
    for s, (g_idx, low) in lowereds.items():
        cells[s, :, : low.n_cells] = low.cells
        gw = low.group_width
        for t in range(low.n_stages):
            stage_seg[s, :, t * width : t * width + gw] = low.stage_seg[
                :, t * gw : (t + 1) * gw
            ]
        n_true[s] = low.n_cells_true
        perm_start = np.concatenate([[0], np.cumsum(low.cell_counts)])[:-1]
        inv = np.empty(len(low.order), np.int64)
        inv[low.order] = np.arange(len(low.order))
        grp_off[g_idx] = perm_start[inv]
        grp_cnt[g_idx] = low.cell_counts[inv]
    return ShardedLoweredPlan(
        cells=cells,
        stage_seg=stage_seg,
        group_width=width,
        stage_iters=tuple(iters),
        n_queries=n_queries,
        n_queries_pad=_quantize(n_queries),
        n_cells_true=n_true,
        grp_shard=gshard,
        grp_off=grp_off,
        grp_cnt=grp_cnt,
        shards_touched=len(lowereds),
        n_shards=S,
    )


@functools.lru_cache(maxsize=64)
def _build_sharded_fold(
    mesh,
    group_width: int,
    stage_iters: Tuple[int, ...],
    n_queries_pad: int,
    return_members: bool,
):
    """The compiled sharded fold for one (mesh, quantized-shape) key:
    ``shard_map`` runs :func:`_fold_core` on each shard's row and a
    single ``psum`` over the data axes produces the global counts —
    cached so batches of similar size reuse one executable, exactly like
    the single-device jit cache."""
    import inspect

    from jax.experimental.shard_map import shard_map

    from repro.dist import sharding as sh

    dp_axes = sh.batch_axes(mesh)
    cells_spec, seg_spec = sh.plan_specs(mesh)

    def body(post_docs, cells, stage_seg):
        counts, entering, cur = _fold_core(
            post_docs[0],
            cells[0],
            stage_seg[0],
            group_width=group_width,
            stage_iters=stage_iters,
            n_queries_pad=n_queries_pad,
            return_members=return_members,
        )
        counts = jax.lax.psum(counts, dp_axes)
        if stage_iters:
            entering = jax.lax.psum(entering, dp_axes)
        if return_members:
            return counts, entering, cur[None]
        return counts, entering

    # check_rep=False where supported: the body nests the fused fold,
    # whose replication jax 0.4.x's checker cannot track; the psum is
    # what establishes the replication of the counts.
    kw = {}
    try:
        if "check_rep" in inspect.signature(shard_map).parameters:
            kw["check_rep"] = False
    except (ValueError, TypeError):  # pragma: no cover
        pass
    from jax.sharding import PartitionSpec as P

    out_specs = (P(), P())
    if return_members:
        out_specs = out_specs + (sh.postings_spec(mesh),)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(sh.postings_spec(mesh), cells_spec, seg_spec),
        out_specs=out_specs,
        **kw,
    )
    return jax.jit(fn)


def sharded_device_counts(
    cidx,
    queries,
    plan=None,
    sidx: Optional[ShardedDeviceIndex] = None,
    return_docs: bool = False,
    fault_hook=None,
):
    """Per-query result counts over the mesh-sharded corpus — one
    ``shard_map`` dispatch, counts combined with one psum.

    ``cidx`` is any host index (or a :class:`ShardedDeviceIndex`, whose
    mesh is then reused).  Counts AND member docs are bit-identical to
    :func:`device_counts` and the host loop: the plan is global, each
    group's work runs on the one shard owning its docs, and docs are
    re-gathered in original plan-group order on host.

    ``info`` adds the sharding attribution: ``n_shards``,
    ``shards_touched`` (level-0 routing), ``shard_cells`` (true cells
    per shard), ``shard_times`` (per-shard dispatch seconds — what
    ``SearchService.record_shard_times`` consumes for failover),
    ``agg_throughput`` (total true cells / max per-shard true
    cells — the deterministic load-balance speedup bound) and
    ``load_balance`` (= agg_throughput / n_shards, the scaling
    efficiency).  ``fault_hook`` is the chaos harness's injection point
    (:mod:`repro.serve.faults`): called inside the dispatch path, where
    it may raise scheduled faults and perturb ``shard_times``."""
    from repro.analysis.sanitize import jit_cache_size
    from repro.core.batched_query import plan_segment_pairs

    t0 = time.perf_counter()
    cq = as_queries(queries)
    if sidx is None:
        sidx = (
            cidx
            if isinstance(cidx, ShardedDeviceIndex)
            else sharded_device_index(cidx)
        )
    if plan is None:
        plan = plan_segment_pairs(sidx.host, cq, track_work=False)
    t_plan = time.perf_counter() - t0
    if fault_hook is not None:
        # Chaos-harness injection point (repro.serve.faults): scheduled
        # faults raise here, inside the real sharded dispatch path; the
        # hook also watches n_shards to retire device-loss events once
        # failover re-partitioned without the lost shard.
        fault_hook.on_dispatch(n_shards=sidx.n_shards)
    if plan.n_pairs == 0:
        counts = np.zeros(plan.n_queries, np.int64)
        info = {
            "n_pairs": 0.0,
            "n_kernel_calls": 0.0,
            "n_shards": float(sidx.n_shards),
            "shards_touched": 0.0,
            "shard_cells": [0.0] * sidx.n_shards,
            "shard_times": [0.0] * sidx.n_shards,
            "agg_throughput": 1.0,
            "load_balance": 1.0 / max(sidx.n_shards, 1),
            "padding_overhead": 1.0,
            "t_plan_s": t_plan,
            "t_lower_s": 0.0,
            "t_fold_s": 0.0,
            "jit_compiles": 0.0,
        }
        if return_docs:
            return counts, np.empty(0, np.int32), info
        return counts, info

    t1 = time.perf_counter()
    lowered = lower_plan_sharded(plan, sidx)
    fold = _build_sharded_fold(
        sidx.mesh,
        lowered.group_width,
        lowered.stage_iters,
        lowered.n_queries_pad,
        bool(return_docs),
    )
    t_lower = time.perf_counter() - t1
    try:
        cache_before = jit_cache_size(fold)
    except AttributeError:  # pragma: no cover - other jax versions
        cache_before = None
    # Explicit per-batch upload, pre-placed shard-per-row so the jit
    # never reshards (and never transfers implicitly).
    from jax.sharding import NamedSharding

    from repro.dist import sharding as sh

    t2 = time.perf_counter()
    cells_spec, seg_spec = sh.plan_specs(sidx.mesh)
    out = fold(
        sidx.post_docs,
        jax.device_put(lowered.cells, NamedSharding(sidx.mesh, cells_spec)),
        jax.device_put(
            lowered.stage_seg, NamedSharding(sidx.mesh, seg_spec)
        ),
    )
    counts = jax.device_get(out[0])[: lowered.n_queries].astype(np.int64)
    t_fold = time.perf_counter() - t2
    compiles = (
        0.0
        if cache_before is None
        else float(jit_cache_size(fold) - cache_before)
    )
    total_true = float(lowered.n_cells_true.sum())
    max_true = float(lowered.n_cells_true.max())
    # Per-shard dispatch times for the straggler monitor.  The fused
    # shard_map is a synchronous collective — every shard runs the same
    # unified-shape program and holds the device for the whole fold — so
    # the honest per-shard attribution on a single-process rig is the
    # fold time itself, equal across shards; a real straggler (or an
    # injected one) shows up as that shard's entry inflating.
    shard_times = np.full(lowered.n_shards, t_fold, np.float64)
    if fault_hook is not None:
        shard_times = fault_hook.perturb_shard_times(shard_times)
    info = {
        "n_pairs": float(plan.n_pairs),
        "n_kernel_calls": 1.0,
        "n_shards": float(lowered.n_shards),
        "shards_touched": float(lowered.shards_touched),
        "shard_cells": lowered.n_cells_true.astype(float).tolist(),
        "shard_times": [float(x) for x in shard_times],
        "agg_throughput": total_true / max(max_true, 1.0),
        "load_balance": total_true
        / max(lowered.n_shards * max_true, 1.0),
        "padding_overhead": float(lowered.n_shards * lowered.n_cells)
        / max(total_true, 1.0),
        "t_plan_s": t_plan,
        "t_lower_s": t_lower,
        "t_fold_s": t_fold,
        "jit_compiles": compiles,
    }
    if not return_docs:
        return counts, info

    # Per-shard members -> original plan-group order: each group's cells
    # sit contiguously inside its owning shard's row; gathering rows in
    # group order and dropping PAD holes restores exactly the
    # single-device (and host-loop) doc array.
    members = jax.device_get(out[2]).reshape(-1)
    starts = lowered.grp_shard * lowered.n_cells + lowered.grp_off
    orig_cells = _ragged_gather(members, starts, lowered.grp_cnt)
    docs = orig_cells[orig_cells != PAD].astype(np.int32)
    return counts, docs, info
