"""Two-level cluster index (paper §3.3).

A *cluster index* is an inverted index over a corpus of k "documents",
each the concatenation of one cluster: for every term it lists the
clusters containing at least one document with that term.  A query (t, u)
first intersects the two cluster lists (Lookup, bucket size 8 — paper §4),
then runs the ordinary intersection only inside the common clusters
(Lookup, bucket size 16).

We build it over the *reordered* index (cluster-contiguous ids), so each
(term, cluster) posting segment is a contiguous slice — one ``searchsorted``
per query side, no data duplication.  Construction is O(nnz) via
run-length encoding of the (term, cluster) pairs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.index.build import InvertedIndex
from repro.index.lookup import bucketize, lookup_intersect

__all__ = ["ClusterIndex", "build_cluster_index"]


@dataclasses.dataclass
class ClusterIndex:
    """CSR of (term -> clusters containing it, with posting segments)."""

    cl_ptr: np.ndarray  # (n_terms + 1,) int64
    cl_ids: np.ndarray  # (nnz_c,) int32 — sorted cluster ids per term
    seg_start: np.ndarray  # (nnz_c,) int64 — posting-slice start (absolute)
    seg_end: np.ndarray  # (nnz_c,) int64
    ranges: np.ndarray  # (k + 1,) cluster id-range boundaries
    index: InvertedIndex  # the reordered index the segments point into
    bucket_size_clusters: int = 8
    bucket_size_postings: int = 16

    @property
    def k(self) -> int:
        return len(self.ranges) - 1

    def term_clusters(self, t: int) -> np.ndarray:
        return self.cl_ids[self.cl_ptr[t] : self.cl_ptr[t + 1]]

    def term_segments(self, t: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        lo, hi = self.cl_ptr[t], self.cl_ptr[t + 1]
        return self.cl_ids[lo:hi], self.seg_start[lo:hi], self.seg_end[lo:hi]

    # ------------------------------------------------------------------
    # Query algorithms
    # ------------------------------------------------------------------

    def query(self, t: int, u: int) -> Tuple[np.ndarray, Dict[str, float]]:
        """Two-level query: cluster-list intersection, then per-cluster
        posting intersection.  Returns (result doc ids, work dict)."""
        ct, st, et = self.term_segments(t)
        cu, su, eu = self.term_segments(u)
        # Level 1: intersect cluster lists (bucket size 8, universe k).
        if len(ct) <= len(cu):
            short, long_ = ct, cu
        else:
            short, long_ = cu, ct
        common, w1 = lookup_intersect(
            short.astype(np.int32),
            bucketize(long_.astype(np.int32), self.k, self.bucket_size_clusters),
        )
        # Positions of common clusters in each side's segment arrays.
        it = np.searchsorted(ct, common)
        iu = np.searchsorted(cu, common)

        docs = self.index.post_docs
        results = []
        probes = scanned = 0
        for ci, a, b in zip(common, it, iu):
            seg_t = docs[st[a] : et[a]]
            seg_u = docs[su[b] : eu[b]]
            if len(seg_t) > len(seg_u):
                seg_t, seg_u = seg_u, seg_t
            width = int(self.ranges[ci + 1] - self.ranges[ci])
            blong = bucketize(
                seg_u - self.ranges[ci], max(width, 1), self.bucket_size_postings
            )
            res, w2 = lookup_intersect((seg_t - self.ranges[ci]).astype(np.int32), blong)
            probes += w2["probes"]
            scanned += w2["scanned"]
            if len(res):
                results.append(res + self.ranges[ci])
        out = (
            np.concatenate(results).astype(np.int32)
            if results
            else np.empty(0, np.int32)
        )
        work = {
            "cluster_level": float(w1["total"]),
            "probes": float(probes),
            "scanned": float(scanned),
            "total": float(w1["total"] + probes + scanned),
        }
        return out, work

    def query_all_clusters(self, t: int, u: int) -> Tuple[np.ndarray, Dict[str, float]]:
        """Two-level query WITHOUT the level-1 Lookup: the two cluster
        lists are merge-joined directly (work = |C_t| + |C_u|) and the
        posting intersection runs inside every common cluster.  This is
        the 'most direct way' of §3.3 — competitive when k is small, and
        the oracle the bucketed level-1 Lookup of :meth:`query` must
        match exactly."""
        ct, st, et = self.term_segments(t)
        cu, su, eu = self.term_segments(u)
        # Merge-join the two sorted cluster-id lists.
        common, it, iu = np.intersect1d(ct, cu, return_indices=True)
        docs = self.index.post_docs
        results = []
        probes = scanned = 0
        for ci, a, b in zip(common, it, iu):
            seg_t = docs[st[a] : et[a]]
            seg_u = docs[su[b] : eu[b]]
            if len(seg_t) > len(seg_u):
                seg_t, seg_u = seg_u, seg_t
            width = int(self.ranges[ci + 1] - self.ranges[ci])
            blong = bucketize(
                seg_u - self.ranges[ci], max(width, 1), self.bucket_size_postings
            )
            res, w2 = lookup_intersect((seg_t - self.ranges[ci]).astype(np.int32), blong)
            probes += w2["probes"]
            scanned += w2["scanned"]
            if len(res):
                results.append(res + self.ranges[ci])
        out = (
            np.concatenate(results).astype(np.int32)
            if results
            else np.empty(0, np.int32)
        )
        merge_work = float(len(ct) + len(cu))
        work = {
            "cluster_level": merge_work,
            "probes": float(probes),
            "scanned": float(scanned),
            "total": merge_work + probes + scanned,
        }
        return out, work

    def query_batch(
        self, queries: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, Dict[str, float]]:
        """Vectorized :meth:`query` over an ``(n_queries, 2)`` term array.

        Returns CSR ``(ptr, docs, work)``: ``docs[ptr[i] : ptr[i + 1]]``
        is bit-identical to ``self.query(*queries[i])[0]`` and ``work``
        sums the per-query work dicts — no Python per-query loop (see
        ``repro.core.batched_query``).
        """
        from repro.core.batched_query import batched_query

        return batched_query(self, queries)


def build_cluster_index(
    reordered_index: InvertedIndex,
    ranges: np.ndarray,
    bucket_size_clusters: int = 8,
    bucket_size_postings: int = 16,
) -> ClusterIndex:
    """O(nnz) construction via RLE over (term, cluster) pairs.

    ``reordered_index`` must use cluster-contiguous document ids with
    cluster i owning [ranges[i], ranges[i+1]).
    """
    m = reordered_index.n_terms
    k = len(ranges) - 1
    docs = reordered_index.post_docs.astype(np.int64)
    # Cluster of each posting (ids are cluster-contiguous).
    cl = np.searchsorted(ranges, docs, side="right") - 1
    term = np.repeat(
        np.arange(m, dtype=np.int64), np.diff(reordered_index.post_ptr)
    )
    key = term * k + cl
    # Postings are sorted by (term, doc) and doc order refines cluster
    # order, so equal keys are contiguous: RLE via flat unique.
    change = np.empty(len(key), dtype=bool)
    if len(key):
        change[0] = True
        np.not_equal(key[1:], key[:-1], out=change[1:])
    starts = np.flatnonzero(change)
    ukey = key[starts]
    ends = np.append(starts[1:], len(key))
    cl_ids = (ukey % k).astype(np.int32)
    uterm = ukey // k
    cl_ptr = np.zeros(m + 1, dtype=np.int64)
    np.add.at(cl_ptr, uterm + 1, 1)
    np.cumsum(cl_ptr, out=cl_ptr)
    return ClusterIndex(
        cl_ptr=cl_ptr,
        cl_ids=cl_ids,
        seg_start=starts.astype(np.int64),
        seg_end=ends.astype(np.int64),
        ranges=np.asarray(ranges, dtype=np.int64),
        index=reordered_index,
        bucket_size_clusters=bucket_size_clusters,
        bucket_size_postings=bucket_size_postings,
    )
