"""Two-level cluster index (paper §3.3), arbitrary-arity conjunctions.

A *cluster index* is an inverted index over a corpus of k "documents",
each the concatenation of one cluster: for every term it lists the
clusters containing at least one document with that term.  A conjunctive
query (t_1, ..., t_a) first intersects the a cluster lists (Lookup,
bucket size 8 — paper §4), then runs the ordinary intersection only
inside the common clusters (Lookup, bucket size 16).

Both levels use a *cost-ordered plan* under the paper's lookup cost
model Φ(x, y) = min(x, y) (``repro.index.intersect.pair_cost``): lists
are intersected smallest-first, so the probing side of every Lookup is
the running intersection — never longer than any remaining list.  For
two terms this degenerates to the classic "shorter list probes the
longer" rule; ties keep the original term order (stable).

We build it over the *reordered* index (cluster-contiguous ids), so each
(term, cluster) posting segment is a contiguous slice — one ``searchsorted``
per query side, no data duplication.  Construction is O(nnz) via
run-length encoding of the (term, cluster) pairs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.index.build import InvertedIndex
from repro.index.lookup import bucketize, cost_order, lookup_intersect

__all__ = ["ClusterIndex", "build_cluster_index", "cost_order"]


def _flatten_terms(terms: Sequence) -> Tuple[int, ...]:
    """query(t, u), query(t, u, v), query([t, u, v]) all mean the same."""
    if len(terms) == 1 and not np.isscalar(terms[0]) and hasattr(terms[0], "__len__"):
        terms = tuple(terms[0])
    out = tuple(int(t) for t in terms)
    if not out:
        raise ValueError("a conjunctive query needs >= 1 term")
    return out


@dataclasses.dataclass
class ClusterIndex:
    """CSR of (term -> clusters containing it, with posting segments)."""

    cl_ptr: np.ndarray  # (n_terms + 1,) int64
    cl_ids: np.ndarray  # (nnz_c,) int32 — sorted cluster ids per term
    seg_start: np.ndarray  # (nnz_c,) int64 — posting-slice start (absolute)
    seg_end: np.ndarray  # (nnz_c,) int64
    ranges: np.ndarray  # (k + 1,) cluster id-range boundaries
    index: InvertedIndex  # the reordered index the segments point into
    bucket_size_clusters: int = 8
    bucket_size_postings: int = 16

    @property
    def k(self) -> int:
        return len(self.ranges) - 1

    def term_clusters(self, t: int) -> np.ndarray:
        return self.cl_ids[self.cl_ptr[t] : self.cl_ptr[t + 1]]

    def term_segments(self, t: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        lo, hi = self.cl_ptr[t], self.cl_ptr[t + 1]
        return self.cl_ids[lo:hi], self.seg_start[lo:hi], self.seg_end[lo:hi]

    # ------------------------------------------------------------------
    # Query algorithms
    # ------------------------------------------------------------------

    def _level2(
        self,
        terms: Tuple[int, ...],
        segs: List[Tuple[np.ndarray, np.ndarray, np.ndarray]],
        common: np.ndarray,
    ) -> Tuple[np.ndarray, int, int]:
        """Per-cluster posting intersection, cost-ordered chain.  Shared
        by :meth:`query` and :meth:`query_all_clusters` (they differ only
        in how ``common`` was computed)."""
        pos = [np.searchsorted(segs[i][0], common) for i in range(len(terms))]
        docs = self.index.post_docs
        results = []
        probes = scanned = 0
        for j, ci in enumerate(common):
            base = self.ranges[ci]
            width = int(self.ranges[ci + 1] - base)
            slices = [
                docs[segs[i][1][pos[i][j]] : segs[i][2][pos[i][j]]]
                for i in range(len(terms))
            ]
            order = cost_order([len(s) for s in slices])
            cur = (slices[order[0]] - base).astype(np.int32)
            for i in order[1:]:
                blong = bucketize(
                    slices[i] - base, max(width, 1), self.bucket_size_postings
                )
                cur, w2 = lookup_intersect(cur, blong)
                probes += w2["probes"]
                scanned += w2["scanned"]
            if len(cur):
                results.append(cur.astype(np.int64) + base)
        out = (
            np.concatenate(results).astype(np.int32)
            if results
            else np.empty(0, np.int32)
        )
        return out, probes, scanned

    def query(self, *terms) -> Tuple[np.ndarray, Dict[str, float]]:
        """Two-level conjunctive query over k >= 1 terms: cost-ordered
        cluster-list intersection, then a cost-ordered per-cluster posting
        chain.  Returns (result doc ids, work dict)."""
        terms = _flatten_terms(terms)
        segs = [self.term_segments(t) for t in terms]
        # Level 1: chain the cluster lists smallest-first (bucket size 8,
        # universe k); the running intersection is always the probing side.
        order = cost_order([len(s[0]) for s in segs])
        common = segs[order[0]][0].astype(np.int32)
        cluster_level = 0
        for i in order[1:]:
            common, w1 = lookup_intersect(
                common,
                bucketize(segs[i][0].astype(np.int32), self.k, self.bucket_size_clusters),
            )
            cluster_level += w1["total"]
        out, probes, scanned = self._level2(terms, segs, common)
        work = {
            "cluster_level": float(cluster_level),
            "probes": float(probes),
            "scanned": float(scanned),
            "total": float(cluster_level + probes + scanned),
        }
        return out, work

    def query_all_clusters(self, *terms) -> Tuple[np.ndarray, Dict[str, float]]:
        """Two-level query WITHOUT the level-1 Lookup: the cluster lists
        are merge-joined directly (work = Σ lengths per chain stage) and
        the posting intersection runs inside every common cluster.  This
        is the 'most direct way' of §3.3 — competitive when k is small,
        and the oracle the bucketed level-1 Lookup of :meth:`query` must
        match exactly."""
        terms = _flatten_terms(terms)
        segs = [self.term_segments(t) for t in terms]
        order = cost_order([len(s[0]) for s in segs])
        common = segs[order[0]][0]
        merge_work = 0.0
        for i in order[1:]:
            merge_work += float(len(common) + len(segs[i][0]))
            common = np.intersect1d(common, segs[i][0])
        out, probes, scanned = self._level2(terms, segs, common)
        work = {
            "cluster_level": merge_work,
            "probes": float(probes),
            "scanned": float(scanned),
            "total": merge_work + probes + scanned,
        }
        return out, work

    def query_batch(
        self, queries
    ) -> Tuple[np.ndarray, np.ndarray, Dict[str, float]]:
        """Vectorized :meth:`query` over a query batch — an ``(n, k)``
        term array (``QUERY_PAD`` entries for ragged rows) or a
        :class:`repro.core.queries.ConjunctiveQueries`.

        Returns CSR ``(ptr, docs, work)``: ``docs[ptr[i] : ptr[i + 1]]``
        is bit-identical to ``self.query(*terms_i)`` and ``work``
        sums the per-query work dicts — no Python per-query loop (see
        ``repro.core.batched_query``).
        """
        from repro.core.batched_query import batched_query

        return batched_query(self, queries)


def build_cluster_index(
    reordered_index: InvertedIndex,
    ranges: np.ndarray,
    bucket_size_clusters: int = 8,
    bucket_size_postings: int = 16,
) -> ClusterIndex:
    """O(nnz) construction via RLE over (term, cluster) pairs.

    ``reordered_index`` must use cluster-contiguous document ids with
    cluster i owning [ranges[i], ranges[i+1]).
    """
    m = reordered_index.n_terms
    k = len(ranges) - 1
    docs = reordered_index.post_docs.astype(np.int64)
    # Cluster of each posting (ids are cluster-contiguous).
    cl = np.searchsorted(ranges, docs, side="right") - 1
    term = np.repeat(
        np.arange(m, dtype=np.int64), np.diff(reordered_index.post_ptr)
    )
    key = term * k + cl
    # Postings are sorted by (term, doc) and doc order refines cluster
    # order, so equal keys are contiguous: RLE via flat unique.
    change = np.empty(len(key), dtype=bool)
    if len(key):
        change[0] = True
        np.not_equal(key[1:], key[:-1], out=change[1:])
    starts = np.flatnonzero(change)
    ukey = key[starts]
    ends = np.append(starts[1:], len(key))
    cl_ids = (ukey % k).astype(np.int32)
    uterm = ukey // k
    cl_ptr = np.zeros(m + 1, dtype=np.int64)
    np.add.at(cl_ptr, uterm + 1, 1)
    np.cumsum(cl_ptr, out=cl_ptr)
    return ClusterIndex(
        cl_ptr=cl_ptr,
        cl_ids=cl_ids,
        seg_start=starts.astype(np.int64),
        seg_end=ends.astype(np.int64),
        ranges=np.asarray(ranges, dtype=np.int64),
        index=reordered_index,
        bucket_size_clusters=bucket_size_clusters,
        bucket_size_postings=bucket_size_postings,
    )
