"""Two-level cluster index (paper §3.3) — now a thin L = 2 facade over
the arbitrary-depth hierarchical core (``repro.core.hier_index``).

A *cluster index* is an inverted index over a corpus of k "documents",
each the concatenation of one cluster: for every term it lists the
clusters containing at least one document with that term.  A conjunctive
query (t_1, ..., t_a) first intersects the a cluster lists (Lookup,
bucket size 8 — paper §4), then runs the ordinary intersection only
inside the common clusters (Lookup, bucket size 16).

Both levels use a *cost-ordered plan* under the paper's lookup cost
model Φ(x, y) = min(x, y) (``repro.index.intersect.pair_cost``): lists
are intersected smallest-first, so the probing side of every Lookup is
the running intersection — never longer than any remaining list.  For
two terms this degenerates to the classic "shorter list probes the
longer" rule; ties keep the original term order (stable).

We build it over the *reordered* index (cluster-contiguous ids), so each
(term, cluster) posting segment is a contiguous slice — one ``searchsorted``
per query side, no data duplication.  Construction is O(nnz) via
run-length encoding of the (term, cluster) pairs.

The query algorithms live in :class:`repro.core.hier_index.HierIndex`;
this class is exactly its L = 2 instantiation (``as_hier`` shares the
arrays, copying nothing) and exists so the historical two-level API —
and every caller pickled to it — keeps working unchanged, bit-for-bit
(results and work dicts, property-tested in ``tests/test_hier_index.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.core.hier_index import HierIndex, HierLevel, build_hier_index
from repro.index.build import InvertedIndex
from repro.index.lookup import cost_order

__all__ = ["ClusterIndex", "build_cluster_index", "cost_order"]


@dataclasses.dataclass
class ClusterIndex:
    """CSR of (term -> clusters containing it, with posting segments)."""

    cl_ptr: np.ndarray  # (n_terms + 1,) int64
    cl_ids: np.ndarray  # (nnz_c,) int32 — sorted cluster ids per term
    seg_start: np.ndarray  # (nnz_c,) int64 — posting-slice start (absolute)
    seg_end: np.ndarray  # (nnz_c,) int64
    ranges: np.ndarray  # (k + 1,) cluster id-range boundaries
    index: InvertedIndex  # the reordered index the segments point into
    bucket_size_clusters: int = 8
    bucket_size_postings: int = 16

    @property
    def k(self) -> int:
        return len(self.ranges) - 1

    def term_clusters(self, t: int) -> np.ndarray:
        return self.cl_ids[self.cl_ptr[t] : self.cl_ptr[t + 1]]

    def term_segments(self, t: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        lo, hi = self.cl_ptr[t], self.cl_ptr[t + 1]
        return self.cl_ids[lo:hi], self.seg_start[lo:hi], self.seg_end[lo:hi]

    # ------------------------------------------------------------------
    # The L = 2 view (shared arrays, built once)
    # ------------------------------------------------------------------

    def as_hier(self) -> HierIndex:
        """This index as the L = 2 :class:`HierIndex` — same arrays, no
        copies; the single source of the query algorithms."""
        cached = self.__dict__.get("_hier")
        if cached is None:
            cached = HierIndex(
                levels=(
                    HierLevel(
                        cl_ptr=self.cl_ptr,
                        cl_ids=self.cl_ids,
                        seg_start=self.seg_start,
                        seg_end=self.seg_end,
                        ranges=np.asarray(self.ranges, dtype=np.int64),
                    ),
                ),
                index=self.index,
                bucket_size_clusters=self.bucket_size_clusters,
                bucket_size_postings=self.bucket_size_postings,
            )
            self.__dict__["_hier"] = cached
        return cached

    # ------------------------------------------------------------------
    # Query algorithms (delegating facades)
    # ------------------------------------------------------------------

    def query(self, *terms) -> Tuple[np.ndarray, Dict[str, float]]:
        """Two-level conjunctive query over k >= 1 terms: cost-ordered
        cluster-list intersection, then a cost-ordered per-cluster posting
        chain.  Returns (result doc ids, work dict)."""
        return self.as_hier().query(*terms)

    def query_all_clusters(self, *terms) -> Tuple[np.ndarray, Dict[str, float]]:
        """Two-level query WITHOUT the level-1 Lookup: the cluster lists
        are merge-joined directly (work = Σ lengths per chain stage) and
        the posting intersection runs inside every common cluster.  This
        is the 'most direct way' of §3.3 — competitive when k is small,
        and the oracle the bucketed level-1 Lookup of :meth:`query` must
        match exactly."""
        return self.as_hier().query_all_clusters(*terms)

    def query_batch(
        self, queries
    ) -> Tuple[np.ndarray, np.ndarray, Dict[str, float]]:
        """Vectorized :meth:`query` over a query batch — an ``(n, k)``
        term array (``QUERY_PAD`` entries for ragged rows) or a
        :class:`repro.core.queries.ConjunctiveQueries`.

        Returns CSR ``(ptr, docs, work)``: ``docs[ptr[i] : ptr[i + 1]]``
        is bit-identical to ``self.query(*terms_i)`` and ``work``
        sums the per-query work dicts — no Python per-query loop (see
        ``repro.core.batched_query``).
        """
        from repro.core.batched_query import batched_query

        return batched_query(self, queries)

    def device(self):
        """The upload-once device mirror (cached on the shared L = 2
        hierarchical view — see :meth:`HierIndex.device`)."""
        return self.as_hier().device()


def build_cluster_index(
    reordered_index: InvertedIndex,
    ranges: np.ndarray,
    bucket_size_clusters: int = 8,
    bucket_size_postings: int = 16,
) -> ClusterIndex:
    """O(nnz) construction via RLE over (term, cluster) pairs.

    ``reordered_index`` must use cluster-contiguous document ids with
    cluster i owning [ranges[i], ranges[i+1)).  Exactly the leaf level of
    :func:`repro.core.hier_index.build_hier_index` with a single cluster
    level.
    """
    hier = build_hier_index(
        reordered_index,
        [np.asarray(ranges, dtype=np.int64)],
        bucket_size_clusters=bucket_size_clusters,
        bucket_size_postings=bucket_size_postings,
    )
    leaf = hier.levels[0]
    cidx = ClusterIndex(
        cl_ptr=leaf.cl_ptr,
        cl_ids=leaf.cl_ids,
        seg_start=leaf.seg_start,
        seg_end=leaf.seg_end,
        ranges=leaf.ranges,
        index=reordered_index,
        bucket_size_clusters=bucket_size_clusters,
        bucket_size_postings=bucket_size_postings,
    )
    cidx.__dict__["_hier"] = hier
    return cidx
