"""Cluster-contiguous document reordering (paper §3.3).

"The j-th document in cluster i gets document id j + Σ_{l<i} |c_l|."
Beyond the renumbering the clustering is ignored — the ordinary
single-index Lookup intersection runs on the reordered index, and the
skewed local term density accelerates it (speedup S_R, the paper's
best-performing variant).
"""

from __future__ import annotations

import numpy as np

__all__ = ["reorder_permutation", "cluster_ranges"]


def reorder_permutation(assign: np.ndarray, k: int) -> np.ndarray:
    """perm[old_id] = new_id; documents sorted by (cluster, old_id).

    ``assign`` must be a valid assignment into [0, k): a stale array from
    an earlier clustering (or a wrong k) would otherwise be silently
    renumbered into a permutation that disagrees with ``cluster_ranges``.
    """
    assign = np.asarray(assign)
    if assign.size and (assign.min() < 0 or assign.max() >= k):
        raise ValueError(
            f"assignment out of range: values span [{assign.min()}, "
            f"{assign.max()}] but k = {k}"
        )
    order = np.argsort(assign, kind="stable")  # old ids in new order
    perm = np.empty_like(order)
    perm[order] = np.arange(len(order))
    return perm


def cluster_ranges(assign: np.ndarray, k: int) -> np.ndarray:
    """(k + 1,) boundaries of the cluster-contiguous id ranges after
    reordering: cluster i owns new ids [ranges[i], ranges[i+1])."""
    sizes = np.bincount(np.asarray(assign), minlength=k)
    out = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(sizes, out=out[1:])
    return out
