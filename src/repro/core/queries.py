"""Arbitrary-arity conjunctive queries as a ragged (CSR) batch.

The paper's analysis is about *conjunctive queries* in general; the
2-term query is just its smallest instance.  This module is the single
representation every query path (``ClusterIndex.query``, the batched
engine, ``SearchService``, ``SecludPipeline.evaluate``) accepts:

* ragged/CSR — ``(q_ptr, q_terms)``: query i asks for the conjunction of
  ``q_terms[q_ptr[i] : q_ptr[i + 1]]`` (k_i >= 1 terms);
* padded — an ``(n_queries, max_arity)`` int array where rows shorter
  than ``max_arity`` are filled with ``QUERY_PAD`` (= -1, never a valid
  term id).  The historical ``(n, 2)`` term-pair array is the degenerate
  pad-free case.

``as_queries`` coerces either form (or a list of per-query term
sequences) so callers never branch on arity.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = ["QUERY_PAD", "ConjunctiveQueries", "as_queries"]

# Pad sentinel of the rectangular convenience form. Term ids are >= 0.
QUERY_PAD = -1


@dataclasses.dataclass
class ConjunctiveQueries:
    """A batch of conjunctive queries in CSR form."""

    q_ptr: np.ndarray  # (n_queries + 1,) int64
    q_terms: np.ndarray  # (nnz,) int64 term ids, >= 0

    def __post_init__(self):
        self.q_ptr = np.asarray(self.q_ptr, dtype=np.int64)
        self.q_terms = np.asarray(self.q_terms, dtype=np.int64)
        if len(self.q_ptr) == 0 or self.q_ptr[0] != 0:
            raise ValueError("q_ptr must start at 0")
        if self.q_ptr[-1] != len(self.q_terms):
            raise ValueError("q_ptr[-1] must equal len(q_terms)")
        if (np.diff(self.q_ptr) < 1).any():
            raise ValueError("every conjunctive query needs >= 1 term")
        if len(self.q_terms) and self.q_terms.min() < 0:
            raise ValueError("term ids must be >= 0")

    # -- shape ---------------------------------------------------------

    @property
    def n_queries(self) -> int:
        return len(self.q_ptr) - 1

    def __len__(self) -> int:
        return self.n_queries

    @property
    def arities(self) -> np.ndarray:
        return np.diff(self.q_ptr)

    @property
    def max_arity(self) -> int:
        return int(self.arities.max()) if self.n_queries else 0

    def terms(self, i: int) -> np.ndarray:
        return self.q_terms[self.q_ptr[i] : self.q_ptr[i + 1]]

    def __iter__(self):
        for i in range(self.n_queries):
            yield self.terms(i)

    def __getitem__(self, s: slice) -> "ConjunctiveQueries":
        if not isinstance(s, slice):
            raise TypeError("only slicing is supported")
        start, stop, step = s.indices(self.n_queries)
        if step != 1:
            raise ValueError("only unit-stride slices")
        lo, hi = self.q_ptr[start], self.q_ptr[stop]
        return ConjunctiveQueries(
            q_ptr=self.q_ptr[start : stop + 1] - lo, q_terms=self.q_terms[lo:hi]
        )

    # -- conversions ---------------------------------------------------

    def padded(self, pad: int = QUERY_PAD, width: int | None = None) -> np.ndarray:
        """The ``(n_queries, width)`` rectangular form, ``pad``-filled."""
        width = self.max_arity if width is None else int(width)
        out = np.full((self.n_queries, max(width, 1)), pad, dtype=np.int64)
        lens = self.arities
        rows = np.repeat(np.arange(self.n_queries), lens)
        within = np.arange(len(self.q_terms)) - self.q_ptr[:-1][rows]
        out[rows, within] = self.q_terms
        return out

    @classmethod
    def from_padded(cls, arr: np.ndarray, pad: int = QUERY_PAD) -> "ConjunctiveQueries":
        """Build from an ``(n, max_arity)`` array; entries == ``pad`` (or
        any negative id) are dropped.  Pads may appear anywhere in a row;
        term order of the survivors is preserved."""
        arr = np.asarray(arr, dtype=np.int64)
        if arr.ndim != 2:
            raise ValueError(f"padded query array must be 2-D, got shape {arr.shape}")
        keep = (arr != pad) & (arr >= 0)
        ptr = np.zeros(arr.shape[0] + 1, dtype=np.int64)
        np.cumsum(keep.sum(axis=1), out=ptr[1:])
        return cls(q_ptr=ptr, q_terms=arr[keep])

    @classmethod
    def from_lists(cls, lists: Iterable[Sequence[int]]) -> "ConjunctiveQueries":
        lists = [np.asarray(x, dtype=np.int64).ravel() for x in lists]
        ptr = np.zeros(len(lists) + 1, dtype=np.int64)
        np.cumsum([len(x) for x in lists], out=ptr[1:])
        terms = np.concatenate(lists) if lists else np.zeros(0, np.int64)
        return cls(q_ptr=ptr, q_terms=terms)


def as_queries(queries) -> ConjunctiveQueries:
    """Coerce any accepted query form to :class:`ConjunctiveQueries`.

    Accepts a ``ConjunctiveQueries``, an ``(n, k)`` int array (``k >= 1``,
    ``QUERY_PAD`` entries allowed for ragged rows), or an iterable of
    per-query term sequences.
    """
    if isinstance(queries, ConjunctiveQueries):
        return queries
    if isinstance(queries, np.ndarray):
        if queries.ndim == 2 and queries.shape[0] == 0:
            return ConjunctiveQueries(
                q_ptr=np.zeros(1, np.int64), q_terms=np.zeros(0, np.int64)
            )
        return ConjunctiveQueries.from_padded(queries)
    if isinstance(queries, (list, tuple)):
        first = queries[0] if len(queries) else None
        if first is not None and np.isscalar(first):
            raise ValueError(
                "a flat term sequence is ambiguous; pass [[t0, t1, ...]] "
                "for a single query or an (n, k) array for a batch"
            )
        return ConjunctiveQueries.from_lists(queries)
    return ConjunctiveQueries.from_padded(np.asarray(queries))
