"""Flat K-means on the ψ objective (paper §3.2).

Two update modes, exactly as the paper:

* **round-based** — each iteration rebuilds the δ⁺ tables once, scores all
  documents with one SpMM, and reassigns every document simultaneously.
  One iteration is O(kN).  Iterates "as long as the objective improves by
  at least 1 %" (paper §4).

* **document-grained** — for small |D| (the paper switches below 100k
  documents at its 25M-document scale; the cutoff is a parameter here,
  default scaled to our corpus sizes) documents are visited one at a time
  and the objective state (counts + affected tables) is updated after
  *every* move: remove d from its cluster (δ⁻), add to the best (δ⁺).
  This kills the oscillations the round-based scheme suffers on small
  cluster sizes.

Beyond-paper robustness (noted in DESIGN.md): empty clusters are reseeded
with the documents that fit their current cluster worst; the paper leaves
empties unspecified.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.objective import (
    FrequentTermView,
    assignment_scores,
    cluster_counts,
    delta_add_tables,
    delta_remove_tables,
    psi_from_counts,
)

__all__ = ["KMeansResult", "kmeans", "document_grained_pass"]


@dataclasses.dataclass
class KMeansResult:
    assign: np.ndarray  # (n_docs,) int64 in [0, k)
    psi: float
    n_iters: int
    psi_history: list


def _reseed_empty(
    assign: np.ndarray, scores: np.ndarray, k: int, rng: np.random.Generator
) -> np.ndarray:
    """Move the worst-fitting documents into empty clusters."""
    sizes = np.bincount(assign, minlength=k)
    empty = np.flatnonzero(sizes == 0)
    if len(empty) == 0:
        return assign
    # Documents whose current-cluster fit is worst (largest own-δ).
    own = scores[np.arange(len(assign)), assign]
    donors = np.argsort(-own)
    used = 0
    for j in empty:
        # Skip donors that would empty their own cluster.
        while used < len(donors) and sizes[assign[donors[used]]] <= 1:
            used += 1
        if used >= len(donors):
            break
        d = donors[used]
        sizes[assign[d]] -= 1
        assign[d] = j
        sizes[j] += 1
        used += 1
    return assign


def kmeans(
    view: FrequentTermView,
    k: int,
    init_assign: Optional[np.ndarray] = None,
    max_iters: int = 100,
    min_rel_improvement: float = 0.01,
    doc_grained_below: int = 2_048,
    seed: int = 0,
) -> KMeansResult:
    """Cluster ``view`` into k clusters minimizing ψ.

    ``init_assign=None`` → random balanced init. Switches to the
    document-grained mode when |D| < ``doc_grained_below`` (paper §3.2).
    """
    rng = np.random.default_rng(seed)
    n = view.n_docs
    if init_assign is None:
        init_assign = rng.permutation(n) % k
    assign = np.asarray(init_assign, dtype=np.int64).copy()

    if n < doc_grained_below:
        return document_grained_pass(
            view, k, assign, max_passes=max_iters, rng=rng,
            min_rel_improvement=min_rel_improvement,
        )

    history = []
    counts = cluster_counts(view, assign, k)
    psi = psi_from_counts(counts, view.p_freq)
    history.append(psi)
    _it = 0
    for _it in range(1, max_iters + 1):
        tables = delta_add_tables(counts, view.p_freq)
        scores = assignment_scores(view, tables)  # (n, k)
        new_assign = np.argmin(scores, axis=1)
        new_assign = _reseed_empty(new_assign, scores, k, rng)
        counts_new = cluster_counts(view, new_assign, k)
        psi_new = psi_from_counts(counts_new, view.p_freq)
        history.append(psi_new)
        if psi_new < psi * (1.0 - 1e-12):
            improved = (psi - psi_new) / max(psi, 1e-30)
            assign, counts, psi = new_assign, counts_new, psi_new
            if improved < min_rel_improvement:
                break
        else:
            break  # no improvement: keep previous assignment
    return KMeansResult(assign=assign, psi=psi, n_iters=_it, psi_history=history)


def document_grained_pass(
    view: FrequentTermView,
    k: int,
    assign: np.ndarray,
    max_passes: int = 20,
    min_rel_improvement: float = 0.01,
    rng: Optional[np.random.Generator] = None,
    table_refresh: int = 1,
) -> KMeansResult:
    """Document-grained K-means: objective state updated after every move.

    Exact bookkeeping: counts are updated per move; the δ tables of the two
    affected clusters are rebuilt every ``table_refresh`` moves (=1 → fully
    exact, the paper's description; >1 → the paper-§6 "compromise"
    between round-based and document-wise updates).
    """
    rng = rng or np.random.default_rng(0)
    n = view.n_docs
    assign = np.asarray(assign, dtype=np.int64).copy()
    counts = cluster_counts(view, assign, k)
    p = view.p_freq
    mat = view.mat  # CSR: rows are documents, values P[rank]

    add_t = delta_add_tables(counts, p)
    rem_t = delta_remove_tables(counts, p)
    psi = psi_from_counts(counts, p)
    history = [psi]
    stale = np.zeros(k, dtype=bool)
    moves_since_refresh = 0

    indptr, indices, data = mat.indptr, mat.indices, mat.data
    _npass = 0
    for _npass in range(1, max_passes + 1):
        moved = 0
        for d in rng.permutation(n):
            lo, hi = indptr[d], indptr[d + 1]
            ranks = indices[lo:hi]
            pvals = data[lo:hi]  # already P[rank]
            if len(ranks) == 0:
                continue
            cur = assign[d]
            if stale.any() and moves_since_refresh >= table_refresh:
                for j in np.flatnonzero(stale):
                    add_t[j] = delta_add_tables(counts[j : j + 1], p)[0]
                    rem_t[j] = delta_remove_tables(counts[j : j + 1], p)[0]
                stale[:] = False
                moves_since_refresh = 0
            # Gain of removing d from cur; cost of adding to each j.
            add_scores = pvals @ add_t[:, ranks].T  # (k,)
            remove_gain = float(pvals @ rem_t[cur, ranks])
            # Moving d from cur to j≠cur changes ψ by add(j) − remove(cur);
            # staying costs 0.
            dpsi = add_scores - remove_gain
            dpsi[cur] = 0.0
            best = int(np.argmin(dpsi))
            if best != cur and dpsi[best] < -1e-15:
                counts[cur, ranks] -= 1
                counts[best, ranks] += 1
                assign[d] = best
                stale[cur] = stale[best] = True
                moves_since_refresh += 1
                moved += 1
        psi_new = psi_from_counts(counts, p)
        history.append(psi_new)
        rel = (psi - psi_new) / max(psi, 1e-30)
        psi = psi_new
        # Refresh all tables between passes.
        add_t = delta_add_tables(counts, p)
        rem_t = delta_remove_tables(counts, p)
        stale[:] = False
        moves_since_refresh = 0
        if moved == 0 or rel < min_rel_improvement:
            break
    return KMeansResult(assign=assign, psi=psi, n_iters=_npass, psi_history=history)
