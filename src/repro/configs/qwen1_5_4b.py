"""qwen1.5-4b [hf:Qwen/Qwen1.5-4B]: dense 40L MHA with QKV bias."""

import dataclasses

from repro.configs.base import ArchSpec, lm_cells
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="qwen1.5-4b",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
    rope_theta=5_000_000.0,
    tie_embeddings=False,
    remat="none",
)

SMOKE = dataclasses.replace(
    CFG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512, dtype="float32", loss_chunk=16,
)


def spec() -> ArchSpec:
    import dataclasses as dc

    cells = lm_cells(full_attention_only=True, microbatches=8)
    # 20 MHA heads don't divide the 16-way model axis -> head-replicated
    # prefill score tiles; a smaller query chunk bounds them.
    c = cells["prefill_32k"]
    cells["prefill_32k"] = dc.replace(
        c, overrides={**c.overrides, "attn_q_chunk": 512}
    )
    return ArchSpec(
        name="qwen1.5-4b",
        family="lm",
        cfg=CFG,
        smoke_cfg=SMOKE,
        cells=cells,
    )
