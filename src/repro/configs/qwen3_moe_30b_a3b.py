"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 48L GQA(32q/4kv, head 128,
QK-norm), 128-expert top-8 MoE (expert d_ff=768), no shared expert."""

import dataclasses

from repro.configs.base import ArchSpec, lm_cells
from repro.models.transformer import LMConfig, MoESpec

CFG = LMConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoESpec(n_experts=128, top_k=8, d_expert=768, capacity_factor=1.25),
    tie_embeddings=False,
    remat="none",
)

SMOKE = dataclasses.replace(
    CFG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab=512, moe=MoESpec(n_experts=8, top_k=2, d_expert=64),
    dtype="float32", loss_chunk=16,
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="qwen3-moe-30b-a3b",
        family="lm",
        cfg=CFG,
        smoke_cfg=SMOKE,
        cells=lm_cells(full_attention_only=True, microbatches=8),
        fsdp=True,  # 30B params: Adam state exceeds 16-way model sharding
    )
