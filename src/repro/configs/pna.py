"""pna [arXiv:2004.05718]: 4-layer PNA, d_hidden=75, aggregators
mean/max/min/std, scalers identity/amplification/attenuation.

The four graph shapes carry their own data geometry:
  * full_graph_sm — Cora-like:        2,708 nodes / 10,556 edges / 1,433 feats
  * minibatch_lg  — Reddit-like:    232,965 nodes / 114.6M edges, 1,024-seed
                    batches with fanout (15, 10) via the real neighbor sampler
  * ogb_products  — 2,449,029 nodes / 61.9M edges / 100 feats, full batch
  * molecule      — 30-node / 64-edge graphs, batch 128, graph readout
"""

import dataclasses

from repro.configs.base import ArchSpec, Cell
from repro.models.pna import PNAConfig

CFG = PNAConfig(
    name="pna", n_layers=4, d_hidden=75, d_feat=1433, n_classes=47,
    delta=2.5, readout="node",
)

SMOKE = dataclasses.replace(CFG, d_feat=32, d_hidden=16, n_classes=4)


def spec() -> ArchSpec:
    cells = {
        "full_graph_sm": Cell(
            kind="train", batch=1,
            extra={"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
                   "n_classes": 7},
        ),
        "minibatch_lg": Cell(
            kind="train_minibatch", batch=1024,
            extra={"n_nodes": 232965, "n_edges": 114_615_892, "d_feat": 602,
                   "fanouts": (15, 10), "n_classes": 41},
        ),
        "ogb_products": Cell(
            kind="train", batch=1,
            extra={"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100,
                   "n_classes": 47},
        ),
        "molecule": Cell(
            kind="train", batch=128,
            extra={"nodes_per_graph": 30, "edges_per_graph": 64, "d_feat": 32,
                   "n_classes": 16, "readout": "graph"},
        ),
    }
    return ArchSpec(name="pna", family="gnn", cfg=CFG, smoke_cfg=SMOKE, cells=cells)
