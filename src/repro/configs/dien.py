"""dien [arXiv:1809.03672]: GRU interest extraction + AUGRU evolution."""

import dataclasses

from repro.configs.base import ArchSpec, recsys_cells
from repro.models.recsys.dien import DIENConfig

CFG = DIENConfig(
    name="dien", vocab=1_000_000, embed_dim=18, seq_len=100, gru_dim=108,
    mlp=(200, 80),
)

SMOKE = dataclasses.replace(CFG, vocab=1000, seq_len=12, gru_dim=24, mlp=(32, 16))


def spec() -> ArchSpec:
    return ArchSpec(
        name="dien", family="recsys", cfg=CFG, smoke_cfg=SMOKE,
        cells=recsys_cells(),
    )
