"""mind [arXiv:1904.08030]: multi-interest capsule routing retrieval."""

import dataclasses

from repro.configs.base import ArchSpec, recsys_cells
from repro.models.recsys.mind import MINDConfig

CFG = MINDConfig(
    name="mind", vocab=1_000_000, embed_dim=64, n_interests=4,
    capsule_iters=3, hist_len=50,
)

SMOKE = dataclasses.replace(CFG, vocab=1000, embed_dim=16, hist_len=10)


def spec() -> ArchSpec:
    return ArchSpec(
        name="mind", family="recsys", cfg=CFG, smoke_cfg=SMOKE,
        cells=recsys_cells(),
    )
