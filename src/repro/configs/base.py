"""ArchSpec / Cell descriptors shared by every architecture config."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

__all__ = ["Cell", "ArchSpec", "lm_cells", "recsys_cells"]


@dataclasses.dataclass(frozen=True)
class Cell:
    """One (architecture × input shape) dry-run/roofline cell."""

    kind: str  # train | prefill | decode | serve | retrieval | train_minibatch
    batch: int
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)
    overrides: Dict[str, Any] = dataclasses.field(default_factory=dict)
    skip: Optional[str] = None  # reason, if this cell is skipped by design


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str  # lm | gnn | recsys
    cfg: Any
    smoke_cfg: Any
    cells: Dict[str, Cell]
    fsdp: bool = False  # shard params over 'data' too (ZeRO-3 style)


def lm_cells(full_attention_only: bool, microbatches: int = 4) -> Dict[str, Cell]:
    """The four LM shapes. ``long_500k`` is skipped for pure full-attention
    architectures per the assignment note (sub-quadratic attention
    required); gemma3's hybrid local:global qualifies and runs it."""
    skip = (
        "pure full-attention arch: 500k-token decode requires sub-quadratic "
        "attention (assignment note; see DESIGN.md §7)"
        if full_attention_only
        else None
    )
    return {
        "train_4k": Cell(
            kind="train", batch=256,
            extra={"seq_len": 4096, "microbatches": microbatches},
            overrides={"remat": "full", "attn_q_chunk": 512},
        ),
        "prefill_32k": Cell(
            kind="prefill", batch=32, extra={"seq_len": 32768},
            overrides={"kv_quant": True, "attn_q_chunk": 2048},
        ),
        "decode_32k": Cell(
            kind="decode", batch=128, extra={"cache_len": 32768},
            overrides={"kv_quant": True},
        ),
        "long_500k": Cell(
            kind="decode", batch=1, extra={"cache_len": 524288},
            overrides={"kv_quant": True}, skip=skip,
        ),
    }


def recsys_cells() -> Dict[str, Cell]:
    return {
        "train_batch": Cell(kind="train", batch=65536),
        "serve_p99": Cell(kind="serve", batch=512),
        "serve_bulk": Cell(kind="serve", batch=262144),
        "retrieval_cand": Cell(
            kind="retrieval", batch=1, extra={"n_candidates": 1_000_000}
        ),
    }
