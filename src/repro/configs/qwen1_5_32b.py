"""qwen1.5-32b [hf:Qwen/Qwen1.5-32B]: dense 64L GQA(kv=40 = MHA) with QKV bias."""

import dataclasses

from repro.configs.base import ArchSpec, lm_cells
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="qwen1.5-32b",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    remat="dots",
)

SMOKE = dataclasses.replace(
    CFG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512, dtype="float32", remat="none", loss_chunk=16,
)


def spec() -> ArchSpec:
    import dataclasses as dc

    cells = lm_cells(full_attention_only=True, microbatches=8)
    # 40 MHA heads don't divide the 16-way model axis, so XLA keeps the
    # (q_chunk, 32k) prefill score tiles head-replicated; a smaller query
    # chunk bounds them (measured: 49 GiB -> fits; EXPERIMENTS.md §Perf).
    c = cells["prefill_32k"]
    cells["prefill_32k"] = dc.replace(
        c, overrides={**c.overrides, "attn_q_chunk": 512}
    )
    return ArchSpec(
        name="qwen1.5-32b",
        family="lm",
        cfg=CFG,
        smoke_cfg=SMOKE,
        cells=cells,
        fsdp=True,  # 32B params: optimizer state exceeds per-chip HBM
    )
