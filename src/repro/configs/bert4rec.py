"""bert4rec [arXiv:1904.06690]: bidirectional 2-block transformer,
masked-item (Cloze) training. Encoder-only: no decode shapes exist in the
recsys set (nothing to skip)."""

import dataclasses

from repro.configs.base import ArchSpec, recsys_cells
from repro.models.recsys.bert4rec import BERT4RecConfig

CFG = BERT4RecConfig(
    name="bert4rec", vocab=1_000_000, embed_dim=64, n_blocks=2, n_heads=2,
    seq_len=200, d_ff=256,
)

SMOKE = dataclasses.replace(CFG, vocab=1000, embed_dim=16, seq_len=16, d_ff=32)


def spec() -> ArchSpec:
    return ArchSpec(
        name="bert4rec", family="recsys", cfg=CFG, smoke_cfg=SMOKE,
        cells=recsys_cells(),
    )
