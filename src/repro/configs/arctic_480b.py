"""arctic-480b [hf:Snowflake/snowflake-arctic-base]: dense-MoE hybrid —
35L GQA(56q/8kv) with a dense FFN residual in parallel with a 128-expert
top-2 MoE per layer. bf16 optimizer moments + FSDP: at 480B params the
optimizer state, not activations, is the HBM constraint."""

import dataclasses

from repro.configs.base import ArchSpec, lm_cells
from repro.models.transformer import LMConfig, MoESpec

CFG = LMConfig(
    name="arctic-480b",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab=32000,
    moe=MoESpec(
        n_experts=128, top_k=2, d_expert=4864, dense_residual=True,
        capacity_factor=1.25,
    ),
    tie_embeddings=False,
    remat="full",
    param_dtype="bfloat16",  # 480B: f32 params alone would be 7.5 GiB/chip
)

SMOKE = dataclasses.replace(
    CFG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab=512,
    moe=MoESpec(n_experts=8, top_k=2, d_expert=96, dense_residual=True),
    dtype="float32", remat="none", loss_chunk=16,
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="arctic-480b",
        family="lm",
        cfg=CFG,
        smoke_cfg=SMOKE,
        cells=lm_cells(full_attention_only=True, microbatches=8),
        fsdp=True,
    )
