"""Architecture configs (--arch <id>) and the cell matrix.

One module per assigned architecture with the exact public-literature
config, plus the paper's own SeCluD configs.  ``registry.get_arch(name)``
returns an ArchSpec; ``ArchSpec.cells`` maps shape names to Cell
descriptors (kind of step, batch, per-shape config overrides, skip
reasons).
"""

from repro.configs.base import ArchSpec, Cell
from repro.configs.registry import ARCH_NAMES, get_arch

__all__ = ["ArchSpec", "Cell", "ARCH_NAMES", "get_arch"]
