"""dcn-v2 [arXiv:2008.13535]: 3 full-rank cross layers + 1024-1024-512 MLP."""

import dataclasses

from repro.configs.base import ArchSpec, recsys_cells
from repro.models.recsys.dcnv2 import DCNv2Config

CFG = DCNv2Config(
    name="dcn-v2", n_dense=13, n_sparse=26, vocab_per_field=100_000,
    embed_dim=16, n_cross_layers=3, mlp=(1024, 1024, 512),
)

SMOKE = dataclasses.replace(
    CFG, vocab_per_field=500, n_sparse=6, embed_dim=8, mlp=(64, 32),
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="dcn-v2", family="recsys", cfg=CFG, smoke_cfg=SMOKE,
        cells=recsys_cells(),
    )
