"""gemma3-4b [hf:google/gemma-3-4b-pt]: 34L GQA(8q/4kv, head 256), 5:1
local:global sliding window (1024), 128k context, 262k vocab, tied
embeddings, QK-norm. The only assigned LM that runs ``long_500k``
(hybrid local:global is sub-quadratic in the local layers; decode reads
are O(window) there and O(L) only in every 6th layer)."""

import dataclasses

from repro.configs.base import ArchSpec, lm_cells
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="gemma3-4b",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262144,
    qk_norm=True,
    rope_theta=1_000_000.0,
    window=1024,
    global_every=6,  # 5 local : 1 global
    tie_embeddings=True,
    remat="none",
)

SMOKE = dataclasses.replace(
    CFG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, window=8, global_every=3, dtype="float32",
    loss_chunk=16,
)


def spec() -> ArchSpec:
    return ArchSpec(
        name="gemma3-4b",
        family="lm",
        cfg=CFG,
        smoke_cfg=SMOKE,
        cells=lm_cells(full_attention_only=False, microbatches=8),
    )
