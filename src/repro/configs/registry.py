"""--arch registry. Lazy imports keep ``import repro.configs`` light."""

import importlib

_MODULES = {
    "qwen1.5-32b": "repro.configs.qwen1_5_32b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "arctic-480b": "repro.configs.arctic_480b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "pna": "repro.configs.pna",
    "dien": "repro.configs.dien",
    "mind": "repro.configs.mind",
    "dcn-v2": "repro.configs.dcn_v2",
    "bert4rec": "repro.configs.bert4rec",
}

ARCH_NAMES = tuple(_MODULES)


def get_arch(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(_MODULES[name]).spec()
