"""Verification subsystem: static invariant lint + runtime sanitizers.

Two heads over the same concern — the engine invariants nothing else
enforces mechanically:

* :mod:`repro.analysis.lint` — the ``seclint`` AST rules (SEC001–SEC004)
  run by ``tools/seclint.py`` and the CI ``lint-static`` job.
* :mod:`repro.analysis.runtime` — the ``REPRO_DEBUG`` gate behind the
  structural ``validate()`` methods on ``HierIndex`` / ``SegmentPlan`` /
  ``DeviceIndex`` / ``ShardedDeviceIndex``.
* :mod:`repro.analysis.sanitize` — the pytest sanitize mode: implicit
  transfer guard + jit compile counter.

``lint`` is import-light (stdlib ast only) so the CLI stays usable
without jax installed; the jax-importing pieces live in ``sanitize``.
"""

from repro.analysis.runtime import debug_enabled, force_debug, maybe_validate

__all__ = ["debug_enabled", "force_debug", "maybe_validate"]
