"""The `REPRO_DEBUG` gate for the runtime validation head.

Structural ``validate()`` methods (monotone CSR pointers, nested level
ranges, sorted postings, shard partition exactness — see
:mod:`repro.core.hier_index` / :mod:`repro.core.device_engine`) cost real
time on large indexes, so production builds skip them.  They run when

* the ``REPRO_DEBUG`` environment variable is set to anything but
  ``""``/``"0"``/``"false"`` — the CI sanitize job sets ``REPRO_DEBUG=1``
  so every index/plan built during the gated test subset self-checks; or
* a test forces the flag locally with :func:`force_debug`.

Call sites gate through :func:`maybe_validate` so the fast path stays a
single dict lookup.
"""

from __future__ import annotations

import contextlib
import os

__all__ = ["debug_enabled", "force_debug", "maybe_validate"]

_FALSY = ("", "0", "false", "False", "no")

# tri-state override: None = follow the environment variable.
_forced: list = [None]


def debug_enabled() -> bool:
    """True when structural validation should run (env or forced)."""
    if _forced[0] is not None:
        return bool(_forced[0])
    return os.environ.get("REPRO_DEBUG", "") not in _FALSY


@contextlib.contextmanager
def force_debug(value: bool = True):
    """Override the ``REPRO_DEBUG`` environment gate within a block —
    how property tests turn validation on without mutating ``os.environ``
    (subprocess tests inherit the real environment, not this)."""
    prev = _forced[0]
    _forced[0] = value
    try:
        yield
    finally:
        _forced[0] = prev


def maybe_validate(obj):
    """Run ``obj.validate()`` when debugging is enabled; always returns
    ``obj`` so builders can gate in tail position."""
    if debug_enabled():
        obj.validate()
    return obj
