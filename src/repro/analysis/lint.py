"""seclint — repo-specific static invariants of the SeCluD engine.

The device hot path (PR 5/6) is fast for reasons the type system cannot
see: traced code never syncs to host, jit cache keys are quantized
shapes, PAD discipline makes masked execution exact, and every kernel
package ships its jnp oracle.  These are one careless edit away from
silently rotting, so they are linted as ASTs:

* **SEC001** — host-device sync points inside traced code of the
  device-path modules (``core/device_engine.py``, ``kernels/*``):
  ``.item()``, ``np.asarray``/``np.array``, ``int()``/``float()``/
  ``bool()`` on traced values, and implicit truthiness (``if x:`` on a
  traced value).  Any of these blocks dispatch and drags the value over
  PCIe — exactly the host⇄device ping-pong the fused fold removed.

* **SEC002** — recompilation hazards anywhere in ``src/``: ``jax.jit``
  constructed inside a function body (a fresh jit per call retraces
  every batch; exempt under ``functools.lru_cache``/``cache``, the
  sharded fold's pattern), unhashable ``static_arg*`` defaults, and raw
  ``len(...)``/``.shape`` expressions passed as static arguments of a
  jitted callable without going through ``_quantize`` — dynamic shapes
  leaking into the jit cache key defeat the ~1/8 quantization grid.

* **SEC003** — literal ``-1`` sentinel use on doc/query cell data in the
  data-plane modules: comparisons against ``-1`` and ``cells[...] = -1``
  style fills must use the exported ``PAD``/``QUERY_PAD`` constants
  (``repro.kernels.intersect.ref`` / ``repro.core.queries``) so the
  sentinel stays one value everywhere the fold masks on it.

* **SEC004** — kernel-contract completeness: every ``kernels/<name>/``
  package must ship ``kernel.py`` (the pallas kernel), ``ref.py`` (the
  jnp oracle), ``ops.py`` importing the oracle as its fallback, and a
  ``tests/test_kernels_<name>.py`` kernel≡ref test.

* **SEC005** — jit construction in the serving request path
  (``serve/*``): the serving loop's whole latency story rests on the
  shape-grid prewarm — every executable compiled at startup, zero
  traces under traffic.  ``jax.jit`` (or ``partial(jax.jit, ...)``)
  constructed inside any function body of a serve module builds a
  fresh empty cache per request and retraces every batch; bind jitted
  callables at module level or behind ``functools.lru_cache`` (the
  engine's pattern) and let the loop prewarm them.

* **SEC006** — resilience-defeating error handling in the fault-path
  modules (``serve/*``, ``dist/*``): a bare ``except:``, an
  ``except Exception:`` whose body only passes/continues, or a
  ``while True:`` loop with no ``break``/``return``/``raise`` in its
  own body.  The resilience ladder only degrades gracefully if every
  failure is *observed* (fed to the circuit breaker / straggler
  monitor) and every retry is *bounded*; swallowed exceptions and
  unbounded retry loops turn a dead shard into a silent hang.

``lint_paths`` is the engine; ``tools/seclint.py`` is the CLI.  Rules
are deliberately narrow: a finding is an invariant violation, not a
style nit, and ``src/`` must stay finding-free (CI enforces it).
"""

from __future__ import annotations

import ast
import dataclasses
import fnmatch
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

__all__ = ["Finding", "lint_paths", "lint_file", "lint_source", "RULES"]

RULES = {
    "SEC001": "host-device sync point in traced device-path code",
    "SEC002": "jit recompilation hazard",
    "SEC003": "literal -1 sentinel instead of PAD/QUERY_PAD",
    "SEC004": "incomplete kernel contract (kernel + ref + ops + test)",
    "SEC005": "jit construction in the serving request path",
    "SEC006": "resilience-defeating error handling (swallowed exception "
    "or unbounded retry loop)",
}

# Modules whose traced code must never sync to host (SEC001).  Matched
# against the posix path suffix.
DEVICE_PATH_PATTERNS = (
    "*/core/device_engine.py",
    "*/kernels/*/kernel.py",
    "*/kernels/*/ref.py",
    "*/kernels/*/ops.py",
)

# Serving modules whose function bodies must never construct jit
# (SEC005): request-path code compiles at startup, not under traffic.
SERVE_PATH_PATTERNS = ("*/serve/*.py",)

# Fault-path modules where error handling must stay observable and
# bounded (SEC006): the serving tier's resilience ladder and the
# distributed fault-tolerance layer.
RESILIENCE_PATH_PATTERNS = ("*/serve/*.py", "*/dist/*.py")

# Data-plane modules where -1 must be spelled PAD/QUERY_PAD (SEC003).
# analysis/ is excluded: the linter itself necessarily names -1.
SENTINEL_PATTERNS = (
    "*/core/*.py",
    "*/kernels/*.py",
    "*/kernels/*/*.py",
    "*/serve/*.py",
    "*/index/*.py",
    "*/dist/*.py",
)

# numpy module aliases recognized for np.asarray / np.array (SEC001).
_NP_ALIASES = {"np", "numpy", "onp"}

# Parameter annotations that mark a host scalar/static, exempt from
# taint in transitively-traced helpers (e.g. ``iters: int`` of the
# binary search, ``stage_iters: Tuple[int, ...]`` of the fold).
_SCALAR_ANNOTATIONS = {"int", "bool", "float", "str"}
_SCALAR_ANNOTATION_PREFIXES = ("Tuple", "tuple", "Sequence", "List", "list")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def _matches(path: str, patterns: Sequence[str]) -> bool:
    p = Path(path).as_posix()
    return any(fnmatch.fnmatch(p, pat) for pat in patterns)


# ----------------------------------------------------------------------
# jit-construction recognition (shared by SEC001 root finding and SEC002)
# ----------------------------------------------------------------------


def _is_jit_name(node: ast.AST) -> bool:
    """``jax.jit`` / ``jit`` as an expression."""
    if isinstance(node, ast.Attribute):
        return node.attr == "jit"
    return isinstance(node, ast.Name) and node.id == "jit"


def _is_partial_name(node: ast.AST) -> bool:
    if isinstance(node, ast.Attribute):
        return node.attr == "partial"
    return isinstance(node, ast.Name) and node.id == "partial"


def _static_names_of(call: ast.Call) -> Set[str]:
    """The ``static_argnames`` strings of a jit(-partial) call."""
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
            for e in elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, str):
                    out.add(e.value)
    return out


def _jit_call_info(node: ast.AST) -> Optional[ast.Call]:
    """The jit-constructing Call if ``node`` is ``jax.jit(...)`` or
    ``functools.partial(jax.jit, ...)``; else None."""
    if not isinstance(node, ast.Call):
        return None
    if _is_jit_name(node.func):
        return node
    if _is_partial_name(node.func) and node.args and _is_jit_name(node.args[0]):
        return node
    return None


def _is_cache_decorated(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", ()):
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = target.attr if isinstance(target, ast.Attribute) else getattr(
            target, "id", ""
        )
        if name in ("lru_cache", "cache"):
            return True
    return False


# ----------------------------------------------------------------------
# SEC001 — taint analysis over traced function bodies
# ----------------------------------------------------------------------

# Attribute accesses that yield static (host) metadata under trace:
# shapes are Python ints inside jit, so ``b, l = x.shape`` launders the
# taint legitimately.
_STATIC_ATTRS = {"shape", "ndim", "dtype"}


def _scalar_annotated(arg: ast.arg) -> bool:
    ann = arg.annotation
    if ann is None:
        return False
    if isinstance(ann, ast.Name):
        return ann.id in _SCALAR_ANNOTATIONS
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        text = ann.value
    else:
        try:
            text = ast.unparse(ann)
        except Exception:  # pragma: no cover - malformed annotation
            return False
    text = text.strip()
    if text.startswith("Optional[") and text.endswith("]"):
        text = text[len("Optional[") : -1]
    return all(
        part == "None"
        or part in _SCALAR_ANNOTATIONS
        or part.startswith(_SCALAR_ANNOTATION_PREFIXES)
        for part in (p.strip() for p in text.split("|"))
    )


def _walk_skipping_static_attrs(node: ast.AST):
    """Yield nodes like ast.walk, but do not descend into ``x.shape`` /
    ``x.ndim`` / ``x.dtype`` subtrees (static under trace)."""
    if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
        return
    yield node
    for child in ast.iter_child_nodes(node):
        yield from _walk_skipping_static_attrs(child)


def _names_in(node: ast.AST) -> Set[str]:
    return {
        n.id
        for n in _walk_skipping_static_attrs(node)
        if isinstance(n, ast.Name)
    }


class _ModuleScan:
    """One parsed module: its functions, jit roots, and jitted bindings."""

    def __init__(self, tree: ast.Module):
        self.tree = tree
        # name -> FunctionDef, module level and nested (last def wins —
        # good enough for lint purposes).
        self.functions: Dict[str, ast.AST] = {}
        # function node -> static param names (from a jit decorator or a
        # module-level ``x = partial(jax.jit, ...)(f)`` binding).
        self.static_of: Dict[ast.AST, Set[str]] = {}
        # binding name -> static names of the jitted callable it holds.
        self.jitted_bindings: Dict[str, Set[str]] = {}
        self.roots: List[ast.AST] = []
        self._collect()

    def _collect(self):
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions[node.name] = node
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    call = _jit_call_info(dec)
                    if call is not None:
                        self._add_root(node, _static_names_of(call))
                    elif _is_jit_name(dec):
                        self._add_root(node, set())
            elif isinstance(node, ast.Assign):
                self._scan_binding(node)

    def _scan_binding(self, node: ast.Assign):
        """``X = functools.partial(jax.jit, ...)(f)`` and
        ``X = jax.jit(f, ...)`` bind a jitted callable to X and make f a
        traced root."""
        value = node.value
        statics: Optional[Set[str]] = None
        target_fn: Optional[ast.AST] = None
        if isinstance(value, ast.Call):
            inner = _jit_call_info(value.func)
            if inner is not None:  # partial(jax.jit, ...)(f)
                statics = _static_names_of(inner)
                if value.args and isinstance(value.args[0], ast.Name):
                    target_fn = self.functions.get(value.args[0].id)
            elif _is_jit_name(value.func):  # jax.jit(f, ...)
                statics = _static_names_of(value)
                if value.args and isinstance(value.args[0], ast.Name):
                    target_fn = self.functions.get(value.args[0].id)
        if statics is None:
            return
        for t in node.targets:
            if isinstance(t, ast.Name):
                self.jitted_bindings[t.id] = statics
        if target_fn is not None:
            self._add_root(target_fn, statics)

    def _add_root(self, fn: ast.AST, statics: Set[str]):
        if fn not in self.static_of:
            self.roots.append(fn)
        self.static_of.setdefault(fn, set()).update(statics)

    def traced_functions(self) -> List[ast.AST]:
        """Transitive closure of traced code: jit roots, their nested
        defs, and same-module functions they call or pass as arguments
        (fori_loop bodies, shard_map bodies, pallas kernels)."""
        seen: List[ast.AST] = []
        queue = list(self.roots)
        while queue:
            fn = queue.pop()
            if fn in seen:
                continue
            seen.append(fn)
            for node in ast.walk(fn):
                if node is not fn and isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef)
                ):
                    if node not in seen:
                        queue.append(node)
                elif isinstance(node, ast.Call):
                    for ref in [node.func, *node.args]:
                        if isinstance(ref, ast.Name):
                            callee = self.functions.get(ref.id)
                            if callee is not None and callee not in seen:
                                queue.append(callee)
        return seen


def _initial_taint(fn: ast.AST, statics: Set[str]) -> Set[str]:
    tainted: Set[str] = set()
    a = fn.args
    for arg in [*a.posonlyargs, *a.args, *a.kwonlyargs]:
        if arg.arg in statics or _scalar_annotated(arg):
            continue
        tainted.add(arg.arg)
    for extra in (a.vararg, a.kwarg):
        if extra is not None and extra.arg not in statics:
            tainted.add(extra.arg)
    return tainted


def _propagate_taint(fn: ast.AST, tainted: Set[str]) -> Set[str]:
    """Forward-propagate taint through assignments in ``fn``'s own body
    (nested defs analyzed separately), to a fixpoint."""
    own_nodes = _own_body_nodes(fn)
    for _ in range(10):
        before = len(tainted)
        for node in own_nodes:
            value = None
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                value, targets = node.value, node.targets
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                value, targets = node.value, [node.target]
            elif isinstance(node, ast.AugAssign):
                value, targets = node.value, [node.target]
            elif isinstance(node, ast.For):
                value, targets = node.iter, [node.target]
            elif isinstance(node, ast.withitem) and node.optional_vars:
                value, targets = node.context_expr, [node.optional_vars]
            elif isinstance(node, (ast.NamedExpr,)):
                value, targets = node.value, [node.target]
            if value is None:
                continue
            if _names_in(value) & tainted:
                for t in targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            tainted.add(n.id)
        if len(tainted) == before:
            break
    return tainted


def _own_body_nodes(fn: ast.AST) -> List[ast.AST]:
    """All AST nodes of ``fn`` excluding nested function subtrees."""
    out: List[ast.AST] = []

    def visit(node: ast.AST, top: bool):
        if not top and isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
        ):
            return
        out.append(node)
        for child in ast.iter_child_nodes(node):
            visit(child, False)

    visit(fn, True)
    return out


def _check_sec001(scan: _ModuleScan, path: str) -> List[Finding]:
    findings: List[Finding] = []
    for fn in scan.traced_functions():
        statics = scan.static_of.get(fn, set())
        tainted = _propagate_taint(fn, _initial_taint(fn, statics))
        if not tainted:
            continue

        def is_tainted(expr: ast.AST) -> bool:
            return bool(_names_in(expr) & tainted)

        for node in _own_body_nodes(fn):
            if isinstance(node, ast.Call):
                f = node.func
                # x.item() — a forced device->host scalar pull.
                if (
                    isinstance(f, ast.Attribute)
                    and f.attr == "item"
                    and not node.args
                    and is_tainted(f.value)
                ):
                    findings.append(
                        Finding(
                            "SEC001",
                            path,
                            node.lineno,
                            node.col_offset,
                            ".item() on a traced value blocks dispatch "
                            f"(in `{fn.name}`)",
                        )
                    )
                # np.asarray / np.array on a traced value — implicit D2H.
                elif (
                    isinstance(f, ast.Attribute)
                    and f.attr in ("asarray", "array")
                    and isinstance(f.value, ast.Name)
                    and f.value.id in _NP_ALIASES
                    and any(is_tainted(a) for a in node.args)
                ):
                    findings.append(
                        Finding(
                            "SEC001",
                            path,
                            node.lineno,
                            node.col_offset,
                            f"np.{f.attr}() on a traced value is an "
                            f"implicit device->host transfer (in `{fn.name}`)",
                        )
                    )
                # int(x) / float(x) / bool(x) — concretization error or sync.
                elif (
                    isinstance(f, ast.Name)
                    and f.id in ("int", "float", "bool")
                    and node.args
                    and is_tainted(node.args[0])
                ):
                    findings.append(
                        Finding(
                            "SEC001",
                            path,
                            node.lineno,
                            node.col_offset,
                            f"{f.id}() on a traced value syncs to host "
                            f"(in `{fn.name}`)",
                        )
                    )
            elif isinstance(node, (ast.If, ast.While)) and is_tainted(
                node.test
            ):
                findings.append(
                    Finding(
                        "SEC001",
                        path,
                        node.lineno,
                        node.col_offset,
                        "branching on a traced value is an implicit bool() "
                        f"host sync — use jnp.where/lax.cond (in `{fn.name}`)",
                    )
                )
            elif isinstance(node, ast.Assert) and is_tainted(node.test):
                findings.append(
                    Finding(
                        "SEC001",
                        path,
                        node.lineno,
                        node.col_offset,
                        "assert on a traced value is an implicit bool() "
                        f"host sync (in `{fn.name}`)",
                    )
                )
    return findings


# ----------------------------------------------------------------------
# SEC002 — recompilation hazards
# ----------------------------------------------------------------------

_UNHASHABLE_DEFAULTS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp)


def _check_sec002(scan: _ModuleScan, path: str) -> List[Finding]:
    findings: List[Finding] = []

    # (a) per-call jit construction: a fresh jit has an empty cache, so
    # construct-and-invoke (``jax.jit(f)(x)``) or construction inside a
    # loop body retraces every time it runs.  One-time factory/__init__
    # construction is fine; lru_cache'd builders (one jit per
    # quantized-shape key) are the sanctioned parametric form.
    # ``partial(jax.jit, ...)(f)`` is construction (binding the jitted
    # callable), so only a direct ``jax.jit(f)(x)`` counts as invocation.
    for node in ast.walk(scan.tree):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Call)
            and _is_jit_name(node.func.func)
        ):
            findings.append(
                Finding(
                    "SEC002",
                    path,
                    node.lineno,
                    node.col_offset,
                    "immediately-invoked jax.jit builds a fresh cache "
                    "and retraces on every call — bind the jitted "
                    "callable once (module level or lru_cache)",
                )
            )
    for fn in ast.walk(scan.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if _is_cache_decorated(fn):
            continue
        for node in _own_body_nodes(fn):
            if not isinstance(node, (ast.For, ast.While)):
                continue
            for inner in ast.walk(node):
                if _jit_call_info(inner) is not None:
                    findings.append(
                        Finding(
                            "SEC002",
                            path,
                            inner.lineno,
                            inner.col_offset,
                            "jax.jit constructed inside a loop retraces "
                            "per iteration — hoist the construction or "
                            "cache with functools.lru_cache "
                            f"(in `{fn.name}`)",
                        )
                    )

    # (b) unhashable static arg defaults: jit hashes static args into
    # the cache key; a list/dict default raises at call time.
    def check_statics(fn: ast.AST, statics: Set[str]):
        a = fn.args
        pos = [*a.posonlyargs, *a.args]
        defaults = [None] * (len(pos) - len(a.defaults)) + list(a.defaults)
        pairs = list(zip(pos, defaults, strict=True)) + list(
            zip(a.kwonlyargs, a.kw_defaults, strict=True)
        )
        for arg, default in pairs:
            if (
                arg.arg in statics
                and default is not None
                and isinstance(default, _UNHASHABLE_DEFAULTS)
            ):
                findings.append(
                    Finding(
                        "SEC002",
                        path,
                        default.lineno,
                        default.col_offset,
                        f"static arg `{arg.arg}` of `{fn.name}` has an "
                        "unhashable default — jit cannot key the cache "
                        "on it",
                    )
                )

    for fn, statics in scan.static_of.items():
        if statics:
            check_statics(fn, statics)

    # (c) dynamic shapes leaking into the jit cache key: static kwargs
    # of a known-jitted binding built from raw len()/.shape instead of
    # the _quantize grid retrace per batch size.
    def leaks_shape(expr: ast.AST) -> bool:
        for n in ast.walk(expr):
            if isinstance(n, ast.Call):
                f = n.func
                if isinstance(f, ast.Name) and f.id in (
                    "_quantize",
                    "quantize",
                ):
                    return False  # quantized — the sanctioned route
                if isinstance(f, ast.Name) and f.id == "len":
                    return True
            elif isinstance(n, ast.Attribute) and n.attr == "shape":
                return True
        return False

    for node in ast.walk(scan.tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if not (
            isinstance(f, ast.Name) and f.id in scan.jitted_bindings
        ):
            continue
        statics = scan.jitted_bindings[f.id]
        for kw in node.keywords:
            if kw.arg in statics and leaks_shape(kw.value):
                findings.append(
                    Finding(
                        "SEC002",
                        path,
                        kw.value.lineno,
                        kw.value.col_offset,
                        f"static arg `{kw.arg}` of jitted `{f.id}` is a "
                        "raw dynamic shape — every batch size becomes a "
                        "new jit cache entry; round through _quantize",
                    )
                )
    return findings


# ----------------------------------------------------------------------
# SEC003 — literal -1 sentinels
# ----------------------------------------------------------------------


def _is_neg_one(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.UnaryOp)
        and isinstance(node.op, ast.USub)
        and isinstance(node.operand, ast.Constant)
        and node.operand.value == 1
    )


_CELL_NAME_HINTS = ("cell", "post", "doc", "member")


def _check_sec003(tree: ast.Module, path: str) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Compare):
            operands = [node.left, *node.comparators]
            if any(_is_neg_one(o) for o in operands):
                findings.append(
                    Finding(
                        "SEC003",
                        path,
                        node.lineno,
                        node.col_offset,
                        "comparison against literal -1 — use the exported "
                        "PAD/QUERY_PAD sentinel constants",
                    )
                )
        elif isinstance(node, ast.Assign) and _is_neg_one(node.value):
            for t in node.targets:
                if isinstance(t, ast.Subscript):
                    base = t.value
                    name = (
                        base.id
                        if isinstance(base, ast.Name)
                        else getattr(base, "attr", "")
                    )
                    if any(h in name.lower() for h in _CELL_NAME_HINTS):
                        findings.append(
                            Finding(
                                "SEC003",
                                path,
                                node.lineno,
                                node.col_offset,
                                f"filling `{name}[...]` with literal -1 — "
                                "use the exported PAD/QUERY_PAD sentinels",
                            )
                        )
                        break
    return findings


# ----------------------------------------------------------------------
# SEC005 — jit construction in the serving request path
# ----------------------------------------------------------------------


def _check_sec005(scan: _ModuleScan, path: str) -> List[Finding]:
    """Flag ``jax.jit(...)`` / ``partial(jax.jit, ...)`` constructed inside
    any function body of a serve module.

    Request-path functions run per batch under traffic; a jit built there
    starts with an empty compile cache every call, so the shape-grid
    prewarm can never cover it.  Module-level bindings and
    ``functools.lru_cache``-decorated builders (the engine's pattern:
    construct once, reuse the cached executable) are the sanctioned
    homes and are exempt.
    """
    findings: List[Finding] = []
    for node in ast.walk(scan.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if _is_cache_decorated(node):
            continue
        # Decorators evaluate once at def time, not per call — a
        # ``@jax.jit`` on a nested def is someone else's problem
        # (SEC002 territory), not a per-request construction.
        deco_nodes = {
            id(n) for d in node.decorator_list for n in ast.walk(d)
        }
        for sub in _own_body_nodes(node):
            if id(sub) in deco_nodes:
                continue
            call = _jit_call_info(sub)
            if call is None:
                continue
            findings.append(
                Finding(
                    "SEC005",
                    path,
                    call.lineno,
                    call.col_offset,
                    "jax.jit constructed in the serving request path — "
                    "bind the jitted callable at startup (module level "
                    "or a functools.lru_cache builder) and prewarm its "
                    f"shape grid (in `{node.name}`)",
                )
            )
    return findings


# ----------------------------------------------------------------------
# SEC006 — resilience-defeating error handling in fault-path modules
# ----------------------------------------------------------------------

_BROAD_EXC_NAMES = {"Exception", "BaseException"}


def _is_broad_handler(handler: ast.ExceptHandler) -> bool:
    """Bare ``except:`` or ``except Exception/BaseException`` (possibly
    in a tuple)."""
    t = handler.type
    if t is None:
        return True
    types = t.elts if isinstance(t, ast.Tuple) else [t]
    for typ in types:
        name = typ.attr if isinstance(typ, ast.Attribute) else getattr(
            typ, "id", ""
        )
        if name in _BROAD_EXC_NAMES:
            return True
    return False


def _swallows(handler: ast.ExceptHandler) -> bool:
    """True when the handler body does nothing but pass/continue — the
    exception is silently discarded."""
    return all(
        isinstance(stmt, (ast.Pass, ast.Continue)) for stmt in handler.body
    )


def _loop_own_nodes(loop: ast.While) -> List[ast.AST]:
    """Nodes of the loop body, excluding nested function/lambda subtrees
    and nested loops' own break targets — a ``break`` inside an inner
    ``for`` does not exit the outer ``while True``.  ``return``/``raise``
    anywhere (outside nested defs) does exit, so those are collected from
    the full non-def subtree."""
    exits: List[ast.AST] = []

    def collect(node: ast.AST, loop_depth: int):
        if isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
        ):
            return
        if isinstance(node, ast.Break):
            if loop_depth == 0:
                exits.append(node)
            return
        if isinstance(node, (ast.Return, ast.Raise)):
            exits.append(node)
            return
        child_depth = (
            loop_depth + 1
            if isinstance(node, (ast.For, ast.While, ast.AsyncFor))
            else loop_depth
        )
        for child in ast.iter_child_nodes(node):
            collect(child, child_depth)

    for stmt in loop.body:
        collect(stmt, 0)
    return exits


def _check_sec006(tree: ast.Module, path: str) -> List[Finding]:
    """Flag error handling that defeats the resilience ladder:

    * bare ``except:`` — catches ``KeyboardInterrupt``/``SystemExit`` and
      hides *which* failure fired, so nothing upstream can count strikes;
    * ``except Exception:`` (or broader) whose body only passes/continues
      — the failure is observed by no one: no breaker strike, no
      straggler record, no fallback level in the stats;
    * ``while True:`` with no ``break``/``return``/``raise`` reachable in
      its own body — an unbounded retry spin that turns a dead shard into
      a hang instead of a degraded-but-answering service.  (A ``break``
      belonging to a nested loop does not count; exits inside nested
      ``def``/``lambda`` bodies do not count.)
    """
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ExceptHandler):
            if node.type is None:
                findings.append(
                    Finding(
                        "SEC006",
                        path,
                        node.lineno,
                        node.col_offset,
                        "bare `except:` hides the failure from the "
                        "resilience ladder — catch the typed error and "
                        "feed the breaker/monitor",
                    )
                )
            elif _is_broad_handler(node) and _swallows(node):
                findings.append(
                    Finding(
                        "SEC006",
                        path,
                        node.lineno,
                        node.col_offset,
                        "`except Exception: pass/continue` swallows the "
                        "failure — record it (breaker strike, shard "
                        "times, fallback level) or re-raise",
                    )
                )
        elif (
            isinstance(node, ast.While)
            and isinstance(node.test, ast.Constant)
            and node.test.value is True
            and not _loop_own_nodes(node)
        ):
            findings.append(
                Finding(
                    "SEC006",
                    path,
                    node.lineno,
                    node.col_offset,
                    "unbounded `while True:` retry loop with no "
                    "break/return/raise — bound the attempts "
                    "(for attempt in range(budget)) so a dead shard "
                    "degrades instead of hanging",
                )
            )
    return findings


# ----------------------------------------------------------------------
# SEC004 — kernel-contract completeness (directory-level rule)
# ----------------------------------------------------------------------

_KERNEL_REQUIRED = ("kernel.py", "ref.py", "ops.py")


def _ops_imports_ref(ops_path: Path) -> bool:
    try:
        tree = ast.parse(ops_path.read_text())
    except SyntaxError:
        return False
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "ref" or mod.endswith(".ref"):
                return True
            if any(a.name == "ref" for a in node.names):
                return True
    return False


def check_kernel_contracts(
    kernels_dir: Path, tests_dir: Optional[Path]
) -> List[Finding]:
    """SEC004 over one ``kernels/`` package directory."""
    findings: List[Finding] = []
    for pkg in sorted(kernels_dir.iterdir()):
        if not pkg.is_dir() or not (pkg / "__init__.py").exists():
            continue
        name = pkg.name
        for required in _KERNEL_REQUIRED:
            if not (pkg / required).exists():
                findings.append(
                    Finding(
                        "SEC004",
                        str(pkg),
                        1,
                        0,
                        f"kernel package `{name}` is missing {required} "
                        "(contract: pallas kernel + jnp ref oracle + ops "
                        "wrapper)",
                    )
                )
        ops = pkg / "ops.py"
        if ops.exists() and not _ops_imports_ref(ops):
            findings.append(
                Finding(
                    "SEC004",
                    str(ops),
                    1,
                    0,
                    f"`{name}/ops.py` does not import its ref oracle — "
                    "the ops wrapper must expose the jnp fallback",
                )
            )
        if tests_dir is not None:
            test_file = tests_dir / f"test_kernels_{name}.py"
            if not test_file.exists():
                findings.append(
                    Finding(
                        "SEC004",
                        str(pkg),
                        1,
                        0,
                        f"kernel package `{name}` has no kernel≡ref test "
                        f"(expected {test_file.name})",
                    )
                )
    return findings


# ----------------------------------------------------------------------
# Drivers
# ----------------------------------------------------------------------


def lint_source(source: str, path: str) -> List[Finding]:
    """Per-file rules (SEC001–SEC003, SEC005, SEC006) over one module's
    source text."""
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [
            Finding(
                "SEC000", path, exc.lineno or 1, 0, f"syntax error: {exc.msg}"
            )
        ]
    findings: List[Finding] = []
    scan = _ModuleScan(tree)
    if _matches(path, DEVICE_PATH_PATTERNS):
        findings += _check_sec001(scan, path)
    findings += _check_sec002(scan, path)
    if _matches(path, SENTINEL_PATTERNS):
        findings += _check_sec003(tree, path)
    if _matches(path, SERVE_PATH_PATTERNS):
        findings += _check_sec005(scan, path)
    if _matches(path, RESILIENCE_PATH_PATTERNS):
        findings += _check_sec006(tree, path)
    return findings


def lint_file(path: Path) -> List[Finding]:
    return lint_source(path.read_text(), str(path))


def _iter_py_files(root: Path):
    if root.is_file():
        yield root
        return
    for p in sorted(root.rglob("*.py")):
        if "__pycache__" not in p.parts:
            yield p


def lint_paths(
    paths: Sequence[Path], tests_dir: Optional[Path] = None
) -> List[Finding]:
    """Lint files/trees; SEC004 runs once per discovered ``kernels/``
    directory.  ``tests_dir`` enables the kernel≡ref test-existence
    check (pass None to skip it, e.g. for fixture trees)."""
    findings: List[Finding] = []
    kernels_dirs: List[Path] = []
    for root in paths:
        root = Path(root)
        for f in _iter_py_files(root):
            findings += lint_file(f)
            for parent in f.parents:
                if parent.name == "kernels" and parent not in kernels_dirs:
                    kernels_dirs.append(parent)
    for kd in kernels_dirs:
        findings += check_kernel_contracts(kd, tests_dir)
    return sorted(
        findings, key=lambda f: (f.path, f.line, f.col, f.rule)
    )
