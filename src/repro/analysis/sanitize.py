"""Runtime sanitizers: prove the warm device path never syncs implicitly.

Two mechanisms compose, because each has a blind spot:

* ``jax.transfer_guard("disallow")`` — XLA's own guard.  It has teeth on
  TPU/GPU, where host and device memory are distinct; on the CPU backend
  a jax array and its numpy view share memory, no copy happens, and the
  guard observes *no transfer event at all* (verified empirically: even
  ``disallow`` blocks nothing on CPU).  CI runs on CPU, so alone it
  would be a green light that tests nothing.

* a Python-level sentinel that patches ``np.asarray`` / ``np.array`` to
  reject ``jax.Array`` inputs, and ``jnp.asarray`` / ``jnp.array`` to
  reject concrete ``np.ndarray`` inputs outside a trace.  These are the
  two implicit directions (D2H and H2D).  The explicit transfer API —
  ``jax.device_get`` / ``jax.device_put`` — is wrapped to open an
  allowance window, because *explicit* transfers (the per-batch plan
  upload, the final counts download) are part of the engine's contract;
  only *implicit* ones are bugs.  Patching must happen at the numpy
  module attributes: ``ArrayImpl.__array__`` is a C++ slot that
  monkeypatching cannot reach.

``no_implicit_transfers()`` is the pytest sanitize mode's wrapper: warm
the fused fold once, then run the same-shaped batch inside the guard —
any ``.item()``, ``np.asarray(device_value)`` or stray upload that
sneaks into the hot path raises :class:`ImplicitTransferError` on CPU
and trips the XLA guard on real accelerators.

``jit_cache_size`` reads a jitted callable's executable count — the
compile-counter half of the sanitize mode, asserting the ~1/8 shape
quantization grid bounds compiles across mixed-size batches.
"""

from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ImplicitTransferError",
    "no_implicit_transfers",
    "jit_cache_size",
]


class ImplicitTransferError(RuntimeError):
    """An implicit host<->device transfer inside a sanitized region."""


_state = threading.local()


def _explicit_depth() -> int:
    return getattr(_state, "explicit", 0)


@contextlib.contextmanager
def _explicitly():
    _state.explicit = _explicit_depth() + 1
    try:
        yield
    finally:
        _state.explicit -= 1


def _is_concrete_device(x) -> bool:
    """A committed device value (not a tracer — inside jit everything is
    symbolic and no transfer can occur)."""
    return isinstance(x, jax.Array) and not isinstance(x, jax.core.Tracer)


@contextlib.contextmanager
def no_implicit_transfers():
    """Forbid implicit host<->device transfers inside the block.

    Composes ``jax.transfer_guard("disallow")`` (effective on TPU/GPU)
    with the numpy/jnp sentinel patch (effective everywhere, including
    the CPU backend CI runs on).  ``jax.device_get`` / ``device_put``
    remain allowed — they are the explicit API the engine's per-batch
    upload/download contract is written against.
    """
    orig_np_asarray = np.asarray
    orig_np_array = np.array
    orig_jnp_asarray = jnp.asarray
    orig_jnp_array = jnp.array
    orig_device_get = jax.device_get
    orig_device_put = jax.device_put

    def guard_np(orig, name):
        def wrapper(obj, *args, **kwargs):
            if _explicit_depth() == 0 and _is_concrete_device(obj):
                raise ImplicitTransferError(
                    f"implicit device->host transfer: np.{name}() on a "
                    "jax.Array inside a sanitized region — use "
                    "jax.device_get for the explicit download"
                )
            return orig(obj, *args, **kwargs)

        return wrapper

    def guard_jnp(orig, name):
        def wrapper(obj, *args, **kwargs):
            if _explicit_depth() == 0 and isinstance(obj, np.ndarray):
                raise ImplicitTransferError(
                    f"implicit host->device transfer: jnp.{name}() on a "
                    "np.ndarray inside a sanitized region — use "
                    "jax.device_put for the explicit upload"
                )
            return orig(obj, *args, **kwargs)

        return wrapper

    def explicit_get(x):
        with _explicitly():
            return orig_device_get(x)

    def explicit_put(x, *args, **kwargs):
        with _explicitly():
            return orig_device_put(x, *args, **kwargs)

    np.asarray = guard_np(orig_np_asarray, "asarray")
    np.array = guard_np(orig_np_array, "array")
    jnp.asarray = guard_jnp(orig_jnp_asarray, "asarray")
    jnp.array = guard_jnp(orig_jnp_array, "array")
    jax.device_get = explicit_get
    jax.device_put = explicit_put
    try:
        with jax.transfer_guard("disallow"):
            yield
    finally:
        np.asarray = orig_np_asarray
        np.array = orig_np_array
        jnp.asarray = orig_jnp_asarray
        jnp.array = orig_jnp_array
        jax.device_get = orig_device_get
        jax.device_put = orig_device_put


def jit_cache_size(fn) -> int:
    """Number of traced entries in a jitted callable's cache — the
    compile counter the quantization-grid bound is asserted against."""
    probe = getattr(fn, "_cache_size", None)
    if callable(probe):
        return int(probe())
    raise AttributeError(
        f"{fn!r} exposes no jit cache size probe on this jax version"
    )
