from repro.roofline.analysis import (
    V5E,
    HardwareSpec,
    RooflineReport,
    analyze_compiled,
    collective_bytes_from_hlo,
    model_flops,
)

__all__ = [
    "V5E",
    "HardwareSpec",
    "RooflineReport",
    "analyze_compiled",
    "collective_bytes_from_hlo",
    "model_flops",
]
