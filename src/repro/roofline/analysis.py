"""Three-term roofline from the compiled dry-run artifact.

    compute    = HLO_FLOPs_per_chip   / peak_FLOP/s          [s]
    memory     = HLO_bytes_per_chip   / HBM_bw               [s]
    collective = collective_bytes_per_chip / link_bw         [s]

Sources: ``compiled.cost_analysis()`` (per-device flops / bytes accessed),
and the optimized HLO text for collective operand bytes (cost_analysis
does not expose them).  Hardware constants: TPU v5e.

The "useful-FLOP ratio" compares 6·N·D-style model FLOPs against the
compiled count — it flags remat recompute and padding waste.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

__all__ = [
    "HardwareSpec",
    "V5E",
    "RooflineReport",
    "collective_bytes_from_hlo",
    "analyze_compiled",
    "model_flops",
]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float  # bf16 FLOP/s per chip
    hbm_bw: float  # B/s per chip
    link_bw: float  # B/s per ICI link
    hbm_bytes: float  # capacity per chip


V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    link_bw=50e9,
    hbm_bytes=16e9,
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in (optimized, post-SPMD,
    per-device) HLO text. Returns per-kind byte counts + 'total'."""
    out: Dict[str, int] = {
        "all-gather": 0,
        "all-reduce": 0,
        "reduce-scatter": 0,
        "all-to-all": 0,
        "collective-permute": 0,
    }
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in line:
            continue  # paired with -start; count once
        # operand shapes appear inside the call parens
        paren = line[m.end() - 1 :]
        shapes = _SHAPE_RE.findall(paren)
        if not shapes:  # fall back to the result shape
            shapes = _SHAPE_RE.findall(line[: m.end()])
        out[kind] += sum(_shape_bytes(dt, dims) for dt, dims in shapes)
    out["total"] = sum(out.values())
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_total: float
    peak_memory_per_chip: float
    hw: HardwareSpec = V5E

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flop_ratio(self) -> float:
        total_hlo = self.flops_per_chip * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the hard compute roofline we'd achieve if the step
        ran at its dominant-term time: useful_compute_time / bound_time."""
        useful_s = self.model_flops_total / (self.chips * self.hw.peak_flops)
        return useful_s / self.bound_time_s if self.bound_time_s else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_total": self.model_flops_total,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_memory_per_chip": self.peak_memory_per_chip,
        }


def analyze_compiled(
    compiled,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    model_flops_total: float,
    hw: HardwareSpec = V5E,
) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    mem = compiled.memory_analysis()
    peak = float(
        getattr(mem, "temp_size_in_bytes", 0)
        + getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0)
    )
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops_per_chip=flops,
        bytes_per_chip=byts,
        coll_bytes_per_chip=coll,
        compute_s=flops / hw.peak_flops,
        memory_s=byts / hw.hbm_bw,
        collective_s=coll["total"] / hw.link_bw,
        model_flops_total=model_flops_total,
        peak_memory_per_chip=peak,
        hw=hw,
    )


# ---------------------------------------------------------------------------
# Model FLOPs (the "useful work" yardstick)
# ---------------------------------------------------------------------------


def _lm_attention_flops(cfg, batch: int, s_q: int, s_k: int, train: bool) -> float:
    """QK + PV matmul FLOPs across layers, honouring sliding windows
    (gemma3 local layers attend to at most `window` keys).  Square causal
    attention is halved.  Train multiplies by 3 (fwd + bwd)."""
    h, hd = cfg.n_heads, cfg.head_dim
    total = 0.0
    for i in range(cfg.n_layers):
        is_global = cfg.window is None or (
            cfg.global_every and (i + 1) % cfg.global_every == 0
        )
        keys = s_k if is_global else min(cfg.window, s_k)
        per = 2.0 * batch * s_q * keys * h * hd * 2  # two matmuls
        if s_q == s_k and is_global:
            per *= 0.5  # causal square
        total += per
    return total * (3.0 if train else 1.0)


def model_flops(plan, cell) -> float:
    """Useful-work yardstick: 6·N·D (train) / 2·N·D (inference) plus
    attention matmul FLOPs, with family-specific N and D."""
    kind = plan.kind
    cfg = plan.cfg
    if hasattr(cfg, "n_active_params"):  # LM
        n = cfg.n_active_params()
        if kind == "train":
            s = cell.extra["seq_len"]
            d = cell.batch * s
            return 6.0 * n * d + _lm_attention_flops(cfg, cell.batch, s, s, True)
        if kind == "prefill":
            s = cell.extra["seq_len"]
            d = cell.batch * s
            return 2.0 * n * d + _lm_attention_flops(cfg, cell.batch, s, s, False)
        if kind == "decode":
            # one token per sequence; KV-cache attention reads
            lk = cell.extra["cache_len"]
            return 2.0 * n * cell.batch + _lm_attention_flops(
                cfg, cell.batch, 1, lk, False
            )
    if plan.arch == "pna":
        dh = cfg.d_hidden
        ex = cell.extra
        if kind == "train_minibatch":
            from repro.data.graphs import NeighborSampler

            class _B:
                fanouts = ex["fanouts"]

            n_nodes, n_edges = NeighborSampler.budget(_B, cell.batch)
        elif "nodes_per_graph" in ex:
            n_nodes = cell.batch * ex["nodes_per_graph"]
            n_edges = cell.batch * ex["edges_per_graph"]
        else:
            n_nodes, n_edges = ex["n_nodes"], ex["n_edges"]
        layers = cfg.n_layers
        fwd = layers * (2 * n_edges * 2 * dh * dh + n_nodes * 12 * dh * dh * 2)
        fwd += 2 * n_nodes * cfg.d_feat * dh
        return 3.0 * fwd if kind.startswith("train") else fwd
    # recsys: dense compute only (embedding gathers are bytes, not FLOPs)
    dense_params = {
        "dien": lambda c: c.n_params() - c.vocab * c.embed_dim,
        "mind": lambda c: c.n_params() - c.vocab * c.embed_dim,
        "bert4rec": lambda c: c.n_params() - c.vocab * c.embed_dim,
        "dcn-v2": lambda c: c.n_params()
        - c.n_sparse * c.vocab_per_field * c.embed_dim,
    }[plan.arch](cfg)
    seq = getattr(cfg, "seq_len", getattr(cfg, "hist_len", 1))
    per_ex = dense_params * (seq if plan.arch in ("dien", "bert4rec") else 1)
    if kind == "train":
        return 6.0 * per_ex * cell.batch
    if kind == "serve":
        return 2.0 * per_ex * cell.batch
    if kind == "retrieval":
        emb = getattr(cfg, "embed_dim", 16)
        return 2.0 * per_ex * cell.batch + 2.0 * cell.extra["n_candidates"] * emb
    return 0.0
