"""Query logs and term-probability estimation.

The paper (§3.1) needs only the *marginal* probability P[t] of each term
appearing in a query; it estimates these either from a query log (AOL,
pagenstecher) or from corpus term frequencies.  Queries themselves are
2-term conjunctive queries (the paper's focus).

Synthetic logs here are sampled with Zipf rank-probabilities over terms
(matching the paper's Figure 1) with a configurable topical co-occurrence
bias: with probability ``co_topic`` the two query terms are drawn from the
same topic block, which mirrors real logs where query terms are
semantically related (and which makes the clustered speedup realistic
rather than adversarial).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.data.corpus import Corpus

__all__ = ["QueryLog", "synth_query_log", "term_probabilities"]


@dataclasses.dataclass
class QueryLog:
    """A set of two-term conjunctive queries.

    ``queries`` has shape (n_queries, 2), int32 term ids, t != u.
    """

    queries: np.ndarray

    @property
    def n_queries(self) -> int:
        return len(self.queries)

    def distinct_terms(self) -> np.ndarray:
        return np.unique(self.queries)

    def stats(self) -> dict:
        """Table-2-style statistics."""
        return {
            "queries": self.n_queries,
            "distinct_terms": int(len(self.distinct_terms())),
        }


def synth_query_log(
    corpus: Corpus,
    n_queries: int = 20_000,
    zipf_s: float = 0.85,
    co_topic: float = 0.5,
    frequency_weight: float = 0.5,
    seed: int = 1,
) -> QueryLog:
    """Sample a Zipf-like two-term query log against ``corpus``.

    Term query-propensity mixes corpus document frequency (people search
    for terms that exist) with a Zipf-over-frequency-rank tilt, then pairs
    are drawn either independently or within the same topical block.
    Terms with zero document frequency are never sampled (queries with an
    empty posting list cost nothing and the paper's logs are real text).
    """
    rng = np.random.default_rng(seed)
    df = corpus.term_doc_freq().astype(np.float64)
    alive = df > 0
    # Propensity: df^w * zipf(rank(df))^(1-w)
    order = np.argsort(-df, kind="stable")
    rank = np.empty_like(order)
    rank[order] = np.arange(1, len(order) + 1)
    prop = np.where(alive, (df + 1e-9) ** frequency_weight * rank.astype(np.float64) ** (-zipf_s * (1.0 - frequency_weight)), 0.0)
    prop /= prop.sum()
    cdf = np.cumsum(prop)

    def draw(size: int) -> np.ndarray:
        return np.searchsorted(cdf, rng.random(size), side="right").astype(np.int64)

    t = draw(n_queries)

    # Second term: with prob co_topic, restricted near the first term's
    # frequency-rank neighbourhood (a cheap, corpus-agnostic proxy for
    # topical relatedness that creates correlated posting lists).
    u = draw(n_queries)
    spec = corpus.spec
    if spec is not None and co_topic > 0:
        same = rng.random(n_queries) < co_topic
        hi = spec.topic_block_hi if spec.topic_block_hi is not None else corpus.n_terms // 2
        lo = min(spec.topic_block_lo, hi - 1)
        blockw = max(1, (hi - lo) // max(spec.n_topics, 1))
        in_block = same & (t >= lo) & (t < lo + blockw * spec.n_topics)
        if in_block.any():
            z = (t[in_block] - lo) // blockw
            off = rng.integers(0, blockw, size=int(in_block.sum()))
            u2 = lo + z * blockw + off
            u2 = np.minimum(u2, corpus.n_terms - 1)
            ok = df[u2] > 0
            u[np.flatnonzero(in_block)[ok]] = u2[ok]

    # No degenerate t == u queries.
    eq = t == u
    while eq.any():
        u[eq] = draw(int(eq.sum()))
        eq = t == u

    q = np.stack([t, u], axis=1).astype(np.int32)
    return QueryLog(queries=q)


def term_probabilities(
    n_terms: int,
    log: Optional[QueryLog] = None,
    corpus: Optional[Corpus] = None,
    smoothing: float = 0.0,
) -> np.ndarray:
    """Estimate P[t], the probability a query contains term t (§3.1).

    From a query log when available (the accurate route), otherwise from
    corpus document frequencies (the paper's fallback).  Returns a float64
    array of shape (n_terms,) summing to 1.
    """
    if log is not None:
        counts = np.bincount(log.queries.ravel(), minlength=n_terms).astype(np.float64)
    elif corpus is not None:
        counts = corpus.term_doc_freq().astype(np.float64)
    else:
        raise ValueError("need a query log or a corpus")
    counts += smoothing
    total = counts.sum()
    if total <= 0:
        raise ValueError("empty statistics")
    return counts / total
