"""Query logs and term-probability estimation.

The paper (§3.1) needs only the *marginal* probability P[t] of each term
appearing in a query; it estimates these either from a query log (AOL,
pagenstecher) or from corpus term frequencies.  The paper's evaluation
uses 2-term conjunctive queries; the engine (and this sampler) supports
arbitrary arity — the SAP-HANA attribute-filter scenario the paper cites
("in stock AND category=X AND brand=Y") is a 3-term conjunction.

Synthetic logs here are sampled with Zipf rank-probabilities over terms
(matching the paper's Figure 1) with a configurable topical co-occurrence
bias: with probability ``co_topic`` a non-leading query term is drawn from
the same topic block as the leading term, which mirrors real logs where
query terms are semantically related (and which makes the clustered
speedup realistic rather than adversarial).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.queries import QUERY_PAD, ConjunctiveQueries
from repro.data.corpus import Corpus

__all__ = [
    "QueryLog",
    "synth_query_log",
    "term_probabilities",
    "poisson_arrivals",
]


@dataclasses.dataclass
class QueryLog:
    """A set of conjunctive queries in the padded rectangular form.

    ``queries`` has shape (n_queries, max_arity), int32 term ids; rows
    with fewer terms are filled with ``QUERY_PAD`` (-1).  Terms within a
    query are distinct.  The historical 2-term log is the pad-free
    ``max_arity == 2`` case.

    ``arrivals`` (optional) carries one open-loop arrival timestamp per
    query — float64 seconds, nondecreasing — for serving replay
    (``repro.serve.replay``).  A log without timestamps is the
    historical closed-batch form.
    """

    queries: np.ndarray
    arrivals: Optional[np.ndarray] = None

    @property
    def n_queries(self) -> int:
        return len(self.queries)

    @property
    def max_arity(self) -> int:
        return self.queries.shape[1] if self.queries.ndim == 2 else 0

    def arities(self) -> np.ndarray:
        return (self.queries != QUERY_PAD).sum(axis=1)

    def as_conjunctive(self) -> ConjunctiveQueries:
        return ConjunctiveQueries.from_padded(self.queries)

    def distinct_terms(self) -> np.ndarray:
        t = np.unique(self.queries)
        return t[t != QUERY_PAD]

    def stats(self) -> dict:
        """Table-2-style statistics."""
        return {
            "queries": self.n_queries,
            "distinct_terms": int(len(self.distinct_terms())),
            "mean_arity": float(self.arities().mean()) if self.n_queries else 0.0,
        }


def poisson_arrivals(
    n: int,
    qps: float,
    seed: int = 0,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Open-loop Poisson arrival timestamps at a mean rate of ``qps``.

    Returns ``n`` nondecreasing float64 seconds: the cumulative sum of
    exponential inter-arrival gaps with mean ``1/qps``.  Open-loop means
    arrivals do not wait for replies — the process the serving loop must
    absorb, as opposed to closed-loop ping-pong benchmarking.
    """
    if qps <= 0:
        raise ValueError(f"qps must be positive, got {qps}")
    if rng is None:
        rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / qps, size=int(n)))


def synth_query_log(
    corpus: Corpus,
    n_queries: int = 20_000,
    zipf_s: float = 0.85,
    co_topic: float = 0.5,
    frequency_weight: float = 0.5,
    seed: int = 1,
    arity: int | Sequence[int] = 2,
    arity_weights: Optional[Sequence[float]] = None,
    arrival_qps: Optional[float] = None,
) -> QueryLog:
    """Sample a Zipf-like conjunctive query log against ``corpus``.

    Term query-propensity mixes corpus document frequency (people search
    for terms that exist) with a Zipf-over-frequency-rank tilt, then the
    non-leading terms are drawn either independently or within the same
    topical block as the leading term.  Terms with zero document frequency
    are never sampled (queries with an empty posting list cost nothing and
    the paper's logs are real text).

    ``arity`` is either a single arity for every query (default 2, the
    paper's setting — identical samples to the historical 2-term-only
    sampler) or a sequence of arities sampled per query with optional
    ``arity_weights``; ragged rows are ``QUERY_PAD``-filled.

    ``arrival_qps``, when given, attaches Poisson arrival timestamps at
    that mean rate (``QueryLog.arrivals``).  Arrivals are drawn strictly
    after every query draw from the same rng, so the query stream for a
    given seed is bit-identical with or without timestamps.
    """
    rng = np.random.default_rng(seed)
    df = corpus.term_doc_freq().astype(np.float64)
    alive = df > 0
    # Propensity: df^w * zipf(rank(df))^(1-w)
    order = np.argsort(-df, kind="stable")
    rank = np.empty_like(order)
    rank[order] = np.arange(1, len(order) + 1)
    prop = np.where(alive, (df + 1e-9) ** frequency_weight * rank.astype(np.float64) ** (-zipf_s * (1.0 - frequency_weight)), 0.0)
    prop /= prop.sum()
    cdf = np.cumsum(prop)

    def draw(size: int) -> np.ndarray:
        return np.searchsorted(cdf, rng.random(size), side="right").astype(np.int64)

    def topical(t: np.ndarray) -> np.ndarray:
        """One companion term per entry of ``t``: with prob ``co_topic``
        drawn near t's topic block, else an independent draw."""
        n = len(t)
        u = draw(n)
        spec = corpus.spec
        if spec is not None and co_topic > 0:
            same = rng.random(n) < co_topic
            hi = spec.topic_block_hi if spec.topic_block_hi is not None else corpus.n_terms // 2
            lo = min(spec.topic_block_lo, hi - 1)
            blockw = max(1, (hi - lo) // max(spec.n_topics, 1))
            in_block = same & (t >= lo) & (t < lo + blockw * spec.n_topics)
            if in_block.any():
                z = (t[in_block] - lo) // blockw
                off = rng.integers(0, blockw, size=int(in_block.sum()))
                u2 = lo + z * blockw + off
                u2 = np.minimum(u2, corpus.n_terms - 1)
                ok = df[u2] > 0
                u[np.flatnonzero(in_block)[ok]] = u2[ok]
        return u

    def _arrivals() -> Optional[np.ndarray]:
        # Called after the last query draw: the rng stream consumed by the
        # query sampler is unchanged by the presence of timestamps.
        if arrival_qps is None:
            return None
        return poisson_arrivals(n_queries, arrival_qps, rng=rng)

    arities = np.atleast_1d(np.asarray(arity, dtype=np.int64))
    if (arities < 1).any():
        raise ValueError("query arity must be >= 1")
    max_arity = int(arities.max())

    t = draw(n_queries)

    if max_arity == 2 and len(arities) == 1:
        # The historical 2-term sampler, bit-for-bit (same rng stream).
        u = topical(t)
        eq = t == u
        while eq.any():
            u[eq] = draw(int(eq.sum()))
            eq = t == u
        q = np.stack([t, u], axis=1).astype(np.int32)
        return QueryLog(queries=q, arrivals=_arrivals())

    if arity_weights is not None:
        p = np.asarray(arity_weights, dtype=np.float64)
        p = p / p.sum()
    else:
        p = None
    per_query = rng.choice(arities, size=n_queries, p=p)

    q = np.full((n_queries, max_arity), QUERY_PAD, dtype=np.int64)
    q[:, 0] = t
    for slot in range(1, max_arity):
        need = per_query > slot  # rows still owed a term at this slot
        if not need.any():
            break
        idx = np.flatnonzero(need)
        u = topical(t[idx])
        # Terms within a query must be distinct: resample collisions.
        dup = (q[idx, :slot] == u[:, None]).any(axis=1)
        while dup.any():
            u[dup] = draw(int(dup.sum()))
            dup = (q[idx, :slot] == u[:, None]).any(axis=1)
        q[idx, slot] = u
    return QueryLog(queries=q.astype(np.int32), arrivals=_arrivals())


def term_probabilities(
    n_terms: int,
    log: Optional[QueryLog] = None,
    corpus: Optional[Corpus] = None,
    smoothing: float = 0.0,
) -> np.ndarray:
    """Estimate P[t], the probability a query contains term t (§3.1).

    From a query log when available (the accurate route), otherwise from
    corpus document frequencies (the paper's fallback).  Returns a float64
    array of shape (n_terms,) summing to 1.
    """
    if log is not None:
        flat = log.queries.ravel()
        flat = flat[flat != QUERY_PAD]  # ragged rows carry pad entries
        counts = np.bincount(flat, minlength=n_terms).astype(np.float64)
    elif corpus is not None:
        counts = corpus.term_doc_freq().astype(np.float64)
    else:
        raise ValueError("need a query log or a corpus")
    counts += smoothing
    total = counts.sum()
    if total <= 0:
        raise ValueError("empty statistics")
    return counts / total
