"""Deterministic, resumable data pipeline for model training.

Fault-tolerance requirement: after checkpoint/restart the pipeline must
resume at exactly the next unseen batch with no host coordination.  We get
this by deriving every batch from a *counter-based* PRNG keyed by
``(seed, step, shard)`` — there is no mutable iterator state to lose; the
checkpoint stores only the integer ``step``.

The synthetic LM stream draws Zipf-distributed token ids (matching the
corpus statistics used elsewhere in the framework) with a simple Markov
blending so that the ~100M-parameter example model has learnable structure.
Recsys batches (dense features, multi-hot sparse ids, history sequences)
are generated the same counter-based way.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

__all__ = ["PipelineState", "TokenPipeline", "RecsysPipeline"]


@dataclasses.dataclass(frozen=True)
class PipelineState:
    """Complete pipeline state — an integer. Stored in every checkpoint."""

    step: int = 0

    def advance(self, n: int = 1) -> "PipelineState":
        return PipelineState(step=self.step + n)


def _rng(seed: int, step: int, shard: int) -> np.random.Generator:
    # Counter-based: independent stream per (seed, step, shard).
    return np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=(step, shard))
    )


class TokenPipeline:
    """Synthetic LM token stream.

    Produces ``(tokens, targets)`` of shape (batch_per_shard, seq_len).
    Tokens follow a Zipf marginal with first-order structure: with
    probability ``repeat_p`` a token copies one of the previous 8 tokens,
    which gives next-token prediction a signal the example trainer can
    visibly reduce loss on.
    """

    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        batch_per_shard: int,
        seed: int = 0,
        zipf_s: float = 1.05,
        repeat_p: float = 0.3,
    ):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch_per_shard = batch_per_shard
        self.seed = seed
        self.repeat_p = repeat_p
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = ranks**-zipf_s
        self._cdf = np.cumsum(p / p.sum())

    def batch(self, state: PipelineState, shard: int = 0) -> Dict[str, np.ndarray]:
        rng = _rng(self.seed, state.step, shard)
        shape = (self.batch_per_shard, self.seq_len + 1)
        toks = np.searchsorted(self._cdf, rng.random(shape), side="right").astype(
            np.int32
        )
        # Local repetition structure.
        rep = rng.random(shape) < self.repeat_p
        lag = rng.integers(1, 9, size=shape)
        idx = np.maximum(np.arange(shape[1])[None, :] - lag, 0)
        toks = np.where(rep, np.take_along_axis(toks, idx, axis=1), toks)
        np.clip(toks, 0, self.vocab_size - 1, out=toks)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class RecsysPipeline:
    """Synthetic CTR/sequential-recommendation batches.

    Emits the superset of fields the four recsys architectures consume;
    each model picks what it needs:
      * ``dense``      (B, n_dense) float32
      * ``sparse_ids`` (B, n_fields) int32 — one categorical id per field
      * ``hist_ids``   (B, hist_len) int32 — user behaviour sequence
      * ``hist_mask``  (B, hist_len) float32
      * ``target_id``  (B,) int32 — candidate item
      * ``label``      (B,) float32 — click
    """

    def __init__(
        self,
        n_dense: int,
        n_fields: int,
        vocab_size: int,
        hist_len: int,
        batch_per_shard: int,
        seed: int = 0,
    ):
        self.n_dense = n_dense
        self.n_fields = n_fields
        self.vocab_size = vocab_size
        self.hist_len = hist_len
        self.batch_per_shard = batch_per_shard
        self.seed = seed

    def batch(self, state: PipelineState, shard: int = 0) -> Dict[str, np.ndarray]:
        rng = _rng(self.seed ^ 0x5EC5, state.step, shard)
        b = self.batch_per_shard
        dense = rng.standard_normal((b, self.n_dense)).astype(np.float32)
        sparse = rng.zipf(1.2, size=(b, self.n_fields)) % self.vocab_size
        hist = rng.zipf(1.2, size=(b, self.hist_len)) % self.vocab_size
        hist_valid = (
            np.arange(self.hist_len)[None, :]
            < rng.integers(1, self.hist_len + 1, size=(b, 1))
        )
        target = rng.zipf(1.2, size=b) % self.vocab_size
        # Label has learnable structure: click iff target appears in history
        # or the dense projection is positive, with noise.
        clicked = (hist == target[:, None]).any(axis=1) | (dense[:, 0] > 0.5)
        flip = rng.random(b) < 0.1
        label = (clicked ^ flip).astype(np.float32)
        return {
            "dense": dense,
            "sparse_ids": sparse.astype(np.int32),
            "hist_ids": np.where(hist_valid, hist, 0).astype(np.int32),
            "hist_mask": hist_valid.astype(np.float32),
            "target_id": target.astype(np.int32),
            "label": label,
        }
