"""Synthetic document corpora with Zipf marginals and latent-topic structure.

A corpus is a CSR set-of-terms representation:

  * ``doc_ptr``   -- int64 array of shape (n_docs + 1,)
  * ``doc_terms`` -- int32 array of shape (nnz,); ``doc_terms[doc_ptr[d]:
    doc_ptr[d+1]]`` is the sorted set of distinct term ids in document d.

Posting lists store each document at most once per term (the paper
intersects lists of document IDs), so the corpus stores term *sets*.

Generation model
----------------
Global term marginal is Zipf(s) over ``n_terms`` ranks.  ``n_topics``
latent topics each boost a contiguous block of mid-frequency term ranks by
``topic_boost``; a document draws one topic and samples
``topicality`` of its tokens from the boosted distribution and the rest
from the global one.  This mirrors what makes real corpora clusterable:
frequent terms are everywhere, but mid-frequency terms concentrate by
topic — exactly the non-uniformity SeCluD's objective rewards.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["CorpusSpec", "Corpus", "synth_corpus", "corpus_stats"]


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    """Parameters of a synthetic corpus."""

    n_docs: int = 20_000
    n_terms: int = 50_000
    mean_doc_len: float = 120.0  # tokens, before set-dedup
    sigma_doc_len: float = 0.8  # log-normal sigma
    zipf_s: float = 1.07  # Zipf exponent of the global marginal
    n_topics: int = 32
    topicality: float = 0.6  # fraction of tokens drawn from the topic dist
    topic_boost: float = 40.0  # multiplicative boost of a topic's term block
    topic_block_lo: int = 64  # topical blocks cover ranks [lo, hi)
    topic_block_hi: Optional[int] = None  # default: n_terms // 2
    seed: int = 0

    # Named presets mirroring the paper's corpora (Table 1), scaled down.
    @staticmethod
    def gov2_like(n_docs: int = 20_000, seed: int = 0) -> "CorpusSpec":
        """Long documents, large vocabulary (GOV2: 652 terms/doc)."""
        return CorpusSpec(
            n_docs=n_docs,
            n_terms=60_000,
            mean_doc_len=300.0,
            sigma_doc_len=0.7,
            n_topics=48,
            topicality=0.55,
            seed=seed,
        )

    @staticmethod
    def gov2s_like(n_docs: int = 120_000, seed: int = 0) -> "CorpusSpec":
        """Sentence-granularity: many tiny documents (GOV2s: 18 terms/doc)."""
        return CorpusSpec(
            n_docs=n_docs,
            n_terms=40_000,
            mean_doc_len=14.0,
            sigma_doc_len=0.5,
            n_topics=48,
            topicality=0.6,
            seed=seed,
        )

    @staticmethod
    def wiki_like(n_docs: int = 30_000, seed: int = 0) -> "CorpusSpec":
        """Medium documents (Wikipedia: 230 terms/doc)."""
        return CorpusSpec(
            n_docs=n_docs,
            n_terms=50_000,
            mean_doc_len=150.0,
            sigma_doc_len=0.9,
            n_topics=64,
            topicality=0.5,
            seed=seed,
        )

    @staticmethod
    def forum_like(n_docs: int = 12_000, seed: int = 0) -> "CorpusSpec":
        """Small specialized corpus (pagenstecher.de: 36 terms/doc,
        narrow topic spread — the instance with the best speedups)."""
        return CorpusSpec(
            n_docs=n_docs,
            n_terms=12_000,
            mean_doc_len=30.0,
            sigma_doc_len=0.6,
            n_topics=16,
            topicality=0.75,
            topic_boost=80.0,
            seed=seed,
        )


@dataclasses.dataclass
class Corpus:
    """CSR set-of-terms corpus."""

    doc_ptr: np.ndarray  # (n_docs + 1,) int64
    doc_terms: np.ndarray  # (nnz,) int32, sorted unique within each doc
    n_terms: int
    doc_topic: Optional[np.ndarray] = None  # (n_docs,) ground-truth topics
    spec: Optional[CorpusSpec] = None

    @property
    def n_docs(self) -> int:
        return len(self.doc_ptr) - 1

    @property
    def nnz(self) -> int:
        return int(self.doc_ptr[-1])

    def doc(self, d: int) -> np.ndarray:
        return self.doc_terms[self.doc_ptr[d] : self.doc_ptr[d + 1]]

    def doc_lengths(self) -> np.ndarray:
        return np.diff(self.doc_ptr)

    def term_doc_freq(self) -> np.ndarray:
        """Document frequency df(t) for every term (posting-list lengths)."""
        return np.bincount(self.doc_terms, minlength=self.n_terms)

    def subset(self, doc_ids: np.ndarray) -> "Corpus":
        """Row-subset corpus (used by multilevel sampling & TopDown)."""
        doc_ids = np.asarray(doc_ids)
        lengths = np.diff(self.doc_ptr)[doc_ids]
        new_ptr = np.zeros(len(doc_ids) + 1, dtype=np.int64)
        np.cumsum(lengths, out=new_ptr[1:])
        new_terms = np.empty(int(new_ptr[-1]), dtype=self.doc_terms.dtype)
        for i, d in enumerate(doc_ids):
            new_terms[new_ptr[i] : new_ptr[i + 1]] = self.doc_terms[
                self.doc_ptr[d] : self.doc_ptr[d + 1]
            ]
        return Corpus(
            doc_ptr=new_ptr,
            doc_terms=new_terms,
            n_terms=self.n_terms,
            doc_topic=None if self.doc_topic is None else self.doc_topic[doc_ids],
            spec=self.spec,
        )


def _zipf_probs(n_terms: int, s: float) -> np.ndarray:
    ranks = np.arange(1, n_terms + 1, dtype=np.float64)
    p = ranks**-s
    return p / p.sum()


def synth_corpus(spec: CorpusSpec) -> Corpus:
    """Generate a synthetic corpus per the module docstring.

    Fully vectorized numpy; ~10M token draws per second per core.
    Deterministic in ``spec.seed``.
    """
    rng = np.random.default_rng(spec.seed)
    n, m = spec.n_docs, spec.n_terms

    base_p = _zipf_probs(m, spec.zipf_s)

    # Topic term-blocks over mid-frequency ranks.
    hi = spec.topic_block_hi if spec.topic_block_hi is not None else m // 2
    lo = min(spec.topic_block_lo, hi - 1)
    block = max(1, (hi - lo) // max(spec.n_topics, 1))
    topic_p = np.tile(base_p, (spec.n_topics, 1))
    for z in range(spec.n_topics):
        b0 = lo + z * block
        b1 = min(lo + (z + 1) * block, hi)
        topic_p[z, b0:b1] *= spec.topic_boost
    topic_p /= topic_p.sum(axis=1, keepdims=True)

    # Document lengths (token draws, pre-dedup) and topics.
    mu = np.log(spec.mean_doc_len) - 0.5 * spec.sigma_doc_len**2
    lengths = np.maximum(
        2, rng.lognormal(mean=mu, sigma=spec.sigma_doc_len, size=n).astype(np.int64)
    )
    doc_topic = rng.integers(0, spec.n_topics, size=n)

    # Vectorized sampling: one big draw, segmented by document.
    total = int(lengths.sum())
    tok_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(lengths, out=tok_ptr[1:])
    tok_doc = np.repeat(np.arange(n), lengths)

    from_topic = rng.random(total) < spec.topicality
    # Inverse-CDF sampling against per-topic CDFs.
    u = rng.random(total)
    base_cdf = np.cumsum(base_p)
    tokens = np.empty(total, dtype=np.int64)
    glob = ~from_topic
    tokens[glob] = np.searchsorted(base_cdf, u[glob], side="right")
    topic_cdf = np.cumsum(topic_p, axis=1)
    tok_topic = doc_topic[tok_doc]
    for z in range(spec.n_topics):  # n_topics CDF rows; loop is over topics only
        sel = from_topic & (tok_topic == z)
        if sel.any():
            tokens[sel] = np.searchsorted(topic_cdf[z], u[sel], side="right")
    np.clip(tokens, 0, m - 1, out=tokens)

    # Dedup within documents: sort (doc, term) pairs, drop repeats.
    key = tok_doc * np.int64(m) + tokens
    key = np.unique(key)  # sorted; unique (doc, term)
    out_doc = (key // m).astype(np.int64)
    out_term = (key % m).astype(np.int32)
    counts = np.bincount(out_doc, minlength=n)
    doc_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=doc_ptr[1:])

    return Corpus(
        doc_ptr=doc_ptr,
        doc_terms=out_term,
        n_terms=m,
        doc_topic=doc_topic,
        spec=spec,
    )


def corpus_stats(corpus: Corpus) -> dict:
    """Table-1-style statistics."""
    lengths = corpus.doc_lengths()
    df = corpus.term_doc_freq()
    return {
        "documents": corpus.n_docs,
        "terms": int((df > 0).sum()),
        "terms_per_document": float(lengths.mean()),
        "postings": corpus.nnz,
        "max_doc_len": int(lengths.max()),
        "max_posting_len": int(df.max()),
    }
