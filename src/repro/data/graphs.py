"""Graph substrate: synthetic graphs, CSR adjacency, neighbor sampling.

* ``synth_graph``      — power-law (preferential-attachment-ish) graph with
                         topic-correlated features/labels, CSR adjacency.
* ``NeighborSampler``  — the real layered fanout sampler ``minibatch_lg``
                         requires (kernel_taxonomy §B.3: "needs a real
                         neighbor sampler"): k-hop uniform sampling from
                         CSR, merged into a fixed-shape padded subgraph.
* ``batch_molecules``  — block-diagonal batching of many small graphs.

All host-side numpy (samplers run on CPU feeding the device step), all
deterministic in their seeds.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = ["GraphData", "synth_graph", "NeighborSampler", "batch_molecules"]


@dataclasses.dataclass
class GraphData:
    """CSR graph with features/labels. Edges are directed src -> dst."""

    indptr: np.ndarray  # (N+1,)
    indices: np.ndarray  # (E,) neighbor ids (incoming sources per dst)
    feats: np.ndarray  # (N, F) float32
    labels: np.ndarray  # (N,) int32
    n_classes: int

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.indices)

    def edge_list(self) -> Tuple[np.ndarray, np.ndarray]:
        """(src, dst) arrays; indices holds sources grouped by dst."""
        dst = np.repeat(np.arange(self.n_nodes, dtype=np.int32), np.diff(self.indptr))
        return self.indices.astype(np.int32), dst


def synth_graph(
    n_nodes: int,
    avg_degree: int,
    d_feat: int,
    n_classes: int,
    seed: int = 0,
    power: float = 1.2,
) -> GraphData:
    """Power-law in-degree graph; features = class centroid + noise."""
    rng = np.random.default_rng(seed)
    n_edges = n_nodes * avg_degree
    # Power-law target popularity.
    pop = (np.arange(1, n_nodes + 1) ** -power)
    pop /= pop.sum()
    dst = rng.choice(n_nodes, size=n_edges, p=pop)
    src = rng.integers(0, n_nodes, size=n_edges)
    # Group by dst -> CSR.
    order = np.argsort(dst, kind="stable")
    dst_s, src_s = dst[order], src[order]
    counts = np.bincount(dst_s, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])

    labels = rng.integers(0, n_classes, size=n_nodes).astype(np.int32)
    centroids = rng.standard_normal((n_classes, d_feat)).astype(np.float32)
    feats = centroids[labels] + 0.5 * rng.standard_normal((n_nodes, d_feat)).astype(
        np.float32
    )
    return GraphData(
        indptr=indptr,
        indices=src_s.astype(np.int32),
        feats=feats,
        labels=labels,
        n_classes=n_classes,
    )


class NeighborSampler:
    """Layered uniform neighbor sampling (GraphSAGE-style).

    ``sample(seeds)`` returns a fixed-shape padded subgraph:
      * feats   (N_pad, F)
      * edges   (E_pad, 2) int32 local (src, dst), padded with (0, N_pad-1)
                self-edges into a dummy node
      * edge_mask (E_pad,)
      * seed_pos (B,) local indices of the seeds
      * labels  (B,)
    The union subgraph is run through ALL model layers (subgraph
    convolution) — fixed shapes, jit-friendly.
    """

    def __init__(self, graph: GraphData, fanouts: Tuple[int, ...], seed: int = 0):
        self.g = graph
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)

    def budget(self, batch: int) -> Tuple[int, int]:
        """(N_pad, E_pad) upper bounds for a seed batch."""
        n = batch
        e = 0
        frontier = batch
        for f in self.fanouts:
            e += frontier * f
            frontier *= f
            n += frontier
        return n + 1, e  # +1 dummy node

    def sample(self, seeds: np.ndarray) -> dict:
        g = self.g
        seeds = np.asarray(seeds, dtype=np.int64)
        n_pad, e_pad = self.budget(len(seeds))

        nodes = list(seeds)
        local = {int(v): i for i, v in enumerate(seeds)}
        edges = []
        frontier = seeds
        for f in self.fanouts:
            nxt = []
            for v in frontier:
                lo, hi = g.indptr[v], g.indptr[v + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = self.rng.integers(lo, hi, size=f)  # with replacement
                for e in take:
                    u = int(g.indices[e])
                    if u not in local:
                        local[u] = len(nodes)
                        nodes.append(u)
                    nxt.append(u)
                    edges.append((local[u], local[int(v)]))
            frontier = np.asarray(nxt, dtype=np.int64) if nxt else np.empty(0, np.int64)

        nodes_arr = np.asarray(nodes, dtype=np.int64)
        feats = np.zeros((n_pad, g.feats.shape[1]), np.float32)
        feats[: len(nodes_arr)] = g.feats[nodes_arr]
        e_arr = np.full((e_pad, 2), n_pad - 1, dtype=np.int32)
        mask = np.zeros((e_pad,), np.float32)
        if edges:
            e_np = np.asarray(edges, dtype=np.int32)[:e_pad]
            e_arr[: len(e_np)] = e_np
            mask[: len(e_np)] = 1.0
        return {
            "feats": feats,
            "edges": e_arr,
            "edge_mask": mask,
            "seed_pos": np.arange(len(seeds), dtype=np.int32),
            "labels": g.labels[seeds].astype(np.int32),
            "n_real_nodes": len(nodes_arr),
        }


def batch_molecules(
    n_graphs: int,
    nodes_per_graph: int,
    edges_per_graph: int,
    d_feat: int,
    n_classes: int,
    seed: int = 0,
) -> dict:
    """Block-diagonal batch of small random graphs with graph labels."""
    rng = np.random.default_rng(seed)
    n = n_graphs * nodes_per_graph
    feats = rng.standard_normal((n, d_feat)).astype(np.float32)
    src = []
    dst = []
    for gidx in range(n_graphs):
        base = gidx * nodes_per_graph
        s = rng.integers(0, nodes_per_graph, size=edges_per_graph) + base
        d = rng.integers(0, nodes_per_graph, size=edges_per_graph) + base
        src.append(s)
        dst.append(d)
    graph_id = np.repeat(np.arange(n_graphs, dtype=np.int32), nodes_per_graph)
    labels = rng.integers(0, n_classes, size=n_graphs).astype(np.int32)
    return {
        "feats": feats,
        "edges": np.stack(
            [np.concatenate(src), np.concatenate(dst)], axis=1
        ).astype(np.int32),
        "edge_mask": np.ones((n_graphs * edges_per_graph,), np.float32),
        "graph_id": graph_id,
        "labels": labels,
    }
