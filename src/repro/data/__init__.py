"""Data substrate: synthetic corpora, query logs, and training pipelines.

The paper evaluates on GOV2 / GOV2s / Wikipedia / pagenstecher.de with the
AOL and site query logs.  None of those are shippable inside this container,
so this package provides parameterized synthetic generators that match the
*statistical shape* the paper relies on:

  * Zipf-distributed term marginals (Figure 1 of the paper shows all query
    logs are Zipf-like),
  * latent-topic mixture so that documents are clusterable (the property
    SeCluD exploits),
  * log-normal document lengths, with a "sentence" mode emulating GOV2s
    (many tiny documents),
  * query logs with Zipf rank-probability and topical term co-occurrence.
"""

from repro.data.corpus import Corpus, CorpusSpec, synth_corpus, corpus_stats
from repro.data.query_log import QueryLog, synth_query_log, term_probabilities
from repro.data.pipeline import TokenPipeline, PipelineState

__all__ = [
    "Corpus",
    "CorpusSpec",
    "synth_corpus",
    "corpus_stats",
    "QueryLog",
    "synth_query_log",
    "term_probabilities",
    "TokenPipeline",
    "PipelineState",
]
