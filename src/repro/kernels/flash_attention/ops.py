"""Public flash-attention wrapper: (B, H, L, D) API, GQA-aware.

TPU → Pallas kernel; CPU → pure-jnp reference (tests force interpret).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["flash_attention"]


def flash_attention(
    q: jnp.ndarray,  # (B, H, Lq, D)
    k: jnp.ndarray,  # (B, H, Lk, D)
    v: jnp.ndarray,
    causal: bool = True,
    window: int | None = None,
    tile_q: int = 128,
    tile_k: int = 128,
    force_kernel: bool = False,
    interpret: bool | None = None,
) -> jnp.ndarray:
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_kernel):
        return attention_ref(q, k, v, causal=causal, window=window)
    if interpret is None:
        interpret = not on_tpu
    b, h, lq, d = q.shape
    lk = k.shape[-2]
    tq = min(tile_q, lq)
    tk = min(tile_k, lk)
    assert lq % tq == 0 and lk % tk == 0, "pad sequence to tile multiple"
    out = flash_attention_kernel(
        q.reshape(b * h, lq, d),
        k.reshape(b * h, lk, d),
        v.reshape(b * h, lk, d),
        causal=causal,
        window=window,
        tile_q=tq,
        tile_k=tk,
        interpret=interpret,
    )
    return out.reshape(b, h, lq, d)
