"""Pallas TPU kernel: blocked FlashAttention (fwd) with causal/window skip.

Standard IO-aware tiling (FlashAttention, adapted to TPU VMEM/MXU):
grid (B·H, Lq/TQ, Lk/TK), online-softmax running (m, l, acc) carried in
VMEM scratch across the contraction (last) grid axis.  Causal and
sliding-window tiles that are fully masked are skipped with ``pl.when``
(block-level sparsity — the same skip structure the gemma3 5:1
local:global pattern exploits at long context).

Tile sizes default to (TQ, TK) = (128, 128); D is kept whole (the MXU
contracts (TQ, D) @ (D, TK) then (TQ, TK) @ (TK, D)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams; keep one alias for both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

from repro.kernels.flash_attention.ref import NEG_INF

__all__ = ["flash_attention_kernel"]


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int | None, off: int, tq: int, tk: int,
    n_k: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level skip decision (static per (qi, kj) only when traced with
    # concrete program ids — here dynamic, so use pl.when).
    q_lo = qi * tq + off  # key-aligned position of the first query row
    q_hi = q_lo + tq - 1
    k_lo = kj * tk
    k_hi = k_lo + tk - 1
    live = True
    if causal:
        live = k_lo <= q_hi
    if window is not None:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _():
        q = q_ref[0]  # (TQ, D)
        k = k_ref[0]  # (TK, D)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (TQ, TK)
        ii = q_lo + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        jj = k_lo + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        mask = jnp.ones((tq, tk), jnp.bool_)
        if causal:
            mask &= jj <= ii
        if window is not None:
            mask &= jj > ii - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (TQ, 1)
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * alpha + p.sum(axis=1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )

    @pl.when(kj == n_k - 1)
    def _():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "tile_q", "tile_k", "interpret"),
)
def flash_attention_kernel(
    q: jnp.ndarray,  # (BH, Lq, D)
    k: jnp.ndarray,  # (BH, Lk, D)
    v: jnp.ndarray,
    causal: bool = True,
    window: int | None = None,
    tile_q: int = 128,
    tile_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, lq, d = q.shape
    _, lk, _ = k.shape
    assert lq % tile_q == 0 and lk % tile_k == 0
    off = lk - lq
    n_k = lk // tile_k
    grid = (bh, lq // tile_q, n_k)
    scale = 1.0 / (d**0.5)

    return pl.pallas_call(
        functools.partial(
            _kernel,
            scale=scale,
            causal=causal,
            window=window,
            off=off,
            tq=tile_q,
            tk=tile_k,
            n_k=n_k,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tile_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tile_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
