"""Pure-jnp attention oracle (causal / sliding-window / full).

Contract: q (B, H, Lq, D), k/v (B, H, Lk, D); ``causal`` masks j > i + off
where off = Lk - Lq (decode alignment: the last query attends to all keys);
``window`` additionally masks j < i + off - window + 1 (sliding window of
size ``window``, inclusive of self).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]

NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int | None = None,
) -> jnp.ndarray:
    *_, lq, d = q.shape
    lk = k.shape[-2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    off = lk - lq
    i = jnp.arange(lq)[:, None]
    j = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= j <= i + off
    if window is not None:
        mask &= j > i + off - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
