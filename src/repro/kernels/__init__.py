"""Pallas TPU kernels for the compute hot-spots the paper optimizes in C.

Three kernels, each with the standard triple:

* ``intersect``       — batched sorted-posting-list intersection, the
                        paper's query inner loop (the C "Lookup" code).
                        TPU-native tiled compare-merge with directory-based
                        tile skipping (DESIGN.md §3).
* ``cluster_score``   — the K-means δ⁺ scoring SpMM (the C clustering
                        inner loop), as a one-hot-tiled MXU matmul over an
                        ELL doc-term layout.  The same regime serves GNN
                        aggregation and recsys embedding-bag.
* ``flash_attention`` — blocked attention for the LM serving/training
                        stack (standard FlashAttention tiling, used by the
                        model zoo when running on TPU).

Each directory holds ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd public wrapper; CPU fallback = the reference), and
``ref.py`` (pure-jnp oracle).  Kernels are validated in interpret mode on
CPU across shape/dtype sweeps (tests/test_kernels_*.py); real-TPU Mosaic
lowering is the deployment target.
"""
