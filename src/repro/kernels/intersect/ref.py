"""Pure-jnp oracle for batched posting-list intersection.

Contract (shared with the kernel): ``short`` (B, Ls) and ``long`` (B, Ll)
are rows of sorted int32 doc ids padded with PAD = int32 max; the result
is the per-row intersection size |short_row ∩ long_row| as int32 (B,).
PAD never matches PAD: padding contributes zero.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# numpy scalar (not a jax array) so kernels can close over it as a literal
PAD = np.int32(2**31 - 1)

__all__ = ["intersect_count_ref", "PAD"]


@jax.jit
def intersect_count_ref(short: jnp.ndarray, long: jnp.ndarray) -> jnp.ndarray:
    """Vectorized binary search of each short element into the long row."""
    pos = jax.vmap(jnp.searchsorted)(long, short)
    pos = jnp.minimum(pos, long.shape[1] - 1)
    hit = (jnp.take_along_axis(long, pos, axis=1) == short) & (short != PAD)
    return hit.sum(axis=1).astype(jnp.int32)
