"""Pure-jnp oracle for batched posting-list intersection.

Contract (shared with the kernel): ``short`` (B, Ls) and ``long`` (B, Ll)
are rows of sorted int32 doc ids padded with PAD = int32 max; the result
is the per-row intersection size |short_row ∩ long_row| as int32 (B,).
PAD never matches PAD: padding contributes zero.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# numpy scalar (not a jax array) so kernels can close over it as a literal
PAD = np.int32(2**31 - 1)

__all__ = [
    "intersect_count_ref",
    "intersect_members_ref",
    "intersect_members_docs_ref",
    "PAD",
]


@jax.jit
def intersect_members_ref(short: jnp.ndarray, long: jnp.ndarray) -> jnp.ndarray:
    """Per-element membership of ``short`` rows in ``long`` rows.

    Same contract as :func:`intersect_count_ref` but returns the boolean
    hit mask (B, Ls) instead of its row sum — the pairwise *select* step
    of a k-way intersection fold (``repro.core.batched_query``).  Only the
    ``long`` rows must be sorted; ``short`` elements are searched
    independently, and PAD never matches.
    """
    pos = jax.vmap(jnp.searchsorted)(long, short)
    pos = jnp.minimum(pos, long.shape[1] - 1)
    return (jnp.take_along_axis(long, pos, axis=1) == short) & (short != PAD)


@jax.jit
def intersect_members_docs_ref(
    short: jnp.ndarray, long: jnp.ndarray
) -> jnp.ndarray:
    """PAD-compacted member docs per row (B, Ls): the elements of
    ``short_row ∩ long_row`` left-aligned and sorted, PAD filling the
    rest.  Misses become PAD (= int32 max); rows are sorted, so one sort
    is a stable left-compaction of the survivors."""
    hit = intersect_members_ref(short, long)
    return jnp.sort(jnp.where(hit, short, PAD), axis=1)


@jax.jit
def intersect_count_ref(short: jnp.ndarray, long: jnp.ndarray) -> jnp.ndarray:
    """Vectorized binary search of each short element into the long row."""
    return intersect_members_ref(short, long).sum(axis=1).astype(jnp.int32)
