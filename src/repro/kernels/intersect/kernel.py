"""Pallas TPU kernel: batched sorted-posting-list intersection.

TPU-native redesign of the paper's Lookup intersection (DESIGN.md §3):
instead of per-element bucket probes (pointer-chasing — poison on TPU),
both sorted lists are processed as 128-wide tiles.  For each short tile
the kernel walks the long row tile-by-tile and

  * SKIPS tile pairs whose value ranges don't overlap (the sortedness
    gives tile min/max for free: first/last lane).  This is the vector
    analogue of the paper's empty-bucket skip — and it is exactly what
    cluster-contiguous reordering (paper §3.3, speedup S_R) accelerates:
    skew concentrates matches into few overlapping tile pairs;
  * for overlapping pairs does a branch-free (BQ, TS, TL) broadcast
    equality-count on the VPU (the "wasted" compares in a 128-lane tile
    are cheaper than one HBM round-trip — DESIGN.md §3).

Layout: short (B, Ls), long (B, Ll), PAD = int32 max, rows sorted.
Grid (B/BQ, Ls/TS); the long row block (BQ, Ll) stays resident in VMEM
across the short-tile steps.  Output (B, 1) int32 accumulates across grid
step s (init at s == 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams; keep one alias for both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

from repro.kernels.intersect.ref import PAD

__all__ = ["intersect_count_kernel", "PAD"]


def _kernel(short_ref, long_ref, out_ref, *, tile_l: int):
    s = pl.program_id(1)
    s_tile = short_ref[...]  # (BQ, TS) int32
    l_row = long_ref[...]  # (BQ, Ll) int32
    bq, ts = s_tile.shape
    ll = l_row.shape[1]
    n_lt = ll // tile_l

    valid = s_tile != PAD
    any_valid = jnp.any(valid)
    # Union value-range of this short tile across the BQ rows.
    smin = jnp.min(s_tile[:, 0])
    smax = jnp.max(jnp.where(valid, s_tile, jnp.int32(-(2**31))))

    def body(j, acc):
        l_tile = jax.lax.dynamic_slice(l_row, (0, j * tile_l), (bq, tile_l))
        valid_l = l_tile != PAD
        lmin = jnp.min(l_tile)  # PAD sorts last; per-row first is the min
        lmax = jnp.max(jnp.where(valid_l, l_tile, jnp.int32(-(2**31))))
        # PAD-only tiles get lmax = -2^31 and skip via lmax >= smin.
        pred = any_valid & (lmin <= smax) & (lmax >= smin)

        def compute(a):
            eq = (s_tile[:, :, None] == l_tile[:, None, :]) & valid[:, :, None]
            return a + eq.sum(axis=(1, 2)).astype(jnp.int32)

        return jax.lax.cond(pred, compute, lambda a: a, acc)

    acc = jax.lax.fori_loop(0, n_lt, body, jnp.zeros((bq,), jnp.int32))

    @pl.when(s == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += acc[:, None]


@functools.partial(
    jax.jit, static_argnames=("block_q", "tile_s", "tile_l", "interpret")
)
def intersect_count_kernel(
    short: jnp.ndarray,
    long: jnp.ndarray,
    block_q: int = 8,
    tile_s: int = 128,
    tile_l: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """|short_row ∩ long_row| per row. Shapes must be pre-padded:
    B % block_q == 0, Ls % tile_s == 0, Ll % tile_l == 0."""
    b, ls = short.shape
    _, ll = long.shape
    assert b % block_q == 0 and ls % tile_s == 0 and ll % tile_l == 0

    grid = (b // block_q, ls // tile_s)
    out = pl.pallas_call(
        functools.partial(_kernel, tile_l=tile_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, tile_s), lambda i, s: (i, s)),
            pl.BlockSpec((block_q, ll), lambda i, s: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, 1), lambda i, s: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(short, long)
    return out[:, 0]
