"""Pallas TPU kernel: batched sorted-posting-list intersection.

TPU-native redesign of the paper's Lookup intersection (DESIGN.md §3):
instead of per-element bucket probes (pointer-chasing — poison on TPU),
both sorted lists are processed as 128-wide tiles.  For each short tile
the kernel walks the long row tile-by-tile and

  * SKIPS tile pairs whose value ranges don't overlap (the sortedness
    gives tile min/max for free: first/last lane).  This is the vector
    analogue of the paper's empty-bucket skip — and it is exactly what
    cluster-contiguous reordering (paper §3.3, speedup S_R) accelerates:
    skew concentrates matches into few overlapping tile pairs;
  * for overlapping pairs does a branch-free (BQ, TS, TL) broadcast
    equality-count on the VPU (the "wasted" compares in a 128-lane tile
    are cheaper than one HBM round-trip — DESIGN.md §3).

Layout: short (B, Ls), long (B, Ll), PAD = int32 max, rows sorted.
Grid (B/BQ, Ls/TS); the long row block (BQ, Ll) stays resident in VMEM
across the short-tile steps.  Output (B, 1) int32 accumulates across grid
step s (init at s == 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams; keep one alias for both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

from repro.kernels.intersect.ref import PAD

__all__ = [
    "intersect_count_kernel",
    "intersect_members_kernel",
    "intersect_members_count_kernel",
    "PAD",
]


def _kernel(short_ref, long_ref, out_ref, *, tile_l: int):
    s = pl.program_id(1)
    s_tile = short_ref[...]  # (BQ, TS) int32
    l_row = long_ref[...]  # (BQ, Ll) int32
    bq, ts = s_tile.shape
    ll = l_row.shape[1]
    n_lt = ll // tile_l

    valid = s_tile != PAD
    any_valid = jnp.any(valid)
    # Union value-range of this short tile across the BQ rows.
    smin = jnp.min(s_tile[:, 0])
    smax = jnp.max(jnp.where(valid, s_tile, jnp.int32(-(2**31))))

    def body(j, acc):
        l_tile = jax.lax.dynamic_slice(l_row, (0, j * tile_l), (bq, tile_l))
        valid_l = l_tile != PAD
        lmin = jnp.min(l_tile)  # PAD sorts last; per-row first is the min
        lmax = jnp.max(jnp.where(valid_l, l_tile, jnp.int32(-(2**31))))
        # PAD-only tiles get lmax = -2^31 and skip via lmax >= smin.
        pred = any_valid & (lmin <= smax) & (lmax >= smin)

        def compute(a):
            eq = (s_tile[:, :, None] == l_tile[:, None, :]) & valid[:, :, None]
            return a + eq.sum(axis=(1, 2)).astype(jnp.int32)

        return jax.lax.cond(pred, compute, lambda a: a, acc)

    acc = jax.lax.fori_loop(0, n_lt, body, jnp.zeros((bq,), jnp.int32))

    @pl.when(s == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += acc[:, None]


# ----------------------------------------------------------------------
# Members kernel: per-tile binary probe instead of walking every tile
# ----------------------------------------------------------------------


def _probe_hits(s_tile, l_row, *, tile_l: int):
    """Hit mask (BQ, TS) of a short tile against the resident long row.

    Instead of the all-pairs walk over every long tile, the candidate
    tile range is *probed*: the per-tile start values (free for sorted
    rows: lane 0 of each tile) give monotone lower/upper envelopes
    ``M_j = max_rows start`` / ``m_j = min_rows start``, and a rank count
    against the short tile's value range [smin, smax] — a vectorized
    binary search over the tile directory — yields the only tiles any
    row could match.  All-pairs equality runs just inside that range;
    with cluster-contiguous reordering (paper §3.3) it is typically one
    or two tiles.

    Only the LONG rows must be sorted (PAD last) — the probe range comes
    from their tile directory.  Short rows may carry PAD holes anywhere
    (a masked k-way fold feeds exactly that), so both range ends are
    masked reductions, never a lane-0 shortcut.
    """
    bq, ts = s_tile.shape
    ll = l_row.shape[1]
    n_lt = ll // tile_l

    valid = s_tile != PAD
    # Masked min/max over the valid lanes: PAD holes must not poison the
    # probe window (PAD at lane 0 would push smin to int32 max and skip
    # every tile).  All-PAD tiles get smin = PAD, smax = -2^31, so the
    # rank counts produce an empty range.
    smin = jnp.min(jnp.where(valid, s_tile, PAD))
    smax = jnp.max(jnp.where(valid, s_tile, jnp.int32(-(2**31))))

    starts = l_row.reshape(bq, n_lt, tile_l)[:, :, 0]  # (BQ, n_lt)
    upper = jnp.max(starts, axis=0)  # M_j, nondecreasing
    lower = jnp.min(starts, axis=0)  # m_j, nondecreasing
    # last j with M_j <= smin bounds every row's start tile from below;
    # last j with m_j <= smax bounds every row's end tile from above.
    # PAD-only tiles have start = PAD and fall outside both counts.
    j_lo = jnp.maximum(jnp.sum(upper <= smin).astype(jnp.int32) - 1, 0)
    j_hi = jnp.sum(lower <= smax).astype(jnp.int32) - 1

    def body(j, hit):
        l_tile = jax.lax.dynamic_slice(l_row, (0, j * tile_l), (bq, tile_l))
        eq = (s_tile[:, :, None] == l_tile[:, None, :]) & valid[:, :, None]
        return hit | jnp.any(eq, axis=2)

    return jax.lax.fori_loop(j_lo, j_hi + 1, body, jnp.zeros((bq, ts), bool))


def _members_kernel(short_ref, long_ref, out_ref, *, tile_l: int):
    hit = _probe_hits(short_ref[...], long_ref[...], tile_l=tile_l)
    out_ref[...] = jnp.where(hit, short_ref[...], PAD)


def _members_count_kernel(short_ref, long_ref, out_ref, *, tile_l: int):
    s = pl.program_id(1)
    hit = _probe_hits(short_ref[...], long_ref[...], tile_l=tile_l)

    @pl.when(s == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += hit.sum(axis=1).astype(jnp.int32)[:, None]


def _members_call(kernel_body, out_dtype, out_cols):
    def call(short, long, block_q: int, tile_s: int, tile_l: int, interpret: bool):
        b, ls = short.shape
        _, ll = long.shape
        assert b % block_q == 0 and ls % tile_s == 0 and ll % tile_l == 0
        grid = (b // block_q, ls // tile_s)
        cols = tile_s if out_cols is None else out_cols
        return pl.pallas_call(
            functools.partial(kernel_body, tile_l=tile_l),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_q, tile_s), lambda i, s: (i, s)),
                pl.BlockSpec((block_q, ll), lambda i, s: (i, 0)),
            ],
            out_specs=pl.BlockSpec(
                (block_q, cols), (lambda i, s: (i, s)) if out_cols is None else (lambda i, s: (i, 0))
            ),
            out_shape=jax.ShapeDtypeStruct(
                (b, ls if out_cols is None else out_cols), out_dtype
            ),
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "arbitrary")
            ),
            interpret=interpret,
        )(short, long)

    return call


@functools.partial(
    jax.jit, static_argnames=("block_q", "tile_s", "tile_l", "interpret")
)
def intersect_members_kernel(
    short: jnp.ndarray,
    long: jnp.ndarray,
    block_q: int = 8,
    tile_s: int = 128,
    tile_l: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Member docs of ``short_row ∩ long_row`` per row, in place: matched
    elements keep their value, misses become PAD (compaction — sorting
    the PAD holes to the right — is the wrapper's job; rows stay sorted
    so a sort IS a stable left-compaction).  Shapes must be pre-padded
    like :func:`intersect_count_kernel`."""
    return _members_call(_members_kernel, jnp.int32, None)(
        short, long, block_q, tile_s, tile_l, interpret
    )


@functools.partial(
    jax.jit, static_argnames=("block_q", "tile_s", "tile_l", "interpret")
)
def intersect_members_count_kernel(
    short: jnp.ndarray,
    long: jnp.ndarray,
    block_q: int = 8,
    tile_s: int = 128,
    tile_l: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """|short_row ∩ long_row| per row — the count reduction of the
    members probe (same per-tile binary search, no all-pairs walk over
    non-overlapping tiles)."""
    out = _members_call(_members_count_kernel, jnp.int32, 1)(
        short, long, block_q, tile_s, tile_l, interpret
    )
    return out[:, 0]


@functools.partial(
    jax.jit, static_argnames=("block_q", "tile_s", "tile_l", "interpret")
)
def intersect_count_kernel(
    short: jnp.ndarray,
    long: jnp.ndarray,
    block_q: int = 8,
    tile_s: int = 128,
    tile_l: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """|short_row ∩ long_row| per row. Shapes must be pre-padded:
    B % block_q == 0, Ls % tile_s == 0, Ll % tile_l == 0."""
    b, ls = short.shape
    _, ll = long.shape
    assert b % block_q == 0 and ls % tile_s == 0 and ll % tile_l == 0

    grid = (b // block_q, ls // tile_s)
    out = pl.pallas_call(
        functools.partial(_kernel, tile_l=tile_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, tile_s), lambda i, s: (i, s)),
            pl.BlockSpec((block_q, ll), lambda i, s: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, 1), lambda i, s: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(short, long)
    return out[:, 0]
