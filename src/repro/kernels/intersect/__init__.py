from repro.kernels.intersect.ops import intersect_count
from repro.kernels.intersect.ref import intersect_count_ref

__all__ = ["intersect_count", "intersect_count_ref"]
