"""Public jit'd wrapper for the batched intersection kernel.

Pads ragged inputs to kernel-aligned shapes and dispatches:

* on TPU        → the Pallas kernel (Mosaic),
* elsewhere     → interpret mode when ``force_kernel`` (tests), else the
                  pure-jnp reference (production CPU path — XLA's fused
                  searchsorted is the right tool off-TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.intersect.kernel import (
    intersect_count_kernel,
    intersect_members_count_kernel,
    intersect_members_kernel,
)
from repro.kernels.intersect.ref import (
    PAD,
    intersect_count_ref,
    intersect_members_ref,
)

__all__ = ["intersect_count", "intersect_members"]


def _pad_to(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    return jnp.pad(
        x,
        ((0, rows - x.shape[0]), (0, cols - x.shape[1])),
        constant_values=PAD,
    )


def intersect_count(
    short,
    long,
    block_q: int = 8,
    tile_s: int = 128,
    tile_l: int = 128,
    force_kernel: bool = False,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-row |short ∩ long| for PAD-padded sorted int32 rows (B, *)."""
    short = jnp.asarray(short, jnp.int32)
    long = jnp.asarray(long, jnp.int32)
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_kernel):
        return intersect_count_ref(short, long)
    if interpret is None:
        interpret = not on_tpu
    b = int(np.ceil(short.shape[0] / block_q)) * block_q
    ls = int(np.ceil(short.shape[1] / tile_s)) * tile_s
    ll = int(np.ceil(long.shape[1] / tile_l)) * tile_l
    out = intersect_count_kernel(
        _pad_to(short, b, ls),
        _pad_to(long, b, ll),
        block_q=block_q,
        tile_s=tile_s,
        tile_l=tile_l,
        interpret=interpret,
    )
    return out[: short.shape[0]]


def intersect_members(
    short,
    long,
    block_q: int = 8,
    tile_s: int = 128,
    tile_l: int = 128,
    force_kernel: bool = False,
    interpret: bool | None = None,
    reduce: str = "docs",
) -> jnp.ndarray:
    """Members of ``short_row ∩ long_row`` for PAD-padded sorted int32
    rows — the pairwise select step of a k-way intersection fold.

    ``reduce``:
      * ``"docs"``  — (B, Ls) PAD-compacted member docs (survivors
        left-aligned, sorted; PAD fills the rest);
      * ``"mask"``  — (B, Ls) docs *in place*: matches keep their value,
        misses become PAD (what a masked chain stage consumes);
      * ``"count"`` — (B,) int32 |short ∩ long| through the members
        probe's count reduction.

    On TPU the Pallas kernel probes the long row's tile directory with a
    per-tile binary search; elsewhere the pure-jnp reference runs (XLA's
    fused searchsorted — the production CPU path), or the kernel in
    interpret mode when ``force_kernel`` (tests).

    Only ``long`` rows must be sorted (PAD last); ``short`` rows may
    carry PAD holes anywhere — the select step of a masked fold feeds
    its own PAD-holed output back in.
    """
    if reduce not in ("docs", "mask", "count"):
        raise ValueError(f"unknown reduce mode {reduce!r}")
    short = jnp.asarray(short, jnp.int32)
    long = jnp.asarray(long, jnp.int32)
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_kernel):
        hit = intersect_members_ref(short, long)
        if reduce == "count":
            return hit.sum(axis=1).astype(jnp.int32)
        masked = jnp.where(hit, short, PAD)
        return jnp.sort(masked, axis=1) if reduce == "docs" else masked
    if interpret is None:
        interpret = not on_tpu
    b = int(np.ceil(short.shape[0] / block_q)) * block_q
    ls = int(np.ceil(short.shape[1] / tile_s)) * tile_s
    ll = int(np.ceil(long.shape[1] / tile_l)) * tile_l
    padded_s = _pad_to(short, b, ls)
    padded_l = _pad_to(long, b, ll)
    if reduce == "count":
        out = intersect_members_count_kernel(
            padded_s,
            padded_l,
            block_q=block_q,
            tile_s=tile_s,
            tile_l=tile_l,
            interpret=interpret,
        )
        return out[: short.shape[0]]
    out = intersect_members_kernel(
        padded_s,
        padded_l,
        block_q=block_q,
        tile_s=tile_s,
        tile_l=tile_l,
        interpret=interpret,
    )[: short.shape[0], : short.shape[1]]
    return jnp.sort(out, axis=1) if reduce == "docs" else out
