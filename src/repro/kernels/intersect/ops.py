"""Public jit'd wrapper for the batched intersection kernel.

Pads ragged inputs to kernel-aligned shapes and dispatches:

* on TPU        → the Pallas kernel (Mosaic),
* elsewhere     → interpret mode when ``force_kernel`` (tests), else the
                  pure-jnp reference (production CPU path — XLA's fused
                  searchsorted is the right tool off-TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.intersect.kernel import intersect_count_kernel
from repro.kernels.intersect.ref import PAD, intersect_count_ref

__all__ = ["intersect_count"]


def _pad_to(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    return jnp.pad(
        x,
        ((0, rows - x.shape[0]), (0, cols - x.shape[1])),
        constant_values=PAD,
    )


def intersect_count(
    short,
    long,
    block_q: int = 8,
    tile_s: int = 128,
    tile_l: int = 128,
    force_kernel: bool = False,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-row |short ∩ long| for PAD-padded sorted int32 rows (B, *)."""
    short = jnp.asarray(short, jnp.int32)
    long = jnp.asarray(long, jnp.int32)
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_kernel):
        return intersect_count_ref(short, long)
    if interpret is None:
        interpret = not on_tpu
    b = int(np.ceil(short.shape[0] / block_q)) * block_q
    ls = int(np.ceil(short.shape[1] / tile_s)) * tile_s
    ll = int(np.ceil(long.shape[1] / tile_l)) * tile_l
    out = intersect_count_kernel(
        _pad_to(short, b, ls),
        _pad_to(long, b, ll),
        block_q=block_q,
        tile_s=tile_s,
        tile_l=tile_l,
        interpret=interpret,
    )
    return out[: short.shape[0]]
