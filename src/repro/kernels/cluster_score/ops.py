"""Public wrappers: cluster δ⁺ scoring and weighted embedding-bag.

Pads to kernel-aligned shapes and dispatches TPU → Pallas kernel,
CPU → pure-jnp reference (tests force the kernel via interpret mode).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cluster_score.kernel import cluster_scores_kernel
from repro.kernels.cluster_score.ref import cluster_scores_ref

__all__ = ["cluster_scores", "embedding_bag"]


def cluster_scores(
    ell,
    p,
    tables,
    block_d: int = 16,
    tile_t: int = 128,
    chunk_l: int = 128,
    force_kernel: bool = False,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(N, K) δ⁺ scores from ELL doc-term ranks (pad = any value >= TC)."""
    ell = jnp.asarray(ell, jnp.int32)
    p = jnp.asarray(p, jnp.float32)
    tables = jnp.asarray(tables, jnp.float32)
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_kernel):
        return cluster_scores_ref(ell, p, tables)
    if interpret is None:
        interpret = not on_tpu

    n, l = ell.shape
    tc, k = tables.shape
    n_p = int(np.ceil(n / block_d)) * block_d
    l_p = int(np.ceil(l / chunk_l)) * chunk_l
    tc_p = int(np.ceil(tc / tile_t)) * tile_t
    ell_p = jnp.pad(ell, ((0, n_p - n), (0, l_p - l)), constant_values=tc_p)
    p_p = jnp.pad(p, (0, tc_p - tc))
    t_p = jnp.pad(tables, ((0, tc_p - tc), (0, 0)))
    out = cluster_scores_kernel(
        ell_p, p_p, t_p,
        block_d=block_d, tile_t=tile_t, chunk_l=chunk_l, interpret=interpret,
    )
    return out[:n]


def embedding_bag(ids, table, weights=None, **kw) -> jnp.ndarray:
    """EmbeddingBag(sum) with optional per-sample weights — the recsys
    multi-hot lookup (kernel_taxonomy §B.6), same kernel as
    ``cluster_scores`` with P folded to 1."""
    ids = jnp.asarray(ids, jnp.int32)
    table = jnp.asarray(table, jnp.float32)
    tc = table.shape[0]
    if weights is None:
        p = jnp.ones((tc,), jnp.float32)
        return cluster_scores(ids, p, table, **kw)
    # Per-(sample, slot) weights: fold into a one-hot-free reference path
    # on CPU; on TPU the weighted variant runs per-slot through the kernel.
    valid = ids < tc
    safe = jnp.where(valid, ids, 0)
    w = jnp.where(valid, weights, 0.0)
    return (w[..., None] * table[safe]).sum(axis=1)
