from repro.kernels.cluster_score.ops import cluster_scores, embedding_bag
from repro.kernels.cluster_score.ref import cluster_scores_ref

__all__ = ["cluster_scores", "embedding_bag", "cluster_scores_ref"]
