"""Pallas TPU kernel: δ⁺ scoring SpMM (one-hot-tiled MXU embedding-bag).

The paper's clustering inner loop (per-document δ accumulation, C code)
re-derived for the MXU (DESIGN.md §3): rather than gathering table rows
per term occurrence (random HBM access), the term axis is processed in
tiles of TT. For each (doc block, term tile) the kernel builds the
weighted incidence tile

    W[d, t] = P[tile_base + t] · |{l : ell[d, l] == tile_base + t}|

branch-free on the VPU (one-hot equality over an L-chunk loop, chunked so
the (BD, LC, TT) bool intermediate stays in VMEM), then feeds the MXU:

    out[d, :] += W @ T_tile                     # (BD, TT) @ (TT, K)

Pad slots (ell >= TC) never match a tile and P/T are zero-padded, so
padding contributes nothing. Accumulation runs over the term-tile grid
axis (init at j == 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names this TPUCompilerParams; keep one alias for both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["cluster_scores_kernel"]


def _kernel(ell_ref, p_ref, t_ref, out_ref, *, tile_t: int, chunk_l: int):
    j = pl.program_id(1)
    ell = ell_ref[...]  # (BD, L) int32
    p = p_ref[...]  # (1, TT) float32
    tbl = t_ref[...]  # (TT, K) float32
    bd, l_pad = ell.shape

    base = j * tile_t
    local = ell - base  # matches iff in [0, TT)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, tile_t), 2)

    def body(c, w):
        chunk = jax.lax.dynamic_slice(local, (0, c * chunk_l), (bd, chunk_l))
        oh = chunk[:, :, None] == iota  # (BD, LC, TT)
        return w + oh.sum(axis=1).astype(jnp.float32)

    w = jax.lax.fori_loop(
        0, l_pad // chunk_l, body, jnp.zeros((bd, tile_t), jnp.float32)
    )
    acc = jnp.dot(w * p, tbl, preferred_element_type=jnp.float32)  # (BD, K)

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("block_d", "tile_t", "chunk_l", "interpret")
)
def cluster_scores_kernel(
    ell: jnp.ndarray,
    p: jnp.ndarray,
    tables: jnp.ndarray,
    block_d: int = 16,
    tile_t: int = 128,
    chunk_l: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """out (N, K) = weighted one-hot bag. Pre-padded shapes required:
    N % block_d == 0, L % chunk_l == 0, TC % tile_t == 0 (p/tables
    zero-padded; ell pad value >= TC)."""
    n, l_pad = ell.shape
    tc, k = tables.shape
    assert n % block_d == 0 and l_pad % chunk_l == 0 and tc % tile_t == 0
    assert p.shape == (tc,)

    grid = (n // block_d, tc // tile_t)
    return pl.pallas_call(
        functools.partial(_kernel, tile_t=tile_t, chunk_l=chunk_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_d, l_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((1, tile_t), lambda i, j: (0, j)),
            pl.BlockSpec((tile_t, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_d, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(ell, p.reshape(1, -1), tables)
