"""Pure-jnp oracle for the K-means δ⁺ scoring SpMM / weighted embedding-bag.

Contract: ``ell`` (N, L) int32 holds per-document frequent-term ranks,
padded with values >= TC (= table rows).  ``p`` (TC,) are the term
weights P[t]; ``tables`` (TC, K) are the δ⁺ columns (or an embedding
table).  Result (N, K):

    out[d, :] = Σ_l  p[ell[d, l]] · tables[ell[d, l], :]      (pad → 0)

This is exactly `scores = A @ Sᵀ` of DESIGN.md §3 in ELL layout, and also
exactly an EmbeddingBag(sum) with per-sample weights (kernel_taxonomy
§B.6/§B.11).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["cluster_scores_ref"]


@jax.jit
def cluster_scores_ref(
    ell: jnp.ndarray, p: jnp.ndarray, tables: jnp.ndarray
) -> jnp.ndarray:
    tc, k = tables.shape
    valid = ell < tc
    safe = jnp.where(valid, ell, 0)
    w = jnp.where(valid, p[safe], 0.0)  # (N, L)
    rows = tables[safe]  # (N, L, K)
    return (w[..., None] * rows).sum(axis=1)
