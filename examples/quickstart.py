"""Quickstart: cluster a corpus with SeCluD and run exact conjunctive
queries faster.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.seclud import SecludPipeline
from repro.data.corpus import CorpusSpec, synth_corpus, corpus_stats
from repro.data.query_log import synth_query_log

# 1. A corpus (synthetic stand-in for GOV2/Wikipedia: Zipf marginals,
#    latent topics) and a query log to estimate term probabilities from.
corpus = synth_corpus(CorpusSpec.forum_like(n_docs=8000, seed=0))
log = synth_query_log(corpus, n_queries=1500, seed=1)
print("corpus:", corpus_stats(corpus))

# 2. Fit: TopDown multilevel K-means on the paper's query-cost objective.
pipe = SecludPipeline(tc=3000, doc_grained_below=512)
result = pipe.fit(corpus, k=128, algo="topdown", log=log)
print(
    f"clustered into k={result.k} clusters in {result.cluster_time_s:.1f}s; "
    f"objective ψ {result.psi_single:.3g} -> {result.psi:.3g} "
    f"(theoretical speedup S_T = {result.s_t:.2f}x)"
)

# 3. Queries: identical results, less work. Three algorithms:
#    baseline Lookup / two-level cluster index (S_C) / reordered (S_R).
report = pipe.evaluate(corpus, result, log, max_queries=300)
print(
    f"measured speedups over {int(report['n_queries'])} queries: "
    f"S_T={report['S_T']:.2f} S_C={report['S_C']:.2f} S_R={report['S_R']:.2f} "
    f"(every query returned identical results — lossless)"
)

# 4. One query by hand through the cluster index.
t, u = map(int, log.queries[0])
docs, work = result.cluster_index.query(t, u)
inv = np.empty(corpus.n_docs, dtype=np.int64)
inv[result.perm] = np.arange(corpus.n_docs)
print(
    f"query ({t} AND {u}): {len(docs)} documents, "
    f"{work['total']:.0f} work units (e.g. doc ids {sorted(inv[docs])[:5]}...)"
)
