"""End-to-end LM training driver (deliverable b): a small transformer on
the synthetic token pipeline, with checkpointing, restart-resume and the
fault-tolerant Trainer loop.

    PYTHONPATH=src python examples/train_lm.py                 # ~10M params
    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --full          # ~100M params

Kill it mid-run and start again: it resumes from the latest checkpoint at
the exact batch it left off (counter-based pipeline).
"""

import argparse

import jax

from repro.data.pipeline import TokenPipeline
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    if args.full:
        cfg = T.LMConfig(
            name="demo-100m", n_layers=16, d_model=640, n_heads=10,
            n_kv_heads=10, head_dim=64, d_ff=2560, vocab=16384,
            dtype="float32", loss_chunk=64,
        )
        seq, batch = 256, 8
    else:
        cfg = T.LMConfig(
            name="demo-10m", n_layers=6, d_model=256, n_heads=4,
            n_kv_heads=4, head_dim=64, d_ff=1024, vocab=8192,
            dtype="float32", loss_chunk=64,
        )
        seq, batch = 128, 8
    print(f"model {cfg.name}: {cfg.n_params() / 1e6:.1f}M params")

    pipe = TokenPipeline(
        vocab_size=cfg.vocab, seq_len=seq, batch_per_shard=batch, seed=0
    )
    trainer = Trainer(
        loss_fn=lambda p, b: T.loss_fn(p, cfg, b),
        init_params_fn=lambda k: T.init(cfg, k),
        pipeline=pipe,
        cfg=TrainerConfig(
            total_steps=args.steps, ckpt_every=50, log_every=10,
            ckpt_dir=args.ckpt_dir,
        ),
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
    )
    params, _ = trainer.run()
    first = trainer.history[0][1] if trainer.history else float("nan")
    last = trainer.history[-1][1] if trainer.history else float("nan")
    print(f"loss: {first:.3f} -> {last:.3f} over {len(trainer.history)} steps")

    # Greedy decode a few tokens as a smoke of the serving path.
    import jax.numpy as jnp

    cache = T.init_cache(cfg, 1, 64)
    prompt = jnp.asarray([[5, 17, 42, 7]], dtype=jnp.int32)
    logits, cache = T.prefill(params, cfg, prompt, cache)
    toks = []
    for _ in range(8):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        toks.append(int(nxt[0, 0]))
        logits, cache = T.decode_step(params, cfg, nxt, cache)
    print("greedy continuation:", toks)


if __name__ == "__main__":
    main()
