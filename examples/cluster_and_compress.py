"""Appendix-A example: clustering as a compression booster.

Cluster-contiguous reordering skews posting-list gaps; adaptive codes
(Elias-γ/δ) then beat Golomb — the paper's Figure 8 effect.

    PYTHONPATH=src python examples/cluster_and_compress.py
"""

import numpy as np

from repro.core.seclud import SecludPipeline
from repro.data.corpus import CorpusSpec, synth_corpus
from repro.data.query_log import synth_query_log
from repro.index.build import build_index, permute_docs
from repro.index.compress import (
    decode_gaps,
    encode_gaps,
    gaps_of,
    index_bits_per_posting,
)

corpus = synth_corpus(CorpusSpec.forum_like(n_docs=8000, seed=0))
log = synth_query_log(corpus, n_queries=1000, seed=1)
pipe = SecludPipeline(tc=2000, doc_grained_below=512)
res = pipe.fit(corpus, k=128, algo="topdown", log=log)

idx = build_index(corpus)
rng = np.random.default_rng(0)
variants = {
    "random ids   ": permute_docs(idx, rng.permutation(corpus.n_docs)),
    "clustered ids": res.reordered_index,
}
print(f"{'ordering':16s} {'golomb':>8s} {'gamma':>8s} {'delta':>8s} {'varbyte':>8s}")
for name, vidx in variants.items():
    bits = index_bits_per_posting(vidx)
    print(
        f"{name:16s} "
        + " ".join(f"{bits[c]:8.2f}" for c in ("golomb", "gamma", "delta", "varbyte"))
    )

# Bit-exact roundtrip on one real posting list (losslessness, not just size):
t = int(np.argmax(np.diff(idx.post_ptr)))  # the longest list
post = res.reordered_index.postings(t)
g = gaps_of(post)
packed, nbits = encode_gaps(g, "delta")
assert np.array_equal(decode_gaps(packed, nbits, len(g), "delta"), g)
print(
    f"\nlongest posting list (term {t}, {len(post)} entries): "
    f"raw {32 * len(post)} bits -> Elias-delta {nbits} bits "
    f"({32 * len(post) / nbits:.1f}x), decodes losslessly ✓"
)
