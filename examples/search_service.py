"""Serving example: the distributed SeCluD search service + the recsys
retrieval pipeline with exact conjunctive pre-filtering.

    PYTHONPATH=src python examples/search_service.py
"""

import os

# Part 4 shards the engine over a device mesh; on a plain CPU host, fake
# a grid before jax initializes so the walkthrough has devices to shard.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.core.queries import ConjunctiveQueries
from repro.core.seclud import SecludPipeline
from repro.data.corpus import CorpusSpec, synth_corpus
from repro.data.query_log import synth_query_log
from repro.serve.retrieval import FilteredRetriever, items_as_corpus
from repro.serve.search_service import SearchService

# ---------------------------------------------------------------------------
# Part 1 — full-text search service
# ---------------------------------------------------------------------------
corpus = synth_corpus(CorpusSpec.forum_like(n_docs=6000, seed=0))
log = synth_query_log(corpus, n_queries=800, seed=1)
pipe = SecludPipeline(tc=2000, doc_grained_below=512)
res = pipe.fit(corpus, k=64, algo="topdown", log=log)
svc = SearchService(res)

queries = log.queries[:64]
counts, work = svc.serve_counts(queries)
print(f"host path: {len(queries)} queries, total work {work['work']:.0f}, "
      f"mean hits {counts.mean():.1f}")

packed = svc.pack(queries)
dev_counts = np.asarray(SearchService.device_counts(packed))
assert np.array_equal(dev_counts, counts), "device path must be lossless"
print(f"device path: {packed.short.shape[0]} cluster-segment rows "
      f"(padded {packed.short.shape}), counts agree ✓")

# The device-resident engine: fit() uploaded the index once
# (res.device_index); every batch now runs the whole cost-ordered k-way
# chain as ONE fused jit call against that persistent copy — only the
# counts come back to host.
di = svc.device_index
print(f"device index: {di.nbytes / 1e6:.2f} MB resident "
      f"(uploaded once at fit, reused per batch)")
for batch in (queries, log.queries[64:256]):
    eng_counts, eng_info = svc.serve_counts_device(batch)
    host_counts, _ = svc.serve_counts(batch)
    assert np.array_equal(eng_counts, host_counts), "fused fold must be exact"
print(f"fused fold: {eng_info['n_kernel_calls']:.0f} dispatch/batch, "
      f"pad overhead {eng_info['padding_overhead']:.2f}x, "
      f"occupancy {eng_info['occupancy']:.2f} — counts agree ✓")

# ---------------------------------------------------------------------------
# Part 2 — recsys retrieval with SeCluD attribute pre-filtering
# ---------------------------------------------------------------------------
rng = np.random.default_rng(0)
n_items, n_attrs = 20_000, 2_000
# Items carry sparse attribute sets (Zipf-ish popularity).
attr_p = (np.arange(1, n_attrs + 1) ** -1.1)
attr_p /= attr_p.sum()
item_attrs = [
    np.unique(rng.choice(n_attrs, size=rng.integers(3, 20), p=attr_p))
    for _ in range(n_items)
]
items = items_as_corpus(item_attrs, n_attrs)
retriever = FilteredRetriever(items, k=32, tc=500)

# Dense scorer: any model head works; here a random embedding dot product.
emb = rng.standard_normal((n_items, 16)).astype(np.float32)
user = rng.standard_normal((1, 16)).astype(np.float32)
score_fn = lambda cand: user @ emb[cand].T

a, b = 3, 17  # "category=a AND in_stock=b"
ids, scores, report = retriever.retrieve(score_fn, a, b, top_k=5)
print(
    f"retrieval: {report.n_candidates} candidates -> {report.n_filtered} "
    f"after exact conjunctive filter (work {report.filter_work:.0f} vs "
    f"unclustered {report.baseline_work:.0f}, speedup {report.speedup:.2f}x)"
)
print("top items:", ids.tolist(), "scores:", np.round(scores, 3).tolist())

# The SAP-HANA scenario the paper cites is a 3-term conjunction:
# "in_stock AND category=a AND brand=c".  Same engine, cost-ordered plan.
c = 8
ids3, scores3, report3 = retriever.retrieve(score_fn, a, b, c, top_k=5)
brute = [
    i for i, s in enumerate(item_attrs) if a in s and b in s and c in s
]
assert report3.n_filtered == len(brute), "3-term filter must stay exact"
print(
    f"3-term filter ({a} AND {b} AND {c}): {report3.n_filtered} items, "
    f"work {report3.filter_work:.0f} vs unclustered "
    f"{report3.baseline_work:.0f} ({report3.speedup:.2f}x); "
    f"top: {ids3.tolist()}"
)

# Ragged query batches (mixed arity) go through the same serving path.
ragged = ConjunctiveQueries.from_lists(
    [q.tolist() for q in log.queries[:4]] + [[3, 17, 8], [3]]
)
counts, work = svc.serve_counts(ragged)
print(f"ragged batch (arities {ragged.arities.tolist()}): counts {counts.tolist()}")

# ---------------------------------------------------------------------------
# Part 3 — a 3-level hierarchy: postings → clusters → super-clusters
# ---------------------------------------------------------------------------
# Depth is a parameter: fit(levels=3) recursively clusters the clusters,
# and the top level doubles as a machine-level router.  Exactness is the
# defining invariant — every depth returns the identical result sets.
res3 = pipe.fit(corpus, k=64, algo="topdown", log=log, levels=3)
hier = res3.hier_index
print(
    f"3-level index: {hier.levels[0].k} super-clusters over "
    f"{hier.k} clusters over {corpus.n_docs} docs "
    f"(psi per level: {[round(p, 1) for p in res3.psi_levels]})"
)
svc3 = SearchService(res3)
counts3, work3 = svc3.serve_counts(queries)
counts_l2, _ = svc.serve_counts(queries)
assert np.array_equal(counts3, counts_l2), "every depth must return identical counts"
docs3, qwork = hier.query(*log.queries[0])
print(
    f"3-level descent: {len(docs3)} hits, work {qwork['total']:.0f} "
    f"(level_0 {qwork['level_0']:.0f} + level_1 {qwork['level_1']:.0f} "
    f"+ postings {qwork['probes'] + qwork['scanned']:.0f})"
)
# Pin each super-cluster's device rows to a contiguous run (one mesh
# shard under contiguous row sharding): counts are unchanged.
pinned = svc3.pack(queries, pin_top=True)
dev3 = np.asarray(SearchService.device_counts(pinned))
assert np.array_equal(dev3, counts3), "pinned device path must be lossless"
print(
    f"pinned pack: {pinned.row_top.size} rows grouped into "
    f"{len(np.unique(pinned.row_top))} top-level shards, counts agree ✓"
)

# ---------------------------------------------------------------------------
# Part 4 — multi-shard serving: the mesh-sharded engine + failover
# ---------------------------------------------------------------------------
# The top hierarchy level is the unit of machine-level distribution:
# enable_sharded partitions the corpus into contiguous top-cluster
# groups balanced by posting mass, uploads one per-shard postings slice
# per device, and serves every batch as ONE shard_map dispatch with a
# single psum combining the per-shard counts.  Results stay bit-exact.
import jax

svc4 = SearchService(res3)
n_shards = min(4, len(jax.devices()))
svc4.enable_sharded(n_shards=n_shards, strikes_to_evict=2)
counts_sh, info_sh = svc4.serve_counts_device(queries)
assert np.array_equal(counts_sh, counts3), "sharded serving must be exact"
print(
    f"sharded serving: {svc4.n_shards} shards, "
    f"{info_sh['shards_touched']:.0f} touched by this batch, "
    f"load balance {info_sh['load_balance']:.2f}, "
    f"aggregate throughput {info_sh['agg_throughput']:.2f}x — counts agree ✓"
)

# Each shard's host-side view answers the same queries restricted to its
# doc range — the partition a multi-machine deployment hands each box.
bounds, views = res3.shard_slices(n_shards)
busy_q = queries[int(np.argmax(counts3))]  # the batch's busiest query
per_shard, _ = zip(*(v.query(*busy_q) for v in views), strict=True)
full, _ = hier.query(*busy_q)
assert np.array_equal(np.sort(np.concatenate(per_shard)), np.sort(full))
print(f"shard views: top-cluster bounds {bounds.tolist()}, "
      f"per-shard hits {[len(p) for p in per_shard]} union to the global result ✓")

# Failover: report per-step shard times; a persistently slow shard is
# evicted, the mesh rebuilt one device smaller, and the survivors absorb
# its top clusters.  Serving continues bit-identically.
if svc4.n_shards > 1:
    times = np.ones(svc4.n_shards)
    times[-1] = 25.0  # the last shard misses its deadline, twice
    svc4.record_shard_times(times)
    _verdicts, remeshed = svc4.record_shard_times(times)
    assert remeshed, "two strikes must evict"
    counts_fo, info_fo = svc4.serve_counts_device(queries)
    assert np.array_equal(counts_fo, counts3), "failover must stay exact"
    print(
        f"failover: shard evicted, remeshed to {svc4.n_shards} shards "
        f"(epoch {svc4._elastic.epoch}), counts still agree ✓"
    )

# ---------------------------------------------------------------------------
# Part 5 — the async serving loop: open-loop traffic under a latency SLO
# ---------------------------------------------------------------------------
# A search tier doesn't see batches; it sees an arrival process.  The
# deadline batcher accumulates requests until the oldest has waited
# deadline_s (or max_batch are pending), dispatches each sealed batch as
# one fused engine call, and reports latency percentiles against the
# SLO.  The shape-grid prewarm compiles every executable the trace will
# need at startup — steady-state serving never traces.
import asyncio

from repro.core.device_engine import prewarm
from repro.serve.loop import ServeConfig, plan_batches
from repro.serve.replay import replay

# 30 seconds of Zipf traffic at 100 QPS: arrival timestamps ride along
# on the log without changing its bit-exact query stream.
traffic = synth_query_log(
    corpus, n_queries=3000, seed=2,
    arity=(1, 2, 3), arity_weights=(0.2, 0.6, 0.2),
    arrival_qps=100.0,
)
cfg = ServeConfig(max_batch=32, deadline_s=0.002)
batches = plan_batches(traffic.arrivals, cfg.max_batch, cfg.deadline_s)
pw = prewarm(
    svc.query_index, traffic.queries, batches=batches,
    dindex=svc.device_index,
)
print(
    f"prewarm: {pw['n_batches']} planned windows -> {pw['n_keys']} distinct "
    f"shape keys, {pw['n_compiles']} compiles (startup cost, paid once)"
)

rep = replay(svc, traffic, config=cfg)  # sealed: deterministic composition
assert rep.jit_compiles == 0, "prewarm must cover the whole replay"
direct, _ = svc.serve_counts_device(traffic.queries)
assert np.array_equal(rep.counts, direct), "batching must not change results"
s = rep.summary()
hist = " ".join(f"{k}x{v}" for k, v in sorted(s["batch_hist"].items()))
print(
    f"replay: {s['n_requests']} requests in {s['duration_s']:.1f}s "
    f"({s['qps_sustained']:.0f} QPS sustained of {s['qps_offered']:.0f} "
    f"offered), p50 {s['p50_ms']:.2f}ms / p99 {s['p99_ms']:.2f}ms / "
    f"p999 {s['p999_ms']:.2f}ms"
)
print(
    f"batching: mean {s['mean_batch']:.1f}/batch "
    f"(occupancy {s['occupancy']:.2f}), hist [{hist}], "
    f"steady-state jit compiles {s['jit_compiles']} ✓"
)

# The same policy live: an asyncio loop serving concurrent submitters.
# Warm the burst windows this demo will dispatch — live traffic should
# hit the same compiled grid the replay proved out.
cq = traffic.as_conjunctive()


async def live_demo():
    loop = svc.serve_async(max_batch=32, deadline_s=0.002)
    loop.prewarm(traffic.queries, batches=[(0, 32), (32, 64)])
    await loop.start()
    counts = await asyncio.gather(
        *(loop.submit(cq.terms(r)) for r in range(64))
    )
    await loop.stop()
    return np.asarray(counts), loop.stats


live_counts, stats = asyncio.run(live_demo())
assert np.array_equal(live_counts, direct[:64]), "live loop must be exact"
print(
    f"live loop: 64 concurrent submits -> {stats.n_batches} batches "
    f"(sizes {stats.batch_sizes}), p99 {stats.percentile_ms(99):.2f}ms, "
    f"counts agree ✓"
)

# ---------------------------------------------------------------------------
# Part 6 — chaos replay: kill a shard mid-run, stay exact
# ---------------------------------------------------------------------------
# A FaultSchedule is a frozen description of what goes wrong and when, in
# sealed-batch ordinals: here shard 0's device dies at batch 2 and stays
# dead until the mesh shrinks.  The injector fires INSIDE the real
# sharded dispatch (the engine's fault_hook) — no monkeypatching — and
# the resilience ladder handles it: retries strike the dead shard into
# record_shard_times, the ElasticMesh evicts it and re-partitions, and
# the sealed batch redispatches on the survivors.  Every response stays
# bit-identical to the healthy run.
from repro.serve.faults import FaultSchedule
from repro.serve.resilience import ResilienceConfig

svc6 = SearchService(res3)
svc6.enable_sharded(n_shards=n_shards, strikes_to_evict=3)
truth, _ = svc6.serve_counts(traffic.as_conjunctive())  # healthy host truth
shards_before, epoch_before = svc6.n_shards, svc6._elastic.epoch

rc = ResilienceConfig(dispatch_timeout_s=1e9)  # virtual clock: no timeouts
rep6 = replay(
    svc6, traffic, config=cfg, mode="sealed",
    faults=FaultSchedule.shard_loss(0, at=2), resilience=rc,
)
levels = rep6.stats.batch_levels
assert levels[2] == "remesh", "the loss batch must recover via eviction"
assert svc6.n_shards == shards_before - 1, "the dead shard must be evicted"
assert np.array_equal(rep6.counts, truth), "chaos must never change answers"
degraded = [i for i, lv in enumerate(levels) if lv != "device"]
print(
    f"chaos replay: shard 0 died at batch 2 -> served at rung "
    f"'{levels[2]}' ({rep6.stats.batch_attempts[2]} attempts), mesh "
    f"{shards_before} -> {svc6.n_shards} shards "
    f"(epoch {epoch_before} -> {svc6._elastic.epoch})"
)
print(
    f"recovery: degraded window {len(degraded)} batch(es) of "
    f"{len(levels)}, every response bit-identical to the healthy run ✓"
)
