import numpy as np
import pytest

from repro.core.objective import (
    assignment_scores,
    cluster_counts,
    delta_add_tables,
    delta_remove_tables,
    frequent_term_view,
    psi_from_counts,
    query_set_cost,
)


def _brute_psi(counts, p):
    k, tc = counts.shape
    total = 0.0
    for i in range(k):
        for t in range(tc):
            for u in range(t + 1, tc):
                total += p[t] * p[u] * min(counts[i, t], counts[i, u])
    return total


def _brute_add_table(counts, p):
    k, tc = counts.shape
    out = np.zeros((k, tc))
    for j in range(k):
        for t in range(tc):
            out[j, t] = sum(
                p[u] for u in range(tc) if u != t and counts[j, u] > counts[j, t]
            )
    return out


def _brute_remove_table(counts, p):
    k, tc = counts.shape
    out = np.zeros((k, tc))
    for j in range(k):
        for t in range(tc):
            out[j, t] = sum(
                p[u] for u in range(tc) if u != t and counts[j, u] >= counts[j, t]
            )
    return out


@pytest.fixture(scope="module")
def tiny():
    rng = np.random.default_rng(5)
    counts = rng.integers(0, 6, size=(3, 12))
    p = rng.random(12)
    p /= p.sum()
    return counts, p


def test_psi_matches_bruteforce(tiny):
    counts, p = tiny
    assert np.isclose(psi_from_counts(counts, p), _brute_psi(counts, p), rtol=1e-12)


def test_psi_with_ties():
    # All-equal counts: every min is the same value; ties must not break ψ.
    counts = np.full((2, 5), 3)
    p = np.full(5, 0.2)
    want = _brute_psi(counts, p)
    assert np.isclose(psi_from_counts(counts, p), want, rtol=1e-12)


def test_delta_add_table_exact(tiny):
    counts, p = tiny
    got = delta_add_tables(counts, p)
    want = _brute_add_table(counts, p)
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_delta_remove_table_exact(tiny):
    counts, p = tiny
    got = delta_remove_tables(counts, p)
    want = _brute_remove_table(counts, p)
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_delta_is_psi_difference(tiny):
    """δ_j⁺(t) must equal ψ(counts + e_jt) − ψ(counts) — the paper's
    defining identity (§3.2)."""
    counts, p = tiny
    tables = delta_add_tables(counts, p)
    psi0 = psi_from_counts(counts, p)
    for j in range(counts.shape[0]):
        for t in range(counts.shape[1]):
            c2 = counts.copy()
            c2[j, t] += 1
            dpsi = psi_from_counts(c2, p) - psi0
            assert np.isclose(dpsi, p[t] * tables[j, t], rtol=1e-9, atol=1e-12), (
                f"mismatch at j={j} t={t}"
            )


def test_delta_remove_is_psi_difference(tiny):
    counts, p = tiny
    counts = counts + 1  # ensure removable
    tables = delta_remove_tables(counts, p)
    psi0 = psi_from_counts(counts, p)
    for j in range(counts.shape[0]):
        for t in range(counts.shape[1]):
            c2 = counts.copy()
            c2[j, t] -= 1
            dpsi = psi0 - psi_from_counts(c2, p)
            assert np.isclose(dpsi, p[t] * tables[j, t], rtol=1e-9, atol=1e-12)


def test_view_and_counts(small_corpus, small_p, small_view):
    v = small_view
    assert v.tc == 800
    # rank_of_term inverse relationship
    for r in (0, 5, 700):
        assert v.rank_of_term[v.term_of_rank[r]] == r
    # P is descending in rank
    assert np.all(np.diff(v.p_freq) <= 1e-15)
    assign = np.arange(v.n_docs) % 4
    counts = cluster_counts(v, assign, 4)
    assert counts.sum() == v.mat.nnz
    # column sums = total df among frequent terms
    df = small_corpus.term_doc_freq()[v.term_of_rank]
    np.testing.assert_array_equal(counts.sum(axis=0), df)


def test_assignment_scores_equals_edge_sum(small_view):
    v = small_view
    k = 4
    rng = np.random.default_rng(0)
    tables = rng.random((k, v.tc))
    scores = assignment_scores(v, tables)
    # brute per-doc for a few docs
    indptr, indices = v.mat.indptr, v.mat.indices
    for d in (0, 17, 400):
        ranks = indices[indptr[d] : indptr[d + 1]]
        want = (v.p_freq[ranks][None, :] * tables[:, ranks]).sum(axis=1)
        np.testing.assert_allclose(scores[d], want, rtol=1e-10)


def test_query_set_cost_single_vs_clustered(small_corpus, small_log):
    q = small_log.queries[:50]
    base = query_set_cost(small_corpus, None, 1, q)
    assign = np.random.default_rng(1).integers(0, 8, small_corpus.n_docs)
    clustered = query_set_cost(small_corpus, assign, 8, q)
    # min is superadditive: Σ_i min(x_i, y_i) <= min(Σ_i x_i, Σ_i y_i),
    # so ANY clustering is at least as cheap as the single-cluster case
    # under the Phi = min model — the paper's Section-1 example.
    assert clustered <= base + 1e-9
    assert base > 0
