"""End-to-end behaviour of the paper's system: corpus -> clustering ->
three query algorithms -> serving -> retrieval, losslessness throughout,
plus the adaptive symmetric Lookup (paper §6 future work)."""

import numpy as np

from repro.core.seclud import SecludPipeline
from repro.index.lookup import adaptive_intersect, lookup_work
from repro.serve.search_service import SearchService


def test_end_to_end_system(small_corpus, small_log):
    pipe = SecludPipeline(tc=800, doc_grained_below=256, seed=0)
    res = pipe.fit(small_corpus, k=10, algo="topdown", log=small_log)
    report = pipe.evaluate(small_corpus, res, small_log, max_queries=60)
    assert report["S_T"] >= 1.0 - 1e-9  # clustering never hurts psi
    # Serving returns the same counts as the work-metric path.
    svc = SearchService(res)
    q = small_log.queries[:16]
    counts, _ = svc.serve_counts(q)
    dev = np.asarray(SearchService.device_counts(svc.pack(q)))
    np.testing.assert_array_equal(counts, dev)


def test_adaptive_lookup_exact_and_cheap(rng):
    universe = 1 << 14
    for trial in range(10):
        r = np.random.default_rng(trial)
        # Skewed lists (the clustered regime the adaptation targets).
        lo1, lo2 = r.integers(0, universe // 2, 2)
        a = np.unique(r.integers(lo1, lo1 + 2000, 300)).astype(np.int32)
        b = np.unique(r.integers(lo2, lo2 + 4000, 1500)).astype(np.int32)
        want = np.intersect1d(a, b)
        got, w_ad = adaptive_intersect(a, b, universe)
        assert np.array_equal(got, want)
        _, w_fix = lookup_work(a, b, universe)
        # Never dramatically worse than the one-directional lookup.
        assert w_ad["total"] <= 2 * w_fix["total"] + 16
