"""The REPRO_DEBUG runtime head: validate() passes on every structure
the engine actually builds, rejects corrupted copies, and stays inert
(zero work) when debug mode is off."""

import dataclasses

import numpy as np
import pytest

from repro.analysis.runtime import debug_enabled, force_debug, maybe_validate
from repro.core.batched_query import plan_segment_pairs
from repro.core.cluster_index import build_cluster_index
from repro.core.device_engine import (
    device_index,
    shard_mesh,
    sharded_device_index,
)
from repro.core.queries import ConjunctiveQueries
from repro.core.reorder import cluster_ranges, reorder_permutation
from repro.data.corpus import Corpus
from repro.index.build import build_index, permute_docs


@pytest.fixture(scope="module")
def cidx():
    rng = np.random.default_rng(11)
    n_docs, n_terms, k = 260, 110, 7
    rows, ptr = [], [0]
    for _ in range(n_docs):
        r = np.unique(rng.integers(0, n_terms, 16))
        rows.append(r)
        ptr.append(ptr[-1] + len(r))
    corpus = Corpus(
        doc_ptr=np.asarray(ptr, np.int64),
        doc_terms=np.concatenate(rows).astype(np.int32),
        n_terms=n_terms,
    )
    assign = rng.integers(0, k, n_docs)
    perm = reorder_permutation(assign, k)
    ranges = cluster_ranges(assign, k)
    reordered = permute_docs(build_index(corpus), perm)
    return build_cluster_index(reordered, ranges)


@pytest.fixture(scope="module")
def plan(cidx):
    rng = np.random.default_rng(12)
    lists = [
        rng.integers(0, 110, int(rng.integers(1, 5))).tolist() for _ in range(30)
    ]
    return plan_segment_pairs(cidx, ConjunctiveQueries.from_lists(lists))


def test_debug_switch(monkeypatch):
    monkeypatch.delenv("REPRO_DEBUG", raising=False)
    assert not debug_enabled()
    monkeypatch.setenv("REPRO_DEBUG", "1")
    assert debug_enabled()
    monkeypatch.setenv("REPRO_DEBUG", "0")
    assert not debug_enabled()
    with force_debug(True):
        assert debug_enabled()  # override beats the env
        with force_debug(False):
            assert not debug_enabled()
        assert debug_enabled()


def test_maybe_validate_is_inert_when_off():
    class Bomb:
        def validate(self):  # must never run with debug off
            raise AssertionError("validate ran with REPRO_DEBUG off")

    with force_debug(False):
        b = Bomb()
        assert maybe_validate(b) is b
    with force_debug(True), pytest.raises(AssertionError):
        maybe_validate(Bomb())


def test_real_structures_validate_clean(cidx, plan):
    hidx = cidx.as_hier()
    with force_debug(True):
        maybe_validate(hidx)
        maybe_validate(plan)
        maybe_validate(device_index(cidx))
        maybe_validate(sharded_device_index(cidx, mesh=shard_mesh(4)))


def test_hier_index_rejects_corruption(cidx):
    hidx = cidx.as_hier()
    bad_ptr = hidx.index.post_ptr.copy()
    bad_ptr[1] = bad_ptr[-1] + 5  # not a CSR any more
    bad = dataclasses.replace(hidx, index=dataclasses.replace(hidx.index, post_ptr=bad_ptr))
    with pytest.raises(ValueError, match="post_ptr"):
        bad.validate()
    lev = hidx.levels[0]
    bad_ranges = lev.ranges.copy()
    if len(bad_ranges) > 2:
        bad_ranges[1], bad_ranges[2] = bad_ranges[2], bad_ranges[1] + 1
    bad = dataclasses.replace(hidx, levels=(dataclasses.replace(lev, ranges=bad_ranges),) + hidx.levels[1:])
    with pytest.raises(ValueError):
        bad.validate()


def test_segment_plan_rejects_corruption(plan):
    bad = dataclasses.replace(plan, arity=plan.arity + 1)  # breaks the CSR
    with pytest.raises(ValueError):
        bad.validate()
    bad_len = plan.seg_len.copy()
    if len(bad_len):
        bad_len[0] = -3
    bad = dataclasses.replace(plan, seg_len=bad_len)
    with pytest.raises(ValueError):
        bad.validate()


def test_device_index_rejects_corruption(cidx):
    di = device_index(cidx)
    bad = dataclasses.replace(di, n_docs=1)  # postings now out of range
    with pytest.raises(ValueError, match="doc ids"):
        bad.validate()
    bad = dataclasses.replace(di, search_iters=0)
    with pytest.raises(ValueError):
        bad.validate()


def test_sharded_index_rejects_corruption(cidx):
    sidx = sharded_device_index(cidx, mesh=shard_mesh(4))
    bad_counts = sidx.shard_counts.copy()
    bad_counts[0] += 1  # partition no longer exact
    bad = dataclasses.replace(sidx, shard_counts=bad_counts)
    with pytest.raises(ValueError):
        bad.validate()
    bad_bounds = sidx.doc_bounds.copy()
    bad_bounds[1] = bad_bounds[-1] + 1
    bad = dataclasses.replace(sidx, doc_bounds=bad_bounds)
    with pytest.raises(ValueError):
        bad.validate()


def test_build_paths_validate_under_debug(cidx):
    """The builders call maybe_validate on their own results — with the
    flag forced on, a full build + upload round-trip must stay clean."""
    with force_debug(True):
        hidx = cidx.as_hier()
        rng = np.random.default_rng(1)
        lists = [rng.integers(0, 110, 3).tolist() for _ in range(10)]
        cq = ConjunctiveQueries.from_lists(lists)
        plan_segment_pairs(hidx, cq)  # validated on return
        device_index(cidx)
        sharded_device_index(cidx, mesh=shard_mesh(2))
