import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or fallback

from repro.index.intersect import (
    COST_MODELS,
    intersect_gallop,
    intersect_merge,
    intersect_searchsorted,
    pair_cost,
)
from repro.index.lookup import bucketize, lookup_intersect, lookup_work


def _sorted_unique(rng, n, universe):
    return np.sort(rng.choice(universe, size=min(n, universe), replace=False)).astype(
        np.int32
    )


@pytest.mark.parametrize("na,nb", [(0, 10), (10, 0), (5, 5), (17, 301), (256, 256)])
def test_intersections_agree(rng, na, nb):
    a = _sorted_unique(rng, na, 1000)
    b = _sorted_unique(rng, nb, 1000)
    want = np.intersect1d(a, b)
    r1, _ = intersect_merge(a, b)
    r2, _ = intersect_searchsorted(a, b)
    r3, _ = intersect_gallop(a, b)
    r4, _ = lookup_work(a, b, universe=1000)
    assert np.array_equal(np.sort(r1), want)
    assert np.array_equal(np.sort(r2), want)
    assert np.array_equal(np.sort(r3), want)
    assert np.array_equal(np.sort(r4), want)


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_intersections_property(data):
    universe = data.draw(st.integers(8, 2000))
    a = data.draw(
        st.lists(st.integers(0, universe - 1), max_size=200, unique=True)
    )
    b = data.draw(
        st.lists(st.integers(0, universe - 1), max_size=200, unique=True)
    )
    a = np.sort(np.asarray(a, dtype=np.int32))
    b = np.sort(np.asarray(b, dtype=np.int32))
    want = np.intersect1d(a, b)
    for fn in (intersect_merge, intersect_searchsorted, intersect_gallop):
        got, work = fn(a, b)
        assert np.array_equal(np.sort(got), want)
        assert work >= 0
    got, work = lookup_work(a, b, universe=universe)
    assert np.array_equal(np.sort(got), want)
    # Lookup work is bounded: <= probes + |long list| scan-everything.
    assert work["scanned"] <= max(len(a), len(b)) + work["probes"]


def test_cost_models_basic():
    assert pair_cost(3, 100, "lookup") == 3
    assert pair_cost(3, 100, "merge") == 103
    assert pair_cost(0, 100, "comparison") == 0
    # min dominates: comparison >= min when lists differ a lot
    assert pair_cost(4, 1024, "comparison") >= 4
    for name in COST_MODELS:
        v = pair_cost(np.array([0, 1, 7]), np.array([5, 5, 5]), name)
        assert v.shape == (3,)
        assert np.all(v >= 0)


def test_lookup_resumable_scan_monotone(rng):
    """Resumable accounting never exceeds restart-from-bucket-start."""
    universe = 4096
    b = _sorted_unique(rng, 1024, universe)
    a = _sorted_unique(rng, 128, universe)
    bl = bucketize(b, universe)
    _, w = lookup_intersect(a, bl)
    # naive upper bound: every probe scans its full bucket
    occ = np.diff(bl.dir_ptr)
    assert w["scanned"] <= occ.max() * len(a)


def test_bucketize_directory_exact(rng):
    universe = 1 << 12
    vals = _sorted_unique(rng, 700, universe)
    bl = bucketize(vals, universe, bucket_size=16)
    # every bucket slice contains exactly the values in its range
    for b in range(0, len(bl.dir_ptr) - 1, 13):
        seg = bl.bucket(b)
        lo_v, hi_v = b << bl.shift, (b + 1) << bl.shift
        want = vals[(vals >= lo_v) & (vals < hi_v)]
        assert np.array_equal(seg, want)


def test_skewed_input_cheaper_than_uniform(rng):
    """The [14] observation the paper exploits: clustered (skewed) doc ids
    make Lookup cheaper than uniformly random ids."""
    universe = 1 << 14
    # Both lists concentrated in disjoint + small overlap regions.
    a_skew = np.sort(rng.choice(2048, 400, replace=False)).astype(np.int32)
    b_skew = np.sort(
        np.concatenate(
            [
                rng.choice(2048, 200, replace=False),
                8192 + rng.choice(2048, 1800, replace=False),
            ]
        )
    ).astype(np.int32)
    # Same lengths, uniform ids.
    a_uni = _sorted_unique(rng, 400, universe)
    b_uni = _sorted_unique(rng, 2000, universe)
    _, w_skew = lookup_work(a_skew, b_skew, universe)
    _, w_uni = lookup_work(a_uni, b_uni, universe)
    assert w_skew["total"] < w_uni["total"]
