import numpy as np
import pytest

from repro.core.kmeans import document_grained_pass, kmeans
from repro.core.multilevel import multilevel_cluster
from repro.core.objective import cluster_counts, psi_from_counts
from repro.core.topdown import topdown_cluster


def test_kmeans_round_based_improves(small_view):
    rng = np.random.default_rng(0)
    init = rng.integers(0, 8, small_view.n_docs)
    counts0 = cluster_counts(small_view, init, 8)
    psi0 = psi_from_counts(counts0, small_view.p_freq)
    res = kmeans(small_view, 8, init_assign=init, doc_grained_below=0)
    assert res.psi <= psi0
    assert res.assign.shape == (small_view.n_docs,)
    assert res.assign.min() >= 0 and res.assign.max() < 8
    # reported psi matches recomputation
    counts = cluster_counts(small_view, res.assign, 8)
    assert np.isclose(psi_from_counts(counts, small_view.p_freq), res.psi)


def test_kmeans_psi_history_monotone(small_view):
    res = kmeans(small_view, 6, doc_grained_below=0, seed=2)
    h = res.psi_history
    # Accepted iterations are non-increasing (last entry may be the
    # rejected proposal).
    assert all(h[i + 1] <= h[i] + 1e-9 for i in range(len(h) - 2))


def test_document_grained_improves(small_view):
    sub = small_view.subset(np.arange(400))
    rng = np.random.default_rng(1)
    init = rng.integers(0, 5, sub.n_docs)
    counts0 = cluster_counts(sub, init, 5)
    psi0 = psi_from_counts(counts0, sub.p_freq)
    res = document_grained_pass(sub, 5, init, max_passes=3)
    assert res.psi <= psi0 + 1e-9
    counts = cluster_counts(sub, res.assign, 5)
    assert np.isclose(psi_from_counts(counts, sub.p_freq), res.psi, rtol=1e-9)


def test_document_grained_beats_or_ties_rounds(small_view):
    """Doc-grained should not oscillate on small inputs (paper §3.2)."""
    sub = small_view.subset(np.arange(300))
    init = np.arange(300) % 4
    r_doc = document_grained_pass(sub, 4, init.copy(), max_passes=5)
    r_rnd = kmeans(sub, 4, init_assign=init.copy(), doc_grained_below=0, max_iters=5)
    assert r_doc.psi <= r_rnd.psi * 1.05  # at least comparable


def test_no_empty_clusters(small_view):
    res = kmeans(small_view, 16, doc_grained_below=0, seed=3)
    sizes = np.bincount(res.assign, minlength=16)
    assert (sizes > 0).all()


def test_multilevel_runs_and_improves(small_view):
    res = multilevel_cluster(small_view, 8, doc_grained_below=256, seed=0)
    rng = np.random.default_rng(0)
    rand = rng.integers(0, 8, small_view.n_docs)
    psi_rand = psi_from_counts(
        cluster_counts(small_view, rand, 8), small_view.p_freq
    )
    assert res.psi < psi_rand


def test_topdown_cluster_count_band(small_view):
    for k in (8, 32):
        res = topdown_cluster(small_view, k, doc_grained_below=256, seed=0)
        assert k <= res.k_actual <= 2 * k + 1
        sizes = np.bincount(res.assign, minlength=res.k_actual)
        assert (sizes > 0).all()
        # Balancing side effect: max cluster is within a small factor of
        # the ideal size (paper: "this approach balances cluster sizes").
        assert sizes.max() <= max(4 * small_view.n_docs / k, 8)


def test_topdown_better_than_random(small_view):
    res = topdown_cluster(small_view, 16, doc_grained_below=256, seed=1)
    k = res.k_actual
    rng = np.random.default_rng(0)
    rand = rng.integers(0, k, small_view.n_docs)
    psi_td = psi_from_counts(
        cluster_counts(small_view, res.assign, k), small_view.p_freq
    )
    psi_rand = psi_from_counts(
        cluster_counts(small_view, rand, k), small_view.p_freq
    )
    assert psi_td < psi_rand
