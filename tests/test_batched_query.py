"""Batched two-level engine: equivalence, work accounting, edge cases.

The contract under test: on any corpus, for any (n, 2) query batch,

    ClusterIndex.query  ≡  query_all_clusters  ≡  brute np.intersect1d
                        ≡  batched_query (docs + work)  ≡  batched_counts

including empty posting lists, k = 1 (single cluster), and terms absent
from the cluster index.
"""

import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis, or fallback

from repro.core.batched_query import (
    batched_counts,
    batched_lookup,
    batched_query,
    gather_padded,
    plan_segment_pairs,
    pow2_buckets,
)
from repro.core.cluster_index import build_cluster_index
from repro.core.reorder import cluster_ranges, reorder_permutation
from repro.data.corpus import Corpus
from repro.index.build import build_index, permute_docs
from repro.index.lookup import bucketize, lookup_intersect


def _random_setup(rng, n_docs, n_terms, k, mean_len=12):
    """A random CSR corpus (possibly with empty posting lists) and its
    reordered cluster index under a random assignment."""
    doc_lens = rng.integers(1, 2 * mean_len, n_docs)
    rows = []
    ptr = [0]
    for d in range(n_docs):
        r = np.unique(rng.integers(0, n_terms, doc_lens[d]))
        rows.append(r)
        ptr.append(ptr[-1] + len(r))
    corpus = Corpus(
        doc_ptr=np.asarray(ptr, np.int64),
        doc_terms=np.concatenate(rows).astype(np.int32),
        n_terms=n_terms,
    )
    assign = rng.integers(0, k, n_docs)
    assign[rng.integers(0, n_docs)] = k - 1  # keep cluster k-1 non-empty
    perm = reorder_permutation(assign, k)
    ranges = cluster_ranges(assign, k)
    index = build_index(corpus)
    reordered = permute_docs(index, perm)
    cidx = build_cluster_index(reordered, ranges)
    return index, reordered, cidx, perm


def _assert_engine_matches_loop(index, cidx, perm, queries):
    """The full equivalence chain for one query batch."""
    inv = np.empty(len(perm), np.int64)
    inv[perm] = np.arange(len(perm))
    ptr, docs, work = batched_query(cidx, queries)
    counts, _ = batched_counts(cidx, queries)
    assert np.array_equal(counts, np.diff(ptr))
    cl = pr = sc = 0.0
    for i, (t, u) in enumerate(queries):
        want = np.intersect1d(index.postings(int(t)), index.postings(int(u)))
        r1, w1 = cidx.query(int(t), int(u))
        r2, w2 = cidx.query_all_clusters(int(t), int(u))
        got = docs[ptr[i] : ptr[i + 1]]
        assert np.array_equal(got, r1)  # bit-identical to the loop
        assert np.array_equal(np.sort(inv[r1]), want)
        assert np.array_equal(np.sort(inv[r2]), want)
        cl += w1["cluster_level"]
        pr += w1["probes"]
        sc += w1["scanned"]
    assert work["cluster_level"] == cl
    assert work["probes"] == pr and work["scanned"] == sc


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_engine_equivalence_random_corpora(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    n_docs = data.draw(st.integers(50, 400))
    n_terms = data.draw(st.integers(20, 300))
    k = data.draw(st.integers(1, 16))
    index, reordered, cidx, perm = _random_setup(rng, n_docs, n_terms, k)
    n_q = data.draw(st.integers(1, 40))
    queries = rng.integers(0, n_terms, (n_q, 2))
    _assert_engine_matches_loop(index, cidx, perm, queries)


def test_engine_single_cluster_k1(rng):
    index, reordered, cidx, perm = _random_setup(rng, 200, 80, k=1)
    queries = rng.integers(0, 80, (30, 2))
    assert cidx.k == 1
    _assert_engine_matches_loop(index, cidx, perm, queries)


def test_engine_terms_absent_from_cluster_index(rng):
    index, reordered, cidx, perm = _random_setup(rng, 150, 500, k=8)
    df = np.diff(index.post_ptr)
    empty = np.flatnonzero(df == 0)
    assert len(empty) >= 2, "want terms with no postings in this setup"
    alive = np.flatnonzero(df > 0)
    queries = np.array(
        [
            [empty[0], empty[1]],  # both absent
            [empty[0], alive[0]],  # one absent
            [alive[0], empty[1]],
            [alive[0], alive[1]],
        ]
    )
    ptr, docs, work = batched_query(cidx, queries)
    assert ptr[3] == 0  # absent terms produce empty results
    _assert_engine_matches_loop(index, cidx, perm, queries)


def test_engine_empty_query_batch(rng):
    index, reordered, cidx, perm = _random_setup(rng, 100, 50, k=4)
    ptr, docs, work = batched_query(cidx, np.empty((0, 2), np.int64))
    assert ptr.tolist() == [0] and len(docs) == 0 and work["total"] == 0
    counts, _ = batched_counts(cidx, np.empty((0, 2), np.int64))
    assert len(counts) == 0


def test_batched_lookup_matches_loop(small_corpus, small_log):
    index = build_index(small_corpus)
    queries = small_log.queries[:120]
    ptr, docs, work = batched_lookup(index, queries, bucket_size=16)
    probes = scanned = 0
    for i, (t, u) in enumerate(queries):
        a, b = index.postings(int(t)), index.postings(int(u))
        if len(a) > len(b):
            a, b = b, a
        r, w = lookup_intersect(a, bucketize(b, index.n_docs, 16))
        assert np.array_equal(docs[ptr[i] : ptr[i + 1]], r)
        probes += w["probes"]
        scanned += w["scanned"]
    assert work["probes"] == probes and work["scanned"] == scanned


def test_plan_matches_query_level1(small_corpus, small_log):
    """Planner pairs ≡ intersect1d of the two cluster lists, per query."""
    rng = np.random.default_rng(5)
    k = 12
    index = build_index(small_corpus)
    assign = rng.integers(0, k, small_corpus.n_docs)
    perm = reorder_permutation(assign, k)
    reordered = permute_docs(index, perm)
    cidx = build_cluster_index(reordered, cluster_ranges(assign, k))
    queries = small_log.queries[:60]
    plan = plan_segment_pairs(cidx, queries)
    for i, (t, u) in enumerate(queries):
        want = np.intersect1d(cidx.term_clusters(int(t)), cidx.term_clusters(int(u)))
        got = plan.cluster[plan.pair_query == i]
        assert np.array_equal(got, want)
        # Segment pairs really are the shorter/longer posting segments.
    assert np.all(plan.short_len <= plan.long_len)
    assert np.all(plan.width >= 1)


def test_gather_padded_and_pow2_buckets():
    vals = np.arange(100, dtype=np.int32)
    out = gather_padded(vals, np.array([0, 10]), np.array([3, 0]), 4)
    assert out.shape == (2, 4)
    assert out[0, :3].tolist() == [0, 1, 2]
    from repro.kernels.intersect.ref import PAD

    assert (out[0, 3:] == PAD).all() and (out[1] == PAD).all()
    got = pow2_buckets(np.array([0, 1, 3, 4, 5, 16, 17, 1000]))
    assert got.tolist() == [4, 4, 4, 4, 8, 16, 32, 1024]


def test_query_batch_method(small_corpus, small_log):
    rng = np.random.default_rng(9)
    k = 6
    index = build_index(small_corpus)
    assign = rng.integers(0, k, small_corpus.n_docs)
    perm = reorder_permutation(assign, k)
    reordered = permute_docs(index, perm)
    cidx = build_cluster_index(reordered, cluster_ranges(assign, k))
    queries = small_log.queries[:40]
    ptr, docs, work = cidx.query_batch(queries)
    for i, (t, u) in enumerate(queries):
        assert np.array_equal(docs[ptr[i] : ptr[i + 1]], cidx.query(int(t), int(u))[0])


def test_count_intersections_jnp_is_the_kernel_oracle():
    """Satellite: the intersect oracle is defined in exactly one place."""
    from repro.index.batched import _PAD, count_intersections_jnp
    from repro.kernels.intersect.ref import PAD, intersect_count_ref

    assert count_intersections_jnp is intersect_count_ref
    assert _PAD == PAD
