"""Training substrate tests: optimizer, checkpoint, fault tolerance,
gradient compression, data pipeline determinism, trainer restart."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import PipelineState, RecsysPipeline, TokenPipeline
from repro.dist.compression import (
    compress_decompress,
    compressed_psum_tree,
    init_error_state,
)
from repro.dist.fault_tolerance import (
    ElasticMesh,
    StragglerMonitor,
    plan_mesh_shape,
)
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, cosine_schedule


# -- optimizer ------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(cfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(cfg, g, opt, params)
    assert float(loss(params)) < 1e-3


def test_adamw_bf16_moments():
    cfg = AdamWConfig(lr=0.05, moment_dtype="bfloat16", warmup_steps=0)
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(cfg, params)
    assert opt["mu"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4,))}
    params2, opt2 = adamw_update(cfg, g, opt, params)
    assert opt2["mu"]["w"].dtype == jnp.bfloat16
    assert float(params2["w"][0]) < 1.0


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(cosine_schedule(cfg, jnp.float32(0))) == 0.0
    assert float(cosine_schedule(cfg, jnp.float32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(cosine_schedule(cfg, jnp.float32(100))) == pytest.approx(0.1, rel=1e-2)


def test_grad_clipping():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((3,))}
    opt = adamw_init(cfg, params)
    huge = {"w": jnp.full((3,), 1e9)}
    # lr=0 -> params unchanged, but moments reflect the clipped gradient.
    _, opt2 = adamw_update(cfg, huge, opt, params)
    gnorm_after = float(jnp.linalg.norm(opt2["mu"]["w"])) / (1 - cfg.b1)
    assert gnorm_after <= 1.01


# -- checkpoint -----------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    state = {
        "params": {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
        "opt": {"step": jnp.int32(7)},
        "pipeline_step": np.int64(42),
    }
    mgr.save(10, state)
    assert mgr.latest_step() == 10
    step, restored = mgr.restore(state)
    assert step == 10
    np.testing.assert_array_equal(restored["params"]["a"], state["params"]["a"])
    assert int(restored["pipeline_step"]) == 42


def test_checkpoint_retention_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    state = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    steps = sorted(mgr._complete())
    assert steps == [3, 4]
    # A stale tmp dir from a "crash" is ignored and cleaned.
    os.makedirs(tmp_path / "ckpt_00000099.tmp123", exist_ok=True)
    assert mgr.latest_step() == 4
    mgr.save(5, state)
    assert not any(".tmp" in n for n in os.listdir(tmp_path))


def test_checkpoint_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"x": jnp.arange(4.0)}
    path = mgr.save(1, state)
    shard = os.path.join(path, "shard_0.npz")
    data = dict(np.load(shard))
    data["x"] = data["x"] + 1
    np.savez(shard, **data)
    with pytest.raises(IOError):
        mgr.restore(state)


# -- fault tolerance --------------------------------------------------------


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(n_hosts=4, strikes_to_evict=3)
    for _ in range(10):
        mon.record([1.0, 1.0, 1.0, 1.0])
    verdicts = []
    for _ in range(3):
        verdicts = mon.record([1.0, 1.0, 8.0, 1.0])
    assert any(v.host == 2 and v.evict for v in verdicts)
    assert mon.evictees() == [2]


def test_straggler_monitor_tolerates_noise():
    mon = StragglerMonitor(n_hosts=2)
    rng = np.random.default_rng(0)
    for _ in range(30):
        out = mon.record(list(1.0 + 0.05 * rng.random(2)))
    assert mon.evictees() == []


def test_plan_mesh_shape():
    assert plan_mesh_shape(512, 16, prefer_pods=2) == ((2, 16, 16), ("pod", "data", "model"))
    assert plan_mesh_shape(256, 16) == ((16, 16), ("data", "model"))
    # Losing 16 devices: 496 // 16 = 31 data rows.
    assert plan_mesh_shape(496, 16) == ((31, 16), ("data", "model"))
    with pytest.raises(ValueError):
        plan_mesh_shape(8, 16)


def test_elastic_remesh_local():
    em = ElasticMesh(model_parallel=1)
    mesh = em.remesh()
    assert mesh.devices.size >= 1
    assert em.epoch == 1


# -- gradient compression ----------------------------------------------------


def test_error_feedback_invariant():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(100).astype(np.float32))
    err = jnp.zeros_like(x)
    deq, err2 = compress_decompress(x, err)
    np.testing.assert_allclose(np.asarray(deq + err2), np.asarray(x), rtol=1e-5, atol=1e-6)


def test_error_feedback_accumulates_to_truth():
    """Over many steps, the sum of transmitted values converges to the sum
    of true values (nothing is systematically lost)."""
    rng = np.random.default_rng(1)
    err = jnp.zeros((50,))
    sent = jnp.zeros((50,))
    true = jnp.zeros((50,))
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal(50).astype(np.float32)) * 1e-3
        deq, err = compress_decompress(g, err)
        sent = sent + deq
        true = true + g
    np.testing.assert_allclose(np.asarray(sent), np.asarray(true), atol=1e-4)


def test_compressed_psum_tree_no_axis():
    grads = {"a": jnp.ones((8,)), "b": {"c": jnp.full((3,), 2.0)}}
    err = init_error_state(grads)
    out, err2 = compressed_psum_tree(grads, err, axis_name=None)
    np.testing.assert_allclose(np.asarray(out["a"]), 1.0, rtol=1e-2)
    assert jax.tree.structure(err2) == jax.tree.structure(grads)


# -- data pipeline -----------------------------------------------------------


def test_pipeline_deterministic_and_stateless():
    p = TokenPipeline(vocab_size=100, seq_len=16, batch_per_shard=4, seed=3)
    s5 = PipelineState(step=5)
    b1 = p.batch(s5, shard=0)
    b2 = p.batch(s5, shard=0)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p.batch(PipelineState(step=6), shard=0)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    b4 = p.batch(s5, shard=1)
    assert not np.array_equal(b1["tokens"], b4["tokens"])
    assert b1["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_recsys_pipeline_fields():
    p = RecsysPipeline(n_dense=5, n_fields=3, vocab_size=50, hist_len=7,
                       batch_per_shard=6, seed=0)
    b = p.batch(PipelineState(0))
    assert b["dense"].shape == (6, 5)
    assert b["sparse_ids"].shape == (6, 3)
    assert b["hist_ids"].shape == (6, 7)
    assert set(np.unique(b["label"])) <= {0.0, 1.0}
    assert (b["sparse_ids"] >= 0).all() and (b["sparse_ids"] < 50).all()


# -- trainer restart ----------------------------------------------------------


def test_trainer_checkpoint_restart(tmp_path):
    from repro.train.trainer import Trainer, TrainerConfig

    vocab, seq = 64, 16
    pipe = TokenPipeline(vocab_size=vocab, seq_len=seq, batch_per_shard=4, seed=0)

    def init_fn(key):
        k1, k2 = jax.random.split(key)
        return {
            "emb": jax.random.normal(k1, (vocab, 16)) * 0.1,
            "out": jax.random.normal(k2, (16, vocab)) * 0.1,
        }

    def loss_fn(params, batch):
        h = params["emb"][batch["tokens"]]
        logits = h @ params["out"]
        lse = jax.scipy.special.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["targets"][..., None], -1)[..., 0]
        return (lse - gold).mean()

    cfg = TrainerConfig(total_steps=6, ckpt_every=3, log_every=100,
                        ckpt_dir=str(tmp_path))
    t1 = Trainer(loss_fn, init_fn, pipe, cfg)
    t1.run()
    losses_full = [l for _, l, _ in t1.history]

    # Second trainer resumes from step 3's checkpoint... but we saved at
    # 3 and 6; simulate crash after step 3 by removing the later ckpt.
    import shutil

    shutil.rmtree(tmp_path / "ckpt_00000006")
    t2 = Trainer(loss_fn, init_fn, pipe, cfg)
    t2.run()
    # Resumed steps are 3..5 and reproduce the original losses exactly
    # (deterministic pipeline + identical state).
    resumed = [l for _, l, _ in t2.history]
    np.testing.assert_allclose(resumed, losses_full[3:], rtol=1e-5)
