"""Per-architecture smoke tests: reduced config, one real forward/train
step on CPU, asserting output shapes and no NaNs (deliverable f)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_NAMES, get_arch
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

pytestmark = pytest.mark.slow  # per-arch forward/train/decode smoke across all 9 configs (~90 s)

LM_ARCHS = [a for a in ARCH_NAMES if get_arch(a).family == "lm"]
RECSYS_ARCHS = [a for a in ARCH_NAMES if get_arch(a).family == "recsys"]


def _finite(tree):
    return all(
        bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree) if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)
    )


def _recsys_batch(cfg, name, batch, rng):
    if name == "dcn-v2":
        return {
            "dense": rng.standard_normal((batch, cfg.n_dense)).astype(np.float32),
            "sparse_ids": rng.integers(0, cfg.vocab_per_field, (batch, cfg.n_sparse)).astype(np.int32),
            "target_id": rng.integers(0, cfg.vocab_per_field, (batch,)).astype(np.int32),
            "label": rng.integers(0, 2, (batch,)).astype(np.float32),
        }
    seq = getattr(cfg, "seq_len", None) or cfg.hist_len
    out = {
        "hist_ids": rng.integers(0, cfg.vocab if hasattr(cfg, "vocab") else 100, (batch, seq)).astype(np.int32),
        "hist_mask": np.ones((batch, seq), np.float32),
        "target_id": rng.integers(0, getattr(cfg, "vocab", 100), (batch,)).astype(np.int32),
        "label": rng.integers(0, 2, (batch,)).astype(np.float32),
    }
    return out


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke(name):
    from repro.models import transformer as T

    spec = get_arch(name)
    cfg = spec.smoke_cfg
    params = T.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (2, 32)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "targets": jnp.roll(jnp.asarray(toks), -1, 1)}

    # train step
    opt_cfg = AdamWConfig()
    opt = adamw_init(opt_cfg, params)
    loss, grads = jax.value_and_grad(lambda p: T.loss_fn(p, cfg, batch))(params)
    params2, opt2 = adamw_update(opt_cfg, grads, opt, params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert _finite(params2)

    # decode path
    cache = T.init_cache(cfg, 2, 48)
    logits, cache = T.prefill(params, cfg, batch["tokens"], cache)
    assert logits.shape == (2, cfg.vocab)
    logits2, cache = T.decode_step(
        params, cfg, jnp.argmax(logits, -1).astype(jnp.int32)[:, None], cache
    )
    assert logits2.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits2)).all()
    assert int(cache.length) == 33


@pytest.mark.parametrize("name", RECSYS_ARCHS)
def test_recsys_smoke(name):
    spec = get_arch(name)
    cfg = spec.smoke_cfg
    from repro.launch.steps import _recsys_module

    M = _recsys_module(name)
    params = M.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    batch = {k: jnp.asarray(v) for k, v in _recsys_batch(cfg, name, 8, rng).items()}

    scores = M.forward(params, cfg, {k: v for k, v in batch.items() if k != "label"})
    assert scores.shape == (8,)
    assert np.isfinite(np.asarray(scores)).all()

    opt_cfg = AdamWConfig()
    opt = adamw_init(opt_cfg, params)
    loss, grads = jax.value_and_grad(lambda p: M.loss_fn(p, cfg, batch))(params)
    params2, _ = adamw_update(opt_cfg, grads, opt, params)
    assert np.isfinite(float(loss))
    assert _finite(params2)

    # retrieval head
    cand = jnp.asarray(rng.integers(0, 500, 64).astype(np.int32))
    s = M.score_candidates(params, cfg, {k: v for k, v in batch.items() if k != "label"}, cand)
    assert s.shape == (8, 64)
    assert np.isfinite(np.asarray(s)).all()


def test_pna_full_graph_smoke():
    from repro.data.graphs import synth_graph
    from repro.models import pna as M

    spec = get_arch("pna")
    cfg = dataclasses.replace(spec.smoke_cfg, d_feat=16, n_classes=5)
    g = synth_graph(n_nodes=300, avg_degree=6, d_feat=16, n_classes=5, seed=0)
    src, dst = g.edge_list()
    batch = {
        "feats": jnp.asarray(g.feats),
        "edges": jnp.stack([jnp.asarray(src), jnp.asarray(dst)], axis=1),
        "edge_mask": jnp.ones((g.n_edges,), jnp.float32),
        "labels": jnp.asarray(g.labels),
        "label_mask": jnp.ones((g.n_nodes,), jnp.float32),
    }
    params = M.init(cfg, jax.random.key(0))
    logits = M.forward(params, cfg, batch)
    assert logits.shape == (300, 5)
    loss, grads = jax.value_and_grad(lambda p: M.loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    # A couple of steps reduce the loss (features are class-separable).
    opt_cfg = AdamWConfig(lr=1e-2)
    opt = adamw_init(opt_cfg, params)
    p = params
    for _ in range(5):
        l, g_ = jax.value_and_grad(lambda p_: M.loss_fn(p_, cfg, batch))(p)
        p, opt = adamw_update(opt_cfg, g_, opt, p)
    l_end = float(M.loss_fn(p, cfg, batch))
    assert l_end < float(loss)


def test_pna_minibatch_smoke():
    from repro.data.graphs import NeighborSampler, synth_graph
    from repro.models import pna as M

    spec = get_arch("pna")
    cfg = dataclasses.replace(spec.smoke_cfg, d_feat=8, n_classes=3)
    g = synth_graph(n_nodes=500, avg_degree=8, d_feat=8, n_classes=3, seed=1)
    sampler = NeighborSampler(g, fanouts=(4, 3), seed=0)
    sub = sampler.sample(np.arange(16))
    batch = {k: jnp.asarray(v) for k, v in sub.items() if k != "n_real_nodes"}
    params = M.init(cfg, jax.random.key(1))
    loss = M.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))
    logits = M.forward(params, cfg, batch)
    assert logits.shape[0] == batch["feats"].shape[0]


def test_pna_molecule_smoke():
    from repro.data.graphs import batch_molecules
    from repro.models import pna as M

    spec = get_arch("pna")
    cfg = dataclasses.replace(spec.smoke_cfg, d_feat=8, n_classes=4, readout="graph")
    mb = batch_molecules(
        n_graphs=10, nodes_per_graph=12, edges_per_graph=20, d_feat=8,
        n_classes=4, seed=0,
    )
    batch = {k: jnp.asarray(v) for k, v in mb.items()}
    params = M.init(cfg, jax.random.key(2))
    logits = M.forward(params, cfg, batch)
    assert logits.shape == (10, 4)
    loss = M.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))


def test_neighbor_sampler_budget():
    from repro.data.graphs import NeighborSampler, synth_graph

    g = synth_graph(200, 5, 4, 2, seed=3)
    s = NeighborSampler(g, fanouts=(3, 2), seed=0)
    n_pad, e_pad = s.budget(8)
    sub = s.sample(np.arange(8))
    assert sub["feats"].shape[0] == n_pad
    assert sub["edges"].shape[0] == e_pad
    assert (sub["edges"] < n_pad).all()
    assert sub["n_real_nodes"] <= n_pad


def test_all_archs_registered():
    assert len(ARCH_NAMES) == 10
    for a in ARCH_NAMES:
        s = get_arch(a)
        assert len(s.cells) == 4
