"""The degradation ladder: bounded retry, breaker-gated host fallback,
automatic evict+remesh under injected faults, load shedding — and the
invariant underneath all of it: every answered request is bit-identical
to the host engine, no matter which rung answered."""

import asyncio

import numpy as np
import pytest

from repro.dist.fault_tolerance import ElasticMesh, NoDevicesError
from repro.serve.faults import SHED, FaultInjector, FaultSchedule
from repro.serve.resilience import (
    LEVELS,
    CircuitBreaker,
    DispatchOutcome,
    ResilienceConfig,
    ResilientDispatcher,
    ShedError,
)

# ----------------------------------------------------------------------
# Unit: config + breaker
# ----------------------------------------------------------------------


def test_config_validation():
    with pytest.raises(ValueError, match="max_retries"):
        ResilienceConfig(max_retries=-1)
    with pytest.raises(ValueError, match="breaker_threshold"):
        ResilienceConfig(breaker_threshold=0)
    with pytest.raises(ValueError, match="shed_queue_depth"):
        ResilienceConfig(shed_queue_depth=-1)


def test_breaker_opens_after_consecutive_failures():
    b = CircuitBreaker(threshold=2, probe_after=3)
    assert b.allow()
    b.record_failure()
    assert b.allow() and b.state == "closed"  # one strike: still closed
    b.record_failure()
    assert b.state == "open" and not b.allow()
    b.record_success()  # success anywhere resets the run
    assert b.state == "closed" and b.allow()


def test_breaker_half_open_probe_cycle():
    b = CircuitBreaker(threshold=1, probe_after=2)
    b.record_failure()
    assert b.state == "open"
    assert not b.allow()
    b.note_host()
    assert not b.allow()  # one host batch: not yet
    b.note_host()
    assert b.allow() and b.state == "half_open"  # probe admitted
    b.record_failure()  # probe failed: straight back open
    assert b.state == "open" and not b.allow()
    b.note_host()
    b.note_host()
    assert b.allow()  # next probe
    b.record_success()
    assert b.state == "closed" and b.allow()


def test_breaker_permanent_trip():
    b = CircuitBreaker(threshold=2, probe_after=1)
    b.trip(permanent=True)
    b.note_host()
    b.note_host()
    assert not b.allow()  # no probe ever again


# ----------------------------------------------------------------------
# Unit: the dispatcher ladder on fake engines
# ----------------------------------------------------------------------

TRUTH = np.arange(10, dtype=np.int64)


def _engines(fail_first=0, error=RuntimeError("boom")):
    calls = {"device": 0, "host": 0}

    def device(q):
        calls["device"] += 1
        if calls["device"] <= fail_first:
            raise error
        return TRUTH.copy(), {"path": "device"}

    def host(q):
        calls["host"] += 1
        return TRUTH.copy(), {"path": "host"}

    return device, host, calls


def test_retry_then_success():
    device, host, calls = _engines(fail_first=1)
    d = ResilientDispatcher(
        config=ResilienceConfig(max_retries=3), engine=device, host_engine=host
    )
    counts, info, out = d.dispatch(None)
    np.testing.assert_array_equal(counts, TRUTH)
    assert out.level == "retry" and out.attempts == 2
    assert calls["host"] == 0 and d.breaker.state == "closed"


def test_retry_budget_exhausted_falls_to_host():
    device, host, calls = _engines(fail_first=10_000)
    d = ResilientDispatcher(
        config=ResilienceConfig(max_retries=2), engine=device, host_engine=host
    )
    counts, info, out = d.dispatch(None)
    np.testing.assert_array_equal(counts, TRUTH)  # exact on the last rung too
    assert out.level == "host" and out.attempts == 3  # 1 try + 2 retries
    assert "RuntimeError" in out.error
    assert info["fallback"] == out.error
    assert calls["device"] == 3 and calls["host"] == 1


def test_no_devices_trips_breaker_permanently():
    device, host, calls = _engines(
        fail_first=10_000, error=NoDevicesError("pool empty")
    )
    d = ResilientDispatcher(
        config=ResilienceConfig(max_retries=3), engine=device, host_engine=host
    )
    _, _, out = d.dispatch(None)
    assert out.level == "host"
    assert calls["device"] == 1  # no point retrying an empty pool
    assert d.breaker.permanent
    d.dispatch(None)
    assert calls["device"] == 1  # breaker open for good: host only
    assert calls["host"] == 2


def test_breaker_routes_around_dead_device_then_reprobes():
    # Fails long enough to open the breaker, then heals: the half-open
    # probe must discover the recovery and close it again.
    device, host, calls = _engines(fail_first=3)
    cfg = ResilienceConfig(max_retries=0, breaker_threshold=2, probe_after=2)
    d = ResilientDispatcher(config=cfg, engine=device, host_engine=host)
    levels = [d.dispatch(None)[2].level for _ in range(9)]
    # 2 failed device tries open it; 2 host batches buy a probe; the
    # probe (device call #3) still fails -> reopen; 2 more host batches;
    # probe #2 lands on the healed engine and closes the breaker.
    assert levels[:5] == ["host", "host", "host", "host", "host"]
    assert levels[7] == "device"  # the successful probe
    assert d.breaker.state == "closed"
    assert levels[-1] == "device"


def test_zero_timeout_strikes_breaker_but_keeps_exact_results():
    # Timeout is detection, not preemption: with a zero budget every
    # completed dispatch is "late", results are kept (exact), and the
    # breaker drains traffic to the host path.
    device, host, calls = _engines()
    cfg = ResilienceConfig(
        dispatch_timeout_s=0.0, breaker_threshold=2, probe_after=2
    )
    d = ResilientDispatcher(config=cfg, engine=device, host_engine=host)
    results = [d.dispatch(None) for _ in range(6)]
    for counts, _info, _out in results:
        np.testing.assert_array_equal(counts, TRUTH)
    assert results[0][2].timed_out
    assert any(out.level == "host" for _, _, out in results)
    assert d.breaker.state == "open"  # probes keep timing out


def test_outcome_levels_are_ladder_members():
    assert LEVELS == ("device", "retry", "remesh", "host", "shed")
    assert DispatchOutcome().level == "device"


# ----------------------------------------------------------------------
# ElasticMesh edges (the typed floor of the eviction chain)
# ----------------------------------------------------------------------


def test_elastic_mesh_single_survivor_is_valid():
    import jax

    em = ElasticMesh(model_parallel=1)
    devs = list(jax.devices())[:4]
    em.remesh(devs)
    for d in devs[1:]:
        em.exclude_device(int(d.id))
    mesh = em.remesh()  # down to one device: still a legal (1, 1) mesh
    assert mesh.devices.size == 1
    assert mesh.axis_names == ("data", "model")
    assert em.epoch == 2


def test_elastic_mesh_empty_pool_raises_typed_error():
    import jax

    em = ElasticMesh(model_parallel=1)
    devs = list(jax.devices())[:2]
    em.remesh(devs)
    for d in devs:
        em.exclude_device(int(d.id))
    with pytest.raises(NoDevicesError, match="no mesh can be built"):
        em.remesh()


# ----------------------------------------------------------------------
# Integration: chaos replay through the real sharded engine
# ----------------------------------------------------------------------


@pytest.fixture(scope="module")
def chaos_log(small_corpus):
    from repro.data.query_log import synth_query_log

    return synth_query_log(
        small_corpus, n_queries=80, seed=11, arrival_qps=400.0
    )


def _sharded(small_seclud, n_shards=4, strikes=3):
    from repro.serve.search_service import SearchService

    svc = SearchService(small_seclud)
    svc.enable_sharded(n_shards=n_shards, strikes_to_evict=strikes)
    return svc


# Virtual-clock replays assert on composition/outcomes, never wall time;
# a huge timeout keeps real compile noise out of the breaker.
_RC = ResilienceConfig(dispatch_timeout_s=1e9)


def test_shard_loss_recovers_within_one_batch_and_stays_exact(
    small_seclud, chaos_log
):
    from repro.serve.replay import replay

    svc = _sharded(small_seclud)
    truth, _ = svc.serve_counts(chaos_log.as_conjunctive())
    rep = replay(
        svc,
        chaos_log,
        mode="sealed",
        faults=FaultSchedule.shard_loss(0, at=2),
        resilience=_RC,
    )
    levels = rep.stats.batch_levels
    # the lost shard is struck out inside the retry budget of the very
    # batch it died on: evict + remesh + answer, no manual feed anywhere
    assert levels[2] == "remesh"
    assert all(lv == "device" for lv in levels[3:])  # recovery complete
    assert svc.n_shards == 3
    np.testing.assert_array_equal(rep.counts, truth)  # zero wrong answers
    assert rep.stats.summary()["max_attempts"] <= _RC.max_retries + 1


def test_fault_on_first_batch_cold_cache(small_seclud, chaos_log):
    # Losing a shard on batch 0 exercises the ladder before any jit
    # cache exists — recovery must not depend on a warm grid.
    from repro.serve.replay import replay

    svc = _sharded(small_seclud)
    truth, _ = svc.serve_counts(chaos_log.as_conjunctive())
    rep = replay(
        svc,
        chaos_log,
        mode="sealed",
        faults=FaultSchedule.shard_loss(1, at=0),
        resilience=_RC,
    )
    assert rep.stats.batch_levels[0] == "remesh"
    assert svc.n_shards == 3
    np.testing.assert_array_equal(rep.counts, truth)


def test_slowdown_evicts_through_auto_fed_shard_times(
    small_seclud, chaos_log
):
    # Satellite: real per-shard timings flow from sharded_device_counts
    # into record_shard_times automatically.  A slowdown never fails a
    # dispatch — only the reported times carry the signal — so eviction
    # here proves the serving path feeds the monitor by itself.
    from repro.serve.replay import replay

    svc = _sharded(small_seclud, strikes=3)
    truth, _ = svc.serve_counts(chaos_log.as_conjunctive())
    epoch0 = svc._elastic.epoch
    rep = replay(
        svc,
        chaos_log,
        mode="sealed",
        faults=FaultSchedule.shard_slowdown(2, at=0, factor=50.0),
        resilience=_RC,
    )
    assert svc.n_shards == 3  # the straggler got voted off
    assert svc._elastic.epoch == epoch0 + 1
    np.testing.assert_array_equal(rep.counts, truth)
    # no dispatch ever failed: attempts stay 1 across the whole replay
    assert set(rep.stats.batch_attempts) == {1}


def test_flood_sheds_typed_and_non_shed_stay_exact(small_seclud, chaos_log):
    from repro.serve.replay import replay

    svc = _sharded(small_seclud)
    truth, _ = svc.serve_counts(chaos_log.as_conjunctive())
    rc = ResilienceConfig(dispatch_timeout_s=1e9, shed_queue_depth=500)
    rep = replay(
        svc,
        chaos_log,
        mode="sealed",
        faults=FaultSchedule.flood(at=3, depth=600, n_batches=2),
        resilience=rc,
    )
    s = rep.stats.summary()
    assert s["n_shed"] > 0
    assert s["levels"]["shed"] == 2  # exactly the flood window
    shed = rep.counts == SHED
    assert shed.any()
    np.testing.assert_array_equal(rep.counts[~shed], truth[~shed])
    # shed replies are refusals, not answers: they must not deflate p50
    assert (np.asarray(rep.stats.outcomes) == "shed").sum() == s["n_shed"]


def test_chaos_replay_is_deterministic(small_seclud, chaos_log):
    from repro.serve.replay import replay

    sch = FaultSchedule.chaos(seed=7, n_batches=40, n_events=5, n_shards=4)
    rc = ResilienceConfig(dispatch_timeout_s=1e9, shed_queue_depth=500)

    def run():
        svc = _sharded(small_seclud)
        return replay(svc, chaos_log, mode="sealed", faults=sch, resilience=rc)

    r1, r2 = run(), run()
    assert r1.stats.outcomes == r2.stats.outcomes
    assert r1.stats.batch_levels == r2.stats.batch_levels
    assert r1.stats.batch_attempts == r2.stats.batch_attempts
    assert r1.stats.batch_sizes == r2.stats.batch_sizes
    np.testing.assert_array_equal(r1.counts, r2.counts)


def test_async_submit_sheds_with_typed_error(small_seclud, small_log):
    from repro.serve.loop import AsyncServingLoop
    from repro.serve.search_service import SearchService

    svc = SearchService(small_seclud)
    loop = AsyncServingLoop(
        svc, resilience=ResilienceConfig(shed_queue_depth=0)
    )
    cq = small_log.as_conjunctive()

    async def drive():
        await loop.start()
        with pytest.raises(ShedError) as exc:
            await loop.submit(cq.terms(0))
        await loop.stop()
        return exc.value

    err = asyncio.run(drive())
    assert err.threshold == 0
    assert loop.stats.n_shed == 1
    assert loop.stats.summary()["frac_shed"] == 1.0


def test_async_chaos_replay_answers_exactly(small_seclud, chaos_log):
    # The wall-clock loop under a transient fault: composition is
    # nondeterministic, exactness is not.
    from repro.serve.replay import replay

    svc = _sharded(small_seclud)
    truth, _ = svc.serve_counts(chaos_log.as_conjunctive())
    rep = replay(
        svc,
        chaos_log,
        mode="async",
        faults=FaultSchedule.flaky(at=0, n_batches=3, n_attempts=1),
        resilience=_RC,
    )
    shed = rep.counts == SHED
    np.testing.assert_array_equal(rep.counts[~shed], truth[~shed])
    assert rep.stats.summary()["max_attempts"] >= 1
