"""The CI perf-regression gate must demonstrably trip on an injected
slowdown (and stay quiet on healthy runs)."""

import json

from benchmarks.compare import (
    compare,
    engine_device_ratios,
    engine_speedups,
    main,
)


def _doc(speedups, total_seconds=30.0, errors=(), device_s=None, host_s=0.05):
    """``device_s`` maps row name -> device seconds (None = 0.04 for all;
    the value False omits the device fields, like a pre-device baseline)."""
    rows = []
    for name, s in speedups.items():
        dev = 0.04 if device_s is None else device_s.get(name, 0.04)
        derived = f"loop_s=1.0;host_s={host_s};"
        if dev is not False:
            derived += f"device_s={dev};"
        derived += f"host_speedup={s:.1f}x;pad_overhead=1.1"
        rows.append({"name": name, "us_per_call": 100.0, "derived": derived})
    return {
        "suites": ["speedups"],
        "quick": True,
        "total_seconds": total_seconds,
        "rows": rows,
        "errors": list(errors),
    }


BASE = {
    "speedups/forum/batched_engine/n1000": 20.0,
    "speedups/forum/batched_engine_a3/n1000": 15.0,
    "speedups/forum/batched_engine_a5/n1000": 12.0,
}


def test_engine_speedups_parses_rows():
    doc = _doc(BASE)
    assert engine_speedups(doc) == BASE
    # non-engine rows are ignored
    doc["rows"].append({"name": "speedups/forum/topdown/k16", "derived": "S_T=2"})
    assert engine_speedups(doc) == BASE


def test_gate_passes_on_healthy_run():
    assert compare(_doc(BASE), _doc(BASE)) == []
    # mild noise within 25% passes
    noisy = {k: v * 0.8 for k, v in BASE.items()}
    assert compare(_doc(BASE), _doc(noisy, total_seconds=36.0)) == []
    # faster is always fine
    faster = {k: v * 3 for k, v in BASE.items()}
    assert compare(_doc(BASE), _doc(faster, total_seconds=10.0)) == []


def test_gate_trips_on_injected_speedup_regression():
    slow = dict(BASE)
    slow["speedups/forum/batched_engine/n1000"] = 20.0 * 0.5  # injected 2x slowdown
    fails = compare(_doc(BASE), _doc(slow))
    assert len(fails) == 1
    assert "batched_engine/n1000" in fails[0] and "regressed" in fails[0]


def test_engine_device_ratios_parses_rows():
    doc = _doc(BASE, device_s={k: 0.04 for k in BASE})
    assert engine_device_ratios(doc) == {k: 0.04 / 0.05 for k in BASE}
    # rows without the fields (old baselines) are simply absent
    old = _doc(BASE, device_s={k: False for k in BASE})
    assert engine_device_ratios(old) == {}


def test_gate_trips_on_injected_device_slowdown():
    """Satellite: a device-path regression must fail CI even when the
    host speedup is perfectly healthy."""
    name = "speedups/forum/batched_engine_a3/n1000"
    slow = _doc(BASE, device_s={name: 0.2})  # 0.8 -> 4.0 ratio
    fails = compare(_doc(BASE), slow)
    assert len(fails) == 1
    assert name in fails[0] and "device/host ratio regressed" in fails[0]


def test_gate_trips_when_device_crosses_host():
    """A device path that flips from winning to losing fails even inside
    the relative tolerance."""
    name = "speedups/forum/batched_engine_a5/n1000"
    base = _doc(BASE, device_s={name: 0.0475})  # ratio 0.95: winning
    fresh = _doc(BASE, device_s={name: 0.0525})  # ratio 1.05: now losing,
    fails = compare(base, fresh)  # but only ~10% growth (< 25%)
    assert len(fails) == 1
    assert name in fails[0] and "lost to the host path" in fails[0]


def test_device_gate_tolerates_old_baselines():
    """Baselines recorded before the device_s field existed warn instead
    of failing (and fresh rows missing the field warn too)."""
    old = _doc(BASE, device_s={k: False for k in BASE})
    assert compare(old, _doc(BASE)) == []
    warnings = []
    assert compare(_doc(BASE), old, warnings=warnings) == []
    assert sum("device-path gate skipped" in w for w in warnings) == len(BASE)


def test_gate_trips_on_wallclock_regression():
    fails = compare(_doc(BASE), _doc(BASE, total_seconds=30.0 * 1.5))
    assert any("wall-clock" in m for m in fails)


def test_wallclock_tolerance_is_independent():
    """CI judges wall-clock loosely (cross-machine baseline) without
    loosening the speedup-ratio gate."""
    slow_clock = _doc(BASE, total_seconds=30.0 * 2.0)
    assert compare(_doc(BASE), slow_clock, max_wallclock_regression=1.5) == []
    # ... the speedup gate still trips at its own threshold
    slow_ratio = {k: v * 0.5 for k, v in BASE.items()}
    fails = compare(
        _doc(BASE), _doc(slow_ratio), max_wallclock_regression=1.5
    )
    assert len(fails) == 3 and all("regressed" in m for m in fails)


def test_new_rows_warn_but_never_fail():
    """Satellite: rows present in the fresh run but absent from the
    baseline (a PR adding benchmarks) are tolerated with a warning — no
    same-PR --update dance — and the wall-clock gate steps aside because
    the stale baseline total does not include the new rows' time."""
    grown = dict(BASE)
    grown["speedups/forum/batched_engine_a7/n1000"] = 11.0
    doc = _doc(grown, total_seconds=55.0)  # well past the 25% growth gate
    doc["rows"].append(
        {"name": "speedups/forum/hier_engine/L3", "us_per_call": 9.0,
         "derived": "k=16-391;work=181436"}
    )
    warnings = []
    assert compare(_doc(BASE), doc, warnings=warnings) == []
    assert any("not in the baseline" in w for w in warnings)
    assert any("wall-clock check skipped" in w for w in warnings)
    # known rows are still gated at full strength alongside new ones
    grown_slow = dict(grown)
    grown_slow["speedups/forum/batched_engine/n1000"] = 20.0 * 0.5
    fails = compare(_doc(BASE), _doc(grown_slow, total_seconds=55.0))
    assert len(fails) == 1 and "regressed" in fails[0]


def test_gate_trips_on_missing_row_and_errors():
    partial = {k: v for k, v in BASE.items() if "a5" not in k}
    fails = compare(_doc(BASE), _doc(partial))
    assert any("disappeared" in m for m in fails)
    fails = compare(_doc(BASE), _doc(BASE, errors=[{"suite": "kernels", "error": "boom"}]))
    assert any("kernels" in m for m in fails)


def test_gate_trips_on_empty_baseline():
    assert compare(_doc({}), _doc(BASE)) != []


def test_main_exit_codes(tmp_path):
    base_p = tmp_path / "BENCH_baseline.json"
    fresh_p = tmp_path / "BENCH_smoke.json"
    base_p.write_text(json.dumps(_doc(BASE)))

    fresh_p.write_text(json.dumps(_doc(BASE)))
    assert main([str(fresh_p), "--baseline", str(base_p)]) == 0

    slow = {k: v * 0.5 for k, v in BASE.items()}
    fresh_p.write_text(json.dumps(_doc(slow)))
    assert main([str(fresh_p), "--baseline", str(base_p)]) == 1
    # a looser threshold lets the same run through
    assert main(
        [str(fresh_p), "--baseline", str(base_p), "--max-regression", "0.6"]
    ) == 0

    # --update re-baselines and the gate goes green again
    assert main([str(fresh_p), "--baseline", str(base_p), "--update"]) == 0
    assert main([str(fresh_p), "--baseline", str(base_p)]) == 0


def test_repo_baseline_is_committed_and_gateable():
    """The committed baseline must contain every batched_engine row the
    smoke suite produces (arity 2, 3, 5)."""
    from benchmarks.compare import DEFAULT_BASELINE, load

    assert DEFAULT_BASELINE.exists(), "BENCH_baseline.json must be committed"
    doc = load(DEFAULT_BASELINE)
    sp = engine_speedups(doc)
    names = "\n".join(sp)
    assert any("/batched_engine/" in n for n in sp), names
    assert any("/batched_engine_a3/" in n for n in sp), names
    assert any("/batched_engine_a5/" in n for n in sp), names
    assert all(v > 1.0 for v in sp.values())  # the engine must actually win
    assert float(doc["total_seconds"]) > 0
    assert not doc.get("errors")
    # the hierarchical-depth and adaptive-intersect rows are baselined too
    from benchmarks.compare import row_names

    all_names = row_names(doc)
    for want in ("/hier_engine/L1", "/hier_engine/L2", "/hier_engine/L3",
                 "/adaptive_vs_lookup/", "/device_engine/a2",
                 "/device_engine/a3", "/device_engine/a5"):
        assert any(want in n for n in all_names), (want, sorted(all_names))
    # The device path must be baselined as WINNING (ratio <= 1.0) at
    # every arity so the cross-over gate has teeth.
    ratios = engine_device_ratios(doc)
    assert set(ratios) == set(sp), sorted(ratios)
    assert all(r <= 1.0 for r in ratios.values()), ratios
