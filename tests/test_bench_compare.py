"""The CI perf-regression gate must demonstrably trip on an injected
slowdown (and stay quiet on healthy runs)."""

import json

from benchmarks.compare import (
    MAX_RECOVERY_BATCHES,
    SHED_SLACK,
    chaos_metrics,
    compare,
    engine_device_ratios,
    engine_speedups,
    filter_prefix,
    main,
    serving_metrics,
    sharded_metrics,
    write_step_summary,
)


def _doc(speedups, total_seconds=30.0, errors=(), device_s=None, host_s=0.05):
    """``device_s`` maps row name -> device seconds (None = 0.04 for all;
    the value False omits the device fields, like a pre-device baseline)."""
    rows = []
    for name, s in speedups.items():
        dev = 0.04 if device_s is None else device_s.get(name, 0.04)
        derived = f"loop_s=1.0;host_s={host_s};"
        if dev is not False:
            derived += f"device_s={dev};"
        derived += f"host_speedup={s:.1f}x;pad_overhead=1.1"
        rows.append({"name": name, "us_per_call": 100.0, "derived": derived})
    return {
        "suites": ["speedups"],
        "quick": True,
        "total_seconds": total_seconds,
        "rows": rows,
        "errors": list(errors),
    }


BASE = {
    "speedups/forum/batched_engine/n1000": 20.0,
    "speedups/forum/batched_engine_a3/n1000": 15.0,
    "speedups/forum/batched_engine_a5/n1000": 12.0,
}


def test_engine_speedups_parses_rows():
    doc = _doc(BASE)
    assert engine_speedups(doc) == BASE
    # non-engine rows are ignored
    doc["rows"].append({"name": "speedups/forum/topdown/k16", "derived": "S_T=2"})
    assert engine_speedups(doc) == BASE


def test_gate_passes_on_healthy_run():
    assert compare(_doc(BASE), _doc(BASE)) == []
    # mild noise within 25% passes
    noisy = {k: v * 0.8 for k, v in BASE.items()}
    assert compare(_doc(BASE), _doc(noisy, total_seconds=36.0)) == []
    # faster is always fine
    faster = {k: v * 3 for k, v in BASE.items()}
    assert compare(_doc(BASE), _doc(faster, total_seconds=10.0)) == []


def test_gate_trips_on_injected_speedup_regression():
    slow = dict(BASE)
    slow["speedups/forum/batched_engine/n1000"] = 20.0 * 0.5  # injected 2x slowdown
    fails = compare(_doc(BASE), _doc(slow))
    assert len(fails) == 1
    assert "batched_engine/n1000" in fails[0] and "regressed" in fails[0]


def test_engine_device_ratios_parses_rows():
    doc = _doc(BASE, device_s={k: 0.04 for k in BASE})
    assert engine_device_ratios(doc) == {k: 0.04 / 0.05 for k in BASE}
    # rows without the fields (old baselines) are simply absent
    old = _doc(BASE, device_s={k: False for k in BASE})
    assert engine_device_ratios(old) == {}


def test_gate_trips_on_injected_device_slowdown():
    """Satellite: a device-path regression must fail CI even when the
    host speedup is perfectly healthy."""
    name = "speedups/forum/batched_engine_a3/n1000"
    slow = _doc(BASE, device_s={name: 0.2})  # 0.8 -> 4.0 ratio
    fails = compare(_doc(BASE), slow)
    assert len(fails) == 1
    assert name in fails[0] and "device/host ratio regressed" in fails[0]


def test_gate_trips_when_device_crosses_host():
    """A device path that flips from winning to losing fails even inside
    the relative tolerance."""
    name = "speedups/forum/batched_engine_a5/n1000"
    base = _doc(BASE, device_s={name: 0.0475})  # ratio 0.95: winning
    fresh = _doc(BASE, device_s={name: 0.0525})  # ratio 1.05: now losing,
    fails = compare(base, fresh)  # but only ~10% growth (< 25%)
    assert len(fails) == 1
    assert name in fails[0] and "lost to the host path" in fails[0]


def test_device_gate_tolerates_old_baselines():
    """Baselines recorded before the device_s field existed warn instead
    of failing (and fresh rows missing the field warn too)."""
    old = _doc(BASE, device_s={k: False for k in BASE})
    assert compare(old, _doc(BASE)) == []
    warnings = []
    assert compare(_doc(BASE), old, warnings=warnings) == []
    assert sum("device-path gate skipped" in w for w in warnings) == len(BASE)


def test_gate_trips_on_wallclock_regression():
    fails = compare(_doc(BASE), _doc(BASE, total_seconds=30.0 * 1.5))
    assert any("wall-clock" in m for m in fails)


def test_wallclock_tolerance_is_independent():
    """CI judges wall-clock loosely (cross-machine baseline) without
    loosening the speedup-ratio gate."""
    slow_clock = _doc(BASE, total_seconds=30.0 * 2.0)
    assert compare(_doc(BASE), slow_clock, max_wallclock_regression=1.5) == []
    # ... the speedup gate still trips at its own threshold
    slow_ratio = {k: v * 0.5 for k, v in BASE.items()}
    fails = compare(
        _doc(BASE), _doc(slow_ratio), max_wallclock_regression=1.5
    )
    assert len(fails) == 3 and all("regressed" in m for m in fails)


def test_new_rows_warn_but_never_fail():
    """Satellite: rows present in the fresh run but absent from the
    baseline (a PR adding benchmarks) are tolerated with a warning — no
    same-PR --update dance — and the wall-clock gate steps aside because
    the stale baseline total does not include the new rows' time."""
    grown = dict(BASE)
    grown["speedups/forum/batched_engine_a7/n1000"] = 11.0
    doc = _doc(grown, total_seconds=55.0)  # well past the 25% growth gate
    doc["rows"].append(
        {"name": "speedups/forum/hier_engine/L3", "us_per_call": 9.0,
         "derived": "k=16-391;work=181436"}
    )
    warnings = []
    assert compare(_doc(BASE), doc, warnings=warnings) == []
    assert any("not in the baseline" in w for w in warnings)
    assert any("wall-clock check skipped" in w for w in warnings)
    # known rows are still gated at full strength alongside new ones
    grown_slow = dict(grown)
    grown_slow["speedups/forum/batched_engine/n1000"] = 20.0 * 0.5
    fails = compare(_doc(BASE), _doc(grown_slow, total_seconds=55.0))
    assert len(fails) == 1 and "regressed" in fails[0]


def test_gate_trips_on_missing_row_and_errors():
    partial = {k: v for k, v in BASE.items() if "a5" not in k}
    fails = compare(_doc(BASE), _doc(partial))
    assert any("disappeared" in m for m in fails)
    fails = compare(_doc(BASE), _doc(BASE, errors=[{"suite": "kernels", "error": "boom"}]))
    assert any("kernels" in m for m in fails)


def test_gate_trips_on_empty_baseline():
    assert compare(_doc({}), _doc(BASE)) != []


def test_main_exit_codes(tmp_path):
    base_p = tmp_path / "BENCH_baseline.json"
    fresh_p = tmp_path / "BENCH_smoke.json"
    base_p.write_text(json.dumps(_doc(BASE)))

    fresh_p.write_text(json.dumps(_doc(BASE)))
    assert main([str(fresh_p), "--baseline", str(base_p)]) == 0

    slow = {k: v * 0.5 for k, v in BASE.items()}
    fresh_p.write_text(json.dumps(_doc(slow)))
    assert main([str(fresh_p), "--baseline", str(base_p)]) == 1
    # a looser threshold lets the same run through
    assert main(
        [str(fresh_p), "--baseline", str(base_p), "--max-regression", "0.6"]
    ) == 0

    # --update re-baselines and the gate goes green again
    assert main([str(fresh_p), "--baseline", str(base_p), "--update"]) == 0
    assert main([str(fresh_p), "--baseline", str(base_p)]) == 0


def _with_shards(doc, metrics):
    """Append ``sharded_engine/s{N}`` rows; ``metrics`` maps shard count
    -> (agg_throughput, efficiency) in the bench_speedups derived format."""
    for s, (agg, eff) in metrics.items():
        doc["rows"].append(
            {
                "name": f"speedups/forum/sharded_engine/s{s}",
                "us_per_call": 1000.0,
                "derived": f"exec_s=0.01;qps=4000.0;agg_throughput={agg:.3f};"
                f"efficiency={eff:.3f};shards_touched={s};resident_mb=1.0",
            }
        )
    return doc


HEALTHY_SHARDS = {1: (1.0, 1.0), 2: (1.9, 0.95), 4: (3.6, 0.9), 8: (6.4, 0.8)}


def test_sharded_metrics_parses_rows():
    doc = _with_shards(_doc(BASE), HEALTHY_SHARDS)
    got = sharded_metrics(doc)
    assert set(got) == {1, 2, 4, 8}
    assert got[8] == {"agg": 6.4, "eff": 0.8}
    assert sharded_metrics(_doc(BASE)) == {}  # pre-sharding baseline


def test_shard_gate_passes_on_healthy_scaling():
    base = _with_shards(_doc(BASE), HEALTHY_SHARDS)
    fresh = _with_shards(_doc(BASE), HEALTHY_SHARDS)
    assert compare(base, fresh) == []


def test_shard_gate_trips_on_non_monotone_throughput():
    bad = dict(HEALTHY_SHARDS)
    bad[4] = (1.5, 0.375)  # s4 now below s2: more shards, less throughput
    fails = compare(
        _with_shards(_doc(BASE), HEALTHY_SHARDS), _with_shards(_doc(BASE), bad)
    )
    assert any("not monotone" in m and "s2" in m and "s4" in m for m in fails)


def test_shard_gate_trips_on_efficiency_floor():
    """Satellite: the committed floor at the largest shard count has
    teeth — an injected load-balance collapse fails the gate even when
    throughput stays monotone."""
    bad = dict(HEALTHY_SHARDS)
    bad[8] = (3.7, 0.46)  # monotone (> s4's 3.6) but badly unbalanced
    fails = compare(
        _with_shards(_doc(BASE), HEALTHY_SHARDS),
        _with_shards(_doc(BASE), bad),
        min_scaling_efficiency=0.6,
    )
    assert any("below the committed floor" in m and "s8" in m for m in fails)
    # the floor is a knob: a permissive floor lets the same run through
    fails = compare(
        _with_shards(_doc(BASE), HEALTHY_SHARDS),
        _with_shards(_doc(BASE), bad),
        min_scaling_efficiency=0.1,
    )
    assert not any("committed floor" in m for m in fails)
    # ... but the baseline-relative regression gate still catches the drop
    assert any("efficiency regressed" in m for m in fails)


def test_shard_gate_trips_on_baseline_efficiency_regression():
    worse = dict(HEALTHY_SHARDS)
    worse[8] = (5.0, 0.625)  # above the 0.6 floor, but 22% below baseline
    fails = compare(
        _with_shards(_doc(BASE), HEALTHY_SHARDS),
        _with_shards(_doc(BASE), worse),
        max_regression=0.15,
    )
    assert any("s8 efficiency regressed" in m for m in fails)


def test_shard_gate_trips_on_disappearing_shard_rows():
    base = _with_shards(_doc(BASE), HEALTHY_SHARDS)
    # largest shard count gone -> dedicated failure
    fewer = {s: m for s, m in HEALTHY_SHARDS.items() if s != 8}
    fails = compare(base, _with_shards(_doc(BASE), fewer))
    assert any("largest shard count s8 disappeared" in m for m in fails)
    # all sharded rows gone -> dedicated failure
    fails = compare(base, _doc(BASE))
    assert any("baseline has sharded rows but the fresh run has none" in m
               for m in fails)


def test_any_baseline_row_disappearance_fails():
    """Satellite bugfix: the gate must fail when ANY baseline row is
    missing from the smoke run, not just batched_engine rows."""
    base = _doc(BASE)
    base["rows"].append(
        {"name": "speedups/forum/hier_engine/L3", "us_per_call": 9.0,
         "derived": "k=16-391;work=181436"}
    )
    fails = compare(base, _doc(BASE))
    assert len(fails) == 1
    assert "hier_engine/L3" in fails[0] and "disappeared" in fails[0]


def test_step_summary_renders_and_appends(tmp_path):
    base = _with_shards(_doc(BASE), HEALTHY_SHARDS)
    bad = dict(HEALTHY_SHARDS)
    bad[8] = (3.7, 0.46)
    fresh = _with_shards(_doc(BASE), bad)
    warnings: list = []
    fails = compare(base, fresh, warnings=warnings)
    out = tmp_path / "summary.md"
    out.write_text("prior step content\n")
    md = write_step_summary(base, fresh, fails, warnings, path=str(out))
    assert "## Perf gate: ❌ FAILED" in md
    assert "| `speedups/forum/batched_engine/n1000` |" in md
    assert "| s8 |" in md and "0.80" in md and "0.46" in md
    assert "**Failures:**" in md
    assert any(line.startswith("- sharded_engine:") for line in md.splitlines())
    # appended after the prior content, not truncated over it
    text = out.read_text()
    assert text.startswith("prior step content\n") and md in text
    # healthy run renders the green banner (and without a path or
    # $GITHUB_STEP_SUMMARY it only returns the markdown)
    md_ok = write_step_summary(base, base, [], [])
    assert "## Perf gate: ✅ passed" in md_ok


def test_main_min_scaling_efficiency_flag(tmp_path):
    base_p = tmp_path / "BENCH_baseline.json"
    fresh_p = tmp_path / "BENCH_smoke.json"
    base_p.write_text(json.dumps(_with_shards(_doc(BASE), HEALTHY_SHARDS)))
    fresh_p.write_text(json.dumps(_with_shards(_doc(BASE), HEALTHY_SHARDS)))
    assert main([str(fresh_p), "--baseline", str(base_p)]) == 0
    # raising the floor above the measured 0.8 trips the gate from the CLI
    assert main(
        [str(fresh_p), "--baseline", str(base_p),
         "--min-scaling-efficiency", "0.95"]
    ) == 1


def _with_serving(doc, metrics):
    """Append ``serving/forum/replay/r{qps}`` rows; ``metrics`` maps qps
    -> (p50_ms, p99_ms, qps_sustained, compiles_steady) in the
    bench_serving derived format."""
    for qps, (p50, p99, sus, comp) in metrics.items():
        doc["rows"].append(
            {
                "name": f"serving/forum/replay/r{qps}",
                "us_per_call": p50 * 1e3,
                "derived": f"qps_offered={qps};qps_sustained={sus:.1f};"
                f"p50_ms={p50:.3f};p99_ms={p99:.3f};p999_ms={p99 * 1.5:.3f};"
                f"mean_batch=12.0;occupancy=0.19;batches=50;"
                f"compiles_steady={comp};prewarm_keys=20;prewarm_compiles=20;"
                f"prewarm_s=30.0;n=600;hist=1:10/64:40",
            }
        )
    return doc


HEALTHY_SERVING = {500: (3.5, 30.0, 510.0, 0), 2000: (2.9, 6.0, 2050.0, 0)}


def test_serving_metrics_parses_rows():
    doc = _with_serving(_doc(BASE), HEALTHY_SERVING)
    got = serving_metrics(doc)
    assert set(got) == {
        "serving/forum/replay/r500", "serving/forum/replay/r2000"
    }
    assert got["serving/forum/replay/r500"] == {
        "p50": 3.5, "p99": 30.0, "qps": 510.0, "compiles": 0.0
    }
    assert serving_metrics(_doc(BASE)) == {}  # pre-serving baseline


def test_serving_gate_passes_on_healthy_run():
    base = _with_serving(_doc(BASE), HEALTHY_SERVING)
    fresh = _with_serving(_doc(BASE), HEALTHY_SERVING)
    assert compare(base, fresh) == []
    # mild latency noise within the tolerance passes
    noisy = {q: (p50 * 1.1, p99 * 1.1, s * 0.9, c)
             for q, (p50, p99, s, c) in HEALTHY_SERVING.items()}
    assert compare(base, _with_serving(_doc(BASE), noisy)) == []


def test_serving_gate_trips_on_injected_p99_regression():
    """The acceptance criterion: an injected latency regression provably
    fails the gate."""
    slow = dict(HEALTHY_SERVING)
    slow[2000] = (2.9, 6.0 * 2.0, 2050.0, 0)  # injected 2x p99 blowup
    fails = compare(
        _with_serving(_doc(BASE), HEALTHY_SERVING),
        _with_serving(_doc(BASE), slow),
    )
    assert len(fails) == 1
    assert "r2000" in fails[0] and "p99 latency regressed" in fails[0]
    # a deliberately loose tolerance (cross-hardware CI) lets it through
    assert compare(
        _with_serving(_doc(BASE), HEALTHY_SERVING),
        _with_serving(_doc(BASE), slow),
        max_serving_regression=1.5,
    ) == []


def test_serving_gate_trips_on_qps_drop():
    slow = dict(HEALTHY_SERVING)
    slow[500] = (3.5, 30.0, 510.0 * 0.5, 0)  # can no longer keep up
    fails = compare(
        _with_serving(_doc(BASE), HEALTHY_SERVING),
        _with_serving(_doc(BASE), slow),
    )
    assert len(fails) == 1
    assert "r500" in fails[0] and "QPS regressed" in fails[0]


def test_serving_gate_trips_on_steady_state_compiles():
    """The compile gate is exact and survives any latency tolerance: a
    single compile after prewarm means the shape grid broke."""
    broken = dict(HEALTHY_SERVING)
    broken[500] = (3.5, 30.0, 510.0, 3)
    fails = compare(
        _with_serving(_doc(BASE), HEALTHY_SERVING),
        _with_serving(_doc(BASE), broken),
        max_serving_regression=10.0,  # even absurdly loose
    )
    assert len(fails) == 1
    assert "steady-state jit compiles" in fails[0]
    assert "prewarm no longer covers" in fails[0]


def test_serving_rows_new_in_fresh_warn_not_fail():
    """A PR introducing the serving bench against a pre-serving baseline
    must stay green (warn + re-baseline, no same-PR --update dance)."""
    warnings = []
    fails = compare(
        _doc(BASE),
        _with_serving(_doc(BASE), HEALTHY_SERVING),
        warnings=warnings,
    )
    assert fails == []
    assert any("not in the baseline" in w for w in warnings)


def test_filter_prefix_scopes_the_gate():
    full = _with_serving(_with_shards(_doc(BASE), HEALTHY_SHARDS),
                         HEALTHY_SERVING)
    scoped = filter_prefix(full, "serving/")
    assert {r["name"] for r in scoped["rows"]} == {
        "serving/forum/replay/r500", "serving/forum/replay/r2000"
    }
    assert scoped["total_seconds"] == 0.0
    # a serving-only artifact gates cleanly against the scoped full
    # baseline: no disappearance failures for suites it never ran
    fresh = filter_prefix(
        _with_serving(_doc({}, total_seconds=70.0), HEALTHY_SERVING),
        "serving/",
    )
    assert compare(scoped, fresh) == []
    # and a real serving regression still trips inside the scope
    slow = dict(HEALTHY_SERVING)
    slow[500] = (3.5, 90.0, 510.0, 0)
    fresh_slow = filter_prefix(
        _with_serving(_doc({}, total_seconds=70.0), slow), "serving/"
    )
    fails = compare(scoped, fresh_slow)
    assert len(fails) == 1 and "p99 latency regressed" in fails[0]
    # errors survive the filter: a broken partial run must still fail
    broken = filter_prefix(
        _with_serving(
            _doc({}, errors=[{"suite": "serving", "error": "boom"}]),
            HEALTHY_SERVING,
        ),
        "serving/",
    )
    assert any("serving" in m and "boom" in m for m in compare(scoped, broken))


def test_main_only_prefix_and_serving_flags(tmp_path):
    base_p = tmp_path / "BENCH_baseline.json"
    fresh_p = tmp_path / "BENCH_serving.json"
    base_p.write_text(json.dumps(
        _with_serving(_with_shards(_doc(BASE), HEALTHY_SHARDS),
                      HEALTHY_SERVING)
    ))
    # serving-only artifact vs full baseline: green only under the scope
    fresh_p.write_text(json.dumps(
        _with_serving(_doc({}, total_seconds=70.0), HEALTHY_SERVING)
    ))
    assert main([str(fresh_p), "--baseline", str(base_p),
                 "--only-prefix", "serving/"]) == 0
    assert main([str(fresh_p), "--baseline", str(base_p)]) == 1  # unscoped
    # the CLI tolerance flag reaches the serving gate
    slow = {q: (p50, p99 * 2.0, s, c)
            for q, (p50, p99, s, c) in HEALTHY_SERVING.items()}
    fresh_p.write_text(json.dumps(
        _with_serving(_doc({}, total_seconds=70.0), slow)
    ))
    assert main([str(fresh_p), "--baseline", str(base_p),
                 "--only-prefix", "serving/"]) == 1
    assert main([str(fresh_p), "--baseline", str(base_p),
                 "--only-prefix", "serving/",
                 "--max-serving-regression", "1.5"]) == 0
    # --update with --only-prefix would clobber the full baseline: refused
    assert main([str(fresh_p), "--baseline", str(base_p),
                 "--only-prefix", "serving/", "--update"]) == 1
    assert json.loads(base_p.read_text())["total_seconds"] == 30.0


def test_step_summary_includes_serving_table(tmp_path):
    base = _with_serving(_doc(BASE), HEALTHY_SERVING)
    broken = dict(HEALTHY_SERVING)
    broken[500] = (3.5, 30.0, 510.0, 2)
    fresh = _with_serving(_doc(BASE), broken)
    fails = compare(base, fresh)
    md = write_step_summary(base, fresh, fails, [])
    assert "| serving row |" in md
    assert "| `serving/forum/replay/r500` |" in md
    assert "0 → 2 |" in md  # the compile column shows the break
    assert "## Perf gate: ❌ FAILED" in md


def _with_chaos(doc, loss=None, brownout=None):
    """Append chaos rows in the bench_chaos derived format.  ``loss`` is
    (recovery_batches, exact), ``brownout`` is (frac_shed, p99_deg_ms,
    exact)."""
    if loss is not None:
        recovery, exact = loss
        doc["rows"].append(
            {
                "name": "chaos/forum/shard_loss",
                "us_per_call": 9000.0,
                "derived": f"n_shards=4;shards_after=3;evictions=1;"
                f"recovery_batches={recovery};max_attempts=4;exact={exact};"
                f"p50_ms=9.0;p99_ms=40.0;batches=7;n=400",
            }
        )
    if brownout is not None:
        frac, p99d, exact = brownout
        doc["rows"].append(
            {
                "name": "chaos/forum/brownout",
                "us_per_call": 9000.0,
                "derived": f"n_shards=4;frac_shed={frac:.4f};n_shed=20;"
                f"shed_batches=3;p99_degraded_ms={p99d:.3f};exact={exact};"
                f"batches=7;n=400",
            }
        )
    return doc


HEALTHY_LOSS = (1, 1)  # recovers in one batch, every answer exact
HEALTHY_BROWNOUT = (0.05, 12.0, 1)


def test_chaos_metrics_parses_rows():
    doc = _with_chaos(_doc(BASE), loss=HEALTHY_LOSS, brownout=HEALTHY_BROWNOUT)
    got = chaos_metrics(doc)
    assert set(got) == {"chaos/forum/shard_loss", "chaos/forum/brownout"}
    # fields a row does not carry parse to None, not 0
    assert got["chaos/forum/shard_loss"] == {
        "recovery": 1.0, "frac_shed": None, "p99_deg": None, "exact": 1.0
    }
    assert got["chaos/forum/brownout"] == {
        "recovery": None, "frac_shed": 0.05, "p99_deg": 12.0, "exact": 1.0
    }
    assert chaos_metrics(_doc(BASE)) == {}  # pre-chaos baseline


def test_chaos_gate_passes_on_healthy_run():
    base = _with_chaos(_doc(BASE), loss=HEALTHY_LOSS,
                       brownout=HEALTHY_BROWNOUT)
    fresh = _with_chaos(_doc(BASE), loss=HEALTHY_LOSS,
                        brownout=HEALTHY_BROWNOUT)
    assert compare(base, fresh) == []
    # shed drift inside the committed slack passes
    drift = (0.05 + SHED_SLACK - 0.01, 12.0, 1)
    assert compare(base, _with_chaos(_doc(BASE), loss=HEALTHY_LOSS,
                                     brownout=drift)) == []


def test_chaos_gate_trips_on_inexact_answers():
    """The acceptance criterion: a chaos row answering anything wrong
    fails absolutely — even against a pre-chaos baseline, and under any
    latency tolerance."""
    wrong = _with_chaos(_doc(BASE), loss=(1, 0))
    fails = compare(_doc(BASE), wrong, max_serving_regression=100.0)
    assert len(fails) == 1
    assert "shard_loss" in fails[0] and "diverged" in fails[0]
    wrong_shed = _with_chaos(_doc(BASE), brownout=(0.05, 12.0, 0))
    fails = compare(_doc(BASE), wrong_shed)
    assert any("brownout" in m and "diverged" in m for m in fails)


def test_chaos_gate_trips_on_recovery_bound():
    """Failover slower than the committed absolute bound fails, baseline
    or not."""
    limping = _with_chaos(_doc(BASE), loss=(MAX_RECOVERY_BATCHES + 1, 1))
    fails = compare(_doc(BASE), limping)
    assert len(fails) == 1
    assert "recovery took" in fails[0] and "no longer prompt" in fails[0]


def test_chaos_gate_trips_on_recovery_growth_over_baseline():
    """Inside the absolute bound, growing the degraded window over the
    committed run still fails — the window is schedule-deterministic."""
    base = _with_chaos(_doc(BASE), loss=(1, 1))
    slower = _with_chaos(_doc(BASE), loss=(3, 1))  # 3 <= bound of 4
    fails = compare(base, slower)
    assert len(fails) == 1
    assert "recovery window grew 1 -> 3" in fails[0]


def test_chaos_gate_trips_on_shed_fraction_growth():
    base = _with_chaos(_doc(BASE), brownout=HEALTHY_BROWNOUT)
    greedy = _with_chaos(
        _doc(BASE), brownout=(0.05 + SHED_SLACK + 0.05, 12.0, 1)
    )
    fails = compare(base, greedy)
    assert len(fails) == 1
    assert "shed fraction grew" in fails[0]


def test_chaos_gate_trips_on_degraded_p99_regression():
    base = _with_chaos(_doc(BASE), brownout=HEALTHY_BROWNOUT)
    slow = _with_chaos(_doc(BASE), brownout=(0.05, 12.0 * 4.0, 1))
    fails = compare(base, slow)
    assert len(fails) == 1
    assert "degraded-path p99 regressed" in fails[0]
    # the loose cross-hardware tolerance flag reaches this gate too
    assert compare(base, slow, max_serving_regression=5.0) == []


def test_chaos_rows_new_in_fresh_warn_not_fail():
    """A PR introducing the chaos bench against a pre-chaos baseline must
    stay green (warn + re-baseline) — but only while the new rows are
    healthy; the absolute checks still apply."""
    warnings = []
    fails = compare(
        _doc(BASE),
        _with_chaos(_doc(BASE), loss=HEALTHY_LOSS,
                    brownout=HEALTHY_BROWNOUT),
        warnings=warnings,
    )
    assert fails == []
    assert any("not in the baseline" in w for w in warnings)


def test_chaos_row_disappearance_fails():
    base = _with_chaos(_doc(BASE), loss=HEALTHY_LOSS,
                       brownout=HEALTHY_BROWNOUT)
    fails = compare(base, _with_chaos(_doc(BASE), loss=HEALTHY_LOSS))
    assert len(fails) == 1
    assert "brownout" in fails[0] and "disappeared" in fails[0]


def test_step_summary_includes_chaos_table():
    base = _with_chaos(_doc(BASE), loss=HEALTHY_LOSS,
                       brownout=HEALTHY_BROWNOUT)
    fresh = _with_chaos(_doc(BASE), loss=(MAX_RECOVERY_BATCHES + 2, 1),
                        brownout=HEALTHY_BROWNOUT)
    fails = compare(base, fresh)
    md = write_step_summary(base, fresh, fails, [])
    assert "| chaos row |" in md
    assert "| `chaos/forum/shard_loss` |" in md
    assert "## Perf gate: ❌ FAILED" in md


def test_repo_baseline_is_committed_and_gateable():
    """The committed baseline must contain every batched_engine row the
    smoke suite produces (arity 2, 3, 5)."""
    from benchmarks.compare import DEFAULT_BASELINE, load

    assert DEFAULT_BASELINE.exists(), "BENCH_baseline.json must be committed"
    doc = load(DEFAULT_BASELINE)
    sp = engine_speedups(doc)
    names = "\n".join(sp)
    assert any("/batched_engine/" in n for n in sp), names
    assert any("/batched_engine_a3/" in n for n in sp), names
    assert any("/batched_engine_a5/" in n for n in sp), names
    assert all(v > 1.0 for v in sp.values())  # the engine must actually win
    assert float(doc["total_seconds"]) > 0
    assert not doc.get("errors")
    # the hierarchical-depth and adaptive-intersect rows are baselined too
    from benchmarks.compare import row_names

    all_names = row_names(doc)
    for want in ("/hier_engine/L1", "/hier_engine/L2", "/hier_engine/L3",
                 "/adaptive_vs_lookup/", "/device_engine/a2",
                 "/device_engine/a3", "/device_engine/a5"):
        assert any(want in n for n in all_names), (want, sorted(all_names))
    # The device path must be baselined as WINNING (ratio <= 1.0) at
    # every arity so the cross-over gate has teeth.
    ratios = engine_device_ratios(doc)
    assert set(ratios) == set(sp), sorted(ratios)
    assert all(r <= 1.0 for r in ratios.values()), ratios
    # The sharded engine is baselined at every smoke shard count with its
    # largest-count efficiency above the committed floor — the scaling
    # gate judges real numbers, not a vacuous pass.
    from benchmarks.compare import MIN_SCALING_EFFICIENCY

    sh = sharded_metrics(doc)
    assert set(sh) == {1, 2, 4, 8}, sorted(sh)
    assert sh[8]["eff"] >= MIN_SCALING_EFFICIENCY, sh
    aggs = [sh[s]["agg"] for s in sorted(sh)]
    assert aggs == sorted(aggs), aggs  # monotone in the committed run too
    # Serving rows are baselined with a provably covering prewarm: the
    # committed steady-state compile count is 0 at every QPS point, so
    # the exact compile gate has teeth from day one.
    srv = serving_metrics(doc)
    assert srv, "baseline must carry serving/* rows"
    assert all(n.startswith("serving/forum/replay/r") for n in srv), srv
    assert all(m["compiles"] == 0 for m in srv.values()), srv
    assert all(m["p99"] > 0 and m["qps"] > 0 for m in srv.values()), srv
    # Chaos rows are baselined with exact=1 everywhere and a recovery
    # window inside the committed bound — the resilience gate judges a
    # committed run that actually survived its faults.
    ch = chaos_metrics(doc)
    assert set(ch) == {"chaos/forum/shard_loss", "chaos/forum/brownout"}, ch
    assert all(m["exact"] == 1.0 for m in ch.values()), ch
    loss = ch["chaos/forum/shard_loss"]
    assert loss["recovery"] is not None
    assert 0 < loss["recovery"] <= MAX_RECOVERY_BATCHES, loss
    brown = ch["chaos/forum/brownout"]
    assert brown["frac_shed"] is not None and brown["frac_shed"] > 0, brown
    assert brown["p99_deg"] is not None and brown["p99_deg"] > 0, brown
