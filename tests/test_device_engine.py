"""The device-resident engine: upload-once DeviceIndex, lean planning,
and the fused fold — counts AND docs bit-identical to the per-query loop
at every depth and arity (the loop ≡ batched ≡ device property chain).
"""

import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis, or fallback

from repro.core.batched_query import batched_query, plan_segment_pairs
from repro.core.cluster_index import build_cluster_index
from repro.core.device_engine import (
    device_counts,
    device_index,
    lower_plan,
)
from repro.core.queries import ConjunctiveQueries
from repro.core.reorder import cluster_ranges, reorder_permutation
from repro.data.corpus import Corpus
from repro.index.build import build_index, permute_docs
from repro.kernels.intersect.ref import PAD


def _random_setup(rng, n_docs, n_terms, k, mean_len=12):
    doc_lens = rng.integers(1, 2 * mean_len, n_docs)
    rows, ptr = [], [0]
    for d in range(n_docs):
        r = np.unique(rng.integers(0, n_terms, doc_lens[d]))
        rows.append(r)
        ptr.append(ptr[-1] + len(r))
    corpus = Corpus(
        doc_ptr=np.asarray(ptr, np.int64),
        doc_terms=np.concatenate(rows).astype(np.int32),
        n_terms=n_terms,
    )
    assign = rng.integers(0, k, n_docs)
    assign[rng.integers(0, n_docs)] = k - 1
    perm = reorder_permutation(assign, k)
    ranges = cluster_ranges(assign, k)
    index = build_index(corpus)
    reordered = permute_docs(index, perm)
    return index, build_cluster_index(reordered, ranges)


def _random_ragged_queries(rng, n_q, n_terms, max_arity=5):
    lists = []
    for _ in range(n_q):
        a = int(rng.integers(1, max_arity + 1))
        t = rng.integers(0, n_terms, a).tolist()
        if a >= 2 and rng.random() < 0.25:
            t[1] = t[0]  # duplicate term: ∩ is idempotent
        lists.append(t)
    return ConjunctiveQueries.from_lists(lists)


def _assert_device_matches_loop(cidx, cq):
    ptr, docs, _work = batched_query(cidx, cq)
    counts, docs_dev, info = device_counts(cidx, cq, return_docs=True)
    np.testing.assert_array_equal(counts, np.diff(ptr))
    np.testing.assert_array_equal(docs_dev, docs)
    for i, terms in enumerate(cq):
        r, _w = cidx.query(*terms)
        assert counts[i] == len(r)
    return info


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_device_engine_equivalence_random_corpora(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    index, cidx = _random_setup(
        rng,
        data.draw(st.integers(50, 250)),
        data.draw(st.integers(20, 200)),
        data.draw(st.integers(1, 10)),
    )
    cq = _random_ragged_queries(rng, data.draw(st.integers(1, 30)), index.n_terms)
    info = _assert_device_matches_loop(cidx, cq)
    assert info["n_kernel_calls"] == 1.0  # the whole chain, one dispatch


def test_device_engine_absent_terms_and_empty_postings(rng):
    index, cidx = _random_setup(rng, 150, 500, k=8)
    df = np.diff(index.post_ptr)
    empty = np.flatnonzero(df == 0)
    alive = np.flatnonzero(df > 0)
    cq = ConjunctiveQueries.from_lists(
        [
            [int(empty[0])],
            [int(empty[0]), int(empty[1]), int(empty[2])],
            [int(alive[0]), int(empty[0]), int(alive[1])],
            [int(alive[0]), int(alive[1]), int(alive[2])],
            [int(alive[3])],
        ]
    )
    counts, _info = device_counts(cidx, cq)
    assert counts[0] == 0 and counts[1] == 0 and counts[2] == 0
    _assert_device_matches_loop(cidx, cq)


def test_device_engine_every_depth(small_corpus):
    """L = 1 / 2 / 3 hierarchies return identical device counts."""
    from repro.core.seclud import SecludPipeline
    from repro.data.query_log import synth_query_log

    log = synth_query_log(small_corpus, n_queries=150, seed=7, arity=(2, 3))
    pipe = SecludPipeline(tc=800, doc_grained_below=256, seed=0)
    cq = log.as_conjunctive()[:60]
    ref = None
    for levels in (1, 2, 3):
        res = pipe.fit(small_corpus, k=8, algo="topdown", log=log, levels=levels)
        hidx = res.hier_index
        # fit() already uploaded: device_counts must reuse that copy.
        assert res.device_index is device_index(hidx)
        info = _assert_device_matches_loop(hidx, cq)
        counts, _ = device_counts(hidx, cq)
        if ref is None:
            ref = counts
        else:
            np.testing.assert_array_equal(counts, ref)
        assert info["padding_overhead"] <= 1.5  # tiny corpora pad a bit more


def test_device_index_is_cached_and_shared(rng):
    index, cidx = _random_setup(rng, 120, 60, k=5)
    di = device_index(cidx)
    assert device_index(cidx) is di  # cached on the hier view
    assert cidx.device() is di and cidx.as_hier().device() is di
    assert di.n_postings == len(cidx.index.post_docs)
    assert di.nbytes > 0
    # resident levels mirror the host CSR exactly
    np.testing.assert_array_equal(
        np.asarray(di.levels[0].cl_ids), cidx.cl_ids
    )
    np.testing.assert_array_equal(np.asarray(di.post_docs), cidx.index.post_docs)


def test_fit_shares_upload_with_cluster_index(small_corpus):
    from repro.core.seclud import SecludPipeline
    from repro.data.query_log import synth_query_log

    log = synth_query_log(small_corpus, n_queries=100, seed=3)
    pipe = SecludPipeline(tc=800, doc_grained_below=256, seed=0)
    res = pipe.fit(small_corpus, k=6, algo="topdown", log=log)
    # At L = 2 the facade's hier view IS the fitted hier index, so the
    # benchmark path batched_counts(res.cluster_index, ...) reuses the
    # fit-time upload instead of re-uploading.
    assert res.cluster_index.as_hier() is res.hier_index
    assert device_index(res.cluster_index) is res.device_index


def test_search_service_device_paths(rng):
    from repro.serve.search_service import SearchService

    index, cidx = _random_setup(rng, 300, 120, k=7)

    class _Res:
        cluster_index = cidx

    svc = SearchService(_Res())
    cq = _random_ragged_queries(rng, 40, 120)
    counts, _ = svc.serve_counts(cq)
    dev_counts, info = svc.serve_counts_device(cq)
    np.testing.assert_array_equal(dev_counts, counts)
    assert svc.device_index is device_index(cidx)  # persistent, shared
    # the packed/sharded path (now through ops.intersect_members) agrees
    packed = svc.pack(cq)
    np.testing.assert_array_equal(
        np.asarray(SearchService.device_counts(packed)), counts
    )


def test_lower_plan_layout(rng):
    index, cidx = _random_setup(rng, 200, 80, k=6)
    cq = _random_ragged_queries(rng, 25, 80)
    plan = plan_segment_pairs(cidx, cq)
    lowered = lower_plan(plan)
    # groups are permuted arity-descending; stage s touches the prefix
    # of groups with arity > s and nothing else
    sorted_arity = plan.arity[lowered.order]
    assert (np.diff(sorted_arity) <= 0).all()
    for i, n_g in enumerate(lowered.group_prefix):
        stage = i + 1  # chain stage number
        assert (sorted_arity[:n_g] > stage).all()
        if n_g < len(sorted_arity):
            assert (sorted_arity[n_g:] <= stage).all()
    # tail cells are dead: post PAD, group == G, query >= n_queries, arity 0
    n_true = lowered.n_cells_true
    assert (lowered.cells[0, n_true:] == PAD).all()
    assert (lowered.cells[1, n_true:] == len(lowered.order)).all()
    assert (lowered.cells[2, n_true:] >= lowered.n_queries).all()
    assert (lowered.cells[3, n_true:] == 0).all()
    assert lowered.n_cells % 8 == 0
    # live cells carry their group's arity (the stage mask's source)
    np.testing.assert_array_equal(
        lowered.cells[3, :n_true],
        np.repeat(plan.arity[lowered.order], lowered.cell_counts),
    )


def test_quantized_shapes_share_jit_signature():
    """Nearby batch sizes must land on the same quantized shapes (the
    fused fold's jit cache key), within a bounded <= 12.5% waste."""
    from repro.core.device_engine import _quantize

    assert _quantize(1000) == _quantize(1024) == 1024
    assert _quantize(37000) == _quantize(36001)
    for n in (1, 7, 9, 100, 5000, 123456):
        q = _quantize(n)
        assert q >= n and q <= max(8, int(n * 1.125)) + 8
        assert q % 8 == 0


def test_lean_planning_same_layout_zero_work(rng):
    index, cidx = _random_setup(rng, 180, 90, k=5)
    cq = _random_ragged_queries(rng, 30, 90)
    full = plan_segment_pairs(cidx, cq)
    lean = plan_segment_pairs(cidx, cq, track_work=False)
    for f in ("pair_query", "cluster", "base", "arity", "seg_ptr",
              "seg_start", "seg_len"):
        np.testing.assert_array_equal(
            getattr(full, f), getattr(lean, f), err_msg=f
        )
    assert full.cluster_work.sum() >= 0
    assert lean.cluster_work.sum() == 0  # work accounting skipped


def test_device_engine_empty_batch_and_empty_plan(rng):
    index, cidx = _random_setup(rng, 100, 400, k=4)
    counts, info = device_counts(cidx, np.empty((0, 2), np.int64))
    assert len(counts) == 0 and info["n_pairs"] == 0.0
    counts, docs, info = device_counts(
        cidx, np.empty((0, 2), np.int64), return_docs=True
    )
    assert len(docs) == 0
    # absent term => empty plan with a nonzero batch
    df = np.diff(index.post_ptr)
    empty_t = int(np.flatnonzero(df == 0)[0])
    counts, info = device_counts(cidx, np.array([[empty_t, empty_t]]))
    assert counts.tolist() == [0]


def test_device_counts_info_contract(rng):
    index, cidx = _random_setup(rng, 250, 100, k=6)
    cq = _random_ragged_queries(rng, 50, 100)
    counts, info = device_counts(cidx, cq)
    assert {"n_pairs", "n_kernel_calls", "padding_overhead", "occupancy",
            "stages"} <= set(info)
    assert info["n_kernel_calls"] == 1.0
    assert 0.0 < info["occupancy"] <= 1.0
    for s in info["stages"]:
        assert {"stage", "cur_cells", "cur_live", "long_cells",
                "padding_overhead", "kernel_calls"} <= set(s)
        assert s["padding_overhead"] >= 1.0 or s["long_cells"] == 0
        assert s["cur_live"] <= s["cur_cells"]


def test_device_docs_drop_pad_holes(rng):
    """Survivor docs come back in plan order with every PAD hole gone."""
    index, cidx = _random_setup(rng, 150, 60, k=4)
    cq = _random_ragged_queries(rng, 20, 60, max_arity=4)
    _ptr, docs, _w = batched_query(cidx, cq)
    _c, docs_dev, _i = device_counts(cidx, cq, return_docs=True)
    assert docs_dev.dtype == np.int32
    assert int(PAD) not in set(docs_dev.tolist())
    np.testing.assert_array_equal(docs_dev, docs)
