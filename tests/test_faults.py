"""Fault-injection layer: schedules are pure descriptions, the injector
interprets them deterministically, and the hook rides inside the real
engine dispatch (``fault_hook``) without changing any result."""

import numpy as np
import pytest

from repro.serve.faults import (
    SHED,
    DeviceLostError,
    FaultEvent,
    FaultInjector,
    FaultSchedule,
    InjectedFault,
)

# ----------------------------------------------------------------------
# FaultEvent / FaultSchedule — validation and windows
# ----------------------------------------------------------------------


def test_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("meteor_strike", at=0)
    with pytest.raises(ValueError, match="ordinal"):
        FaultEvent("exception", at=-1)
    with pytest.raises(ValueError, match="n_batches"):
        FaultEvent("exception", at=0, n_batches=0)
    with pytest.raises(ValueError, match="factor"):
        FaultEvent("slowdown", at=0, factor=0.0)


def test_event_active_window():
    ev = FaultEvent("exception", at=3, n_batches=2)
    assert [ev.active_at(b) for b in range(6)] == [
        False, False, False, True, True, False,
    ]
    # n_batches=None: active forever (until a remesh consumes it)
    forever = FaultEvent("device_loss", at=2, n_batches=None, shard=0)
    assert not forever.active_at(1)
    assert forever.active_at(2) and forever.active_at(10_000)


def test_canonical_scenarios():
    loss = FaultSchedule.shard_loss(2, at=5)
    (ev,) = loss.events
    assert ev.kind == "device_loss" and ev.shard == 2
    assert ev.at == 5 and ev.n_batches is None

    slow = FaultSchedule.shard_slowdown(1, at=0, factor=25.0)
    assert slow.events[0].kind == "slowdown"
    assert slow.events[0].factor == 25.0

    flaky = FaultSchedule.flaky(at=3, n_attempts=1)
    assert flaky.events[0].kind == "exception"
    assert flaky.events[0].n_attempts == 1

    flood = FaultSchedule.flood(at=4, depth=100, n_batches=2)
    assert flood.events[0].depth == 100


def test_chaos_is_seed_deterministic():
    a = FaultSchedule.chaos(seed=42, n_batches=50, n_events=6, n_shards=4)
    b = FaultSchedule.chaos(seed=42, n_batches=50, n_events=6, n_shards=4)
    c = FaultSchedule.chaos(seed=43, n_batches=50, n_events=6, n_shards=4)
    assert a.events == b.events  # frozen dataclass equality, field for field
    assert a.events != c.events
    # device loss is one-way and deliberately excluded from random mixes
    assert all(ev.kind != "device_loss" for ev in a.events)
    assert len(a.events) == 6


# ----------------------------------------------------------------------
# FaultInjector — batch/attempt bookkeeping
# ----------------------------------------------------------------------


def test_injector_attempt_window():
    # n_attempts=1: the first dispatch of each active batch fails, the
    # retry sails through — exactly one retry per affected batch.
    inj = FaultInjector(FaultSchedule.flaky(at=1, n_batches=2, n_attempts=1))
    inj.begin_batch()  # batch 0: clean
    inj.on_dispatch()
    inj.begin_batch()  # batch 1: first attempt raises, second passes
    with pytest.raises(InjectedFault):
        inj.on_dispatch()
    inj.on_dispatch()
    inj.begin_batch()  # batch 2: same again
    with pytest.raises(InjectedFault):
        inj.on_dispatch()
    inj.on_dispatch()
    inj.begin_batch()  # batch 3: window expired
    inj.on_dispatch()
    assert [f[:2] for f in inj.fired] == [(1, 0), (2, 0)]


def test_injector_flood_window():
    inj = FaultInjector(FaultSchedule.flood(at=2, depth=64, n_batches=2))
    depths = []
    for _ in range(5):
        inj.begin_batch()
        depths.append(inj.extra_queue_depth())
    assert depths == [0, 0, 64, 64, 0]


def test_injector_slowdown_perturbs_and_delays():
    inj = FaultInjector(
        FaultSchedule.shard_slowdown(1, at=0, factor=8.0, delay_s=0.25)
    )
    inj.begin_batch()
    inj.on_dispatch(n_shards=4)
    times = inj.perturb_shard_times([1.0, 1.0, 1.0, 1.0])
    np.testing.assert_allclose(times, [1.0, 8.0, 1.0, 1.0])
    assert inj.take_delay() == pytest.approx(0.25)
    assert inj.take_delay() == 0.0  # drained


def test_device_loss_persists_until_remesh():
    inj = FaultInjector(FaultSchedule.shard_loss(1, at=0))
    for _ in range(3):  # keeps failing, batch after batch
        inj.begin_batch()
        with pytest.raises(DeviceLostError) as exc:
            inj.on_dispatch(n_shards=4)
        assert exc.value.shard == 1
    # failover shrank the mesh: the event is consumed, dispatches pass
    inj.begin_batch()
    inj.on_dispatch(n_shards=3)
    inj.on_dispatch(n_shards=3)
    assert inj.extra_queue_depth() == 0


def test_remesh_does_not_consume_future_events():
    # A second loss scheduled for later must survive an earlier remesh.
    sch = FaultSchedule(
        (
            FaultEvent("device_loss", at=0, n_batches=None, shard=0),
            FaultEvent("device_loss", at=10, n_batches=None, shard=1),
        )
    )
    inj = FaultInjector(sch)
    inj.begin_batch()
    with pytest.raises(DeviceLostError):
        inj.on_dispatch(n_shards=4)
    inj.on_dispatch(n_shards=3)  # remesh observed: first event consumed
    for _ in range(9):
        inj.begin_batch()
        inj.on_dispatch(n_shards=3)  # batches 1..9: clean
    inj.begin_batch()  # batch 10: the second loss is still armed
    with pytest.raises(DeviceLostError) as exc:
        inj.on_dispatch(n_shards=3)
    assert exc.value.shard == 1


def test_shed_sentinel_is_typed_constant():
    # SHED is the count sentinel shed replies carry; spelling it through
    # the constant (not a literal) is what SEC003/SEC006 police.
    assert isinstance(SHED, int) and SHED < 0


# ----------------------------------------------------------------------
# The hook inside the real engine dispatch (no monkeypatching)
# ----------------------------------------------------------------------


@pytest.fixture(scope="module")
def svc(small_seclud):
    from repro.serve.search_service import SearchService

    return SearchService(small_seclud)


def test_hook_is_inert_on_single_device_engine(svc, small_log):
    cq = small_log.as_conjunctive()[:24]
    base, _ = svc.serve_counts_device(cq)
    svc.install_faults(FaultInjector(FaultSchedule()))  # empty schedule
    hooked, _ = svc.serve_counts_device(cq)
    svc.install_faults(None)
    np.testing.assert_array_equal(base, hooked)


def test_hook_raises_inside_sharded_dispatch(small_seclud, small_log):
    from repro.serve.search_service import SearchService

    svc = SearchService(small_seclud)
    svc.enable_sharded(n_shards=4)
    cq = small_log.as_conjunctive()[:24]
    inj = svc.install_faults(FaultInjector(FaultSchedule.flaky(at=0)))
    inj.begin_batch()
    with pytest.raises(InjectedFault):
        svc.serve_counts_device(cq)
    # the second attempt of the same batch passes, counts exact
    counts, info = svc.serve_counts_device(cq)
    svc.install_faults(None)
    host, _ = svc.serve_counts(cq)
    np.testing.assert_array_equal(counts, host)
    assert len(info["shard_times"]) == 4


def test_hook_perturbs_reported_shard_times(small_seclud, small_log):
    from repro.serve.search_service import SearchService

    svc = SearchService(small_seclud)
    svc.enable_sharded(n_shards=4, strikes_to_evict=10_000)  # never evict
    cq = small_log.as_conjunctive()[:24]
    _, clean_info = svc.serve_counts_device(cq)
    inj = FaultInjector(FaultSchedule.shard_slowdown(2, at=0, factor=100.0))
    svc.install_faults(inj)
    inj.begin_batch()
    counts, info = svc.serve_counts_device(cq)
    svc.install_faults(None)
    times = np.asarray(info["shard_times"])
    clean = np.asarray(clean_info["shard_times"])
    # the collective reports uniform honest times; the fault hook is the
    # only source of asymmetry — shard 2 now reads 100x its peers
    assert np.ptp(clean) == pytest.approx(0.0)
    assert times[2] == pytest.approx(100.0 * times[0])
    host, _ = svc.serve_counts(cq)
    np.testing.assert_array_equal(counts, host)  # timing lies, counts don't
