import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or fallback

from repro.kernels.intersect.ops import intersect_count
from repro.kernels.intersect.ref import PAD, intersect_count_ref

pytestmark = pytest.mark.slow  # Pallas kernel sweeps in interpret mode


def _make_batch(rng, b, ls, ll, universe, skew=False):
    short = np.full((b, ls), PAD, dtype=np.int32)
    long = np.full((b, ll), PAD, dtype=np.int32)
    for r in range(b):
        ns = rng.integers(0, ls + 1)
        nl = rng.integers(0, ll + 1)
        if skew:
            lo = rng.integers(0, universe // 2)
            w = max(universe // 8, nl + ns + 1)
            pool = np.arange(lo, min(lo + w, universe))
        else:
            pool = np.arange(universe)
        s_vals = np.sort(rng.choice(pool, size=min(ns, len(pool)), replace=False))
        l_vals = np.sort(rng.choice(pool, size=min(nl, len(pool)), replace=False))
        short[r, : len(s_vals)] = s_vals
        long[r, : len(l_vals)] = l_vals
    return short, long


def _brute(short, long):
    out = []
    for s, l in zip(short, long, strict=True):
        out.append(
            len(np.intersect1d(s[s != int(PAD)], l[l != int(PAD)]))
        )
    return np.asarray(out, np.int32)


@pytest.mark.parametrize(
    "b,ls,ll",
    [(1, 16, 64), (8, 128, 128), (5, 100, 300), (16, 128, 512), (3, 257, 1000)],
)
def test_kernel_matches_brute(b, ls, ll):
    rng = np.random.default_rng(b * 1000 + ls + ll)
    short, long = _make_batch(rng, b, ls, ll, universe=4 * ll)
    want = _brute(short, long)
    got_ref = np.asarray(intersect_count_ref(short, long))
    got_kern = np.asarray(intersect_count(short, long, force_kernel=True))
    np.testing.assert_array_equal(got_ref, want)
    np.testing.assert_array_equal(got_kern, want)


def test_kernel_skewed_clustered_ids():
    """The reordered-index regime: ids concentrated in cluster ranges."""
    rng = np.random.default_rng(0)
    short, long = _make_batch(rng, 8, 128, 384, universe=1 << 16, skew=True)
    want = _brute(short, long)
    got = np.asarray(intersect_count(short, long, force_kernel=True))
    np.testing.assert_array_equal(got, want)


def test_kernel_tile_sweep():
    rng = np.random.default_rng(1)
    short, long = _make_batch(rng, 4, 96, 200, universe=1024)
    want = _brute(short, long)
    for ts, tl in [(64, 64), (128, 128), (128, 256)]:
        got = np.asarray(
            intersect_count(short, long, tile_s=ts, tile_l=tl, force_kernel=True)
        )
        np.testing.assert_array_equal(got, want)


def test_all_pad_rows():
    short = np.full((8, 128), PAD, np.int32)
    long = np.full((8, 128), PAD, np.int32)
    got = np.asarray(intersect_count(short, long, force_kernel=True))
    np.testing.assert_array_equal(got, 0)


def test_identical_rows():
    row = np.arange(0, 256, 2, dtype=np.int32)
    short = np.tile(row, (8, 1))
    long = np.tile(row, (8, 1))
    got = np.asarray(intersect_count(short, long, force_kernel=True))
    np.testing.assert_array_equal(got, len(row))


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_kernel_property(data):
    universe = data.draw(st.integers(16, 5000))
    b = data.draw(st.integers(1, 6))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    ls = data.draw(st.integers(1, 150))
    ll = data.draw(st.integers(1, 400))
    short, long = _make_batch(rng, b, ls, ll, universe)
    want = _brute(short, long)
    got = np.asarray(intersect_count(short, long, force_kernel=True))
    np.testing.assert_array_equal(got, want)
