import numpy as np
import pytest

from repro.core.seclud import SecludPipeline
from repro.serve.retrieval import FilteredRetriever, items_as_corpus
from repro.serve.search_service import SearchService


@pytest.fixture(scope="module")
def service(small_corpus, small_log):
    pipe = SecludPipeline(tc=800, doc_grained_below=256, seed=0)
    res = pipe.fit(small_corpus, k=12, algo="topdown", log=small_log)
    return small_corpus, res, SearchService(res)


def test_serve_counts_lossless(service):
    corpus, res, svc = service
    from repro.index.build import build_index

    idx = build_index(corpus)
    queries = np.array([[int(t), int(u)] for t, u in
                        np.random.default_rng(0).choice(
                            np.flatnonzero(corpus.term_doc_freq() > 1), (20, 2))])
    counts, work = svc.serve_counts(queries)
    for qi, (t, u) in enumerate(queries):
        want = len(np.intersect1d(idx.postings(int(t)), idx.postings(int(u))))
        assert counts[qi] == want
    assert work["work"] > 0


def test_device_counts_match_host(service):
    corpus, res, svc = service
    queries = res.cluster_index.index.post_ptr  # any terms; use log instead
    rng = np.random.default_rng(1)
    alive = np.flatnonzero(corpus.term_doc_freq() > 1)
    queries = rng.choice(alive, (16, 2))
    queries = queries[queries[:, 0] != queries[:, 1]]
    host_counts, _ = svc.serve_counts(queries)
    packed = svc.pack(queries)
    dev = np.asarray(SearchService.device_counts(packed))
    np.testing.assert_array_equal(dev, host_counts)


def test_device_counts_sharded_local_mesh(service):
    """shard_map path on the local 1xN mesh."""
    import jax
    from jax.sharding import Mesh

    corpus, res, svc = service
    rng = np.random.default_rng(2)
    alive = np.flatnonzero(corpus.term_doc_freq() > 1)
    queries = rng.choice(alive, (8, 2))
    queries = queries[queries[:, 0] != queries[:, 1]]
    host_counts, _ = svc.serve_counts(queries)
    mesh = Mesh(np.asarray(jax.devices()).reshape(-1, 1), ("data", "model"))
    packed = svc.pack(queries)
    dev = np.asarray(SearchService.device_counts(packed, mesh=mesh))
    np.testing.assert_array_equal(dev, host_counts)


def test_pack_empty_is_honest(service):
    """Regression (satellite 2): an empty pack must emit zero rows, not a
    fabricated all-PAD row with row_query=[0] silently credited to query 0."""
    corpus, res, svc = service
    # Terms with no postings have no clusters -> no segment pairs.
    df = np.diff(res.cluster_index.index.post_ptr)
    empty = np.flatnonzero(df == 0)
    assert len(empty) >= 2
    queries = np.array([[int(empty[0]), int(empty[1])]])
    packed = svc.pack(queries)
    assert packed.short.shape[0] == 0 and packed.long.shape[0] == 0
    assert packed.row_query.size == 0
    assert packed.n_queries == 1
    dev = np.asarray(SearchService.device_counts(packed))
    np.testing.assert_array_equal(dev, [0])


def test_device_counts_shard_padding_not_credited_to_query0(service):
    """Regression (satellite 2): mesh-shard padding rows carry query id
    n_queries and are dropped by segment_sum, never attributed to query 0."""
    import jax
    from jax.sharding import Mesh

    corpus, res, svc = service
    alive = np.flatnonzero(corpus.term_doc_freq() > 1)
    mesh = Mesh(np.asarray(jax.devices()).reshape(-1, 1), ("data", "model"))
    dp = int(mesh.shape["data"])
    if dp == 1:
        pytest.skip("one device: shard padding can never occur")
    for seed in range(32):  # find a batch whose row count needs padding
        rng = np.random.default_rng(seed)
        queries = rng.choice(alive, (5, 2))
        queries = queries[queries[:, 0] != queries[:, 1]]
        packed = svc.pack(queries)
        if len(queries) and packed.short.shape[0] % dp != 0:
            break
    assert packed.short.shape[0] % dp != 0, "want real shard padding"
    host_counts, _ = svc.serve_counts(queries)
    dev = np.asarray(SearchService.device_counts(packed, mesh=mesh))
    np.testing.assert_array_equal(dev, host_counts)


def test_serve_counts_work_matches_query_loop(service):
    """serve_counts (now on the batched engine) reports the exact summed
    work of looping cluster_index.query."""
    corpus, res, svc = service
    rng = np.random.default_rng(3)
    alive = np.flatnonzero(corpus.term_doc_freq() > 1)
    queries = rng.choice(alive, (12, 2))
    counts, work = svc.serve_counts(queries)
    total = 0.0
    for qi, (t, u) in enumerate(queries):
        docs, w = res.cluster_index.query(int(t), int(u))
        assert counts[qi] == len(docs)
        total += w["total"]
    assert work["work"] == total


def test_items_as_corpus():
    attrs = [np.array([1, 5]), np.array([2]), np.array([1, 2, 9])]
    c = items_as_corpus(attrs, n_attrs=10)
    assert c.n_docs == 3
    assert np.array_equal(c.doc(2), [1, 2, 9])


def test_filtered_retriever_exact():
    rng = np.random.default_rng(0)
    n_items, n_attrs = 3000, 200
    item_attrs = [
        np.unique(rng.choice(n_attrs, size=rng.integers(2, 10)))
        for _ in range(n_items)
    ]
    items = items_as_corpus(item_attrs, n_attrs)
    r = FilteredRetriever(items, k=16, tc=200)
    a, b = 3, 7
    got, report = r.filter(a, b)
    want = [i for i, s in enumerate(item_attrs) if a in s and b in s]
    assert sorted(got.tolist()) == want
    assert report.n_filtered == len(want)
    assert report.filter_work > 0 and report.baseline_work > 0

    emb = rng.standard_normal((n_items, 8)).astype(np.float32)
    user = rng.standard_normal((1, 8)).astype(np.float32)
    ids, scores, _ = r.retrieve(lambda c: user @ emb[c].T, a, b, top_k=3)
    # Top-3 by score among the exact filtered set.
    all_scores = (user @ emb[want].T)[0]
    want_top = np.asarray(want)[np.argsort(-all_scores)[:3]]
    np.testing.assert_array_equal(ids, want_top)
