import numpy as np
import pytest

from repro.core.seclud import SecludPipeline
from repro.serve.retrieval import FilteredRetriever, items_as_corpus
from repro.serve.search_service import SearchService


@pytest.fixture(scope="module")
def service(small_corpus, small_log):
    pipe = SecludPipeline(tc=800, doc_grained_below=256, seed=0)
    res = pipe.fit(small_corpus, k=12, algo="topdown", log=small_log)
    return small_corpus, res, SearchService(res)


def test_serve_counts_lossless(service):
    corpus, res, svc = service
    from repro.index.build import build_index

    idx = build_index(corpus)
    queries = np.array([[int(t), int(u)] for t, u in
                        np.random.default_rng(0).choice(
                            np.flatnonzero(corpus.term_doc_freq() > 1), (20, 2))])
    counts, work = svc.serve_counts(queries)
    for qi, (t, u) in enumerate(queries):
        want = len(np.intersect1d(idx.postings(int(t)), idx.postings(int(u))))
        assert counts[qi] == want
    assert work["work"] > 0


def test_device_counts_match_host(service):
    corpus, res, svc = service
    queries = res.cluster_index.index.post_ptr  # any terms; use log instead
    rng = np.random.default_rng(1)
    alive = np.flatnonzero(corpus.term_doc_freq() > 1)
    queries = rng.choice(alive, (16, 2))
    queries = queries[queries[:, 0] != queries[:, 1]]
    host_counts, _ = svc.serve_counts(queries)
    packed = svc.pack(queries)
    dev = np.asarray(SearchService.device_counts(packed))
    np.testing.assert_array_equal(dev, host_counts)


def test_device_counts_sharded_local_mesh(service):
    """shard_map path on the local 1xN mesh."""
    import jax
    from jax.sharding import Mesh

    corpus, res, svc = service
    rng = np.random.default_rng(2)
    alive = np.flatnonzero(corpus.term_doc_freq() > 1)
    queries = rng.choice(alive, (8, 2))
    queries = queries[queries[:, 0] != queries[:, 1]]
    host_counts, _ = svc.serve_counts(queries)
    mesh = Mesh(np.asarray(jax.devices()).reshape(-1, 1), ("data", "model"))
    packed = svc.pack(queries)
    dev = np.asarray(SearchService.device_counts(packed, mesh=mesh))
    np.testing.assert_array_equal(dev, host_counts)


def test_items_as_corpus():
    attrs = [np.array([1, 5]), np.array([2]), np.array([1, 2, 9])]
    c = items_as_corpus(attrs, n_attrs=10)
    assert c.n_docs == 3
    assert np.array_equal(c.doc(2), [1, 2, 9])


def test_filtered_retriever_exact():
    rng = np.random.default_rng(0)
    n_items, n_attrs = 3000, 200
    item_attrs = [
        np.unique(rng.choice(n_attrs, size=rng.integers(2, 10)))
        for _ in range(n_items)
    ]
    items = items_as_corpus(item_attrs, n_attrs)
    r = FilteredRetriever(items, k=16, tc=200)
    a, b = 3, 7
    got, report = r.filter(a, b)
    want = [i for i, s in enumerate(item_attrs) if a in s and b in s]
    assert sorted(got.tolist()) == want
    assert report.n_filtered == len(want)
    assert report.filter_work > 0 and report.baseline_work > 0

    emb = rng.standard_normal((n_items, 8)).astype(np.float32)
    user = rng.standard_normal((1, 8)).astype(np.float32)
    ids, scores, _ = r.retrieve(lambda c: user @ emb[c].T, a, b, top_k=3)
    # Top-3 by score among the exact filtered set.
    all_scores = (user @ emb[want].T)[0]
    want_top = np.asarray(want)[np.argsort(-all_scores)[:3]]
    np.testing.assert_array_equal(ids, want_top)
