"""Batched query layout + adaptive lookup property tests."""

import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis, or fallback

from repro.index.batched import batch_queries, count_intersections_jnp
from repro.index.build import build_index
from repro.index.lookup import adaptive_intersect


def test_batched_counts_match_brute(small_corpus, small_log):
    idx = build_index(small_corpus)
    queries = small_log.queries[:80]
    batched = batch_queries(idx, queries)
    got = np.zeros(len(queries), np.int64)
    for b in batched.bins:
        counts = np.asarray(count_intersections_jnp(b.short, b.long))
        got[b.query_ids] = counts
    for qi, (t, u) in enumerate(queries):
        want = len(np.intersect1d(idx.postings(int(t)), idx.postings(int(u))))
        assert got[qi] == want
    assert 1.0 <= batched.padding_overhead() <= 4.0


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_adaptive_intersect_property(data):
    universe = data.draw(st.integers(32, 4096))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    na = data.draw(st.integers(0, 300))
    nb = data.draw(st.integers(0, 300))
    a = np.unique(rng.integers(0, universe, na)).astype(np.int32)
    b = np.unique(rng.integers(0, universe, nb)).astype(np.int32)
    got, work = adaptive_intersect(a, b, universe)
    assert np.array_equal(got, np.intersect1d(a, b))
    assert work["total"] >= 0
    # Work never exceeds examining both lists plus one probe per element.
    assert work["total"] <= 2 * (len(a) + len(b)) + 2
