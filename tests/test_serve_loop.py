"""The async serving loop and its replay harness.

Invariants under test: the batching policy is a pure, deterministic
function of arrival timestamps; batching never changes results (loop ≡
sealed replay ≡ direct device dispatch ≡ host engine, bit for bit); the
shape-grid prewarm provably covers a planned replay (zero steady-state
compiles); and arrival timestamps ride along on the query log without
perturbing its bit-exact query streams.
"""

import asyncio

import numpy as np
import pytest

from repro.core.seclud import SecludPipeline
from repro.data.query_log import QueryLog, poisson_arrivals, synth_query_log
from repro.serve.loop import (
    AsyncServingLoop,
    ServeConfig,
    plan_batches,
    seal_times,
)
from repro.serve.replay import replay
from repro.serve.search_service import SearchService


@pytest.fixture(scope="module")
def fitted(small_corpus, small_log):
    pipe = SecludPipeline(tc=800, doc_grained_below=256, seed=0)
    return pipe.fit(small_corpus, k=12, algo="topdown", log=small_log)


@pytest.fixture(scope="module")
def service(fitted):
    return SearchService(fitted)


@pytest.fixture(scope="module")
def traffic(small_corpus):
    """A mixed-arity Zipf log with open-loop Poisson arrivals."""
    return synth_query_log(
        small_corpus,
        n_queries=150,
        seed=5,
        arity=(1, 2, 3),
        arity_weights=(0.2, 0.6, 0.2),
        arrival_qps=400.0,
    )


# ----------------------------------------------------------------------
# The pure batching policy
# ----------------------------------------------------------------------


def test_serve_config_validates():
    with pytest.raises(ValueError, match="max_batch"):
        ServeConfig(max_batch=0)
    with pytest.raises(ValueError, match="deadline_s"):
        ServeConfig(deadline_s=-1e-3)


def test_plan_batches_deadline_splits_sparse_traffic():
    # A single request whose deadline fires before the next arrival
    # must dispatch alone — the single-request SLO case.
    assert plan_batches(np.array([0.0, 10.0]), 32, 0.5) == [(0, 1), (1, 2)]


def test_plan_batches_max_batch_splits_bursts():
    # 100 simultaneous arrivals, max_batch 32 -> 32/32/32/4.
    b = plan_batches(np.zeros(100), 32, 1.0)
    assert b == [(0, 32), (32, 64), (64, 96), (96, 100)]


def test_plan_batches_partitions_in_order():
    t = np.sort(np.random.default_rng(0).random(200)) * 0.1
    b = plan_batches(t, 16, 0.003)
    assert b[0][0] == 0 and b[-1][1] == 200
    assert all(j0 == i1 for (_, j0), (i1, _) in zip(b, b[1:], strict=False))
    assert all(j - i <= 16 for i, j in b)
    # every batch honors the deadline: last absorbed arrival within
    # the first's deadline window
    assert all(t[j - 1] <= t[i] + 0.003 + 1e-12 for i, j in b)


def test_plan_batches_rejects_bad_arrivals():
    with pytest.raises(ValueError, match="nondecreasing"):
        plan_batches(np.array([1.0, 0.5]), 8, 0.01)
    with pytest.raises(ValueError, match="1-d"):
        plan_batches(np.zeros((3, 2)), 8, 0.01)
    assert plan_batches(np.array([]), 8, 0.01) == []


def test_seal_times_full_vs_deadline_batches():
    t = np.array([0.0, 0.001, 0.002, 0.5])
    batches = plan_batches(t, 2, 0.01)
    assert batches == [(0, 2), (2, 3), (3, 4)]
    seals = seal_times(t, batches, 2, 0.01)
    # full batch seals when it fills; deadline batches wait out the clock
    np.testing.assert_allclose(seals, [0.001, 0.012, 0.51])


# ----------------------------------------------------------------------
# Arrival timestamps on the query log
# ----------------------------------------------------------------------


def test_poisson_arrivals_deterministic_and_monotone():
    a = poisson_arrivals(500, 1000.0, seed=3)
    b = poisson_arrivals(500, 1000.0, seed=3)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) >= 0).all() and a[0] > 0
    assert not np.array_equal(a, poisson_arrivals(500, 1000.0, seed=4))
    with pytest.raises(ValueError, match="positive"):
        poisson_arrivals(10, 0.0)


def test_arrival_qps_does_not_change_query_stream(small_corpus):
    """Regression: timestamps are drawn after all query draws, so the
    arity-2 historical sampler stays bit-identical with them attached."""
    plain = synth_query_log(small_corpus, n_queries=400, seed=9)
    timed = synth_query_log(
        small_corpus, n_queries=400, seed=9, arrival_qps=250.0
    )
    np.testing.assert_array_equal(plain.queries, timed.queries)
    assert plain.arrivals is None
    assert timed.arrivals is not None and len(timed.arrivals) == 400
    assert (np.diff(timed.arrivals) >= 0).all()
    # and for the mixed-arity sampler too
    plain3 = synth_query_log(small_corpus, n_queries=200, seed=9, arity=(1, 3))
    timed3 = synth_query_log(
        small_corpus, n_queries=200, seed=9, arity=(1, 3), arrival_qps=250.0
    )
    np.testing.assert_array_equal(plain3.queries, timed3.queries)


# ----------------------------------------------------------------------
# Sealed replay: deterministic, exact, prewarm-coverable
# ----------------------------------------------------------------------


def test_sealed_replay_matches_direct_and_host(service, traffic):
    cfg = ServeConfig(max_batch=16, deadline_s=0.002)
    rep = replay(service, traffic, config=cfg)
    assert rep.mode == "sealed"
    direct, _ = service.serve_counts_device(traffic.queries)
    np.testing.assert_array_equal(rep.counts, direct)
    host, _ = service.serve_counts(traffic.queries)
    np.testing.assert_array_equal(rep.counts, host)
    s = rep.summary()
    assert s["n_requests"] == traffic.n_queries
    assert s["n_batches"] == len(rep.batches)
    assert s["p99_ms"] >= s["p50_ms"] >= 0.0
    assert 0.0 < s["occupancy"] <= 1.0


def test_sealed_replay_is_deterministic(service, traffic):
    cfg = ServeConfig(max_batch=16, deadline_s=0.002)
    a = replay(service, traffic, config=cfg)
    b = replay(service, traffic, config=cfg)
    assert a.batches == b.batches
    np.testing.assert_array_equal(a.counts, b.counts)
    # qps-drawn arrivals under a fixed seed are deterministic too
    log = QueryLog(queries=traffic.queries)
    c = replay(service, log, qps=400.0, seed=7, config=cfg)
    d = replay(service, log, qps=400.0, seed=7, config=cfg)
    assert c.batches == d.batches
    np.testing.assert_array_equal(c.counts, a.counts)


def test_replay_requires_arrivals_or_qps(service, traffic):
    with pytest.raises(ValueError, match="no arrivals"):
        replay(service, QueryLog(queries=traffic.queries))
    with pytest.raises(ValueError, match="unknown replay mode"):
        replay(service, traffic, mode="warp")


def test_replay_empty_plan_batches(service, small_corpus):
    """Batches whose every term has an empty posting list never reach
    the fold (empty plan) — the replay must still produce their zero
    counts and keep request accounting consistent."""
    df = small_corpus.term_doc_freq()
    dead = np.flatnonzero(df == 0)
    assert len(dead) >= 3, "synth corpus should have unused terms"
    q = np.stack([dead[:3], dead[:3]], axis=1).astype(np.int32)
    log = QueryLog(
        queries=q, arrivals=np.array([0.0, 0.0005, 0.001])
    )
    rep = replay(service, log, config=ServeConfig(max_batch=8, deadline_s=0.01))
    np.testing.assert_array_equal(rep.counts, [0, 0, 0])
    assert rep.stats.n_requests == 3


def test_prewarm_covers_planned_replay(service, traffic):
    """The acceptance bar: prewarm the exact planned windows, then the
    sealed replay compiles nothing."""
    from repro.core.device_engine import fold_cache_size, prewarm

    cfg = ServeConfig(max_batch=16, deadline_s=0.002)
    batches = plan_batches(traffic.arrivals, cfg.max_batch, cfg.deadline_s)
    pw = prewarm(
        service.query_index,
        traffic.queries,
        batches=batches,
        dindex=service.device_index,
    )
    assert pw["n_batches"] == len(batches)
    assert pw["n_keys"] >= 1
    rep = replay(service, traffic, config=cfg)
    assert rep.jit_compiles == 0, (
        f"steady state compiled {rep.jit_compiles}x after prewarm"
    )
    assert all(c == 0 for c in rep.stats.batch_compiles)
    # warming the same grid again is a no-op on the cache
    before = fold_cache_size()
    pw2 = prewarm(
        service.query_index,
        traffic.queries,
        batches=batches,
        dindex=service.device_index,
    )
    assert pw2["n_compiles"] == 0 and fold_cache_size() == before


# ----------------------------------------------------------------------
# The real-time async loop
# ----------------------------------------------------------------------


def _direct_count(service, terms) -> int:
    counts, _ = service.serve_counts_device(np.asarray([terms], np.int32))
    return int(np.asarray(counts)[0])


def test_async_loop_single_request_deadline(service, traffic):
    """One lone request: nothing fills the batch, the deadline must
    fire and dispatch it alone."""
    terms = [int(t) for t in traffic.as_conjunctive().terms(0)]

    async def go():
        loop = service.serve_async(max_batch=32, deadline_s=0.005)
        await loop.start()
        count = await loop.submit(terms)
        await loop.stop()
        return count, loop.stats

    count, stats = asyncio.run(go())
    assert count == _direct_count(service, terms)
    assert stats.batch_sizes == [1]
    assert stats.n_requests == 1
    lat = stats.latencies_s()
    assert lat[0] >= 0.005  # it genuinely waited out the deadline


def test_async_loop_burst_splits_and_matches_direct(service, traffic):
    """A burst larger than max_batch splits into <=max_batch dispatches
    and every request still gets its exact count."""
    cq = traffic.as_conjunctive()
    n = 10
    reqs = [[int(t) for t in cq.terms(r)] for r in range(n)]

    async def go():
        loop = service.serve_async(max_batch=4, deadline_s=0.02)
        await loop.start()
        counts = await asyncio.gather(*(loop.submit(r) for r in reqs))
        await loop.stop()
        return counts, loop.stats

    counts, stats = asyncio.run(go())
    assert stats.n_requests == n
    assert sum(stats.batch_sizes) == n
    assert max(stats.batch_sizes) <= 4
    assert len(stats.batch_sizes) >= 3  # a 10-burst needs >= ceil(10/4)
    direct, _ = service.serve_counts_device(traffic.queries[:n])
    np.testing.assert_array_equal(counts, np.asarray(direct))


def test_async_loop_lifecycle_errors(service):
    loop = service.serve_async()

    async def submit_unstarted():
        await loop.submit([0])

    with pytest.raises(RuntimeError, match="not started"):
        asyncio.run(submit_unstarted())

    async def double_start():
        await loop.start()
        try:
            with pytest.raises(RuntimeError, match="already running"):
                await loop.start()
        finally:
            await loop.stop()

    asyncio.run(double_start())


def test_loop_prewarm_default_grid_idempotent(service, traffic):
    """The loop's default power-of-two prewarm: a second call finds the
    whole grid cached."""
    loop = service.serve_async(max_batch=8)
    loop.prewarm(traffic.queries)
    pw = loop.prewarm(traffic.queries)
    assert pw["n_compiles"] == 0


def test_async_replay_mode_exact(service, small_corpus):
    """Wall-clock replay through the real loop: composition is timing
    dependent, results are not."""
    log = synth_query_log(
        small_corpus, n_queries=40, seed=21, arrival_qps=2000.0
    )
    rep = replay(
        service, log, config=ServeConfig(max_batch=8, deadline_s=0.005),
        mode="async",
    )
    assert rep.mode == "async"
    direct, _ = service.serve_counts_device(log.queries)
    np.testing.assert_array_equal(rep.counts, direct)
    assert rep.stats.n_requests == 40
    assert sum(rep.stats.batch_sizes) == 40


# ----------------------------------------------------------------------
# Sharded serving through the loop
# ----------------------------------------------------------------------


def test_sealed_replay_sharded_exact(fitted, small_corpus):
    """After enable_sharded the same replay serves through the mesh
    fold — counts still bit-identical to the host engine."""
    import jax

    n = min(2, len(jax.devices()))
    svc = SearchService(fitted)
    svc.enable_sharded(n)
    log = synth_query_log(
        small_corpus, n_queries=60, seed=13, arrival_qps=500.0
    )
    rep = replay(svc, log, config=ServeConfig(max_batch=16, deadline_s=0.002))
    host, _ = svc.serve_counts(log.queries)
    np.testing.assert_array_equal(rep.counts, host)


def test_loop_prewarm_sharded_executes_samples(fitted, small_corpus):
    import jax

    n = min(2, len(jax.devices()))
    svc = SearchService(fitted)
    svc.enable_sharded(n)
    log = synth_query_log(small_corpus, n_queries=32, seed=13)
    loop = svc.serve_async(max_batch=8)
    pw = loop.prewarm(log.queries)
    assert pw["n_batches"] >= 1


# ----------------------------------------------------------------------
# Engine timing hooks (what the loop's telemetry is built on)
# ----------------------------------------------------------------------


def test_device_counts_timing_hooks(service, traffic):
    _, info = service.serve_counts_device(traffic.queries[:8])
    for key in ("t_plan_s", "t_lower_s", "t_fold_s", "jit_compiles"):
        assert key in info, f"info missing {key}"
        assert float(info[key]) >= 0.0
