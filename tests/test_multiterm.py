"""Arbitrary-arity conjunctive queries: representation, cost-ordered
plans, and the full engine equivalence chain.

The contract under test: on any corpus, for any ragged batch of
conjunctive queries with arities 1..5 (duplicate terms, absent terms and
empty posting lists included),

    ClusterIndex.query(*terms)  ≡  query_all_clusters(*terms)
        ≡  brute chained np.intersect1d
        ≡  batched_query (docs + work dicts, bit-identical)
        ≡  batched_counts (per-query counts)
        ≡  SearchService.pack + device_counts

and the single-index ``batched_lookup`` matches the cost-ordered
``lookup_intersect`` chain exactly.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or fallback

from repro.core.batched_query import batched_counts, batched_lookup, batched_query
from repro.core.cluster_index import build_cluster_index, cost_order
from repro.core.queries import QUERY_PAD, ConjunctiveQueries, as_queries
from repro.core.reorder import cluster_ranges, reorder_permutation
from repro.data.corpus import Corpus
from repro.index.build import build_index, permute_docs
from repro.index.lookup import bucketize, lookup_intersect


# ----------------------------------------------------------------------
# Representation
# ----------------------------------------------------------------------


def test_conjunctive_queries_roundtrip():
    cq = ConjunctiveQueries.from_lists([[3], [1, 2], [5, 4, 5, 9]])
    assert cq.n_queries == 3
    assert cq.arities.tolist() == [1, 2, 4]
    assert cq.max_arity == 4
    assert cq.terms(2).tolist() == [5, 4, 5, 9]
    pad = cq.padded()
    assert pad.shape == (3, 4)
    assert pad[0].tolist() == [3, QUERY_PAD, QUERY_PAD, QUERY_PAD]
    back = ConjunctiveQueries.from_padded(pad)
    assert np.array_equal(back.q_ptr, cq.q_ptr)
    assert np.array_equal(back.q_terms, cq.q_terms)


def test_as_queries_accepts_all_forms():
    arr = np.array([[1, 2], [3, 4]])
    for form in (arr, ConjunctiveQueries.from_padded(arr), [[1, 2], [3, 4]]):
        cq = as_queries(form)
        assert cq.n_queries == 2 and cq.q_terms.tolist() == [1, 2, 3, 4]
    empty = as_queries(np.empty((0, 2), np.int64))
    assert empty.n_queries == 0 and empty.max_arity == 0


def test_as_queries_rejects_bad_input():
    with pytest.raises(ValueError):
        as_queries([1, 2, 3])  # flat scalars: ambiguous
    with pytest.raises(ValueError):
        ConjunctiveQueries.from_padded(np.full((1, 2), QUERY_PAD))  # arity 0
    with pytest.raises(ValueError):
        ConjunctiveQueries(q_ptr=np.array([0, 0]), q_terms=np.zeros(0, np.int64))


def test_cost_order_is_stable_ascending():
    assert cost_order([5, 2, 9, 2]) == [1, 3, 0, 2]
    assert cost_order([4, 4]) == [0, 1]  # ties keep term order
    assert cost_order([7]) == [0]


# ----------------------------------------------------------------------
# Engine equivalence chain
# ----------------------------------------------------------------------


def _random_setup(rng, n_docs, n_terms, k, mean_len=12):
    doc_lens = rng.integers(1, 2 * mean_len, n_docs)
    rows = []
    ptr = [0]
    for d in range(n_docs):
        r = np.unique(rng.integers(0, n_terms, doc_lens[d]))
        rows.append(r)
        ptr.append(ptr[-1] + len(r))
    corpus = Corpus(
        doc_ptr=np.asarray(ptr, np.int64),
        doc_terms=np.concatenate(rows).astype(np.int32),
        n_terms=n_terms,
    )
    assign = rng.integers(0, k, n_docs)
    assign[rng.integers(0, n_docs)] = k - 1
    perm = reorder_permutation(assign, k)
    ranges = cluster_ranges(assign, k)
    index = build_index(corpus)
    reordered = permute_docs(index, perm)
    cidx = build_cluster_index(reordered, ranges)
    return index, reordered, cidx, perm


def _random_ragged_queries(rng, n_q, n_terms, max_arity=5):
    """Arities 1..max_arity, with occasional duplicate terms."""
    lists = []
    for _ in range(n_q):
        a = int(rng.integers(1, max_arity + 1))
        t = rng.integers(0, n_terms, a).tolist()
        if a >= 2 and rng.random() < 0.25:
            t[1] = t[0]  # duplicate term: ∩ is idempotent
        lists.append(t)
    return ConjunctiveQueries.from_lists(lists)


def _assert_multiterm_engine_matches_loop(index, cidx, perm, cq):
    inv = np.empty(len(perm), np.int64)
    inv[perm] = np.arange(len(perm))
    ptr, docs, work = batched_query(cidx, cq)
    counts, _ = batched_counts(cidx, cq)
    assert np.array_equal(counts, np.diff(ptr))
    cl = pr = sc = 0.0
    for i, terms in enumerate(cq):
        want = index.postings(int(terms[0]))
        for t in terms[1:]:
            want = np.intersect1d(want, index.postings(int(t)))
        r1, w1 = cidx.query(*terms)
        r2, w2 = cidx.query_all_clusters(*terms)
        got = docs[ptr[i] : ptr[i + 1]]
        assert np.array_equal(got, r1)  # bit-identical to the loop
        assert np.array_equal(np.sort(inv[r1]), want)
        assert np.array_equal(np.sort(inv[r2]), want)
        cl += w1["cluster_level"]
        pr += w1["probes"]
        sc += w1["scanned"]
    assert work["cluster_level"] == cl
    assert work["probes"] == pr and work["scanned"] == sc


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_multiterm_equivalence_random_corpora(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    n_docs = data.draw(st.integers(50, 300))
    n_terms = data.draw(st.integers(20, 250))
    k = data.draw(st.integers(1, 12))
    index, reordered, cidx, perm = _random_setup(rng, n_docs, n_terms, k)
    n_q = data.draw(st.integers(1, 30))
    cq = _random_ragged_queries(rng, n_q, n_terms)
    _assert_multiterm_engine_matches_loop(index, cidx, perm, cq)


def test_multiterm_absent_terms_and_empty_postings(rng):
    index, reordered, cidx, perm = _random_setup(rng, 150, 500, k=8)
    df = np.diff(index.post_ptr)
    empty = np.flatnonzero(df == 0)
    alive = np.flatnonzero(df > 0)
    assert len(empty) >= 3
    cq = ConjunctiveQueries.from_lists(
        [
            [int(empty[0])],  # single absent term
            [int(empty[0]), int(empty[1]), int(empty[2])],  # all absent
            [int(alive[0]), int(empty[0]), int(alive[1])],  # mixed
            [int(alive[0]), int(alive[1]), int(alive[2])],
            [int(alive[3])],  # single-term query: all its postings
        ]
    )
    ptr, docs, work = batched_query(cidx, cq)
    assert ptr[1] == 0 and ptr[2] == 0 and ptr[3] == 0  # absent ⇒ empty
    inv = np.empty(len(perm), np.int64)
    inv[perm] = np.arange(len(perm))
    want = index.postings(int(alive[3]))
    assert np.array_equal(np.sort(inv[docs[ptr[4] : ptr[5]]]), want)
    _assert_multiterm_engine_matches_loop(index, cidx, perm, cq)


def test_multiterm_single_cluster_k1(rng):
    index, reordered, cidx, perm = _random_setup(rng, 200, 80, k=1)
    cq = _random_ragged_queries(rng, 25, 80)
    assert cidx.k == 1
    _assert_multiterm_engine_matches_loop(index, cidx, perm, cq)


def test_query_accepts_iterable_and_rejects_empty(rng):
    index, reordered, cidx, perm = _random_setup(rng, 100, 40, k=4)
    r1, w1 = cidx.query(3, 7, 11)
    r2, w2 = cidx.query([3, 7, 11])
    assert np.array_equal(r1, r2) and w1 == w2
    with pytest.raises(ValueError):
        cidx.query()


def test_batched_lookup_multiterm_matches_chain(rng):
    index, reordered, cidx, perm = _random_setup(rng, 250, 100, k=6)
    cq = _random_ragged_queries(rng, 60, 100)
    ptr, docs, work = batched_lookup(index, cq, bucket_size=16)
    probes = scanned = 0.0
    for i, terms in enumerate(cq):
        lists = [index.postings(int(t)) for t in terms]
        order = cost_order([len(x) for x in lists])
        cur = lists[order[0]]
        for j in order[1:]:
            cur, w = lookup_intersect(cur, bucketize(lists[j], index.n_docs, 16))
            probes += w["probes"]
            scanned += w["scanned"]
        assert np.array_equal(docs[ptr[i] : ptr[i + 1]], cur)
    assert work["probes"] == probes and work["scanned"] == scanned


def test_padded_and_ragged_forms_agree(rng):
    index, reordered, cidx, perm = _random_setup(rng, 120, 60, k=5)
    cq = _random_ragged_queries(rng, 30, 60)
    ptr_r, docs_r, work_r = batched_query(cidx, cq)
    ptr_p, docs_p, work_p = batched_query(cidx, cq.padded())
    assert np.array_equal(ptr_r, ptr_p)
    assert np.array_equal(docs_r, docs_p)
    assert work_r == work_p


# ----------------------------------------------------------------------
# Serving layer
# ----------------------------------------------------------------------


def test_search_service_multiterm(rng):
    from repro.serve.search_service import SearchService

    index, reordered, cidx, perm = _random_setup(rng, 400, 150, k=8)

    class _Res:  # only the cluster index matters for serving
        cluster_index = cidx

    svc = SearchService(_Res())
    cq = _random_ragged_queries(rng, 40, 150)
    counts, work = svc.serve_counts(cq)
    total = 0.0
    for i, terms in enumerate(cq):
        r, w = cidx.query(*terms)
        assert counts[i] == len(r)
        total += w["total"]
    assert work["work"] == total
    packed = svc.pack(cq)
    assert len(packed.segments) == max(cq.max_arity, 2)
    dev = np.asarray(SearchService.device_counts(packed))
    np.testing.assert_array_equal(dev, counts)


def test_search_service_multiterm_sharded(rng):
    import jax
    from jax.sharding import Mesh

    from repro.serve.search_service import SearchService

    index, reordered, cidx, perm = _random_setup(rng, 300, 100, k=6)

    class _Res:
        cluster_index = cidx

    svc = SearchService(_Res())
    cq = _random_ragged_queries(rng, 24, 100, max_arity=4)
    counts, _ = svc.serve_counts(cq)
    packed = svc.pack(cq)
    mesh = Mesh(np.asarray(jax.devices()).reshape(-1, 1), ("data", "model"))
    dev = np.asarray(SearchService.device_counts(packed, mesh=mesh))
    np.testing.assert_array_equal(dev, counts)


def test_filtered_retriever_three_terms():
    from repro.serve.retrieval import FilteredRetriever, items_as_corpus

    rng = np.random.default_rng(0)
    n_items, n_attrs = 2500, 150
    item_attrs = [
        np.unique(rng.choice(n_attrs, size=rng.integers(2, 12)))
        for _ in range(n_items)
    ]
    items = items_as_corpus(item_attrs, n_attrs)
    r = FilteredRetriever(items, k=16, tc=200)
    a, b, c = 3, 7, 11
    got, report = r.filter(a, b, c)
    want = [i for i, s in enumerate(item_attrs) if a in s and b in s and c in s]
    assert sorted(got.tolist()) == want
    assert report.n_filtered == len(want)
    assert report.filter_work > 0 and report.baseline_work > 0

    # A single-attribute filter intersects nothing: both systems just
    # emit the posting list, so the report prices them equally (1.0x)
    # instead of baseline_work=0 rendering as a 0.0x "regression".
    got1, report1 = r.filter(a)
    want1 = [i for i, s in enumerate(item_attrs) if a in s]
    assert sorted(got1.tolist()) == want1
    assert report1.baseline_work == report1.filter_work == len(want1)
    assert report1.speedup == 1.0


# ----------------------------------------------------------------------
# Multi-term query logs + evaluate
# ----------------------------------------------------------------------


def test_synth_query_log_multiterm(small_corpus):
    from repro.data.query_log import synth_query_log

    log = synth_query_log(
        small_corpus, n_queries=200, seed=3, arity=(2, 3, 5),
        arity_weights=(0.5, 0.3, 0.2),
    )
    assert log.queries.shape == (200, 5)
    ar = log.arities()
    assert set(np.unique(ar)) <= {2, 3, 5}
    assert (ar >= 2).all()
    # terms within a query are distinct and alive
    df = small_corpus.term_doc_freq()
    for row in log.queries:
        t = row[row != QUERY_PAD]
        assert len(np.unique(t)) == len(t)
        assert (df[t] > 0).all()
    # the padded form round-trips through the CSR form
    cq = log.as_conjunctive()
    assert cq.n_queries == 200 and np.array_equal(cq.arities, ar)


def test_synth_query_log_arity2_unchanged(small_corpus):
    """The default 2-term sampler is bit-for-bit the historical one."""
    from repro.data.query_log import synth_query_log

    a = synth_query_log(small_corpus, n_queries=120, seed=11)
    b = synth_query_log(small_corpus, n_queries=120, seed=11, arity=2)
    assert np.array_equal(a.queries, b.queries)
    assert a.queries.shape == (120, 2)


def test_evaluate_multiterm_batched_matches_loop(small_corpus):
    from repro.core.seclud import SecludPipeline
    from repro.data.query_log import synth_query_log

    log = synth_query_log(small_corpus, n_queries=400, seed=5, arity=(2, 3))
    pipe = SecludPipeline(tc=800, doc_grained_below=256, seed=0)
    res = pipe.fit(small_corpus, k=10, algo="topdown", log=log)
    ev_loop = pipe.evaluate(small_corpus, res, log, max_queries=60)
    ev_bat = pipe.evaluate(small_corpus, res, log, max_queries=60, batched=True)
    for key in ("S_T", "S_C", "S_R", "work_baseline", "work_cluster_index",
                "work_reordered"):
        assert ev_loop[key] == ev_bat[key], key
    assert ev_loop["S_C"] > 0 and ev_loop["S_R"] > 0


def test_query_set_cost_multiterm(small_corpus):
    from repro.core.objective import query_set_cost

    rng = np.random.default_rng(2)
    alive = np.flatnonzero(small_corpus.term_doc_freq() > 0)
    q2 = rng.choice(alive, (40, 2))
    # 2-term cost equals the historical pairwise formula
    from repro.index.intersect import pair_cost

    base = query_set_cost(small_corpus, None, 1, q2)
    df = small_corpus.term_doc_freq()
    want = pair_cost(df[q2[:, 0]], df[q2[:, 1]]).sum()
    assert base == pytest.approx(float(want))
    # single-term queries cost nothing; higher arity costs at least as
    # much as its cheapest pair and clustering never increases the cost
    q1 = ConjunctiveQueries.from_lists([[int(alive[0])], [int(alive[1])]])
    assert query_set_cost(small_corpus, None, 1, q1) == 0.0
    q3 = ConjunctiveQueries.from_lists(
        [rng.choice(alive, 3, replace=False).tolist() for _ in range(25)]
    )
    base3 = query_set_cost(small_corpus, None, 1, q3)
    assign = rng.integers(0, 8, small_corpus.n_docs)
    clus3 = query_set_cost(small_corpus, assign, 8, q3)
    assert clus3 <= base3 + 1e-9
    assert base3 > 0


def test_reorder_permutation_validates_k(rng):
    assign = np.array([0, 2, 1, 2])
    perm = reorder_permutation(assign, 3)
    assert sorted(perm.tolist()) == [0, 1, 2, 3]
    with pytest.raises(ValueError):
        reorder_permutation(assign, 2)  # stale k: assignment has cluster 2
    with pytest.raises(ValueError):
        reorder_permutation(np.array([0, -1]), 2)
