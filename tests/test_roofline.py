import numpy as np

from repro.roofline.analysis import (
    V5E,
    RooflineReport,
    collective_bytes_from_hlo,
)


HLO_SAMPLE = """
HloModule jit_step
ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %p0), replica_groups={}
  %ag = f32[256,128]{1,0} all-gather(f32[16,128]{1,0} %ar), dimensions={0}
  %rs = bf16[2,64]{1,0} reduce-scatter(bf16[32,64]{1,0} %x), dimensions={0}
  %a2a = s8[8,8]{1,0} all-to-all(s8[8,8]{1,0} %y), dimensions={0}
  %cp-start = f32[4]{0} collective-permute-start(f32[4]{0} %z)
  %cp-done = f32[4]{0} collective-permute-done(f32[4]{0} %cp-start)
  %not-a-collective = f32[999]{0} add(f32[999]{0} %p0, f32[999]{0} %p0)
}
"""


def test_collective_parser():
    out = collective_bytes_from_hlo(HLO_SAMPLE)
    assert out["all-reduce"] == 16 * 128 * 4
    assert out["all-gather"] == 16 * 128 * 4  # operand, not gathered result
    assert out["reduce-scatter"] == 32 * 64 * 2
    assert out["all-to-all"] == 8 * 8 * 1
    assert out["collective-permute"] == 4 * 4  # -start counted, -done not
    assert out["total"] == sum(
        v for k, v in out.items() if k != "total"
    )


def test_collective_parser_ignores_non_collectives():
    out = collective_bytes_from_hlo("%z = f32[10] add(f32[10] %a, f32[10] %b)")
    assert out["total"] == 0


def test_roofline_report_terms():
    r = RooflineReport(
        arch="x", shape="y", mesh="m", chips=256,
        flops_per_chip=197e12,  # exactly 1 second of compute
        bytes_per_chip=819e9,  # exactly 1 second of HBM
        coll_bytes_per_chip={"total": 25e9},  # 0.5 s of link
        compute_s=1.0, memory_s=1.0, collective_s=0.5,
        model_flops_total=197e12 * 256,  # all useful
        peak_memory_per_chip=8e9,
    )
    assert r.dominant in ("compute", "memory")
    assert np.isclose(r.useful_flop_ratio, 1.0)
    assert np.isclose(r.roofline_fraction, 1.0)
    d = r.to_dict()
    assert d["chips"] == 256 and "dominant" in d


def test_roofline_dominant_collective():
    r = RooflineReport(
        arch="x", shape="y", mesh="m", chips=2,
        flops_per_chip=1.0, bytes_per_chip=1.0,
        coll_bytes_per_chip={"total": int(100e9)},
        compute_s=1e-12, memory_s=1e-12, collective_s=2.0,
        model_flops_total=1.0, peak_memory_per_chip=1.0,
    )
    assert r.dominant == "collective"
    assert r.bound_time_s == 2.0
