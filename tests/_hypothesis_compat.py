"""Property-test shim: real hypothesis when installed, a tiny deterministic
fallback otherwise.

CI installs hypothesis from the pinned dependency set and gets full
shrinking/replay behaviour.  Minimal environments (like the bare container
this repo is grown in) still *run* every property test — the fallback draws
``max_examples`` pseudo-random examples from a seeded generator, so the
tests keep their coverage, deterministically, just without shrinking.

Only the strategy surface this test-suite uses is implemented:
``integers``, ``lists``, ``sampled_from``, ``booleans``, ``data``.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised on CI where hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import inspect
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example_from(self, rng):
            return self._draw(rng)

    class _DataObject:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example_from(self._rng)

    class strategies:  # mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def lists(elements, min_size=0, max_size=10, unique=False):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                out = [elements.example_from(rng) for _ in range(n)]
                if unique:
                    seen = list(dict.fromkeys(out))
                    while len(seen) < min_size:
                        v = elements.example_from(rng)
                        if v not in seen:
                            seen.append(v)
                    out = seen
                return out

            return _Strategy(draw)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def data():
            return _Strategy(lambda rng: _DataObject(rng))

    class settings:
        def __init__(self, max_examples=20, deadline=None, **_ignored):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._max_examples = self.max_examples
            return fn

    def given(*strats):
        def deco(fn):
            def runner(**fixture_kwargs):
                n = getattr(runner, "_max_examples", 20)
                seed0 = zlib.crc32(f"{fn.__module__}.{fn.__name__}".encode())
                for i in range(n):
                    rng = np.random.default_rng(seed0 + i)
                    drawn = [s.example_from(rng) for s in strats]
                    fn(*drawn, **fixture_kwargs)

            # Hide the strategy-bound (leading positional) params so pytest
            # does not try to resolve them as fixtures; keep any trailing
            # fixture params visible.
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())[len(strats):]
            runner.__signature__ = sig.replace(parameters=params)
            runner.__name__ = fn.__name__
            runner.__module__ = fn.__module__
            runner.__doc__ = fn.__doc__
            runner._max_examples = getattr(fn, "_max_examples", 20)
            return runner

        return deco


st = strategies

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st", "strategies"]
