"""Numerical equivalence of the shard_map expert-parallel MoE vs the
dense single-device dispatch.  Runs in a subprocess with 8 fake host
devices so the main test process keeps its single-device view."""

import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # 8-device subprocess with its own jax startup

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")  # skip TPU probing on CI hosts
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.dist import sharding as sh
from repro.models import layers as L

key = jax.random.key(0)
e, d, f, t, k = 8, 16, 32, 64, 2
p = L.moe_init(key, d, f, e, dtype=jnp.float32)
x = jax.random.normal(jax.random.key(1), (t, d), jnp.float32)

# Dense reference (no mesh): generous capacity so nothing drops.
out_ref, aux_ref = L._moe_apply_dense(p, x, k, 8.0, "silu")

mesh = jax.make_mesh((2, 4), ("data", "model"))
sh.set_mesh(mesh)
fn = jax.jit(lambda p_, x_: L.moe_apply(p_, x_, k, 8.0, "silu"))
lowered = fn.lower(
    jax.device_put(p, NamedSharding(mesh, P())),
    jax.device_put(x, NamedSharding(mesh, P("data", None))),
)
assert "all_reduce" in lowered.as_text(), "sharded MoE path did not activate"
out_sh, aux_sh = fn(
    jax.device_put(p, NamedSharding(mesh, P())),
    jax.device_put(x, NamedSharding(mesh, P("data", None))),
)
np.testing.assert_allclose(np.asarray(out_sh), np.asarray(out_ref), rtol=2e-4, atol=2e-5)
# aux loss uses per-data-shard statistics (standard DP-MoE semantics);
# it approximates the global aux within a few percent, not exactly.
np.testing.assert_allclose(float(aux_sh), float(aux_ref), rtol=0.05)
print("MOE_SHARDED_OK")
"""


def test_moe_sharded_matches_dense():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )
    assert "MOE_SHARDED_OK" in r.stdout, r.stdout + r.stderr
