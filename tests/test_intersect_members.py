"""The members-returning intersect kernel vs the ``np.intersect1d``
oracle — tier-1 (the jnp fallback and the kernel's interpret mode both
run on CPU; no TPU marker).

Contract: ``short`` (B, Ls) / ``long`` (B, Ll) are rows of sorted int32
ids padded with PAD; ``intersect_members`` returns the PAD-compacted
member docs (``reduce="docs"``), the in-place masked docs
(``reduce="mask"``) or the count reduction (``reduce="count"``) — all
three bit-identical between the Pallas kernel (per-tile binary probe)
and the pure-jnp reference.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or fallback

from repro.kernels.intersect.ops import intersect_members
from repro.kernels.intersect.ref import (
    PAD,
    intersect_members_docs_ref,
    intersect_members_ref,
)

IPAD = int(PAD)


def _rows(rng, b, ls, ll, universe, dup_rate=0.0):
    short = np.full((b, ls), PAD, np.int32)
    long = np.full((b, ll), PAD, np.int32)
    for r in range(b):
        ns = int(rng.integers(0, ls + 1))
        nl = int(rng.integers(0, ll + 1))
        sv = np.sort(rng.integers(0, universe, ns)) if dup_rate else np.sort(
            rng.choice(universe, min(ns, universe), replace=False)
        )
        lv = np.sort(rng.choice(universe, min(nl, universe), replace=False))
        short[r, : len(sv)] = sv
        long[r, : len(lv)] = lv
    return short, long


def _brute_docs(short, long):
    """Membership semantics: every short element present in long survives
    (duplicates in short are retained, unlike np.intersect1d)."""
    out = np.full_like(short, PAD)
    for r in range(short.shape[0]):
        l = set(long[r][long[r] != IPAD].tolist())
        keep = [x for x in short[r].tolist() if x != IPAD and x in l]
        out[r, : len(keep)] = keep
    return out


def _check_all_paths(short, long):
    want = _brute_docs(short, long)
    ref_docs = np.asarray(intersect_members_docs_ref(short, long))
    np.testing.assert_array_equal(ref_docs, want)
    for reduce, expect in (
        ("docs", want),
        ("count", (want != IPAD).sum(axis=1).astype(np.int32)),
    ):
        got_ref = np.asarray(intersect_members(short, long, reduce=reduce))
        got_kern = np.asarray(
            intersect_members(short, long, reduce=reduce, force_kernel=True)
        )
        np.testing.assert_array_equal(got_ref, expect)
        np.testing.assert_array_equal(got_kern, expect)
    # mask mode: same survivors in place (sorting compacts them)
    for force in (False, True):
        masked = np.asarray(
            intersect_members(short, long, reduce="mask", force_kernel=force)
        )
        np.testing.assert_array_equal(np.sort(masked, axis=1), want)
        hit = masked != IPAD
        np.testing.assert_array_equal(masked[hit], short[hit])


def test_members_matches_intersect1d_oracle():
    rng = np.random.default_rng(0)
    short, long = _rows(rng, 6, 40, 90, universe=300)
    # unique rows: membership == np.intersect1d exactly
    want = _brute_docs(short, long)
    for r in range(short.shape[0]):
        inter = np.intersect1d(
            short[r][short[r] != IPAD], long[r][long[r] != IPAD]
        )
        np.testing.assert_array_equal(want[r, : len(inter)], inter)
        assert (want[r, len(inter):] == IPAD).all()
    _check_all_paths(short, long)


def test_members_pad_only_rows():
    short = np.full((4, 32), PAD, np.int32)
    long = np.full((4, 64), PAD, np.int32)
    _check_all_paths(short, long)
    # PAD never matches PAD even though both sides are full of it
    assert (np.asarray(intersect_members(short, long, reduce="count")) == 0).all()


def test_members_empty_short_or_long_rows():
    rng = np.random.default_rng(1)
    short, long = _rows(rng, 6, 24, 48, universe=100)
    short[0] = PAD  # empty short row
    long[1] = PAD  # empty long row
    short[2] = PAD
    long[2] = PAD  # both empty
    _check_all_paths(short, long)


def test_members_duplicate_doc_ids_are_retained():
    """Duplicates inside a sorted short row each match (membership
    semantics) — where np.intersect1d would deduplicate."""
    short = np.array([[3, 3, 7, 7, 7, PAD, PAD, PAD]], np.int32)
    long = np.array([[1, 3, 7, 9, PAD, PAD, PAD, PAD]], np.int32)
    want = np.array([[3, 3, 7, 7, 7, PAD, PAD, PAD]], np.int32)
    np.testing.assert_array_equal(
        np.asarray(intersect_members(short, long)), want
    )
    np.testing.assert_array_equal(
        np.asarray(intersect_members(short, long, force_kernel=True)), want
    )
    assert int(intersect_members(short, long, reduce="count")[0]) == 5
    assert len(np.intersect1d(short[0][:5], long[0][:4])) == 2  # the contrast


def test_members_non_pow2_widths():
    rng = np.random.default_rng(2)
    for b, ls, ll in [(3, 37, 101), (5, 129, 257), (1, 13, 7), (7, 100, 300)]:
        short, long = _rows(rng, b, ls, ll, universe=4 * ll)
        _check_all_paths(short, long)


def test_members_short_longer_than_long():
    rng = np.random.default_rng(3)
    short, long = _rows(rng, 4, 200, 24, universe=260)
    _check_all_paths(short, long)


def test_members_short_rows_with_pad_holes():
    """The masked k-way fold feeds cur rows whose misses became PAD *in
    place* — PAD holes anywhere, rows no longer sorted-with-PAD-last.
    The kernel must match the ref on those (regression: a lane-0 PAD
    used to collapse the probe window and drop every hit)."""
    rng = np.random.default_rng(5)
    short, long = _rows(rng, 6, 64, 128, universe=300)
    # punch PAD holes into random positions, including lane 0
    hole = rng.random(short.shape) < 0.4
    hole[:, 0] = True
    short = np.where(hole, PAD, short).astype(np.int32)
    want_mask = np.asarray(intersect_members(short, long, reduce="mask"))
    got_mask = np.asarray(
        intersect_members(short, long, reduce="mask", force_kernel=True)
    )
    np.testing.assert_array_equal(got_mask, want_mask)
    np.testing.assert_array_equal(
        np.asarray(intersect_members(short, long, reduce="count", force_kernel=True)),
        np.asarray(intersect_members(short, long, reduce="count")),
    )
    assert (want_mask != IPAD).any()  # the case actually exercises hits


def test_members_rejects_unknown_reduce():
    short = np.full((1, 8), PAD, np.int32)
    with pytest.raises(ValueError):
        intersect_members(short, short, reduce="bogus")


def test_members_mask_is_select_step():
    """reduce='mask' is exactly the hit-masked select the k-way fold
    consumes: hits keep their value and position, misses become PAD."""
    rng = np.random.default_rng(4)
    short, long = _rows(rng, 5, 64, 128, universe=400)
    hit = np.asarray(intersect_members_ref(short, long))
    masked = np.asarray(intersect_members(short, long, reduce="mask"))
    np.testing.assert_array_equal(masked, np.where(hit, short, PAD))


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_members_property(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    b = data.draw(st.integers(1, 6))
    ls = data.draw(st.integers(1, 160))
    ll = data.draw(st.integers(1, 300))
    universe = data.draw(st.integers(4, 2000))
    dup = data.draw(st.booleans())
    short, long = _rows(rng, b, ls, ll, universe, dup_rate=float(dup))
    _check_all_paths(short, long)
