"""Mesh-sharded serving: per-shard DeviceIndex under shard_map.

The exactness anchor of the whole PR: sharded counts AND member docs are
bit-identical to the single-device fused path and to the host loop for
shard counts {1, 2, 4, 8} (clamped to the visible device grid — the CI
shard-matrix re-runs this file under 2 and 8 fake devices) across
arities 1–5 and hierarchy depths L ∈ {1, 2, 3}, plus the partitioning /
routing invariants and the ElasticMesh + StragglerMonitor failover path.
"""

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or fallback

from repro.core.batched_query import batched_query, plan_segment_pairs
from repro.core.cluster_index import build_cluster_index
from repro.core.device_engine import (
    device_counts,
    lower_plan_sharded,
    shard_mesh,
    sharded_device_counts,
    sharded_device_index,
)
from repro.core.hier_index import as_hier, shard_tops
from repro.core.queries import ConjunctiveQueries
from repro.core.reorder import cluster_ranges, reorder_permutation
from repro.data.corpus import Corpus
from repro.index.build import build_index, permute_docs
from repro.kernels.intersect.ref import PAD


def _shard_counts():
    """{1, 2, 4, 8} clamped to the visible device grid."""
    n = len(jax.devices())
    return [s for s in (1, 2, 4, 8) if s <= n]


def _random_setup(rng, n_docs, n_terms, k, mean_len=12):
    doc_lens = rng.integers(1, 2 * mean_len, n_docs)
    rows, ptr = [], [0]
    for d in range(n_docs):
        r = np.unique(rng.integers(0, n_terms, doc_lens[d]))
        rows.append(r)
        ptr.append(ptr[-1] + len(r))
    corpus = Corpus(
        doc_ptr=np.asarray(ptr, np.int64),
        doc_terms=np.concatenate(rows).astype(np.int32),
        n_terms=n_terms,
    )
    assign = rng.integers(0, k, n_docs)
    assign[rng.integers(0, n_docs)] = k - 1
    perm = reorder_permutation(assign, k)
    ranges = cluster_ranges(assign, k)
    index = build_index(corpus)
    reordered = permute_docs(index, perm)
    return index, build_cluster_index(reordered, ranges)


def _random_ragged_queries(rng, n_q, n_terms, max_arity=5):
    lists = []
    for _ in range(n_q):
        a = int(rng.integers(1, max_arity + 1))
        t = rng.integers(0, n_terms, a).tolist()
        if a >= 2 and rng.random() < 0.25:
            t[1] = t[0]  # duplicate term: ∩ is idempotent
        lists.append(t)
    return ConjunctiveQueries.from_lists(lists)


def _assert_sharded_matches_all(cidx, cq):
    """host loop ≡ single-device fused ≡ sharded at every shard count."""
    ptr, docs_host, _w = batched_query(cidx, cq)
    counts_dev, docs_dev, _i = device_counts(cidx, cq, return_docs=True)
    np.testing.assert_array_equal(counts_dev, np.diff(ptr))
    last_info = None
    for s in _shard_counts():
        sidx = sharded_device_index(cidx, mesh=shard_mesh(s))
        counts, docs, info = sharded_device_counts(
            cidx, cq, sidx=sidx, return_docs=True
        )
        np.testing.assert_array_equal(counts, np.diff(ptr))
        np.testing.assert_array_equal(counts, counts_dev)
        np.testing.assert_array_equal(docs, docs_host)
        np.testing.assert_array_equal(docs, docs_dev)
        # A random corpus can produce an all-empty plan (no query's terms
        # co-occur in any leaf cluster): that is the 0-dispatch fast path,
        # not a missing kernel call.
        assert info["n_kernel_calls"] == (1.0 if info["n_pairs"] else 0.0)
        assert info["n_shards"] == float(s)
        assert info["shards_touched"] <= s
        last_info = info
    return last_info


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_sharded_engine_equivalence_random_corpora(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    index, cidx = _random_setup(
        rng,
        data.draw(st.integers(50, 250)),
        data.draw(st.integers(20, 200)),
        data.draw(st.integers(1, 10)),
    )
    cq = _random_ragged_queries(rng, data.draw(st.integers(1, 30)), index.n_terms)
    _assert_sharded_matches_all(cidx, cq)


def test_sharded_engine_every_depth(small_corpus):
    """L = 1 / 2 / 3 hierarchies: sharded ≡ single-device ≡ host at every
    depth and every shard count (at L = 1 the single implicit top node
    lands wholly on shard 0 and the others stay empty)."""
    from repro.core.seclud import SecludPipeline
    from repro.data.query_log import synth_query_log

    log = synth_query_log(small_corpus, n_queries=150, seed=7, arity=(2, 3))
    pipe = SecludPipeline(tc=800, doc_grained_below=256, seed=0)
    cq = log.as_conjunctive()[:60]
    for levels in (1, 2, 3):
        res = pipe.fit(small_corpus, k=8, algo="topdown", log=log, levels=levels)
        _assert_sharded_matches_all(res.hier_index, cq)


def test_sharded_engine_empty_and_absent_terms(rng):
    index, cidx = _random_setup(rng, 150, 500, k=8)
    df = np.diff(index.post_ptr)
    empty = np.flatnonzero(df == 0)
    alive = np.flatnonzero(df > 0)
    cq = ConjunctiveQueries.from_lists(
        [
            [int(empty[0])],
            [int(alive[0]), int(empty[0])],
            [int(alive[0]), int(alive[1]), int(alive[2])],
        ]
    )
    info = _assert_sharded_matches_all(cidx, cq)
    assert info is not None
    # empty batch / empty plan
    for s in _shard_counts():
        sidx = sharded_device_index(cidx, mesh=shard_mesh(s))
        counts, docs, info = sharded_device_counts(
            cidx, np.empty((0, 2), np.int64), sidx=sidx, return_docs=True
        )
        assert len(counts) == 0 and len(docs) == 0
        assert info["shards_touched"] == 0.0
        counts, _ = sharded_device_counts(
            cidx, np.array([[int(empty[0]), int(empty[1])]]), sidx=sidx
        )
        assert counts.tolist() == [0]


# ----------------------------------------------------------------------
# Partitioning and routing invariants
# ----------------------------------------------------------------------


def test_shard_tops_partition_properties(rng):
    index, cidx = _random_setup(rng, 400, 120, k=12)
    hidx = as_hier(cidx)
    k0 = len(hidx.top_ranges) - 1
    docs = hidx.index.post_docs.astype(np.int64)
    top_of_post = np.searchsorted(hidx.top_ranges, docs, side="right") - 1
    mass = np.bincount(top_of_post, minlength=k0)
    for s in (1, 2, 3, 5, 8, k0, k0 + 3):
        bounds = shard_tops(hidx, s)
        assert bounds.shape == (s + 1,)
        assert bounds[0] == 0 and bounds[-1] == k0
        assert (np.diff(bounds) >= 0).all()  # contiguous, no straddling
        # every top node lands in exactly one shard; posting mass is
        # conserved across the partition
        per_shard = [
            int(mass[bounds[i] : bounds[i + 1]].sum()) for i in range(s)
        ]
        assert sum(per_shard) == int(mass.sum())
        if s > k0:
            # more shards than top nodes: surplus shards come back empty
            # (repeated boundaries) rather than splitting a node
            assert int((np.diff(bounds) == 0).sum()) >= s - k0
    with pytest.raises(ValueError):
        shard_tops(hidx, 0)


def test_shard_tops_balances_posting_mass(rng):
    """With many equal-mass top nodes the partition is near-perfect."""
    index, cidx = _random_setup(rng, 600, 80, k=24)
    hidx = as_hier(cidx)
    docs = hidx.index.post_docs.astype(np.int64)
    k0 = len(hidx.top_ranges) - 1
    mass = np.bincount(
        np.searchsorted(hidx.top_ranges, docs, side="right") - 1, minlength=k0
    )
    bounds = shard_tops(hidx, 4)
    per_shard = np.array(
        [mass[bounds[i] : bounds[i + 1]].sum() for i in range(4)]
    )
    # quantile cuts: no shard exceeds its fair share by more than the
    # single largest top node (the indivisible unit)
    assert per_shard.max() <= mass.sum() / 4 + mass.max()


def test_slice_top_shard_views_answer_locally(small_corpus, small_log):
    """Each shard's host view (SecludResult.shard_slices) returns exactly
    the full index's hits restricted to the shard's doc range."""
    from repro.core.seclud import SecludPipeline

    pipe = SecludPipeline(tc=800, doc_grained_below=256, seed=0)
    res = pipe.fit(small_corpus, k=8, algo="topdown", log=small_log, levels=3)
    hidx = res.hier_index
    cq = small_log.as_conjunctive()[:40]
    ptr, docs, _w = batched_query(hidx, cq)
    bounds, views = res.shard_slices(3)
    doc_bounds = hidx.top_ranges[bounds]
    got_all = []
    for s, view in enumerate(views):
        assert view.index is hidx.index  # shares postings, no copy
        vptr, vdocs, _ = batched_query(view, cq)
        lo, hi = int(doc_bounds[s]), int(doc_bounds[s + 1])
        assert ((vdocs >= lo) & (vdocs < hi)).all()
        got_all.append((vptr, vdocs))
    # per-query union over shards == full index results
    for q in range(cq.n_queries):
        want = docs[ptr[q] : ptr[q + 1]]
        got = np.concatenate(
            [vdocs[vptr[q] : vptr[q + 1]] for vptr, vdocs in got_all]
        )
        np.testing.assert_array_equal(np.sort(got), np.sort(want))


def test_sharded_lowered_plan_routing(rng):
    """Groups route to the shard owning their docs; stacked rows carry
    the dead-cell conventions the fold's masking relies on."""
    n_dev = len(jax.devices())
    if n_dev < 2:
        pytest.skip("needs >= 2 devices for a multi-shard mesh")
    s = min(4, n_dev)
    index, cidx = _random_setup(rng, 300, 100, k=9)
    cq = _random_ragged_queries(rng, 25, 100)
    sidx = sharded_device_index(cidx, mesh=shard_mesh(s))
    plan = plan_segment_pairs(as_hier(cidx), cq, track_work=False)
    lowered = lower_plan_sharded(plan, sidx)
    # every group's doc range sits inside its assigned shard's doc range
    for g in range(plan.n_pairs):
        sh = int(lowered.grp_shard[g])
        assert sidx.doc_bounds[sh] <= plan.base[g] < sidx.doc_bounds[sh + 1]
    # true-cell mass is conserved and group offsets tile each shard row
    assert lowered.n_cells_true.sum() == int(
        plan.seg_len[plan.seg_ptr[:-1]].sum()
    )
    for sh in range(s):
        g_in = np.flatnonzero(lowered.grp_shard == sh)
        assert lowered.grp_cnt[g_in].sum() == lowered.n_cells_true[sh]
        # beyond the true cells, rows are dead: post PAD, arity 0, query
        # out of range (segment_sum drops them)
        t = int(lowered.n_cells_true[sh])
        assert (lowered.cells[sh, 0, t:] == PAD).all()
        assert (lowered.cells[sh, 3, t:] == 0).all()
        assert (lowered.cells[sh, 2, t:] >= lowered.n_queries).all()


def test_sharded_index_cached_per_mesh(rng):
    index, cidx = _random_setup(rng, 120, 60, k=5)
    mesh = shard_mesh(min(2, len(jax.devices())))
    a = sharded_device_index(cidx, mesh=mesh)
    b = sharded_device_index(cidx, mesh=mesh)
    assert a is b
    assert a.nbytes > 0
    # the per-shard rows hold exactly the global postings, re-bucketed
    stacked = np.asarray(a.post_docs)
    live = stacked[stacked != PAD]
    assert len(live) == len(cidx.index.post_docs)
    np.testing.assert_array_equal(
        np.sort(live), np.sort(cidx.index.post_docs)
    )
    # per-shard rows hold only docs inside the shard's doc range
    for sh in range(a.n_shards):
        row = stacked[sh, : int(a.shard_counts[sh])]
        assert ((row >= a.doc_bounds[sh]) & (row < a.doc_bounds[sh + 1])).all()


# ----------------------------------------------------------------------
# Shard failover through the serving layer
# ----------------------------------------------------------------------


def _service(small_corpus, small_log):
    from repro.core.seclud import SecludPipeline
    from repro.serve.search_service import SearchService

    pipe = SecludPipeline(tc=800, doc_grained_below=256, seed=0)
    res = pipe.fit(small_corpus, k=8, algo="topdown", log=small_log, levels=2)
    return res, SearchService(res)


def test_serve_counts_device_sharded_path(small_corpus, small_log):
    res, svc = _service(small_corpus, small_log)
    cq = small_log.as_conjunctive()[:40]
    host, _ = svc.serve_counts(cq)
    single, single_docs, _ = svc.serve_counts_device(cq, return_docs=True)
    np.testing.assert_array_equal(single, host)
    s = min(4, len(jax.devices()))
    svc.enable_sharded(n_shards=s)
    assert svc.n_shards == s
    counts, docs, info = svc.serve_counts_device(cq, return_docs=True)
    np.testing.assert_array_equal(counts, host)
    np.testing.assert_array_equal(docs, single_docs)
    assert info["n_shards"] == float(s)


def test_shard_failover_rebalances_and_stays_exact(small_corpus, small_log):
    """Evict one fake device through the monitor: the mesh shrinks, the
    survivors absorb the evicted shard's top clusters, and results stay
    bit-identical."""
    n_dev = len(jax.devices())
    if n_dev < 2:
        pytest.skip("needs >= 2 devices to lose one")
    res, svc = _service(small_corpus, small_log)
    cq = small_log.as_conjunctive()[:40]
    host, _ = svc.serve_counts(cq)

    s = min(4, n_dev)
    svc.enable_sharded(n_shards=s, strikes_to_evict=2)
    before = svc.sharded_index
    evict = s - 1  # the last shard: its top clusters must be re-owned
    lost_tops = set(
        range(
            int(before.top_bounds[evict]), int(before.top_bounds[evict + 1])
        )
    )
    lost_device = np.asarray(before.mesh.devices).reshape(-1)[evict]

    times = np.ones(s)
    times[evict] = 50.0  # persistently past the 1.5x-median deadline
    verdicts, remeshed = svc.record_shard_times(times)
    assert not remeshed and verdicts[evict].slow
    verdicts, remeshed = svc.record_shard_times(times)
    assert remeshed and verdicts[evict].evict
    assert svc._elastic.epoch == 2  # enable_sharded meshed once already

    after = svc.sharded_index
    assert after.n_shards == s - 1
    assert lost_device.id not in {
        d.id for d in np.asarray(after.mesh.devices).reshape(-1)
    }
    # the new partition still covers every top cluster (the lost shard's
    # clusters re-routed to the survivors) and the whole corpus
    k0 = len(after.host.top_ranges) - 1
    assert after.top_bounds[0] == 0 and after.top_bounds[-1] == k0
    assert after.doc_bounds[-1] == after.host.index.n_docs
    covered = set()
    for sh in range(after.n_shards):
        covered |= set(
            range(int(after.top_bounds[sh]), int(after.top_bounds[sh + 1]))
        )
    assert lost_tops <= covered
    # ... and serving stays bit-identical through the failover
    counts, docs, info = svc.serve_counts_device(cq, return_docs=True)
    np.testing.assert_array_equal(counts, host)
    _c, docs_single, _i = device_counts(svc.query_index, cq, return_docs=True)
    np.testing.assert_array_equal(docs, docs_single)
    assert info["n_shards"] == float(s - 1)
    # the fresh monitor watches the new, smaller world
    assert svc._monitor.n_hosts == s - 1


def test_record_shard_times_requires_enable(small_corpus, small_log):
    _res, svc = _service(small_corpus, small_log)
    with pytest.raises(RuntimeError):
        svc.record_shard_times([1.0, 1.0])


def test_elastic_mesh_exclude_device():
    """Device-granular eviction: fake CPU devices all share process 0,
    so exclude_host cannot shrink the pool — exclude_device must."""
    from repro.dist.fault_tolerance import ElasticMesh

    n_dev = len(jax.devices())
    if n_dev < 2:
        pytest.skip("needs >= 2 devices")
    em = ElasticMesh(model_parallel=1)
    mesh = em.remesh()
    assert int(np.prod(tuple(mesh.shape.values()))) == n_dev
    em.exclude_device(int(jax.devices()[0].id))
    mesh2 = em.remesh()  # bare remesh reuses the remembered pool
    assert int(np.prod(tuple(mesh2.shape.values()))) == n_dev - 1
    ids = {d.id for d in np.asarray(mesh2.devices).reshape(-1)}
    assert jax.devices()[0].id not in ids
    assert em.epoch == 2
