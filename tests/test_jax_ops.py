import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core.jax_ops import (
    counts_from_ell,
    delta_add_tables_jax,
    ell_pack,
    kmeans_round_jax,
    psi_jax,
    scores_from_ell,
)
from repro.core.objective import (
    assignment_scores,
    cluster_counts,
    delta_add_tables,
    psi_from_counts,
)


@pytest.fixture(scope="module")
def packed(small_view):
    sub = small_view.subset(np.arange(600))
    ell, l_pad = ell_pack(sub)
    return sub, ell, l_pad


def test_ell_pack_contents(packed):
    sub, ell, l_pad = packed
    indptr, indices = sub.mat.indptr, sub.mat.indices
    for d in (0, 11, 599):
        ranks = np.sort(indices[indptr[d] : indptr[d + 1]])
        row = ell[d]
        assert np.array_equal(row[row < sub.tc], ranks[:l_pad])


def test_counts_match_numpy(packed):
    sub, ell, _ = packed
    k = 5
    assign = np.arange(sub.n_docs) % k
    got = np.asarray(counts_from_ell(jnp.asarray(ell), jnp.asarray(assign), k, sub.tc))
    want = cluster_counts(sub, assign, k)
    np.testing.assert_array_equal(got, want)


def test_psi_matches_numpy(packed):
    sub, ell, _ = packed
    k = 5
    assign = np.arange(sub.n_docs) % k
    counts = cluster_counts(sub, assign, k)
    got = float(psi_jax(jnp.asarray(counts), jnp.asarray(sub.p_freq, jnp.float32)))
    want = psi_from_counts(counts, sub.p_freq)
    assert np.isclose(got, want, rtol=1e-4)


def test_tables_match_numpy(packed):
    sub, ell, _ = packed
    k = 5
    assign = np.arange(sub.n_docs) % k
    counts = cluster_counts(sub, assign, k)
    got = np.asarray(
        delta_add_tables_jax(jnp.asarray(counts), jnp.asarray(sub.p_freq, jnp.float32))
    )
    want = delta_add_tables(counts, sub.p_freq)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-6)


def test_scores_match_numpy(packed):
    sub, ell, _ = packed
    k = 5
    rng = np.random.default_rng(0)
    tables = rng.random((k, sub.tc)).astype(np.float32)
    got = np.asarray(
        scores_from_ell(
            jnp.asarray(ell), jnp.asarray(tables), jnp.asarray(sub.p_freq, jnp.float32),
            block=128,
        )
    )
    want = assignment_scores(sub, tables)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)


def test_kmeans_round_jax_improves_psi(packed):
    sub, ell, _ = packed
    k = 5
    assign = np.arange(sub.n_docs) % k
    new_assign, psi0 = kmeans_round_jax(
        jnp.asarray(ell), jnp.asarray(assign), jnp.asarray(sub.p_freq, jnp.float32),
        k, sub.tc, block=128,
    )
    counts1 = cluster_counts(sub, np.asarray(new_assign), k)
    psi1 = psi_from_counts(counts1, sub.p_freq)
    assert psi1 <= float(psi0) * 1.001
