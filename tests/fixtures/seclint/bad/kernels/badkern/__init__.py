"""seclint fixture: a kernel package violating the SEC004 contract —
it ships only ``kernel.py``, with no ``ref.py`` oracle, no ``ops.py``
wrapper, and no kernel≡ref test."""
