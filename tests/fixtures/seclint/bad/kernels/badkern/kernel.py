"""seclint fixture: SEC004 — a kernel with no ref oracle or ops wrapper."""


def badkern_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]
