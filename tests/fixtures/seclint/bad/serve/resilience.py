"""Bad fixture: resilience-defeating error handling (SEC006)."""


def dispatch_forever(engine, batch):
    # BAD: unbounded retry spin — no break/return/raise in the loop's
    # own body, so a dead shard hangs the serving loop forever instead
    # of degrading to the host path.
    results = []
    while True:
        out = engine(batch)
        results.append(out)


def swallow(engine, batch):
    for attempt in range(3):
        try:
            return engine(batch)
        except Exception:
            # BAD: the failure is observed by no one — no breaker
            # strike, no shard-time record, no fallback level.
            continue
    return None


def hide_everything(engine, batch):
    try:
        return engine(batch)
    except:  # noqa: E722  BAD: bare except hides which failure fired
        pass
