"""Bad fixture: jit constructed per request in the serving path (SEC005)."""

import functools

import jax


def fold(counts):
    return counts.sum()


async def handle_request(batch):
    # BAD: a fresh jit per request — empty compile cache every call,
    # the startup shape-grid prewarm can never cover it.
    fn = jax.jit(fold)
    return fn(batch)


def dispatch(batch, n):
    # BAD: partial(jax.jit, ...) is the same construction, spelled
    # differently.
    fn = functools.partial(jax.jit, static_argnames=("n",))(fold)
    return fn(batch, n=n)
