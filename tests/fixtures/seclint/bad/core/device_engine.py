"""seclint fixture: every per-file rule (SEC001–SEC003) must trip here.

This file is a deliberately broken miniature of the real device engine —
it is never imported, only parsed by ``tools/seclint.py --selftest`` and
``tests/test_seclint.py``.  Its path suffix (``core/device_engine.py``)
is what routes it into the device-path rule set.  Each violation below
names the rule it exists to prove alive; if a rule stops tripping on
this file, the selftest fails the build.
"""

import functools

import jax
import numpy as np

# --- SEC001: host-device sync points inside traced code ---------------


@jax.jit
def bad_sync(x, y):
    if x:  # SEC001: implicit bool() on a traced value
        y = y + 1
    n = int(x)  # SEC001: int() on a traced value
    s = x.item()  # SEC001: .item() on a traced value
    h = np.asarray(y)  # SEC001: implicit device->host transfer
    return n + s + h


# --- SEC002a: jit constructed inside a function body ------------------


def fold_per_batch(cells):
    # SEC002: a fresh jit per call — every batch retraces.
    return jax.jit(lambda c: c + 1)(cells)


# --- SEC002b: unhashable static arg default ---------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def bad_static(x, cfg=[]):  # SEC002: list default cannot key the cache
    return x


# --- SEC002c: dynamic shape leaking into the jit cache key ------------


def _fold_core(cells, n_queries_pad):
    return cells


_fused_fold = functools.partial(jax.jit, static_argnames=("n_queries_pad",))(
    _fold_core
)


def run_batch(cells, queries):
    # SEC002: raw len() as a static arg — every batch size recompiles.
    return _fused_fold(cells, n_queries_pad=len(queries))


# --- SEC003: literal -1 sentinels on cell data ------------------------


def lower(cells, cell_post):
    cells[0] = -1  # SEC003: fill must use PAD
    return cell_post == -1  # SEC003: comparison must use PAD
