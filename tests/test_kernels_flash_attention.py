import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

pytestmark = pytest.mark.slow  # Pallas kernel sweeps in interpret mode


def _qkv(rng, b, h, lq, lk, d, dtype=np.float32):
    q = rng.standard_normal((b, h, lq, d)).astype(dtype)
    k = rng.standard_normal((b, h, lk, d)).astype(dtype)
    v = rng.standard_normal((b, h, lk, d)).astype(dtype)
    return q, k, v


def _brute(q, k, v, causal, window=None):
    b, h, lq, d = q.shape
    lk = k.shape[2]
    s = np.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(d)
    off = lk - lq
    i = np.arange(lq)[:, None]
    j = np.arange(lk)[None, :]
    mask = np.ones((lq, lk), bool)
    if causal:
        mask &= j <= i + off
    if window is not None:
        mask &= j > i + off - window
    s = np.where(mask, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bhkd->bhqd", p, v)


@pytest.mark.parametrize(
    "b,h,lq,lk,d,causal",
    [
        (1, 1, 128, 128, 64, True),
        (2, 2, 128, 128, 64, False),
        (1, 2, 128, 256, 32, True),  # decode-ish: kv longer than q
        (1, 1, 256, 256, 128, True),
    ],
)
def test_flash_matches_brute(b, h, lq, lk, d, causal):
    rng = np.random.default_rng(b + h + lq + lk + d)
    q, k, v = _qkv(rng, b, h, lq, lk, d)
    want = _brute(q, k, v, causal)
    got_ref = np.asarray(attention_ref(q, k, v, causal=causal))
    got_kern = np.asarray(flash_attention(q, k, v, causal=causal, force_kernel=True))
    np.testing.assert_allclose(got_ref, want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(got_kern, want, rtol=2e-4, atol=2e-4)


def test_sliding_window():
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, 1, 2, 256, 256, 64)
    for window in (64, 128):
        want = _brute(q, k, v, True, window=window)
        got = np.asarray(
            flash_attention(q, k, v, causal=True, window=window, force_kernel=True)
        )
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_tile_sweep():
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, 1, 1, 256, 512, 64)
    want = _brute(q, k, v, True)
    for tq, tk in [(64, 64), (128, 256), (256, 128)]:
        got = np.asarray(
            flash_attention(
                q, k, v, causal=True, tile_q=tq, tile_k=tk, force_kernel=True
            )
        )
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_bf16():
    rng = np.random.default_rng(2)
    import jax.numpy as jnp

    q, k, v = _qkv(rng, 1, 1, 128, 128, 64)
    qb, kb, vb = (jnp.asarray(x, jnp.bfloat16) for x in (q, k, v))
    want = _brute(q, k, v, True)
    got = np.asarray(
        flash_attention(qb, kb, vb, causal=True, force_kernel=True)
    ).astype(np.float32)
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


def test_decode_single_query():
    """Lq=1 decode shape (tile_q clamps to 1)."""
    rng = np.random.default_rng(3)
    q, k, v = _qkv(rng, 2, 4, 1, 512, 64)
    want = _brute(q, k, v, True)
    got = np.asarray(flash_attention(q, k, v, causal=True, force_kernel=True))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
