"""shard_map flash-decode == single-device cached decode (subprocess,
8 fake devices; bf16-class and int8 caches, windowed and global)."""

import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")  # skip TPU probing on CI hosts
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models import transformer as T
from repro.dist import sharding as sh

CASES = [(False, None, 2e-5), (True, None, 6e-2), (False, 6, 2e-5)]
cfgs, refs, seqs, params_list = [], [], [], []

# Pass 1: references on the single-device path (no mesh set).
for kv_quant, window, tol in CASES:
    cfg = T.LMConfig(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                     head_dim=16, d_ff=64, vocab=64, dtype="float32",
                     loss_chunk=4, kv_quant=kv_quant,
                     window=window, global_every=2 if window else None)
    params = T.init(cfg, jax.random.key(0))
    seq = jax.random.randint(jax.random.key(1), (4, 9), 0, 64)
    c0 = T.init_cache(cfg, 4, 16)
    lg, c0 = T.prefill(params, cfg, seq[:, :-1], c0)
    ref, _ = T.decode_step(params, cfg, seq[:, -1:], c0)
    cfgs.append(cfg); refs.append(np.asarray(ref)); seqs.append(seq)
    params_list.append(params)

# Pass 2: sharded path under the mesh.
mesh = jax.make_mesh((2, 4), ("data", "model"))
sh.set_mesh(mesh)
for (kv_quant, window, tol), cfg, ref, seq, params in zip(
    CASES, cfgs, refs, seqs, params_list, strict=True
):
    cspecs = sh.cache_specs(jax.eval_shape(lambda: T.init_cache(cfg, 4, 16)), mesh)
    c1 = T.init_cache(cfg, 4, 16)
    c1 = jax.tree.map(
        lambda a, s_: None if a is None else jax.device_put(
            a, NamedSharding(mesh, s_ if s_ is not None else P())
        ),
        c1, cspecs, is_leaf=lambda x: x is None,
    )
    lg1, c1 = jax.jit(lambda p_, t_, c_: T.prefill(p_, cfg, t_, c_))(params, seq[:, :-1], c1)
    got, _ = jax.jit(lambda p_, t_, c_: T.decode_step(p_, cfg, t_, c_))(params, seq[:, -1:], c1)
    err = np.abs(np.asarray(got) - ref).max() / max(np.abs(ref).max(), 1e-9)
    assert err < tol, (kv_quant, window, float(err))
print("FLASH_DECODE_OK")
"""


def test_flash_decode_matches_reference():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )
    assert "FLASH_DECODE_OK" in r.stdout, r.stdout + r.stderr[-3000:]
