"""Distributed (shard_map) clustering matches the host implementation's
objective behaviour. Runs in a subprocess with 8 fake devices."""

import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")  # skip TPU probing on CI hosts
import jax, numpy as np
from repro.data.corpus import CorpusSpec, synth_corpus
from repro.data.query_log import synth_query_log, term_probabilities
from repro.core.objective import frequent_term_view, cluster_counts, psi_from_counts
from repro.dist.cluster_dist import distributed_kmeans

corpus = synth_corpus(CorpusSpec(n_docs=600, n_terms=800, mean_doc_len=25,
                                 n_topics=6, seed=0))
log = synth_query_log(corpus, n_queries=300, seed=1)
p = term_probabilities(corpus.n_terms, log=log)
view = frequent_term_view(corpus, p, tc=300)

mesh = jax.make_mesh((4, 2), ("data", "model"))
assign, psi = distributed_kmeans(view, k=6, mesh=mesh, max_iters=20)
assert assign.shape == (600,)
assert assign.min() >= 0 and assign.max() < 6

# psi reported by the device round == host recomputation
host_psi = psi_from_counts(cluster_counts(view, assign, 6), view.p_freq)
# (device psi is from BEFORE the last accepted move; compare loosely)
rng = np.random.default_rng(0)
rand_psi = psi_from_counts(
    cluster_counts(view, rng.integers(0, 6, 600), 6), view.p_freq
)
assert host_psi < rand_psi, (host_psi, rand_psi)
print("DIST_KMEANS_OK", psi, host_psi, rand_psi)
"""


def test_distributed_kmeans():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )
    assert "DIST_KMEANS_OK" in r.stdout, r.stdout + r.stderr
