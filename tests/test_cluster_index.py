import numpy as np
import pytest

from repro.core.cluster_index import build_cluster_index
from repro.core.reorder import cluster_ranges, reorder_permutation
from repro.index.build import build_index, permute_docs


@pytest.fixture(scope="module")
def setup(small_corpus):
    rng = np.random.default_rng(0)
    k = 12
    assign = rng.integers(0, k, small_corpus.n_docs)
    perm = reorder_permutation(assign, k)
    ranges = cluster_ranges(assign, k)
    index = build_index(small_corpus)
    reordered = permute_docs(index, perm)
    cidx = build_cluster_index(reordered, ranges)
    return small_corpus, index, reordered, cidx, perm, ranges, assign


def test_reorder_permutation_is_cluster_contiguous(setup):
    corpus, index, reordered, cidx, perm, ranges, assign = setup
    k = len(ranges) - 1
    for i in range(k):
        docs_in = np.flatnonzero(assign == i)
        new_ids = perm[docs_in]
        assert new_ids.min() == ranges[i]
        assert new_ids.max() == ranges[i + 1] - 1


def test_permute_docs_sorted(setup):
    _, _, reordered, *_ = setup
    for t in range(0, reordered.n_terms, 371):
        p = reordered.postings(t)
        assert np.all(np.diff(p) > 0)


def test_cluster_index_segments_exact(setup):
    corpus, index, reordered, cidx, perm, ranges, assign = setup
    # For sampled terms: segments partition the posting list and each
    # segment holds exactly the docs of that cluster.
    for t in range(0, corpus.n_terms, 499):
        cl, s, e = cidx.term_segments(t)
        post = reordered.postings(t)
        assert (e - s).sum() == len(post)
        for c, a, b in zip(cl, s, e, strict=True):
            seg = reordered.post_docs[a:b]
            assert np.all(seg >= ranges[c]) and np.all(seg < ranges[c + 1])


def test_cluster_index_query_lossless(setup):
    corpus, index, reordered, cidx, perm, ranges, assign = setup
    rng = np.random.default_rng(3)
    df = corpus.term_doc_freq()
    alive = np.flatnonzero(df > 2)
    inv = np.empty(corpus.n_docs, dtype=np.int64)
    inv[perm] = np.arange(corpus.n_docs)
    for _ in range(30):
        t, u = rng.choice(alive, 2, replace=False)
        want = np.intersect1d(index.postings(int(t)), index.postings(int(u)))
        got, work = cidx.query(int(t), int(u))
        got2, work2 = cidx.query_all_clusters(int(t), int(u))
        assert np.array_equal(np.sort(inv[got]), want)
        assert np.array_equal(np.sort(inv[got2]), want)
        assert work["total"] >= 0 and work2["total"] >= 0
