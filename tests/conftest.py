import os
import sys
from pathlib import Path

# Must be set before jax first initializes its backend: the mesh tests
# (e.g. the (4,2) mesh in test_cluster_dist.py, (2,4) in test_flash_decode)
# need >= 8 devices, and CI runners are CPU-only.  setdefault so an outer
# environment (TPU runs) can still override.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# `benchmarks` is a repo-root package (not installed by `pip install -e .`,
# which only ships src/): put the root on sys.path so the perf-gate tests
# can import benchmarks.compare under bare `pytest` as well as
# `python -m pytest`.
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import numpy as np
import pytest

from repro.data.corpus import CorpusSpec, synth_corpus
from repro.data.query_log import synth_query_log, term_probabilities
from repro.core.objective import frequent_term_view

try:  # hypothesis is a pinned dev dependency; keep working without it
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci",
        deadline=None,  # CI runners have noisy timing; never flake on it
        max_examples=25,
        suppress_health_check=[HealthCheck.too_slow],
    )
    if os.environ.get("CI"):
        settings.load_profile("ci")
except ModuleNotFoundError:
    pass


def pytest_report_header(config):
    # Make sanitize-mode CI runs self-documenting: the header says
    # whether the REPRO_DEBUG validation head is live for this run.
    from repro.analysis.runtime import debug_enabled

    state = "ON (validate() runs on every build)" if debug_enabled() else "off"
    return f"repro: REPRO_DEBUG validation {state}"


@pytest.fixture
def repro_debug():
    """Force the REPRO_DEBUG validation head on for one test."""
    from repro.analysis.runtime import force_debug

    with force_debug(True):
        yield


@pytest.fixture
def transfer_guard():
    """Run one test under the implicit host<->device transfer sanitizer."""
    from repro.analysis.sanitize import no_implicit_transfers

    with no_implicit_transfers():
        yield


@pytest.fixture(scope="session")
def small_corpus():
    spec = CorpusSpec(
        n_docs=1500,
        n_terms=3000,
        mean_doc_len=40,
        n_topics=8,
        topicality=0.6,
        seed=7,
    )
    return synth_corpus(spec)


@pytest.fixture(scope="session")
def small_log(small_corpus):
    return synth_query_log(small_corpus, n_queries=300, seed=11)


@pytest.fixture(scope="session")
def small_p(small_corpus, small_log):
    return term_probabilities(small_corpus.n_terms, log=small_log)


@pytest.fixture(scope="session")
def small_view(small_corpus, small_p):
    return frequent_term_view(small_corpus, small_p, tc=800)


@pytest.fixture(scope="session")
def small_seclud(small_corpus, small_log):
    """One fitted SeCluD pipeline shared by the serving-tier suites
    (the fit is the expensive part; SearchService instances built on it
    per-test stay independent — serving state lives on the service)."""
    from repro.core.seclud import SecludPipeline

    pipe = SecludPipeline(tc=800, doc_grained_below=256, seed=0)
    return pipe.fit(
        small_corpus, k=8, algo="topdown", log=small_log, levels=2
    )


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
