"""The arbitrary-depth hierarchical index: degeneracy, exactness and
pipeline contracts.

The contract under test (the PR's acceptance criteria):

* **L = 2 bit-identity** — a ``HierIndex`` with one cluster level
  reproduces the historical ``ClusterIndex`` facade exactly: results AND
  work dicts, per query and batched.
* **L = 1 degeneracy** — zero cluster levels IS the flat single-index
  cost-ordered Lookup chain (``chain_lookup`` / ``batched_lookup``),
  bit-for-bit including work.
* **Exactness at every depth** — L ∈ {1, 2, 3} all return the identical
  result sets, equal to chained ``np.intersect1d``, on randomized
  corpora including empty postings, k = 1 clusters, absent terms and
  duplicate query terms; the batched engine and the device count path
  agree with the per-query loop at every depth.
* **TopDown ≡ FM result sets** — a hierarchy grown from TopDown leaf
  assignments returns the same result sets as the FM-grown one (the
  clustering only moves work around, never answers).
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or fallback

from repro.core.batched_query import (
    batched_counts,
    batched_lookup,
    batched_query,
)
from repro.core.cluster_index import build_cluster_index
from repro.core.hier_index import HierIndex, as_hier, build_hier_index
from repro.core.objective import hier_query_set_cost, query_set_cost
from repro.core.queries import ConjunctiveQueries
from repro.core.reorder import cluster_ranges, reorder_permutation
from repro.core.seclud import SecludPipeline
from repro.data.corpus import Corpus
from repro.index.build import build_index, permute_docs
from repro.index.lookup import chain_lookup


# ----------------------------------------------------------------------
# Randomized nested setups
# ----------------------------------------------------------------------


def _random_corpus(rng, n_docs, n_terms, mean_len=12):
    doc_lens = rng.integers(1, 2 * mean_len, n_docs)
    rows, ptr = [], [0]
    for d in range(n_docs):
        r = np.unique(rng.integers(0, n_terms, doc_lens[d]))
        rows.append(r)
        ptr.append(ptr[-1] + len(r))
    return Corpus(
        doc_ptr=np.asarray(ptr, np.int64),
        doc_terms=np.concatenate(rows).astype(np.int32),
        n_terms=n_terms,
    )


def _nested_setup(rng, n_docs, n_terms, k, k0):
    """Random leaf clustering + random parent map, renumbered so parents
    own contiguous leaf blocks; returns indexes at depths 1, 2, 3 over
    the SAME reordered id space."""
    corpus = _random_corpus(rng, n_docs, n_terms)
    assign = rng.integers(0, k, n_docs)
    assign[rng.integers(0, n_docs)] = k - 1  # force cluster k-1 nonempty
    parent = rng.integers(0, k0, k)
    order = np.argsort(parent, kind="stable")
    rank = np.empty(k, np.int64)
    rank[order] = np.arange(k)
    assign2 = rank[assign]  # leaf ids grouped by parent
    perm = reorder_permutation(assign2, k)
    ranges_leaf = cluster_ranges(assign2, k)
    sizes_leaf = np.diff(ranges_leaf)
    ranges_top = np.zeros(k0 + 1, np.int64)
    np.add.at(ranges_top, parent[order] + 1, sizes_leaf)
    np.cumsum(ranges_top, out=ranges_top)
    index = build_index(corpus)
    reordered = permute_docs(index, perm)
    h1 = build_hier_index(reordered, [])
    h2 = build_hier_index(reordered, [ranges_leaf])
    h3 = build_hier_index(reordered, [ranges_top, ranges_leaf])
    cidx = build_cluster_index(reordered, ranges_leaf)
    return corpus, index, reordered, perm, cidx, h1, h2, h3


def _random_ragged_queries(rng, n_q, n_terms, max_arity=5):
    lists = []
    for _ in range(n_q):
        a = int(rng.integers(1, max_arity + 1))
        t = rng.integers(0, n_terms, a).tolist()
        if a >= 2 and rng.random() < 0.25:
            t[1] = t[0]  # duplicate term: ∩ is idempotent
        lists.append(t)
    return ConjunctiveQueries.from_lists(lists)


def _brute(index, terms):
    want = index.postings(int(terms[0]))
    for t in terms[1:]:
        want = np.intersect1d(want, index.postings(int(t)))
    return want


# ----------------------------------------------------------------------
# Degeneracy + exactness at every depth
# ----------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_hier_depths_agree_and_match_oracle(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    n_docs = data.draw(st.integers(50, 250))
    n_terms = data.draw(st.integers(20, 200))
    k = data.draw(st.integers(1, 12))
    k0 = data.draw(st.integers(1, max(1, k // 2 + 1)))
    corpus, index, reordered, perm, cidx, h1, h2, h3 = _nested_setup(
        rng, n_docs, n_terms, k, k0
    )
    inv = np.empty(n_docs, np.int64)
    inv[perm] = np.arange(n_docs)
    cq = _random_ragged_queries(rng, data.draw(st.integers(1, 20)), n_terms)
    for h in (h1, h2, h3):
        ptr, docs, work = batched_query(h, cq)
        counts, _ = batched_counts(h, cq)
        assert np.array_equal(counts, np.diff(ptr))
        sums = {}
        for i, terms in enumerate(cq):
            want = _brute(index, terms)
            r_loop, w_loop = h.query(*terms)
            r_merge, w_merge = h.query_all_clusters(*terms)
            assert np.array_equal(np.sort(inv[r_loop]), want)
            assert np.array_equal(np.sort(inv[r_merge]), want)
            assert np.array_equal(docs[ptr[i] : ptr[i + 1]], r_loop)
            for key, v in w_loop.items():
                sums[key] = sums.get(key, 0.0) + v
        # batched work dict == summed loop dicts, per-level keys included
        for key, v in sums.items():
            assert work[key] == v, key


def test_hier_L2_bit_identical_to_cluster_index(rng):
    corpus, index, reordered, perm, cidx, h1, h2, h3 = _nested_setup(
        rng, 220, 120, k=9, k0=3
    )
    cq = _random_ragged_queries(rng, 40, 120)
    for terms in cq:
        r_f, w_f = cidx.query(*terms)
        r_h, w_h = h2.query(*terms)
        assert np.array_equal(r_f, r_h) and w_f == w_h
        r_fa, w_fa = cidx.query_all_clusters(*terms)
        r_ha, w_ha = h2.query_all_clusters(*terms)
        assert np.array_equal(r_fa, r_ha) and w_fa == w_ha
    ptr_f, docs_f, work_f = cidx.query_batch(cq)
    ptr_h, docs_h, work_h = batched_query(h2, cq)
    assert np.array_equal(ptr_f, ptr_h)
    assert np.array_equal(docs_f, docs_h)
    assert work_f == work_h
    # the facade's L = 2 view shares the arrays, no copies
    assert as_hier(cidx).levels[0].cl_ids is cidx.cl_ids


def test_hier_L1_is_the_flat_lookup_chain(rng):
    corpus, index, reordered, perm, cidx, h1, h2, h3 = _nested_setup(
        rng, 180, 90, k=7, k0=2
    )
    cq = _random_ragged_queries(rng, 40, 90)
    ptr, docs, work = batched_query(h1, cq)
    ptr_l, docs_l, work_l = batched_lookup(reordered, cq, bucket_size=16)
    assert np.array_equal(ptr, ptr_l) and np.array_equal(docs, docs_l)
    assert work["probes"] == work_l["probes"]
    assert work["scanned"] == work_l["scanned"]
    assert work["cluster_level"] == 0.0
    for terms in cq:
        r, w = h1.query(*terms)
        want, chain_work = chain_lookup(
            [reordered.postings(int(t)) for t in terms], reordered.n_docs, 16
        )
        assert np.array_equal(r, want)
        assert w["total"] == chain_work


def test_hier_empty_postings_absent_terms_k1(rng):
    corpus, index, reordered, perm, cidx, h1, h2, h3 = _nested_setup(
        rng, 150, 500, k=1, k0=1
    )
    df = np.diff(index.post_ptr)
    empty = np.flatnonzero(df == 0)
    alive = np.flatnonzero(df > 0)
    assert len(empty) >= 3
    inv = np.empty(150, np.int64)
    inv[perm] = np.arange(150)
    cq = ConjunctiveQueries.from_lists(
        [
            [int(empty[0])],
            [int(empty[0]), int(empty[1]), int(empty[2])],
            [int(alive[0]), int(empty[0]), int(alive[1])],
            [int(alive[0]), int(alive[1]), int(alive[2])],
            [int(alive[3])],
        ]
    )
    for h in (h1, h2, h3):
        ptr, docs, _ = batched_query(h, cq)
        assert ptr[1] == 0 and ptr[2] == 0 and ptr[3] == 0
        for i, terms in enumerate(cq):
            r, _ = h.query(*terms)
            assert np.array_equal(docs[ptr[i] : ptr[i + 1]], r)
            assert np.array_equal(np.sort(inv[r]), _brute(index, terms))


def test_build_hier_index_validates_ranges(rng):
    corpus = _random_corpus(rng, 60, 30)
    index = build_index(corpus)
    with pytest.raises(ValueError, match="boundary array"):
        build_hier_index(index, [np.array([0, 10])])  # doesn't span [0, n]
    leaf = np.array([0, 20, 40, 60])
    with pytest.raises(ValueError, match="not nested"):
        build_hier_index(index, [np.array([0, 30, 60]), leaf])
    # nested is fine
    h = build_hier_index(index, [np.array([0, 40, 60]), leaf])
    assert h.depth == 3 and h.levels[0].k == 2 and h.k == 3


# ----------------------------------------------------------------------
# Pipeline: fit(levels=L)
# ----------------------------------------------------------------------


def _fit(corpus, log, algo, levels, k=10, seed=0):
    pipe = SecludPipeline(tc=600, doc_grained_below=128, seed=seed)
    return pipe, pipe.fit(corpus, k=k, algo=algo, log=log, levels=levels)


def test_fit_levels_nested_ranges_and_psi(small_corpus, small_log):
    pipe, res = _fit(small_corpus, small_log, "topdown", levels=4)
    assert res.levels == 4 and res.hier_index.depth == 4
    assert len(res.level_ranges) == 3 == len(res.psi_levels)
    # nesting: every coarser boundary is a boundary of the next finer level
    for coarse, fine in zip(res.level_ranges, res.level_ranges[1:], strict=False):
        assert np.isin(coarse, fine).all()
    assert np.array_equal(res.level_ranges[-1], res.ranges)
    # coarser levels can only merge lists -> ψ never decreases going up
    assert res.psi_levels[-1] == res.psi
    assert all(
        a >= b - 1e-9 for a, b in zip(res.psi_levels, res.psi_levels[1:], strict=False)
    )
    # leaf assignment is consistent with the nested reorder
    assert np.array_equal(
        cluster_ranges(res.assign, res.k), res.level_ranges[-1]
    )
    assert np.array_equal(reorder_permutation(res.assign, res.k), res.perm)


@pytest.mark.parametrize("levels", [1, 3])
def test_evaluate_reports_hier_and_stays_lossless(
    small_corpus, small_log, levels
):
    pipe, res = _fit(small_corpus, small_log, "topdown", levels=levels)
    ev_loop = pipe.evaluate(small_corpus, res, small_log, max_queries=50)
    ev_bat = pipe.evaluate(
        small_corpus, res, small_log, max_queries=50, batched=True
    )
    assert ev_loop["depth"] == float(levels)
    for key in ("S_H", "work_hier", "S_T_hier", "S_C", "S_R", "S_T"):
        assert ev_bat[key] == ev_loop[key], key
    assert ev_loop["work_hier"] > 0
    if levels == 1:
        # flat hier == the reordered single-index Lookup... except L=1
        # never reorders (one cluster), so it matches the S_R path run
        # on its identity permutation exactly.
        assert ev_loop["work_hier"] == ev_loop["work_reordered"]


def test_topdown_and_fm_hierarchies_return_identical_results(rng):
    """Satellite: a HierIndex grown from TopDown leaf assignments answers
    exactly like the FM-grown one (and like intersect1d), including empty
    postings, absent terms, duplicate terms and k = 1."""
    for trial, (n_docs, n_terms, k) in enumerate(
        [(140, 400, 8), (90, 60, 1), (200, 150, 12)]
    ):
        corpus = _random_corpus(np.random.default_rng(100 + trial), n_docs, n_terms)
        from repro.data.query_log import synth_query_log

        log = synth_query_log(corpus, n_queries=60, seed=trial)
        index = build_index(corpus)
        _, res_td = _fit(corpus, log, "topdown", levels=3, k=k, seed=trial)
        _, res_fm = _fit(corpus, log, "flat", levels=3, k=k, seed=trial)
        assert res_td.hier_index.depth == res_fm.hier_index.depth == 3
        inv_td = np.empty(n_docs, np.int64)
        inv_td[res_td.perm] = np.arange(n_docs)
        inv_fm = np.empty(n_docs, np.int64)
        inv_fm[res_fm.perm] = np.arange(n_docs)
        df = np.diff(index.post_ptr)
        absent = np.flatnonzero(df == 0)
        qrng = np.random.default_rng(1000 + trial)
        cq = _random_ragged_queries(qrng, 30, n_terms)
        if len(absent):
            cq = ConjunctiveQueries.from_lists(
                [list(t) for t in cq]
                + [[int(absent[0])], [int(absent[0]), int(qrng.integers(n_terms))]]
            )
        for terms in cq:
            want = _brute(index, terms)
            r_td, _ = res_td.hier_index.query(*terms)
            r_fm, _ = res_fm.hier_index.query(*terms)
            assert np.array_equal(np.sort(inv_td[r_td]), want)
            assert np.array_equal(np.sort(inv_fm[r_fm]), want)


# ----------------------------------------------------------------------
# Descent pricing
# ----------------------------------------------------------------------


def test_hier_query_set_cost_recovers_eq2_at_L2(small_corpus, small_log):
    pipe, res = _fit(small_corpus, small_log, "topdown", levels=2)
    queries = small_log.queries[:80]
    hc = hier_query_set_cost(
        small_corpus,
        res.level_assigns,
        [len(r) - 1 for r in res.level_ranges],
        queries,
    )
    legacy = query_set_cost(small_corpus, res.assign, res.k, queries)
    assert hc["postings"] == legacy  # Eq. 2 recovered at L = 2
    assert hc["total"] == hc["postings"] + hc["level_0"]
    assert hc["level_0"] >= 0
    # L = 1: no cluster levels, pure unclustered baseline
    flat = hier_query_set_cost(small_corpus, [], [], queries)
    assert flat["total"] == flat["postings"] == query_set_cost(
        small_corpus, None, 1, queries
    )
    # empty query set prices to zero
    zero = hier_query_set_cost(
        small_corpus, res.level_assigns, [res.k], queries[:0]
    )
    assert zero["total"] == 0.0


# ----------------------------------------------------------------------
# Serving at depth
# ----------------------------------------------------------------------


def test_search_service_routes_through_hierarchy(small_corpus, small_log):
    from repro.serve.search_service import SearchService

    pipe, res = _fit(small_corpus, small_log, "topdown", levels=3)
    svc = SearchService(res)
    assert isinstance(svc.query_index, HierIndex)
    assert svc.query_index.depth == 3
    queries = small_log.queries[:30]
    counts, work = svc.serve_counts(queries)
    # host counts == looping the hierarchical query
    total = 0.0
    for qi, terms in enumerate(np.asarray(queries)):
        r, w = res.hier_index.query(*[int(t) for t in terms])
        assert counts[qi] == len(r)
        total += w["total"]
    assert work["work"] == total
    # device path, pinned and unpinned, agrees with the host
    from repro.serve.search_service import SearchService as S

    packed = svc.pack(queries)
    np.testing.assert_array_equal(np.asarray(S.device_counts(packed)), counts)
    pinned = svc.pack(queries, pin_top=True)
    assert pinned.row_top is not None
    assert np.all(np.diff(pinned.row_top) >= 0)  # grouped by level-0 node
    assert packed.row_top.min() >= 0
    assert packed.row_top.max() < res.hier_index.levels[0].k
    np.testing.assert_array_equal(
        np.asarray(S.device_counts(pinned)), counts
    )


def test_pack_row_top_equals_cluster_at_L2(small_corpus, small_log):
    from repro.serve.search_service import SearchService

    pipe, res = _fit(small_corpus, small_log, "topdown", levels=2)
    svc = SearchService(res)
    packed = svc.pack(small_log.queries[:20])
    # at L = 2 the top level IS the leaf level: row_top = leaf cluster,
    # recoverable from the rank-0 segment's first doc id (leaf segments
    # are never empty — a cluster is listed only if it holds the term).
    leaf = (
        np.searchsorted(
            res.ranges, packed.segments[0][:, 0].astype(np.int64), side="right"
        )
        - 1
    )
    np.testing.assert_array_equal(packed.row_top, leaf)
