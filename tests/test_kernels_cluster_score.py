import numpy as np
import pytest

from repro.kernels.cluster_score.ops import cluster_scores, embedding_bag
from repro.kernels.cluster_score.ref import cluster_scores_ref

pytestmark = pytest.mark.slow  # Pallas kernel sweeps in interpret mode


def _inputs(rng, n, l, tc, k, pad_frac=0.3):
    ell = rng.integers(0, tc, size=(n, l)).astype(np.int32)
    pad = rng.random((n, l)) < pad_frac
    ell[pad] = tc  # pad slot
    p = rng.random(tc).astype(np.float32)
    tables = rng.standard_normal((tc, k)).astype(np.float32)
    return ell, p, tables


def _brute(ell, p, tables):
    n, l = ell.shape
    tc, k = tables.shape
    out = np.zeros((n, k), np.float64)
    for d in range(n):
        for t in ell[d]:
            if t < tc:
                out[d] += p[t] * tables[t]
    return out.astype(np.float32)


@pytest.mark.parametrize(
    "n,l,tc,k",
    [(4, 8, 32, 4), (16, 128, 128, 8), (10, 50, 300, 33), (32, 64, 1024, 128)],
)
def test_scores_match_brute(n, l, tc, k):
    rng = np.random.default_rng(n + l + tc + k)
    ell, p, tables = _inputs(rng, n, l, tc, k)
    want = _brute(ell, p, tables)
    got_ref = np.asarray(cluster_scores_ref(ell, p, tables))
    got_kern = np.asarray(cluster_scores(ell, p, tables, force_kernel=True))
    np.testing.assert_allclose(got_ref, want, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(got_kern, want, rtol=2e-5, atol=1e-5)


def test_tile_sweep():
    rng = np.random.default_rng(0)
    ell, p, tables = _inputs(rng, 8, 40, 200, 16)
    want = _brute(ell, p, tables)
    for bd, tt, lc in [(8, 64, 64), (16, 128, 128), (8, 256, 32)]:
        got = np.asarray(
            cluster_scores(
                ell, p, tables, block_d=bd, tile_t=tt, chunk_l=lc, force_kernel=True
            )
        )
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


def test_all_pad():
    tc, k = 64, 8
    ell = np.full((4, 16), tc, np.int32)
    p = np.ones(tc, np.float32)
    tables = np.ones((tc, k), np.float32)
    got = np.asarray(cluster_scores(ell, p, tables, force_kernel=True))
    np.testing.assert_array_equal(got, 0.0)


def test_duplicate_terms_accumulate():
    tc, k = 16, 4
    ell = np.array([[3, 3, 3, tc]], np.int32)
    p = np.arange(1, tc + 1, dtype=np.float32)
    tables = np.eye(tc, k, dtype=np.float32)
    got = np.asarray(cluster_scores(ell, p, tables, force_kernel=True))
    want = np.zeros((1, k), np.float32)
    want[0, 3] = 3 * p[3]
    np.testing.assert_allclose(got, want)


def test_embedding_bag_matches_ref():
    rng = np.random.default_rng(2)
    ids = rng.integers(0, 100, size=(6, 10)).astype(np.int32)
    table = rng.standard_normal((100, 12)).astype(np.float32)
    got = np.asarray(embedding_bag(ids, table))
    want = table[ids].sum(axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # Weighted variant.
    w = rng.random((6, 10)).astype(np.float32)
    got_w = np.asarray(embedding_bag(ids, table, weights=w))
    want_w = (w[..., None] * table[ids]).sum(axis=1)
    np.testing.assert_allclose(got_w, want_w, rtol=1e-5, atol=1e-5)
